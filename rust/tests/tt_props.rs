//! Property tests for the TT algebra the adapters are built on:
//!
//! 1. The [`TtChain`] contraction behind `MetaTt::delta_w` equals the
//!    corresponding slice of the densely materialized TT tensor, for random
//!    ranks and slice axes, across all three MetaTT variants (paper Eqs.
//!    5–6: the chain *is* the stacked ΔW bank).
//! 2. The DMRG merge → SVD → split primitive (Algorithm 1's inner move) is
//!    exact at full rank: the merged two-core matrix is preserved by both
//!    the left- and right-canonical splits, and a full-rank double sweep
//!    leaves the represented tensor untouched.

use metatt::linalg::truncated_svd_with_tail;
use metatt::tensor::{rel_err, Tensor};
use metatt::testutil::prop_check;
use metatt::tt::{dmrg_sweep, CoreInit, InitStrategy, MetaTt, MetaTtDims, MetaTtKind, TtChain};
use metatt::util::rng::Pcg64;

fn small_dims() -> MetaTtDims {
    MetaTtDims { d_in: 8, d_out: 8, layers: 3, matrices: 2, heads: 2, tasks: 3 }
}

/// Flat row-major index into a materialized tensor with the given modes.
fn flat(modes: &[usize], idx: &[usize]) -> usize {
    assert_eq!(modes.len(), idx.len());
    let mut off = 0;
    for (m, i) in modes.iter().zip(idx) {
        debug_assert!(i < m);
        off = off * m + i;
    }
    off
}

/// ΔW slice read directly out of the dense materialized chain.
fn dense_delta_w(tt: &MetaTt, layer: usize, matrix: usize, task: usize) -> Tensor {
    let dims = tt.dims;
    let modes = MetaTt::mode_sizes(tt.kind, &dims);
    let full = tt.chain.materialize();
    let mut out = Tensor::zeros(&[dims.d_in, dims.d_out]);
    match tt.kind {
        MetaTtKind::FourD => {
            for i in 0..dims.d_in {
                for j in 0..dims.d_out {
                    let v = full.data()[flat(&modes, &[i, layer, matrix, j])];
                    out.set(i, j, v);
                }
            }
        }
        MetaTtKind::FiveD => {
            let dh = dims.d_out / dims.heads;
            for i in 0..dims.d_in {
                for h in 0..dims.heads {
                    for j in 0..dh {
                        let v = full.data()[flat(&modes, &[i, layer, matrix, h, j])];
                        out.set(i, h * dh + j, v);
                    }
                }
            }
        }
        MetaTtKind::FourPlusOneD => {
            for i in 0..dims.d_in {
                for j in 0..dims.d_out {
                    let v = full.data()[flat(&modes, &[i, layer, task, matrix, j])];
                    out.set(i, j, v);
                }
            }
        }
    }
    out
}

#[test]
fn chain_contraction_matches_dense_delta_w_slice() {
    prop_check("delta_w == dense slice", 15, |rng, case| {
        let kind = [MetaTtKind::FourD, MetaTtKind::FiveD, MetaTtKind::FourPlusOneD][case % 3];
        let dims = small_dims();
        let rank = 1 + rng.uniform_usize(5); // random rank in [1, 5]
        let init = InitStrategy { cores: vec![CoreInit::Normal; kind.order()] };
        let tt = MetaTt::new(kind, dims, rank, 1.0, &init, rng);
        let layer = rng.uniform_usize(dims.layers);
        let matrix = rng.uniform_usize(dims.matrices);
        let task = rng.uniform_usize(dims.tasks);
        let via_chain = tt.delta_w(layer, matrix, task);
        let via_dense = dense_delta_w(&tt, layer, matrix, task);
        let err = rel_err(&via_chain, &via_dense);
        if err < 1e-4 {
            Ok(())
        } else {
            Err(format!(
                "{kind:?} r={rank} (l={layer}, m={matrix}, t={task}): rel_err {err}"
            ))
        }
    });
}

#[test]
fn zero_init_chain_materializes_to_zero_everywhere() {
    // The paper-default ze-id-… init must be the zero map on EVERY slice,
    // not just the ones the training loop happens to touch.
    let mut rng = Pcg64::new(11);
    for kind in [MetaTtKind::FourD, MetaTtKind::FiveD, MetaTtKind::FourPlusOneD] {
        let tt = MetaTt::new_default(kind, small_dims(), 3, 1.0, &mut rng);
        assert_eq!(tt.chain.materialize().max_abs(), 0.0, "{kind:?}");
    }
}

fn random_chain(rng: &mut Pcg64, modes: &[usize], rank: usize) -> TtChain {
    let d = modes.len();
    let cores = (0..d)
        .map(|k| {
            let rl = if k == 0 { 1 } else { rank };
            let rr = if k == d - 1 { 1 } else { rank };
            Tensor::randn(&[rl, modes[k], rr], 0.5, rng)
        })
        .collect();
    TtChain::new(cores)
}

#[test]
fn dmrg_merge_svd_split_roundtrip_is_exact_at_full_rank() {
    prop_check("merge→tSVD→split exact at full rank", 8, |rng, case| {
        let modes = [4, 3, 5, 2];
        let rank = 2 + case % 3;
        let tt = random_chain(rng, &modes, rank);
        for bond in 0..tt.order() - 1 {
            let merged = tt.merge_pair(bond);
            let full_rank = merged.rows().min(merged.cols());
            let (svd, dropped) = truncated_svd_with_tail(&merged, full_rank);
            if dropped > 1e-5 {
                return Err(format!("bond {bond}: full-rank SVD dropped {dropped}"));
            }
            // u·s·vt reconstructs the merged two-core tensor…
            let err = rel_err(&svd.reconstruct(), &merged);
            if err > 1e-4 {
                return Err(format!("bond {bond}: reconstruct err {err}"));
            }
            // …and so do both canonical splits (U)(S·Vᵀ) and (U·S)(Vᵀ).
            let (u, svt) = svd.split_left_canonical();
            let err_l = rel_err(&u.matmul(&svt), &merged);
            let (us, vt) = svd.split_right_canonical();
            let err_r = rel_err(&us.matmul(&vt), &merged);
            if err_l > 1e-4 || err_r > 1e-4 {
                return Err(format!("bond {bond}: split errs {err_l} / {err_r}"));
            }
        }
        Ok(())
    });
}

#[test]
fn full_rank_double_sweep_preserves_tensor() {
    let mut rng = Pcg64::new(21);
    let mut tt = random_chain(&mut rng, &[4, 3, 4, 3], 4);
    let before = tt.materialize();
    let report = dmrg_sweep(&mut tt, &|_| 64); // cap far above any bond
    let after = tt.materialize();
    assert!(
        rel_err(&after, &before) < 1e-4,
        "full-rank sweep changed the tensor: {}",
        rel_err(&after, &before)
    );
    assert!(report.max_dropped() < 1e-5);
}
