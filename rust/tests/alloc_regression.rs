//! Allocation-regression guard for the zero-allocation hot path (PR 3).
//!
//! A counting global allocator wraps `System`; after a warmup step has
//! populated the bound step's workspace arena, a steady-state train step
//! (fwd + bwd, adapter grads, single thread) must perform **zero** heap
//! allocations — every intermediate is a pooled checkout and the gradient
//! buffers round-trip through `Step::recycle`.
//!
//! This file deliberately contains a SINGLE test: the counter is
//! process-global, so a sibling test running on another libtest thread
//! would pollute the measured window. (Other allocation-sensitive checks
//! live inside the same test body.) The measurement takes the *minimum*
//! delta over several steps so an unrelated one-off allocation elsewhere
//! in the process cannot flake the assertion — a real regression in the
//! step itself allocates on every iteration and keeps the minimum > 0.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

use metatt::adapters::{AdapterKind, AdapterSpec};
use metatt::config::ModelPreset;
use metatt::data::{Batcher, TaskId};
use metatt::runtime::{assemble_frozen, ArtifactSpec, Backend, RefBackend, StepKind};
use metatt::tensor::Tensor;
use metatt::tt::{CoreInit, InitStrategy, MetaTtKind};
use metatt::util::rng::Pcg64;

fn allocs() -> u64 {
    ALLOC_COUNT.load(Ordering::SeqCst)
}

#[test]
fn warmed_train_step_is_allocation_free_with_arena() {
    let backend = RefBackend::with_config(1, true).unwrap();
    let spec = ArtifactSpec {
        step: StepKind::Train,
        model: "tiny".into(),
        adapter: "metatt4d".into(),
        rank: 4,
        classes: 2,
        tasks: 1,
        batch: 8,
        seq: 16,
    };
    let entry = backend.entry(&spec).unwrap();
    let frozen = std::sync::Arc::new(
        assemble_frozen(&entry, None, ModelPreset::Tiny).unwrap(),
    );
    let step = backend.bind(&spec, &frozen).unwrap();
    let mut rng = Pcg64::new(42);
    let params: Vec<Tensor> = entry
        .trainable_inputs()
        .iter()
        .map(|io| Tensor::randn(&io.shape, 0.2, &mut rng))
        .collect();
    let ds = TaskId::MrpcSyn.generate_at(8, 8, 3, 16, 512);
    let batch = Batcher::new(8).eval(&ds).remove(0);

    // Warmup: populate the arena (and normalize pooled shape-vector
    // capacities). Two steps so the grad buffers have round-tripped
    // through recycle at least once before measuring.
    let (ref_loss, ref_grads) = step.run_train(&params, &batch, 0, 1.5).unwrap();
    let ref_g0 = ref_grads[0].clone();
    step.recycle(ref_grads);
    let (_, g) = step.run_train(&params, &batch, 0, 1.5).unwrap();
    step.recycle(g);

    // Steady state: minimum allocation delta over several repeats must be
    // exactly zero (a per-step regression allocates on every iteration).
    let mut min_delta = u64::MAX;
    for _ in 0..5 {
        let before = allocs();
        let (loss, grads) = step.run_train(&params, &batch, 0, 1.5).unwrap();
        let after = allocs();
        min_delta = min_delta.min(after - before);
        // Steps are pure: the warmed loop must also stay bit-stable, and
        // the pooled buffers must come back zeroed (not holding stale
        // gradients from the previous step).
        assert_eq!(loss.to_bits(), ref_loss.to_bits(), "loss drifted across steps");
        assert_eq!(grads[0], ref_g0, "grad_g1 drifted across steps");
        step.recycle(grads);
    }
    assert_eq!(
        min_delta, 0,
        "warmed-up train step heap-allocated (min over 5 steps); \
         an intermediate is bypassing the workspace arena"
    );

    // --- Serving tick (PR 5): a warmed folded-adapter `run_serve` must
    // also be allocation-free — logits are written into a caller buffer,
    // the folded factors are pre-built, and the frozen forward GEMMs run
    // off the bind-time packed panels. (Same test body: the allocation
    // counter is process-global, see the module docs.)
    let serve_spec = ArtifactSpec {
        step: StepKind::Eval,
        model: "tiny".into(),
        adapter: "metatt4d".into(),
        rank: 4,
        classes: 2,
        tasks: 1,
        batch: 8,
        seq: 16,
    };
    let serve_step = backend.bind(&serve_spec, &frozen).unwrap();
    let aspec = AdapterSpec::new(
        AdapterKind::MetaTt(MetaTtKind::FourD),
        4,
        1.5,
        ModelPreset::Tiny.dims(1),
    );
    let init = InitStrategy { cores: vec![CoreInit::Normal; 4] };
    let tt = aspec.build_metatt_with(&mut rng, Some(&init));
    let folded = tt.fold_for_serving(0);
    let tokens = batch.tokens.clone(); // 8 x 16, valid ids
    let mut out = vec![0f32; 8 * 2];
    serve_step.run_serve(&folded, &tokens, 0, &mut out).unwrap();
    let ref_logits = out.clone();
    serve_step.run_serve(&folded, &tokens, 0, &mut out).unwrap();
    let mut min_serve_delta = u64::MAX;
    for _ in 0..5 {
        let before = allocs();
        serve_step.run_serve(&folded, &tokens, 0, &mut out).unwrap();
        let after = allocs();
        min_serve_delta = min_serve_delta.min(after - before);
        for (a, b) in out.iter().zip(&ref_logits) {
            assert_eq!(a.to_bits(), b.to_bits(), "serving logits drifted across ticks");
        }
    }
    assert_eq!(
        min_serve_delta, 0,
        "warmed-up serving tick heap-allocated (min over 5 ticks); \
         the folded-inference path is bypassing the workspace arena"
    );

    // --- Observability hooks (PR 10): an UNARMED Obs must add nothing to
    // the path above — every `event()` is a single relaxed atomic load and
    // the disarmed tracer owns no rings, so a serve tick interleaved with
    // the full request-lifecycle hook sequence stays at zero allocations.
    // (Same test body: the allocation counter is process-global.)
    use metatt::obs::{EventCode, Obs};
    let obs = Obs::new(false);
    assert!(!obs.armed());
    // Warm any lazy statics in the hook path (thread-local ring key).
    obs.event(EventCode::Admit, 0, 0);
    obs.event_at(0, EventCode::TickEnd, 0, 0);
    metatt::obs::global_event(EventCode::CkptSave, 0, 0);
    let mut min_obs_delta = u64::MAX;
    for i in 0..5 {
        let before = allocs();
        // The per-request lifecycle, as the engine stamps it around a tick.
        obs.event(EventCode::Admit, i, 0);
        obs.event(EventCode::BatchFormed, i, 0);
        obs.event(EventCode::TickStart, 0, 8);
        serve_step.run_serve(&folded, &tokens, 0, &mut out).unwrap();
        obs.event_at(obs.now_us(), EventCode::TickEnd, 0, 0);
        obs.event(EventCode::ResponseWritten, i, 0);
        metatt::obs::global_event(EventCode::CkptSave, 0, 0);
        let after = allocs();
        min_obs_delta = min_obs_delta.min(after - before);
    }
    assert_eq!(
        min_obs_delta, 0,
        "unarmed observability hooks heap-allocated around a warmed serve \
         tick; the disarmed fast path must be a single relaxed load"
    );
    assert_eq!(obs.tracer().recorded(), 0, "disarmed hooks must record nothing");
}
