//! Tracer self-tests and engine-integration checks for the PR 10
//! observability layer.
//!
//! The load-bearing properties:
//!
//! 1. **Exact loss accounting** — a full ring wraps over its *oldest*
//!    events and `dropped()` counts every lost event exactly; nothing is
//!    lost silently.
//! 2. **Disarmed is inert** — a disarmed `Obs` records nothing no matter
//!    how many hooks fire (the zero-allocation side is pinned in
//!    `tests/alloc_regression.rs`).
//! 3. **Lifecycle ordering** — an armed engine run emits every request
//!    lifecycle stage, and per request the span timestamps and the wire
//!    stage stamps are monotone: admit ≤ batch-formed ≤ tick-start ≤
//!    tick-end ≤ response-written.
//! 4. **Faults are visible** — `slow_tick=<D>ms@p=1.0` yields tick spans
//!    (and `slow_tick` span payloads) of at least D.

use metatt::adapters::AdapterKind;
use metatt::config::ModelPreset;
use metatt::obs::{self, EventCode, Obs};
use metatt::runtime::RefBackend;
use metatt::serving::{
    adapter_spec_for, request_stream, EngineConfig, LoadGenConfig, Response, ServingEngine,
};
use metatt::tensor::DtypeKind;
use metatt::tt::{CoreInit, InitStrategy, MetaTt, MetaTtKind};
use metatt::util::fault::FaultPlan;
use metatt::util::rng::Pcg64;
use std::sync::Arc;
use std::time::Duration;

const TASKS: usize = 3;

fn engine_cfg(workers: usize, obs: Arc<Obs>, faults: FaultPlan) -> EngineConfig {
    EngineConfig {
        model: ModelPreset::Tiny,
        adapter: AdapterKind::MetaTt(MetaTtKind::FourPlusOneD),
        rank: 4,
        alpha: 1.3,
        num_tasks: TASKS,
        classes: 2,
        max_batch: 4,
        batch_deadline: Duration::from_millis(1),
        queue_capacity: 128,
        workers,
        cache_capacity_bytes: 64 << 20,
        dtype: DtypeKind::F32,
        faults: Arc::new(faults),
        obs,
    }
}

fn demo_tt(cfg: &EngineConfig, seed: u64) -> MetaTt {
    let spec = adapter_spec_for(cfg);
    let init = InitStrategy {
        cores: vec![CoreInit::Normal; MetaTtKind::FourPlusOneD.order()],
    };
    spec.build_metatt_with(&mut Pcg64::new(seed), Some(&init))
}

/// Serve a deterministic stream through an engine built around `obs` and
/// return the responses in request order.
fn serve_with(obs: Arc<Obs>, faults: FaultPlan, count: usize) -> Vec<Response> {
    let backend = RefBackend::with_config(1, true).unwrap();
    let cfg = engine_cfg(2, obs, faults);
    let tt = demo_tt(&cfg, 11);
    let dims = ModelPreset::Tiny.dims(TASKS);
    let lcfg = LoadGenConfig { seed: 33, ..Default::default() };
    let stream = request_stream(&lcfg, TASKS, dims.max_seq, dims.vocab, 0, count);
    let engine = ServingEngine::new(&backend, cfg, tt, None).unwrap();
    engine
        .serve(|eng| {
            let handles: Vec<_> = stream
                .iter()
                .map(|(task, tokens)| eng.submit(*task, tokens.clone()).unwrap())
                .collect();
            handles.into_iter().map(|h| h.wait().unwrap()).collect::<Vec<_>>()
        })
        .unwrap()
}

#[test]
fn ring_wraparound_drops_oldest_with_exact_count() {
    // One ring of 8 slots, 20 single-threaded records: the 8 newest
    // survive, exactly 12 are dropped, and `recorded` counts all 20.
    let obs = Obs::with_rings(true, 1, 8);
    for i in 0..20u64 {
        obs.event_at(i, EventCode::Admit, i, 0);
    }
    let t = obs.tracer();
    assert_eq!(t.recorded(), 20);
    assert_eq!(t.dropped(), 12, "wraparound must count every overwritten event");
    let events = t.snapshot();
    assert_eq!(events.len(), 8, "only the ring's capacity survives");
    let ids: Vec<u64> = events.iter().map(|e| e.a).collect();
    assert_eq!(ids, (12..20).collect::<Vec<u64>>(), "oldest events are the ones dropped");
}

#[test]
fn full_ring_pool_counts_unclaimed_thread_drops() {
    // Two threads, one ring: the loser of the claim race loses its events
    // to `dropped()`, never silently.
    let obs = Arc::new(Obs::with_rings(true, 1, 64));
    let mut joins = Vec::new();
    for t in 0..2u64 {
        let obs = Arc::clone(&obs);
        joins.push(std::thread::spawn(move || {
            for i in 0..10 {
                obs.event_at(i, EventCode::Admit, t, i);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let t = obs.tracer();
    assert_eq!(
        t.recorded() + t.dropped(),
        20,
        "every event is either recorded or counted as dropped"
    );
    assert_eq!(t.recorded(), 10, "a single ring admits exactly one thread's events");
}

#[test]
fn disarmed_obs_records_nothing_across_all_hooks() {
    let obs = Obs::new(false);
    for i in 0..100 {
        obs.event(EventCode::Admit, i, 0);
        obs.event_at(i, EventCode::TickEnd, 0, i);
    }
    assert_eq!(obs.tracer().recorded(), 0);
    assert_eq!(obs.tracer().dropped(), 0);
    assert!(obs.tracer().snapshot().is_empty());
    assert_eq!(obs.chrome_trace(), "{\"displayTimeUnit\":\"ms\",\"traceEvents\":[]}");
}

#[test]
fn armed_engine_run_emits_monotone_lifecycle_spans() {
    let obs = Arc::new(Obs::new(true));
    let responses = serve_with(Arc::clone(&obs), FaultPlan::empty(), 24);
    assert_eq!(responses.len(), 24);

    // Wire stage stamps: every computed response carries a complete,
    // monotone admit ≤ batch ≤ start ≤ end ≤ done chain.
    for r in &responses {
        assert!(r.stamps.complete(), "incomplete stamps on request {}: {:?}", r.id, r.stamps);
        assert!(r.stamps.start_us <= r.stamps.end_us, "tick inverted on request {}", r.id);
        assert!(r.stamps.end_us <= r.done_us, "done precedes tick end on request {}", r.id);
    }

    // Span stream: at least one event per lifecycle stage...
    let events = obs.tracer().snapshot();
    for code in [
        EventCode::Admit,
        EventCode::BatchFormed,
        EventCode::TickStart,
        EventCode::TickEnd,
        EventCode::ResponseWritten,
        EventCode::CacheFold,
    ] {
        assert!(
            events.iter().any(|e| e.code == code),
            "no {} span in an armed run ({} events)",
            code.name(),
            events.len()
        );
    }
    // ...and per request the lifecycle timestamps never run backwards.
    for r in &responses {
        let at = |code: EventCode| {
            events.iter().find(|e| e.code == code && e.a == r.id).map(|e| e.ts_us)
        };
        let (admit, formed, written) = (
            at(EventCode::Admit),
            at(EventCode::BatchFormed),
            at(EventCode::ResponseWritten),
        );
        // Ring pressure may have evicted early events; order what survived.
        if let (Some(a), Some(f)) = (admit, formed) {
            assert!(a <= f, "admit after batch-formed for request {}", r.id);
        }
        if let (Some(f), Some(w)) = (formed, written) {
            assert!(f <= w, "batch-formed after response-written for request {}", r.id);
        }
    }

    // The metrics registry saw the same traffic: stage histograms filled
    // and the Prometheus rendering exposes them.
    assert!(obs.stages.compute_us.count() > 0, "compute histogram never observed");
    let mut text = String::new();
    obs.render(&mut text);
    assert!(text.contains("metatt_stage_compute_us"), "{text}");
    assert!(text.contains("metatt_trace_armed 1"), "{text}");

    // Chrome export parses structurally: one X event per tick span.
    let json = obs.chrome_trace();
    assert!(json.contains("\"ph\":\"X\""), "tick spans must export as complete events");
    assert!(json.contains("\"name\":\"admit\""), "{json}");
}

#[test]
fn slow_tick_fault_is_visible_in_tick_spans() {
    let obs = Arc::new(Obs::new(true));
    let plan = FaultPlan::parse("slow_tick=20ms@p=1.0,seed=5").unwrap();
    let responses = serve_with(Arc::clone(&obs), plan, 8);
    assert_eq!(responses.len(), 8);
    let events = obs.tracer().snapshot();
    let ticks: Vec<_> = events.iter().filter(|e| e.code == EventCode::TickEnd).collect();
    assert!(!ticks.is_empty(), "no tick spans recorded");
    for e in &ticks {
        // TickEnd carries its start timestamp in `b`: span length ≥ the
        // injected 20 ms sleep.
        assert!(
            e.ts_us.saturating_sub(e.b) >= 20_000,
            "tick span shorter than the injected slow_tick: {} µs",
            e.ts_us.saturating_sub(e.b)
        );
    }
    assert!(
        events.iter().any(|e| e.code == EventCode::SlowTick && e.a >= 20_000),
        "slow_tick span with the slept duration must be recorded"
    );
}

#[test]
fn global_handle_feeds_checkpoint_events() {
    // `set_global` routes the free-function checkpoint hooks into this
    // Obs; clearing it disarms them again.
    let obs = Arc::new(Obs::new(true));
    obs::set_global(Some(Arc::clone(&obs)));
    let dir = std::env::temp_dir().join(format!("metatt_obs_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ck.bin");
    let t = metatt::tensor::Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
    metatt::coordinator::checkpoint::save(&path, &[("w".into(), t)]).unwrap();
    let _ = metatt::coordinator::checkpoint::load(&path).unwrap();
    obs::set_global(None);
    let events = obs.tracer().snapshot();
    let save = events.iter().find(|e| e.code == EventCode::CkptSave);
    let load = events.iter().find(|e| e.code == EventCode::CkptLoad);
    let _ = std::fs::remove_dir_all(&dir);
    let save = save.expect("save span missing");
    let load = load.expect("load span missing");
    assert!(save.a > 0, "save span must carry the byte count");
    assert_eq!(save.b, 0, "an intact save is not torn");
    // The save counts the 8-byte CRC trailer it lands; the load counts the
    // body it parses after verifying and stripping that trailer.
    assert_eq!(load.a + 8, save.a, "load body must be the save minus its trailer");
    assert_eq!(load.b, 1, "one tensor loaded");
}
