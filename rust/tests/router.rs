//! Router suite (PR 9): sharding is *transparent* — it changes which
//! queue a request waits in, never what is computed.
//!
//! The load-bearing properties:
//!
//! 1. **Bit identity across topologies** — a 1-shard engine and a 2x2
//!    replicated topology answer the same seeded request stream with
//!    bit-identical logits, request by request.
//! 2. **Affinity keeps folds hot** — on a skewed-by-construction task
//!    stream, affinity routing folds each task exactly once across the
//!    group while round-robin folds it on every replica; the cache hit
//!    rate ranks accordingly.
//! 3. **Degraded mode is explicit** — when every replica of a group is
//!    Down, admission still returns a handle and it resolves to an
//!    `Error` response naming the condition; nothing hangs or vanishes.
//! 4. **Config validation** — bad topologies and route policies are
//!    flag-time errors, not serve-time surprises.

use metatt::adapters::AdapterKind;
use metatt::config::ModelPreset;
use metatt::runtime::{assemble_frozen, ArtifactSpec, Backend, RefBackend, StepKind};
use metatt::serving::{
    adapter_spec_for, EngineConfig, ResponseStatus, RoutePolicy, RouterConfig, ServeTarget,
    ShardHealth, ShardRouter,
};
use metatt::tensor::DtypeKind;
use metatt::tt::{CoreInit, InitStrategy, MetaTt, MetaTtKind};
use metatt::util::fault::FaultPlan;
use metatt::util::rng::Pcg64;
use std::sync::Arc;
use std::time::Duration;

const TASKS: usize = 4;
const RANK: usize = 4;
const ALPHA: f32 = 1.1;

fn engine_cfg(workers: usize, faults: FaultPlan) -> EngineConfig {
    EngineConfig {
        model: ModelPreset::Tiny,
        adapter: AdapterKind::MetaTt(MetaTtKind::FourPlusOneD),
        rank: RANK,
        alpha: ALPHA,
        num_tasks: TASKS,
        classes: 2,
        max_batch: 4,
        batch_deadline: Duration::from_millis(1),
        queue_capacity: 64,
        workers,
        cache_capacity_bytes: 64 << 20,
        dtype: DtypeKind::F32,
        faults: Arc::new(faults),
        obs: Arc::new(metatt::obs::Obs::new(false)),
    }
}

fn router_cfg(shards: usize, replicas: usize, route: RoutePolicy) -> RouterConfig {
    RouterConfig {
        engine: engine_cfg(1, FaultPlan::empty()),
        shards,
        replicas,
        route,
        heartbeat: Duration::from_millis(10),
        failure_threshold: 3,
    }
}

fn demo_tt(seed: u64) -> MetaTt {
    let spec = adapter_spec_for(&engine_cfg(1, FaultPlan::empty()));
    let init = InitStrategy {
        cores: vec![CoreInit::Normal; MetaTtKind::FourPlusOneD.order()],
    };
    spec.build_metatt_with(&mut Pcg64::new(seed), Some(&init))
}

/// The deterministic request of `(client, index)`: pure function, so two
/// topologies (and the fault-free oracle) replay exactly the same stream.
fn stream_request(seq: usize, vocab: usize, client: usize, i: usize) -> (usize, Vec<i32>) {
    let mut rng = Pcg64::with_stream(700 + client as u64, i as u64);
    let task = (client + i) % TASKS;
    let tokens = (0..seq).map(|_| 1 + rng.uniform_usize(vocab - 1) as i32).collect();
    (task, tokens)
}

/// Drive `clients x per_client` closed-loop requests through a fresh
/// topology and return each one's logits, indexed `[client][i]`.
fn run_closed_loop(
    backend: &RefBackend,
    shards: usize,
    replicas: usize,
    tt: &MetaTt,
    clients: usize,
    per_client: usize,
) -> Vec<Vec<Vec<f32>>> {
    let router = ShardRouter::new(
        backend,
        router_cfg(shards, replicas, RoutePolicy::Affinity),
        |_| tt.clone(),
        None,
    )
    .unwrap();
    let seq = router.seq_len();
    let vocab = router.vocab();
    router
        .serve(|r| {
            std::thread::scope(|scope| {
                let handles: Vec<_> = (0..clients)
                    .map(|client| {
                        scope.spawn(move || {
                            (0..per_client)
                                .map(|i| {
                                    let (task, tokens) =
                                        stream_request(seq, vocab, client, i);
                                    let resp = r
                                        .submit_with(task, tokens, None, 0)
                                        .unwrap()
                                        .wait()
                                        .unwrap();
                                    assert_eq!(
                                        resp.status,
                                        ResponseStatus::Ok,
                                        "client {client} request {i}: {:?}",
                                        resp.error
                                    );
                                    resp.logits
                                })
                                .collect::<Vec<_>>()
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>()
            })
        })
        .unwrap()
}

#[test]
fn one_shard_and_a_replicated_topology_answer_bit_identically() {
    const CLIENTS: usize = 3;
    const PER_CLIENT: usize = 20;
    let backend = RefBackend::with_config(1, true).unwrap();
    let tt = demo_tt(9);
    let single = run_closed_loop(&backend, 1, 1, &tt, CLIENTS, PER_CLIENT);
    let sharded = run_closed_loop(&backend, 2, 2, &tt, CLIENTS, PER_CLIENT);

    for (client, (a, b)) in single.iter().zip(&sharded).enumerate() {
        assert_eq!(a.len(), b.len());
        for (i, (la, lb)) in a.iter().zip(b).enumerate() {
            assert_eq!(la.len(), lb.len());
            for (x, y) in la.iter().zip(lb) {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "client {client} request {i}: 1x1 logit {x} != 2x2 logit {y}"
                );
            }
        }
    }

    // Oracle: both topologies must also match a direct fault-free batch-1
    // forward of the same (task, tokens) — routing never changes compute.
    let dims = ModelPreset::Tiny.dims(TASKS);
    let spec = ArtifactSpec {
        step: StepKind::Eval,
        model: "tiny".into(),
        adapter: "metatt4p1d".into(),
        rank: RANK,
        classes: 2,
        tasks: TASKS,
        batch: 1,
        seq: dims.max_seq,
    };
    let entry = backend.entry(&spec).unwrap();
    let frozen = Arc::new(assemble_frozen(&entry, None, ModelPreset::Tiny).unwrap());
    let step = backend.bind(&spec, &frozen).unwrap();
    let folded: Vec<_> = (0..TASKS).map(|t| tt.fold_for_serving(t)).collect();
    let mut want = vec![0f32; 2];
    for (client, per) in sharded.iter().enumerate() {
        for (i, got) in per.iter().enumerate() {
            let (task, tokens) = stream_request(dims.max_seq, dims.vocab, client, i);
            step.run_serve(&folded[task], &tokens, task as i32, &mut want).unwrap();
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(
                    g.to_bits(),
                    w.to_bits(),
                    "client {client} request {i} task {task}: sharded {g} != oracle {w}"
                );
            }
        }
    }
}

#[test]
fn affinity_routing_beats_round_robin_on_cache_hits() {
    // A paired task stream — every task submitted back to back, round
    // after round — through one group of two replicas. Affinity pins each
    // task to `(task / groups) % replicas`, so the group folds each task
    // exactly once; round-robin's cursor alternates replicas between the
    // paired submissions, so every task is folded on *both* caches.
    const ROUNDS: usize = 5;
    let backend = RefBackend::with_config(1, true).unwrap();
    let tt = demo_tt(11);
    let mut outcomes = Vec::new();
    for route in [RoutePolicy::Affinity, RoutePolicy::RoundRobin] {
        let router =
            ShardRouter::new(&backend, router_cfg(2, 2, route), |_| tt.clone(), None)
                .unwrap();
        let seq = router.seq_len();
        router
            .serve(|r| {
                for _round in 0..ROUNDS {
                    for task in 0..TASKS {
                        for rep in 0..2 {
                            let tokens = vec![1 + (task + rep) as i32; seq];
                            let resp =
                                r.submit_with(task, tokens, None, 0)?.wait()?;
                            assert_eq!(resp.status, ResponseStatus::Ok);
                        }
                    }
                }
                anyhow::Ok(())
            })
            .unwrap()
            .unwrap();
        let cache = router.cache_stats();
        let lookups = cache.hits + cache.folds;
        outcomes.push((route, cache.folds, cache.hits as f64 / lookups.max(1) as f64));
    }
    let (_, affinity_folds, affinity_rate) = outcomes[0];
    let (_, rr_folds, rr_rate) = outcomes[1];
    assert_eq!(
        affinity_folds, TASKS as u64,
        "affinity folds each task exactly once across the group"
    );
    assert_eq!(
        rr_folds,
        2 * TASKS as u64,
        "round-robin folds every task on both replicas"
    );
    assert!(
        affinity_rate > rr_rate,
        "affinity hit rate {affinity_rate:.3} must beat round-robin {rr_rate:.3}"
    );
}

#[test]
fn a_fully_down_group_answers_with_explicit_errors() {
    // One sweep probes both shards (global tick ordinals 1 and 2), so a
    // two-tick kill plan downs the whole group in a single heartbeat.
    let backend = RefBackend::with_config(1, true).unwrap();
    let plan = FaultPlan::parse("shard_down@tick=1,shard_down@tick=2,seed=1").unwrap();
    let mut rcfg = router_cfg(2, 2, RoutePolicy::Affinity);
    rcfg.engine.faults = Arc::new(plan);
    let router = ShardRouter::new(&backend, rcfg, |_| demo_tt(13), None).unwrap();
    let seq = router.seq_len();

    router.heartbeat_now();
    let rs = router.router_stats();
    assert_eq!(rs.heartbeats, 1);
    assert_eq!(rs.failovers, 2, "both shards declared Down");
    assert_eq!(router.health(0), ShardHealth::Down);
    assert_eq!(router.health(1), ShardHealth::Down);

    // Blocking admission: a handle that resolves to a named Error.
    let resp = router.submit(0, vec![1; seq]).unwrap().wait().unwrap();
    assert_eq!(resp.status, ResponseStatus::Error);
    assert!(resp.logits.is_empty());
    let msg = resp.error.as_deref().unwrap_or("");
    assert!(msg.contains("down"), "error must name the condition: {msg:?}");

    // Non-blocking admission degrades the same way — never Ok(None),
    // which would claim overload rather than outage.
    let h = router
        .try_submit_with(1, vec![2; seq], Some(Duration::from_millis(50)), 0)
        .unwrap()
        .expect("a downed group answers, it does not shed");
    let resp = h.wait().unwrap();
    assert_eq!(resp.status, ResponseStatus::Error);
    assert!(router.router_stats().down_errors >= 2);
}

#[test]
fn bad_topologies_and_policies_are_flag_time_errors() {
    let backend = RefBackend::with_config(1, true).unwrap();
    let err = ShardRouter::new(&backend, router_cfg(4, 3, RoutePolicy::Affinity), |_| {
        demo_tt(1)
    }, None)
    .expect_err("3 replicas cannot divide 4 shards");
    assert!(format!("{err:#}").contains("divide"));
    assert!(RoutePolicy::parse("affinity").is_ok());
    assert!(RoutePolicy::parse("rr").is_ok());
    let err = RoutePolicy::parse("random").expect_err("unknown policy must error");
    assert!(format!("{err:#}").contains("unknown route policy"));
}
