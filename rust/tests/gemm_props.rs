//! Property suite for the packed register-tiled GEMM family (PR 4).
//!
//! The packed kernels' documented contract is *per-element*: every output
//! element starts from its prior C value and accumulates `a·b` products in
//! strictly ascending k order, one mul-rounding and one add-rounding per
//! step — vector lanes span columns, never k. That sequence is exactly what
//! the retired PR 2/3 blocked kernels computed, so the oracle below (a
//! direct transcription of the contract) simultaneously pins:
//!
//! 1. **bit-identity with the PR 3 kernels** for every shape/orientation,
//! 2. **thread-count independence** (1 vs 4 workers),
//! 3. **pack-scratch independence** (arena `PackScratch` vs the
//!    per-thread `*_into_local` scratch),
//! 4. the **accumulate-into-C** semantics the encoder backward fuses on.
//!
//! Shapes cover the degenerate edges (m/n/k = 0 and 1), single-panel and
//! panel-straddling sizes, non-multiples of the MR/NR/KC tiles, and random
//! rectangles. Numerical sanity against a float64-free naive product is
//! checked with a relative tolerance on top of the bitwise pins.

use metatt::tensor::{
    matmul_into, matmul_into_local, matmul_into_prepacked, matmul_t_into,
    matmul_t_into_local, rel_err, t_matmul_into, t_matmul_into_local, PackScratch,
    PackedB, Tensor,
};
use metatt::util::rng::Pcg64;

/// The documented per-element contract, transcribed literally: for each
/// (i, j), start from C and fold `a_ik · b_kj` in ascending k with f32
/// rounding at every step. This is bit-for-bit what the PR 3 blocked
/// kernels (and therefore the packed kernels) must produce.
#[allow(clippy::too_many_arguments)]
fn oracle(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    at: impl Fn(usize, usize) -> usize,
    bt: impl Fn(usize, usize) -> usize,
) {
    for i in 0..m {
        for j in 0..n {
            let mut acc = c[i * n + j];
            for t in 0..k {
                acc += a[at(i, t)] * b[bt(t, j)];
            }
            c[i * n + j] = acc;
        }
    }
}

fn shapes() -> Vec<(usize, usize, usize)> {
    let mut out = vec![
        // Degenerate edges: empty dims must not touch C (accumulate) nor panic.
        (0, 5, 7),
        (5, 0, 7),
        (5, 7, 0),
        (1, 1, 1),
        // Single partial panels.
        (3, 5, 5),
        (4, 9, 8),
        // Panel-straddling, non-multiples of MR=4 / NR=8. The first two sit
        // below the small-product threshold (direct k-ascending path), the
        // rest go through packing — the oracle must match bitwise on both
        // sides of the dispatch.
        (5, 3, 9),
        (17, 23, 10),
        (63, 65, 7),
        (129, 100, 17),
        // Above the parallel threshold; straddles the KC=256 k-tile too.
        (96, 300, 40),
        (260, 70, 40),
    ];
    let mut rng = Pcg64::new(0xbead);
    for _ in 0..4 {
        let dim = |r: &mut Pcg64| 1 + (r.next_u64() % 90) as usize;
        out.push((dim(&mut rng), dim(&mut rng), dim(&mut rng)));
    }
    out
}

fn assert_bits(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (idx, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            g.to_bits() == w.to_bits(),
            "{what}: elem {idx}: {g:?} != {w:?} (bits differ)"
        );
    }
}

fn naive(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for t in 0..k {
                acc += a.at(i, t) * b.at(t, j);
            }
            c.set(i, j, acc);
        }
    }
    c
}

/// Exercise one orientation across shapes, thread counts, scratch kinds,
/// and a nonzero C base (the accumulate contract), against the oracle.
#[allow(clippy::too_many_arguments)]
fn check_orientation(
    name: &str,
    seed: u64,
    a_shape: impl Fn(usize, usize) -> [usize; 2],
    b_shape: impl Fn(usize, usize) -> [usize; 2],
    at: impl Fn(usize, usize, usize, usize) -> usize + Copy,
    bt: impl Fn(usize, usize, usize, usize) -> usize + Copy,
    run: impl Fn(&[f32], &[f32], &mut [f32], usize, usize, usize, usize, &mut PackScratch),
    run_local: impl Fn(&[f32], &[f32], &mut [f32], usize, usize, usize, usize),
) {
    let mut rng = Pcg64::new(seed);
    let mut packs = PackScratch::new();
    for (m, k, n) in shapes() {
        let a = Tensor::randn(&a_shape(m, k), 1.0, &mut rng);
        let b = Tensor::randn(&b_shape(k, n), 1.0, &mut rng);
        let base = Tensor::randn(&[m, n], 1.0, &mut rng);
        let mut want = base.data().to_vec();
        oracle(
            a.data(),
            b.data(),
            &mut want,
            m,
            k,
            n,
            |i, t| at(i, t, m, k),
            |t, j| bt(t, j, k, n),
        );
        for threads in [1usize, 4] {
            let mut got = base.data().to_vec();
            run(a.data(), b.data(), &mut got, m, k, n, threads, &mut packs);
            assert_bits(&got, &want, &format!("{name} ({m},{k},{n}) t{threads} arena"));
            let mut got_local = base.data().to_vec();
            run_local(a.data(), b.data(), &mut got_local, m, k, n, threads);
            assert_bits(
                &got_local,
                &want,
                &format!("{name} ({m},{k},{n}) t{threads} local"),
            );
        }
    }
}

#[test]
fn packed_matmul_bitwise_matches_k_ascending_oracle() {
    check_orientation(
        "matmul",
        7,
        |m, k| [m, k],
        |k, n| [k, n],
        |i, t, _m, k| i * k + t,
        |t, j, _k, n| t * n + j,
        matmul_into,
        matmul_into_local,
    );
}

#[test]
fn packed_matmul_t_bitwise_matches_k_ascending_oracle() {
    // B is (n × k); the pack absorbs the transpose.
    check_orientation(
        "matmul_t",
        8,
        |m, k| [m, k],
        |k, n| [n, k],
        |i, t, _m, k| i * k + t,
        |t, j, k, _n| j * k + t,
        matmul_t_into,
        matmul_t_into_local,
    );
}

#[test]
fn packed_t_matmul_bitwise_matches_k_ascending_oracle() {
    // A is (k × m); the pack absorbs the transpose.
    check_orientation(
        "t_matmul",
        9,
        |m, k| [k, m],
        |k, n| [k, n],
        |i, t, m, _k| t * m + i,
        |t, j, _k, n| t * n + j,
        t_matmul_into,
        t_matmul_into_local,
    );
}

#[test]
fn prepacked_b_bitwise_matches_k_ascending_oracle() {
    // The bind-time PackedB cache (PR 5) must keep the exact per-element
    // contract of the per-call path: same shapes, same thread counts, same
    // accumulate-into-C semantics, identical bits — on both sides of the
    // small-product dispatch.
    let mut rng = Pcg64::new(10);
    let mut packs = PackScratch::new();
    for (m, k, n) in shapes() {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let base = Tensor::randn(&[m, n], 1.0, &mut rng);
        let mut want = base.data().to_vec();
        oracle(
            a.data(),
            b.data(),
            &mut want,
            m,
            k,
            n,
            |i, t| i * k + t,
            |t, j| t * n + j,
        );
        let bp = PackedB::pack(b.data(), k, n);
        for threads in [1usize, 4] {
            let mut got = base.data().to_vec();
            matmul_into_prepacked(a.data(), &bp, &mut got, m, threads, &mut packs);
            assert_bits(&got, &want, &format!("prepacked ({m},{k},{n}) t{threads}"));
        }
    }
}

#[test]
fn packed_kernels_are_numerically_sane_vs_naive() {
    // The bitwise oracle pins the rounding sequence; this pins plain
    // mathematical correctness on a handful of rectangles per orientation.
    let mut rng = Pcg64::new(42);
    for &(m, k, n) in &[(33usize, 47usize, 29usize), (64, 64, 64), (7, 200, 3)] {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        assert!(rel_err(&a.matmul(&b), &naive(&a, &b)) < 1e-4, "matmul ({m},{k},{n})");
        let bt = Tensor::randn(&[n, k], 1.0, &mut rng);
        assert!(
            rel_err(&a.matmul_t(&bt), &naive(&a, &bt.transpose())) < 1e-4,
            "matmul_t ({m},{k},{n})"
        );
        let at = Tensor::randn(&[k, m], 1.0, &mut rng);
        assert!(
            rel_err(&at.t_matmul(&b), &naive(&at.transpose(), &b)) < 1e-4,
            "t_matmul ({m},{k},{n})"
        );
    }
}

#[test]
fn degenerate_dims_leave_accumulator_untouched() {
    // k == 0 contributes nothing; m == 0 / n == 0 produce empty outputs.
    let mut packs = PackScratch::new();
    let base: Vec<f32> = (0..15).map(|x| x as f32 - 7.0).collect();
    let mut c = base.clone();
    matmul_into(&[], &[], &mut c, 3, 0, 5, 4, &mut packs);
    assert_bits(&c, &base, "k=0 accumulate");
    let mut c2 = base.clone();
    matmul_t_into(&[], &[], &mut c2, 3, 0, 5, 1, &mut packs);
    assert_bits(&c2, &base, "k=0 matmul_t accumulate");
    let mut c3 = base.clone();
    t_matmul_into(&[], &[], &mut c3, 3, 0, 5, 1, &mut packs);
    assert_bits(&c3, &base, "k=0 t_matmul accumulate");
    let mut empty: Vec<f32> = vec![];
    matmul_into(&[], &[1.0, 2.0], &mut empty, 0, 1, 2, 1, &mut packs);
    matmul_into(&[1.0, 2.0], &[], &mut empty, 2, 1, 0, 1, &mut packs);
}

#[test]
fn shared_scratch_across_mixed_shapes_is_stateless() {
    // Interleave differently-shaped and differently-oriented GEMMs through
    // ONE scratch: stale panel contents from a previous (larger) pack must
    // never leak into a later product's bits.
    let mut rng = Pcg64::new(1234);
    let mut packs = PackScratch::new();
    let big_a = Tensor::randn(&[96, 120], 1.0, &mut rng);
    let big_b = Tensor::randn(&[120, 72], 1.0, &mut rng);
    let mut big_c = vec![0.0f32; 96 * 72];
    matmul_into(big_a.data(), big_b.data(), &mut big_c, 96, 120, 72, 4, &mut packs);
    for (m, k, n) in [(5usize, 3usize, 9usize), (12, 40, 4), (33, 7, 31)] {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[n, k], 1.0, &mut rng);
        let mut got = vec![0.0f32; m * n];
        matmul_t_into(a.data(), b.data(), &mut got, m, k, n, 1, &mut packs);
        let mut want = vec![0.0f32; m * n];
        let mut fresh = PackScratch::new();
        matmul_t_into(a.data(), b.data(), &mut want, m, k, n, 1, &mut fresh);
        assert_bits(&got, &want, &format!("shared-scratch ({m},{k},{n})"));
    }
}
