//! Reference-backend tests: gradient parity against finite differences and
//! hermetic end-to-end smoke runs of every coordinator entry point.
//!
//! Everything here runs with NO artifacts, NO Python, NO network — this is
//! the suite the ISSUE's acceptance criteria point at: the pure-rust
//! backend must make the whole training/DMRG/MTL stack executable and
//! testable from a fresh checkout.

use metatt::adapters::{AdapterKind, AdapterSpec};
use metatt::config::{ModelPreset, TrainConfig};
use metatt::coordinator::{run_dmrg, run_mtl, run_single_task, DmrgConfig, MtlConfig};
use metatt::data::{Batch, Batcher, TaskId};
use metatt::runtime::{
    assemble_frozen, ArtifactSpec, Backend, RefBackend, Step, StepKind,
};
use metatt::tensor::{rel_err, Tensor};
use metatt::tt::{MetaTtKind, RankSchedule};
use metatt::util::rng::Pcg64;

fn tiny_spec(step: StepKind, adapter: &str, rank: usize, tasks: usize, batch: usize, seq: usize) -> ArtifactSpec {
    ArtifactSpec {
        step,
        model: "tiny".into(),
        adapter: adapter.into(),
        rank,
        classes: 2,
        tasks,
        batch,
        seq,
    }
}

fn small_batch(batch: usize, seq: usize, seed: u64) -> Batch {
    let ds = TaskId::MrpcSyn.generate_at(batch, batch, seed, seq, 512);
    Batcher::new(batch).eval(&ds).remove(0)
}

/// Random trainable tensors for an entry (exercises every gradient path —
/// the structured inits zero entire factors, which would hide bugs).
fn random_params(backend: &RefBackend, spec: &ArtifactSpec, seed: u64) -> Vec<Tensor> {
    let entry = backend.entry(spec).unwrap();
    let mut rng = Pcg64::new(seed);
    entry
        .trainable_inputs()
        .iter()
        .map(|io| Tensor::randn(&io.shape, 0.2, &mut rng))
        .collect()
}

// ---------------------------------------------------------------------------
// Gradient parity: analytic backward vs central finite differences.
// ---------------------------------------------------------------------------

/// Check ∂L/∂θ along the gradient direction and at the largest individual
/// coordinates, via central differences on the loss.
fn check_gradients(adapter: &str, tasks: usize, task_id: i32) {
    let backend = RefBackend::new();
    let (batch_n, seq) = (4, 8);
    let spec = tiny_spec(StepKind::Train, adapter, 3, tasks, batch_n, seq);
    let entry = backend.entry(&spec).unwrap();
    let frozen = std::sync::Arc::new(assemble_frozen(&entry, None, ModelPreset::Tiny).unwrap());
    let step = backend.bind(&spec, &frozen).unwrap();
    let params = random_params(&backend, &spec, 42);
    let batch = small_batch(batch_n, seq, 5);
    let alpha = 1.0f32;

    let (loss0, grads) = step.run_train(&params, &batch, task_id, alpha).unwrap();
    assert!(loss0.is_finite() && loss0 > 0.0, "{adapter}: bad loss {loss0}");
    for (g, p) in grads.iter().zip(&params) {
        assert_eq!(g.shape(), p.shape(), "{adapter}: grad shape");
        assert!(g.all_finite(), "{adapter}: non-finite grads");
    }

    let loss_at = |theta: &[Tensor]| -> f32 {
        step.run_train(theta, &batch, task_id, alpha).unwrap().0
    };

    // 1. Directional derivative along the unit gradient direction:
    //    (L(θ+εu) − L(θ−εu)) / 2ε ≈ ‖∇L‖.
    let gnorm: f64 = grads
        .iter()
        .map(|g| g.data().iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>())
        .sum::<f64>()
        .sqrt();
    assert!(gnorm > 1e-6, "{adapter}: gradient vanished ({gnorm})");
    let eps = 5e-3f32;
    let shift = |sign: f32| -> Vec<Tensor> {
        params
            .iter()
            .zip(&grads)
            .map(|(p, g)| {
                let mut t = p.clone();
                t.axpy(sign * eps / gnorm as f32, g);
                t
            })
            .collect()
    };
    let fd = (loss_at(&shift(1.0)) - loss_at(&shift(-1.0))) as f64 / (2.0 * eps as f64);
    let rel = (fd - gnorm).abs() / gnorm.max(1e-9);
    assert!(
        rel < 5e-2,
        "{adapter}: directional derivative mismatch: fd {fd} vs ‖g‖ {gnorm} (rel {rel})"
    );

    // 2. The largest-magnitude coordinate of each trainable tensor.
    for (ti, g) in grads.iter().enumerate() {
        let (mut best, mut best_abs) = (0usize, 0.0f32);
        for (i, &v) in g.data().iter().enumerate() {
            if v.abs() > best_abs {
                best_abs = v.abs();
                best = i;
            }
        }
        if best_abs < 1e-5 {
            continue; // structurally (near-)zero gradient — nothing to probe
        }
        let eps_c = 5e-3f32;
        let mut plus = params.clone();
        plus[ti].data_mut()[best] += eps_c;
        let mut minus = params.clone();
        minus[ti].data_mut()[best] -= eps_c;
        let fd_c = (loss_at(&plus) - loss_at(&minus)) / (2.0 * eps_c);
        let an_c = g.data()[best];
        let rel_c = (fd_c - an_c).abs() / an_c.abs().max(1e-4);
        assert!(
            rel_c < 8e-2,
            "{adapter}: tensor {ti} coord {best}: fd {fd_c} vs analytic {an_c}"
        );
    }
}

#[test]
fn gradients_match_finite_differences_metatt4d() {
    check_gradients("metatt4d", 1, 0);
}

#[test]
fn gradients_match_finite_differences_metatt5d() {
    check_gradients("metatt5d", 1, 0);
}

#[test]
fn gradients_match_finite_differences_metatt4p1d() {
    check_gradients("metatt4p1d", 3, 1);
}

#[test]
fn gradients_match_finite_differences_lora() {
    check_gradients("lora", 1, 0);
}

#[test]
fn gradients_match_finite_differences_vera() {
    check_gradients("vera", 1, 0);
}

#[test]
fn gradients_match_finite_differences_lotr() {
    check_gradients("lotr", 1, 0);
}

#[test]
fn gradients_match_finite_differences_full_ft() {
    // Full fine-tuning exercises the encoder-weight gradients: projections,
    // LN parameters, and the embedding scatter.
    check_gradients("full", 1, 0);
}

#[test]
fn pretrain_gradients_match_finite_differences() {
    use metatt::data::MlmCorpus;
    let backend = RefBackend::new();
    let spec = ArtifactSpec {
        step: StepKind::Pretrain,
        model: "tiny".into(),
        adapter: "none".into(),
        rank: 0,
        classes: 1,
        tasks: 1,
        batch: 2,
        seq: 8,
    };
    let step = backend.bind(&spec, &Default::default()).unwrap();
    let params = random_params(&backend, &spec, 3);
    let mut corpus = MlmCorpus::new(512, 8, 11);
    let batch = corpus.next_batch(2);
    let (loss0, grads) = step.run_pretrain(&params, &batch).unwrap();
    // Random weights over vocab 512: CE should be in the ln(512) ≈ 6.2 zone.
    assert!((2.0..12.0).contains(&loss0), "MLM loss {loss0}");
    let gnorm: f64 = grads
        .iter()
        .map(|g| g.data().iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>())
        .sum::<f64>()
        .sqrt();
    assert!(gnorm > 1e-6);
    let eps = 2e-3f32;
    let shift = |sign: f32| -> Vec<Tensor> {
        params
            .iter()
            .zip(&grads)
            .map(|(p, g)| {
                let mut t = p.clone();
                t.axpy(sign * eps / gnorm as f32, g);
                t
            })
            .collect()
    };
    let lp = step.run_pretrain(&shift(1.0), &batch).unwrap().0;
    let lm = step.run_pretrain(&shift(-1.0), &batch).unwrap().0;
    let fd = (lp - lm) as f64 / (2.0 * eps as f64);
    let rel = (fd - gnorm).abs() / gnorm.max(1e-9);
    assert!(rel < 5e-2, "pretrain directional derivative: fd {fd} vs ‖g‖ {gnorm}");
}

// ---------------------------------------------------------------------------
// Structural gradient properties at the paper's zero init.
// ---------------------------------------------------------------------------

#[test]
fn zero_init_gradient_structure_matches_tt_algebra() {
    // With g1 == 0 (ze-id-id-id): grad_g1 flows, grads of g2/g3/g4 are
    // exactly zero because every derivative path contains the zero factor.
    let backend = RefBackend::new();
    let spec = tiny_spec(StepKind::Train, "metatt4d", 8, 1, 8, 16);
    let entry = backend.entry(&spec).unwrap();
    let frozen = std::sync::Arc::new(assemble_frozen(&entry, None, ModelPreset::Tiny).unwrap());
    let step = backend.bind(&spec, &frozen).unwrap();
    let aspec = AdapterSpec::new(
        AdapterKind::MetaTt(MetaTtKind::FourD),
        8,
        4.0,
        ModelPreset::Tiny.dims(1),
    );
    let mut rng = Pcg64::new(1);
    let params = aspec.init_params(&mut rng); // paper default: ze-id-id-id
    let batch = small_batch(8, 16, 3);
    let (loss, grads) = step.run_train(&params, &batch, 0, 4.0).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert!(grads[0].max_abs() > 0.0, "grad_g1 must flow");
    assert_eq!(grads[1].max_abs(), 0.0, "grad_g2 must vanish at ze-init");
    assert_eq!(grads[2].max_abs(), 0.0, "grad_g3 must vanish at ze-init");
    assert_eq!(grads[3].max_abs(), 0.0, "grad_g4 must vanish at ze-init");
}

#[test]
fn zero_init_adapters_agree_on_logits() {
    // Different adapter families, all zero maps at init, over the same
    // frozen backbone must produce identical logits.
    let backend = RefBackend::new();
    let dims = ModelPreset::Tiny.dims(1);
    let mut rng = Pcg64::new(2);
    let batch = small_batch(8, 16, 9);
    let mut all_logits: Vec<Tensor> = Vec::new();
    for kind in [
        AdapterKind::MetaTt(MetaTtKind::FourD),
        AdapterKind::LoRa,
        AdapterKind::LoTr,
    ] {
        let aspec = AdapterSpec::new(kind, 8, 4.0, dims);
        let spec = tiny_spec(StepKind::Eval, &aspec.kind.name(), 8, 1, 8, 16);
        let entry = backend.entry(&spec).unwrap();
        let frozen =
            std::sync::Arc::new(assemble_frozen(&entry, None, ModelPreset::Tiny).unwrap());
        let step = backend.bind(&spec, &frozen).unwrap();
        let params = aspec.init_params(&mut rng);
        all_logits.push(step.run_eval(&params, &batch, 0, 4.0).unwrap());
    }
    for other in &all_logits[1..] {
        assert!(
            rel_err(other, &all_logits[0]) < 1e-5,
            "zero-init adapters disagree: {}",
            rel_err(other, &all_logits[0])
        );
    }
}

// ---------------------------------------------------------------------------
// Coordinator smoke tests: single-task, DMRG, MTL — hermetic end to end.
// ---------------------------------------------------------------------------

#[test]
fn single_task_smoke_runs_and_learns_on_ref_backend() {
    let backend = RefBackend::new();
    let model = ModelPreset::Tiny;
    let aspec = AdapterSpec::new(
        AdapterKind::MetaTt(MetaTtKind::FourD),
        4,
        4.0,
        model.dims(1),
    );
    let train = TrainConfig {
        epochs: 3,
        train_cap: 96,
        eval_cap: 48,
        ..Default::default()
    };
    let res = run_single_task(
        &backend, model, &aspec, TaskId::Sst2Syn, &train, 4.0, None, None,
    )
    .unwrap();
    assert_eq!(res.epochs.len(), 3);
    for e in &res.epochs {
        assert!(e.train_loss.is_finite() && e.train_loss > 0.0);
        assert!((0.0..=1.0).contains(&e.metric), "accuracy {e:?}");
    }
    let first = res.epochs.first().unwrap().train_loss;
    let last = res.epochs.last().unwrap().train_loss;
    // Gradient correctness is pinned by the FD tests; here we only require
    // the optimization loop to make (at least marginal) progress.
    assert!(
        last < first + 0.02,
        "training loss did not decrease: {first} -> {last}"
    );
    assert!(res.best_metric >= 0.4, "metric collapsed: {}", res.best_metric);
}

#[test]
fn dmrg_smoke_hot_swaps_ranks_on_ref_backend() {
    let backend = RefBackend::new();
    let mut cfg = DmrgConfig::default();
    cfg.train.epochs = 3;
    cfg.train.train_cap = 64;
    cfg.train.eval_cap = 32;
    cfg.start_rank = 6;
    cfg.schedule = RankSchedule::parse("0:5,1:4").unwrap();
    let res = run_dmrg(
        &backend,
        ModelPreset::Tiny,
        AdapterKind::MetaTt(MetaTtKind::FiveD),
        TaskId::MrpcSyn,
        &cfg,
        None,
    )
    .unwrap();
    assert_eq!(res.epochs.len(), 3);
    assert_eq!(res.epochs[0].rank, 5, "first sweep fires after epoch 0");
    assert_eq!(res.epochs[1].rank, 4, "second sweep fires after epoch 1");
    assert!(res.epochs[0].swept && res.epochs[1].swept && !res.epochs[2].swept);
    assert_eq!(res.final_rank, 4);
    // Three ranks × (train + eval) distinct steps bound.
    assert!(
        res.executables_compiled >= 4,
        "expected hot-swapped steps, got {}",
        res.executables_compiled
    );
    assert!(res.epochs.iter().all(|e| e.metric.is_finite()));
}

#[test]
fn mtl_smoke_runs_task_cores_on_ref_backend() {
    let backend = RefBackend::new();
    let tasks = [TaskId::ColaSyn, TaskId::RteSyn];
    let model = ModelPreset::Tiny;
    let aspec = AdapterSpec::new(
        AdapterKind::MetaTt(MetaTtKind::FourPlusOneD),
        3,
        2.0,
        model.dims(tasks.len()),
    );
    let mut cfg = MtlConfig::default();
    cfg.train.epochs = 1;
    cfg.per_task_cap = 48;
    cfg.eval_cap = 24;
    let res = run_mtl(&backend, model, &aspec, &tasks, &cfg, None).unwrap();
    assert_eq!(res.epochs.len(), 1);
    assert_eq!(res.best_per_task.len(), 2);
    assert_eq!(res.param_names.len(), 5); // g1..g5
    let epoch = &res.epochs[0];
    assert!(epoch.train_loss.is_finite());
    assert!(epoch.grad_norms.iter().all(|g| g.is_finite()));
    // The task core g3 receives gradient signal under the (4+1)D routing
    // once training has moved g1 off zero.
    assert_eq!(res.param_names[2], "g3");
}

#[test]
fn eval_batches_drive_metrics_without_padding_bias() {
    // Eval with a ragged final batch: padded rows carry weight 0 and must
    // not affect the metric path (regression guard on the ref backend's
    // batch handling).
    let backend = RefBackend::new();
    let model = ModelPreset::Tiny;
    let aspec = AdapterSpec::new(
        AdapterKind::MetaTt(MetaTtKind::FourD),
        4,
        4.0,
        model.dims(1),
    );
    let train = TrainConfig {
        epochs: 1,
        train_cap: 40, // 40 / 16 → ragged batches on both splits
        eval_cap: 20,
        ..Default::default()
    };
    let res = run_single_task(
        &backend, model, &aspec, TaskId::RteSyn, &train, 4.0, None, None,
    )
    .unwrap();
    assert!((0.0..=1.0).contains(&res.best_metric));
}
