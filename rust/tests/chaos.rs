//! Chaos suite (PR 8): drive seeded fault plans through the full serving
//! path and pin that self-healing is *lossless*.
//!
//! The load-bearing properties:
//!
//! 1. **Zero lost requests** — a 500-request TCP run with 3 injected
//!    worker panics, 2 injected connection drops, and a torn checkpoint
//!    write still answers every request exactly once.
//! 2. **Bit identity** — every non-faulted response carries logits
//!    bit-identical to a fault-free direct forward; supervision and
//!    requeueing never change what is computed, only when.
//! 3. **Monotonic generations** — hot-swap under fault keeps each
//!    client's generation stamps non-decreasing.
//! 4. **Quarantine precision** — a request that keeps panicking its
//!    batch is bisected down and answered with an explicit `Error`;
//!    its batch-mates all succeed.
//! 5. **Clean timeouts** — a wedged server surfaces as a "timed out"
//!    error on the client, not a forever-blocked read.
//!
//! The fault seed comes from `METATT_CHAOS_SEED` (default 1) so CI can
//! re-run the suite under a second seed; every assertion here holds for
//! any seed (the seed only moves jitter and `slow_tick` draws).

use metatt::adapters::AdapterKind;
use metatt::config::ModelPreset;
use metatt::coordinator::checkpoint::{self, CheckpointMeta};
use metatt::runtime::{assemble_frozen, ArtifactSpec, Backend, RefBackend, StepKind};
use metatt::serving::{
    adapter_spec_for, metatt_from_tensors, serve_net, EngineConfig, NetClient,
    ResponseStatus, RetryClient, RetryPolicy, ServingEngine, WireStatus,
};
use metatt::tensor::DtypeKind;
use metatt::tt::{CoreInit, InitStrategy, MetaTt, MetaTtKind};
use metatt::util::fault::FaultPlan;
use metatt::util::rng::Pcg64;
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

const TASKS: usize = 3;
const RANK: usize = 4;
const ALPHA: f32 = 1.3;

fn chaos_seed() -> u64 {
    std::env::var("METATT_CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
}

fn engine_cfg(workers: usize, max_batch: usize, faults: FaultPlan) -> EngineConfig {
    EngineConfig {
        model: ModelPreset::Tiny,
        adapter: AdapterKind::MetaTt(MetaTtKind::FourPlusOneD),
        rank: RANK,
        alpha: ALPHA,
        num_tasks: TASKS,
        classes: 2,
        max_batch,
        batch_deadline: Duration::from_millis(1),
        queue_capacity: 64,
        workers,
        cache_capacity_bytes: 64 << 20,
        dtype: DtypeKind::F32,
        faults: Arc::new(faults),
        obs: Arc::new(metatt::obs::Obs::new(false)),
    }
}

fn demo_tt(seed: u64) -> MetaTt {
    let spec = adapter_spec_for(&engine_cfg(1, 4, FaultPlan::empty()));
    let init = InitStrategy {
        cores: vec![CoreInit::Normal; MetaTtKind::FourPlusOneD.order()],
    };
    spec.build_metatt_with(&mut Pcg64::new(seed), Some(&init))
}

/// The deterministic request of `(client, index)`: pure function, so the
/// fault-free reference can replay exactly what the chaos run asked.
fn chaos_request(seq: usize, vocab: usize, client: usize, i: usize) -> (usize, Vec<i32>) {
    let mut rng = Pcg64::with_stream(900 + client as u64, i as u64);
    let task = (client + i) % TASKS;
    let tokens = (0..seq).map(|_| 1 + rng.uniform_usize(vocab - 1) as i32).collect();
    (task, tokens)
}

#[test]
fn chaos_tcp_run_loses_nothing_and_stays_bit_identical() {
    const CLIENTS: usize = 5;
    const PER_CLIENT: usize = 100;
    let seed = chaos_seed();
    // 3 worker panics and 2 connection drops, all at fixed ordinals well
    // inside the run (>= 125 serve ticks, 500+ request frames), plus a
    // low-probability slow tick so latency jitter rides along.
    let plan = FaultPlan::parse(&format!(
        "worker_panic@tick=10,worker_panic@tick=45,worker_panic@tick=80,\
         net_drop@frame=120,net_drop@frame=260,slow_tick=1ms@p=0.02,seed={seed}"
    ))
    .unwrap();
    let backend = RefBackend::with_config(1, true).unwrap();
    let tt = demo_tt(5);
    let engine =
        ServingEngine::new(&backend, engine_cfg(2, 4, plan), tt.clone(), None).unwrap();
    let seq = engine.seq_len();
    let vocab = engine.vocab();
    let swap_path = std::env::temp_dir().join(format!(
        "metatt_chaos_swap_{}_{seed}.bin",
        std::process::id()
    ));

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let addr = addr.as_str();
    let shutdown = AtomicBool::new(false);
    let engine_ref = &engine;
    let tt_ref = &tt;
    let swap_ref = &swap_path;

    type ClientOut = (Vec<(usize, Vec<i32>, Vec<f32>)>, Vec<u64>, u64, u64);
    let per_client: Vec<ClientOut> = std::thread::scope(|scope| {
        let server =
            scope.spawn(|| engine_ref.serve(|eng| serve_net(eng, listener, &shutdown)));

        // Hot-swap under fault: the first checkpoint write is torn (temp
        // file only, live path untouched), the retry lands atomically,
        // and the reload swaps in *identical* adapter state — so the
        // generation bump is observable while every logit stays put.
        let swapper = scope.spawn(move || {
            std::thread::sleep(Duration::from_millis(150));
            let aspec = adapter_spec_for(engine_ref.config());
            let named: Vec<(String, metatt::tensor::Tensor)> = aspec
                .param_specs()
                .iter()
                .zip(tt_ref.export_cores())
                .map(|(p, t)| (p.name.clone(), t))
                .collect();
            let meta = CheckpointMeta {
                adapter: "metatt4p1d".into(),
                rank: RANK,
                tasks: TASKS,
                alpha: ALPHA,
                model: "tiny".into(),
                dtype: "f32".into(),
            };
            let save_plan = FaultPlan::parse("torn_write@save=1").unwrap();
            let err =
                checkpoint::save_with_meta_faults(swap_ref, &meta, &named, Some(&save_plan))
                    .expect_err("first save must be torn");
            assert!(err.contains("torn write"), "unexpected torn-save error: {err}");
            let tmp = swap_ref.with_file_name(format!(
                "{}.tmp",
                swap_ref.file_name().unwrap().to_string_lossy()
            ));
            assert!(
                checkpoint::load_with_meta(&tmp).is_err(),
                "a half-written temp file must be rejected by the loader"
            );
            // Same plan, save ordinal 2: the retry writes cleanly.
            checkpoint::save_with_meta_faults(swap_ref, &meta, &named, Some(&save_plan))
                .unwrap();
            let (_, tensors) = checkpoint::load_with_meta(swap_ref).unwrap();
            std::fs::remove_file(swap_ref).ok();
            std::fs::remove_file(&tmp).ok();
            let restored = metatt_from_tensors(&aspec, &tensors).unwrap();
            engine_ref.reload(restored).unwrap();
        });

        let clients: Vec<_> = (0..CLIENTS)
            .map(|client| {
                scope.spawn(move || -> ClientOut {
                    let policy = RetryPolicy {
                        max_attempts: 6,
                        base_backoff: Duration::from_millis(5),
                        max_backoff: Duration::from_millis(50),
                        seed: seed.wrapping_add(client as u64),
                    };
                    let mut conn = RetryClient::new(
                        addr,
                        Duration::from_secs(10),
                        Some(Duration::from_secs(30)),
                        policy,
                    );
                    let mut answered = Vec::with_capacity(PER_CLIENT);
                    let mut gens = Vec::with_capacity(PER_CLIENT);
                    for i in 0..PER_CLIENT {
                        let (task, tokens) = chaos_request(seq, vocab, client, i);
                        let id = ((client as u64) << 32) | i as u64;
                        let resp = conn.call(id, task, 0, 0, &tokens).unwrap();
                        assert_eq!(resp.id, id, "responses keyed by request id");
                        assert_eq!(
                            resp.status,
                            WireStatus::Ok,
                            "request {id} not computed: {:?}",
                            resp.error
                        );
                        gens.push(resp.generation);
                        answered.push((task, tokens, resp.logits));
                    }
                    (answered, gens, conn.retries, conn.reconnects)
                })
            })
            .collect();
        let per_client: Vec<ClientOut> =
            clients.into_iter().map(|h| h.join().unwrap()).collect();
        swapper.join().unwrap();
        shutdown.store(true, Ordering::Relaxed);
        server.join().unwrap().unwrap().unwrap();
        per_client
    });

    // 1. Zero lost requests, and exactly one reconnect per injected drop.
    let total_ok: usize = per_client.iter().map(|(r, _, _, _)| r.len()).sum();
    assert_eq!(total_ok, CLIENTS * PER_CLIENT, "every request answered exactly once");
    let reconnects: u64 = per_client.iter().map(|&(_, _, _, rc)| rc).sum();
    assert_eq!(reconnects, 2, "each injected net_drop costs exactly one reconnect");
    let retries: u64 = per_client.iter().map(|&(_, _, r, _)| r).sum();
    assert_eq!(retries, 2, "worker panics heal server-side, never via client retry");

    // 3. Generation stamps never go backwards within a client, and the
    // torn-then-clean swap landed.
    for (_, gens, _, _) in &per_client {
        assert!(
            gens.windows(2).all(|w| w[0] <= w[1]),
            "generation went backwards under fault: {gens:?}"
        );
    }
    assert_eq!(engine.generation(), 1, "the retried checkpoint save was swapped in");

    // Supervision accounting: all three panics restarted the worker and
    // requeued the in-flight batch; nothing was quarantined (each request
    // fails at most once — the panic ticks are distinct).
    let stats = engine.stats();
    assert_eq!(stats.worker_restarts, 3, "three injected panics, three restarts");
    assert_eq!(stats.quarantined, 0);
    assert!(
        stats.requeued >= 3,
        "each panicked batch is requeued (got {})",
        stats.requeued
    );

    // 2. Bit identity: every response matches a fault-free direct forward
    // of the same (task, tokens) — the swap reloaded identical state, so
    // this holds across the generation bump too.
    let dims = ModelPreset::Tiny.dims(TASKS);
    let spec = ArtifactSpec {
        step: StepKind::Eval,
        model: "tiny".into(),
        adapter: "metatt4p1d".into(),
        rank: RANK,
        classes: 2,
        tasks: TASKS,
        batch: 1,
        seq: dims.max_seq,
    };
    let entry = backend.entry(&spec).unwrap();
    let frozen = Arc::new(assemble_frozen(&entry, None, ModelPreset::Tiny).unwrap());
    let step = backend.bind(&spec, &frozen).unwrap();
    let folded: Vec<_> = (0..TASKS).map(|t| tt.fold_for_serving(t)).collect();
    let mut want = vec![0f32; 2];
    for (answered, _, _, _) in &per_client {
        for (task, tokens, got) in answered {
            step.run_serve(&folded[*task], tokens, *task as i32, &mut want).unwrap();
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(
                    g.to_bits(),
                    w.to_bits(),
                    "task {task}: chaos-run logits {g} != fault-free {w}"
                );
            }
        }
    }
}

#[test]
fn poisoned_request_is_quarantined_and_batch_mates_succeed() {
    // One worker, one batch of four: ticks 1 and 2 panic the whole batch
    // (requeue, then solo), tick 3 panics the first solo run — that
    // request has now failed three times and is quarantined with an
    // explicit Error while its former batch-mates all compute.
    let plan =
        FaultPlan::parse("worker_panic@tick=1,worker_panic@tick=2,worker_panic@tick=3")
            .unwrap();
    let backend = RefBackend::with_config(1, true).unwrap();
    let engine =
        ServingEngine::new(&backend, engine_cfg(1, 4, plan), demo_tt(5), None).unwrap();
    let seq = engine.seq_len();
    // Submit before serve so all four coalesce into the first batch.
    let handles: Vec<_> =
        (0..4).map(|i| engine.submit(0, vec![1 + i as i32; seq]).unwrap()).collect();
    let responses = engine
        .serve(|_| handles.into_iter().map(|h| h.wait().unwrap()).collect::<Vec<_>>())
        .unwrap();

    assert_eq!(responses[0].status, ResponseStatus::Error, "the poison is request 0");
    assert!(responses[0].logits.is_empty());
    let msg = responses[0].error.as_deref().unwrap_or("");
    assert!(
        msg.contains("quarantined after 3 failed executions"),
        "error should say what happened: {msg:?}"
    );
    for (i, resp) in responses.iter().enumerate().skip(1) {
        assert_eq!(resp.status, ResponseStatus::Ok, "batch-mate {i} must compute");
        assert_eq!(resp.logits.len(), 2);
        assert_eq!(resp.batch_rows, 1, "suspects re-execute solo");
    }
    let stats = engine.stats();
    assert_eq!(stats.worker_restarts, 3);
    assert_eq!(stats.quarantined, 1);
    assert_eq!(stats.requeued, 8, "four requeued at tick 1, four (solo) at tick 2");
    assert_eq!(stats.requests, 3, "three batch-mates computed");
    assert_eq!(stats.shed, 0);
}

#[test]
fn shard_kill_fails_over_without_losing_requests() {
    use metatt::serving::{RoutePolicy, RouterConfig, ServeTarget, ShardHealth, ShardRouter};
    const CLIENTS: usize = 4;
    const PER_CLIENT: usize = 60;
    let seed = chaos_seed();
    // A fixed-ordinal shard kill only: with 2 live shards probed in index
    // order, global tick 4 is beat 2's probe of shard 1 — deterministic
    // for any METATT_CHAOS_SEED (the seed moves only probabilistic draws).
    let plan = FaultPlan::parse(&format!("shard_down@tick=4,seed={seed}")).unwrap();
    let backend = RefBackend::with_config(1, true).unwrap();
    let mut ecfg = engine_cfg(2, 4, FaultPlan::empty());
    ecfg.faults = Arc::new(plan);
    let rcfg = RouterConfig {
        engine: ecfg,
        shards: 2,
        replicas: 2,
        route: RoutePolicy::Affinity,
        heartbeat: Duration::from_millis(20),
        failure_threshold: 3,
    };
    let tt_old = demo_tt(5);
    let tt_new = demo_tt(6);
    let router = ShardRouter::new(&backend, rcfg, |_| tt_old.clone(), None).unwrap();
    let seq = router.seq_len();
    let vocab = router.vocab();
    let tt_new_ref = &tt_new;

    type ClientOut = Vec<(usize, Vec<i32>, Vec<f32>, u64)>;
    let per_client: Vec<ClientOut> = router
        .serve(|r| {
            std::thread::scope(|scope| {
                // Hot-swap identical new state into every shard mid-run,
                // after the kill beat: reload walks shard 0 first and
                // failover only moves work 1 -> 0, so per-task generation
                // stamps stay monotone across the failover.
                let swapper = scope.spawn(move || {
                    std::thread::sleep(Duration::from_millis(80));
                    r.reload(|_| tt_new_ref.clone()).unwrap();
                });
                let clients: Vec<_> = (0..CLIENTS)
                    .map(|client| {
                        scope.spawn(move || -> ClientOut {
                            (0..PER_CLIENT)
                                .map(|i| {
                                    // A little think time so the run spans
                                    // both the kill beat and the reload.
                                    std::thread::sleep(Duration::from_micros(500));
                                    let (task, tokens) =
                                        chaos_request(seq, vocab, client, i);
                                    let resp = r
                                        .submit_with(task, tokens.clone(), None, 0)
                                        .unwrap()
                                        .wait()
                                        .unwrap();
                                    assert_eq!(
                                        resp.status,
                                        ResponseStatus::Ok,
                                        "client {client} request {i} lost: {:?}",
                                        resp.error
                                    );
                                    (task, tokens, resp.logits, resp.generation)
                                })
                                .collect()
                        })
                    })
                    .collect();
                let out: Vec<ClientOut> =
                    clients.into_iter().map(|h| h.join().unwrap()).collect();
                swapper.join().unwrap();
                out
            })
        })
        .unwrap();

    // 1. Zero lost requests: every admitted request answered Ok exactly
    // once, across the kill, the failover requeue, and the hot swap.
    let total: usize = per_client.iter().map(|c| c.len()).sum();
    assert_eq!(total, CLIENTS * PER_CLIENT, "every request answered exactly once");

    // 2. Exactly one shard went Down and the survivor absorbed its work.
    assert_eq!(router.health(1), ShardHealth::Down, "tick 4 kills shard 1");
    assert_ne!(router.health(0), ShardHealth::Down, "shard 0 survives");
    let rs = router.router_stats();
    assert_eq!(rs.failovers, 1, "one kill, one failover");
    assert_eq!(rs.down_errors, 0, "a surviving replica means no outage errors");
    let s0 = router.shard_stats(0).requests as usize;
    let s1 = router.shard_stats(1).requests as usize;
    assert_eq!(s0 + s1, CLIENTS * PER_CLIENT, "shard counters account for every request");
    assert!(s0 > 0, "the survivor served the failed-over traffic");

    // 3. Per-task generation stamps never go backwards across the
    // failover, and the reload landed everywhere.
    for (client, out) in per_client.iter().enumerate() {
        let mut last = vec![0u64; TASKS];
        for (task, _, _, gen) in out {
            assert!(*gen <= 1, "one reload: generations are 0 or 1, got {gen}");
            assert!(
                *gen >= last[*task],
                "client {client} task {task}: generation went backwards"
            );
            last[*task] = *gen;
        }
    }

    // 4. Bit identity per generation: failover, requeueing, and work
    // stealing never change what is computed, only where it waits.
    let dims = ModelPreset::Tiny.dims(TASKS);
    let spec = ArtifactSpec {
        step: StepKind::Eval,
        model: "tiny".into(),
        adapter: "metatt4p1d".into(),
        rank: RANK,
        classes: 2,
        tasks: TASKS,
        batch: 1,
        seq: dims.max_seq,
    };
    let entry = backend.entry(&spec).unwrap();
    let frozen = Arc::new(assemble_frozen(&entry, None, ModelPreset::Tiny).unwrap());
    let step = backend.bind(&spec, &frozen).unwrap();
    let folded: [Vec<_>; 2] = [
        (0..TASKS).map(|t| tt_old.fold_for_serving(t)).collect(),
        (0..TASKS).map(|t| tt_new.fold_for_serving(t)).collect(),
    ];
    let mut want = vec![0f32; 2];
    for out in &per_client {
        for (task, tokens, got, gen) in out {
            step.run_serve(&folded[*gen as usize][*task], tokens, *task as i32, &mut want)
                .unwrap();
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(
                    g.to_bits(),
                    w.to_bits(),
                    "task {task} gen {gen}: sharded logits {g} != fault-free {w}"
                );
            }
        }
    }
}

#[test]
fn armed_shard_kill_trace_contains_the_failover_story() {
    use metatt::obs::{EventCode, Obs};
    use metatt::serving::{RoutePolicy, RouterConfig, ServeTarget, ShardHealth, ShardRouter};
    let seed = chaos_seed();
    // One manual sweep probes shard 0 (tick 1) then shard 1 (tick 2, the
    // kill); slow_tick wedges every serve tick long enough that requests
    // submitted just before the sweep are still queued when the kill
    // drains shard 1 — so the failover drain is non-empty by construction.
    let plan =
        FaultPlan::parse(&format!("slow_tick=25ms@p=1.0,shard_down@tick=2,seed={seed}"))
            .unwrap();
    let backend = RefBackend::with_config(1, true).unwrap();
    let obs = Arc::new(Obs::new(true));
    let mut ecfg = engine_cfg(2, 4, FaultPlan::empty());
    ecfg.faults = Arc::new(plan);
    ecfg.obs = Arc::clone(&obs);
    let rcfg = RouterConfig {
        engine: ecfg,
        shards: 2,
        replicas: 2,
        route: RoutePolicy::Affinity,
        // Long enough that the only sweep during the ~150ms driver is the
        // manual one (serve's teardown still pays one sleep of this).
        heartbeat: Duration::from_secs(1),
        failure_threshold: 3,
    };
    let router = ShardRouter::new(&backend, rcfg, |_| demo_tt(5), None).unwrap();
    let seq = router.seq_len();
    let vocab = router.vocab();

    router
        .serve(|r| {
            // Task 1 pins to slot 1 under affinity (groups=1). 14 requests
            // against 2 workers x batch 4 leaves at least 6 queued while
            // the in-flight batches sleep through their slow ticks.
            let handles: Vec<_> = (0..14)
                .map(|i| {
                    let (_, tokens) = chaos_request(seq, vocab, 1, i);
                    r.submit_with(1, tokens, None, 0).unwrap()
                })
                .collect();
            std::thread::sleep(Duration::from_millis(10));
            r.heartbeat_now();
            for (i, h) in handles.into_iter().enumerate() {
                let resp = h.wait().unwrap();
                assert_eq!(
                    resp.status,
                    ResponseStatus::Ok,
                    "request {i} lost across the failover: {:?}",
                    resp.error
                );
            }
        })
        .unwrap();

    assert_eq!(router.health(1), ShardHealth::Down, "tick 2 kills shard 1");
    let rs = router.router_stats();
    assert_eq!(rs.failovers, 1, "one kill, one failover");
    assert!(rs.moved >= 1, "the drain must move the queued work");

    // The exported trace tells the whole story: a health transition, the
    // failover drain, and the router requeue — in causal order (all three
    // are stamped by the supervisor thread, so one ring preserves it).
    let events = obs.tracer().snapshot();
    assert!(
        events.windows(2).all(|w| w[0].ts_us <= w[1].ts_us),
        "snapshot timestamps must be globally monotone"
    );
    let ts_of = |code: EventCode| events.iter().find(|e| e.code == code).map(|e| e.ts_us);
    let down = ts_of(EventCode::ShardDown).expect("health-transition span missing");
    let drain = ts_of(EventCode::FailoverDrain).expect("failover span missing");
    let requeue = ts_of(EventCode::Requeue).expect("requeue span missing");
    assert!(down <= drain, "health transition precedes the failover drain");
    assert!(drain <= requeue, "drain precedes the router requeue");
    let drain_ev = events.iter().find(|e| e.code == EventCode::FailoverDrain).unwrap();
    assert_eq!(drain_ev.a, 1, "the drained shard is the killed one");
    assert_eq!(drain_ev.b, rs.moved, "the span payload carries the moved count");

    // And the Chrome export names all three for the trace viewer.
    let json = obs.chrome_trace();
    for name in ["shard_down", "failover_drain", "requeue", "slow_tick"] {
        assert!(json.contains(&format!("\"name\":\"{name}\"")), "{name} missing: {json}");
    }
}

#[test]
fn a_wedged_server_surfaces_as_a_clean_timeout() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let done = AtomicBool::new(false);
    std::thread::scope(|scope| {
        // Accept and then say nothing: the client handshake write lands in
        // the socket buffer, the hello read must hit its timeout.
        let acceptor = scope.spawn(|| {
            let (stream, _) = listener.accept().unwrap();
            while !done.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(5));
            }
            drop(stream);
        });
        let err = NetClient::connect_with(&addr, Some(Duration::from_millis(80)))
            .expect_err("handshake against a mute server must time out");
        assert!(
            format!("{err:#}").contains("timed out"),
            "timeout must be a clean, named error: {err:#}"
        );
        done.store(true, Ordering::Relaxed);
        acceptor.join().unwrap();
    });
}
