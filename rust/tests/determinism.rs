//! Determinism suite for the parallel execution engine.
//!
//! The PR-2 contract: threading changes *where* work runs, never *what* is
//! computed. Concretely —
//!
//! 1. the parallel matmul kernel family must match the serial kernels
//!    **bit-for-bit** on arbitrary rectangular shapes (including the
//!    m=1 / n=1 / k=1 degenerate edges and non-multiple-of-block sizes);
//! 2. full RefBackend train / eval / pretrain steps run with 1 thread and
//!    N threads must produce bit-identical losses, gradients, and logits.
//!
//! Everything here is hermetic (ref backend, synthesized layouts).

use metatt::data::{Batcher, MlmCorpus, TaskId};
use metatt::runtime::{assemble_frozen, ArtifactSpec, Backend, RefBackend, StepKind};
use metatt::tensor::{rel_err, Tensor};
use metatt::util::rng::Pcg64;

// ---------------------------------------------------------------------------
// Kernel parity: parallel vs serial, and both vs a naive oracle.
// ---------------------------------------------------------------------------

fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for t in 0..k {
                acc += a.at(i, t) * b.at(t, j);
            }
            c.set(i, j, acc);
        }
    }
    c
}

/// Random rectangular shapes, biased toward the sizes where banding and
/// blocking boundaries live, plus the degenerate edges.
fn shapes() -> Vec<(usize, usize, usize)> {
    let mut out = vec![
        (0, 5, 7), // zero-row/col/inner edges must not panic
        (5, 0, 7),
        (5, 7, 0),
        (1, 1, 1),
        (1, 300, 300),
        (300, 1, 300),
        (300, 300, 1),
        (1, 1, 513),
        (513, 1, 1),
        (2, 500, 2),
        (63, 64, 65),
        (128, 128, 128),
        (257, 129, 65),
        (256, 256, 256), // above the parallel threshold
        (512, 64, 300),
    ];
    let mut rng = Pcg64::new(0x5eed);
    for _ in 0..6 {
        let dim = |r: &mut Pcg64| 1 + (r.next_u64() % 200) as usize;
        out.push((dim(&mut rng), dim(&mut rng), dim(&mut rng)));
    }
    out
}

#[test]
fn parallel_matmul_bitwise_matches_serial_on_rectangles() {
    let mut rng = Pcg64::new(7);
    for (m, k, n) in shapes() {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let serial = a.matmul_mt(&b, 1);
        for threads in [2, 4, 7] {
            let par = a.matmul_mt(&b, threads);
            assert_eq!(serial, par, "matmul ({m},{k},{n}) threads={threads}");
        }
        assert!(
            rel_err(&serial, &naive_matmul(&a, &b)) < 1e-4,
            "matmul vs naive ({m},{k},{n})"
        );
    }
}

#[test]
fn parallel_matmul_t_bitwise_matches_serial_on_rectangles() {
    let mut rng = Pcg64::new(8);
    for (m, k, n) in shapes() {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[n, k], 1.0, &mut rng);
        let serial = a.matmul_t_mt(&b, 1);
        for threads in [2, 4, 7] {
            let par = a.matmul_t_mt(&b, threads);
            assert_eq!(serial, par, "matmul_t ({m},{k},{n}) threads={threads}");
        }
        assert!(
            rel_err(&serial, &naive_matmul(&a, &b.transpose())) < 1e-4,
            "matmul_t vs naive ({m},{k},{n})"
        );
    }
}

#[test]
fn parallel_t_matmul_bitwise_matches_serial_on_rectangles() {
    let mut rng = Pcg64::new(9);
    for (m, k, n) in shapes() {
        let a = Tensor::randn(&[k, m], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let serial = a.t_matmul_mt(&b, 1);
        for threads in [2, 4, 7] {
            let par = a.t_matmul_mt(&b, threads);
            assert_eq!(serial, par, "t_matmul ({m},{k},{n}) threads={threads}");
        }
        assert!(
            rel_err(&serial, &naive_matmul(&a.transpose(), &b)) < 1e-4,
            "t_matmul vs naive ({m},{k},{n})"
        );
    }
}

// ---------------------------------------------------------------------------
// Full-step determinism: 1 thread vs N threads, bit-identical.
// ---------------------------------------------------------------------------

fn tiny_spec(step: StepKind, adapter: &str, batch: usize, seq: usize) -> ArtifactSpec {
    ArtifactSpec {
        step,
        model: "tiny".into(),
        adapter: adapter.into(),
        rank: 4,
        classes: 2,
        tasks: 1,
        batch,
        seq,
    }
}

fn random_params(backend: &RefBackend, spec: &ArtifactSpec, seed: u64) -> Vec<Tensor> {
    let entry = backend.entry(spec).unwrap();
    let mut rng = Pcg64::new(seed);
    entry
        .trainable_inputs()
        .iter()
        .map(|io| Tensor::randn(&io.shape, 0.2, &mut rng))
        .collect()
}

fn assert_tensors_bit_identical(a: &[Tensor], b: &[Tensor], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: tensor count");
    for (i, (ta, tb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ta.shape(), tb.shape(), "{what}[{i}]: shape");
        for (j, (&va, &vb)) in ta.data().iter().zip(tb.data()).enumerate() {
            assert!(
                va.to_bits() == vb.to_bits(),
                "{what}[{i}] elem {j}: {va:?} != {vb:?} (bits differ)"
            );
        }
    }
}

/// Run the same train step on 1-thread and N-thread backends — and with
/// the workspace arena on and off — losses and every gradient must agree
/// to the bit across all combinations. Exercised per adapter family so
/// each backward path's parallel splits and pooled buffers are covered.
fn check_train_step_determinism(adapter: &str) {
    let (batch_n, seq) = (8, 16);
    let spec = tiny_spec(StepKind::Train, adapter, batch_n, seq);
    let ds = TaskId::MrpcSyn.generate_at(batch_n, batch_n, 3, seq, 512);
    let batch = Batcher::new(batch_n).eval(&ds).remove(0);

    let reference = RefBackend::with_config(1, true).unwrap(); // serial, arena on
    let entry = reference.entry(&spec).unwrap();
    let frozen = std::sync::Arc::new(
        assemble_frozen(&entry, None, metatt::config::ModelPreset::Tiny).unwrap(),
    );
    let params = random_params(&reference, &spec, 42);

    let s_ref = reference.bind(&spec, &frozen).unwrap();
    let (l_ref, g_ref) = s_ref.run_train(&params, &batch, 0, 1.5).unwrap();
    // Second step on the same (now warmed) arena: pooled buffers must not
    // leak state between steps.
    let (l_warm, g_warm) = s_ref.run_train(&params, &batch, 0, 1.5).unwrap();
    assert_eq!(l_ref.to_bits(), l_warm.to_bits(), "{adapter}: warmed arena drifted");
    assert_tensors_bit_identical(&g_ref, &g_warm, &format!("{adapter} warmed grads"));

    for (threads, arena) in [(4usize, true), (1, false), (4, false)] {
        let b = RefBackend::with_config(threads, arena).unwrap();
        let s = b.bind(&spec, &frozen).unwrap();
        let (l, g) = s.run_train(&params, &batch, 0, 1.5).unwrap();
        assert_eq!(
            l_ref.to_bits(),
            l.to_bits(),
            "{adapter}: loss bits differ (threads={threads}, arena={arena})"
        );
        assert_tensors_bit_identical(
            &g_ref,
            &g,
            &format!("{adapter} grads (threads={threads}, arena={arena})"),
        );
    }
}

#[test]
fn train_step_bit_identical_across_thread_counts_metatt4d() {
    check_train_step_determinism("metatt4d");
}

#[test]
fn train_step_bit_identical_across_thread_counts_metatt5d() {
    check_train_step_determinism("metatt5d");
}

#[test]
fn train_step_bit_identical_across_thread_counts_lora() {
    check_train_step_determinism("lora");
}

#[test]
fn train_step_bit_identical_across_thread_counts_full_ft() {
    // Full FT flows gradients through every encoder weight — covers the
    // LN γ/β reductions, bias colsums, and the embedding scatter. With no
    // frozen projections there are no packed transposes either, so this
    // also pins the strided-fallback backward orientation.
    check_train_step_determinism("full");
}

#[test]
fn train_step_bit_identical_across_thread_counts_metatt4p1d() {
    // The (4+1)D task-core routing plus the per-step ab/bc precompute.
    check_train_step_determinism("metatt4p1d");
}

#[test]
fn train_step_bit_identical_across_thread_counts_vera() {
    // VeRA's shared frozen projections + fused dx accumulation.
    check_train_step_determinism("vera");
}

#[test]
fn train_step_bit_identical_across_thread_counts_lotr() {
    // LoTR's shared x·U prefix + fused backward tail.
    check_train_step_determinism("lotr");
}

#[test]
fn eval_step_bit_identical_across_thread_counts_and_arena() {
    let (batch_n, seq) = (8, 16);
    let spec = tiny_spec(StepKind::Eval, "metatt4d", batch_n, seq);
    let ds = TaskId::RteSyn.generate_at(batch_n, batch_n, 5, seq, 512);
    let batch = Batcher::new(batch_n).eval(&ds).remove(0);

    let reference = RefBackend::with_config(1, true).unwrap();
    let entry = reference.entry(&spec).unwrap();
    let frozen = std::sync::Arc::new(
        assemble_frozen(&entry, None, metatt::config::ModelPreset::Tiny).unwrap(),
    );
    let params = random_params(&reference, &spec, 11);
    let s_ref = reference.bind(&spec, &frozen).unwrap();
    let logits_ref = s_ref.run_eval(&params, &batch, 0, 2.0).unwrap();
    // Warmed cache-free forward must be bit-stable too.
    let logits_warm = s_ref.run_eval(&params, &batch, 0, 2.0).unwrap();
    for (threads, arena) in [(1usize, true), (4, true), (1, false), (4, false)] {
        let b = RefBackend::with_config(threads, arena).unwrap();
        let logits = b.bind(&spec, &frozen).unwrap().run_eval(&params, &batch, 0, 2.0).unwrap();
        assert_tensors_bit_identical(
            std::slice::from_ref(&logits_ref),
            std::slice::from_ref(&logits),
            &format!("eval logits (threads={threads}, arena={arena})"),
        );
    }
    assert_tensors_bit_identical(
        std::slice::from_ref(&logits_ref),
        std::slice::from_ref(&logits_warm),
        "eval logits (warmed arena)",
    );
}

#[test]
fn pretrain_step_bit_identical_across_thread_counts() {
    let spec = ArtifactSpec {
        step: StepKind::Pretrain,
        model: "tiny".into(),
        adapter: "none".into(),
        rank: 0,
        classes: 1,
        tasks: 1,
        batch: 4,
        seq: 16,
    };
    let b1 = RefBackend::with_threads(1).unwrap();
    let b4 = RefBackend::with_threads(4).unwrap();
    let params = random_params(&b1, &spec, 23);
    let mut corpus = MlmCorpus::new(512, 16, 77);
    let batch = corpus.next_batch(4);
    let (l1, g1) = b1
        .bind(&spec, &Default::default())
        .unwrap()
        .run_pretrain(&params, &batch)
        .unwrap();
    let (l4, g4) = b4
        .bind(&spec, &Default::default())
        .unwrap()
        .run_pretrain(&params, &batch)
        .unwrap();
    assert_eq!(l1.to_bits(), l4.to_bits(), "pretrain loss bits differ");
    assert_tensors_bit_identical(&g1, &g4, "pretrain grads");
}

#[test]
fn apply_step_bit_identical_across_thread_counts() {
    let b1 = RefBackend::with_threads(1).unwrap();
    let b4 = RefBackend::with_threads(4).unwrap();
    let spec = b1.apply_spec("metatt4d", 8).unwrap();
    let entry = b1.entry(&spec).unwrap();
    let mut rng = Pcg64::new(3);
    let inputs: Vec<Tensor> = entry
        .inputs
        .iter()
        .map(|io| Tensor::randn(&io.shape, 0.5, &mut rng))
        .collect();
    let y1 = b1.bind(&spec, &Default::default()).unwrap().run_raw(&inputs).unwrap();
    let y4 = b4.bind(&spec, &Default::default()).unwrap().run_raw(&inputs).unwrap();
    assert_tensors_bit_identical(&y1, &y4, "apply output");
}
