//! End-to-end tests for the TCP serving front-end (PR 6).
//!
//! The load-bearing properties:
//!
//! 1. **Wire transparency** — logits that traveled the MTS1 protocol are
//!    bit-identical to a direct in-process `run_serve` forward for the
//!    same task and tokens (f32 bits survive encode/decode).
//! 2. **Deadline semantics over the wire** — an effectively-zero deadline
//!    comes back with the explicit `Expired` status and no logits.
//! 3. **Protocol robustness** — a bad handshake drops that connection
//!    only; an invalid request gets an error frame and the connection
//!    keeps serving.
//! 4. **Graceful drain** — responses already admitted when the shutdown
//!    flag rises are still flushed to the client before the socket closes.

use metatt::adapters::AdapterKind;
use metatt::config::ModelPreset;
use metatt::runtime::{assemble_frozen, ArtifactSpec, Backend, RefBackend, StepKind};
use metatt::serving::{
    adapter_spec_for, serve_net, EngineConfig, NetClient, ServingEngine, WireStatus,
};
use metatt::tensor::DtypeKind;
use metatt::tt::{CoreInit, InitStrategy, MetaTt, MetaTtKind};
use metatt::util::rng::Pcg64;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

const TASKS: usize = 3;
const RANK: usize = 4;
const ALPHA: f32 = 1.3;

fn engine_cfg(workers: usize, max_batch: usize) -> EngineConfig {
    EngineConfig {
        model: ModelPreset::Tiny,
        adapter: AdapterKind::MetaTt(MetaTtKind::FourPlusOneD),
        rank: RANK,
        alpha: ALPHA,
        num_tasks: TASKS,
        classes: 2,
        max_batch,
        batch_deadline: Duration::from_millis(1),
        queue_capacity: 64,
        workers,
        cache_capacity_bytes: 64 << 20,
        dtype: DtypeKind::F32,
        faults: std::sync::Arc::new(metatt::util::fault::FaultPlan::empty()),
        obs: std::sync::Arc::new(metatt::obs::Obs::new(false)),
    }
}

fn demo_tt(seed: u64) -> MetaTt {
    let spec = adapter_spec_for(&engine_cfg(1, 4));
    let init = InitStrategy {
        cores: vec![CoreInit::Normal; MetaTtKind::FourPlusOneD.order()],
    };
    spec.build_metatt_with(&mut Pcg64::new(seed), Some(&init))
}

/// Direct single-request folded forward, bypassing the engine and the
/// wire entirely — the bit-exactness reference.
fn single_request_logits(
    backend: &RefBackend,
    tt: &MetaTt,
    task: usize,
    tokens: &[i32],
) -> Vec<f32> {
    let dims = ModelPreset::Tiny.dims(TASKS);
    let spec = ArtifactSpec {
        step: StepKind::Eval,
        model: "tiny".into(),
        adapter: "metatt4p1d".into(),
        rank: RANK,
        classes: 2,
        tasks: TASKS,
        batch: 1,
        seq: dims.max_seq,
    };
    let entry = backend.entry(&spec).unwrap();
    let frozen = Arc::new(assemble_frozen(&entry, None, ModelPreset::Tiny).unwrap());
    let step = backend.bind(&spec, &frozen).unwrap();
    let folded = tt.fold_for_serving(task);
    let mut out = vec![0f32; 2];
    step.run_serve(&folded, tokens, task as i32, &mut out).unwrap();
    out
}

/// Run `body(addr)` against a loopback server for `engine`, then raise the
/// shutdown flag and return (body result, server NetStats).
fn with_server<T>(
    engine: &ServingEngine<'_>,
    body: impl FnOnce(&str) -> T,
) -> (T, metatt::serving::NetStats) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let shutdown = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let server = scope.spawn(|| engine.serve(|eng| serve_net(eng, listener, &shutdown)));
        let out = body(&addr);
        shutdown.store(true, Ordering::Relaxed);
        let net = server.join().unwrap().unwrap().unwrap();
        (out, net)
    })
}

#[test]
fn wire_responses_are_bit_identical_to_direct_forwards() {
    let backend = RefBackend::with_config(1, true).unwrap();
    let tt = demo_tt(5);
    let engine =
        ServingEngine::new(&backend, engine_cfg(2, 4), tt.clone(), None).unwrap();
    let seq = engine.seq_len();
    let vocab = engine.vocab() as i32;
    let requests: Vec<(usize, Vec<i32>)> = (0..9)
        .map(|i| (i % TASKS, (0..seq).map(|j| 1 + ((i * 7 + j) as i32 % (vocab - 1))).collect()))
        .collect();
    let (got, net) = with_server(&engine, |addr| {
        let mut client = NetClient::connect_retry(addr, Duration::from_secs(10)).unwrap();
        // The hello carries everything a client needs to build requests.
        assert_eq!(client.hello.seq, seq);
        assert_eq!(client.hello.vocab, vocab as usize);
        assert_eq!(client.hello.classes, 2);
        assert_eq!(client.hello.num_tasks, TASKS);
        requests
            .iter()
            .enumerate()
            .map(|(i, (task, tokens))| {
                let resp = client.call(i as u64, *task, 0, 0, tokens).unwrap();
                assert_eq!(resp.id, i as u64, "ids echo back");
                assert_eq!(resp.status, WireStatus::Ok);
                assert_eq!(resp.task, *task);
                resp
            })
            .collect::<Vec<_>>()
    });
    assert_eq!(net.connections, 1);
    assert_eq!(net.requests, requests.len() as u64);
    for (resp, (task, tokens)) in got.iter().zip(&requests) {
        let want = single_request_logits(&backend, &tt, *task, tokens);
        assert_eq!(resp.logits.len(), want.len());
        for (g, w) in resp.logits.iter().zip(&want) {
            assert_eq!(
                g.to_bits(),
                w.to_bits(),
                "task {task}: wire logits {g} != direct {w}"
            );
        }
    }
}

#[test]
fn near_zero_deadline_comes_back_expired_over_the_wire() {
    let backend = RefBackend::with_config(1, true).unwrap();
    let engine = ServingEngine::new(&backend, engine_cfg(1, 1), demo_tt(5), None).unwrap();
    let seq = engine.seq_len();
    let ((ok, exp), _net) = with_server(&engine, |addr| {
        let mut client = NetClient::connect_retry(addr, Duration::from_secs(10)).unwrap();
        // Pipeline: a priority-0 no-deadline request to occupy the single
        // worker, then a priority-1 request with a 1µs deadline. Strict
        // priority keeps the second request queued behind the first's
        // full forward (if both are visible at formation), so whenever
        // its expiry is checked, far more than 1µs has passed since its
        // admission — it must be shed, never computed.
        client.send(0, 0, 0, 0, &vec![1; seq]).unwrap();
        client.send(1, 0, 1, 1, &vec![2; seq]).unwrap();
        let a = client.recv().unwrap();
        let b = client.recv().unwrap();
        // Responses arrive in request order per connection.
        assert_eq!((a.id, b.id), (0, 1));
        (a, b)
    });
    assert_eq!(ok.status, WireStatus::Ok);
    assert_eq!(ok.logits.len(), 2);
    assert_eq!(exp.status, WireStatus::Expired, "1µs deadline must be shed");
    assert!(exp.logits.is_empty());
    let stats = engine.stats();
    assert_eq!(stats.shed, 1);
    assert_eq!(stats.requests, 1);
}

#[test]
fn bad_magic_drops_the_connection_but_not_the_server() {
    let backend = RefBackend::with_config(1, true).unwrap();
    let engine = ServingEngine::new(&backend, engine_cfg(1, 4), demo_tt(5), None).unwrap();
    let seq = engine.seq_len();
    let (_, net) = with_server(&engine, |addr| {
        // A client speaking the wrong protocol is disconnected without a
        // hello…
        let mut bad = TcpStream::connect(addr).unwrap();
        bad.write_all(b"XXXX").unwrap();
        let mut buf = [0u8; 1];
        match bad.read(&mut buf) {
            Ok(0) => {}                   // clean close
            Ok(_) => panic!("server answered a bad-magic handshake"),
            Err(_) => {}                  // reset is also a rejection
        }
        drop(bad);
        // …and the listener keeps serving well-behaved clients.
        let mut good = NetClient::connect_retry(addr, Duration::from_secs(10)).unwrap();
        let resp = good.call(7, 1, 0, 0, &vec![3; seq]).unwrap();
        assert_eq!(resp.status, WireStatus::Ok);
        // An invalid request gets an error frame, not a dead socket.
        let err = good.call(8, 99, 0, 0, &vec![3; seq]).unwrap();
        assert_eq!(err.id, 8);
        assert_eq!(err.status, WireStatus::Error);
        assert!(
            err.error.as_deref().unwrap_or("").contains("out of range"),
            "error message should name the problem: {:?}",
            err.error
        );
        // The connection survives the error frame.
        let again = good.call(9, 0, 0, 0, &vec![4; seq]).unwrap();
        assert_eq!(again.status, WireStatus::Ok);
    });
    assert_eq!(net.connections, 2);
    assert_eq!(net.requests, 3, "the bad-magic connection served nothing");
    // PR 10: the protocol-error counters saw exactly this traffic. The
    // rejected handshake counts as bad magic AND a dropped connection; the
    // out-of-range request decoded fine (it is a validation error with an
    // echoed id, not a framing error), so bad_frames stays clean — and the
    // well-behaved connection was never disturbed (asserted above).
    let ctrs = &engine.obs().net;
    assert_eq!(ctrs.bad_magic.get(), 1, "one bad-magic handshake");
    assert_eq!(ctrs.dropped_conns.get(), 1, "the bad connection was dropped");
    assert_eq!(ctrs.bad_frames.get(), 0, "no framing errors on the good connection");
    assert_eq!(ctrs.oversized_frames.get(), 0);
}

#[test]
fn stat_admin_frame_returns_a_live_metrics_snapshot() {
    let backend = RefBackend::with_config(1, true).unwrap();
    let engine = ServingEngine::new(&backend, engine_cfg(1, 4), demo_tt(5), None).unwrap();
    let seq = engine.seq_len();
    let (text, net) = with_server(&engine, |addr| {
        let mut client = NetClient::connect_retry(addr, Duration::from_secs(10)).unwrap();
        // Interleave request → STAT → request: the snapshot rides the
        // ordered writer queue without disturbing pipelined responses.
        let r1 = client.call(1, 0, 0, 0, &vec![1; seq]).unwrap();
        assert_eq!(r1.status, WireStatus::Ok);
        let text = client.stat().unwrap();
        let r2 = client.call(2, 1, 0, 0, &vec![2; seq]).unwrap();
        assert_eq!(r2.status, WireStatus::Ok);
        text
    });
    assert_eq!(net.requests, 2, "STAT is an admin frame, not a request");
    // The snapshot is a live engine view in Prometheus text format: engine
    // families, cache families, net counters (including this very STAT),
    // stage histograms, and the tracer meta-gauges.
    assert!(text.contains("metatt_engine_requests_total 1"), "{text}");
    assert!(text.contains("metatt_net_stat_frames_total 1"), "{text}");
    assert!(text.contains("metatt_cache_folds_total"), "{text}");
    assert!(text.contains("metatt_stage_compute_us_count"), "{text}");
    assert!(text.contains("metatt_trace_armed 0"), "{text}");
    assert_eq!(engine.obs().net.stat_frames.get(), 1);
}

#[test]
fn shutdown_flushes_admitted_responses_before_closing() {
    let backend = RefBackend::with_config(1, true).unwrap();
    let engine = ServingEngine::new(&backend, engine_cfg(2, 4), demo_tt(5), None).unwrap();
    let seq = engine.seq_len();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let shutdown = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let server = scope.spawn(|| engine.serve(|eng| serve_net(eng, listener, &shutdown)));
        let mut client = NetClient::connect_retry(&addr, Duration::from_secs(10)).unwrap();
        let n = 6u64;
        for i in 0..n {
            client.send(i, (i as usize) % TASKS, 0, 0, &vec![1 + i as i32; seq]).unwrap();
        }
        // Raise shutdown while responses may still be in flight: the
        // graceful drain must flush every admitted response first.
        shutdown.store(true, Ordering::Relaxed);
        for i in 0..n {
            let resp = client.recv().unwrap_or_else(|e| {
                panic!("response {i} lost across shutdown: {e}")
            });
            assert_eq!(resp.id, i);
            assert_eq!(resp.status, WireStatus::Ok);
        }
        // After the drain the server closes the socket: the next read is
        // a clean EOF, not a hang.
        assert!(client.recv().is_err(), "socket must be closed after the drain");
        let net = server.join().unwrap().unwrap().unwrap();
        assert_eq!(net.requests, n);
    });
    assert_eq!(engine.stats().requests, 6);
}
