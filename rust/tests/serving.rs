//! End-to-end tests for the multi-task serving engine (PR 5).
//!
//! The load-bearing properties:
//!
//! 1. **Batching transparency** — a response that traveled the full
//!    queue → dynamic-batcher → folded-cache → worker path is bit-identical
//!    to a *direct single-request* `run_serve` forward (batch-1 spec bound
//!    straight on the backend) for the same task and tokens. Every row of a
//!    serving batch depends only on its own tokens, so coalescing and
//!    padding never leak into results.
//! 2. **Worker-count determinism** — 1-worker and N-worker engines answer
//!    the same seeded request stream bit-identically, even though their
//!    batch compositions differ.
//! 3. **Serving ≈ training forward** — folded-path logits agree with the
//!    family-path `run_eval` logits to FP-reassociation tolerance (exact
//!    parity of the fold itself is pinned per family/task in tt::meta).
//! 4. **Checkpoint round-trip** — the engine serves adapter state written
//!    through the v2 (metadata) checkpoint container.
//! 5. **Hot-swap** — `reload` bumps the generation served to later
//!    requests without invalidating earlier ones.

use metatt::adapters::AdapterKind;
use metatt::config::ModelPreset;
use metatt::coordinator::checkpoint::{self, CheckpointMeta};
use metatt::data::Batch;
use metatt::runtime::{assemble_frozen, ArtifactSpec, Backend, RefBackend, StepKind};
use metatt::serving::{
    adapter_spec_for, metatt_from_tensors, request_stream, EngineConfig, LoadGenConfig,
    Response, ResponseStatus, ServingEngine,
};
use metatt::tensor::DtypeKind;
use metatt::tt::{CoreInit, InitStrategy, MetaTt, MetaTtKind};
use metatt::util::rng::Pcg64;
use std::sync::Arc;
use std::time::Duration;

const TASKS: usize = 3;
const RANK: usize = 4;
const ALPHA: f32 = 1.3;

fn engine_cfg(workers: usize, max_batch: usize) -> EngineConfig {
    EngineConfig {
        model: ModelPreset::Tiny,
        adapter: AdapterKind::MetaTt(MetaTtKind::FourPlusOneD),
        rank: RANK,
        alpha: ALPHA,
        num_tasks: TASKS,
        classes: 2,
        max_batch,
        batch_deadline: Duration::from_millis(1),
        queue_capacity: 128,
        workers,
        cache_capacity_bytes: 64 << 20,
        dtype: DtypeKind::F32,
        faults: std::sync::Arc::new(metatt::util::fault::FaultPlan::empty()),
        obs: std::sync::Arc::new(metatt::obs::Obs::new(false)),
    }
}

/// `engine_cfg` with the adapter family and serving dtype swapped out —
/// the quantized-parity tests sweep both axes.
fn cfg_for(kind: MetaTtKind, dtype: DtypeKind) -> EngineConfig {
    EngineConfig {
        adapter: AdapterKind::MetaTt(kind),
        dtype,
        ..engine_cfg(2, 4)
    }
}

/// A deterministic non-zero adapter state for an arbitrary TT family.
fn tt_for(kind: MetaTtKind, seed: u64) -> MetaTt {
    let spec = adapter_spec_for(&cfg_for(kind, DtypeKind::F32));
    let init = InitStrategy { cores: vec![CoreInit::Normal; kind.order()] };
    spec.build_metatt_with(&mut Pcg64::new(seed), Some(&init))
}

/// A deterministic non-zero adapter state for the test config.
fn demo_tt(seed: u64) -> MetaTt {
    let spec = adapter_spec_for(&engine_cfg(1, 4));
    let init = InitStrategy {
        cores: vec![CoreInit::Normal; MetaTtKind::FourPlusOneD.order()],
    };
    spec.build_metatt_with(&mut Pcg64::new(seed), Some(&init))
}

/// The deterministic request stream the tests replay on both sides.
fn demo_stream(count: usize) -> Vec<(usize, Vec<i32>)> {
    let dims = ModelPreset::Tiny.dims(TASKS);
    let lcfg = LoadGenConfig { seed: 21, ..Default::default() };
    request_stream(&lcfg, TASKS, dims.max_seq, dims.vocab, 0, count)
}

/// Run `stream` through a full engine and return the responses in request
/// order.
fn serve_stream(
    backend: &dyn Backend,
    cfg: EngineConfig,
    tt: MetaTt,
    stream: &[(usize, Vec<i32>)],
) -> Vec<Response> {
    let engine = ServingEngine::new(backend, cfg, tt, None).unwrap();
    engine
        .serve(|eng| {
            let handles: Vec<_> = stream
                .iter()
                .map(|(task, tokens)| eng.submit(*task, tokens.clone()).unwrap())
                .collect();
            handles.into_iter().map(|h| h.wait().unwrap()).collect::<Vec<_>>()
        })
        .unwrap()
}

/// Direct single-request folded forward: a batch-1 eval spec bound straight
/// on the backend, bypassing queue/batcher/cache entirely.
fn single_request_logits(
    backend: &RefBackend,
    tt: &MetaTt,
    task: usize,
    tokens: &[i32],
) -> Vec<f32> {
    let dims = ModelPreset::Tiny.dims(TASKS);
    let spec = ArtifactSpec {
        step: StepKind::Eval,
        model: "tiny".into(),
        adapter: "metatt4p1d".into(),
        rank: RANK,
        classes: 2,
        tasks: TASKS,
        batch: 1,
        seq: dims.max_seq,
    };
    let entry = backend.entry(&spec).unwrap();
    let frozen = Arc::new(assemble_frozen(&entry, None, ModelPreset::Tiny).unwrap());
    let step = backend.bind(&spec, &frozen).unwrap();
    let folded = tt.fold_for_serving(task);
    let mut out = vec![0f32; 2];
    step.run_serve(&folded, tokens, task as i32, &mut out).unwrap();
    out
}

#[test]
fn engine_responses_are_bit_identical_to_direct_single_request_forwards() {
    let backend = RefBackend::with_config(1, true).unwrap();
    let tt = demo_tt(5);
    let stream = demo_stream(24);
    let responses = serve_stream(&backend, engine_cfg(2, 4), tt.clone(), &stream);
    assert_eq!(responses.len(), stream.len());
    for (resp, (task, tokens)) in responses.iter().zip(&stream) {
        assert_eq!(resp.task, *task);
        assert_eq!(resp.logits.len(), 2);
        assert!(resp.batch_rows >= 1 && resp.batch_rows <= 4);
        let want = single_request_logits(&backend, &tt, *task, tokens);
        for (g, w) in resp.logits.iter().zip(&want) {
            assert_eq!(
                g.to_bits(),
                w.to_bits(),
                "request {} (task {task}): batched {g:?} != direct {w:?}",
                resp.id
            );
        }
    }
}

#[test]
fn one_and_four_worker_engines_answer_bit_identically() {
    let backend = RefBackend::with_config(1, true).unwrap();
    let stream = demo_stream(32);
    let serial = serve_stream(&backend, engine_cfg(1, 4), demo_tt(5), &stream);
    let parallel = serve_stream(&backend, engine_cfg(4, 4), demo_tt(5), &stream);
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.task, b.task);
        for (x, y) in a.logits.iter().zip(&b.logits) {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "request {}: 1-worker and 4-worker logits differ",
                a.id
            );
        }
    }
}

#[test]
fn serving_logits_match_the_family_eval_forward_numerically() {
    // The folded factors reassociate the TT contraction (A = G1·mid is
    // merged), so serving vs run_eval is an FP-tolerance comparison; the
    // fold's exact algebra is pinned separately in tt::meta tests.
    let backend = RefBackend::with_config(1, true).unwrap();
    let tt = demo_tt(5);
    let stream = demo_stream(8);
    let responses = serve_stream(&backend, engine_cfg(2, 4), tt.clone(), &stream);
    let dims = ModelPreset::Tiny.dims(TASKS);
    let spec = ArtifactSpec {
        step: StepKind::Eval,
        model: "tiny".into(),
        adapter: "metatt4p1d".into(),
        rank: RANK,
        classes: 2,
        tasks: TASKS,
        batch: 1,
        seq: dims.max_seq,
    };
    let entry = backend.entry(&spec).unwrap();
    let frozen = Arc::new(assemble_frozen(&entry, None, ModelPreset::Tiny).unwrap());
    let step = backend.bind(&spec, &frozen).unwrap();
    let params = tt.export_cores();
    for (resp, (task, tokens)) in responses.iter().zip(&stream) {
        let batch = Batch {
            tokens: tokens.clone(),
            labels: vec![0],
            scores: vec![0.0],
            weights: vec![1.0],
            batch_size: 1,
            seq_len: dims.max_seq,
        };
        let logits = step.run_eval(&params, &batch, *task as i32, ALPHA).unwrap();
        for (c, (&g, &w)) in resp.logits.iter().zip(logits.data()).enumerate() {
            let scale = w.abs().max(1.0);
            assert!(
                ((g - w) / scale).abs() < 1e-3,
                "request {} class {c}: serving {g} vs eval {w}",
                resp.id
            );
        }
    }
}

#[test]
fn engine_serves_state_from_a_v2_checkpoint_and_hot_swaps_generations() {
    let backend = RefBackend::with_config(1, true).unwrap();
    let tt = demo_tt(9);
    // Round-trip the adapter through the v2 (metadata) container.
    let aspec = adapter_spec_for(&engine_cfg(1, 4));
    let named: Vec<(String, metatt::tensor::Tensor)> = aspec
        .param_specs()
        .iter()
        .zip(tt.export_cores())
        .map(|(p, t)| (p.name.clone(), t))
        .collect();
    let meta = CheckpointMeta {
        adapter: "metatt4p1d".into(),
        rank: RANK,
        tasks: TASKS,
        alpha: ALPHA,
        model: "tiny".into(),
        dtype: "f32".into(),
    };
    let path = std::env::temp_dir().join("metatt_serving_test_adapter.bin");
    checkpoint::save_with_meta(&path, &meta, &named).unwrap();
    let (loaded_meta, tensors) = checkpoint::load_with_meta(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded_meta.unwrap(), meta);
    let restored = metatt_from_tensors(&aspec, &tensors).unwrap();

    let stream = demo_stream(6);
    let engine =
        ServingEngine::new(&backend, engine_cfg(2, 4), restored.clone(), None).unwrap();
    let (before, after) = engine
        .serve(|eng| {
            let before: Vec<Response> = stream
                .iter()
                .map(|(t, tok)| eng.submit(*t, tok.clone()).unwrap().wait().unwrap())
                .collect();
            eng.reload(demo_tt(10)).unwrap();
            let after: Vec<Response> = stream
                .iter()
                .map(|(t, tok)| eng.submit(*t, tok.clone()).unwrap().wait().unwrap())
                .collect();
            (before, after)
        })
        .unwrap();
    // Pre-reload responses came from generation 0 and match the
    // checkpointed state exactly (round-trip is lossless).
    for (resp, (task, tokens)) in before.iter().zip(&stream) {
        assert_eq!(resp.generation, 0);
        let want = single_request_logits(&backend, &tt, *task, tokens);
        for (g, w) in resp.logits.iter().zip(&want) {
            assert_eq!(g.to_bits(), w.to_bits(), "checkpointed state drifted");
        }
    }
    // Post-reload responses come from generation 1 with different values.
    let mut any_diff = false;
    for (resp, b) in after.iter().zip(&before) {
        assert_eq!(resp.generation, 1);
        any_diff |= resp.logits != b.logits;
    }
    assert!(any_diff, "reloaded adapter must change at least one response");
    assert_eq!(engine.generation(), 1);
    assert_eq!(engine.cache_stats().reloads, 1);
    // Dimension-incompatible reloads are rejected up front. (Rank is
    // deliberately NOT structural — the folded serving form is
    // rank-agnostic — so probe with a different task-core arity.)
    let cfg_bad = EngineConfig { num_tasks: TASKS + 2, ..engine_cfg(1, 4) };
    let bad_tt = {
        let spec = adapter_spec_for(&cfg_bad);
        let init = InitStrategy {
            cores: vec![CoreInit::Normal; MetaTtKind::FourPlusOneD.order()],
        };
        spec.build_metatt_with(&mut Pcg64::new(3), Some(&init))
    };
    assert!(engine.reload(bad_tt).is_err(), "wrong task arity must be rejected");
}

#[test]
fn engine_validates_requests_and_config() {
    let backend = RefBackend::with_config(1, true).unwrap();
    let engine = ServingEngine::new(&backend, engine_cfg(1, 4), demo_tt(5), None).unwrap();
    let seq = engine.seq_len();
    engine
        .serve(|eng| {
            assert!(eng.submit(TASKS, vec![1; seq]).is_err(), "task out of range");
            assert!(eng.submit(0, vec![1; seq - 1]).is_err(), "short token row");
            assert!(eng.submit(0, vec![-1; seq]).is_err(), "negative token id");
            let vocab = eng.vocab() as i32;
            assert!(eng.submit(0, vec![vocab; seq]).is_err(), "token beyond vocab");
            // A valid request still flows.
            let resp = eng.submit(1, vec![1; seq]).unwrap().wait().unwrap();
            assert_eq!(resp.task, 1);
        })
        .unwrap();
    // Non-TT adapters cannot be folded for serving.
    let cfg = EngineConfig { adapter: AdapterKind::LoRa, ..engine_cfg(1, 4) };
    assert!(ServingEngine::new(&backend, cfg, demo_tt(5), None).is_err());
}

#[test]
fn expired_requests_are_shed_answered_not_computed() {
    // A zero relative deadline is expired the instant a worker reaches it
    // (expiry is inclusive and batch formation happens strictly after
    // admission), so this is deterministic: the request must come back
    // `Expired` with empty logits, and the engine must have spent zero
    // compute — no batch, no request counted, shed counted.
    let backend = RefBackend::with_config(1, true).unwrap();
    let engine = ServingEngine::new(&backend, engine_cfg(1, 4), demo_tt(5), None).unwrap();
    let seq = engine.seq_len();
    let resp = engine
        .serve(|eng| {
            eng.submit_with(0, vec![1; seq], Some(Duration::ZERO), 0)
                .unwrap()
                .wait()
                .unwrap()
        })
        .unwrap();
    assert_eq!(resp.status, ResponseStatus::Expired);
    assert!(resp.logits.is_empty(), "shed responses carry no logits");
    assert_eq!(resp.batch_rows, 0);
    assert_eq!(resp.generation, 0);
    let stats = engine.stats();
    assert_eq!(stats.shed, 1, "the shed counter must record it");
    assert_eq!(stats.requests, 0, "a shed request is not a computed request");
    assert_eq!(stats.batches, 0, "shed-only drains must not execute a batch");
}

#[test]
fn graceful_drain_answers_every_admitted_request() {
    // The driver submits a burst — live requests and guaranteed-expired
    // ones — and returns the handles WITHOUT waiting. `serve` then closes
    // the queue and drains: every admitted request must still resolve
    // (computed or shed), i.e. zero admitted-but-unanswered on shutdown.
    let backend = RefBackend::with_config(1, true).unwrap();
    let engine = ServingEngine::new(&backend, engine_cfg(2, 4), demo_tt(5), None).unwrap();
    let seq = engine.seq_len();
    let n = 12usize;
    let handles = engine
        .serve(|eng| {
            (0..n)
                .map(|i| {
                    let deadline =
                        if i % 3 == 0 { Some(Duration::ZERO) } else { None };
                    eng.submit_with(i % TASKS, vec![1 + i as i32; seq], deadline, 0)
                        .unwrap()
                })
                .collect::<Vec<_>>()
        })
        .unwrap();
    assert_eq!(handles.len(), n);
    let (mut ok, mut expired) = (0usize, 0usize);
    for (i, h) in handles.into_iter().enumerate() {
        let resp = h.wait().unwrap_or_else(|e| {
            panic!("request {i} was admitted but never answered: {e}")
        });
        match resp.status {
            ResponseStatus::Ok => {
                assert_eq!(resp.logits.len(), 2);
                ok += 1;
            }
            ResponseStatus::Expired => expired += 1,
            ResponseStatus::Error => panic!("request {i} quarantined with no faults armed"),
        }
        // A deadline-free request can never be shed.
        if i % 3 != 0 {
            assert_eq!(resp.status, ResponseStatus::Ok, "request {i} had no deadline");
        }
    }
    assert_eq!(ok + expired, n, "every admitted request is answered exactly once");
    let stats = engine.stats();
    assert_eq!(stats.requests + stats.shed, n as u64);
}

#[test]
fn queue_delay_telemetry_sees_waiting_requests() {
    // One worker, batch cap 1: a burst of requests serializes, so later
    // requests measurably wait between admission and drain. Pins that
    // `Pending.enqueued` feeds EngineStats queue-delay counters.
    let backend = RefBackend::with_config(1, true).unwrap();
    let engine = ServingEngine::new(&backend, engine_cfg(1, 1), demo_tt(5), None).unwrap();
    let seq = engine.seq_len();
    engine
        .serve(|eng| {
            let handles: Vec<_> =
                (0..8).map(|i| eng.submit(i % TASKS, vec![2; seq]).unwrap()).collect();
            for h in handles {
                h.wait().unwrap();
            }
        })
        .unwrap();
    let stats = engine.stats();
    assert_eq!(stats.requests, 8);
    assert!(
        stats.queue_us_sum > 0,
        "8 serialized requests must accumulate queue wait"
    );
    assert!(stats.queue_us_max > 0, "the last request waited for 7 ticks");
    assert!(stats.queue_us_max as f64 * 1e-6 >= stats.queue_wait_mean_s());
    assert!(stats.queue_wait_mean_s() > 0.0);
}

#[test]
fn stats_delta_isolates_a_measured_window() {
    // delta_since is what keeps warmup traffic out of reported batch
    // statistics: counters snapshotted mid-run subtract cleanly.
    let backend = RefBackend::with_config(1, true).unwrap();
    let engine = ServingEngine::new(&backend, engine_cfg(1, 4), demo_tt(5), None).unwrap();
    let seq = engine.seq_len();
    let (base, window) = engine
        .serve(|eng| {
            for _ in 0..3 {
                eng.submit(0, vec![1; seq]).unwrap().wait().unwrap();
            }
            let base = eng.stats();
            for _ in 0..2 {
                eng.submit(1, vec![2; seq]).unwrap().wait().unwrap();
            }
            (base, eng.stats())
        })
        .unwrap();
    assert_eq!(base.requests, 3);
    let delta = window.delta_since(&base);
    assert_eq!(delta.requests, 2, "the window must exclude earlier traffic");
    assert_eq!(delta.shed, 0);
    assert_eq!(delta.rejected, 0);
    let hist_total: u64 = delta.batch_hist.iter().sum();
    assert_eq!(hist_total, delta.batches, "windowed histogram matches windowed batches");
    assert!(window.requests > base.requests);
}

#[test]
fn full_queue_rejects_open_loop_admission_and_counts_it() {
    // No worker pool is running (serve() not called), so the queue cannot
    // drain: capacity 1 makes the second non-blocking admission a
    // deterministic rejection, counted in EngineStats::rejected.
    let backend = RefBackend::with_config(1, true).unwrap();
    let cfg = EngineConfig { queue_capacity: 1, ..engine_cfg(1, 4) };
    let engine = ServingEngine::new(&backend, cfg, demo_tt(5), None).unwrap();
    let seq = engine.seq_len();
    let first = engine.try_submit_with(0, vec![1; seq], None, 0).unwrap();
    assert!(first.is_some(), "an empty queue admits");
    let second = engine.try_submit_with(0, vec![1; seq], None, 0).unwrap();
    assert!(second.is_none(), "a full queue rejects without blocking");
    let stats = engine.stats();
    assert_eq!(stats.rejected, 1);
    assert_eq!(stats.requests, 0);
}

#[test]
fn quantized_serving_tracks_f32_for_every_family_and_task() {
    // Quantized binds store the packed frozen panels AND the folded
    // adapter factors at reduced precision, so this is a tolerance
    // comparison against the f32 engine (which itself is pinned
    // bit-identical to the dense oracle above). Both engines replay the
    // same deterministic stream, which covers every task index.
    let backend = RefBackend::with_config(1, true).unwrap();
    let stream = demo_stream(24);
    for task in 0..TASKS {
        assert!(
            stream.iter().any(|(t, _)| *t == task),
            "seeded stream must exercise task {task}"
        );
    }
    for kind in [MetaTtKind::FourD, MetaTtKind::FourPlusOneD, MetaTtKind::FiveD] {
        let tt = tt_for(kind, 11);
        let baseline =
            serve_stream(&backend, cfg_for(kind, DtypeKind::F32), tt.clone(), &stream);
        for (dtype, tol) in [(DtypeKind::Bf16, 5e-2f32), (DtypeKind::I8, 2.5e-1f32)] {
            let got = serve_stream(&backend, cfg_for(kind, dtype), tt.clone(), &stream);
            assert_eq!(got.len(), baseline.len());
            for (q, f) in got.iter().zip(&baseline) {
                assert_eq!(q.task, f.task);
                assert_eq!(q.logits.len(), f.logits.len());
                for (c, (&a, &b)) in q.logits.iter().zip(&f.logits).enumerate() {
                    let scale = b.abs().max(1.0);
                    assert!(
                        ((a - b) / scale).abs() < tol,
                        "{} task {} class {c}: {} logit {a} vs f32 {b}",
                        kind.name(),
                        q.task,
                        dtype.name()
                    );
                }
            }
        }
    }
}

#[test]
fn f32_serving_is_unchanged_by_the_dtype_seam() {
    // The engine's f32 path routes through the same packers and kernels
    // as before the dtype refactor; a quantized engine must answer with
    // DIFFERENT bits (otherwise the dtype plumbing is a no-op).
    let backend = RefBackend::with_config(1, true).unwrap();
    let tt = demo_tt(5);
    let stream = demo_stream(8);
    let f32_resp =
        serve_stream(&backend, cfg_for(MetaTtKind::FourPlusOneD, DtypeKind::F32), tt.clone(), &stream);
    for (resp, (task, tokens)) in f32_resp.iter().zip(&stream) {
        let want = single_request_logits(&backend, &tt, *task, tokens);
        for (g, w) in resp.logits.iter().zip(&want) {
            assert_eq!(g.to_bits(), w.to_bits(), "f32 packed path drifted from dense oracle");
        }
    }
    let bf16_resp =
        serve_stream(&backend, cfg_for(MetaTtKind::FourPlusOneD, DtypeKind::Bf16), tt, &stream);
    let any_bit_diff = bf16_resp
        .iter()
        .zip(&f32_resp)
        .any(|(a, b)| a.logits.iter().zip(&b.logits).any(|(x, y)| x.to_bits() != y.to_bits()));
    assert!(any_bit_diff, "bf16 serving must actually round the weights");
}

#[test]
fn cache_counters_reflect_per_task_folding() {
    let backend = RefBackend::with_config(1, true).unwrap();
    let engine = ServingEngine::new(&backend, engine_cfg(1, 2), demo_tt(5), None).unwrap();
    let seq = engine.seq_len();
    engine
        .serve(|eng| {
            for task in [0usize, 1, 0, 2, 1, 0] {
                eng.submit(task, vec![2; seq]).unwrap().wait().unwrap();
            }
        })
        .unwrap();
    let cache = engine.cache_stats();
    assert_eq!(cache.folds, TASKS as u64, "one fold per distinct task");
    assert!(cache.hits >= 1, "repeat tasks must hit the cache");
    assert_eq!(cache.evictions, 0, "capacity covers all tasks here");
    let stats = engine.stats();
    assert_eq!(stats.requests, 6);
    assert!(stats.batches >= 3, "distinct tasks cannot share a batch");
    let histogram_total: u64 = stats.batch_hist.iter().sum();
    assert_eq!(histogram_total, stats.batches);
}
