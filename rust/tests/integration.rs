//! Integration tests across runtime + coordinator on the **PJRT backend**:
//! these execute real AOT artifacts, so they compile only with
//! `--features pjrt` and need `make artifacts` to have run. Every test is
//! skipped (with a loud message) when artifacts are absent so `cargo test`
//! stays green on a fresh checkout. The hermetic equivalents of the
//! coordinator tests live in `tests/ref_backend.rs` and run everywhere.
#![cfg(feature = "pjrt")]

use metatt::adapters::{AdapterKind, AdapterSpec};
use metatt::config::{ModelPreset, TrainConfig};
use metatt::coordinator::{run_dmrg, run_mtl, run_single_task, DmrgConfig, MtlConfig};
use metatt::data::{Batcher, TaskId};
use metatt::runtime::{assemble_frozen, ArtifactSpec, Runtime, Step, StepKind, StepRunner};
use metatt::tensor::{rel_err, Tensor};
use metatt::tt::{InitStrategy, MetaTtKind, RankSchedule};
use metatt::util::rng::Pcg64;
use std::path::Path;
use std::sync::OnceLock;

fn runtime() -> Option<&'static Runtime> {
    static RT: OnceLock<Option<Runtime>> = OnceLock::new();
    RT.get_or_init(|| {
        if !Path::new("artifacts/manifest.json").exists() {
            eprintln!("SKIP: artifacts/manifest.json missing — run `make artifacts`");
            return None;
        }
        Some(Runtime::new(Path::new("artifacts")).expect("runtime"))
    })
    .as_ref()
}

fn tiny_train_spec(adapter: &str, rank: usize, classes: usize, tasks: usize) -> ArtifactSpec {
    ArtifactSpec {
        step: StepKind::Train,
        model: "tiny".into(),
        adapter: adapter.into(),
        rank,
        classes,
        tasks,
        batch: 16,
        seq: 32,
    }
}

#[test]
fn manifest_covers_all_experiment_specs() {
    let Some(rt) = runtime() else { return };
    // Table 1 adapters.
    for adapter in ["metatt4d", "metatt5d", "lora", "vera", "lotr"] {
        let rank = match adapter {
            "vera" => 64,
            _ => 8,
        };
        for classes in [1, 2, 3] {
            let spec = tiny_train_spec(adapter, rank, classes, 1);
            assert!(rt.manifest.get(&spec).is_some(), "{}", spec.stem());
        }
    }
    // DMRG ladder 4..10 for metatt5d.
    for r in 4..=10 {
        assert!(rt.manifest.get(&tiny_train_spec("metatt5d", r, 2, 1)).is_some());
    }
    // MTL artifacts.
    for tasks in [3, 4] {
        for adapter in ["metatt4p1d", "metatt4d", "lora"] {
            assert!(rt.manifest.get(&tiny_train_spec(adapter, 8, 2, tasks)).is_some());
        }
    }
}

#[test]
fn train_step_executes_and_grads_respect_zero_init_structure() {
    let Some(rt) = runtime() else { return };
    let model = ModelPreset::Tiny;
    let dims = model.dims(1);
    let spec = AdapterSpec::new(AdapterKind::MetaTt(MetaTtKind::FourD), 8, 4.0, dims);
    let aspec = tiny_train_spec("metatt4d", 8, 2, 1);
    let entry = rt.manifest.require(&aspec).unwrap();
    let frozen = assemble_frozen(entry, None, model).unwrap();
    let runner = StepRunner::bind(rt, &aspec, &frozen).unwrap();
    let mut rng = Pcg64::new(1);
    let params = spec.init_params(&mut rng); // g1 = 0, rest identity
    let ds = TaskId::MrpcSyn.generate_at(16, 0, 3, 32, 512);
    let batches = Batcher::new(16).epoch(&ds, &mut rng);
    let batch = &batches[0];
    let (loss, grads) = runner.run_train(&params, batch, 0, 4.0).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert_eq!(grads.len(), 4);
    // With g1 == 0: grad_g1 nonzero, grads of g2/g3 exactly zero (their
    // derivative paths all contain g1), grad_g4 zero too (left factor 0).
    assert!(grads[0].max_abs() > 0.0, "grad_g1 must flow");
    assert_eq!(grads[1].max_abs(), 0.0, "grad_g2 should be zero at ze-init");
    assert_eq!(grads[2].max_abs(), 0.0, "grad_g3 should be zero at ze-init");
    for (g, p) in grads.iter().zip(&params) {
        assert_eq!(g.shape(), p.shape());
        assert!(g.all_finite());
    }
}

#[test]
fn eval_step_matches_zero_adapter_between_methods() {
    // Two different adapters, both zero maps at init, over the same frozen
    // backbone must produce identical logits — cross-artifact consistency.
    let Some(rt) = runtime() else { return };
    let model = ModelPreset::Tiny;
    let dims = model.dims(1);
    let mut rng = Pcg64::new(2);
    let ds = TaskId::Sst2Syn.generate_at(16, 16, 5, 32, 512);
    let batches = Batcher::new(16).eval(&ds);
    let batch = &batches[0];
    let mut logits: Vec<Tensor> = Vec::new();
    for adapter in [
        AdapterKind::MetaTt(MetaTtKind::FourD),
        AdapterKind::LoRa,
        AdapterKind::LoTr,
    ] {
        let rank = 8;
        let spec = AdapterSpec::new(adapter, rank, 4.0, dims);
        let mut aspec = tiny_train_spec(&spec.kind.name(), rank, 2, 1);
        aspec.step = StepKind::Eval;
        let entry = rt.manifest.require(&aspec).unwrap();
        let frozen = assemble_frozen(entry, None, model).unwrap();
        let runner = StepRunner::bind(rt, &aspec, &frozen).unwrap();
        let params = spec.init_params(&mut rng);
        logits.push(runner.run_eval(&params, batch, 0, 4.0).unwrap());
    }
    for other in &logits[1..] {
        assert!(
            rel_err(other, &logits[0]) < 1e-4,
            "zero-init adapters disagree: {}",
            rel_err(other, &logits[0])
        );
    }
}

#[test]
fn hlo_apply_artifact_matches_rust_tt_oracle() {
    // The Pallas apply artifact (L1) against the rust-side TT algebra (L3):
    // independent implementations of paper Eq. 5 must agree.
    let Some(rt) = runtime() else { return };
    let spec = rt
        .manifest
        .specs()
        .find(|s| s.step == StepKind::Apply && s.adapter == "metatt4d")
        .cloned()
        .expect("apply artifact");
    let entry = rt.manifest.require(&spec).unwrap().clone();
    let runner = StepRunner::bind(rt, &spec, &Default::default()).unwrap();
    let mut rng = Pcg64::new(3);
    let n = entry.inputs[0].shape[0];
    let d = entry.inputs[0].shape[1];
    let r = entry.inputs[1].shape[1];
    let x = Tensor::randn(&[n, d], 0.5, &mut rng);
    let g1 = Tensor::randn(&[d, r], 0.5, &mut rng);
    let mid = Tensor::randn(&[r, r], 0.5, &mut rng);
    let g4 = Tensor::randn(&[r, d], 0.5, &mut rng);
    let got = runner
        .run_raw(&[x.clone(), g1.clone(), mid.clone(), g4.clone()])
        .unwrap()
        .remove(0);
    let want = x.matmul(&g1).matmul(&mid).matmul(&g4); // alpha = 1 baked
    assert!(rel_err(&got, &want) < 1e-4, "kernel vs oracle: {}", rel_err(&got, &want));
}

#[test]
fn short_training_run_learns_above_chance() {
    let Some(rt) = runtime() else { return };
    let model = ModelPreset::Tiny;
    let dims = model.dims(1);
    let spec = AdapterSpec::new(AdapterKind::MetaTt(MetaTtKind::FourD), 8, 4.0, dims);
    let train = TrainConfig {
        epochs: 4,
        train_cap: 320,
        eval_cap: 200,
        ..Default::default()
    };
    // sst2_syn is the easiest task (polarity counting) — must beat chance
    // quickly even on an unpretrained backbone.
    let res = run_single_task(
        rt, model, &spec, TaskId::Sst2Syn, &train, 4.0, None, None,
    )
    .unwrap();
    assert!(
        res.best_metric > 0.60,
        "sst2_syn accuracy {:.3} did not beat chance",
        res.best_metric
    );
    // Loss decreased over training.
    let first = res.epochs.first().unwrap().train_loss;
    let last = res.epochs.last().unwrap().train_loss;
    assert!(last < first, "loss did not decrease: {first} -> {last}");
}

#[test]
fn mtl_run_produces_per_task_metrics_and_grad_probes() {
    let Some(rt) = runtime() else { return };
    let model = ModelPreset::Tiny;
    let tasks = [TaskId::ColaSyn, TaskId::MrpcSyn, TaskId::RteSyn];
    let dims = model.dims(tasks.len());
    let spec = AdapterSpec::new(AdapterKind::MetaTt(MetaTtKind::FourPlusOneD), 8, 2.0, dims);
    let mut cfg = MtlConfig::default();
    cfg.train.epochs = 2;
    cfg.per_task_cap = 160;
    cfg.eval_cap = 100;
    let res = run_mtl(rt, model, &spec, &tasks, &cfg, None).unwrap();
    assert_eq!(res.epochs.len(), 2);
    assert_eq!(res.best_per_task.len(), 3);
    assert_eq!(res.param_names.len(), 5); // g1..g5
    // Task core must receive gradient signal once g1 has moved.
    let g3 = res.param_names.iter().position(|n| n == "g3").unwrap();
    let late = res.epochs.last().unwrap();
    assert!(late.grad_norms[g3].is_finite());
    assert!(late.grad_norms.iter().all(|g| g.is_finite()));
}

#[test]
fn dmrg_run_hot_swaps_executables_and_keeps_training() {
    let Some(rt) = runtime() else { return };
    let model = ModelPreset::Tiny;
    let mut cfg = DmrgConfig::default();
    cfg.train.epochs = 4;
    cfg.train.train_cap = 160;
    cfg.train.eval_cap = 100;
    cfg.start_rank = 8;
    cfg.schedule = RankSchedule::parse("0:6,2:4").unwrap();
    let res = run_dmrg(
        rt,
        model,
        AdapterKind::MetaTt(MetaTtKind::FiveD),
        TaskId::MrpcSyn,
        &cfg,
        None,
    )
    .unwrap();
    assert_eq!(res.epochs.len(), 4);
    assert_eq!(res.epochs[0].rank, 6, "first sweep after epoch 0");
    assert_eq!(res.epochs[2].rank, 4, "second sweep after epoch 2");
    assert!(res.epochs[0].swept && res.epochs[2].swept);
    assert!(!res.epochs[1].swept && !res.epochs[3].swept);
    assert!(res.executables_compiled >= 4, "train+eval per rank");
    assert!(res.epochs.iter().all(|e| e.metric.is_finite()));
}

#[test]
fn regression_task_roundtrip_spearman() {
    let Some(rt) = runtime() else { return };
    let model = ModelPreset::Tiny;
    let dims = model.dims(1);
    let spec = AdapterSpec::new(AdapterKind::MetaTt(MetaTtKind::FourD), 8, 4.0, dims);
    let train = TrainConfig {
        epochs: 3,
        train_cap: 320,
        eval_cap: 200,
        ..Default::default()
    };
    // Use the pretrained backbone when present (regression needs a usable
    // CLS representation; 3 epochs on a random backbone can land slightly
    // negative).
    let ckpt = metatt::runtime::checkpoint_path(model);
    let ckpt = ckpt.exists().then_some(ckpt);
    let res = run_single_task(
        rt, model, &spec, TaskId::StsbSyn, &train, 4.0, ckpt.as_deref(), None,
    )
    .unwrap();
    // Spearman in [-1, 1]; training on band similarity should correlate.
    for e in &res.epochs {
        assert!((-1.0..=1.0).contains(&e.metric));
    }
    let floor = if ckpt.is_some() { 0.05 } else { -0.2 };
    assert!(res.best_metric > floor, "spearman {:.3}", res.best_metric);
}

#[test]
fn init_strategy_flows_through_training_stack() {
    let Some(rt) = runtime() else { return };
    let model = ModelPreset::Tiny;
    let dims = model.dims(1);
    let spec = AdapterSpec::new(AdapterKind::MetaTt(MetaTtKind::FourD), 8, 4.0, dims);
    let train = TrainConfig { epochs: 1, train_cap: 64, eval_cap: 64, ..Default::default() };
    let strat = InitStrategy::from_code("id-ze-id-id").unwrap();
    let res = run_single_task(
        rt, model, &spec, TaskId::MrpcSyn, &train, 4.0, None, Some(&strat),
    )
    .unwrap();
    assert!(res.epochs[0].metric.is_finite());
}
