//! End-to-end driver (EXPERIMENTS.md §E2E): exercises every layer of the
//! stack on a real (small) workload, proving they compose:
//!
//!   1. **Pretrain** the base_sim encoder (12 layers, d=256, ~10 M params —
//!      the CPU-feasible RoBERTa stand-in, DESIGN.md §3) with the MLM
//!      artifact for a few hundred steps, logging the loss curve.
//!   2. **Freeze** it and fine-tune a single global MetaTT-4D adapter on a
//!      synthetic GLUE task through the backend's train step.
//!   3. **Serve**: fold the trained TT into per-(l,m) factors (paper §2.4)
//!      and run the fused apply step on the folded factors.
//!
//! Hermetic by default (pure-rust reference backend); set
//! METATT_BACKEND=pjrt after `make artifacts --with-base` for the AOT path:
//!
//!     cargo run --release --example e2e_pretrain_finetune
//!
//! Pass `--model small` via env E2E_MODEL=small for a faster run.

use metatt::adapters::{AdapterKind, AdapterSpec};
use metatt::config::{ModelPreset, TrainConfig};
use metatt::coordinator::{pretrain, run_single_task, PretrainConfig};
use metatt::data::TaskId;
use metatt::runtime::{backend_from_env, checkpoint_path, Backend, Step};
use metatt::tensor::Tensor;
use metatt::tt::MetaTtKind;
use metatt::util::rng::Pcg64;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let model = match std::env::var("E2E_MODEL").as_deref() {
        Ok("small") => ModelPreset::Small,
        Ok("tiny") => ModelPreset::Tiny,
        _ => ModelPreset::BaseSim,
    };
    let steps: usize = std::env::var("E2E_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let backend = backend_from_env()?;
    let dims = model.dims(1);
    let total_params = dims.encoder_param_count();
    println!(
        "=== E2E on {} ({} layers, d={}, ~{:.1}M params) ===",
        model.name(),
        dims.layers,
        dims.hidden,
        total_params as f64 / 1e6
    );

    // ---- Stage 1: MLM pretraining (full-weight fwd+bwd through XLA). ----
    let ckpt = checkpoint_path(model);
    if ckpt.exists() {
        println!("[1/3] reusing checkpoint {}", ckpt.display());
    } else {
        println!("[1/3] MLM pretraining for {steps} steps…");
        let t0 = Instant::now();
        let res = pretrain(
            backend.as_ref(),
            model,
            &PretrainConfig { steps, ..Default::default() },
        )?;
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "      loss {:.3} -> {:.3} in {:.1}s ({:.2} s/step)",
            res.losses.first().map(|l| l.1).unwrap_or(f64::NAN),
            res.final_loss,
            dt,
            dt / steps as f64
        );
    }

    // ---- Stage 2: global-TT fine-tuning through the train artifact. ----
    println!("[2/3] fine-tuning MetaTT-4D (rank 8) on mrpc_syn…");
    let spec = AdapterSpec::new(AdapterKind::MetaTt(MetaTtKind::FourD), 8, 4.0, dims);
    let batch = if model == ModelPreset::BaseSim { 8 } else { 16 };
    let train = TrainConfig {
        epochs: 4,
        batch_size: batch,
        train_cap: 512,
        eval_cap: 256,
        ..Default::default()
    };
    let t0 = Instant::now();
    let res = run_single_task(
        backend.as_ref(),
        model,
        &spec,
        TaskId::MrpcSyn,
        &train,
        4.0,
        Some(&ckpt),
        None,
    )?;
    for e in &res.epochs {
        println!(
            "      epoch {:>2}  loss {:.4}  acc {:.3}",
            e.epoch, e.train_loss, e.metric
        );
    }
    println!(
        "      best acc {:.3} with {} trainable params ({:.1}s total, {:.0}x fewer than LoRA r=8)",
        res.best_metric,
        res.param_count,
        t0.elapsed().as_secs_f64(),
        AdapterSpec::new(AdapterKind::LoRa, 8, 4.0, dims).param_count() as f64
            / res.param_count as f64
    );

    // ---- Stage 3: serve via the folded Pallas apply artifact. ----
    println!("[3/3] folding the trained TT for serving (paper §2.4)…");
    let mut tt = spec.build_metatt(&mut Pcg64::new(0));
    tt.import_cores(&res.params);
    let folded = tt.fold_for_serving(0);
    let apply_spec = backend.apply_spec("metatt4d", 8).ok();
    match apply_spec {
        Some(aspec) if dims.hidden == 256 => {
            let entry = backend.entry(&aspec)?;
            let runner = backend.bind(&aspec, &Default::default())?;
            let n = entry.inputs[0].shape[0];
            let mut rng = Pcg64::new(7);
            let x = Tensor::randn(&[n, dims.hidden], 1.0, &mut rng);
            // apply step signature: (x, g1, mid, g4); alpha baked = 1.
            let (a, b) = &folded[0][0];
            let g1 = a.clone(); // alpha already folded into a
            let mid = Tensor::eye(a.cols());
            let t0 = Instant::now();
            let reps = 50;
            for _ in 0..reps {
                let out = runner.run_raw(&[x.clone(), g1.clone(), mid.clone(), b.clone()])?;
                std::hint::black_box(out);
            }
            let dt = t0.elapsed().as_secs_f64();
            println!(
                "      fused apply: {:.2} ms / call ({} tokens, {:.1}k tok/s) — \
                 two GEMMs per layer at serve time, same as LoRA",
                dt / reps as f64 * 1e3,
                n,
                reps as f64 * n as f64 / dt / 1e3
            );
        }
        _ => {
            // Folded serving demo on host (apply artifact is base_sim-only).
            let x = Tensor::randn(&[64, dims.hidden], 1.0, &mut Pcg64::new(7));
            let (a, b) = &folded[1][0];
            let y = x.matmul(a).matmul(b);
            println!(
                "      host folded apply: |y|_F = {:.4} ({} x {} · {} x {})",
                y.fro_norm(),
                x.rows(),
                a.shape()[0],
                b.shape()[0],
                b.shape()[1]
            );
        }
    }
    println!("=== E2E complete: pretrain → adapter fine-tune → folded serve ===");
    Ok(())
}
