//! Quickstart: fine-tune one global MetaTT-4D adapter on a synthetic GLUE
//! task and compare its parameter count against LoRA at the same rank.
//!
//!     cargo run --release --example quickstart
//!
//! Hermetic by default: runs on the pure-rust reference backend (set
//! METATT_BACKEND=pjrt after `make artifacts` for the PJRT path). Uses the
//! tiny preset so it finishes in under a minute on CPU. If a pretrained
//! checkpoint exists (`metatt pretrain --model tiny`) it is used
//! automatically; otherwise the frozen backbone is a fresh random encoder.

use metatt::adapters::{AdapterKind, AdapterSpec};
use metatt::config::{ModelPreset, TrainConfig};
use metatt::coordinator::run_single_task;
use metatt::data::TaskId;
use metatt::runtime::{backend_from_env, checkpoint_path, Backend};
use metatt::tt::MetaTtKind;

fn main() -> anyhow::Result<()> {
    let model = ModelPreset::Tiny;
    let task = TaskId::MrpcSyn;
    let backend = backend_from_env()?;
    println!("backend: {}", backend.platform());

    let dims = model.dims(1);
    let metatt = AdapterSpec::new(AdapterKind::MetaTt(MetaTtKind::FourD), 8, 4.0, dims);
    let lora = AdapterSpec::new(AdapterKind::LoRa, 8, 4.0, dims);
    println!(
        "MetaTT-4D r=8: {} trainable params  |  LoRA r=8: {} ({}x compression)",
        metatt.param_count(),
        lora.param_count(),
        (lora.param_count() as f64 / metatt.param_count() as f64).round()
    );

    let train = TrainConfig {
        epochs: 5,
        train_cap: 512,
        eval_cap: 300,
        ..Default::default()
    };
    let ckpt = checkpoint_path(model);
    let ckpt = ckpt.exists().then_some(ckpt);
    if ckpt.is_none() {
        println!("(no pretrained checkpoint — using a random frozen backbone)");
    }
    let res = run_single_task(
        backend.as_ref(),
        model,
        &metatt,
        task,
        &train,
        4.0,
        ckpt.as_deref(),
        None,
    )?;
    for e in &res.epochs {
        println!(
            "epoch {:>2}  train-loss {:.4}  accuracy {:.3}",
            e.epoch, e.train_loss, e.metric
        );
    }
    println!(
        "\nbest accuracy {:.3} with {} trainable parameters — one shared TT \
         steering all {} x {} attention projections.",
        res.best_metric,
        res.param_count,
        dims.layers,
        dims.matrices
    );
    Ok(())
}
