//! Multi-task learning with a task core (paper §3.2): joint-train one
//! adapter over three binary tasks and compare
//!
//!   * MetaTT-4D      — one shared TT, no task structure
//!   * MetaTT-(4+1)D  — same TT plus an r×r task core G3[t] in the middle
//!   * LoRA           — a single per-matrix adapter shared across tasks
//!
//! reproducing the qualitative Table-2 finding: the task core buys back
//! most of the task interference for ~(T·r²) extra parameters.
//!
//!     cargo run --release --example multitask_adapter

use metatt::adapters::{AdapterKind, AdapterSpec};
use metatt::config::ModelPreset;
use metatt::coordinator::{run_mtl, MtlConfig};
use metatt::data::TaskId;
use metatt::runtime::{backend_from_env, checkpoint_path};
use metatt::tt::MetaTtKind;

fn main() -> anyhow::Result<()> {
    let model = ModelPreset::Tiny;
    let tasks = [TaskId::ColaSyn, TaskId::MrpcSyn, TaskId::RteSyn];
    let backend = backend_from_env()?;
    let ckpt = checkpoint_path(model);
    let ckpt = ckpt.exists().then_some(ckpt);
    let mut cfg = MtlConfig::default();
    cfg.train.epochs = 5;
    cfg.per_task_cap = 600;
    cfg.eval_cap = 300;

    let dims = model.dims(tasks.len());
    println!(
        "joint training over {:?}\n{:<14} {:>8} {:>10} {:>24}",
        tasks.iter().map(|t| t.name()).collect::<Vec<_>>(),
        "adapter",
        "params",
        "best-mean",
        "per-task"
    );
    for kind in [
        AdapterKind::MetaTt(MetaTtKind::FourD),
        AdapterKind::MetaTt(MetaTtKind::FourPlusOneD),
        AdapterKind::LoRa,
    ] {
        let spec = AdapterSpec::new(kind, 8, cfg.alpha, dims);
        let res = run_mtl(backend.as_ref(), model, &spec, &tasks, &cfg, ckpt.as_deref())?;
        println!(
            "{:<14} {:>8} {:>10.3} {:>24}",
            spec.kind.name(),
            spec.param_count(),
            res.best_mean,
            format!(
                "{:?}",
                res.best_per_task
                    .iter()
                    .map(|m| (m * 100.0).round() / 100.0)
                    .collect::<Vec<_>>()
            )
        );
    }
    println!(
        "\nThe (4+1)D task core adds only {} params over 4D yet recovers \
         per-task specialization (paper Table 2).",
        AdapterSpec::new(AdapterKind::MetaTt(MetaTtKind::FourPlusOneD), 8, 2.0, dims)
            .param_count()
            - AdapterSpec::new(AdapterKind::MetaTt(MetaTtKind::FourD), 8, 2.0, dims)
                .param_count()
    );
    Ok(())
}
