//! Rank-adaptive training via DMRG-inspired sweeps (paper §3.3, Alg. 1).
//!
//! Starts a MetaTT-5D at rank 10 and anneals to rank 4 while training,
//! comparing against fixed-rank-4 AdamW. Shows the paper's signature
//! pattern: an accuracy dip right after each truncation, rapid recovery,
//! and a better final-rank model than training at rank 4 from scratch.
//! Also demonstrates the coordinator's step hot-swap: each rank on the
//! ladder is a different spec, bound once and cached (a compiled HLO
//! executable on the pjrt backend; a synthesized layout on the default
//! pure-rust reference backend).
//!
//!     cargo run --release --example dmrg_rank_adaptive

use metatt::adapters::AdapterKind;
use metatt::config::ModelPreset;
use metatt::coordinator::{run_dmrg, run_fixed_rank_baseline, DmrgConfig};
use metatt::data::TaskId;
use metatt::runtime::{backend_from_env, checkpoint_path};
use metatt::tt::{MetaTtKind, RankSchedule};

fn main() -> anyhow::Result<()> {
    let model = ModelPreset::Tiny;
    let task = TaskId::MrpcSyn;
    let kind = AdapterKind::MetaTt(MetaTtKind::FiveD);
    let backend = backend_from_env()?;
    let ckpt = checkpoint_path(model);
    let ckpt = ckpt.exists().then_some(ckpt);

    let mut cfg = DmrgConfig::default();
    cfg.train.epochs = 12;
    cfg.train.train_cap = 640;
    cfg.train.eval_cap = 300;
    cfg.start_rank = 10;
    cfg.schedule = RankSchedule::parse("1:9,3:8,5:7,6:6,7:5,8:4").map_err(anyhow::Error::msg)?;

    println!("AdamW + DMRG sweeps (start rank 10 → 4):");
    let res = run_dmrg(backend.as_ref(), model, kind, task, &cfg, ckpt.as_deref())?;
    for e in &res.epochs {
        let marker = if e.swept { " ← sweep" } else { "" };
        println!(
            "  epoch {:>2}  acc {:.3}  rank {:>2}{}",
            e.epoch, e.metric, e.rank, marker
        );
    }
    println!(
        "  {} rank-specific steps bound and hot-swapped\n",
        res.executables_compiled
    );

    println!("fixed-rank-4 AdamW baseline:");
    let base =
        run_fixed_rank_baseline(backend.as_ref(), model, kind, task, 4, &cfg, ckpt.as_deref())?;
    let best_base = base.iter().map(|e| e.metric).fold(f64::NEG_INFINITY, f64::max);
    for e in base.iter().step_by(3) {
        println!("  epoch {:>2}  acc {:.3}", e.epoch, e.metric);
    }
    println!(
        "\nbest at rank 4 — annealed: {:.3}  vs fixed-rank: {:.3} (paper Figs 2/6 shape)",
        res.best_at_final_rank, best_base
    );
    Ok(())
}
