//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The offline environment ships no crates registry, so this vendored crate
//! provides the (small) subset of the `anyhow` API the repo uses: the
//! [`Error`] type with a context chain, the [`Result`] alias, the
//! [`Context`] extension trait for `Result`/`Option`, and the `anyhow!` /
//! `bail!` / `ensure!` macros. Errors are stored as a chain of messages
//! (outermost context first); `{}` shows the outermost message, `{:#}` and
//! `{:?}` show the full `context: ...: root cause` chain, mirroring real
//! anyhow's formatting closely enough for log output.

use std::fmt;

/// A chain of error messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend a context message (becomes the new outermost message).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The root-cause (innermost) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }

    /// Iterate the message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for msg in &self.chain[1..] {
                write!(f, "\n    {msg}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that is
// what keeps this blanket conversion coherent (same trick as real anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result` defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path")?;
        Ok(())
    }

    #[test]
    fn context_chain_formats() {
        let e = io_fail().context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        let full = format!("{e:#}");
        assert!(full.starts_with("reading config: "), "{full}");
    }

    #[test]
    fn macros_and_option_context() {
        let e: Error = anyhow!("rank {} too large", 99);
        assert_eq!(e.root_cause(), "rank 99 too large");
        let none: Option<u32> = None;
        assert!(none.context("missing").is_err());
        fn guarded(x: u32) -> Result<u32> {
            ensure!(x < 10, "x was {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(guarded(3).unwrap(), 3);
        assert!(guarded(12).is_err());
        assert_eq!(guarded(5).unwrap_err().root_cause(), "five is right out");
    }

    #[test]
    fn error_msg_accepts_strings() {
        let s: Result<(), String> = Err("boom".to_string());
        let e = s.map_err(Error::msg).unwrap_err();
        assert_eq!(format!("{e}"), "boom");
    }
}
