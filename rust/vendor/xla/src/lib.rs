//! API-compatible stub for the `xla` PJRT bindings.
//!
//! The real crate (PJRT C-API wrapper + XLA compiler) is unavailable in the
//! offline build environment. This stub mirrors the type and method surface
//! `metatt`'s PJRT backend compiles against, so `cargo build --features
//! pjrt` succeeds everywhere; every runtime entry point returns
//! [`Error::Unavailable`]. Deployments with real PJRT replace this path
//! dependency with the actual bindings — no source change needed in
//! `metatt` itself.

use std::fmt;

/// Stub error: always "PJRT unavailable".
#[derive(Debug, Clone)]
pub enum Error {
    /// The stub cannot execute anything.
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: the vendored `xla` crate is a compile-only stub \
                 (link real PJRT bindings at rust/vendor/xla, or use `--backend ref`)"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// PJRT client handle (stub).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// Host literal (stub).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        unavailable("Literal::decompose_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Parsed HLO module proto (stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_errors_are_loud() {
        let err = PjRtClient::cpu().err().expect("stub must not pretend to work");
        let msg = err.to_string();
        assert!(msg.contains("stub") && msg.contains("--backend ref"), "{msg}");
    }
}
