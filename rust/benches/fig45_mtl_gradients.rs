//! Figures 4 & 5 — per-core normalized gradients in MTL (Appendix B).
//!
//! Joint-trains MetaTT-(4+1)D and records, per epoch and per TT core, the
//! paper's probe `‖∇G‖_F / √|G|` (Frobenius norm over root non-zeros),
//! alongside each task's metric — the raw data behind the paper's heatmaps.
//! Fig 4 uses tasks {MRPC, RTE, CoLA}; Fig 5 adds QNLI ({MRPC, QNLI, RTE,
//! CoLA}); both at rank 8, alpha 2, lr 5e-4, grad-clip 3 (paper settings).
//!
//! Claims under test: the task core G3 acquires significant gradient (at
//! times the largest across cores), and the CoLA slice dominates within it
//! (hardest task).

use metatt::adapters::{AdapterKind, AdapterSpec};
use metatt::bench::Table;
use metatt::config::ModelPreset;
use metatt::coordinator::{run_mtl, MtlConfig};
use metatt::data::TaskId;
use metatt::runtime::{backend_from_env, checkpoint_path};
use metatt::tt::MetaTtKind;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn run_figure(tasks: &[TaskId], stem: &str, epochs: usize, cap: usize) -> anyhow::Result<()> {
    let model = ModelPreset::Tiny;
    let backend = backend_from_env()?;
    let ckpt = checkpoint_path(model);
    let ckpt = ckpt.exists().then_some(ckpt);
    let dims = model.dims(tasks.len());
    let spec = AdapterSpec::new(AdapterKind::MetaTt(MetaTtKind::FourPlusOneD), 8, 2.0, dims);
    let mut cfg = MtlConfig::default();
    cfg.train.epochs = epochs;
    cfg.train.lr = 5e-4; // Appendix B
    cfg.per_task_cap = cap;
    cfg.eval_cap = 300;
    let res = run_mtl(backend.as_ref(), model, &spec, tasks, &cfg, ckpt.as_deref())?;

    let mut header = vec!["epoch".to_string()];
    header.extend(res.param_names.iter().map(|n| format!("grad_{n}")));
    header.extend(tasks.iter().map(|t| format!("metric_{}", t.name())));
    let mut table = Table::new(
        &format!(
            "Figures 4/5 data ({stem}): normalized per-core gradients + per-task metrics"
        ),
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for e in &res.epochs {
        let mut row = vec![e.epoch.to_string()];
        row.extend(e.grad_norms.iter().map(|g| format!("{g:.6}")));
        row.extend(e.metrics.iter().map(|m| format!("{m:.4}")));
        table.row(row);
    }
    table.emit(stem);

    // Claim checks: the task core (g3 in (4+1)D ordering) gets real signal.
    let g3_idx = res.param_names.iter().position(|n| n == "g3").unwrap();
    let late = &res.epochs[res.epochs.len() / 2..];
    let g3_mean: f64 =
        late.iter().map(|e| e.grad_norms[g3_idx]).sum::<f64>() / late.len() as f64;
    let max_core_mean = (0..res.param_names.len())
        .map(|i| late.iter().map(|e| e.grad_norms[i]).sum::<f64>() / late.len() as f64)
        .fold(f64::MIN, f64::max);
    println!(
        "[{stem}] task-core g3 mean grad {:.5} vs max core {:.5} (ratio {:.2}) — \
         nonzero means the task core is learning task structure",
        g3_mean,
        max_core_mean,
        g3_mean / max_core_mean
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let full = std::env::var("METATT_FULL").is_ok();
    let epochs = env_usize("METATT_EPOCHS", if full { 16 } else { 8 });
    let cap = env_usize("METATT_CAP", if full { 5000 } else { 700 });
    // Figure 4: tasks 0:MRPC 1:RTE 2:CoLA (paper's labeling).
    run_figure(
        &[TaskId::MrpcSyn, TaskId::RteSyn, TaskId::ColaSyn],
        "fig4_mtl_gradients_3task",
        epochs,
        cap,
    )?;
    // Figure 5: 0:MRPC 1:QNLI 2:RTE 3:CoLA.
    run_figure(
        &[TaskId::MrpcSyn, TaskId::QnliSyn, TaskId::RteSyn, TaskId::ColaSyn],
        "fig5_mtl_gradients_4task",
        epochs,
        cap,
    )?;
    Ok(())
}
