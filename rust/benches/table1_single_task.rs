//! Table 1 — single-task fine-tuning: MetaTT-4D/5D vs FT / LoRA / VeRA /
//! LoTR across the synthetic GLUE suite.
//!
//! Regenerates the paper's table layout: one row per (method, rank) with
//! the trainable-parameter count and per-task metrics (mean(stderr) over
//! seeds), plus the paper's RoBERTa-Base numbers for shape comparison.
//! Absolute values differ (tiny encoder, synthetic tasks — DESIGN.md §3);
//! the claims under test are: (a) MetaTT matches or approaches LoRA at a
//! fraction of the parameters, (b) parameter counts follow §2.4 exactly.
//!
//! Env knobs: METATT_FULL=1 (all 8 tasks, 3 seeds, 12 epochs),
//!            METATT_SEEDS=n, METATT_EPOCHS=n, METATT_CAP=n.

use metatt::adapters::{AdapterKind, AdapterSpec};
use metatt::bench::{paper_fmt, Table};
use metatt::config::{ModelPreset, TrainConfig};
use metatt::coordinator::{results, run_single_task};
use metatt::data::TaskId;
use metatt::metrics::mean_stderr;
use metatt::runtime::{backend_from_env, checkpoint_path};
use metatt::tt::MetaTtKind;
use metatt::util::json::Json;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Paper Table 1, RoBERTa-Base block (param ×10³, per-task metric %): used
/// for the side-by-side "shape" comparison in the emitted table.
const PAPER_BASE: &[(&str, usize, f64, &[(&str, f64)])] = &[
    ("lora", 8, 295.0, &[("cola_syn", 61.1), ("mrpc_syn", 88.0), ("rte_syn", 73.0), ("sst2_syn", 94.2), ("stsb_syn", 90.7), ("qnli_syn", 91.3), ("qqp_syn", 90.1), ("mnli_syn", 87.3)]),
    ("vera", 64, 43.0, &[("cola_syn", 58.0), ("mrpc_syn", 87.2), ("rte_syn", 73.4), ("sst2_syn", 92.2), ("stsb_syn", 88.7), ("qnli_syn", 89.6), ("qqp_syn", 85.9), ("mnli_syn", 81.0)]),
    ("lotr", 8, 100.0, &[("cola_syn", 58.0), ("mrpc_syn", 88.0), ("rte_syn", 53.0), ("sst2_syn", 93.8), ("stsb_syn", 89.8), ("qnli_syn", 92.5), ("qqp_syn", 87.6), ("mnli_syn", 85.2)]),
    ("metatt4d", 8, 13.0, &[("cola_syn", 58.8), ("mrpc_syn", 87.6), ("rte_syn", 72.9), ("sst2_syn", 92.0), ("stsb_syn", 89.1), ("qnli_syn", 90.4), ("qqp_syn", 86.9), ("mnli_syn", 84.2)]),
    ("metatt5d", 16, 20.0, &[("cola_syn", 50.0), ("mrpc_syn", 88.2), ("rte_syn", 73.6), ("sst2_syn", 93.2), ("stsb_syn", 88.6), ("qnli_syn", 89.7), ("qqp_syn", 87.0), ("mnli_syn", 84.0)]),
];

fn main() -> anyhow::Result<()> {
    let full = std::env::var("METATT_FULL").is_ok();
    let n_seeds = env_usize("METATT_SEEDS", if full { 3 } else { 1 });
    let epochs = env_usize("METATT_EPOCHS", if full { 12 } else { 6 });
    let cap = env_usize("METATT_CAP", if full { 2000 } else { 512 });
    let seeds: &[u64] = &[33305628, 2025, 42][..n_seeds]; // paper's Base seeds

    let tasks: Vec<TaskId> = if full {
        metatt::data::ALL_TASKS.to_vec()
    } else {
        vec![TaskId::ColaSyn, TaskId::MrpcSyn, TaskId::RteSyn, TaskId::Sst2Syn, TaskId::StsbSyn]
    };
    // (method, rank, alpha) grid — the Table-1 methods at their table ranks.
    let methods: Vec<(AdapterKind, usize, f32)> = vec![
        (AdapterKind::Full, 0, 0.0),
        (AdapterKind::LoRa, 8, 4.0),
        (AdapterKind::VeRa, 64, 4.0),
        (AdapterKind::LoTr, 8, 4.0),
        (AdapterKind::MetaTt(MetaTtKind::FourD), 4, 4.0),
        (AdapterKind::MetaTt(MetaTtKind::FourD), 8, 4.0),
        (AdapterKind::MetaTt(MetaTtKind::FourD), 16, 4.0),
        (AdapterKind::MetaTt(MetaTtKind::FiveD), 8, 4.0),
    ];

    let model = ModelPreset::Tiny;
    let backend = backend_from_env()?;
    let ckpt = checkpoint_path(model);
    let ckpt = ckpt.exists().then_some(ckpt);
    if ckpt.is_none() {
        eprintln!("WARNING: no pretrained checkpoint; run `metatt pretrain --model tiny`");
    }
    let dims = model.dims(1);

    let mut header = vec!["method".to_string(), "rank".into(), "params".into()];
    header.extend(tasks.iter().map(|t| t.name().to_string()));
    let mut table = Table::new(
        "Table 1 (reproduction): single-task fine-tuning, tiny encoder, synthetic GLUE",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );

    for (kind, rank, alpha) in &methods {
        let spec = AdapterSpec::new(*kind, *rank, *alpha, dims);
        let mut cells = vec![
            spec.kind.name(),
            rank.to_string(),
            spec.param_count().to_string(),
        ];
        for task in &tasks {
            // FT baseline only has a 2-class artifact.
            let info = task.info();
            let classes_ok =
                !matches!(kind, AdapterKind::Full) || (!info.regression && info.num_classes == 2);
            if !classes_ok {
                cells.push("-".into());
                continue;
            }
            let mut vals = Vec::new();
            for &seed in seeds {
                let train = TrainConfig {
                    epochs,
                    train_cap: cap,
                    eval_cap: 400,
                    seed,
                    ..Default::default()
                };
                let res = run_single_task(
                    backend.as_ref(), model, &spec, *task, &train, *alpha, ckpt.as_deref(), None,
                )?;
                vals.push(res.best_metric * 100.0);
                results::append_record(
                    "table1",
                    &Json::obj(vec![
                        ("task", Json::str(task.name())),
                        ("method", Json::str(spec.kind.name())),
                        ("rank", Json::num(*rank as f64)),
                        ("seed", Json::num(seed as f64)),
                        ("params", Json::num(spec.param_count() as f64)),
                        ("best", Json::num(res.best_metric)),
                    ]),
                );
            }
            let (m, e) = mean_stderr(&vals);
            cells.push(paper_fmt(m, e));
            println!(
                "[table1] {:<10} r{:<3} {:<9}: {}",
                spec.kind.name(),
                rank,
                task.name(),
                paper_fmt(m, e)
            );
        }
        table.row(cells);
    }
    table.emit("table1_single_task");

    // Side-by-side: paper's RoBERTa-Base rows (shape reference).
    let mut ref_table = Table::new(
        "Paper Table 1 (RoBERTa-Base reference rows)",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for (name, rank, params_k, metrics) in PAPER_BASE {
        let mut cells = vec![name.to_string(), rank.to_string(), format!("{}k", params_k)];
        for task in &tasks {
            let v = metrics.iter().find(|(t, _)| t == &task.name()).map(|(_, v)| *v);
            cells.push(v.map(|v| format!("{v:.1}")).unwrap_or("-".into()));
        }
        ref_table.row(cells);
    }
    ref_table.emit("table1_paper_reference");

    // Compression-ratio check (paper abstract: 2x-20x+ fewer than LoRA).
    let lora = AdapterSpec::new(AdapterKind::LoRa, 8, 4.0, dims).param_count();
    for (kind, rank, alpha) in &methods {
        if matches!(kind, AdapterKind::MetaTt(_)) {
            let c = AdapterSpec::new(*kind, *rank, *alpha, dims);
            println!(
                "[table1] compression {} r{} vs LoRA r8: {:.1}x",
                c.kind.name(),
                rank,
                lora as f64 / c.param_count() as f64
            );
        }
    }
    Ok(())
}
