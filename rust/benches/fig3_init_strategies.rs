//! Figure 3 — TT initialization ablation (Appendix A.1): MetaTT-4D on the
//! MRPC and RTE analogues under different per-core init strategies.
//!
//! Each strategy is a 4-letter-pair code (`ze` zero / `id` identity / `no`
//! normal(0, 0.2)) per core; only zero-preserving combinations are valid
//! (the adapter must be an exact zero map at step 0). The paper picks
//! `ze-id-id-id` as the default; the claim under test is that it is at or
//! near the top of the ablation, and that where the zero core sits (and
//! what surrounds it) matters.
//!
//! Env: METATT_FULL=1 runs the whole zero-preserving grid (19 strategies ×
//! 2 tasks × 3 seeds); default runs the paper's six headline codes.

use metatt::adapters::{AdapterKind, AdapterSpec};
use metatt::bench::{paper_fmt, Table};
use metatt::config::{ModelPreset, TrainConfig};
use metatt::coordinator::{results, run_single_task};
use metatt::data::TaskId;
use metatt::metrics::mean_stderr;
use metatt::runtime::{backend_from_env, checkpoint_path};
use metatt::tt::{InitStrategy, MetaTtKind};
use metatt::util::json::Json;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let full = std::env::var("METATT_FULL").is_ok();
    let n_seeds = env_usize("METATT_SEEDS", if full { 3 } else { 1 });
    let epochs = env_usize("METATT_EPOCHS", if full { 12 } else { 6 });
    let seeds: &[u64] = &[33305628, 2025, 42][..n_seeds];

    let strategies: Vec<InitStrategy> = if full {
        InitStrategy::zero_preserving_grid(4)
    } else {
        ["ze-id-id-id", "ze-no-no-no", "id-ze-id-id", "no-ze-no-no", "id-id-id-ze", "no-no-no-ze"]
            .iter()
            .map(|c| InitStrategy::from_code(c).unwrap())
            .collect()
    };
    let tasks = if full {
        vec![TaskId::MrpcSyn, TaskId::RteSyn]
    } else {
        vec![TaskId::MrpcSyn]
    };

    let model = ModelPreset::Tiny;
    let backend = backend_from_env()?;
    let ckpt = checkpoint_path(model);
    let ckpt = ckpt.exists().then_some(ckpt);
    let dims = model.dims(1);
    let spec = AdapterSpec::new(AdapterKind::MetaTt(MetaTtKind::FourD), 8, 4.0, dims);

    let mut header = vec!["init".to_string()];
    header.extend(tasks.iter().map(|t| t.name().to_string()));
    let mut table = Table::new(
        "Figure 3 (reproduction): MetaTT-4D init-strategy ablation",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let mut default_score = f64::MIN;
    let mut best_score = f64::MIN;
    let mut best_code = String::new();
    for strat in &strategies {
        let mut cells = vec![strat.code()];
        let mut row_mean = 0.0;
        for task in &tasks {
            let mut vals = Vec::new();
            for &seed in seeds {
                let train = TrainConfig {
                    epochs,
                    train_cap: 640,
                    eval_cap: 300,
                    seed,
                    ..Default::default()
                };
                let res = run_single_task(
                    backend.as_ref(), model, &spec, *task, &train, 4.0, ckpt.as_deref(), Some(strat),
                )?;
                vals.push(res.best_metric * 100.0);
                results::append_record(
                    "fig3",
                    &Json::obj(vec![
                        ("init", Json::str(strat.code())),
                        ("task", Json::str(task.name())),
                        ("seed", Json::num(seed as f64)),
                        ("best", Json::num(res.best_metric)),
                    ]),
                );
            }
            let (m, e) = mean_stderr(&vals);
            row_mean += m;
            cells.push(paper_fmt(m, e));
            println!("[fig3] {:<12} {:<9} {}", strat.code(), task.name(), paper_fmt(m, e));
        }
        row_mean /= tasks.len() as f64;
        if strat.code() == "ze-id-id-id" {
            default_score = row_mean;
        }
        if row_mean > best_score {
            best_score = row_mean;
            best_code = strat.code();
        }
        table.row(cells);
    }
    table.emit("fig3_init_strategies");
    println!(
        "\npaper default ze-id-id-id: {:.2} | grid best {}: {:.2} — the default \
         should be at or near the top (paper: 'generally performs well on average')",
        default_score, best_code, best_score
    );
    Ok(())
}
