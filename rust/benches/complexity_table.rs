//! §2.4 complexity analysis — parameter-count formulas and compression
//! ratios at *true* RoBERTa dimensions (no compute needed, so this one
//! runs at the paper's actual scale).
//!
//! Checks, exactly:
//!   * MetaTT-4D = 2Dr + (L+M)r²  ;  MetaTT-5D = (D+D/H)r + (L+M+H)r²
//!   * LoRA = 2LMDr ; LoTR = 2Dr + LMr² ; VeRA = LM(D+r)
//!   * the Table-1 "Param ×10³" column (295k LoRA r8, 13k MetaTT-4D r8, …)
//!   * the abstract's "between 20x and 2x less parameters than LoRA".

use metatt::adapters::{AdapterKind, AdapterSpec, ModelDims};
use metatt::bench::Table;
use metatt::tt::MetaTtKind;

fn main() {
    for (label, dims) in [
        ("RoBERTa-Base", ModelDims::roberta_base()),
        ("RoBERTa-Large", ModelDims::roberta_large()),
    ] {
        let mut table = Table::new(
            &format!("§2.4 parameter counts at {label} dims (D={}, L={})", dims.hidden, dims.layers),
            &["method", "rank", "params", "formula", "×10³", "vs LoRA r=8"],
        );
        let lora8 = AdapterSpec::new(AdapterKind::LoRa, 8, 1.0, dims).param_count() as f64;
        let grid: Vec<(AdapterKind, usize)> = vec![
            (AdapterKind::Full, 0),
            (AdapterKind::LoRa, 8),
            (AdapterKind::VeRa, if dims.hidden == 768 { 1024 } else { 256 }),
            (AdapterKind::LoTr, 40),
            (AdapterKind::LoTr, 80),
            (AdapterKind::MetaTt(MetaTtKind::FourD), 8),
            (AdapterKind::MetaTt(MetaTtKind::FourD), 16),
            (AdapterKind::MetaTt(MetaTtKind::FourD), 24),
            (AdapterKind::MetaTt(MetaTtKind::FourD), 32),
            (AdapterKind::MetaTt(MetaTtKind::FourD), 64),
            (AdapterKind::MetaTt(MetaTtKind::FiveD), 16),
            (AdapterKind::MetaTt(MetaTtKind::FiveD), 32),
            (AdapterKind::MetaTt(MetaTtKind::FiveD), 64),
        ];
        for (kind, rank) in grid {
            let spec = AdapterSpec::new(kind, rank, 1.0, dims);
            let count = spec.param_count();
            let formula = spec.paper_formula_count();
            assert_eq!(count, formula, "{:?} r{rank}: constructed != closed form", kind);
            table.row(vec![
                spec.kind.name(),
                rank.to_string(),
                count.to_string(),
                formula.to_string(),
                format!("{:.1}", count as f64 / 1e3),
                format!("{:.1}x", lora8 / count as f64),
            ]);
        }
        table.emit(&format!(
            "complexity_{}",
            label.to_lowercase().replace('-', "_")
        ));
    }

    // Pin the paper's Table-1 param column (×10³) exactly.
    let base = ModelDims::roberta_base();
    let large = ModelDims::roberta_large();
    let checks: Vec<(&str, AdapterKind, usize, ModelDims, f64)> = vec![
        ("Base LoRA r8", AdapterKind::LoRa, 8, base, 295.0),
        ("Base MetaTT-4D r8", AdapterKind::MetaTt(MetaTtKind::FourD), 8, base, 13.0),
        ("Base MetaTT-4D r24", AdapterKind::MetaTt(MetaTtKind::FourD), 24, base, 45.0),
        ("Base MetaTT-4D r64", AdapterKind::MetaTt(MetaTtKind::FourD), 64, base, 156.0),
        ("Base MetaTT-5D r64", AdapterKind::MetaTt(MetaTtKind::FiveD), 64, base, 160.0),
        ("Base LoTR r40", AdapterKind::LoTr, 40, base, 100.0),
        ("Large LoRA r8", AdapterKind::LoRa, 8, large, 786.0),
        ("Large MetaTT-4D r16", AdapterKind::MetaTt(MetaTtKind::FourD), 16, large, 39.0),
        ("Large MetaTT-4D r32", AdapterKind::MetaTt(MetaTtKind::FourD), 32, large, 92.0),
        ("Large MetaTT-5D r32", AdapterKind::MetaTt(MetaTtKind::FiveD), 32, large, 78.0),
    ];
    println!("\nPaper Table-1 'Param ×10³' column check:");
    let mut all_ok = true;
    for (label, kind, rank, dims, paper_k) in checks {
        let got = AdapterSpec::new(kind, rank, 1.0, dims).param_count() as f64 / 1e3;
        let ok = (got - paper_k).abs() / paper_k < 0.07; // table rounds to integers
        all_ok &= ok;
        println!(
            "  {:<22} ours {:>7.1}k  paper {:>6.0}k  {}",
            label,
            got,
            paper_k,
            if ok { "✓" } else { "✗" }
        );
    }
    assert!(all_ok, "a paper param count diverged beyond rounding");

    // Abstract claim: 20x–2x fewer than LoRA across the Table-1 MetaTT grid.
    let ratios: Vec<f64> = [(8, base), (24, base), (64, base), (16, large), (32, large)]
        .iter()
        .map(|&(r, d)| {
            AdapterSpec::new(AdapterKind::LoRa, 8, 1.0, d).param_count() as f64
                / AdapterSpec::new(AdapterKind::MetaTt(MetaTtKind::FourD), r, 1.0, d).param_count()
                    as f64
        })
        .collect();
    println!(
        "\ncompression vs LoRA r=8 across the grid: {:?} (paper: between ~2x and >20x)",
        ratios.iter().map(|r| (r * 10.0).round() / 10.0).collect::<Vec<_>>()
    );
    assert!(ratios.iter().any(|&r| r > 20.0) && ratios.iter().all(|&r| r > 1.8));
    println!("complexity_table: all closed-form checks PASSED");
}
