//! Figure 2 — AdamW vs AdamW + DMRG-inspired sweeps on the MRPC analogue
//! (MetaTT-5D). Emits the accuracy-vs-epoch series for fixed ranks
//! {4, 6, 8} and for the annealed run (10 → 4), as CSV for plotting.
//!
//! Claims under test (paper §3.3): (a) a sweep causes an accuracy dip then
//! rapid recovery; (b) annealing from a high rank reaches a better rank-4
//! model than fixed-rank-4 AdamW.
//!
//! Env: METATT_FULL=1 (more epochs/seeds), METATT_EPOCHS, METATT_SEEDS.

use metatt::adapters::AdapterKind;
use metatt::bench::Table;
use metatt::config::ModelPreset;
use metatt::coordinator::{run_dmrg, run_fixed_rank_baseline, DmrgConfig};
use metatt::data::TaskId;
use metatt::metrics::mean_stderr;
use metatt::runtime::{backend_from_env, checkpoint_path};
use metatt::tt::{MetaTtKind, RankSchedule};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub fn dmrg_figure(task: TaskId, stem: &str) -> anyhow::Result<()> {
    let full = std::env::var("METATT_FULL").is_ok();
    let epochs = env_usize("METATT_EPOCHS", if full { 20 } else { 12 });
    let n_seeds = env_usize("METATT_SEEDS", if full { 3 } else { 1 });
    let seeds: &[u64] = &[33305628, 2025, 42][..n_seeds];
    let model = ModelPreset::Tiny;
    let kind = AdapterKind::MetaTt(MetaTtKind::FiveD);
    let backend = backend_from_env()?;
    let ckpt = checkpoint_path(model);
    let ckpt = ckpt.exists().then_some(ckpt);

    let mut cfg = DmrgConfig::default();
    cfg.train.epochs = epochs;
    cfg.train.train_cap = if full { 2000 } else { 640 };
    cfg.train.eval_cap = 400;
    cfg.start_rank = 10;
    // Paper Fig 2: progressive 10 → 4 (arrows on the left panel).
    cfg.schedule = RankSchedule::parse("1:9,3:8,5:7,7:6,8:5,9:4").map_err(anyhow::Error::msg)?;

    let mut header = vec!["epoch".to_string()];
    let mut series: Vec<(String, Vec<f64>)> = Vec::new();

    // Fixed-rank AdamW baselines.
    for rank in [4usize, 6, 8] {
        let mut curves: Vec<Vec<f64>> = Vec::new();
        let mut bests = Vec::new();
        for &seed in seeds {
            let mut c = cfg.clone();
            c.train.seed = seed;
            let logs = run_fixed_rank_baseline(backend.as_ref(), model, kind, task, rank, &c, ckpt.as_deref())?;
            bests.push(logs.iter().map(|e| e.metric).fold(f64::MIN, f64::max) * 100.0);
            curves.push(logs.iter().map(|e| e.metric).collect());
        }
        let avg: Vec<f64> = (0..epochs)
            .map(|e| curves.iter().map(|c| c[e]).sum::<f64>() / curves.len() as f64)
            .collect();
        let (m, se) = mean_stderr(&bests);
        println!("[{stem}] AdamW r={rank}: best {}", metatt::bench::paper_fmt(m, se));
        header.push(format!("adamw_r{rank}"));
        series.push((format!("adamw_r{rank}"), avg));
    }

    // Annealed AdamW + DMRG.
    let mut curves: Vec<Vec<f64>> = Vec::new();
    let mut ranks_at: Vec<usize> = Vec::new();
    let mut bests = Vec::new();
    for &seed in seeds {
        let mut c = cfg.clone();
        c.train.seed = seed;
        let res = run_dmrg(backend.as_ref(), model, kind, task, &c, ckpt.as_deref())?;
        bests.push(res.best_at_final_rank * 100.0);
        ranks_at = res.epochs.iter().map(|e| e.rank).collect();
        curves.push(res.epochs.iter().map(|e| e.metric).collect());
    }
    let avg: Vec<f64> = (0..epochs)
        .map(|e| curves.iter().map(|c| c[e]).sum::<f64>() / curves.len() as f64)
        .collect();
    let (m, se) = mean_stderr(&bests);
    println!(
        "[{stem}] AdamW+DMRG (10→4): best-at-rank-4 {}",
        metatt::bench::paper_fmt(m, se)
    );
    header.push("adamw_dmrg".into());
    header.push("dmrg_rank".into());
    series.push(("adamw_dmrg".into(), avg));
    series.push((
        "dmrg_rank".into(),
        ranks_at.iter().map(|&r| r as f64).collect(),
    ));

    let mut table = Table::new(
        &format!("Figure {} series: accuracy vs epoch on {}", stem, task.name()),
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for e in 0..epochs {
        let mut row = vec![e.to_string()];
        for (_, s) in &series {
            row.push(format!("{:.4}", s[e]));
        }
        table.row(row);
    }
    table.emit(stem);
    Ok(())
}

fn main() -> anyhow::Result<()> {
    dmrg_figure(TaskId::MrpcSyn, "fig2_dmrg_mrpc")
}
