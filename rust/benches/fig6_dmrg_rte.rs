//! Figure 6 — AdamW vs AdamW + DMRG-inspired sweeps on the RTE analogue
//! (the Appendix-C companion of Figure 2; RTE is the harder task, where
//! the paper reports the larger relative gain from annealing).
//!
//! Same series and knobs as fig2_dmrg_mrpc; see that bench for details.

use metatt::data::TaskId;

#[path = "fig2_dmrg_mrpc.rs"]
mod fig2;

fn main() -> anyhow::Result<()> {
    fig2::dmrg_figure(TaskId::RteSyn, "fig6_dmrg_rte")
}
