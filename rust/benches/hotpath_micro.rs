//! Hot-path micro-benchmarks (§2.4 timing claims + DESIGN.md §7 ablations).
//!
//! Backend-agnostic: runs on the pure-rust reference backend by default, or
//! on PJRT with `METATT_BACKEND=pjrt` (after `make artifacts`). Measures:
//!
//!   1. **Adapter apply** (serving path): fused MetaTT-4D chain vs fused
//!      LoRA at the same rank — paper §2.4: "training times of TT adapters
//!      are very competitive with LoRA" because the extra work is r×r
//!      GEMMs, negligible next to the D×r boundaries.
//!   2. **Train/eval step latency** per adapter (the L3 hot loop).
//!   3. **DMRG sweep** host cost at the paper's ranks — §C: "a small
//!      overhead … a much smaller fraction of SVDs than per-matrix schemes".
//!   4. **Ablation** (DESIGN.md §7.2): one-time step bind (frozen weights
//!      resident) vs re-binding per step.
//!   5. **Step hot-swap** cost across the DMRG rank ladder: first bind
//!      (compile on pjrt, layout synthesis on ref) vs re-bind.
//!   6. **Threading scaling** (PR 2): the parallel kernel family and
//!      encoder steps at 1 vs N worker threads, emitted as
//!      `BENCH_pr2.json` so the perf trajectory is recorded per commit.
//!   7. **Zero-allocation hot path** (PR 3): per-phase p50s + allocs/step
//!      + arena speedup, emitted as `BENCH_pr3.json`.
//!   8. **Packed register-tiled GEMM** (PR 4): per-shape GFLOP/s and the
//!      speedup over the retired PR 3 blocked kernel (kept here as the
//!      baseline and asserted bit-identical first), emitted as
//!      `BENCH_pr4.json`.
//!   9. **Quantized serving dtypes** (PR 7): the folded-adapter serving
//!      tick at f32 / bf16 / int8 packed storage — packed weight bytes
//!      resident, ticks/s, and effective weight-stream GB/s per dtype,
//!      emitted as `BENCH_pr7.json`. Asserts the quantized paths actually
//!      move fewer bytes (bf16 < f32, int8 < bf16).
//!  10. **Observability overhead** (PR 10): the serving tick with its
//!      lifecycle hooks armed vs unarmed (acceptance: armed p50 within 5%),
//!      raw tracer events/s armed and disarmed, and exact loss accounting
//!      under deliberate ring pressure — emitted as `BENCH_pr10.json`.
//!
//! `METATT_BENCH_SMOKE=1` runs a fast subset with tiny iteration counts —
//! CI uses it to catch kernel regressions (crashes, determinism breaks,
//! pathological slowdowns) without paying full measurement cost.

use metatt::adapters::{AdapterKind, AdapterSpec};
use metatt::bench::{bench, save_record, Stats};
use metatt::config::ModelPreset;
use metatt::data::TaskId;
use metatt::obs::{EventCode, Obs};
use metatt::optim::AdamW;
use metatt::runtime::{
    assemble_frozen, backend_from_env, pack_frozen_weights, packed_frozen_bytes,
    ArtifactSpec, Backend, FoldedPairPacked, RefBackend, Step, StepKind,
};
use metatt::tensor::{matmul_into, DtypeKind, PackScratch, Tensor, PAR_MIN_MACS};
use metatt::tt::{dmrg_sweep, InitStrategy, MetaTt, MetaTtKind};
use metatt::util::json::Json;
use metatt::util::rng::Pcg64;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// Counting wrapper over the system allocator: section 7 reports heap
/// allocations per step so the zero-allocation contract is visible in the
/// recorded numbers, not just in the test suite.
struct CountingAlloc;

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Heap allocations performed by one invocation of `f`.
fn count_allocs(mut f: impl FnMut()) -> u64 {
    let before = ALLOC_COUNT.load(Ordering::SeqCst);
    f();
    ALLOC_COUNT.load(Ordering::SeqCst) - before
}

/// The retired PR 3 cache-blocked matmul, kept here as the §8 baseline
/// (the packed register-tiled kernel replaced it in `tensor::ops`). Same
/// row-band policy (min 8 rows, [`PAR_MIN_MACS`] gate) and the same
/// per-element k-ascending accumulation order — which is exactly why the
/// packed kernel must reproduce its output bit-for-bit.
#[allow(clippy::too_many_arguments)]
fn pr3_blocked_matmul(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    use metatt::util::threadpool::{gated_threads, scope_rows, SharedSliceMut};
    const BLOCK: usize = 64;
    let th = gated_threads(threads, m * k * n, PAR_MIN_MACS);
    let cs = SharedSliceMut::new(c);
    scope_rows(th, m, 8, |r| {
        // SAFETY: bands are disjoint row ranges of c.
        let c_band = unsafe { cs.range_mut(r.start * n, r.end * n) };
        let a_band = &a[r.start * k..r.end * k];
        let mb = r.end - r.start;
        for i0 in (0..mb).step_by(BLOCK) {
            let i1 = (i0 + BLOCK).min(mb);
            for k0 in (0..k).step_by(BLOCK) {
                let k1 = (k0 + BLOCK).min(k);
                for j0 in (0..n).step_by(BLOCK) {
                    let j1 = (j0 + BLOCK).min(n);
                    for i in i0..i1 {
                        let crow = &mut c_band[i * n..(i + 1) * n];
                        for kk in k0..k1 {
                            let aik = a_band[i * k + kk];
                            let brow = &b[kk * n..(kk + 1) * n];
                            for j in j0..j1 {
                                crow[j] += aik * brow[j];
                            }
                        }
                    }
                }
            }
        }
    });
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("METATT_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false);
    let scale = |n: usize| if smoke { (n / 8).max(2) } else { n };
    let backend = backend_from_env()?;
    println!("[backend] {}{}", backend.platform(), if smoke { " (smoke mode)" } else { "" });
    let mut rng = Pcg64::new(42);

    // ---- 1. Serving apply: MetaTT vs LoRA at rank 8. ---------------------
    println!("== 1. serving apply (base_sim dims: d=256) ==");
    let mut apply_stats: Vec<(String, Stats)> = Vec::new();
    for adapter in ["metatt4d", "lora"] {
        let spec = backend.apply_spec(adapter, 8)?;
        let entry = backend.entry(&spec)?;
        let runner = backend.bind(&spec, &Default::default())?;
        let inputs: Vec<Tensor> = entry
            .inputs
            .iter()
            .map(|io| Tensor::randn(&io.shape, 0.5, &mut rng))
            .collect();
        let s = bench(&format!("apply/{adapter}/r8"), scale(5), scale(40), || {
            let out = runner.run_raw(&inputs).unwrap();
            std::hint::black_box(out);
        });
        apply_stats.push((adapter.to_string(), s));
    }
    let ratio = apply_stats[0].1.p50 / apply_stats[1].1.p50;
    println!(
        "   MetaTT/LoRA apply latency ratio: {:.2} (paper §2.4 claims ≈1: the r×r \
         middle GEMM is negligible)\n",
        ratio
    );

    // ---- 2. Train-step latency per adapter. ------------------------------
    println!("== 2. train-step latency (tiny, batch 16) ==");
    let model = ModelPreset::Tiny;
    let dims = model.dims(1);
    let ds = TaskId::MrpcSyn.generate_at(64, 32, 1, dims.max_seq, dims.vocab);
    let batcher = metatt::data::Batcher::new(16);
    let eval_batches = batcher.eval(&ds);
    let batch = &eval_batches[0];
    for (adapter, rank) in [
        (AdapterKind::MetaTt(MetaTtKind::FourD), 8),
        (AdapterKind::MetaTt(MetaTtKind::FiveD), 8),
        (AdapterKind::LoRa, 8),
        (AdapterKind::VeRa, 64),
        (AdapterKind::LoTr, 8),
    ] {
        let spec = AdapterSpec::new(adapter, rank, 4.0, dims);
        let aspec = ArtifactSpec {
            step: StepKind::Train,
            model: model.name().to_string(),
            adapter: spec.kind.name(),
            rank,
            classes: 2,
            tasks: 1,
            batch: 16,
            seq: dims.max_seq,
        };
        let entry = backend.entry(&aspec)?;
        let frozen = std::sync::Arc::new(assemble_frozen(&entry, None, model)?);
        let runner = backend.bind(&aspec, &frozen)?;
        let params = spec.init_params(&mut rng);
        bench(&format!("train-step/{}/r{rank}", spec.kind.name()), scale(3), scale(25), || {
            let out = runner.run_train(&params, batch, 0, 4.0).unwrap();
            std::hint::black_box(out);
        });
    }
    println!();

    // ---- 3. DMRG sweep host cost. ----------------------------------------
    println!("== 3. DMRG sweep (host Jacobi SVD) ==");
    for (d_model, rank) in [(64usize, 10), (256, 10), (768, 10), (768, 64)] {
        let dims = metatt::adapters::ModelDims {
            hidden: d_model,
            layers: 12,
            heads: 8,
            matrices: 2,
            tasks: 1,
            vocab: 512,
            ffn: 4 * d_model,
            max_seq: 64,
        };
        let spec = AdapterSpec::new(AdapterKind::MetaTt(MetaTtKind::FourD), rank, 1.0, dims);
        let init = InitStrategy::from_code("no-no-no-no").unwrap();
        let tt0: MetaTt = spec.build_metatt_with(&mut rng, Some(&init));
        bench(&format!("dmrg-sweep/d{d_model}/r{rank}->r{}", rank / 2), scale(2), scale(10), || {
            let mut tt = tt0.clone();
            let rep = dmrg_sweep(&mut tt.chain, &|_| rank / 2);
            std::hint::black_box(rep);
        });
    }
    println!();

    // ---- 4. Ablation: bind once (frozen resident) vs re-bind per step. ---
    println!("== 4. ablation: bind-once vs re-bind per step ==");
    let aspec = ArtifactSpec {
        step: StepKind::Eval,
        model: "tiny".into(),
        adapter: "metatt4d".into(),
        rank: 8,
        classes: 2,
        tasks: 1,
        batch: 16,
        seq: dims.max_seq,
    };
    let entry = backend.entry(&aspec)?;
    let frozen = std::sync::Arc::new(assemble_frozen(&entry, None, model)?);
    let spec8 = AdapterSpec::new(AdapterKind::MetaTt(MetaTtKind::FourD), 8, 4.0, dims);
    let params = spec8.init_params(&mut rng);
    let runner = backend.bind(&aspec, &frozen)?;
    let resident = bench("eval-step/bind-once", scale(3), scale(30), || {
        let out = runner.run_eval(&params, batch, 0, 4.0).unwrap();
        std::hint::black_box(out);
    });
    let reupload = bench("eval-step/re-bind", scale(3), scale(30), || {
        let r = backend.bind(&aspec, &frozen).unwrap();
        let out = r.run_eval(&params, batch, 0, 4.0).unwrap();
        std::hint::black_box(out);
    });
    println!(
        "   bind-once is {:.1}x faster per step\n",
        reupload.p50 / resident.p50
    );

    // ---- 5. Step hot-swap across the DMRG rank ladder. -------------------
    println!("== 5. step hot-swap (DMRG rank ladder) ==");
    let rank_spec = |r: usize| ArtifactSpec {
        step: StepKind::Train,
        model: "tiny".into(),
        adapter: "metatt5d".into(),
        rank: r,
        classes: 2,
        tasks: 1,
        batch: 16,
        seq: dims.max_seq,
    };
    let ladder_frozen = {
        let e = backend.entry(&rank_spec(4))?;
        std::sync::Arc::new(assemble_frozen(&e, None, model)?)
    };
    let t0 = std::time::Instant::now();
    for r in [4, 5, 6, 7, 8, 9, 10] {
        let step = backend.bind(&rank_spec(r), &ladder_frozen)?;
        std::hint::black_box(step.entry().spec.rank);
    }
    let bind_all = t0.elapsed().as_secs_f64();
    let cached = bench("step/re-bind-rank6", scale(2), scale(50), || {
        let e = backend.bind(&rank_spec(6), &ladder_frozen).unwrap();
        std::hint::black_box(e.entry().spec.rank);
    });
    println!(
        "   7-rank DMRG ladder binds in {:.3}s total (amortized once per run); \
         re-bind {}",
        bind_all,
        Stats::fmt_time(cached.p50)
    );

    // ---- 6. Threading scaling (PR 2): kernels + encoder steps. -----------
    let par_threads = metatt::util::threadpool::default_threads().max(2);
    println!("== 6. threading scaling (1 vs {par_threads} threads) ==");
    let mut records: Vec<Json> = Vec::new();

    // 6a. Parallel matmul kernel at the sizes the acceptance criteria pin.
    // Besides timing, this is the smoke gate CI relies on: the parallel
    // result must match the serial result bit-for-bit, or we abort loudly.
    for &(m, k, n) in &[(256usize, 256usize, 256usize), (384, 384, 384), (512, 512, 512)] {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        assert_eq!(
            a.matmul_mt(&b, 1),
            a.matmul_mt(&b, par_threads),
            "determinism regression: {m}x{k}x{n} parallel != serial"
        );
        let serial = bench(&format!("matmul/{m}x{k}x{n}/t1"), scale(3), scale(20), || {
            std::hint::black_box(a.matmul_mt(&b, 1));
        });
        let par = bench(
            &format!("matmul/{m}x{k}x{n}/t{par_threads}"),
            scale(3),
            scale(20),
            || {
                std::hint::black_box(a.matmul_mt(&b, par_threads));
            },
        );
        let speedup = serial.p50 / par.p50;
        println!("   {m}x{k}x{n}: {speedup:.2}x speedup at {par_threads} threads");
        records.push(Json::obj(vec![
            ("kind", Json::str("matmul")),
            ("shape", Json::str(format!("{m}x{k}x{n}"))),
            ("threads", Json::num(par_threads as f64)),
            ("t1_p50_s", Json::num(serial.p50)),
            ("tn_p50_s", Json::num(par.p50)),
            ("speedup", Json::num(speedup)),
        ]));
    }

    // 6b. Encoder train + eval steps, tokens/sec at batch 8–32.
    for &bsz in &[8usize, 16, 32] {
        for step_kind in [StepKind::Train, StepKind::Eval] {
            let sspec = ArtifactSpec {
                step: step_kind,
                model: "tiny".into(),
                adapter: "metatt4d".into(),
                rank: 8,
                classes: 2,
                tasks: 1,
                batch: bsz,
                seq: dims.max_seq,
            };
            let b1 = RefBackend::with_threads(1)?;
            let bn = RefBackend::with_threads(par_threads)?;
            let entry = b1.entry(&sspec)?;
            let frozen = std::sync::Arc::new(assemble_frozen(&entry, None, model)?);
            let ds = TaskId::MrpcSyn.generate_at(bsz, bsz, 1, dims.max_seq, dims.vocab);
            let sbatch = metatt::data::Batcher::new(bsz).eval(&ds).remove(0);
            let params = spec8.init_params(&mut rng);
            let kind_name = match step_kind {
                StepKind::Train => "train",
                _ => "eval",
            };
            let run = |backend: &RefBackend, tag: &str| -> anyhow::Result<Stats> {
                let runner = backend.bind(&sspec, &frozen)?;
                Ok(bench(
                    &format!("{kind_name}-step/b{bsz}/{tag}"),
                    scale(3),
                    scale(20),
                    || match step_kind {
                        StepKind::Train => {
                            std::hint::black_box(
                                runner.run_train(&params, &sbatch, 0, 4.0).unwrap(),
                            );
                        }
                        _ => {
                            std::hint::black_box(
                                runner.run_eval(&params, &sbatch, 0, 4.0).unwrap(),
                            );
                        }
                    },
                ))
            };
            let s1 = run(&b1, "t1")?;
            let sn = run(&bn, &format!("t{par_threads}"))?;
            let toks = (bsz * dims.max_seq) as f64;
            let speedup = s1.p50 / sn.p50;
            println!(
                "   {kind_name} b{bsz}: {:.0} tok/s -> {:.0} tok/s ({speedup:.2}x)",
                toks / s1.p50,
                toks / sn.p50
            );
            records.push(Json::obj(vec![
                ("kind", Json::str(format!("{kind_name}-step"))),
                ("batch", Json::num(bsz as f64)),
                ("seq", Json::num(dims.max_seq as f64)),
                ("threads", Json::num(par_threads as f64)),
                ("t1_tokens_per_s", Json::num(toks / s1.p50)),
                ("tn_tokens_per_s", Json::num(toks / sn.p50)),
                ("speedup", Json::num(speedup)),
            ]));
        }
    }

    let host_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0);
    let doc = Json::obj(vec![
        ("bench", Json::str("hotpath_micro/threading")),
        ("host_parallelism", Json::num(host_threads as f64)),
        ("threads", Json::num(par_threads as f64)),
        ("smoke", Json::Bool(smoke)),
        ("records", Json::Arr(records)),
    ]);
    println!();
    save_record("pr2", &doc)?;

    // ---- 7. Zero-allocation hot path (PR 3): per-phase timing + allocs. --
    // Single-thread, tiny/metatt4d — the configuration the allocation
    // contract is pinned at. `arena_speedup` compares the pooled hot path
    // against the allocate-per-intermediate reference mode on identical
    // math (bit-identical results), isolating the allocator/memset cost.
    println!("== 7. zero-allocation hot path (PR 3): phases + allocation counts ==");
    let mut pr3: Vec<Json> = Vec::new();
    let tspec = ArtifactSpec {
        step: StepKind::Train,
        model: "tiny".into(),
        adapter: "metatt4d".into(),
        rank: 8,
        classes: 2,
        tasks: 1,
        batch: 16,
        seq: dims.max_seq,
    };
    let espec = ArtifactSpec { step: StepKind::Eval, ..tspec.clone() };
    let entry7 = RefBackend::with_config(1, true)?.entry(&tspec)?;
    let frozen7 = std::sync::Arc::new(assemble_frozen(&entry7, None, model)?);
    let params7 = spec8.init_params(&mut rng);
    // (tag, fwd+bwd p50 seconds, train allocs/step) per arena mode.
    let mut phase_stats: Vec<(String, f64, u64)> = Vec::new();
    for arena in [true, false] {
        let b = RefBackend::with_config(1, arena)?;
        let train7 = b.bind(&tspec, &frozen7)?;
        let eval7 = b.bind(&espec, &frozen7)?;
        // Warm the arenas so steady state is what gets measured.
        for _ in 0..2 {
            let (_, g) = train7.run_train(&params7, batch, 0, 4.0)?;
            train7.recycle(g);
            std::hint::black_box(eval7.run_eval(&params7, batch, 0, 4.0)?);
        }
        let tag = if arena { "arena" } else { "no-arena" };
        let fwd = bench(&format!("pr3/fwd-eval/{tag}"), scale(3), scale(25), || {
            std::hint::black_box(eval7.run_eval(&params7, batch, 0, 4.0).unwrap());
        });
        let fwdbwd = bench(&format!("pr3/fwd+bwd-train/{tag}"), scale(3), scale(25), || {
            let (loss, g) = train7.run_train(&params7, batch, 0, 4.0).unwrap();
            std::hint::black_box(loss);
            train7.recycle(g);
        });
        let train_allocs = count_allocs(|| {
            let (_, g) = train7.run_train(&params7, batch, 0, 4.0).unwrap();
            train7.recycle(g);
        });
        let eval_allocs = count_allocs(|| {
            std::hint::black_box(eval7.run_eval(&params7, batch, 0, 4.0).unwrap());
        });
        println!(
            "   {tag}: fwd {} | fwd+bwd {} | bwd≈{} | allocs/step: train {} eval {}",
            Stats::fmt_time(fwd.p50),
            Stats::fmt_time(fwdbwd.p50),
            Stats::fmt_time((fwdbwd.p50 - fwd.p50).max(0.0)),
            train_allocs,
            eval_allocs
        );
        phase_stats.push((tag.to_string(), fwdbwd.p50, train_allocs));
        pr3.push(Json::obj(vec![
            ("phase", Json::str("fwd")),
            ("mode", Json::str(tag)),
            ("p50_s", Json::num(fwd.p50)),
            ("allocs_per_step", Json::num(eval_allocs as f64)),
        ]));
        pr3.push(Json::obj(vec![
            ("phase", Json::str("fwd+bwd")),
            ("mode", Json::str(tag)),
            ("p50_s", Json::num(fwdbwd.p50)),
            ("bwd_approx_s", Json::num((fwdbwd.p50 - fwd.p50).max(0.0))),
            ("allocs_per_step", Json::num(train_allocs as f64)),
        ]));
    }
    let arena_speedup = phase_stats[1].1 / phase_stats[0].1;
    println!(
        "   arena speedup on fwd+bwd: {arena_speedup:.2}x (allocs/step {} -> {})",
        phase_stats[1].2, phase_stats[0].2
    );

    // Adapter phase: the fused serving apply chain (the α=1 AOT shape).
    let apply_spec7 = backend.apply_spec("metatt4d", 8)?;
    let apply_entry7 = backend.entry(&apply_spec7)?;
    let b_apply = RefBackend::with_config(1, true)?;
    let apply_runner7 = b_apply.bind(&apply_spec7, &Default::default())?;
    let apply_inputs: Vec<Tensor> = apply_entry7
        .inputs
        .iter()
        .map(|io| Tensor::randn(&io.shape, 0.5, &mut rng))
        .collect();
    std::hint::black_box(apply_runner7.run_raw(&apply_inputs)?); // warm the arena
    let adapter_stats = bench("pr3/adapter-apply", scale(3), scale(25), || {
        std::hint::black_box(apply_runner7.run_raw(&apply_inputs).unwrap());
    });
    pr3.push(Json::obj(vec![
        ("phase", Json::str("adapter")),
        ("mode", Json::str("arena")),
        ("p50_s", Json::num(adapter_stats.p50)),
    ]));

    // Optimizer phase: one AdamW update over the adapter's flat params.
    let mut flat: Vec<f32> = params7.iter().flat_map(|t| t.data().to_vec()).collect();
    let gflat: Vec<f32> = flat.iter().map(|&x| 0.01 * x + 1e-4).collect();
    let mut opt = AdamW::new(flat.len(), 0.01);
    let opt_stats = bench("pr3/optimizer-adamw", scale(3), scale(50), || {
        opt.step(&mut flat, &gflat, 1e-3);
        std::hint::black_box(flat[0]);
    });
    let opt_allocs = count_allocs(|| opt.step(&mut flat, &gflat, 1e-3));
    pr3.push(Json::obj(vec![
        ("phase", Json::str("optimizer")),
        ("mode", Json::str("in-place")),
        ("p50_s", Json::num(opt_stats.p50)),
        ("allocs_per_step", Json::num(opt_allocs as f64)),
    ]));

    let pr3_doc = Json::obj(vec![
        ("bench", Json::str("hotpath_micro/zero-alloc")),
        ("smoke", Json::Bool(smoke)),
        ("arena_speedup_fwd_bwd", Json::num(arena_speedup)),
        ("records", Json::Arr(pr3)),
    ]);
    save_record("pr3", &pr3_doc)?;

    // ---- 8. Packed register-tiled GEMM (PR 4) vs the PR 3 blocked kernel.
    // Two gates ride on the measurement: the packed kernel must reproduce
    // the retired blocked kernel bit-for-bit (identical per-element
    // k-ascending accumulation), and the recorded `packed_speedup` tracks
    // the register-tiling win per shape at 1 and N threads.
    println!("\n== 8. packed GEMM (PR 4): GFLOP/s + speedup vs the PR 3 blocked kernel ==");
    let mut pr4: Vec<Json> = Vec::new();
    let mut packs = PackScratch::new();
    for &(m, k, n) in &[
        (256usize, 256usize, 256usize),
        (512, 512, 512),
        (768, 256, 768),
        (1024, 256, 64), // skinny adapter-projection shape
    ] {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let mut c_packed = vec![0.0f32; m * n];
        let mut c_blocked = vec![0.0f32; m * n];
        for threads in [1usize, par_threads] {
            c_packed.fill(0.0);
            matmul_into(a.data(), b.data(), &mut c_packed, m, k, n, threads, &mut packs);
            c_blocked.fill(0.0);
            pr3_blocked_matmul(a.data(), b.data(), &mut c_blocked, m, k, n, threads);
            assert!(
                c_packed.iter().zip(&c_blocked).all(|(x, y)| x.to_bits() == y.to_bits()),
                "packed kernel drifted from the PR 3 blocked kernel ({m}x{k}x{n}, t{threads})"
            );
            let packed = bench(
                &format!("packed/{m}x{k}x{n}/t{threads}"),
                scale(3),
                scale(15),
                || {
                    c_packed.fill(0.0);
                    matmul_into(a.data(), b.data(), &mut c_packed, m, k, n, threads, &mut packs);
                    std::hint::black_box(&c_packed);
                },
            );
            let blocked = bench(
                &format!("blocked/{m}x{k}x{n}/t{threads}"),
                scale(3),
                scale(15),
                || {
                    c_blocked.fill(0.0);
                    pr3_blocked_matmul(a.data(), b.data(), &mut c_blocked, m, k, n, threads);
                    std::hint::black_box(&c_blocked);
                },
            );
            let flops = 2.0 * (m * k * n) as f64;
            let speedup = blocked.p50 / packed.p50;
            println!(
                "   {m}x{k}x{n} t{threads}: {:.2} GFLOP/s packed vs {:.2} blocked ({speedup:.2}x)",
                flops / packed.p50 / 1e9,
                flops / blocked.p50 / 1e9
            );
            pr4.push(Json::obj(vec![
                ("shape", Json::str(format!("{m}x{k}x{n}"))),
                ("threads", Json::num(threads as f64)),
                ("packed_gflops", Json::num(flops / packed.p50 / 1e9)),
                ("blocked_gflops", Json::num(flops / blocked.p50 / 1e9)),
                ("packed_speedup", Json::num(speedup)),
            ]));
        }
    }
    let pr4_doc = Json::obj(vec![
        ("bench", Json::str("hotpath_micro/packed-gemm")),
        ("threads", Json::num(par_threads as f64)),
        ("smoke", Json::Bool(smoke)),
        ("records", Json::Arr(pr4)),
    ]);
    save_record("pr4", &pr4_doc)?;

    // ---- 9. Quantized serving dtypes (PR 7). -----------------------------
    // The serving read path binds packed frozen panels + packed folded
    // adapter factors at a storage dtype chosen per bind (accumulation is
    // always f32). Byte totals come straight from the packed buffers, so
    // `weight_gb_per_s` is the effective weight-stream rate of one serving
    // tick — the number that should rise as the dtype shrinks once the
    // tick is memory-bound.
    println!("\n== 9. quantized serving dtypes (PR 7): bytes + ticks/s per dtype ==");
    let tasks9 = 3usize;
    let dims9 = model.dims(tasks9);
    let spec9 = ArtifactSpec {
        step: StepKind::Eval,
        model: "tiny".into(),
        adapter: "metatt4p1d".into(),
        rank: 8,
        classes: 2,
        tasks: tasks9,
        batch: 1,
        seq: dims9.max_seq,
    };
    let b9 = RefBackend::with_config(1, true)?;
    let entry9 = b9.entry(&spec9)?;
    let frozen9 = std::sync::Arc::new(assemble_frozen(&entry9, None, model)?);
    let aspec9 = AdapterSpec::new(
        AdapterKind::MetaTt(MetaTtKind::FourPlusOneD),
        8,
        2.0,
        dims9,
    );
    let tt9 = aspec9.build_metatt_with(&mut rng, None);
    let dense9 = tt9.fold_for_serving(0);
    let tokens9 = vec![3i32; dims9.max_seq];
    let mut pr7: Vec<Json> = Vec::new();
    let mut bytes_by_kind: Vec<(DtypeKind, usize)> = Vec::new();
    for kind in [DtypeKind::F32, DtypeKind::Bf16, DtypeKind::I8] {
        let pairs9: Vec<Vec<FoldedPairPacked>> = dense9
            .iter()
            .map(|row| row.iter().map(|(a, b)| FoldedPairPacked::pack(a, b, kind)).collect())
            .collect();
        let fold_bytes: usize = pairs9.iter().flatten().map(|p| p.bytes()).sum();
        let frozen_bytes = packed_frozen_bytes(&pack_frozen_weights(&frozen9, kind));
        let total_bytes = frozen_bytes + fold_bytes;
        let step9 = b9.bind_serve(&spec9, &frozen9, kind)?;
        let mut out9 = vec![0f32; 2];
        step9.run_serve_packed(&pairs9, &tokens9, 0, &mut out9)?; // warm the arena
        let s = bench(&format!("serve-tick/{}", kind.name()), scale(3), scale(30), || {
            step9.run_serve_packed(&pairs9, &tokens9, 0, &mut out9).unwrap();
            std::hint::black_box(&out9);
        });
        let ticks_per_s = 1.0 / s.p50;
        let gb_per_s = total_bytes as f64 / s.p50 / 1e9;
        println!(
            "   {:>4}: {:.1} KiB packed weights, {:.0} ticks/s, {:.2} GB/s weight stream",
            kind.name(),
            total_bytes as f64 / 1024.0,
            ticks_per_s,
            gb_per_s
        );
        pr7.push(Json::obj(vec![
            ("dtype", Json::str(kind.name())),
            ("frozen_packed_bytes", Json::num(frozen_bytes as f64)),
            ("folded_packed_bytes", Json::num(fold_bytes as f64)),
            ("total_packed_bytes", Json::num(total_bytes as f64)),
            ("tick_p50_s", Json::num(s.p50)),
            ("ticks_per_s", Json::num(ticks_per_s)),
            ("weight_gb_per_s", Json::num(gb_per_s)),
        ]));
        bytes_by_kind.push((kind, total_bytes));
    }
    assert!(
        bytes_by_kind[1].1 < bytes_by_kind[0].1 && bytes_by_kind[2].1 < bytes_by_kind[1].1,
        "quantized serving must move fewer weight bytes: f32 {} / bf16 {} / int8 {}",
        bytes_by_kind[0].1,
        bytes_by_kind[1].1,
        bytes_by_kind[2].1
    );
    let pr7_doc = Json::obj(vec![
        ("bench", Json::str("hotpath_micro/serve-dtypes")),
        ("smoke", Json::Bool(smoke)),
        ("records", Json::Arr(pr7)),
    ]);
    save_record("pr7", &pr7_doc)?;

    // ---- 10. Observability overhead (PR 10). -----------------------------
    // Three numbers CI tracks: (a) the serving tick with its full lifecycle
    // hook pattern (admit / tick-start / tick-end / response-written) armed
    // vs unarmed — acceptance pins the armed p50 within 5%; (b) raw tracer
    // throughput, armed (ring record) and disarmed (one relaxed load); and
    // (c) exact loss accounting under deliberate multi-thread ring pressure
    // — recorded + dropped must equal the offered load.
    println!("\n== 10. observability (PR 10): hook overhead + tracer throughput ==");
    let mut pr10: Vec<Json> = Vec::new();
    let pairs10: Vec<Vec<FoldedPairPacked>> = dense9
        .iter()
        .map(|row| {
            row.iter().map(|(a, b)| FoldedPairPacked::pack(a, b, DtypeKind::F32)).collect()
        })
        .collect();
    let step10 = b9.bind_serve(&spec9, &frozen9, DtypeKind::F32)?;
    let mut out10 = vec![0f32; 2];
    step10.run_serve_packed(&pairs10, &tokens9, 0, &mut out10)?; // warm the arena
    let mut tick_p50 = Vec::new();
    for armed in [false, true] {
        let obs = Obs::new(armed);
        let tag = if armed { "armed" } else { "unarmed" };
        // Identical code on both arms — the only difference is whether the
        // hooks fall through their relaxed load or record into a ring — so
        // the ratio isolates the tracing cost of one serving tick.
        let s = bench(&format!("obs/serve-tick/{tag}"), scale(3), scale(30), || {
            let t0 = obs.now_us();
            obs.event_at(t0, EventCode::Admit, 1, 0);
            obs.event_at(t0, EventCode::TickStart, 0, 0);
            step10.run_serve_packed(&pairs10, &tokens9, 0, &mut out10).unwrap();
            obs.event_at(obs.now_us(), EventCode::TickEnd, 0, t0);
            obs.event(EventCode::ResponseWritten, 1, 0);
            std::hint::black_box(&out10);
        });
        tick_p50.push(s.p50);
        pr10.push(Json::obj(vec![
            ("kind", Json::str("serve-tick")),
            ("mode", Json::str(tag)),
            ("p50_s", Json::num(s.p50)),
            ("ticks_per_s", Json::num(1.0 / s.p50)),
        ]));
    }
    let armed_overhead = tick_p50[1] / tick_p50[0];
    println!(
        "   armed/unarmed tick p50 ratio: {armed_overhead:.3} (acceptance: within 5%)"
    );

    // 10b. Raw tracer throughput: a single thread hammering one hook.
    const EVENTS_PER_ITER: u64 = 100_000;
    let obs_on = Obs::with_rings(true, 1, 1 << 16);
    let rec = bench("obs/event/armed", scale(2), scale(10), || {
        for i in 0..EVENTS_PER_ITER {
            obs_on.event_at(i, EventCode::Admit, std::hint::black_box(i), 0);
        }
    });
    let obs_off = Obs::new(false);
    let off = bench("obs/event/disarmed", scale(2), scale(10), || {
        for i in 0..EVENTS_PER_ITER {
            obs_off.event(EventCode::Admit, std::hint::black_box(i), 0);
        }
    });
    let armed_events_per_s = EVENTS_PER_ITER as f64 / rec.p50;
    let disarmed_events_per_s = EVENTS_PER_ITER as f64 / off.p50;
    println!(
        "   tracer: {:.1} M events/s armed, {:.1} M hook calls/s disarmed",
        armed_events_per_s / 1e6,
        disarmed_events_per_s / 1e6
    );

    // 10c. Loss accounting under ring pressure: more threads than rings,
    // rings far smaller than the offered load. Everything not recorded must
    // be counted as dropped — the bench asserts the invariant and records
    // the observed loss so ring-sizing regressions show up in the numbers.
    let pressure_threads = 4u64;
    let per_thread = if smoke { 20_000u64 } else { 200_000u64 };
    let obs_pressure = std::sync::Arc::new(Obs::with_rings(true, 2, 1024));
    std::thread::scope(|scope| {
        for t in 0..pressure_threads {
            let obs = std::sync::Arc::clone(&obs_pressure);
            scope.spawn(move || {
                for i in 0..per_thread {
                    obs.event_at(i, EventCode::Admit, t, i);
                }
            });
        }
    });
    let offered = pressure_threads * per_thread;
    let recorded = obs_pressure.tracer().recorded();
    let dropped = obs_pressure.tracer().dropped();
    assert_eq!(
        recorded + dropped,
        offered,
        "ring pressure must never lose events silently"
    );
    println!(
        "   ring pressure ({pressure_threads} threads -> 2x1024 rings): \
         {offered} offered, {recorded} recorded, {dropped} dropped (accounted exactly)"
    );

    let pr10_doc = Json::obj(vec![
        ("bench", Json::str("hotpath_micro/observability")),
        ("smoke", Json::Bool(smoke)),
        ("armed_tick_overhead", Json::num(armed_overhead)),
        ("armed_events_per_s", Json::num(armed_events_per_s)),
        ("disarmed_hook_calls_per_s", Json::num(disarmed_events_per_s)),
        ("pressure_offered", Json::num(offered as f64)),
        ("pressure_recorded", Json::num(recorded as f64)),
        ("pressure_dropped", Json::num(dropped as f64)),
        ("records", Json::Arr(pr10)),
    ]);
    save_record("pr10", &pr10_doc)?;
    Ok(())
}
