//! Table 2 — multi-task learning: LoRA vs MetaTT-4D vs MetaTT-(4+1)D
//! jointly trained on the CoLA/MRPC/RTE analogues.
//!
//! Reproduces the paper's protocol (§3.2): ≤5000 train / ≤500 eval per
//! task, best *mean-across-tasks* epoch, seeds aggregated as mean(stderr).
//! Claims under test: (4+1)D ≥ 4D at ~200 extra params; both are far
//! below LoRA's parameter count; LoRA remains a strong single-adapter
//! multi-task baseline.
//!
//! Env knobs: METATT_FULL=1 (3 seeds, 10 epochs, full caps), METATT_SEEDS,
//! METATT_EPOCHS, METATT_CAP.

use metatt::adapters::{AdapterKind, AdapterSpec};
use metatt::bench::{paper_fmt, Table};
use metatt::config::ModelPreset;
use metatt::coordinator::{results, run_mtl, MtlConfig};
use metatt::data::TaskId;
use metatt::metrics::mean_stderr;
use metatt::runtime::{backend_from_env, checkpoint_path};
use metatt::tt::MetaTtKind;
use metatt::util::json::Json;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let full = std::env::var("METATT_FULL").is_ok();
    let n_seeds = env_usize("METATT_SEEDS", if full { 3 } else { 1 });
    let epochs = env_usize("METATT_EPOCHS", if full { 10 } else { 5 });
    let cap = env_usize("METATT_CAP", if full { 5000 } else { 800 });
    let seeds: &[u64] = &[33305628, 2025, 42][..n_seeds];

    let model = ModelPreset::Tiny;
    let tasks = [TaskId::ColaSyn, TaskId::MrpcSyn, TaskId::RteSyn];
    let backend = backend_from_env()?;
    let ckpt = checkpoint_path(model);
    let ckpt = ckpt.exists().then_some(ckpt);
    let dims = model.dims(tasks.len());

    let methods = [
        (AdapterKind::LoRa, 8),
        (AdapterKind::MetaTt(MetaTtKind::FourD), 8),
        (AdapterKind::MetaTt(MetaTtKind::FourPlusOneD), 8),
    ];

    let mut table = Table::new(
        "Table 2 (reproduction): multi-task joint training (tiny encoder)",
        &["method", "rank", "params", "cola_syn", "mrpc_syn", "rte_syn", "avg"],
    );
    for (kind, rank) in methods {
        let spec = AdapterSpec::new(kind, rank, 2.0, dims);
        let mut per_task: Vec<Vec<f64>> = vec![Vec::new(); tasks.len()];
        let mut means = Vec::new();
        for &seed in seeds {
            let mut cfg = MtlConfig::default();
            cfg.train.epochs = epochs;
            cfg.train.seed = seed;
            cfg.per_task_cap = cap;
            cfg.eval_cap = 400;
            let res = run_mtl(backend.as_ref(), model, &spec, &tasks, &cfg, ckpt.as_deref())?;
            for (i, m) in res.best_per_task.iter().enumerate() {
                per_task[i].push(m * 100.0);
            }
            means.push(res.best_mean * 100.0);
            results::append_record(
                "table2",
                &Json::obj(vec![
                    ("method", Json::str(spec.kind.name())),
                    ("seed", Json::num(seed as f64)),
                    ("params", Json::num(spec.param_count() as f64)),
                    ("best_mean", Json::num(res.best_mean)),
                ]),
            );
        }
        let mut cells = vec![
            spec.kind.name(),
            rank.to_string(),
            spec.param_count().to_string(),
        ];
        for vals in &per_task {
            let (m, e) = mean_stderr(vals);
            cells.push(paper_fmt(m, e));
        }
        let (m, e) = mean_stderr(&means);
        cells.push(paper_fmt(m, e));
        println!("[table2] {:<12} avg {}", spec.kind.name(), paper_fmt(m, e));
        table.row(cells);
    }
    table.emit("table2_multitask");

    println!(
        "\nPaper Table 2 (RoBERTa-Base): LoRA 295k → 74.9(2) | MetaTT-4D 13.2k → \
         70.3(8) | MetaTT-(4+1)D 13.4k → 70.5(8).\nShape claim: (4+1)D ≥ 4D with \
         ~200 extra params; LoRA ahead at ~20x the parameters."
    );
    Ok(())
}
