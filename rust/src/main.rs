//! `metatt` — the L3 coordinator launcher.
//!
//! Subcommands:
//!   info                         inspect the artifact manifest & runtime
//!   pretrain  --model tiny       MLM-pretrain the frozen backbone
//!   train     --task mrpc_syn    single-task fine-tuning (Table-1 protocol)
//!   mtl       --tasks a,b,c      joint multi-task training (Table-2)
//!   dmrg      --task mrpc_syn    AdamW + DMRG rank-annealing (Figs 2/6)
//!   serve     --requests N       multi-task serving engine + load generator
//!
//! Every run appends a JSONL record under results/.

use anyhow::{anyhow, bail, Result};
use metatt::adapters::{AdapterKind, AdapterSpec};
use metatt::cli::Args;
use metatt::config::{ModelPreset, TrainConfig};
use metatt::coordinator::{self, results, DmrgConfig, MtlConfig, PretrainConfig};
use metatt::data::TaskId;
use metatt::runtime::{checkpoint_path, make_backend, Backend, BackendKind};
use metatt::tt::{InitStrategy, RankSchedule};
use metatt::util::json::Json;
use std::path::Path;

const USAGE: &str = "\
metatt <command> [options]

commands:
  info       show backend status (and artifact manifest, pjrt backend)
  pretrain   MLM-pretrain the frozen backbone
             --model tiny|small|base_sim [--steps N] [--lr F] [--seed N]
  train      single-task fine-tuning (Table-1 protocol)
             --task T [--adapter A] [--rank R] [--alpha F] [--epochs N]
             [--batch N] [--lr F] [--seed N] [--init ze-id-id-id]
             [--train-cap N] [--eval-cap N] [--warmup-ratio F]
             [--grad-clip F] [--save-adapter FILE] [--no-checkpoint]
  mtl        joint multi-task training with a task core (Table-2)
             --tasks a,b,c [--adapter metatt4p1d] [--rank R] [--alpha F]
             [--epochs N] [--batch N] [--lr F] [--seed N] [--train-cap N]
             [--eval-cap N] [--warmup-ratio F] [--grad-clip F]
             [--save-adapter FILE] [--no-checkpoint]
  dmrg       AdamW + DMRG rank-annealing (Figs 2/6)
             --task T [--adapter metatt5d] [--start-rank 10]
             [--schedule e:r,e:r,...] [--alpha F] [--epochs N] [--seed N]
  seq        sequential A->B->A transfer / forgetting measurement
             --task-a A --task-b B [--adapter A] [--rank R] [--alpha F]
             [--epochs N] [--batch N] [--lr F] [--seed N] [--no-checkpoint]
  serve      multi-task serving engine: queue -> EDF batcher (deadlines,
             priorities, overload shedding) -> per-task folded-adapter
             cache -> workers; in-process closed-loop load generator by
             default, records BENCH_pr5.json
             [--requests N] [--clients C] [--num-tasks T] [--classes K]
             [--adapter A] [--rank R] [--alpha F] [--checkpoint FILE]
             [--max-batch B] [--batch-deadline-ms MS] [--serve-workers W]
             [--queue-cap N] [--cache-cap BYTES] [--mix w1,w2,...]
             [--serve-dtype f32|bf16|int8]   storage dtype for packed frozen
                              panels + folded adapter factors (accumulation
                              stays f32; default f32 = bit-exact)
             [--think-us U] [--seed N] [--no-checkpoint]
             [--deadline-ms MS] [--priority P]   per-request deadline/class
             modes (mutually exclusive, default = in-process load gen):
             --listen ADDR    TCP front-end (MTS1 wire protocol); stops
                              after --serve-secs N seconds (0 = until
                              killed), then drains gracefully
                              [--drain-grace-ms MS]  post-shutdown grace
                              for half-received frames (default 1000, > 0)
             --connect ADDR   closed-loop TCP clients against a listener
                              [--net-timeout-ms MS]  socket read/write
                              timeout (default 30000; 0 = block forever)
             --overload       closed-loop capacity probe, then open-loop
                              Poisson arrivals at --overload-mults m,m,...
                              times capacity (--overload-requests arrivals
                              per level); records BENCH_pr6.json — or, with
                              faults armed, a faulted-vs-clean twin sweep
                              into BENCH_pr8.json
             --trace          arm the tracing + metrics layer (also env
                              METATT_TRACE=1); unarmed, every hook is one
                              relaxed atomic load and the warmed serve
                              tick stays zero-allocation
             [--trace-out FILE]    write the recorded spans as Chrome
                              trace-event JSON on exit (implies --trace;
                              open in chrome://tracing or Perfetto)
             [--metrics-out FILE]  rewrite a JSON metrics snapshot once a
                              second while serving, and once on exit
             --connect ... --stat  after the load run, scrape the server's
                              STAT admin frame (live Prometheus-style
                              metrics snapshot) and print it
             --faults SPEC    arm deterministic fault injection (also env
                              METATT_FAULTS), e.g. \"worker_panic@tick=17,
                              net_drop@frame=3,slow_tick=5ms@p=0.01,
                              torn_write@save=2,shard_down@tick=4,
                              shard_wedge=5ms@p=0.01,seed=1\"
             --shards N       sharded topology: N engines behind one
                              supervised router (heartbeat health, failover,
                              work stealing); works with --listen and the
                              in-process load generator
                              [--replicas R]   same-adapter replicas per
                              group (R must divide N; default N = one group)
                              [--route affinity|rr]  replica pick within a
                              group (affinity keeps per-task folds hot)
             --topology       sharded capacity sweep over layouts of the
                              worker budget (4 workers -> 1x4, 2x2, 4x1),
                              then a kill-one-shard-mid-run goodput
                              retention probe on the smallest multi-shard
                              layout; records BENCH_pr9.json
  run        config-file-driven run
             --config configs/foo.toml

options shared:
  --backend ref|pjrt   execution backend (default ref: hermetic pure-rust
                       CPU; pjrt needs `--features pjrt` + `make artifacts`)
  --threads N          worker threads for the ref backend's step execution
                       (default: METATT_THREADS or host parallelism; results
                       are bit-identical for any N)
  --model PRESET       model preset (default tiny)
  --artifacts DIR      HLO artifact dir for the pjrt backend (default artifacts)
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const OPTS: &[&str] = &[
    "task-a", "task-b", "config", "backend", "threads",
    "model", "steps", "lr", "seed", "task", "tasks", "adapter", "rank", "alpha",
    "epochs", "batch", "init", "train-cap", "eval-cap", "artifacts", "schedule",
    "start-rank", "requests", "warmup-ratio", "grad-clip",
    // serve engine + load generator, and the adapter-checkpoint writer
    "clients", "num-tasks", "classes", "checkpoint", "max-batch",
    "batch-deadline-ms", "serve-workers", "queue-cap", "cache-cap", "mix",
    "think-us", "save-adapter", "serve-dtype",
    // serve front-end modes: TCP listener / TCP client / overload sweep
    "listen", "connect", "serve-secs", "deadline-ms", "priority",
    "overload-mults", "overload-requests",
    // fault injection + robustness knobs
    "faults", "net-timeout-ms", "drain-grace-ms",
    // sharded serving topology
    "shards", "replicas", "route",
    // observability exports
    "trace-out", "metrics-out",
];
const FLAGS: &[&str] =
    &["help", "no-checkpoint", "verbose", "overload", "topology", "trace", "stat"];

fn run() -> Result<()> {
    let args = Args::from_env(OPTS, FLAGS).map_err(|e| anyhow!(e))?;
    if args.flag("help") || args.command.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    match args.command.as_str() {
        "info" => cmd_info(&args),
        "pretrain" => cmd_pretrain(&args),
        "train" => cmd_train(&args),
        "mtl" => cmd_mtl(&args),
        "seq" => cmd_seq(&args),
        "dmrg" => cmd_dmrg(&args),
        "serve" => cmd_serve(&args),
        "run" => cmd_run(&args),
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

/// Resolve the worker-thread budget: `--threads` wins, then a TOML
/// `[runtime] threads` (run command), then `METATT_THREADS` / host auto.
fn threads_for(args: &Args, toml_threads: Option<usize>) -> Result<usize> {
    let explicit = args.usize_opt("threads").map_err(|e| anyhow!(e))?;
    metatt::util::threadpool::resolve_threads(explicit.or(toml_threads))
        .map_err(|e| anyhow!(e))
}

/// Build the execution backend. The kind comes from `--backend` (or
/// `default_kind` when the flag is absent — the `run` command passes the
/// TOML's choice); the thread budget from `--threads` > `toml_threads` >
/// env/auto.
fn backend_with(
    args: &Args,
    default_kind: BackendKind,
    toml_threads: Option<usize>,
) -> Result<Box<dyn Backend>> {
    let kind = match args.get("backend") {
        Some(name) => BackendKind::from_name(name).map_err(|e| anyhow!(e))?,
        None => default_kind,
    };
    let artifacts = args.str_or("artifacts", "artifacts");
    make_backend(kind, Path::new(&artifacts), threads_for(args, toml_threads)?)
}

/// Backend selected by `--backend` (default ref: the hermetic pure-rust
/// reference backend).
fn backend_for(args: &Args) -> Result<Box<dyn Backend>> {
    backend_with(args, BackendKind::Ref, None)
}

/// `metatt run --config configs/foo.toml` — config-file-driven single run.
fn cmd_run(args: &Args) -> Result<()> {
    let path = args
        .get("config")
        .or_else(|| args.positional.first().map(|s| s.as_str()))
        .ok_or_else(|| anyhow!("run needs --config <file.toml>"))?;
    let cfg = metatt::config::ExperimentConfig::from_toml(Path::new(path))
        .map_err(|e| anyhow!(e))?;
    // The TOML picks the backend and threads; explicit flags override.
    let backend = backend_with(args, cfg.backend, cfg.threads)?;
    let ckpt = ckpt_for(args, cfg.model);
    let spec = cfg.adapter_spec();
    if cfg.tasks.len() > 1 {
        let tasks: Vec<TaskId> = cfg
            .tasks
            .iter()
            .map(|n| TaskId::from_name(n))
            .collect::<Result<_, _>>()
            .map_err(|e| anyhow!(e))?;
        let mut mcfg = MtlConfig::default();
        mcfg.train = cfg.train.clone();
        mcfg.alpha = cfg.alpha;
        let res = coordinator::run_mtl(
            backend.as_ref(), cfg.model, &spec, &tasks, &mcfg, ckpt.as_deref(),
        )?;
        println!("best mean metric: {:.4} {:?}", res.best_mean, res.best_per_task);
    } else {
        let task = TaskId::from_name(&cfg.tasks[0]).map_err(|e| anyhow!(e))?;
        let res = coordinator::run_single_task(
            backend.as_ref(), cfg.model, &spec, task, &cfg.train, cfg.alpha,
            ckpt.as_deref(), None,
        )?;
        println!("best {}: {:.4}", task.info().metric.name(), res.best_metric);
    }
    Ok(())
}

/// `metatt seq --task-a mrpc_syn --task-b rte_syn` — sequential A→B→A
/// transfer with one shared adapter (paper §3.2, forgetting measurement).
fn cmd_seq(args: &Args) -> Result<()> {
    let model = parse_model(args)?;
    let task_a = TaskId::from_name(&args.str_or("task-a", "mrpc_syn")).map_err(|e| anyhow!(e))?;
    let task_b = TaskId::from_name(&args.str_or("task-b", "rte_syn")).map_err(|e| anyhow!(e))?;
    let adapter =
        AdapterKind::from_name(&args.str_or("adapter", "metatt4d")).map_err(|e| anyhow!(e))?;
    let rank = args.usize_or("rank", 8).map_err(|e| anyhow!(e))?;
    let alpha = args.f32_or("alpha", 4.0).map_err(|e| anyhow!(e))?;
    let train = train_config(args)?;
    let backend = backend_for(args)?;
    let spec = AdapterSpec::new(adapter, rank, alpha, model.dims(1));
    let ckpt = ckpt_for(args, model);
    let res = coordinator::run_sequential(
        backend.as_ref(), model, &spec, task_a, task_b, &train, alpha, ckpt.as_deref(),
    )?;
    for (i, p) in res.phases.iter().enumerate() {
        println!(
            "phase {} (trained {:>9}):  {}={:.3}  {}={:.3}",
            i + 1,
            p.trained_task.name(),
            task_a.name(),
            p.metric_a,
            task_b.name(),
            p.metric_b
        );
    }
    println!(
        "forgetting gap on {} while training {}: {:+.3}   round-trip gain: {:+.3}\n\
         (paper §3.2: sequential transfer risks catastrophic forgetting — joint \
         training with a task core is the remedy, see `metatt mtl`)",
        task_a.name(),
        task_b.name(),
        res.forgetting_gap,
        res.roundtrip_gain
    );
    results::append_record(
        "sequential",
        &Json::obj(vec![
            ("task_a", Json::str(task_a.name())),
            ("task_b", Json::str(task_b.name())),
            ("adapter", Json::str(spec.kind.name())),
            ("forgetting_gap", Json::num(res.forgetting_gap)),
            ("roundtrip_gain", Json::num(res.roundtrip_gain)),
        ]),
    );
    Ok(())
}

fn parse_model(args: &Args) -> Result<ModelPreset> {
    ModelPreset::from_name(&args.str_or("model", "tiny")).map_err(|e| anyhow!(e))
}

fn train_config(args: &Args) -> Result<TrainConfig> {
    let mut t = TrainConfig::default();
    t.epochs = args.usize_or("epochs", t.epochs).map_err(|e| anyhow!(e))?;
    t.batch_size = args.usize_or("batch", 16).map_err(|e| anyhow!(e))?;
    t.lr = args.f32_or("lr", t.lr).map_err(|e| anyhow!(e))?;
    t.seed = args.u64_or("seed", t.seed).map_err(|e| anyhow!(e))?;
    t.train_cap = args.usize_or("train-cap", t.train_cap).map_err(|e| anyhow!(e))?;
    t.eval_cap = args.usize_or("eval-cap", t.eval_cap).map_err(|e| anyhow!(e))?;
    t.warmup_ratio = args.f32_or("warmup-ratio", t.warmup_ratio).map_err(|e| anyhow!(e))?;
    t.grad_clip = args.f32_or("grad-clip", t.grad_clip).map_err(|e| anyhow!(e))?;
    Ok(t)
}

/// `--save-adapter PATH`: checkpoint trained adapter tensors in the v2
/// (metadata) container so `metatt serve --checkpoint PATH` can validate
/// and serve them. No-op when the flag is absent.
fn save_adapter_if_requested(
    args: &Args,
    spec: &AdapterSpec,
    model: ModelPreset,
    params: &[metatt::tensor::Tensor],
) -> Result<()> {
    let Some(path) = args.get("save-adapter") else {
        return Ok(());
    };
    if matches!(spec.kind, metatt::adapters::AdapterKind::Full) {
        bail!("--save-adapter covers adapter states; full fine-tuning saves through the pretrain checkpoint format");
    }
    let specs = spec.param_specs();
    anyhow::ensure!(
        specs.len() == params.len(),
        "adapter state has {} tensors, layout wants {}",
        params.len(),
        specs.len()
    );
    let meta = metatt::coordinator::checkpoint::CheckpointMeta {
        adapter: spec.kind.name(),
        rank: spec.rank,
        tasks: spec.dims.tasks,
        alpha: spec.alpha,
        model: model.name().to_string(),
        dtype: "f32".to_string(),
    };
    let named: Vec<(String, metatt::tensor::Tensor)> = specs
        .iter()
        .map(|p| p.name.clone())
        .zip(params.iter().cloned())
        .collect();
    metatt::coordinator::checkpoint::save_with_meta(Path::new(path), &meta, &named)
        .map_err(|e| anyhow!(e))?;
    println!(
        "saved adapter checkpoint ({} rank {} over {} tasks) to {path}",
        meta.adapter, meta.rank, meta.tasks
    );
    Ok(())
}

fn ckpt_for(args: &Args, model: ModelPreset) -> Option<std::path::PathBuf> {
    if args.flag("no-checkpoint") {
        return None;
    }
    let p = checkpoint_path(model);
    if p.exists() {
        Some(p)
    } else {
        eprintln!(
            "note: {} not found — using an untrained frozen backbone \
             (run `metatt pretrain --model {}` first for paper-faithful runs)",
            p.display(),
            model.name()
        );
        None
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let backend = backend_for(args)?;
    println!("{}", backend.describe());
    for preset in [ModelPreset::Tiny, ModelPreset::Small, ModelPreset::BaseSim] {
        let p = checkpoint_path(preset);
        println!(
            "checkpoint {:>8}: {}",
            preset.name(),
            if p.exists() { "present" } else { "missing" }
        );
    }
    Ok(())
}

fn cmd_pretrain(args: &Args) -> Result<()> {
    let model = parse_model(args)?;
    let backend = backend_for(args)?;
    let cfg = PretrainConfig {
        steps: args.usize_or("steps", 600).map_err(|e| anyhow!(e))?,
        lr: args.f32_or("lr", 1e-3).map_err(|e| anyhow!(e))?,
        seed: args.u64_or("seed", 1234).map_err(|e| anyhow!(e))?,
        ..Default::default()
    };
    let res = coordinator::pretrain(backend.as_ref(), model, &cfg)?;
    results::append_record(
        "pretrain",
        &Json::obj(vec![
            ("model", Json::str(model.name())),
            ("steps", Json::num(cfg.steps as f64)),
            ("final_loss", Json::num(res.final_loss)),
            (
                "losses",
                Json::Arr(
                    res.losses
                        .iter()
                        .map(|(s, l)| Json::Arr(vec![Json::num(*s as f64), Json::num(*l)]))
                        .collect(),
                ),
            ),
        ]),
    );
    println!("final MLM loss: {:.4}", res.final_loss);
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let model = parse_model(args)?;
    let task = TaskId::from_name(&args.str_or("task", "mrpc_syn")).map_err(|e| anyhow!(e))?;
    let adapter =
        AdapterKind::from_name(&args.str_or("adapter", "metatt4d")).map_err(|e| anyhow!(e))?;
    let rank = args.usize_or("rank", 8).map_err(|e| anyhow!(e))?;
    let alpha = args.f32_or("alpha", 4.0).map_err(|e| anyhow!(e))?;
    let train = train_config(args)?;
    let init = match args.get("init") {
        Some(code) => Some(InitStrategy::from_code(code).map_err(|e| anyhow!(e))?),
        None => None,
    };
    let backend = backend_for(args)?;
    let dims = model.dims(1);
    let spec = AdapterSpec::new(adapter, rank, alpha, dims);
    println!(
        "train {} on {} (rank {}, {} params, alpha {})",
        spec.kind.name(),
        task.name(),
        rank,
        spec.param_count(),
        alpha
    );
    let ckpt = ckpt_for(args, model);
    let res = coordinator::run_single_task(
        backend.as_ref(),
        model,
        &spec,
        task,
        &train,
        alpha,
        ckpt.as_deref(),
        init.as_ref(),
    )?;
    for e in &res.epochs {
        println!(
            "epoch {:>2}  loss {:.4}  {} {:.4}",
            e.epoch,
            e.train_loss,
            task.info().metric.name(),
            e.metric
        );
    }
    println!("best {}: {:.4}", task.info().metric.name(), res.best_metric);
    save_adapter_if_requested(args, &spec, model, &res.params)?;
    results::append_record(
        "train",
        &Json::obj(vec![
            ("model", Json::str(model.name())),
            ("task", Json::str(task.name())),
            ("adapter", Json::str(spec.kind.name())),
            ("rank", Json::num(rank as f64)),
            ("alpha", Json::num(alpha as f64)),
            ("seed", Json::num(train.seed as f64)),
            ("params", Json::num(spec.param_count() as f64)),
            ("best", Json::num(res.best_metric)),
            (
                "curve",
                Json::Arr(res.epochs.iter().map(|e| Json::num(e.metric)).collect()),
            ),
        ]),
    );
    Ok(())
}

fn cmd_mtl(args: &Args) -> Result<()> {
    let model = parse_model(args)?;
    let task_names = args.str_list_or("tasks", &["cola_syn", "mrpc_syn", "rte_syn"]);
    let tasks: Vec<TaskId> = task_names
        .iter()
        .map(|n| TaskId::from_name(n))
        .collect::<Result<_, _>>()
        .map_err(|e| anyhow!(e))?;
    let adapter =
        AdapterKind::from_name(&args.str_or("adapter", "metatt4p1d")).map_err(|e| anyhow!(e))?;
    let rank = args.usize_or("rank", 8).map_err(|e| anyhow!(e))?;
    let mut cfg = MtlConfig::default();
    cfg.train = train_config(args)?;
    cfg.alpha = args.f32_or("alpha", 2.0).map_err(|e| anyhow!(e))?;
    // Paper cap is 5000/task; --train-cap lowers it for quick runs.
    cfg.per_task_cap = cfg.per_task_cap.min(cfg.train.train_cap);
    cfg.eval_cap = cfg.eval_cap.min(cfg.train.eval_cap);
    let backend = backend_for(args)?;
    let dims = model.dims(tasks.len());
    let spec = AdapterSpec::new(adapter, rank, cfg.alpha, dims);
    println!(
        "mtl {} over {:?} ({} params)",
        spec.kind.name(),
        task_names,
        spec.param_count()
    );
    let ckpt = ckpt_for(args, model);
    let res =
        coordinator::run_mtl(backend.as_ref(), model, &spec, &tasks, &cfg, ckpt.as_deref())?;
    for e in &res.epochs {
        println!(
            "epoch {:>2}  loss {:.4}  mean {:.4}  per-task {:?}",
            e.epoch,
            e.train_loss,
            e.mean_metric,
            e.metrics.iter().map(|m| (m * 1000.0).round() / 1000.0).collect::<Vec<_>>()
        );
    }
    println!("best mean metric: {:.4} {:?}", res.best_mean, res.best_per_task);
    save_adapter_if_requested(args, &spec, model, &res.params)?;
    results::append_record(
        "mtl",
        &Json::obj(vec![
            ("model", Json::str(model.name())),
            (
                "tasks",
                Json::Arr(task_names.iter().map(|t| Json::str(t.clone())).collect()),
            ),
            ("adapter", Json::str(spec.kind.name())),
            ("rank", Json::num(rank as f64)),
            ("params", Json::num(spec.param_count() as f64)),
            ("seed", Json::num(cfg.train.seed as f64)),
            ("best_mean", Json::num(res.best_mean)),
            (
                "best_per_task",
                Json::Arr(res.best_per_task.iter().map(|m| Json::num(*m)).collect()),
            ),
        ]),
    );
    Ok(())
}

fn cmd_dmrg(args: &Args) -> Result<()> {
    let model = parse_model(args)?;
    let task = TaskId::from_name(&args.str_or("task", "mrpc_syn")).map_err(|e| anyhow!(e))?;
    let adapter =
        AdapterKind::from_name(&args.str_or("adapter", "metatt5d")).map_err(|e| anyhow!(e))?;
    let mut cfg = DmrgConfig::default();
    cfg.train = train_config(args)?;
    cfg.train.lr = args.f32_or("lr", 5e-4).map_err(|e| anyhow!(e))?;
    cfg.alpha = args.f32_or("alpha", 2.0).map_err(|e| anyhow!(e))?;
    cfg.start_rank = args.usize_or("start-rank", 10).map_err(|e| anyhow!(e))?;
    if let Some(s) = args.get("schedule") {
        cfg.schedule = RankSchedule::parse(s).map_err(|e| anyhow!(e))?;
    }
    let backend = backend_for(args)?;
    let ckpt = ckpt_for(args, model);
    println!(
        "dmrg {} on {}: start rank {}, schedule {:?}",
        adapter.name(),
        task.name(),
        cfg.start_rank,
        cfg.schedule.steps
    );
    let res = coordinator::run_dmrg(backend.as_ref(), model, adapter, task, &cfg, ckpt.as_deref())?;
    for e in &res.epochs {
        println!(
            "epoch {:>2}  loss {:.4}  acc {:.4}  rank {:>2}{}{}",
            e.epoch,
            e.train_loss,
            e.metric,
            e.rank,
            if e.swept { "  [swept" } else { "" },
            if e.swept {
                format!(" drop {:.3}]", e.dropped)
            } else {
                String::new()
            }
        );
    }
    println!(
        "best at final rank {}: {:.4} ({} executables compiled)",
        res.final_rank, res.best_at_final_rank, res.executables_compiled
    );
    results::append_record(
        "dmrg",
        &Json::obj(vec![
            ("model", Json::str(model.name())),
            ("task", Json::str(task.name())),
            ("adapter", Json::str(adapter.name())),
            ("start_rank", Json::num(cfg.start_rank as f64)),
            ("seed", Json::num(cfg.train.seed as f64)),
            ("best_final", Json::num(res.best_at_final_rank)),
            (
                "curve",
                Json::Arr(
                    res.epochs
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("metric", Json::num(e.metric)),
                                ("rank", Json::num(e.rank as f64)),
                                ("swept", Json::Bool(e.swept)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
    );
    Ok(())
}

/// The serve session's observability handle (PR 10): builds the shared
/// [`metatt::obs::Obs`], installs the process-global hook (checkpoint
/// save/load events), runs the once-a-second `--metrics-out` dumper, and
/// on drop — every exit path, including errors — writes the final metrics
/// snapshot and the `--trace-out` Chrome trace.
struct ObsSession {
    obs: std::sync::Arc<metatt::obs::Obs>,
    trace_out: Option<String>,
    metrics_out: Option<String>,
    stop: std::sync::Arc<std::sync::atomic::AtomicBool>,
    dumper: Option<std::thread::JoinHandle<()>>,
}

impl ObsSession {
    fn begin(args: &Args) -> ObsSession {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;
        let trace_out = args.get("trace-out").map(str::to_string);
        let metrics_out = args.get("metrics-out").map(str::to_string);
        let armed = metatt::obs::Obs::armed_from_env(args.flag("trace") || trace_out.is_some());
        let obs = Arc::new(metatt::obs::Obs::new(armed));
        metatt::obs::set_global(Some(Arc::clone(&obs)));
        if armed {
            println!("tracing armed (per-thread ring-buffer spans + metrics registry)");
        }
        let stop = Arc::new(AtomicBool::new(false));
        let dumper = metrics_out.clone().map(|path| {
            let stop = Arc::clone(&stop);
            let obs = Arc::clone(&obs);
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    let _ = std::fs::write(&path, obs.metrics_json());
                    // 100 ms granularity so exit never stalls a full second.
                    for _ in 0..10 {
                        if stop.load(Ordering::Relaxed) {
                            break;
                        }
                        std::thread::sleep(std::time::Duration::from_millis(100));
                    }
                }
            })
        });
        ObsSession { obs, trace_out, metrics_out, stop, dumper }
    }
}

impl Drop for ObsSession {
    fn drop(&mut self) {
        self.stop.store(true, std::sync::atomic::Ordering::Relaxed);
        if let Some(h) = self.dumper.take() {
            let _ = h.join();
        }
        if let Some(path) = &self.metrics_out {
            match std::fs::write(path, self.obs.metrics_json()) {
                Ok(()) => println!("metrics snapshot written to {path}"),
                Err(e) => eprintln!("--metrics-out {path}: {e}"),
            }
        }
        if let Some(path) = &self.trace_out {
            let t = self.obs.tracer();
            let spans = t.snapshot().len();
            match self.obs.write_chrome_trace(Path::new(path)) {
                Ok(()) => println!(
                    "wrote {spans} spans to {path} ({} recorded, {} dropped under \
                     ring pressure)",
                    t.recorded(),
                    t.dropped()
                ),
                Err(e) => eprintln!("--trace-out {path}: {e}"),
            }
        }
        metatt::obs::set_global(None);
    }
}

/// One line of per-stage latency percentiles (satellite of the PR 10
/// observability layer): where a request's time went, from the engine's
/// always-on µs stage stamps.
fn print_stages(stages: &Option<metatt::serving::StageBreakdown>) {
    let Some(s) = stages else { return };
    println!(
        "stage p50/p99 ms — queue {:.2}/{:.2}  batch-wait {:.2}/{:.2}  \
         compute {:.2}/{:.2}  respond {:.2}/{:.2}",
        s.queue_wait.p50 * 1e3,
        s.queue_wait.p99 * 1e3,
        s.batch_wait.p50 * 1e3,
        s.batch_wait.p99 * 1e3,
        s.compute.p50 * 1e3,
        s.compute.p99 * 1e3,
        s.respond.p50 * 1e3,
        s.respond.p99 * 1e3
    );
}

/// `metatt serve` — the multi-task serving engine driven by the in-process
/// closed-loop load generator. The adapter state comes from `--checkpoint`
/// (a v2 container's metadata is validated against — and fills in — the
/// adapter flags) or, without one, a seeded normal-init MetaTT so the
/// pipeline is exercisable out of the box. Emits `BENCH_pr5.json` via
/// `bench::save_record` (env override `METATT_BENCH_PR5_OUT`).
fn cmd_serve(args: &Args) -> Result<()> {
    use metatt::coordinator::checkpoint as ckpt;
    use metatt::serving::{self, EngineConfig, LoadGenConfig, ServingEngine};
    use metatt::tt::{CoreInit, InitStrategy};
    use metatt::util::rng::Pcg64;
    use std::time::Duration;

    let mut model = parse_model(args)?;
    let mut adapter =
        AdapterKind::from_name(&args.str_or("adapter", "metatt4p1d")).map_err(|e| anyhow!(e))?;
    let mut rank = args.usize_or("rank", 8).map_err(|e| anyhow!(e))?;
    let mut alpha = args.f32_or("alpha", 2.0).map_err(|e| anyhow!(e))?;
    let mut num_tasks = args.usize_or("num-tasks", 3).map_err(|e| anyhow!(e))?;
    let seed = args.u64_or("seed", 7).map_err(|e| anyhow!(e))?;
    let mut serve_dtype = match args.get("serve-dtype") {
        Some(s) => metatt::tensor::DtypeKind::from_name(s)
            .ok_or_else(|| anyhow!("--serve-dtype must be f32, bf16, or int8 (got '{s}')"))?,
        None => metatt::tensor::DtypeKind::F32,
    };

    // Per-request scheduling knobs, shared by every mode: a relative
    // deadline (0 = none) and a priority class (lower = more urgent).
    let deadline = match args.u64_or("deadline-ms", 0).map_err(|e| anyhow!(e))? {
        0 => None,
        ms => Some(Duration::from_millis(ms)),
    };
    let priority = {
        let p = args.usize_or("priority", 0).map_err(|e| anyhow!(e))?;
        if p > u8::MAX as usize {
            bail!("--priority must fit in a byte (lower = more urgent), got {p}");
        }
        p as u8
    };

    // Fault injection: `--faults` wins, else the METATT_FAULTS env spec;
    // an absent/empty spec leaves every injection point a no-op.
    let faults = std::sync::Arc::new(match args.get("faults") {
        Some(spec) => metatt::util::fault::FaultPlan::parse(spec).map_err(|e| anyhow!(e))?,
        None => metatt::util::fault::FaultPlan::from_env().map_err(|e| anyhow!(e))?,
    });
    if faults.is_armed() {
        println!("fault injection armed: {}", faults.spec());
    }

    // Client mode needs no engine (the server owns the model): dispatch
    // before any backbone/adapter loading.
    if let Some(addr) = args.get("connect") {
        return serve_connect(args, addr, seed, deadline, priority);
    }

    // Observability (PR 10): one `Obs` shared by every engine and router in
    // this process. Unarmed, every hook it feeds is a single relaxed atomic
    // load; armed (--trace / --trace-out / METATT_TRACE=1) it records spans
    // into per-thread rings and exports them on exit. The guard's Drop
    // writes --trace-out / --metrics-out on every exit path.
    let obs_session = ObsSession::begin(args);
    let obs = std::sync::Arc::clone(&obs_session.obs);

    // Adapter state: checkpoint tensors (+ metadata validation/adoption),
    // or a deterministic synthetic adapter when no checkpoint is given.
    let loaded = match args.get("checkpoint") {
        Some(p) => {
            let (meta, tensors) =
                ckpt::load_with_meta(Path::new(p)).map_err(|e| anyhow!(e))?;
            if let Some(m) = &meta {
                // Explicitly-passed flags must agree with the metadata;
                // unset flags adopt it — so `serve --checkpoint f` alone
                // serves exactly what was trained.
                if args.get("adapter").is_none() {
                    adapter = AdapterKind::from_name(&m.adapter).map_err(|e| anyhow!(e))?;
                } else if adapter.name() != m.adapter {
                    bail!(
                        "--adapter {} conflicts with checkpoint metadata ({})",
                        adapter.name(),
                        m.adapter
                    );
                }
                if args.get("rank").is_none() {
                    rank = m.rank;
                } else if rank != m.rank {
                    bail!("--rank {rank} conflicts with checkpoint metadata ({})", m.rank);
                }
                if args.get("num-tasks").is_none() {
                    num_tasks = m.tasks;
                } else if num_tasks != m.tasks {
                    bail!(
                        "--num-tasks {num_tasks} conflicts with checkpoint metadata ({})",
                        m.tasks
                    );
                }
                if args.get("alpha").is_none() {
                    alpha = m.alpha;
                } else if (alpha - m.alpha).abs() > 1e-6 {
                    bail!("--alpha {alpha} conflicts with checkpoint metadata ({})", m.alpha);
                }
                if args.get("model").is_none() {
                    model = ModelPreset::from_name(&m.model).map_err(|e| anyhow!(e))?;
                } else if model.name() != m.model {
                    bail!(
                        "--model {} conflicts with checkpoint metadata ({})",
                        model.name(),
                        m.model
                    );
                }
                // Dtype: the checkpoint records its *storage* dtype. An
                // f32 source may serve at any dtype (quantization happens
                // at bind/fold time); a non-f32 source pins serving.
                if args.get("serve-dtype").is_none() {
                    serve_dtype = metatt::tensor::DtypeKind::from_name(&m.dtype)
                        .ok_or_else(|| {
                            anyhow!("checkpoint metadata has unknown dtype '{}'", m.dtype)
                        })?;
                } else if m.dtype != "f32" && serve_dtype.name() != m.dtype {
                    bail!(
                        "--serve-dtype {} conflicts with checkpoint storage dtype ({}); \
                         only f32 checkpoints can be requantized at bind",
                        serve_dtype.name(),
                        m.dtype
                    );
                }
                println!(
                    "checkpoint metadata: {} rank {} over {} tasks (model {}, alpha {}, dtype {})",
                    m.adapter, m.rank, m.tasks, m.model, m.alpha, m.dtype
                );
            } else {
                println!("note: legacy checkpoint (no metadata) — trusting the adapter flags");
            }
            Some(tensors)
        }
        None => None,
    };

    let cfg = EngineConfig {
        model,
        adapter,
        rank,
        alpha,
        num_tasks,
        classes: args.usize_or("classes", 2).map_err(|e| anyhow!(e))?,
        max_batch: args.usize_or("max-batch", 8).map_err(|e| anyhow!(e))?,
        batch_deadline: Duration::from_millis(
            args.u64_or("batch-deadline-ms", 2).map_err(|e| anyhow!(e))?,
        ),
        queue_capacity: args.usize_or("queue-cap", 256).map_err(|e| anyhow!(e))?,
        workers: args.usize_or("serve-workers", 2).map_err(|e| anyhow!(e))?,
        cache_capacity_bytes: args
            .usize_or("cache-cap", 64 << 20)
            .map_err(|e| anyhow!(e))?,
        dtype: serve_dtype,
        faults: std::sync::Arc::clone(&faults),
        obs: std::sync::Arc::clone(&obs),
    };
    // Guard before any chain construction: metatt_from_tensors /
    // build_metatt panic on non-TT families, the engine only folds TT.
    let AdapterKind::MetaTt(tt_kind) = adapter else {
        bail!("serve folds TT adapters only (got '{}')", adapter.name());
    };
    let aspec = serving::adapter_spec_for(&cfg);
    let tt = match &loaded {
        Some(tensors) => serving::metatt_from_tensors(&aspec, tensors).map_err(|e| anyhow!(e))?,
        None => {
            let init = InitStrategy { cores: vec![CoreInit::Normal; tt_kind.order()] };
            aspec.build_metatt_with(&mut Pcg64::with_stream(seed, 0xada9), Some(&init))
        }
    };

    let backend = backend_for(args)?;
    let backbone = ckpt_for(args, model);

    // Sharded topologies (PR 9): `--topology` sweeps shard layouts into
    // BENCH_pr9.json; `--shards N > 1` serves one layout — TCP front-end
    // or the in-process load generator — behind a supervised router.
    // Every shard gets the same adapter chain: replicas of a group MUST
    // hold identical state, and that is what makes failover transparent.
    let shards = args.usize_or("shards", 1).map_err(|e| anyhow!(e))?;
    let replicas = args.usize_or("replicas", shards.max(1)).map_err(|e| anyhow!(e))?;
    let route = serving::RoutePolicy::parse(&args.str_or("route", "affinity"))?;
    if args.flag("topology") {
        return serve_topology(args, backend.as_ref(), &cfg, &tt, backbone.as_deref());
    }
    if shards > 1 {
        if args.flag("overload") {
            bail!(
                "--overload drives a single engine; use --topology for the \
                 sharded sweep (records BENCH_pr9.json)"
            );
        }
        let rcfg = serving::RouterConfig {
            engine: cfg,
            shards,
            replicas,
            route,
            ..serving::RouterConfig::default()
        };
        let router =
            serving::ShardRouter::new(backend.as_ref(), rcfg, |_| tt.clone(), backbone.as_deref())?;
        if let Some(addr) = args.get("listen") {
            return serve_listen(args, &router, addr);
        }
        return serve_router_load(args, &router, seed, deadline, priority);
    }

    // A fault-free twin for the resilience comparison (`--overload` with
    // faults armed): same config and adapter state, empty fault plan.
    let twin = (args.flag("overload") && faults.is_armed()).then(|| {
        (
            EngineConfig {
                faults: std::sync::Arc::new(metatt::util::fault::FaultPlan::empty()),
                // The baseline gets its own disarmed Obs so the exported
                // trace holds only the faulted arm's spans.
                obs: std::sync::Arc::new(metatt::obs::Obs::new(false)),
                ..cfg.clone()
            },
            tt.clone(),
        )
    });
    let engine = ServingEngine::new(backend.as_ref(), cfg, tt, backbone.as_deref())?;

    if let Some(addr) = args.get("listen") {
        return serve_listen(args, &engine, addr);
    }

    let requests = args.usize_or("requests", 100).map_err(|e| anyhow!(e))?;
    let clients = args.usize_or("clients", 4).map_err(|e| anyhow!(e))?;
    if requests == 0 || clients == 0 {
        bail!("--requests and --clients must be >= 1");
    }
    let lcfg = LoadGenConfig {
        clients,
        requests_per_client: requests.div_ceil(clients).max(1),
        seed,
        task_mix: parse_mix(args, num_tasks)?,
        think_us: args.u64_or("think-us", 0).map_err(|e| anyhow!(e))?,
        deadline,
        priority,
    };

    if args.flag("overload") {
        if let Some((bcfg, btt)) = twin {
            let baseline =
                ServingEngine::new(backend.as_ref(), bcfg, btt, backbone.as_deref())?;
            return serve_resilience(args, &engine, &baseline, &lcfg, deadline, priority);
        }
        return serve_overload(args, &engine, &lcfg, deadline, priority);
    }

    let report = serving::run_load(&engine, &lcfg)?;
    // Batch/queue statistics come from the report's measured window (the
    // warmup wave is excluded); cache counters are engine-lifetime.
    let stats = &report.engine;
    let cache = engine.cache_stats();
    let lookups = (cache.hits + cache.folds).max(1);
    println!(
        "served {} requests over {} tasks in {:.3}s — {:.1} req/s ({} expired)\n\
         latency p50 {:.2}ms  p95 {:.2}ms  p99 {:.2}ms  queue wait mean {:.2}ms\n\
         {} batches (mean fill {:.2}/{})  cache hit rate {:.1}% ({} folds, {} evictions)\n\
         serve dtype {}  folded-adapter cache resident {:.1} KiB",
        report.total_requests,
        engine.config().num_tasks,
        report.elapsed,
        report.throughput_rps,
        report.expired,
        report.latency.p50 * 1e3,
        report.latency.p95 * 1e3,
        report.latency.p99 * 1e3,
        stats.queue_wait_mean_s() * 1e3,
        stats.batches,
        stats.requests as f64 / stats.batches.max(1) as f64,
        engine.config().max_batch,
        100.0 * cache.hits as f64 / lookups as f64,
        cache.folds,
        cache.evictions,
        engine.config().dtype.name(),
        cache.bytes as f64 / 1024.0
    );
    print_stages(&report.stages);
    let doc = serving::report_json(&engine, &lcfg, &report);
    metatt::bench::save_record("pr5", &doc)?;
    results::append_record(
        "serve",
        &Json::obj(vec![
            ("adapter", Json::str(engine.config().adapter.name())),
            ("num_tasks", Json::num(engine.config().num_tasks as f64)),
            ("requests", Json::num(report.total_requests as f64)),
            ("throughput_rps", Json::num(report.throughput_rps)),
            ("p99_ms", Json::num(report.latency.p99 * 1e3)),
        ]),
    );
    Ok(())
}

/// Parse `--mix` into task weights, validated against the served arity
/// here rather than inside load-client threads (a bad flag should be a
/// flag error, not "load client panicked").
fn parse_mix(args: &Args, num_tasks: usize) -> Result<Vec<f64>> {
    let Some(v) = args.get("mix") else {
        return Ok(Vec::new());
    };
    let weights: Vec<f64> = v
        .split(',')
        .map(|p| {
            p.trim()
                .parse::<f64>()
                .map_err(|_| anyhow!("--mix expects comma-separated weights, got '{p}'"))
        })
        .collect::<Result<_>>()?;
    if weights.len() != num_tasks {
        bail!("--mix has {} weights but {num_tasks} tasks are served", weights.len());
    }
    if weights.iter().any(|w| !w.is_finite() || *w < 0.0) {
        bail!("--mix weights must be finite and >= 0 (got {v})");
    }
    if weights.iter().sum::<f64>() <= 0.0 {
        bail!("--mix needs at least one positive weight");
    }
    Ok(weights)
}

/// `serve --listen ADDR`: run the TCP front-end until `--serve-secs`
/// elapses (0 = until the process is killed), then drain gracefully —
/// stop accepting, finish every admitted request, close sockets. Generic
/// over [`ServeTarget`]: one engine and an N-shard router speak the same
/// wire protocol, routing lives strictly behind the admission seam.
fn serve_listen<T: metatt::serving::ServeTarget>(
    args: &Args,
    engine: &T,
    addr: &str,
) -> Result<()> {
    use std::net::TcpListener;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;
    use std::time::Duration;
    let listener = TcpListener::bind(addr).map_err(|e| anyhow!("bind {addr}: {e}"))?;
    let local = listener.local_addr().map_err(|e| anyhow!(e))?;
    let secs = args.u64_or("serve-secs", 0).map_err(|e| anyhow!(e))?;
    let grace_ms = args.u64_or("drain-grace-ms", 1000).map_err(|e| anyhow!(e))?;
    if grace_ms == 0 {
        bail!("--drain-grace-ms must be > 0 (half-received frames need time to finish)");
    }
    let net_cfg = metatt::serving::NetServerConfig {
        drain_grace: Duration::from_millis(grace_ms),
    };
    println!(
        "listening on {local} (MTS1; {} tasks, seq {}, vocab {}, {} classes){}",
        engine.num_tasks(),
        engine.seq_len(),
        engine.vocab(),
        engine.classes(),
        if secs > 0 { format!(" — stopping after {secs}s") } else { String::new() }
    );
    let shutdown = Arc::new(AtomicBool::new(false));
    let net = engine.serve_session(|eng| {
        if secs > 0 {
            let sd = Arc::clone(&shutdown);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_secs(secs));
                sd.store(true, Ordering::Relaxed);
            });
        }
        metatt::serving::serve_net_with(eng, listener, &shutdown, &net_cfg)
    })??;
    let stats = engine.stats();
    println!(
        "front-end drained: {} connections, {} request frames — {} computed, \
         {} shed, {} batches",
        net.connections, net.requests, stats.requests, stats.shed, stats.batches
    );
    results::append_record(
        "serve_net",
        &Json::obj(vec![
            ("addr", Json::str(local.to_string())),
            ("connections", Json::num(net.connections as f64)),
            ("requests", Json::num(net.requests as f64)),
            ("computed", Json::num(stats.requests as f64)),
            ("shed", Json::num(stats.shed as f64)),
        ]),
    );
    Ok(())
}

/// `serve --shards N` without a front-end: the in-process closed-loop
/// load generator pointed at a sharded router. Reports the aggregate
/// engine view plus the supervision counters (failovers/stolen/moved).
fn serve_router_load(
    args: &Args,
    router: &metatt::serving::ShardRouter<'_>,
    seed: u64,
    deadline: Option<std::time::Duration>,
    priority: u8,
) -> Result<()> {
    use metatt::serving::{self, LoadGenConfig};
    let requests = args.usize_or("requests", 100).map_err(|e| anyhow!(e))?;
    let clients = args.usize_or("clients", 4).map_err(|e| anyhow!(e))?;
    if requests == 0 || clients == 0 {
        bail!("--requests and --clients must be >= 1");
    }
    let num_tasks = router.config().engine.num_tasks;
    let lcfg = LoadGenConfig {
        clients,
        requests_per_client: requests.div_ceil(clients).max(1),
        seed,
        task_mix: parse_mix(args, num_tasks)?,
        think_us: args.u64_or("think-us", 0).map_err(|e| anyhow!(e))?,
        deadline,
        priority,
    };
    let report = serving::run_load(router, &lcfg)?;
    let rs = router.router_stats();
    let cache = router.cache_stats();
    let lookups = (cache.hits + cache.folds).max(1);
    println!(
        "served {} requests over {} tasks across {} shards ({} group(s) x {} \
         replica(s), route {}) in {:.3}s — {:.1} req/s ({} expired)\n\
         latency p50 {:.2}ms  p95 {:.2}ms  p99 {:.2}ms\n\
         cache hit rate {:.1}% ({} folds)  heartbeats {}  stolen {}  failovers {}",
        report.total_requests,
        num_tasks,
        router.shards(),
        router.groups(),
        router.replicas(),
        router.config().route.name(),
        report.elapsed,
        report.throughput_rps,
        report.expired,
        report.latency.p50 * 1e3,
        report.latency.p95 * 1e3,
        report.latency.p99 * 1e3,
        100.0 * cache.hits as f64 / lookups as f64,
        cache.folds,
        rs.heartbeats,
        rs.stolen,
        rs.failovers,
    );
    print_stages(&report.stages);
    results::append_record(
        "serve_sharded",
        &Json::obj(vec![
            ("shards", Json::num(router.shards() as f64)),
            ("replicas", Json::num(router.replicas() as f64)),
            ("route", Json::str(router.config().route.name())),
            ("requests", Json::num(report.total_requests as f64)),
            ("throughput_rps", Json::num(report.throughput_rps)),
            ("p99_ms", Json::num(report.latency.p99 * 1e3)),
            ("failovers", Json::num(rs.failovers as f64)),
            ("stolen", Json::num(rs.stolen as f64)),
        ]),
    );
    Ok(())
}

/// `serve --connect ADDR`: closed-loop TCP clients against a listener.
/// Request streams are derived from the server's hello, so the same
/// `(seed, client, index)` asks the same question as the in-process mode.
fn serve_connect(
    args: &Args,
    addr: &str,
    seed: u64,
    deadline: Option<std::time::Duration>,
    priority: u8,
) -> Result<()> {
    use metatt::serving::{self, LoadGenConfig, NetClientConfig, RetryPolicy};
    use std::time::Duration;
    let requests = args.usize_or("requests", 100).map_err(|e| anyhow!(e))?;
    let clients = args.usize_or("clients", 4).map_err(|e| anyhow!(e))?;
    if requests == 0 || clients == 0 {
        bail!("--requests and --clients must be >= 1");
    }
    // Socket read/write timeout: a hung or partitioned server surfaces as
    // a clean "timed out" error instead of a forever-blocked recv.
    let io_timeout = match args.u64_or("net-timeout-ms", 30_000).map_err(|e| anyhow!(e))? {
        0 => None,
        ms => Some(Duration::from_millis(ms)),
    };
    let net = NetClientConfig {
        connect_timeout: Duration::from_secs(10),
        io_timeout,
        retry: RetryPolicy { seed, ..RetryPolicy::default() },
    };
    // Probe once for the hello: validates the endpoint and gives --mix a
    // task arity to check against before the client fleet launches.
    let probe = serving::NetClient::connect_retry_with(addr, net.connect_timeout, io_timeout)?;
    let hello = probe.hello;
    drop(probe);
    println!(
        "server {addr}: {} tasks, seq {}, vocab {}, {} classes",
        hello.num_tasks, hello.seq, hello.vocab, hello.classes
    );
    let lcfg = LoadGenConfig {
        clients,
        requests_per_client: requests.div_ceil(clients).max(1),
        seed,
        task_mix: parse_mix(args, hello.num_tasks)?,
        think_us: args.u64_or("think-us", 0).map_err(|e| anyhow!(e))?,
        deadline,
        priority,
    };
    let report = serving::run_net_load(addr, &lcfg, &net)?;
    let (p50, p95, p99) =
        report.latency.as_ref().map_or((0.0, 0.0, 0.0), |l| (l.p50, l.p95, l.p99));
    println!(
        "{} round trips in {:.3}s — {:.1} req/s computed, {} expired, {} errors\n\
         latency p50 {:.2}ms  p95 {:.2}ms  p99 {:.2}ms\n\
         {} retried round trips, {} reconnects after connection loss",
        report.total,
        report.elapsed,
        report.throughput_rps,
        report.expired,
        report.errors,
        p50 * 1e3,
        p95 * 1e3,
        p99 * 1e3,
        report.retries,
        report.reconnects
    );
    // Client wall latency above includes the network; the engine-clock view
    // (from the wire stage stamps, admit → done on the server's µs clock)
    // isolates server-side time.
    if let Some(l) = &report.engine_latency {
        println!(
            "engine-clock latency p50 {:.2}ms  p95 {:.2}ms  p99 {:.2}ms \
             (server admit→done, network excluded)",
            l.p50 * 1e3,
            l.p95 * 1e3,
            l.p99 * 1e3
        );
    }
    print_stages(&report.stages);
    if args.flag("stat") {
        let mut c =
            serving::NetClient::connect_retry_with(addr, net.connect_timeout, io_timeout)?;
        let text = c.stat()?;
        println!("--- STAT snapshot from {addr} ---");
        print!("{text}");
        println!("--- end STAT snapshot ---");
    }
    if report.errors > 0 {
        bail!("{} requests came back as protocol/validation errors", report.errors);
    }
    results::append_record(
        "serve_net_client",
        &Json::obj(vec![
            ("addr", Json::str(addr)),
            ("requests", Json::num(report.total as f64)),
            ("throughput_rps", Json::num(report.throughput_rps)),
            ("expired", Json::num(report.expired as f64)),
            ("p99_ms", Json::num(p99 * 1e3)),
            ("retries", Json::num(report.retries as f64)),
            ("reconnects", Json::num(report.reconnects as f64)),
        ]),
    );
    Ok(())
}

/// `serve --overload`: the `BENCH_pr6.json` experiment — measure
/// closed-loop capacity, then offer open-loop Poisson arrivals at each
/// configured multiple of it and record goodput / shed / tail latency.
fn overload_cfg(
    args: &Args,
    capacity: &metatt::serving::LoadGenConfig,
    deadline: Option<std::time::Duration>,
    priority: u8,
) -> Result<metatt::serving::OverloadConfig> {
    use metatt::serving::{LoadGenConfig, OverloadConfig};
    use std::time::Duration;
    let mults: Vec<f64> = match args.get("overload-mults") {
        None => vec![0.5, 1.0, 2.0, 4.0],
        Some(v) => v
            .split(',')
            .map(|p| {
                p.trim().parse::<f64>().map_err(|_| {
                    anyhow!("--overload-mults expects comma-separated numbers, got '{p}'")
                })
            })
            .collect::<Result<_>>()?,
    };
    Ok(OverloadConfig {
        // Capacity is probed without deadlines: it measures what the
        // engine *can* do; the levels then hold that rate to a deadline.
        capacity: LoadGenConfig { deadline: None, ..capacity.clone() },
        mults,
        requests_per_level: args.usize_or("overload-requests", 200).map_err(|e| anyhow!(e))?,
        deadline: deadline.unwrap_or(Duration::from_millis(50)),
        priority,
    })
}

fn serve_overload(
    args: &Args,
    engine: &metatt::serving::ServingEngine<'_>,
    capacity: &metatt::serving::LoadGenConfig,
    deadline: Option<std::time::Duration>,
    priority: u8,
) -> Result<()> {
    use metatt::serving;
    let ocfg = overload_cfg(args, capacity, deadline, priority)?;
    let report = serving::run_overload_bench(engine, &ocfg)?;
    println!(
        "capacity: {:.1} req/s (closed loop, {} clients, p99 {:.2}ms); \
         deadline {:.0}ms",
        report.capacity_rps,
        ocfg.capacity.clients,
        report.capacity.latency.p99 * 1e3,
        ocfg.deadline.as_secs_f64() * 1e3
    );
    for (mult, r) in &report.levels {
        let p99 = r.latency.as_ref().map_or(0.0, |l| l.p99);
        println!(
            "x{mult:<4} offered {:>7.1} rps -> goodput {:>7.1} rps  ok {:>4}  \
             shed {:>4}  rejected {:>4}  p99 {:>7.2}ms",
            r.offered_rps,
            r.goodput_rps,
            r.ok,
            r.expired,
            r.rejected,
            p99 * 1e3
        );
    }
    let doc = serving::overload_report_json(engine, &ocfg, &report);
    metatt::bench::save_record("pr6", &doc)?;
    append_overload_record(&ocfg, &report);
    Ok(())
}

fn append_overload_record(
    ocfg: &metatt::serving::OverloadConfig,
    report: &metatt::serving::OverloadReport,
) {
    results::append_record(
        "serve_overload",
        &Json::obj(vec![
            ("capacity_rps", Json::num(report.capacity_rps)),
            ("deadline_ms", Json::num(ocfg.deadline.as_secs_f64() * 1e3)),
            (
                "levels",
                Json::Arr(
                    report
                        .levels
                        .iter()
                        .map(|(m, r)| {
                            Json::obj(vec![
                                ("mult", Json::num(*m)),
                                ("goodput_rps", Json::num(r.goodput_rps)),
                                ("shed", Json::num(r.expired as f64)),
                                ("rejected", Json::num(r.rejected as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
    );
}

/// `serve --overload` with faults armed: the `BENCH_pr8.json` experiment.
/// Runs the sweep twice over identical engine configs and seeds — faults
/// armed, then the fault-free twin — and reports goodput retention plus
/// the self-healing counters (restarts / quarantined / requeued) per level.
fn serve_resilience(
    args: &Args,
    faulted_engine: &metatt::serving::ServingEngine<'_>,
    baseline_engine: &metatt::serving::ServingEngine<'_>,
    capacity: &metatt::serving::LoadGenConfig,
    deadline: Option<std::time::Duration>,
    priority: u8,
) -> Result<()> {
    use metatt::serving;
    let ocfg = overload_cfg(args, capacity, deadline, priority)?;
    let spec = faulted_engine.faults().spec().to_string();
    println!("resilience sweep: faults \"{spec}\" vs fault-free twin");
    let faulted = serving::run_overload_bench(faulted_engine, &ocfg)?;
    let baseline = serving::run_overload_bench(baseline_engine, &ocfg)?;
    for ((mult, f), (_, b)) in faulted.levels.iter().zip(&baseline.levels) {
        let retention = if b.goodput_rps > 0.0 { f.goodput_rps / b.goodput_rps } else { 0.0 };
        println!(
            "x{mult:<4} goodput {:>7.1} rps faulted / {:>7.1} clean ({:>5.1}%)  \
             restarts {:>3}  quarantined {:>3}  requeued {:>3}  errors {:>3}",
            f.goodput_rps,
            b.goodput_rps,
            retention * 100.0,
            f.engine.worker_restarts,
            f.engine.quarantined,
            f.engine.requeued,
            f.errors
        );
    }
    let doc = serving::resilience_report_json(faulted_engine, &ocfg, &spec, &faulted, &baseline);
    metatt::bench::save_record("pr8", &doc)?;
    results::append_record(
        "serve_resilience",
        &Json::obj(vec![
            ("faults", Json::str(&spec)),
            ("capacity_rps_faulted", Json::num(faulted.capacity_rps)),
            ("capacity_rps_baseline", Json::num(baseline.capacity_rps)),
            (
                "levels",
                Json::Arr(
                    faulted
                        .levels
                        .iter()
                        .zip(&baseline.levels)
                        .map(|((m, f), (_, b))| {
                            Json::obj(vec![
                                ("mult", Json::num(*m)),
                                ("goodput_rps_faulted", Json::num(f.goodput_rps)),
                                ("goodput_rps_baseline", Json::num(b.goodput_rps)),
                                ("worker_restarts", Json::num(f.engine.worker_restarts as f64)),
                                ("quarantined", Json::num(f.engine.quarantined as f64)),
                                ("requeued", Json::num(f.engine.requeued as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
    );
    Ok(())
}

/// `serve --topology`: the `BENCH_pr9.json` experiment. Sweep shard
/// layouts of a fixed worker budget (4 workers -> 1x4, 2x2, 4x1 shards),
/// measuring closed-loop capacity per layout; then hold the smallest
/// multi-shard layout at 0.8x its measured capacity open loop and kill
/// one shard mid-run under a seeded fault plan, reporting goodput
/// retention against the fault-free twin. A Down shard's queue fails
/// over, so both arms answer every admitted request.
fn serve_topology(
    args: &Args,
    backend: &dyn Backend,
    base: &metatt::serving::EngineConfig,
    tt: &metatt::tt::MetaTt,
    backbone: Option<&Path>,
) -> Result<()> {
    use metatt::serving::{
        closed_loop_in, open_loop_in, warmup_in, LoadGenConfig, OpenLoopConfig, RoutePolicy,
        RouterConfig, ShardHealth, ShardRouter,
    };
    use metatt::util::fault::FaultPlan;
    use std::sync::Arc;
    use std::time::Duration;

    // An engine cannot serve twice (`serve` closes its queue on exit), so
    // every level gets a fresh router; this helper pins the lifetimes.
    fn fresh<'b>(
        backend: &'b dyn Backend,
        rcfg: RouterConfig,
        tt: &metatt::tt::MetaTt,
        backbone: Option<&Path>,
    ) -> Result<ShardRouter<'b>> {
        ShardRouter::new(backend, rcfg, |_| tt.clone(), backbone)
    }

    let seed = args.u64_or("seed", 7).map_err(|e| anyhow!(e))?;
    let requests = args.usize_or("requests", 100).map_err(|e| anyhow!(e))?;
    let clients = args.usize_or("clients", 4).map_err(|e| anyhow!(e))?;
    if requests == 0 || clients == 0 {
        bail!("--requests and --clients must be >= 1");
    }
    let route = RoutePolicy::parse(&args.str_or("route", "affinity"))?;
    let total_workers = base.workers.max(1);
    let heartbeat = Duration::from_millis(25);
    let cap_cfg = LoadGenConfig {
        clients,
        requests_per_client: requests.div_ceil(clients).max(1),
        seed,
        task_mix: parse_mix(args, base.num_tasks)?,
        think_us: args.u64_or("think-us", 0).map_err(|e| anyhow!(e))?,
        // Capacity measures what a layout *can* do, no deadline pressure.
        deadline: None,
        priority: 0,
    };
    let mk_cfg = |shards: usize, faults: Arc<FaultPlan>| RouterConfig {
        engine: metatt::serving::EngineConfig {
            workers: (total_workers / shards).max(1),
            faults,
            ..base.clone()
        },
        shards,
        // One group per layout: every shard is a same-adapter replica, so
        // the sweep varies queue/worker partitioning, not task placement.
        replicas: shards,
        route,
        heartbeat,
        ..RouterConfig::default()
    };

    let layouts: Vec<usize> = (1..=total_workers).filter(|s| total_workers % s == 0).collect();
    println!(
        "topology sweep: {total_workers} total workers, route {} — shard layouts {:?}",
        route.name(),
        layouts
    );
    let mut levels: Vec<(usize, f64)> = Vec::new();
    let mut level_json = Vec::new();
    for &shards in &layouts {
        let router = fresh(backend, mk_cfg(shards, Arc::new(FaultPlan::empty())), tt, backbone)?;
        let report = router.serve(|r| {
            warmup_in(r, seed)?;
            closed_loop_in(r, &cap_cfg)
        })??;
        let cache = router.cache_stats();
        let rs = router.router_stats();
        let lookups = (cache.hits + cache.folds).max(1);
        println!(
            "{shards} shard(s) x {} worker(s): capacity {:>7.1} req/s  p99 {:>6.2}ms  \
             cache hit {:>5.1}%  stolen {:>3}",
            total_workers / shards,
            report.throughput_rps,
            report.latency.p99 * 1e3,
            100.0 * cache.hits as f64 / lookups as f64,
            rs.stolen
        );
        level_json.push(Json::obj(vec![
            ("shards", Json::num(shards as f64)),
            ("workers_per_shard", Json::num((total_workers / shards) as f64)),
            ("capacity_rps", Json::num(report.throughput_rps)),
            ("p50_ms", Json::num(report.latency.p50 * 1e3)),
            ("p99_ms", Json::num(report.latency.p99 * 1e3)),
            ("expired", Json::num(report.expired as f64)),
            ("cache_hit_rate", Json::num(cache.hits as f64 / lookups as f64)),
            ("folds", Json::num(cache.folds as f64)),
            ("stolen", Json::num(rs.stolen as f64)),
            ("heartbeats", Json::num(rs.heartbeats as f64)),
        ]));
        levels.push((shards, report.throughput_rps));
    }

    // Kill-one-shard-at-steady-state: the smallest multi-shard layout,
    // held at 0.8x its measured capacity, faulted arm vs fault-free twin.
    let kill = levels.iter().find(|(s, _)| *s > 1).copied();
    let kill_json = if let Some((shards, capacity)) = kill {
        let rate = (capacity * 0.8).max(1.0);
        let deadline_ms = args.u64_or("deadline-ms", 0).map_err(|e| anyhow!(e))?;
        let ol = OpenLoopConfig {
            rate_rps: rate,
            requests: args.usize_or("overload-requests", 200).map_err(|e| anyhow!(e))?,
            seed,
            stream: 1,
            task_mix: cap_cfg.task_mix.clone(),
            deadline: Some(Duration::from_millis(if deadline_ms == 0 { 50 } else { deadline_ms })),
            priority: 0,
        };
        // A CLI --faults plan wins; the default kills one shard on the
        // supervisor's third beat (tick 6 = beat 3 probing shard 1 of 2).
        let spec = if base.faults.is_armed() {
            base.faults.spec().to_string()
        } else {
            format!("shard_down@tick=6,seed={seed}")
        };
        let clean_router =
            fresh(backend, mk_cfg(shards, Arc::new(FaultPlan::empty())), tt, backbone)?;
        let clean = clean_router.serve(|r| {
            warmup_in(r, seed)?;
            open_loop_in(r, &ol)
        })??;
        let plan = Arc::new(FaultPlan::parse(&spec).map_err(|e| anyhow!(e))?);
        let faulted_router = fresh(backend, mk_cfg(shards, plan), tt, backbone)?;
        let faulted = faulted_router.serve(|r| {
            warmup_in(r, seed)?;
            open_loop_in(r, &ol)
        })??;
        let rs = faulted_router.router_stats();
        let downed = (0..faulted_router.shards())
            .filter(|&k| faulted_router.health(k) == ShardHealth::Down)
            .count();
        let retention =
            if clean.goodput_rps > 0.0 { faulted.goodput_rps / clean.goodput_rps } else { 0.0 };
        println!(
            "kill probe ({shards} shards @ {rate:.1} rps, faults \"{spec}\"): \
             goodput {:.1} faulted / {:.1} clean rps ({:.1}% retention)\n\
             {} down, {} failovers, {} moved, {} displaced, {} dropped",
            faulted.goodput_rps,
            clean.goodput_rps,
            retention * 100.0,
            downed,
            rs.failovers,
            rs.moved,
            rs.displaced,
            faulted.dropped
        );
        Json::obj(vec![
            ("shards", Json::num(shards as f64)),
            ("rate_rps", Json::num(rate)),
            ("faults", Json::str(&spec)),
            ("goodput_rps_clean", Json::num(clean.goodput_rps)),
            ("goodput_rps_faulted", Json::num(faulted.goodput_rps)),
            ("goodput_retention", Json::num(retention)),
            ("ok_clean", Json::num(clean.ok as f64)),
            ("ok_faulted", Json::num(faulted.ok as f64)),
            ("expired_faulted", Json::num(faulted.expired as f64)),
            ("errors_faulted", Json::num(faulted.errors as f64)),
            ("dropped_faulted", Json::num(faulted.dropped as f64)),
            ("shards_down", Json::num(downed as f64)),
            ("failovers", Json::num(rs.failovers as f64)),
            ("moved", Json::num(rs.moved as f64)),
            ("displaced", Json::num(rs.displaced as f64)),
        ])
    } else {
        println!("kill probe skipped: 1 worker allows only the 1x1 layout");
        Json::Null
    };

    let doc = Json::obj(vec![
        ("bench", Json::str("serving_topology")),
        ("total_workers", Json::num(total_workers as f64)),
        ("route", Json::str(route.name())),
        ("num_tasks", Json::num(base.num_tasks as f64)),
        ("clients", Json::num(clients as f64)),
        ("requests_per_client", Json::num(cap_cfg.requests_per_client as f64)),
        ("levels", Json::Arr(level_json)),
        ("kill", kill_json),
    ]);
    metatt::bench::save_record("pr9", &doc)?;
    results::append_record(
        "serve_topology",
        &Json::obj(vec![
            ("total_workers", Json::num(total_workers as f64)),
            ("route", Json::str(route.name())),
            (
                "levels",
                Json::Arr(
                    levels
                        .iter()
                        .map(|(s, c)| {
                            Json::obj(vec![
                                ("shards", Json::num(*s as f64)),
                                ("capacity_rps", Json::num(*c)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
    );
    Ok(())
}
