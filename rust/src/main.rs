//! `metatt` — the L3 coordinator launcher.
//!
//! Subcommands:
//!   info                         inspect the artifact manifest & runtime
//!   pretrain  --model tiny       MLM-pretrain the frozen backbone
//!   train     --task mrpc_syn    single-task fine-tuning (Table-1 protocol)
//!   mtl       --tasks a,b,c      joint multi-task training (Table-2)
//!   dmrg      --task mrpc_syn    AdamW + DMRG rank-annealing (Figs 2/6)
//!   serve     --requests N       folded-adapter serving loop (apply artifact)
//!
//! Every run appends a JSONL record under results/.

use anyhow::{anyhow, bail, Result};
use metatt::adapters::{AdapterKind, AdapterSpec};
use metatt::cli::Args;
use metatt::config::{ModelPreset, TrainConfig};
use metatt::coordinator::{self, results, DmrgConfig, MtlConfig, PretrainConfig};
use metatt::data::TaskId;
use metatt::runtime::{checkpoint_path, make_backend, Backend, BackendKind, Step};
use metatt::tt::{InitStrategy, RankSchedule};
use metatt::util::json::Json;
use std::path::Path;

const USAGE: &str = "\
metatt <command> [options]

commands:
  info       show backend status (and artifact manifest, pjrt backend)
  pretrain   --model tiny|small|base_sim --steps N [--lr F] [--seed N]
  train      --task T --adapter A --rank R [--alpha F] [--epochs N]
             [--batch N] [--lr F] [--seed N] [--init ze-id-id-id]
             [--train-cap N] [--no-checkpoint]
  mtl        --tasks a,b,c --adapter A --rank R [--alpha F] [--epochs N] ...
  dmrg       --task T [--adapter metatt5d] [--start-rank 10]
             [--schedule e:r,e:r,...] [--epochs N] [--seed N]
  seq        --task-a A --task-b B — sequential A→B→A transfer (forgetting)
  serve      --requests N [--rank R] — run the folded adapter apply step
  run        --config configs/foo.toml — config-file-driven run

options shared:
  --backend ref|pjrt   execution backend (default ref: hermetic pure-rust
                       CPU; pjrt needs `--features pjrt` + `make artifacts`)
  --threads N          worker threads for the ref backend's step execution
                       (default: METATT_THREADS or host parallelism; results
                       are bit-identical for any N)
  --model PRESET       model preset (default tiny)
  --artifacts DIR      HLO artifact dir for the pjrt backend (default artifacts)
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const OPTS: &[&str] = &[
    "task-a", "task-b", "config", "backend", "threads",
    "model", "steps", "lr", "seed", "task", "tasks", "adapter", "rank", "alpha",
    "epochs", "batch", "init", "train-cap", "eval-cap", "artifacts", "schedule",
    "start-rank", "requests", "warmup-ratio", "grad-clip",
];
const FLAGS: &[&str] = &["help", "no-checkpoint", "verbose"];

fn run() -> Result<()> {
    let args = Args::from_env(OPTS, FLAGS).map_err(|e| anyhow!(e))?;
    if args.flag("help") || args.command.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    match args.command.as_str() {
        "info" => cmd_info(&args),
        "pretrain" => cmd_pretrain(&args),
        "train" => cmd_train(&args),
        "mtl" => cmd_mtl(&args),
        "seq" => cmd_seq(&args),
        "dmrg" => cmd_dmrg(&args),
        "serve" => cmd_serve(&args),
        "run" => cmd_run(&args),
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

/// Resolve the worker-thread budget: `--threads` wins, then a TOML
/// `[runtime] threads` (run command), then `METATT_THREADS` / host auto.
fn threads_for(args: &Args, toml_threads: Option<usize>) -> Result<usize> {
    let explicit = args.usize_opt("threads").map_err(|e| anyhow!(e))?;
    metatt::util::threadpool::resolve_threads(explicit.or(toml_threads))
        .map_err(|e| anyhow!(e))
}

/// Build the execution backend. The kind comes from `--backend` (or
/// `default_kind` when the flag is absent — the `run` command passes the
/// TOML's choice); the thread budget from `--threads` > `toml_threads` >
/// env/auto.
fn backend_with(
    args: &Args,
    default_kind: BackendKind,
    toml_threads: Option<usize>,
) -> Result<Box<dyn Backend>> {
    let kind = match args.get("backend") {
        Some(name) => BackendKind::from_name(name).map_err(|e| anyhow!(e))?,
        None => default_kind,
    };
    let artifacts = args.str_or("artifacts", "artifacts");
    make_backend(kind, Path::new(&artifacts), threads_for(args, toml_threads)?)
}

/// Backend selected by `--backend` (default ref: the hermetic pure-rust
/// reference backend).
fn backend_for(args: &Args) -> Result<Box<dyn Backend>> {
    backend_with(args, BackendKind::Ref, None)
}

/// `metatt run --config configs/foo.toml` — config-file-driven single run.
fn cmd_run(args: &Args) -> Result<()> {
    let path = args
        .get("config")
        .or_else(|| args.positional.first().map(|s| s.as_str()))
        .ok_or_else(|| anyhow!("run needs --config <file.toml>"))?;
    let cfg = metatt::config::ExperimentConfig::from_toml(Path::new(path))
        .map_err(|e| anyhow!(e))?;
    // The TOML picks the backend and threads; explicit flags override.
    let backend = backend_with(args, cfg.backend, cfg.threads)?;
    let ckpt = ckpt_for(args, cfg.model);
    let spec = cfg.adapter_spec();
    if cfg.tasks.len() > 1 {
        let tasks: Vec<TaskId> = cfg
            .tasks
            .iter()
            .map(|n| TaskId::from_name(n))
            .collect::<Result<_, _>>()
            .map_err(|e| anyhow!(e))?;
        let mut mcfg = MtlConfig::default();
        mcfg.train = cfg.train.clone();
        mcfg.alpha = cfg.alpha;
        let res = coordinator::run_mtl(
            backend.as_ref(), cfg.model, &spec, &tasks, &mcfg, ckpt.as_deref(),
        )?;
        println!("best mean metric: {:.4} {:?}", res.best_mean, res.best_per_task);
    } else {
        let task = TaskId::from_name(&cfg.tasks[0]).map_err(|e| anyhow!(e))?;
        let res = coordinator::run_single_task(
            backend.as_ref(), cfg.model, &spec, task, &cfg.train, cfg.alpha,
            ckpt.as_deref(), None,
        )?;
        println!("best {}: {:.4}", task.info().metric.name(), res.best_metric);
    }
    Ok(())
}

/// `metatt seq --task-a mrpc_syn --task-b rte_syn` — sequential A→B→A
/// transfer with one shared adapter (paper §3.2, forgetting measurement).
fn cmd_seq(args: &Args) -> Result<()> {
    let model = parse_model(args)?;
    let task_a = TaskId::from_name(&args.str_or("task-a", "mrpc_syn")).map_err(|e| anyhow!(e))?;
    let task_b = TaskId::from_name(&args.str_or("task-b", "rte_syn")).map_err(|e| anyhow!(e))?;
    let adapter =
        AdapterKind::from_name(&args.str_or("adapter", "metatt4d")).map_err(|e| anyhow!(e))?;
    let rank = args.usize_or("rank", 8).map_err(|e| anyhow!(e))?;
    let alpha = args.f32_or("alpha", 4.0).map_err(|e| anyhow!(e))?;
    let train = train_config(args)?;
    let backend = backend_for(args)?;
    let spec = AdapterSpec::new(adapter, rank, alpha, model.dims(1));
    let ckpt = ckpt_for(args, model);
    let res = coordinator::run_sequential(
        backend.as_ref(), model, &spec, task_a, task_b, &train, alpha, ckpt.as_deref(),
    )?;
    for (i, p) in res.phases.iter().enumerate() {
        println!(
            "phase {} (trained {:>9}):  {}={:.3}  {}={:.3}",
            i + 1,
            p.trained_task.name(),
            task_a.name(),
            p.metric_a,
            task_b.name(),
            p.metric_b
        );
    }
    println!(
        "forgetting gap on {} while training {}: {:+.3}   round-trip gain: {:+.3}\n\
         (paper §3.2: sequential transfer risks catastrophic forgetting — joint \
         training with a task core is the remedy, see `metatt mtl`)",
        task_a.name(),
        task_b.name(),
        res.forgetting_gap,
        res.roundtrip_gain
    );
    results::append_record(
        "sequential",
        &Json::obj(vec![
            ("task_a", Json::str(task_a.name())),
            ("task_b", Json::str(task_b.name())),
            ("adapter", Json::str(spec.kind.name())),
            ("forgetting_gap", Json::num(res.forgetting_gap)),
            ("roundtrip_gain", Json::num(res.roundtrip_gain)),
        ]),
    );
    Ok(())
}

fn parse_model(args: &Args) -> Result<ModelPreset> {
    ModelPreset::from_name(&args.str_or("model", "tiny")).map_err(|e| anyhow!(e))
}

fn train_config(args: &Args) -> Result<TrainConfig> {
    let mut t = TrainConfig::default();
    t.epochs = args.usize_or("epochs", t.epochs).map_err(|e| anyhow!(e))?;
    t.batch_size = args.usize_or("batch", 16).map_err(|e| anyhow!(e))?;
    t.lr = args.f32_or("lr", t.lr).map_err(|e| anyhow!(e))?;
    t.seed = args.u64_or("seed", t.seed).map_err(|e| anyhow!(e))?;
    t.train_cap = args.usize_or("train-cap", t.train_cap).map_err(|e| anyhow!(e))?;
    t.eval_cap = args.usize_or("eval-cap", t.eval_cap).map_err(|e| anyhow!(e))?;
    t.warmup_ratio = args.f32_or("warmup-ratio", t.warmup_ratio).map_err(|e| anyhow!(e))?;
    t.grad_clip = args.f32_or("grad-clip", t.grad_clip).map_err(|e| anyhow!(e))?;
    Ok(t)
}

fn ckpt_for(args: &Args, model: ModelPreset) -> Option<std::path::PathBuf> {
    if args.flag("no-checkpoint") {
        return None;
    }
    let p = checkpoint_path(model);
    if p.exists() {
        Some(p)
    } else {
        eprintln!(
            "note: {} not found — using an untrained frozen backbone \
             (run `metatt pretrain --model {}` first for paper-faithful runs)",
            p.display(),
            model.name()
        );
        None
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let backend = backend_for(args)?;
    println!("{}", backend.describe());
    for preset in [ModelPreset::Tiny, ModelPreset::Small, ModelPreset::BaseSim] {
        let p = checkpoint_path(preset);
        println!(
            "checkpoint {:>8}: {}",
            preset.name(),
            if p.exists() { "present" } else { "missing" }
        );
    }
    Ok(())
}

fn cmd_pretrain(args: &Args) -> Result<()> {
    let model = parse_model(args)?;
    let backend = backend_for(args)?;
    let cfg = PretrainConfig {
        steps: args.usize_or("steps", 600).map_err(|e| anyhow!(e))?,
        lr: args.f32_or("lr", 1e-3).map_err(|e| anyhow!(e))?,
        seed: args.u64_or("seed", 1234).map_err(|e| anyhow!(e))?,
        ..Default::default()
    };
    let res = coordinator::pretrain(backend.as_ref(), model, &cfg)?;
    results::append_record(
        "pretrain",
        &Json::obj(vec![
            ("model", Json::str(model.name())),
            ("steps", Json::num(cfg.steps as f64)),
            ("final_loss", Json::num(res.final_loss)),
            (
                "losses",
                Json::Arr(
                    res.losses
                        .iter()
                        .map(|(s, l)| Json::Arr(vec![Json::num(*s as f64), Json::num(*l)]))
                        .collect(),
                ),
            ),
        ]),
    );
    println!("final MLM loss: {:.4}", res.final_loss);
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let model = parse_model(args)?;
    let task = TaskId::from_name(&args.str_or("task", "mrpc_syn")).map_err(|e| anyhow!(e))?;
    let adapter =
        AdapterKind::from_name(&args.str_or("adapter", "metatt4d")).map_err(|e| anyhow!(e))?;
    let rank = args.usize_or("rank", 8).map_err(|e| anyhow!(e))?;
    let alpha = args.f32_or("alpha", 4.0).map_err(|e| anyhow!(e))?;
    let train = train_config(args)?;
    let init = match args.get("init") {
        Some(code) => Some(InitStrategy::from_code(code).map_err(|e| anyhow!(e))?),
        None => None,
    };
    let backend = backend_for(args)?;
    let dims = model.dims(1);
    let spec = AdapterSpec::new(adapter, rank, alpha, dims);
    println!(
        "train {} on {} (rank {}, {} params, alpha {})",
        spec.kind.name(),
        task.name(),
        rank,
        spec.param_count(),
        alpha
    );
    let ckpt = ckpt_for(args, model);
    let res = coordinator::run_single_task(
        backend.as_ref(),
        model,
        &spec,
        task,
        &train,
        alpha,
        ckpt.as_deref(),
        init.as_ref(),
    )?;
    for e in &res.epochs {
        println!(
            "epoch {:>2}  loss {:.4}  {} {:.4}",
            e.epoch,
            e.train_loss,
            task.info().metric.name(),
            e.metric
        );
    }
    println!("best {}: {:.4}", task.info().metric.name(), res.best_metric);
    results::append_record(
        "train",
        &Json::obj(vec![
            ("model", Json::str(model.name())),
            ("task", Json::str(task.name())),
            ("adapter", Json::str(spec.kind.name())),
            ("rank", Json::num(rank as f64)),
            ("alpha", Json::num(alpha as f64)),
            ("seed", Json::num(train.seed as f64)),
            ("params", Json::num(spec.param_count() as f64)),
            ("best", Json::num(res.best_metric)),
            (
                "curve",
                Json::Arr(res.epochs.iter().map(|e| Json::num(e.metric)).collect()),
            ),
        ]),
    );
    Ok(())
}

fn cmd_mtl(args: &Args) -> Result<()> {
    let model = parse_model(args)?;
    let task_names = args.str_list_or("tasks", &["cola_syn", "mrpc_syn", "rte_syn"]);
    let tasks: Vec<TaskId> = task_names
        .iter()
        .map(|n| TaskId::from_name(n))
        .collect::<Result<_, _>>()
        .map_err(|e| anyhow!(e))?;
    let adapter =
        AdapterKind::from_name(&args.str_or("adapter", "metatt4p1d")).map_err(|e| anyhow!(e))?;
    let rank = args.usize_or("rank", 8).map_err(|e| anyhow!(e))?;
    let mut cfg = MtlConfig::default();
    cfg.train = train_config(args)?;
    cfg.alpha = args.f32_or("alpha", 2.0).map_err(|e| anyhow!(e))?;
    // Paper cap is 5000/task; --train-cap lowers it for quick runs.
    cfg.per_task_cap = cfg.per_task_cap.min(cfg.train.train_cap);
    cfg.eval_cap = cfg.eval_cap.min(cfg.train.eval_cap);
    let backend = backend_for(args)?;
    let dims = model.dims(tasks.len());
    let spec = AdapterSpec::new(adapter, rank, cfg.alpha, dims);
    println!(
        "mtl {} over {:?} ({} params)",
        spec.kind.name(),
        task_names,
        spec.param_count()
    );
    let ckpt = ckpt_for(args, model);
    let res =
        coordinator::run_mtl(backend.as_ref(), model, &spec, &tasks, &cfg, ckpt.as_deref())?;
    for e in &res.epochs {
        println!(
            "epoch {:>2}  loss {:.4}  mean {:.4}  per-task {:?}",
            e.epoch,
            e.train_loss,
            e.mean_metric,
            e.metrics.iter().map(|m| (m * 1000.0).round() / 1000.0).collect::<Vec<_>>()
        );
    }
    println!("best mean metric: {:.4} {:?}", res.best_mean, res.best_per_task);
    results::append_record(
        "mtl",
        &Json::obj(vec![
            ("model", Json::str(model.name())),
            (
                "tasks",
                Json::Arr(task_names.iter().map(|t| Json::str(t.clone())).collect()),
            ),
            ("adapter", Json::str(spec.kind.name())),
            ("rank", Json::num(rank as f64)),
            ("params", Json::num(spec.param_count() as f64)),
            ("seed", Json::num(cfg.train.seed as f64)),
            ("best_mean", Json::num(res.best_mean)),
            (
                "best_per_task",
                Json::Arr(res.best_per_task.iter().map(|m| Json::num(*m)).collect()),
            ),
        ]),
    );
    Ok(())
}

fn cmd_dmrg(args: &Args) -> Result<()> {
    let model = parse_model(args)?;
    let task = TaskId::from_name(&args.str_or("task", "mrpc_syn")).map_err(|e| anyhow!(e))?;
    let adapter =
        AdapterKind::from_name(&args.str_or("adapter", "metatt5d")).map_err(|e| anyhow!(e))?;
    let mut cfg = DmrgConfig::default();
    cfg.train = train_config(args)?;
    cfg.train.lr = args.f32_or("lr", 5e-4).map_err(|e| anyhow!(e))?;
    cfg.alpha = args.f32_or("alpha", 2.0).map_err(|e| anyhow!(e))?;
    cfg.start_rank = args.usize_or("start-rank", 10).map_err(|e| anyhow!(e))?;
    if let Some(s) = args.get("schedule") {
        cfg.schedule = RankSchedule::parse(s).map_err(|e| anyhow!(e))?;
    }
    let backend = backend_for(args)?;
    let ckpt = ckpt_for(args, model);
    println!(
        "dmrg {} on {}: start rank {}, schedule {:?}",
        adapter.name(),
        task.name(),
        cfg.start_rank,
        cfg.schedule.steps
    );
    let res = coordinator::run_dmrg(backend.as_ref(), model, adapter, task, &cfg, ckpt.as_deref())?;
    for e in &res.epochs {
        println!(
            "epoch {:>2}  loss {:.4}  acc {:.4}  rank {:>2}{}{}",
            e.epoch,
            e.train_loss,
            e.metric,
            e.rank,
            if e.swept { "  [swept" } else { "" },
            if e.swept {
                format!(" drop {:.3}]", e.dropped)
            } else {
                String::new()
            }
        );
    }
    println!(
        "best at final rank {}: {:.4} ({} executables compiled)",
        res.final_rank, res.best_at_final_rank, res.executables_compiled
    );
    results::append_record(
        "dmrg",
        &Json::obj(vec![
            ("model", Json::str(model.name())),
            ("task", Json::str(task.name())),
            ("adapter", Json::str(adapter.name())),
            ("start_rank", Json::num(cfg.start_rank as f64)),
            ("seed", Json::num(cfg.train.seed as f64)),
            ("best_final", Json::num(res.best_at_final_rank)),
            (
                "curve",
                Json::Arr(
                    res.epochs
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("metric", Json::num(e.metric)),
                                ("rank", Json::num(e.rank as f64)),
                                ("swept", Json::Bool(e.swept)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use metatt::tensor::Tensor;
    use metatt::util::rng::Pcg64;
    let requests = args.usize_or("requests", 100).map_err(|e| anyhow!(e))?;
    let rank = args.usize_or("rank", 8).map_err(|e| anyhow!(e))?;
    let adapter = args.str_or("adapter", "metatt4d");
    let backend = backend_for(args)?;
    let spec = backend.apply_spec(&adapter, rank)?;
    let entry = backend.entry(&spec)?;
    let runner = backend.bind(&spec, &Default::default())?;
    let mut rng = Pcg64::new(1);
    let inputs: Vec<Tensor> = entry
        .inputs
        .iter()
        .map(|io| Tensor::randn(&io.shape, 0.5, &mut rng))
        .collect();
    let t0 = std::time::Instant::now();
    for _ in 0..requests {
        let out = runner.run_raw(&inputs)?;
        std::hint::black_box(out);
    }
    let dt = t0.elapsed().as_secs_f64();
    let n = entry.inputs[0].shape[0];
    println!(
        "served {requests} apply calls ({} tokens each) in {:.3}s — {:.1} req/s, {:.1}k tok/s",
        n,
        dt,
        requests as f64 / dt,
        requests as f64 * n as f64 / dt / 1e3
    );
    Ok(())
}
