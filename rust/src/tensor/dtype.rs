//! Storage dtypes for the packed-operand seam (PR 7).
//!
//! The serving hot path is memory-bandwidth-bound: every tick streams the
//! frozen packed-B panels and the folded adapter factors from RAM. The
//! [`Dtype`] trait lets those *stored* operands shrink to bf16 (2 bytes)
//! or int8-with-per-panel-scale (1 byte + 4 bytes per NR-panel) while the
//! A-side activations and every accumulation chain stay f32 — the
//! microkernel widens each stored element back to f32 (`decode`) before
//! the multiply, so the k-ascending per-element accumulation order of the
//! bit-determinism contract is untouched.
//!
//! Dtype is a property of a *packed panel*, chosen once at bind/fold time;
//! nothing in the train path or the dense kernels changes. The f32
//! instance is the identity encoding (copy in, copy out, scale ignored),
//! which is what keeps the f32 packed path the bit-exact oracle: its
//! `decode` compiles to a no-op and the generic kernels specialize to the
//! exact pre-PR-7 instruction stream.
//!
//! Quantization error contract (pinned by the unit tests below and by the
//! serving parity tests in `tests/serving.rs`):
//!
//! * **bf16** — round-to-nearest-even truncation of the top 16 bits; with
//!   7 explicit mantissa bits the half-ulp error is at most 2⁻⁸ of the
//!   element's magnitude. The per-panel scale is unused (always 1.0).
//! * **int8** — symmetric per-panel scaling: `scale = max|panel| / 127`,
//!   elements round to the nearest step, so `|decode(q) − v| ≤ scale / 2`
//!   for every in-range element. A zero (or non-finite-max) panel encodes
//!   with scale 1.0, mapping every finite element of an all-zero panel to
//!   exactly 0.

/// A storage dtype for packed GEMM operands. Implementations encode one
/// NR-panel at a time ([`Dtype::quantize_panel`], which reports the panel's
/// scale) and decode one element at a time inside the microkernel
/// ([`Dtype::decode`]). All arithmetic downstream of `decode` is f32.
///
/// The `Default` bound doubles as the zero-initialization contract:
/// `T::default()` must be the encoding of 0.0 and must be all-zero bytes
/// (the aligned pack buffers are `alloc_zeroed`).
pub trait Dtype: Copy + Send + Sync + std::fmt::Debug + Default + 'static {
    /// Bytes per stored element (what the bandwidth telemetry counts).
    const BYTES: usize;

    /// Encode `src` into `dst` (same length), returning the panel scale to
    /// pass back into [`Dtype::decode`] for every element of this panel.
    fn quantize_panel(src: &[f32], dst: &mut [Self]) -> f32;

    /// Widen one stored element back to f32 given its panel scale.
    fn decode(self, scale: f32) -> f32;
}

impl Dtype for f32 {
    const BYTES: usize = 4;

    fn quantize_panel(src: &[f32], dst: &mut [f32]) -> f32 {
        dst.copy_from_slice(src);
        1.0
    }

    #[inline(always)]
    fn decode(self, _scale: f32) -> f32 {
        self
    }
}

/// bfloat16: the top 16 bits of an f32 (1 sign, 8 exponent, 7 mantissa),
/// converted with round-to-nearest-even. Same dynamic range as f32, ~2–3
/// decimal digits of precision — the standard inference storage format.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Bf16(pub u16);

impl Bf16 {
    /// Round-to-nearest-even truncation; NaN payloads are forced quiet so
    /// the result is never an infinity-by-truncation.
    pub fn from_f32(v: f32) -> Bf16 {
        let bits = v.to_bits();
        if v.is_nan() {
            return Bf16(((bits >> 16) as u16) | 0x0040);
        }
        let lsb = (bits >> 16) & 1;
        Bf16(((bits + 0x7FFF + lsb) >> 16) as u16)
    }

    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }
}

impl Dtype for Bf16 {
    const BYTES: usize = 2;

    fn quantize_panel(src: &[f32], dst: &mut [Bf16]) -> f32 {
        debug_assert_eq!(src.len(), dst.len());
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = Bf16::from_f32(s);
        }
        1.0
    }

    #[inline(always)]
    fn decode(self, _scale: f32) -> f32 {
        self.to_f32()
    }
}

impl Dtype for i8 {
    const BYTES: usize = 1;

    /// Symmetric per-panel quantization: `scale = max|panel| / 127`,
    /// elements round to the nearest step and clamp to ±127 (the −128 code
    /// is unused so the grid is symmetric). Degenerate panels (all zero,
    /// or a non-finite max) take scale 1.0.
    fn quantize_panel(src: &[f32], dst: &mut [i8]) -> f32 {
        debug_assert_eq!(src.len(), dst.len());
        let max = src.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let scale = if max.is_finite() && max > 0.0 { max / 127.0 } else { 1.0 };
        let inv = 1.0 / scale;
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = (s * inv).round().clamp(-127.0, 127.0) as i8;
        }
        scale
    }

    #[inline(always)]
    fn decode(self, scale: f32) -> f32 {
        self as f32 * scale
    }
}

/// Runtime dtype selector: what `--serve-dtype` parses into and what the
/// bind-time packed caches key on. Maps 1:1 onto the [`Dtype`] instances.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum DtypeKind {
    #[default]
    F32,
    Bf16,
    I8,
}

impl DtypeKind {
    /// Parse a CLI/metadata name. Accepts the canonical names only, so a
    /// checkpoint written by a newer writer fails loudly rather than
    /// silently serving the wrong precision.
    pub fn from_name(name: &str) -> Option<DtypeKind> {
        match name {
            "f32" => Some(DtypeKind::F32),
            "bf16" => Some(DtypeKind::Bf16),
            "int8" => Some(DtypeKind::I8),
            _ => None,
        }
    }

    /// Canonical name (round-trips through [`DtypeKind::from_name`]).
    pub fn name(self) -> &'static str {
        match self {
            DtypeKind::F32 => "f32",
            DtypeKind::Bf16 => "bf16",
            DtypeKind::I8 => "int8",
        }
    }

    /// Bytes per stored element.
    pub fn bytes(self) -> usize {
        match self {
            DtypeKind::F32 => f32::BYTES,
            DtypeKind::Bf16 => Bf16::BYTES,
            DtypeKind::I8 => i8::BYTES,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_panel(rng: &mut Pcg64, n: usize, std: f32) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, std);
        v
    }

    #[test]
    fn f32_roundtrip_is_identity() {
        let mut rng = Pcg64::new(71);
        let src = random_panel(&mut rng, 64, 3.0);
        let mut dst = vec![0.0f32; 64];
        let scale = f32::quantize_panel(&src, &mut dst);
        assert_eq!(scale, 1.0);
        for (&s, &d) in src.iter().zip(&dst) {
            assert_eq!(s.to_bits(), d.decode(scale).to_bits());
        }
    }

    #[test]
    fn bf16_known_values_and_rne() {
        assert_eq!(Bf16::from_f32(1.0).0, 0x3F80);
        assert_eq!(Bf16::from_f32(-2.0).0, 0xC000);
        assert_eq!(Bf16::from_f32(0.0).0, 0x0000);
        // Exactly-halfway values round to even: 1.0 + 2^-8 sits halfway
        // between bf16 neighbours 1.0 (0x3F80, even) and 1.0078125
        // (0x3F81, odd) and must land on the even one.
        let halfway = f32::from_bits(0x3F80_8000);
        assert_eq!(Bf16::from_f32(halfway).0, 0x3F80);
        // Just above halfway rounds up.
        let above = f32::from_bits(0x3F80_8001);
        assert_eq!(Bf16::from_f32(above).0, 0x3F81);
        // NaN survives (quiet), never truncates to an infinity.
        assert!(Bf16::from_f32(f32::NAN).to_f32().is_nan());
    }

    #[test]
    fn bf16_roundtrip_relative_error_bound() {
        let mut rng = Pcg64::new(72);
        for &std in &[0.02f32, 1.0, 750.0] {
            let src = random_panel(&mut rng, 256, std);
            let mut dst = vec![Bf16::default(); 256];
            let scale = Bf16::quantize_panel(&src, &mut dst);
            for (&s, &d) in src.iter().zip(&dst) {
                let err = (d.decode(scale) - s).abs();
                assert!(
                    err <= s.abs() * (1.0 / 256.0) + f32::MIN_POSITIVE,
                    "bf16 {s} -> {} err {err}",
                    d.decode(scale)
                );
            }
        }
    }

    #[test]
    fn int8_roundtrip_half_step_bound() {
        let mut rng = Pcg64::new(73);
        for &std in &[0.005f32, 1.0, 40.0] {
            let src = random_panel(&mut rng, 256, std);
            let mut dst = vec![0i8; 256];
            let scale = i8::quantize_panel(&src, &mut dst);
            assert!(scale > 0.0 && scale.is_finite());
            let max = src.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            assert!((scale - max / 127.0).abs() <= max * 1e-6);
            for (&s, &q) in src.iter().zip(&dst) {
                let err = (q.decode(scale) - s).abs();
                // Nearest-step rounding: within half a quantization step
                // (a hair of slack for the f32 divide/multiply round trip).
                assert!(err <= scale * 0.5 + scale * 1e-5, "int8 {s} err {err} scale {scale}");
            }
        }
    }

    #[test]
    fn int8_degenerate_panels() {
        // All-zero panel: scale 1.0, every code 0.
        let src = vec![0.0f32; 16];
        let mut dst = vec![7i8; 16];
        let scale = i8::quantize_panel(&src, &mut dst);
        assert_eq!(scale, 1.0);
        assert!(dst.iter().all(|&q| q == 0));
        // The max element encodes to exactly ±127 and decodes to the max.
        let src = vec![-4.0f32, 2.0, 4.0, 0.0];
        let mut dst = vec![0i8; 4];
        let scale = i8::quantize_panel(&src, &mut dst);
        assert_eq!(dst[0], -127);
        assert_eq!(dst[2], 127);
        assert!((dst[2].decode(scale) - 4.0).abs() < 1e-6);
    }

    #[test]
    fn kind_names_round_trip() {
        for kind in [DtypeKind::F32, DtypeKind::Bf16, DtypeKind::I8] {
            assert_eq!(DtypeKind::from_name(kind.name()), Some(kind));
        }
        assert_eq!(DtypeKind::from_name("fp16"), None);
        assert_eq!(DtypeKind::F32.bytes(), 4);
        assert_eq!(DtypeKind::Bf16.bytes(), 2);
        assert_eq!(DtypeKind::I8.bytes(), 1);
    }
}
