//! Dense f32 tensor substrate.
//!
//! The rust side of the stack needs host-side numerics for everything the
//! HLO artifacts do *not* cover — and, since the pure-rust reference
//! backend became the default executor, for the full training hot path
//! too: the DMRG sweep (merge / SVD / truncate / re-split of TT cores),
//! optimizer state, adapter materialization checks, metric computation,
//! and every encoder GEMM. The matmul family is a packed register-tiled
//! (BLIS-style) kernel (`ops`), its panel scratch comes 64-byte-aligned
//! from the step workspace arena (`workspace`), and both preserve the
//! crate-wide bit-determinism contract: thread count, arena mode, and
//! packing change *where* work runs, never a single output bit.

mod dtype;
mod ops;
mod workspace;

pub use dtype::{Bf16, Dtype, DtypeKind};
pub use ops::*;
pub use workspace::{AlignedBuf, PackScratch, Workspace};

/// Row-major dense f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Tensor filled with `v`.
    pub fn full(shape: &[usize], v: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    /// Build from shape + data (length must match).
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data len {}",
            shape,
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    /// 2-D identity matrix.
    pub fn eye(n: usize) -> Tensor {
        let mut t = Tensor::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    /// Identity-like rectangular matrix (ones on the main diagonal).
    pub fn eye_rect(rows: usize, cols: usize) -> Tensor {
        let mut t = Tensor::zeros(&[rows, cols]);
        for i in 0..rows.min(cols) {
            t.data[i * cols + i] = 1.0;
        }
        t
    }

    /// Gaussian-filled tensor, N(0, std).
    pub fn randn(shape: &[usize], std: f32, rng: &mut crate::util::rng::Pcg64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, std);
        t
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Number of rows of a 2-D tensor.
    pub fn rows(&self) -> usize {
        assert_eq!(self.ndim(), 2, "rows() needs a matrix, got {:?}", self.shape);
        self.shape[0]
    }

    /// Number of columns of a 2-D tensor.
    pub fn cols(&self) -> usize {
        assert_eq!(self.ndim(), 2, "cols() needs a matrix, got {:?}", self.shape);
        self.shape[1]
    }

    /// Matrix element accessor.
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// Matrix element mutator.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.ndim(), 2);
        let cols = self.shape[1];
        self.data[i * cols + j] = v;
    }

    /// 3-D element accessor (used by TT cores, shape [r_left, n, r_right]).
    #[inline]
    pub fn at3(&self, i: usize, j: usize, k: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 3);
        self.data[(i * self.shape[1] + j) * self.shape[2] + k]
    }

    #[inline]
    pub fn set3(&mut self, i: usize, j: usize, k: usize, v: f32) {
        debug_assert_eq!(self.ndim(), 3);
        let (s1, s2) = (self.shape[1], self.shape[2]);
        self.data[(i * s1 + j) * s2 + k] = v;
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(&self, shape: &[usize]) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "reshape {:?} -> {:?} changes element count",
            self.shape,
            shape
        );
        Tensor { shape: shape.to_vec(), data: self.data.clone() }
    }

    /// In-place reshape (no copy).
    pub fn reshape_inplace(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32
    }

    /// Count of non-zero elements (used for the paper's `‖∇G‖_F/√|G|`
    /// normalized-gradient diagnostic, Appendix B).
    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|&&x| x != 0.0).count()
    }

    /// Max |x|.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// True if all elements are finite.
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn construction_and_accessors() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.at(1, 2), 6.0);
        let e = Tensor::eye(3);
        assert_eq!(e.at(2, 2), 1.0);
        assert_eq!(e.at(0, 1), 0.0);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0; 5]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|x| x as f32).collect());
        let r = t.reshape(&[3, 2]);
        assert_eq!(r.data(), t.data());
        assert_eq!(r.shape(), &[3, 2]);
    }

    #[test]
    fn fro_norm_matches_manual() {
        let t = Tensor::from_vec(&[2, 2], vec![3., 4., 0., 0.]);
        assert!((t.fro_norm() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn randn_is_seed_deterministic() {
        let a = Tensor::randn(&[4, 4], 1.0, &mut Pcg64::new(3));
        let b = Tensor::randn(&[4, 4], 1.0, &mut Pcg64::new(3));
        assert_eq!(a, b);
    }

    #[test]
    fn at3_layout() {
        let t = Tensor::from_vec(&[2, 2, 2], (0..8).map(|x| x as f32).collect());
        assert_eq!(t.at3(1, 0, 1), 5.0);
        assert_eq!(t.at3(0, 1, 0), 2.0);
    }
}
