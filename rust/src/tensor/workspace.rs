//! Step-scoped workspace arena: a bump-style pool of reusable f32 buffers.
//!
//! The reference backend's hot loop (train / eval / apply steps) used to
//! heap-allocate every intermediate tensor of every step. The [`Workspace`]
//! turns that traffic into pool checkouts: [`Workspace::take`] hands out a
//! zero-filled [`Tensor`] — reusing a previously recycled buffer of the same
//! element count when one is available — and [`Workspace::recycle`] returns
//! a tensor's storage to the pool. After a one-step warmup every shape the
//! step touches has a pooled buffer, so the steady-state step performs no
//! heap allocations (pinned by `tests/alloc_regression.rs`).
//!
//! **Determinism contract:** a pooled checkout is indistinguishable from a
//! fresh `Tensor::zeros` — same shape, same zero fill — so arena-on and
//! arena-off runs are bit-identical (`tests/determinism.rs`). The arena is
//! per-bound-step (behind the step's mutex), never shared across threads;
//! parallel regions only ever see raw slices of checked-out buffers.
//!
//! Buffers are keyed by *element count*, not shape: an `[n, d]` buffer can
//! be reissued as `[b·h, s, dh]`. Shape vectors are retained alongside the
//! data (a `Vec<usize>` is a heap allocation too) and normalized to
//! [`MAX_NDIM`] capacity on recycle so reshaping a pooled buffer to a
//! higher-rank shape never reallocates in steady state.

use super::Tensor;
use std::collections::HashMap;

/// Highest tensor rank the crate uses (LoRA params are `[l, m, d, r]`).
/// Pooled shape vectors are grown to this capacity once, on recycle.
const MAX_NDIM: usize = 4;

/// Pool of reusable tensor buffers plus spare `Vec<Tensor>` containers.
#[derive(Debug, Default)]
pub struct Workspace {
    enabled: bool,
    /// Free tensors keyed by element count.
    free: HashMap<usize, Vec<Tensor>>,
    /// Spare tensor-vector containers (capacity preserved across steps).
    spare_vecs: Vec<Vec<Tensor>>,
    takes: u64,
    hits: u64,
}

impl Workspace {
    /// A workspace; `enabled = false` degrades every checkout to a plain
    /// allocation (the arena-off reference mode the determinism suite
    /// compares against).
    pub fn new(enabled: bool) -> Workspace {
        Workspace { enabled, ..Default::default() }
    }

    /// Whether checkouts actually pool (vs plain allocation).
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Zero-filled tensor of `shape`, reusing a pooled buffer of the same
    /// element count when available.
    pub fn take(&mut self, shape: &[usize]) -> Tensor {
        let numel: usize = shape.iter().product();
        if !self.enabled || numel == 0 {
            return Tensor::zeros(shape);
        }
        self.takes += 1;
        if let Some(list) = self.free.get_mut(&numel) {
            if let Some(mut t) = list.pop() {
                self.hits += 1;
                t.data.fill(0.0);
                t.shape.clear();
                t.shape.extend_from_slice(shape);
                return t;
            }
        }
        Tensor::zeros(shape)
    }

    /// Return a tensor's storage (data + shape vector) to the pool.
    pub fn recycle(&mut self, mut t: Tensor) {
        if !self.enabled || t.data.is_empty() {
            return;
        }
        // Normalize the shape vector's capacity once so a later `take` with
        // a higher-rank shape extends in place instead of reallocating.
        if t.shape.capacity() < MAX_NDIM {
            let extra = MAX_NDIM - t.shape.len();
            t.shape.reserve(extra);
        }
        self.free.entry(t.data.len()).or_default().push(t);
    }

    /// Recycle every tensor of an iterator.
    pub fn recycle_all(&mut self, ts: impl IntoIterator<Item = Tensor>) {
        for t in ts {
            self.recycle(t);
        }
    }

    /// Check out an empty `Vec<Tensor>` container (capacity preserved from
    /// a prior [`Workspace::recycle_vec`]).
    pub fn take_vec(&mut self) -> Vec<Tensor> {
        self.spare_vecs.pop().unwrap_or_default()
    }

    /// Recycle the tensors of `v` and keep the emptied container for reuse.
    pub fn recycle_vec(&mut self, mut v: Vec<Tensor>) {
        for t in v.drain(..) {
            self.recycle(t);
        }
        if self.enabled {
            self.spare_vecs.push(v);
        }
    }

    /// Number of buffers currently pooled.
    pub fn pooled_tensors(&self) -> usize {
        self.free.values().map(|v| v.len()).sum()
    }

    /// Total pooled f32 payload in bytes.
    pub fn pooled_bytes(&self) -> usize {
        self.free
            .iter()
            .map(|(numel, v)| numel * v.len() * std::mem::size_of::<f32>())
            .sum()
    }

    /// (checkouts, pool hits) since construction — the warmup telemetry.
    pub fn stats(&self) -> (u64, u64) {
        (self.takes, self.hits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_matches_fresh_zeros() {
        let mut ws = Workspace::new(true);
        let a = ws.take(&[3, 4]);
        assert_eq!(a, Tensor::zeros(&[3, 4]));
        ws.recycle(a);
        // Recycled buffer comes back zeroed even after being dirtied.
        let mut b = ws.take(&[4, 3]);
        assert_eq!(b, Tensor::zeros(&[4, 3]));
        b.data_mut()[5] = 7.0;
        ws.recycle(b);
        let c = ws.take(&[2, 6]);
        assert_eq!(c, Tensor::zeros(&[2, 6]));
    }

    #[test]
    fn pool_reuses_by_element_count() {
        let mut ws = Workspace::new(true);
        let a = ws.take(&[8, 8]);
        ws.recycle(a);
        let _b = ws.take(&[4, 16]); // same numel, different shape: pool hit
        let (takes, hits) = ws.stats();
        assert_eq!(takes, 2);
        assert_eq!(hits, 1);
        assert_eq!(ws.pooled_tensors(), 0);
    }

    #[test]
    fn rank_growth_after_recycle_normalization() {
        let mut ws = Workspace::new(true);
        // A 2-D buffer reissued as 4-D must not need a bigger shape vec.
        let a = ws.take(&[4, 4]);
        ws.recycle(a);
        let b = ws.take(&[2, 2, 2, 2]);
        assert_eq!(b.shape(), &[2, 2, 2, 2]);
        assert_eq!(b.len(), 16);
    }

    #[test]
    fn disabled_workspace_is_plain_allocation() {
        let mut ws = Workspace::new(false);
        let a = ws.take(&[5, 5]);
        assert_eq!(a, Tensor::zeros(&[5, 5]));
        ws.recycle(a);
        assert_eq!(ws.pooled_tensors(), 0);
        let (takes, hits) = ws.stats();
        assert_eq!((takes, hits), (0, 0));
    }

    #[test]
    fn vec_containers_round_trip() {
        let mut ws = Workspace::new(true);
        let mut v = ws.take_vec();
        v.push(ws.take(&[2, 2]));
        v.push(ws.take(&[3]));
        ws.recycle_vec(v);
        assert_eq!(ws.pooled_tensors(), 2);
        let v2 = ws.take_vec();
        assert!(v2.is_empty());
        assert!(v2.capacity() >= 2, "container capacity must be preserved");
    }

    #[test]
    fn zero_sized_shapes_are_not_pooled() {
        let mut ws = Workspace::new(true);
        let a = ws.take(&[0, 5]);
        assert!(a.is_empty());
        ws.recycle(a);
        assert_eq!(ws.pooled_tensors(), 0);
    }
}
