//! Step-scoped workspace arena: a bump-style pool of reusable f32 buffers.
//!
//! The reference backend's hot loop (train / eval / apply steps) used to
//! heap-allocate every intermediate tensor of every step. The [`Workspace`]
//! turns that traffic into pool checkouts: [`Workspace::take`] hands out a
//! zero-filled [`Tensor`] — reusing a previously recycled buffer of the same
//! element count when one is available — and [`Workspace::recycle`] returns
//! a tensor's storage to the pool. After a one-step warmup every shape the
//! step touches has a pooled buffer, so the steady-state step performs no
//! heap allocations (pinned by `tests/alloc_regression.rs`).
//!
//! **Determinism contract:** a pooled checkout is indistinguishable from a
//! fresh `Tensor::zeros` — same shape, same zero fill — so arena-on and
//! arena-off runs are bit-identical (`tests/determinism.rs`). The arena is
//! per-bound-step (behind the step's mutex), never shared across threads;
//! parallel regions only ever see raw slices of checked-out buffers.
//!
//! Buffers are keyed by *element count*, not shape: an `[n, d]` buffer can
//! be reissued as `[b·h, s, dh]`. Shape vectors are retained alongside the
//! data (a `Vec<usize>` is a heap allocation too) and normalized to
//! [`MAX_NDIM`] capacity on recycle so reshaping a pooled buffer to a
//! higher-rank shape never reallocates in steady state.
//!
//! **Pack buffers (PR 4).** The packed GEMM family
//! ([`crate::tensor::matmul_into`] and siblings) needs two panel-packing
//! scratch buffers per product. Those checkouts come from the workspace's
//! [`PackScratch`] — a grow-only pair of [`AlignedBuf`]s, **64-byte
//! aligned** (cache-line / SIMD alignment) and recycled in place across
//! GEMMs, so packing never heap-allocates in steady state and every
//! recycled checkout stays aligned (asserted by the unit tests here).
//! Plain [`Workspace::take`] tensor checkouts intentionally keep their
//! `Vec<f32>` storage (element alignment only): `Tensor::from_vec` /
//! `into_vec` are zero-copy public API, and `Vec` cannot carry a stronger
//! alignment — the bandwidth-critical panel buffers are where the 64-byte
//! guarantee pays, so that is where it lives.

use super::Tensor;
use std::alloc::Layout;
use std::collections::HashMap;

/// Highest tensor rank the crate uses (LoRA params are `[l, m, d, r]`).
/// Pooled shape vectors are grown to this capacity once, on recycle.
const MAX_NDIM: usize = 4;

// ---------------------------------------------------------------------------
// Aligned scratch storage for the GEMM pack panels.
// ---------------------------------------------------------------------------

/// A grow-only scratch buffer whose storage is always **64-byte aligned**
/// (64 bytes, `AlignedBuf::ALIGN`). `Vec<T>` cannot guarantee more than the
/// element alignment, so the pack buffers of the packed GEMM kernels —
/// which want cache-line-aligned, SIMD-friendly panels — use this type
/// instead. Growth discards contents (it is scratch, fully rewritten by
/// every pack) and the capacity never shrinks, so steady-state reuse
/// performs no heap allocation.
///
/// Generic over the stored element (PR 7): pack scratch stays
/// `AlignedBuf<f32>` (the default), while bind-time packed panels store
/// any [`crate::tensor::Dtype`]. The element must be `Copy` and treat
/// all-zero bytes as a valid value (`alloc_zeroed` is the initializer) —
/// true for `f32`, [`crate::tensor::Bf16`] and `i8`.
#[derive(Debug)]
pub struct AlignedBuf<T = f32> {
    ptr: *mut T,
    cap: usize,
}

// SAFETY: AlignedBuf is an owning handle to a unique allocation; mutation
// goes through `&mut self`, so moving the handle across threads is sound
// whenever the element itself is Send.
unsafe impl<T: Send> Send for AlignedBuf<T> {}

// SAFETY: shared references only expose reads (`as_slice` / `capacity` /
// `as_ptr`); every write path takes `&mut self`, so `&AlignedBuf` can be
// shared across threads like any read-only slice of a Sync element.
unsafe impl<T: Sync> Sync for AlignedBuf<T> {}

impl<T> AlignedBuf<T> {
    /// Alignment (bytes) of every allocation: one x86 cache line, and a
    /// superset of every vector-register alignment the kernels could want.
    pub const ALIGN: usize = 64;

    pub fn new() -> AlignedBuf<T> {
        AlignedBuf { ptr: std::ptr::null_mut(), cap: 0 }
    }

    fn layout(cap: usize) -> Layout {
        Layout::from_size_align(cap * std::mem::size_of::<T>(), Self::ALIGN)
            .expect("aligned-buffer layout")
    }

    fn release(&mut self) {
        if !self.ptr.is_null() {
            // SAFETY: `ptr` was allocated with exactly this layout.
            unsafe { std::alloc::dealloc(self.ptr as *mut u8, Self::layout(self.cap)) };
            self.ptr = std::ptr::null_mut();
            self.cap = 0;
        }
    }
}

impl<T: Copy> AlignedBuf<T> {

    /// Current capacity in elements.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Storage pointer (for alignment assertions; null while empty).
    pub fn as_ptr(&self) -> *const T {
        self.ptr
    }

    /// Read-only view of the first `n` elements (`n` must be within the
    /// current capacity). Storage is zero-initialized at allocation and
    /// only ever written through `slice_to`, so the view is always
    /// initialized. This is what lets a pre-packed GEMM operand
    /// ([`crate::tensor::PackedB`]) be *shared* across worker bands: reads
    /// need only `&self`.
    pub fn as_slice(&self, n: usize) -> &[T] {
        if n == 0 {
            return &[];
        }
        assert!(n <= self.cap, "as_slice({n}) beyond capacity {}", self.cap);
        // SAFETY: `ptr` is a live allocation of `cap >= n` initialized
        // elements; shared borrows of self forbid concurrent mutation.
        unsafe { std::slice::from_raw_parts(self.ptr, n) }
    }

    /// Mutable view of the first `n` elements, growing (re-allocating
    /// aligned) when `n` exceeds the capacity. Contents are unspecified
    /// after growth — callers fully overwrite the region they use.
    pub fn slice_to(&mut self, n: usize) -> &mut [T] {
        if n == 0 {
            return &mut [];
        }
        if n > self.cap {
            self.grow(n);
        }
        // SAFETY: `ptr` is a live allocation of `cap >= n` elements (zeroed
        // at allocation time, hence initialized — zero bytes are a valid
        // value by the type's contract), uniquely borrowed via &mut.
        unsafe { std::slice::from_raw_parts_mut(self.ptr, n) }
    }

    fn grow(&mut self, n: usize) {
        // SAFETY: the layout has non-zero size (n > 0 checked by callers,
        // and the stored dtypes are never zero-sized).
        let fresh = unsafe { std::alloc::alloc_zeroed(Self::layout(n)) } as *mut T;
        assert!(!fresh.is_null(), "aligned pack-buffer allocation failed ({n} elements)");
        self.release();
        self.ptr = fresh;
        self.cap = n;
    }
}

impl<T> Default for AlignedBuf<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Drop for AlignedBuf<T> {
    fn drop(&mut self) {
        self.release();
    }
}

/// The pack-buffer pair of the packed GEMM family: an A-side (MR-panel) and
/// a B-side (NR-panel) scratch buffer. Checkouts through
/// [`PackScratch::for_shape`] are 64-byte aligned and grow-only — after a
/// warmup step every GEMM shape the step issues fits the pooled capacity,
/// preserving the zero-allocation hot-path invariant
/// (`tests/alloc_regression.rs`).
#[derive(Debug, Default)]
pub struct PackScratch {
    a: AlignedBuf,
    b: AlignedBuf,
}

impl PackScratch {
    pub fn new() -> PackScratch {
        PackScratch::default()
    }

    /// The (A-pack, B-pack) buffers sized for an `(m × k) · (k × n)`
    /// product. Orientation does not matter: transposed operands pack into
    /// the same panel sizes ([`crate::tensor::pack_sizes`]) — the packer
    /// absorbs the transpose on the read side.
    pub fn for_shape(&mut self, m: usize, k: usize, n: usize) -> (&mut [f32], &mut [f32]) {
        let (an, bn) = super::ops::pack_sizes(m, k, n);
        let PackScratch { a, b } = self;
        (a.slice_to(an), b.slice_to(bn))
    }
}

/// Pool of reusable tensor buffers plus spare `Vec<Tensor>` containers and
/// the step's GEMM pack scratch.
#[derive(Debug, Default)]
pub struct Workspace {
    enabled: bool,
    /// Free tensors keyed by element count.
    free: HashMap<usize, Vec<Tensor>>,
    /// Spare tensor-vector containers (capacity preserved across steps).
    spare_vecs: Vec<Vec<Tensor>>,
    /// Aligned pack-buffer pair for the packed GEMM kernels. Scratch, not
    /// observable state: it is reused even when the arena is disabled (the
    /// kernels fully overwrite the regions they read back, so arena-off
    /// results are still bit-identical).
    packs: PackScratch,
    takes: u64,
    hits: u64,
}

impl Workspace {
    /// A workspace; `enabled = false` degrades every checkout to a plain
    /// allocation (the arena-off reference mode the determinism suite
    /// compares against).
    pub fn new(enabled: bool) -> Workspace {
        Workspace { enabled, ..Default::default() }
    }

    /// Whether checkouts actually pool (vs plain allocation).
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Zero-filled tensor of `shape`, reusing a pooled buffer of the same
    /// element count when available.
    pub fn take(&mut self, shape: &[usize]) -> Tensor {
        let numel: usize = shape.iter().product();
        if !self.enabled || numel == 0 {
            return Tensor::zeros(shape);
        }
        self.takes += 1;
        if let Some(list) = self.free.get_mut(&numel) {
            if let Some(mut t) = list.pop() {
                self.hits += 1;
                t.data.fill(0.0);
                t.shape.clear();
                t.shape.extend_from_slice(shape);
                return t;
            }
        }
        Tensor::zeros(shape)
    }

    /// Return a tensor's storage (data + shape vector) to the pool.
    pub fn recycle(&mut self, mut t: Tensor) {
        if !self.enabled || t.data.is_empty() {
            return;
        }
        // Normalize the shape vector's capacity once so a later `take` with
        // a higher-rank shape extends in place instead of reallocating.
        if t.shape.capacity() < MAX_NDIM {
            let extra = MAX_NDIM - t.shape.len();
            t.shape.reserve(extra);
        }
        self.free.entry(t.data.len()).or_default().push(t);
    }

    /// Recycle every tensor of an iterator.
    pub fn recycle_all(&mut self, ts: impl IntoIterator<Item = Tensor>) {
        for t in ts {
            self.recycle(t);
        }
    }

    /// Check out an empty `Vec<Tensor>` container (capacity preserved from
    /// a prior [`Workspace::recycle_vec`]).
    pub fn take_vec(&mut self) -> Vec<Tensor> {
        self.spare_vecs.pop().unwrap_or_default()
    }

    /// Recycle the tensors of `v` and keep the emptied container for reuse.
    pub fn recycle_vec(&mut self, mut v: Vec<Tensor>) {
        for t in v.drain(..) {
            self.recycle(t);
        }
        if self.enabled {
            self.spare_vecs.push(v);
        }
    }

    /// Number of buffers currently pooled.
    pub fn pooled_tensors(&self) -> usize {
        self.free.values().map(|v| v.len()).sum()
    }

    /// Total pooled f32 payload in bytes.
    pub fn pooled_bytes(&self) -> usize {
        self.free
            .iter()
            .map(|(numel, v)| numel * v.len() * std::mem::size_of::<f32>())
            .sum()
    }

    /// (checkouts, pool hits) since construction — the warmup telemetry.
    pub fn stats(&self) -> (u64, u64) {
        (self.takes, self.hits)
    }

    /// The step's GEMM pack scratch (aligned A/B panel buffers). Handed to
    /// the `*_into` kernels at every workspace-reachable call site so pack
    /// buffers come from the arena rather than per-call allocations.
    pub fn packs(&mut self) -> &mut PackScratch {
        &mut self.packs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_matches_fresh_zeros() {
        let mut ws = Workspace::new(true);
        let a = ws.take(&[3, 4]);
        assert_eq!(a, Tensor::zeros(&[3, 4]));
        ws.recycle(a);
        // Recycled buffer comes back zeroed even after being dirtied.
        let mut b = ws.take(&[4, 3]);
        assert_eq!(b, Tensor::zeros(&[4, 3]));
        b.data_mut()[5] = 7.0;
        ws.recycle(b);
        let c = ws.take(&[2, 6]);
        assert_eq!(c, Tensor::zeros(&[2, 6]));
    }

    #[test]
    fn pool_reuses_by_element_count() {
        let mut ws = Workspace::new(true);
        let a = ws.take(&[8, 8]);
        ws.recycle(a);
        let _b = ws.take(&[4, 16]); // same numel, different shape: pool hit
        let (takes, hits) = ws.stats();
        assert_eq!(takes, 2);
        assert_eq!(hits, 1);
        assert_eq!(ws.pooled_tensors(), 0);
    }

    #[test]
    fn rank_growth_after_recycle_normalization() {
        let mut ws = Workspace::new(true);
        // A 2-D buffer reissued as 4-D must not need a bigger shape vec.
        let a = ws.take(&[4, 4]);
        ws.recycle(a);
        let b = ws.take(&[2, 2, 2, 2]);
        assert_eq!(b.shape(), &[2, 2, 2, 2]);
        assert_eq!(b.len(), 16);
    }

    #[test]
    fn disabled_workspace_is_plain_allocation() {
        let mut ws = Workspace::new(false);
        let a = ws.take(&[5, 5]);
        assert_eq!(a, Tensor::zeros(&[5, 5]));
        ws.recycle(a);
        assert_eq!(ws.pooled_tensors(), 0);
        let (takes, hits) = ws.stats();
        assert_eq!((takes, hits), (0, 0));
    }

    #[test]
    fn vec_containers_round_trip() {
        let mut ws = Workspace::new(true);
        let mut v = ws.take_vec();
        v.push(ws.take(&[2, 2]));
        v.push(ws.take(&[3]));
        ws.recycle_vec(v);
        assert_eq!(ws.pooled_tensors(), 2);
        let v2 = ws.take_vec();
        assert!(v2.is_empty());
        assert!(v2.capacity() >= 2, "container capacity must be preserved");
    }

    #[test]
    fn zero_sized_shapes_are_not_pooled() {
        let mut ws = Workspace::new(true);
        let a = ws.take(&[0, 5]);
        assert!(a.is_empty());
        ws.recycle(a);
        assert_eq!(ws.pooled_tensors(), 0);
    }

    fn assert_aligned(p: *const f32, what: &str) {
        assert_eq!(
            p as usize % AlignedBuf::<f32>::ALIGN,
            0,
            "{what}: pointer {p:?} not {}-byte aligned",
            AlignedBuf::<f32>::ALIGN
        );
    }

    #[test]
    fn pack_checkouts_are_64_byte_aligned_and_recycled_aligned() {
        let mut ws = Workspace::new(true);
        // Fresh checkout: both pack buffers aligned.
        {
            let (a, b) = ws.packs().for_shape(13, 17, 29);
            assert_aligned(a.as_ptr(), "fresh A pack");
            assert_aligned(b.as_ptr(), "fresh B pack");
            a.fill(1.0);
            b.fill(2.0);
        }
        // Recycled (same-capacity) checkout: alignment must survive reuse.
        let p0 = {
            let (a, _) = ws.packs().for_shape(13, 17, 29);
            assert_aligned(a.as_ptr(), "recycled A pack");
            a.as_ptr() as usize
        };
        // Same shape again: no growth, identical storage (true recycling).
        let p1 = ws.packs().for_shape(13, 17, 29).0.as_ptr() as usize;
        assert_eq!(p0, p1, "same-shape checkout must reuse the pooled buffer");
        // Growth re-aligns; shrinking requests keep the larger capacity.
        {
            let (a, b) = ws.packs().for_shape(200, 64, 96);
            assert_aligned(a.as_ptr(), "grown A pack");
            assert_aligned(b.as_ptr(), "grown B pack");
        }
        let cap_after_big = {
            let (a, _) = ws.packs().for_shape(2, 2, 2);
            assert_aligned(a.as_ptr(), "small checkout after growth");
            a.len()
        };
        assert_eq!(cap_after_big, super::super::ops::pack_sizes(2, 2, 2).0);
    }

    #[test]
    fn aligned_buf_zero_len_and_grow_cycle() {
        let mut buf = AlignedBuf::new();
        assert_eq!(buf.capacity(), 0);
        assert!(buf.slice_to(0).is_empty());
        let first = buf.slice_to(7).as_ptr() as usize;
        assert_eq!(first % AlignedBuf::<f32>::ALIGN, 0);
        assert_eq!(buf.capacity(), 7);
        // Fresh storage is zero-initialized.
        assert!(buf.slice_to(7).iter().all(|&v| v == 0.0));
        buf.slice_to(7).fill(3.5);
        // No growth on a smaller request; contents intact (scratch reuse).
        assert_eq!(buf.slice_to(3), &[3.5, 3.5, 3.5]);
        // The shared read view sees the same storage.
        assert_eq!(buf.as_slice(3), &[3.5, 3.5, 3.5]);
        assert!(buf.as_slice(0).is_empty());
        buf.slice_to(1000);
        assert_eq!(buf.capacity(), 1000);
        assert_eq!(buf.slice_to(1000).as_ptr() as usize % AlignedBuf::<f32>::ALIGN, 0);
    }
}
