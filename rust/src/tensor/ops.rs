//! Tensor operations: matmul family, transpose, elementwise, reductions.
//!
//! The matmul family (`matmul`, `matmul_t`, `t_matmul`) is one cache-blocked
//! kernel family (`matmul_into` / `matmul_t_into` / `t_matmul_into`): every
//! variant tiles for L1/L2 reuse and, above [`PAR_MIN_MACS`] multiply-adds,
//! splits contiguous *row bands* of the output across the scoped thread
//! pool. Each output row is produced by exactly one worker with a fixed
//! k-tile accumulation order, so results are bit-identical for any thread
//! count (the determinism suite pins this). The `*_mt` methods take an
//! explicit thread budget; the plain methods are the serial (threads = 1)
//! shorthand every non-hot-path caller keeps using.
//!
//! The bench `hotpath_micro` tracks kernel throughput so regressions are
//! visible; `BENCH_pr2.json` records the serial→parallel trajectory.

use super::Tensor;
use crate::util::threadpool::{gated_threads, scope_rows, SharedSliceMut};

/// Cache block edge for the matmul micro-kernels (f32: 64*64*4B = 16 KB/tile,
/// three tiles comfortably fit in L1+L2).
const BLOCK: usize = 64;

/// Multiply-add count (m·k·n) above which the kernels split row bands
/// across worker threads. Below it a parallel region costs more than the
/// arithmetic (dispatch is ~µs; 2^18 MACs is ~100 µs of scalar work).
pub const PAR_MIN_MACS: usize = 1 << 18;

/// Minimum output rows per band; finer splits shred cache tiles. The band
/// partition itself is `threadpool::scope_rows` — one banding policy for
/// kernels and encoder row loops alike.
const MIN_BAND_ROWS: usize = 8;

/// Thread budget for a kernel of `macs` multiply-adds: serial below
/// [`PAR_MIN_MACS`], the caller's budget above it.
fn kernel_threads(threads: usize, macs: usize) -> usize {
    gated_threads(threads, macs, PAR_MIN_MACS)
}

impl Tensor {
    /// Matrix product `self (m×k) · rhs (k×n)` (serial).
    pub fn matmul(&self, rhs: &Tensor) -> Tensor {
        self.matmul_mt(rhs, 1)
    }

    /// Matrix product with an explicit thread budget.
    pub fn matmul_mt(&self, rhs: &Tensor, threads: usize) -> Tensor {
        let (m, k) = (self.rows(), self.cols());
        let (k2, n) = (rhs.rows(), rhs.cols());
        assert_eq!(k, k2, "matmul inner dims: {:?} x {:?}", self.shape(), rhs.shape());
        let mut out = Tensor::zeros(&[m, n]);
        matmul_into(self.data(), rhs.data(), out.data_mut(), m, k, n, threads);
        out
    }

    /// `self.transpose() · rhs` without materializing the transpose:
    /// self is (k×m), rhs is (k×n), out (m×n). Serial.
    pub fn t_matmul(&self, rhs: &Tensor) -> Tensor {
        self.t_matmul_mt(rhs, 1)
    }

    /// Transposed-left product with an explicit thread budget.
    pub fn t_matmul_mt(&self, rhs: &Tensor, threads: usize) -> Tensor {
        let (k, m) = (self.rows(), self.cols());
        let (k2, n) = (rhs.rows(), rhs.cols());
        assert_eq!(k, k2, "t_matmul inner dims: {:?}^T x {:?}", self.shape(), rhs.shape());
        let mut out = Tensor::zeros(&[m, n]);
        t_matmul_into(self.data(), rhs.data(), out.data_mut(), m, k, n, threads);
        out
    }

    /// `self · rhs^T`: self (m×k), rhs (n×k), out (m×n). Serial.
    pub fn matmul_t(&self, rhs: &Tensor) -> Tensor {
        self.matmul_t_mt(rhs, 1)
    }

    /// Transposed-right product with an explicit thread budget.
    pub fn matmul_t_mt(&self, rhs: &Tensor, threads: usize) -> Tensor {
        let (m, k) = (self.rows(), self.cols());
        let (n, k2) = (rhs.rows(), rhs.cols());
        assert_eq!(k, k2, "matmul_t inner dims: {:?} x {:?}^T", self.shape(), rhs.shape());
        let mut out = Tensor::zeros(&[m, n]);
        matmul_t_into(self.data(), rhs.data(), out.data_mut(), m, k, n, threads);
        out
    }

    /// 2-D transpose (copies).
    pub fn transpose(&self) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        let mut out = Tensor::zeros(&[n, m]);
        for i in 0..m {
            for j in 0..n {
                out.data_mut()[j * m + i] = self.data()[i * n + j];
            }
        }
        out
    }

    /// Elementwise `self + rhs` (same shape).
    pub fn add(&self, rhs: &Tensor) -> Tensor {
        self.zip(rhs, |a, b| a + b)
    }

    /// Elementwise `self - rhs` (same shape).
    pub fn sub(&self, rhs: &Tensor) -> Tensor {
        self.zip(rhs, |a, b| a - b)
    }

    /// Elementwise product.
    pub fn mul(&self, rhs: &Tensor) -> Tensor {
        self.zip(rhs, |a, b| a * b)
    }

    /// Scalar multiply.
    pub fn scale(&self, s: f32) -> Tensor {
        let mut out = self.clone();
        for v in out.data_mut() {
            *v *= s;
        }
        out
    }

    /// In-place `self += s * rhs` (axpy).
    pub fn axpy(&mut self, s: f32, rhs: &Tensor) {
        assert_eq!(self.shape(), rhs.shape());
        for (a, b) in self.data_mut().iter_mut().zip(rhs.data()) {
            *a += s * b;
        }
    }

    fn zip(&self, rhs: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape(), rhs.shape(), "elementwise shape mismatch");
        let data = self.data().iter().zip(rhs.data()).map(|(&a, &b)| f(a, b)).collect();
        Tensor::from_vec(self.shape(), data)
    }

    /// Dot product of flattened tensors (same element count).
    pub fn dot(&self, rhs: &Tensor) -> f64 {
        assert_eq!(self.len(), rhs.len());
        self.data()
            .iter()
            .zip(rhs.data())
            .map(|(&a, &b)| a as f64 * b as f64)
            .sum()
    }

    /// Extract row `i` of a matrix as a vector tensor.
    pub fn row(&self, i: usize) -> Tensor {
        let n = self.cols();
        Tensor::from_vec(&[n], self.data()[i * n..(i + 1) * n].to_vec())
    }

    /// Extract a contiguous row range [lo, hi) of a matrix.
    pub fn rows_slice(&self, lo: usize, hi: usize) -> Tensor {
        let n = self.cols();
        assert!(lo <= hi && hi <= self.rows());
        Tensor::from_vec(&[hi - lo, n], self.data()[lo * n..hi * n].to_vec())
    }

    /// Extract a column range [lo, hi) of a matrix.
    pub fn cols_slice(&self, lo: usize, hi: usize) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        assert!(lo <= hi && hi <= n);
        let w = hi - lo;
        let mut out = Tensor::zeros(&[m, w]);
        for i in 0..m {
            out.data_mut()[i * w..(i + 1) * w]
                .copy_from_slice(&self.data()[i * n + lo..i * n + hi]);
        }
        out
    }

    /// Slice of a 3-D tensor along the middle axis: `self[:, j, :]` as a
    /// matrix (r_left × r_right). TT cores are stored [r_left, n, r_right].
    pub fn mid_slice(&self, j: usize) -> Tensor {
        assert_eq!(self.ndim(), 3);
        let (rl, n, rr) = (self.shape()[0], self.shape()[1], self.shape()[2]);
        assert!(j < n);
        let mut out = Tensor::zeros(&[rl, rr]);
        for i in 0..rl {
            let src = &self.data()[(i * n + j) * rr..(i * n + j) * rr + rr];
            out.data_mut()[i * rr..(i + 1) * rr].copy_from_slice(src);
        }
        out
    }

    /// Write a matrix into the middle-axis slice `self[:, j, :]`.
    pub fn set_mid_slice(&mut self, j: usize, m: &Tensor) {
        assert_eq!(self.ndim(), 3);
        let (rl, n, rr) = (self.shape()[0], self.shape()[1], self.shape()[2]);
        assert_eq!(m.shape(), &[rl, rr]);
        assert!(j < n);
        for i in 0..rl {
            let dst_start = (i * n + j) * rr;
            self.data_mut()[dst_start..dst_start + rr]
                .copy_from_slice(&m.data()[i * rr..(i + 1) * rr]);
        }
    }

    /// Sum over all elements.
    pub fn sum(&self) -> f64 {
        self.data().iter().map(|&x| x as f64).sum()
    }

    /// Mean over all elements.
    pub fn mean(&self) -> f64 {
        if self.is_empty() {
            0.0
        } else {
            self.sum() / self.len() as f64
        }
    }
}

/// Blocked matmul kernel: C (m×n) += A (m×k) · B (k×n). The kernel
/// *accumulates* into C — zero it first for a plain product; the encoder's
/// backward exploits the accumulation to fuse `dst += A·B` without a
/// temporary. Splits row bands across `threads` workers above
/// [`PAR_MIN_MACS`]; each output row keeps the serial k-tile accumulation
/// order, so the result is bit-identical for every thread count.
pub fn matmul_into(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let cs = SharedSliceMut::new(c);
    scope_rows(kernel_threads(threads, m * k * n), m, MIN_BAND_ROWS, |r| {
        // SAFETY: bands are disjoint row ranges of c.
        let c_band = unsafe { cs.range_mut(r.start * n, r.end * n) };
        matmul_band(&a[r.start * k..r.end * k], b, c_band, r.end - r.start, k, n);
    });
}

/// Serial blocked micro-kernel for one row band of C = A·B.
fn matmul_band(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    for i0 in (0..m).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(m);
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for j0 in (0..n).step_by(BLOCK) {
                let j1 = (j0 + BLOCK).min(n);
                for i in i0..i1 {
                    let crow = &mut c[i * n..(i + 1) * n];
                    for kk in k0..k1 {
                        let aik = a[i * k + kk];
                        let brow = &b[kk * n..(kk + 1) * n];
                        for j in j0..j1 {
                            crow[j] += aik * brow[j];
                        }
                    }
                }
            }
        }
    }
}

/// Blocked transposed-right kernel: C (m×n) += A (m×k) · B (n×k)^T
/// (accumulating, like the sibling kernels — zero C for a plain product).
/// Same banding/determinism contract as [`matmul_into`].
pub fn matmul_t_into(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    let cs = SharedSliceMut::new(c);
    scope_rows(kernel_threads(threads, m * k * n), m, MIN_BAND_ROWS, |r| {
        // SAFETY: bands are disjoint row ranges of c.
        let c_band = unsafe { cs.range_mut(r.start * n, r.end * n) };
        matmul_t_band(&a[r.start * k..r.end * k], b, c_band, r.end - r.start, k, n);
    });
}

/// Serial blocked micro-kernel for one row band of C = A·Bᵀ. Tiles over
/// (j, k) so a BLOCK-row slab of B stays hot while all of A streams by;
/// per-(i,j) accumulation runs k-tiles in ascending order.
fn matmul_t_band(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(c.len(), m * n);
    for j0 in (0..n).step_by(BLOCK) {
        let j1 = (j0 + BLOCK).min(n);
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for i in 0..m {
                let arow = &a[i * k + k0..i * k + k1];
                let crow = &mut c[i * n..(i + 1) * n];
                for j in j0..j1 {
                    let brow = &b[j * k + k0..j * k + k1];
                    let mut acc = crow[j];
                    for (&av, &bv) in arow.iter().zip(brow) {
                        acc += av * bv;
                    }
                    crow[j] = acc;
                }
            }
        }
    }
}

/// Blocked transposed-left kernel: C (m×n) += A (k×m)^T · B (k×n)
/// (accumulating — zero C for a plain product). Same banding/determinism
/// contract as [`matmul_into`]; bands split the m output rows (columns of
/// A).
pub fn t_matmul_into(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let cs = SharedSliceMut::new(c);
    scope_rows(kernel_threads(threads, m * k * n), m, MIN_BAND_ROWS, |r| {
        // SAFETY: bands are disjoint row ranges of c.
        let c_band = unsafe { cs.range_mut(r.start * n, r.end * n) };
        t_matmul_band(a, b, c_band, r, m, k, n);
    });
}

/// Serial blocked micro-kernel for output rows `rows` of C = Aᵀ·B. The
/// A reads are column-strided, so k is tiled to keep the touched A slab and
/// the B tile resident; accumulation per (i, j) runs k-tiles in ascending
/// order.
fn t_matmul_band(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    rows: std::ops::Range<usize>,
    m: usize,
    k: usize,
    n: usize,
) {
    let r0 = rows.start;
    for k0 in (0..k).step_by(BLOCK) {
        let k1 = (k0 + BLOCK).min(k);
        for i in rows.clone() {
            let crow = &mut c[(i - r0) * n..(i - r0 + 1) * n];
            for kk in k0..k1 {
                let aval = a[kk * m + i];
                let brow = &b[kk * n..(kk + 1) * n];
                for j in 0..n {
                    crow[j] += aval * brow[j];
                }
            }
        }
    }
}

/// In-place row-wise numerically-stable softmax over a row-major
/// `rows × cols` buffer (the attention-probability transform).
pub fn softmax_rows_into(x: &mut [f32], rows: usize, cols: usize) {
    debug_assert_eq!(x.len(), rows * cols);
    for row in x.chunks_exact_mut(cols) {
        let mx = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mut z = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            z += *v;
        }
        for v in row.iter_mut() {
            *v /= z;
        }
    }
}

/// Slice axpy: `dst += s * src` (each product rounded once, then added —
/// identical to `Tensor::axpy` on the same data).
pub fn axpy_into(dst: &mut [f32], s: f32, src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, &x) in dst.iter_mut().zip(src) {
        *d += s * x;
    }
}

/// Elementwise sum into a destination buffer: `dst = a + b`.
pub fn add_into(a: &[f32], b: &[f32], dst: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), dst.len());
    for ((d, &x), &y) in dst.iter_mut().zip(a).zip(b) {
        *d = x + y;
    }
}

/// In-place scalar multiply.
pub fn scale_into(x: &mut [f32], s: f32) {
    for v in x.iter_mut() {
        *v *= s;
    }
}

/// Relative Frobenius error ‖a-b‖/max(‖b‖, eps); the standard closeness
/// measure used across tests.
pub fn rel_err(a: &Tensor, b: &Tensor) -> f32 {
    assert_eq!(a.shape(), b.shape());
    let diff = a.sub(b).fro_norm();
    diff / b.fro_norm().max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for t in 0..k {
                    acc += a.at(i, t) * b.at(t, j);
                }
                c.set(i, j, acc);
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive_on_random_shapes() {
        let mut rng = Pcg64::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (65, 64, 63), (128, 17, 70)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let got = a.matmul(&b);
            let want = naive_matmul(&a, &b);
            assert!(rel_err(&got, &want) < 1e-5, "({m},{k},{n})");
        }
    }

    #[test]
    fn transpose_variants_agree() {
        let mut rng = Pcg64::new(2);
        let a = Tensor::randn(&[9, 13], 1.0, &mut rng);
        let b = Tensor::randn(&[9, 11], 1.0, &mut rng);
        // a^T b via t_matmul vs explicit transpose
        let got = a.t_matmul(&b);
        let want = a.transpose().matmul(&b);
        assert!(rel_err(&got, &want) < 1e-5);
        // a b^T via matmul_t
        let c = Tensor::randn(&[7, 13], 1.0, &mut rng);
        let got2 = a.matmul_t(&c);
        let want2 = a.matmul(&c.transpose());
        assert!(rel_err(&got2, &want2) < 1e-5);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg64::new(3);
        let a = Tensor::randn(&[6, 6], 1.0, &mut rng);
        assert!(rel_err(&a.matmul(&Tensor::eye(6)), &a) < 1e-6);
        assert!(rel_err(&Tensor::eye(6).matmul(&a), &a) < 1e-6);
    }

    #[test]
    fn slices() {
        let t = Tensor::from_vec(&[3, 4], (0..12).map(|x| x as f32).collect());
        assert_eq!(t.row(1).data(), &[4., 5., 6., 7.]);
        assert_eq!(t.rows_slice(1, 3).shape(), &[2, 4]);
        assert_eq!(t.cols_slice(1, 3).data(), &[1., 2., 5., 6., 9., 10.]);
    }

    #[test]
    fn mid_slice_roundtrip() {
        let mut rng = Pcg64::new(4);
        let mut core = Tensor::randn(&[3, 5, 2], 1.0, &mut rng);
        let m = Tensor::randn(&[3, 2], 1.0, &mut rng);
        core.set_mid_slice(2, &m);
        assert_eq!(core.mid_slice(2), m);
        // untouched slices keep their values finite and distinct
        assert_ne!(core.mid_slice(1), m);
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = Tensor::full(&[2, 2], 1.0);
        let b = Tensor::full(&[2, 2], 2.0);
        a.axpy(0.5, &b);
        assert_eq!(a.data(), &[2.0, 2.0, 2.0, 2.0]);
        assert_eq!(a.scale(0.5).data(), &[1.0, 1.0, 1.0, 1.0]);
    }

    #[test]
    fn kernels_accumulate_into_nonzero_output() {
        // The encoder's backward fuses `dst += A·B` through the kernels'
        // accumulation semantics; pin it for all three orientations.
        let mut rng = Pcg64::new(11);
        let a = Tensor::randn(&[5, 7], 1.0, &mut rng);
        let b = Tensor::randn(&[7, 4], 1.0, &mut rng);
        let base = Tensor::randn(&[5, 4], 1.0, &mut rng);
        let mut c = base.clone();
        matmul_into(a.data(), b.data(), c.data_mut(), 5, 7, 4, 1);
        let want = base.add(&a.matmul(&b));
        assert!(rel_err(&c, &want) < 1e-5, "matmul_into accumulate");
        let bt = b.transpose(); // (4, 7)
        let mut c2 = base.clone();
        matmul_t_into(a.data(), bt.data(), c2.data_mut(), 5, 7, 4, 1);
        assert!(rel_err(&c2, &want) < 1e-5, "matmul_t_into accumulate");
        let at = a.transpose(); // (7, 5)
        let mut c3 = base.clone();
        t_matmul_into(at.data(), b.data(), c3.data_mut(), 5, 7, 4, 1);
        assert!(rel_err(&c3, &want) < 1e-5, "t_matmul_into accumulate");
    }

    #[test]
    fn in_place_ops_match_tensor_ops() {
        let mut rng = Pcg64::new(6);
        // softmax_rows_into matches a per-row manual softmax.
        let t = Tensor::randn(&[3, 5], 2.0, &mut rng);
        let mut s = t.data().to_vec();
        softmax_rows_into(&mut s, 3, 5);
        for i in 0..3 {
            let row = &s[i * 5..(i + 1) * 5];
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "row {i} sums to {sum}");
            // Ordering preserved (softmax is monotone).
            let src = &t.data()[i * 5..(i + 1) * 5];
            for a in 0..5 {
                for b in 0..5 {
                    assert_eq!(src[a] < src[b], row[a] < row[b]);
                }
            }
        }
        // axpy_into is bitwise-identical to Tensor::axpy.
        let mut a = Tensor::randn(&[4, 4], 1.0, &mut rng);
        let b = Tensor::randn(&[4, 4], 1.0, &mut rng);
        let mut raw = a.data().to_vec();
        a.axpy(0.3, &b);
        axpy_into(&mut raw, 0.3, b.data());
        assert_eq!(a.data(), &raw[..]);
        // add_into matches Tensor::add; scale_into matches Tensor::scale.
        let c = Tensor::randn(&[2, 3], 1.0, &mut rng);
        let d = Tensor::randn(&[2, 3], 1.0, &mut rng);
        let mut sum = vec![0.0f32; 6];
        add_into(c.data(), d.data(), &mut sum);
        assert_eq!(&sum[..], c.add(&d).data());
        scale_into(&mut sum, 0.5);
        assert_eq!(&sum[..], c.add(&d).scale(0.5).data());
    }

    #[test]
    fn associativity_of_chain_products() {
        // (X G1) G2 == X (G1 G2) — the algebraic fact the TT apply relies on.
        let mut rng = Pcg64::new(5);
        let x = Tensor::randn(&[8, 16], 1.0, &mut rng);
        let g1 = Tensor::randn(&[16, 4], 1.0, &mut rng);
        let g2 = Tensor::randn(&[4, 4], 1.0, &mut rng);
        let left = x.matmul(&g1).matmul(&g2);
        let right = x.matmul(&g1.matmul(&g2));
        assert!(rel_err(&left, &right) < 1e-5);
    }
}
