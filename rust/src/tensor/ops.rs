//! Tensor operations: the packed GEMM family, blocked transpose,
//! elementwise ops, reductions.
//!
//! # The packed register-tiled GEMM (PR 4)
//!
//! All three matmul orientations — `matmul` (A·B), `matmul_t` (A·Bᵀ) and
//! `t_matmul` (Aᵀ·B) — are one BLIS-style kernel: operands are first
//! *packed* into panel buffers and a fixed-size register-tiled microkernel
//! then does every flop out of those panels.
//!
//! * **Packing.** A is repacked into [`MR`]-row panels laid out so each k
//!   step reads one contiguous MR-column; B is repacked into [`NR`]-wide
//!   column panels, contiguous per k step. The pack absorbs the transpose:
//!   a transposed operand only changes how the packer *reads* its source,
//!   so the three orientations collapse into one inner kernel and the old
//!   orientation-specific `*_band` loops are gone. Edge panels are
//!   zero-padded to full width (padded lanes multiply zeros and are never
//!   stored back).
//! * **Microkernel.** An MR×NR accumulator array lives in registers; the
//!   j-dimension (NR lanes) auto-vectorizes. The k loop is tiled by [`KC`]
//!   so the active A panel (MR·KC floats) and B panel (NR·KC floats) stay
//!   cache-resident.
//! * **Bit-identity.** Vector lanes span *columns*, never k: each output
//!   element keeps one scalar accumulation chain that starts from the
//!   prior C value and adds `a·b` products in strictly ascending k order
//!   (KC tiles ascending, k ascending within a tile; the C tile round-trips
//!   through memory exactly between KC tiles). That is precisely the
//!   per-element sequence of the PR 2/3 blocked kernels, so the packed
//!   kernels are bit-identical to them — and to each other across thread
//!   counts, arena modes, and orientations (pinned by
//!   `tests/gemm_props.rs` against a scalar k-ascending oracle and by the
//!   unmodified `tests/determinism.rs`).
//! * **Threading.** Above [`PAR_MIN_MACS`] multiply-adds the output is
//!   split into contiguous *panel bands* (`threadpool::scope_rows` over
//!   MR-panels): workers share the one packed B and each packs + consumes
//!   its own disjoint slice of packed A, so B is packed once per GEMM and
//!   every C row still belongs to exactly one worker.
//! * **Tiny products.** Below [`PACK_MIN_MACS`] multiply-adds (the r×r
//!   adapter factor chains) packing would cost a meaningful fraction of
//!   the arithmetic, so a direct serial loop runs the same k-ascending
//!   per-element chain instead — bit-identical by the same argument, and
//!   pinned by the same oracle tests on both sides of the threshold.
//! * **Pack buffers.** Panels live in 64-byte-aligned grow-only scratch
//!   ([`crate::tensor::PackScratch`]). Workspace-reachable call sites pass
//!   the arena's scratch (`Workspace::packs`) so a warmed step packs with
//!   zero heap allocations (`tests/alloc_regression.rs`); the `*_into_local`
//!   variants use a per-thread scratch for sites inside parallel regions
//!   (attention's per-(batch, head) GEMMs) and for the `Tensor`
//!   conveniences — pool workers are persistent, so that scratch also
//!   reaches a steady state. Deliberate trade-off: both operands pack in
//!   full (no NC/MC outer blocking), so a scratch's high-water mark is
//!   ~the largest padded `m·k + k·n` its owner ever issues — megabytes at
//!   this crate's model shapes, held for the owner's lifetime. Cache-sized
//!   NC-strip packing would bound that but forces workers to resynchronize
//!   per strip, complicating the share-one-packed-B banding the
//!   determinism contract leans on; revisit only if operand sizes outgrow
//!   the arena budget. Likewise, step-invariant (frozen-weight) operands
//!   currently re-pack on every call — a bind-time packed-panel cache is
//!   the designated follow-up (ROADMAP) if profile data shows the pack
//!   fraction mattering at larger vocab/hidden sizes.
//!
//! The bench `hotpath_micro` §8 tracks per-shape GFLOP/s and the speedup
//! over the retired PR 3 blocked kernel (`BENCH_pr4.json`).

use super::dtype::{Bf16, Dtype, DtypeKind};
use super::workspace::{AlignedBuf, PackScratch};
use super::Tensor;
use crate::util::threadpool::{gated_threads, scope_rows, SharedSliceMut};
use std::cell::RefCell;
use std::ops::Range;

/// Microkernel tile height: rows of A (and C) per packed A panel. Four
/// independent accumulation chains per column hide FP add latency without
/// spilling the MR×NR accumulator block out of registers.
pub const MR: usize = 4;

/// Microkernel tile width: columns of B (and C) per packed B panel. Eight
/// f32 lanes = two SSE / one AVX vector per accumulator row; lanes span
/// columns, so vectorization never touches the k accumulation order.
pub const NR: usize = 8;

/// k-tile edge: the microkernel consumes packed panels KC rows of k at a
/// time so an (MR + NR)·KC·4-byte panel pair (~12 KB) stays cache-resident
/// while a C tile round-trips through it.
const KC: usize = 256;

/// Multiply-add count (m·k·n) above which the kernel splits panel bands
/// across worker threads. Below it a parallel region costs more than the
/// arithmetic (dispatch is ~µs; 2^18 MACs is ~100 µs of scalar work).
pub const PAR_MIN_MACS: usize = 1 << 18;

/// Minimum MR-panels per worker band (= 8 output rows); finer splits shred
/// the packed-panel reuse.
const MIN_BAND_PANELS: usize = 2;

/// Multiply-add count below which packing costs more than it saves (the
/// r×r adapter factor products: pack traffic ≈ (1/(mp·MR) + 1/(np·NR)) of
/// the FLOPs plus padding waste, so sub-16³ shapes run a direct k-ascending
/// loop instead — the per-element rounding chain, and therefore every
/// output bit, is identical either way).
const PACK_MIN_MACS: usize = 1 << 12;

/// Source elements (k·n) above which [`pack_b`] bands its NR-panels across
/// worker threads. Packing is pure bandwidth (~two touches per element),
/// so the dispatch cost only amortizes on packs that stream at least a few
/// hundred KB; below that the serial loop wins.
const PACK_PAR_MIN_ELEMS: usize = 1 << 16;

/// Blocked-transpose tile edge: a TB×TB f32 tile (4 KB) of source plus its
/// transposed destination fit L1 together.
const TB: usize = 32;

/// Thread budget for a kernel of `macs` multiply-adds: serial below
/// [`PAR_MIN_MACS`], the caller's budget above it.
fn kernel_threads(threads: usize, macs: usize) -> usize {
    gated_threads(threads, macs, PAR_MIN_MACS)
}

/// Packed sizes (A-pack, B-pack) in f32 elements for an `(m × k) · (k × n)`
/// product: panels are zero-padded to full MR / NR width. Identical for
/// every orientation — transposes change only the packer's read pattern.
pub fn pack_sizes(m: usize, k: usize, n: usize) -> (usize, usize) {
    (m.div_ceil(MR) * MR * k, n.div_ceil(NR) * NR * k)
}

thread_local! {
    /// Per-thread pack scratch for GEMMs issued where no workspace arena is
    /// reachable: call sites inside parallel regions (each worker packs in
    /// its own scratch) and the allocating `Tensor` conveniences. Pool
    /// workers are persistent, so after warmup these grow-only buffers stop
    /// allocating too.
    static LOCAL_PACKS: RefCell<PackScratch> = RefCell::new(PackScratch::new());
}

impl Tensor {
    /// Matrix product `self (m×k) · rhs (k×n)` (serial).
    pub fn matmul(&self, rhs: &Tensor) -> Tensor {
        self.matmul_mt(rhs, 1)
    }

    /// Matrix product with an explicit thread budget.
    pub fn matmul_mt(&self, rhs: &Tensor, threads: usize) -> Tensor {
        let (m, k) = (self.rows(), self.cols());
        let (k2, n) = (rhs.rows(), rhs.cols());
        assert_eq!(k, k2, "matmul inner dims: {:?} x {:?}", self.shape(), rhs.shape());
        let mut out = Tensor::zeros(&[m, n]);
        matmul_into_local(self.data(), rhs.data(), out.data_mut(), m, k, n, threads);
        out
    }

    /// `self.transpose() · rhs` without materializing the transpose:
    /// self is (k×m), rhs is (k×n), out (m×n). Serial.
    pub fn t_matmul(&self, rhs: &Tensor) -> Tensor {
        self.t_matmul_mt(rhs, 1)
    }

    /// Transposed-left product with an explicit thread budget.
    pub fn t_matmul_mt(&self, rhs: &Tensor, threads: usize) -> Tensor {
        let (k, m) = (self.rows(), self.cols());
        let (k2, n) = (rhs.rows(), rhs.cols());
        assert_eq!(k, k2, "t_matmul inner dims: {:?}^T x {:?}", self.shape(), rhs.shape());
        let mut out = Tensor::zeros(&[m, n]);
        t_matmul_into_local(self.data(), rhs.data(), out.data_mut(), m, k, n, threads);
        out
    }

    /// `self · rhs^T`: self (m×k), rhs (n×k), out (m×n). Serial.
    pub fn matmul_t(&self, rhs: &Tensor) -> Tensor {
        self.matmul_t_mt(rhs, 1)
    }

    /// Transposed-right product with an explicit thread budget.
    pub fn matmul_t_mt(&self, rhs: &Tensor, threads: usize) -> Tensor {
        let (m, k) = (self.rows(), self.cols());
        let (n, k2) = (rhs.rows(), rhs.cols());
        assert_eq!(k, k2, "matmul_t inner dims: {:?} x {:?}^T", self.shape(), rhs.shape());
        let mut out = Tensor::zeros(&[m, n]);
        matmul_t_into_local(self.data(), rhs.data(), out.data_mut(), m, k, n, threads);
        out
    }

    /// 2-D transpose (copies, tile-blocked — see [`transpose_into`]).
    pub fn transpose(&self) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        let mut out = Tensor::zeros(&[n, m]);
        transpose_into(self.data(), out.data_mut(), m, n);
        out
    }

    /// Elementwise `self + rhs` (same shape).
    pub fn add(&self, rhs: &Tensor) -> Tensor {
        self.zip(rhs, |a, b| a + b)
    }

    /// Elementwise `self - rhs` (same shape).
    pub fn sub(&self, rhs: &Tensor) -> Tensor {
        self.zip(rhs, |a, b| a - b)
    }

    /// Elementwise product.
    pub fn mul(&self, rhs: &Tensor) -> Tensor {
        self.zip(rhs, |a, b| a * b)
    }

    /// Scalar multiply.
    pub fn scale(&self, s: f32) -> Tensor {
        let mut out = self.clone();
        for v in out.data_mut() {
            *v *= s;
        }
        out
    }

    /// In-place `self += s * rhs` (axpy).
    pub fn axpy(&mut self, s: f32, rhs: &Tensor) {
        assert_eq!(self.shape(), rhs.shape());
        for (a, b) in self.data_mut().iter_mut().zip(rhs.data()) {
            *a += s * b;
        }
    }

    fn zip(&self, rhs: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape(), rhs.shape(), "elementwise shape mismatch");
        let data = self.data().iter().zip(rhs.data()).map(|(&a, &b)| f(a, b)).collect();
        Tensor::from_vec(self.shape(), data)
    }

    /// Dot product of flattened tensors (same element count).
    pub fn dot(&self, rhs: &Tensor) -> f64 {
        assert_eq!(self.len(), rhs.len());
        self.data()
            .iter()
            .zip(rhs.data())
            .map(|(&a, &b)| a as f64 * b as f64)
            .sum()
    }

    /// Extract row `i` of a matrix as a vector tensor.
    pub fn row(&self, i: usize) -> Tensor {
        let n = self.cols();
        Tensor::from_vec(&[n], self.data()[i * n..(i + 1) * n].to_vec())
    }

    /// Extract a contiguous row range [lo, hi) of a matrix.
    pub fn rows_slice(&self, lo: usize, hi: usize) -> Tensor {
        let n = self.cols();
        assert!(lo <= hi && hi <= self.rows());
        Tensor::from_vec(&[hi - lo, n], self.data()[lo * n..hi * n].to_vec())
    }

    /// Extract a column range [lo, hi) of a matrix.
    pub fn cols_slice(&self, lo: usize, hi: usize) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        assert!(lo <= hi && hi <= n);
        let w = hi - lo;
        let mut out = Tensor::zeros(&[m, w]);
        for i in 0..m {
            out.data_mut()[i * w..(i + 1) * w]
                .copy_from_slice(&self.data()[i * n + lo..i * n + hi]);
        }
        out
    }

    /// Slice of a 3-D tensor along the middle axis: `self[:, j, :]` as a
    /// matrix (r_left × r_right). TT cores are stored [r_left, n, r_right].
    pub fn mid_slice(&self, j: usize) -> Tensor {
        assert_eq!(self.ndim(), 3);
        let (rl, n, rr) = (self.shape()[0], self.shape()[1], self.shape()[2]);
        assert!(j < n);
        let mut out = Tensor::zeros(&[rl, rr]);
        for i in 0..rl {
            let src = &self.data()[(i * n + j) * rr..(i * n + j) * rr + rr];
            out.data_mut()[i * rr..(i + 1) * rr].copy_from_slice(src);
        }
        out
    }

    /// Write a matrix into the middle-axis slice `self[:, j, :]`.
    pub fn set_mid_slice(&mut self, j: usize, m: &Tensor) {
        assert_eq!(self.ndim(), 3);
        let (rl, n, rr) = (self.shape()[0], self.shape()[1], self.shape()[2]);
        assert_eq!(m.shape(), &[rl, rr]);
        assert!(j < n);
        for i in 0..rl {
            let dst_start = (i * n + j) * rr;
            self.data_mut()[dst_start..dst_start + rr]
                .copy_from_slice(&m.data()[i * rr..(i + 1) * rr]);
        }
    }

    /// Sum over all elements.
    pub fn sum(&self) -> f64 {
        self.data().iter().map(|&x| x as f64).sum()
    }

    /// Mean over all elements.
    pub fn mean(&self) -> f64 {
        if self.is_empty() {
            0.0
        } else {
            self.sum() / self.len() as f64
        }
    }
}

// ---------------------------------------------------------------------------
// The packed GEMM engine.
// ---------------------------------------------------------------------------

/// Operand orientation of a GEMM. The packed sizes and the microkernel are
/// orientation-independent; only the packers read their sources differently.
#[derive(Clone, Copy, Debug)]
enum Orient {
    /// C += A (m×k) · B (k×n)
    Nn,
    /// C += A (m×k) · B (n×k)ᵀ
    Nt,
    /// C += A (k×m)ᵀ · B (k×n)
    Tn,
}

/// Packed matmul kernel: C (m×n) += A (m×k) · B (k×n). The kernel
/// *accumulates* into C — zero it first for a plain product; the encoder's
/// backward exploits the accumulation to fuse `dst += A·B` without a
/// temporary. Splits MR-panel bands across `threads` workers above
/// [`PAR_MIN_MACS`]; each output element keeps the serial k-ascending
/// accumulation order, so the result is bit-identical for every thread
/// count (and to the retired PR 3 blocked kernels).
#[allow(clippy::too_many_arguments)]
pub fn matmul_into(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
    packs: &mut PackScratch,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    gemm(Orient::Nn, a, b, c, m, k, n, threads, packs);
}

/// Packed transposed-right kernel: C (m×n) += A (m×k) · B (n×k)ᵀ
/// (accumulating, like the sibling kernels — zero C for a plain product).
/// Same banding/determinism contract as [`matmul_into`]: the pack step
/// absorbs the transpose of B.
#[allow(clippy::too_many_arguments)]
pub fn matmul_t_into(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
    packs: &mut PackScratch,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    gemm(Orient::Nt, a, b, c, m, k, n, threads, packs);
}

/// Packed transposed-left kernel: C (m×n) += A (k×m)ᵀ · B (k×n)
/// (accumulating — zero C for a plain product). Same banding/determinism
/// contract as [`matmul_into`]: the pack step absorbs the transpose of A,
/// and bands split the m output rows (columns of A) at panel granularity.
#[allow(clippy::too_many_arguments)]
pub fn t_matmul_into(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
    packs: &mut PackScratch,
) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    gemm(Orient::Tn, a, b, c, m, k, n, threads, packs);
}

/// [`matmul_into`] with the per-thread pack scratch — for call sites with
/// no workspace in reach (parallel-region bodies, `Tensor` conveniences).
pub fn matmul_into_local(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    LOCAL_PACKS.with(|p| matmul_into(a, b, c, m, k, n, threads, &mut p.borrow_mut()));
}

/// [`matmul_t_into`] with the per-thread pack scratch.
pub fn matmul_t_into_local(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    LOCAL_PACKS.with(|p| matmul_t_into(a, b, c, m, k, n, threads, &mut p.borrow_mut()));
}

/// [`t_matmul_into`] with the per-thread pack scratch.
pub fn t_matmul_into_local(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    LOCAL_PACKS.with(|p| t_matmul_into(a, b, c, m, k, n, threads, &mut p.borrow_mut()));
}

// ---------------------------------------------------------------------------
// Pre-packed right-hand operands (bind-time panel cache, ROADMAP item).
// ---------------------------------------------------------------------------

/// A pre-packed GEMM right-hand operand: the NR-panel form of a logical
/// row-major `(k × n)` B, produced by the exact same `pack_b` the per-call
/// path runs, held in an owned 64-byte-aligned buffer.
///
/// Step-invariant operands — frozen layer weights in their forward
/// orientation, folded serving factors — can be packed once at bind/fold
/// time; every subsequent [`matmul_into_prepacked`] then skips the per-call
/// B pack (and its ~2× B read/write traffic) entirely. Bit-identity holds
/// by construction: the cached panel bytes equal a fresh pack's, the
/// microkernel consumes them with the same k-ascending per-element chain,
/// and sub-[`PACK_MIN_MACS`] products run a scalar loop over the panels
/// whose per-element chain matches `gemm_small` exactly.
///
/// Generic over the storage [`Dtype`] (PR 7): a quantized pack stores the
/// panels as [`Bf16`] or `i8` (one f32 scale per NR-panel, symmetric), and
/// the kernels widen each element back to f32 right before the multiply —
/// accumulation is always f32. The default `PackedB<f32>` is the identity
/// encoding and stays the bit-exact oracle.
#[derive(Debug)]
pub struct PackedB<T: Dtype = f32> {
    k: usize,
    n: usize,
    buf: AlignedBuf<T>,
    /// One scale per NR-panel for scaled encodings (`i8`); empty for the
    /// scale-free encodings (`f32`, [`Bf16`]), which read as 1.0.
    scales: Vec<f32>,
}

impl PackedB<f32> {
    /// Pack a row-major `(k × n)` operand (the forward `x·W` orientation)
    /// at full precision — byte-for-byte the per-call pack's panels.
    pub fn pack(b: &[f32], k: usize, n: usize) -> PackedB<f32> {
        assert_eq!(b.len(), k * n, "PackedB::pack: {} elements for ({k} x {n})", b.len());
        let len = n.div_ceil(NR) * NR * k;
        let mut buf = AlignedBuf::new();
        pack_b(Orient::Nn, b, buf.slice_to(len), k, n, 1);
        PackedB { k, n, buf, scales: Vec::new() }
    }
}

impl<T: Dtype> PackedB<T> {
    /// Pack a row-major `(k × n)` operand, encoding each zero-padded
    /// NR-panel through [`Dtype::quantize_panel`]. For `T = f32` this
    /// produces the same panel values as [`PackedB::pack`].
    pub fn pack_dtype(b: &[f32], k: usize, n: usize) -> PackedB<T> {
        assert_eq!(b.len(), k * n, "PackedB::pack_dtype: {} elements for ({k} x {n})", b.len());
        let np = n.div_ceil(NR);
        let len = np * NR * k;
        let mut buf = AlignedBuf::new();
        let dst = buf.slice_to(len);
        let mut panel = vec![0.0f32; k * NR];
        let mut scales = Vec::with_capacity(np);
        for q in 0..np {
            let j0 = q * NR;
            let w = NR.min(n - j0);
            for kk in 0..k {
                let row = &mut panel[kk * NR..(kk + 1) * NR];
                row[..w].copy_from_slice(&b[kk * n + j0..kk * n + j0 + w]);
                for v in &mut row[w..] {
                    *v = 0.0;
                }
            }
            scales.push(T::quantize_panel(&panel, &mut dst[q * k * NR..(q + 1) * k * NR]));
        }
        PackedB { k, n, buf, scales }
    }

    /// Inner (k) dimension of the logical operand.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output-column (n) dimension of the logical operand.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Bytes held by the panel copy plus its per-panel scales (bind-time
    /// memory telemetry, and the serving bandwidth accounting).
    pub fn panel_bytes(&self) -> usize {
        self.n.div_ceil(NR) * NR * self.k * T::BYTES
            + self.scales.len() * std::mem::size_of::<f32>()
    }

    fn panels(&self) -> &[T] {
        self.buf.as_slice(self.n.div_ceil(NR) * NR * self.k)
    }
}

/// A [`PackedB`] of runtime-selected storage dtype: what the bind-time
/// frozen-panel cache and the folded-adapter store hold when the dtype is
/// a `--serve-dtype` config value rather than a compile-time parameter.
/// [`matmul_into_prepacked_any`] dispatches to the monomorphic kernels.
#[derive(Debug)]
pub enum PackedBAny {
    F32(PackedB<f32>),
    Bf16(PackedB<Bf16>),
    I8(PackedB<i8>),
}

impl PackedBAny {
    /// Pack a row-major `(k × n)` operand at the requested dtype. The F32
    /// variant routes through [`PackedB::pack`], so an f32 `PackedBAny` is
    /// byte-identical to the pre-dtype pack.
    pub fn pack(b: &[f32], k: usize, n: usize, kind: DtypeKind) -> PackedBAny {
        match kind {
            DtypeKind::F32 => PackedBAny::F32(PackedB::pack(b, k, n)),
            DtypeKind::Bf16 => PackedBAny::Bf16(PackedB::pack_dtype(b, k, n)),
            DtypeKind::I8 => PackedBAny::I8(PackedB::pack_dtype(b, k, n)),
        }
    }

    /// Storage dtype of the packed panels.
    pub fn kind(&self) -> DtypeKind {
        match self {
            PackedBAny::F32(_) => DtypeKind::F32,
            PackedBAny::Bf16(_) => DtypeKind::Bf16,
            PackedBAny::I8(_) => DtypeKind::I8,
        }
    }

    /// Inner (k) dimension of the logical operand.
    pub fn k(&self) -> usize {
        match self {
            PackedBAny::F32(p) => p.k(),
            PackedBAny::Bf16(p) => p.k(),
            PackedBAny::I8(p) => p.k(),
        }
    }

    /// Output-column (n) dimension of the logical operand.
    pub fn n(&self) -> usize {
        match self {
            PackedBAny::F32(p) => p.n(),
            PackedBAny::Bf16(p) => p.n(),
            PackedBAny::I8(p) => p.n(),
        }
    }

    /// Bytes held by the packed panels + scales — what a serving tick
    /// streams for this operand.
    pub fn panel_bytes(&self) -> usize {
        match self {
            PackedBAny::F32(p) => p.panel_bytes(),
            PackedBAny::Bf16(p) => p.panel_bytes(),
            PackedBAny::I8(p) => p.panel_bytes(),
        }
    }
}

/// [`matmul_into`] against a [`PackedB`]: `C (m×n) += A (m×k) · B`, with
/// the per-call B pack skipped. Accumulates into C like every kernel in
/// the family; the `f32` instantiation is bit-identical to the on-the-fly
/// path for every shape and thread count (pinned by
/// `prepacked_b_is_bit_identical` below and by `tests/gemm_props.rs`),
/// quantized instantiations decode per element and accumulate in f32.
pub fn matmul_into_prepacked<T: Dtype>(
    a: &[f32],
    bp: &PackedB<T>,
    c: &mut [f32],
    m: usize,
    threads: usize,
    packs: &mut PackScratch,
) {
    let (k, n) = (bp.k, bp.n);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    if m * k * n < PACK_MIN_MACS {
        return gemm_small_panels(a, bp.panels(), &bp.scales, c, m, k, n);
    }
    // Only the A-side scratch is needed; request a zero-width B pack.
    let (apack, _) = packs.for_shape(m, k, 0);
    gemm_from_panels(Orient::Nn, a, bp.panels(), &bp.scales, apack, c, m, k, n, threads);
}

/// [`matmul_into_prepacked`] for a runtime-dtyped operand: one match, then
/// the monomorphic kernel. The F32 arm is the bit-exact path.
pub fn matmul_into_prepacked_any(
    a: &[f32],
    bp: &PackedBAny,
    c: &mut [f32],
    m: usize,
    threads: usize,
    packs: &mut PackScratch,
) {
    match bp {
        PackedBAny::F32(p) => matmul_into_prepacked(a, p, c, m, threads, packs),
        PackedBAny::Bf16(p) => matmul_into_prepacked(a, p, c, m, threads, packs),
        PackedBAny::I8(p) => matmul_into_prepacked(a, p, c, m, threads, packs),
    }
}

/// Serial small-product path reading B from its NR-panels: every output
/// element accumulates its k products in ascending order — exactly the
/// chain of [`gemm_small`]'s Nn arm, so prepacked small products keep the
/// family-wide bit-identity contract (at f32; quantized panels decode per
/// element first, accumulation unchanged).
fn gemm_small_panels<T: Dtype>(
    a: &[f32],
    bp: &[T],
    scales: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            for (q, cchunk) in crow.chunks_mut(NR).enumerate() {
                let scale = scales.get(q).copied().unwrap_or(1.0);
                let brow = &bp[q * k * NR + kk * NR..q * k * NR + (kk + 1) * NR];
                for (cv, &bv) in cchunk.iter_mut().zip(brow) {
                    *cv += aik * bv.decode(scale);
                }
            }
        }
    }
}

/// The one packed kernel behind all three orientations.
#[allow(clippy::too_many_arguments)]
fn gemm(
    orient: Orient,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
    packs: &mut PackScratch,
) {
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 || k == 0 {
        return; // k == 0 leaves C unchanged: the kernel accumulates.
    }
    if m * k * n < PACK_MIN_MACS {
        return gemm_small(orient, a, b, c, m, k, n);
    }
    let (apack, bpack) = packs.for_shape(m, k, n);
    pack_b(orient, b, bpack, k, n, threads);
    gemm_from_panels(orient, a, bpack, &[], apack, c, m, k, n, threads);
}

/// The banding + microkernel body shared by the pack-on-call path and the
/// prepacked-B path ([`matmul_into_prepacked`]). `orient` governs only how
/// the A packer reads its source; `bp` already holds the NR-panels of the
/// logical `(k × n)` B at storage dtype `T` with `scales` holding one f32
/// per panel for scaled encodings (empty reads as 1.0 — the per-call f32
/// path and the scale-free dtypes).
#[allow(clippy::too_many_arguments)]
fn gemm_from_panels<T: Dtype>(
    orient: Orient,
    a: &[f32],
    bp: &[T],
    scales: &[f32],
    apack: &mut [f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    let (mp, np) = (m.div_ceil(MR), n.div_ceil(NR));
    debug_assert_eq!(bp.len(), np * NR * k);
    let th = kernel_threads(threads, m * k * n);
    let cs = SharedSliceMut::new(c);
    let aps = SharedSliceMut::new(apack);
    scope_rows(th, mp, MIN_BAND_PANELS, |pr| {
        let row0 = pr.start * MR;
        let row1 = (pr.end * MR).min(m);
        // SAFETY: panel bands are disjoint, so this band's C row range and
        // packed-A region are touched by exactly one worker.
        let c_band = unsafe { cs.range_mut(row0 * n, row1 * n) };
        let a_band = unsafe { aps.range_mut(pr.start * k * MR, pr.end * k * MR) };
        pack_a(orient, a, a_band, pr.clone(), m, k);
        // KC tiles ascending, panels inside: every (i, j) accumulates its
        // k products in ascending order with exact C round-trips between
        // tiles — the bit-identity invariant.
        let mut k0 = 0;
        while k0 < k {
            let kc = KC.min(k - k0);
            for q in 0..np {
                let bpanel = &bp[q * k * NR + k0 * NR..q * k * NR + (k0 + kc) * NR];
                let scale = scales.get(q).copied().unwrap_or(1.0);
                let nr_eff = NR.min(n - q * NR);
                for p in pr.clone() {
                    let po = (p - pr.start) * k * MR;
                    let apanel = &a_band[po + k0 * MR..po + (k0 + kc) * MR];
                    let mr_eff = MR.min(m - p * MR);
                    let coff = (p * MR - row0) * n + q * NR;
                    micro_tile(apanel, bpanel, scale, &mut c_band[coff..], n, mr_eff, nr_eff);
                }
            }
            k0 += kc;
        }
    });
}

/// Direct serial path for sub-[`PACK_MIN_MACS`] products (the r×r adapter
/// factors): everything fits in L1, so panel packing would cost a
/// meaningful fraction of the arithmetic. Each element accumulates the
/// same k-ascending chain as the packed path — bit-identical output.
fn gemm_small(orient: Orient, a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    match orient {
        Orient::Nn => {
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n..(i + 1) * n];
                for (kk, &aik) in arow.iter().enumerate() {
                    let brow = &b[kk * n..(kk + 1) * n];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += aik * bv;
                    }
                }
            }
        }
        Orient::Nt => {
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n..(i + 1) * n];
                for (cv, brow) in crow.iter_mut().zip(b.chunks_exact(k)) {
                    let mut acc = *cv;
                    for (&av, &bv) in arow.iter().zip(brow) {
                        acc += av * bv;
                    }
                    *cv = acc;
                }
            }
        }
        Orient::Tn => {
            for kk in 0..k {
                let arow = &a[kk * m..(kk + 1) * m];
                let brow = &b[kk * n..(kk + 1) * n];
                for (i, &aval) in arow.iter().enumerate() {
                    let crow = &mut c[i * n..(i + 1) * n];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += aval * bv;
                    }
                }
            }
        }
    }
}

/// Pack the A-side panels for `panels` (each MR rows of the logical
/// (m × k) A) into `dst`, column-major within each panel so the microkernel
/// reads one contiguous MR-chunk per k step. Rows past `m` pad with zeros.
fn pack_a(orient: Orient, a: &[f32], dst: &mut [f32], panels: Range<usize>, m: usize, k: usize) {
    debug_assert_eq!(dst.len(), (panels.end - panels.start) * k * MR);
    match orient {
        // A is (m × k) row-major: stream each panel row, scatter MR-strided.
        Orient::Nn | Orient::Nt => {
            for (pi, dst_p) in dst.chunks_exact_mut(k * MR).enumerate() {
                let row0 = (panels.start + pi) * MR;
                for i in 0..MR {
                    let row = row0 + i;
                    if row < m {
                        for (kk, &v) in a[row * k..(row + 1) * k].iter().enumerate() {
                            dst_p[kk * MR + i] = v;
                        }
                    } else {
                        for kk in 0..k {
                            dst_p[kk * MR + i] = 0.0;
                        }
                    }
                }
            }
        }
        // A is (k × m): the pack absorbs the transpose — each k row of the
        // source contributes one contiguous MR-chunk per panel.
        Orient::Tn => {
            for (pi, dst_p) in dst.chunks_exact_mut(k * MR).enumerate() {
                let col0 = (panels.start + pi) * MR;
                let w = MR.min(m - col0);
                for kk in 0..k {
                    let src = &a[kk * m + col0..kk * m + col0 + w];
                    let out = &mut dst_p[kk * MR..(kk + 1) * MR];
                    out[..w].copy_from_slice(src);
                    for v in &mut out[w..] {
                        *v = 0.0;
                    }
                }
            }
        }
    }
}

/// Pack all NR-wide B panels of the logical (k × n) B into `bpack`; columns
/// past `n` pad with zeros. Panels are independent (each reads its own
/// column strip, writes its own contiguous `k·NR` chunk), so above
/// [`PACK_PAR_MIN_ELEMS`] source elements the panel range is banded across
/// `threads` workers — pure data movement into disjoint destinations, so
/// the packed bytes (and therefore every downstream output bit) are
/// identical at any thread count.
fn pack_b(orient: Orient, b: &[f32], bpack: &mut [f32], k: usize, n: usize, threads: usize) {
    let np = n.div_ceil(NR);
    debug_assert_eq!(bpack.len(), np * NR * k);
    let th = gated_threads(threads, k * n, PACK_PAR_MIN_ELEMS);
    let bs = SharedSliceMut::new(bpack);
    scope_rows(th, np, MIN_BAND_PANELS, |qr| {
        // SAFETY: panel bands are disjoint — exactly one worker writes
        // this contiguous run of packed panels.
        let band = unsafe { bs.range_mut(qr.start * k * NR, qr.end * k * NR) };
        pack_b_panels(orient, b, band, qr, k, n);
    });
}

/// Pack the B panels of `panels` (NR columns each) into `dst` — the serial
/// per-band body of [`pack_b`].
fn pack_b_panels(
    orient: Orient,
    b: &[f32],
    dst: &mut [f32],
    panels: Range<usize>,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(dst.len(), (panels.end - panels.start) * k * NR);
    match orient {
        // B is (k × n) row-major: fill panel-major (q outer) so every
        // write is sequential within one panel buffer. The reads stride by
        // n, but consecutive panels read adjacent 32-byte column strips —
        // the k-cache-line working set of a strip stays resident across
        // panel passes, whereas a kk-outer order would keep `np` strided
        // write streams alive at once and thrash wide-n packs (MLP f,
        // vocab-sized GEMMs).
        Orient::Nn | Orient::Tn => {
            for (qi, dst_q) in dst.chunks_exact_mut(k * NR).enumerate() {
                let j0 = (panels.start + qi) * NR;
                let w = NR.min(n - j0);
                for kk in 0..k {
                    let dst = &mut dst_q[kk * NR..(kk + 1) * NR];
                    dst[..w].copy_from_slice(&b[kk * n + j0..kk * n + j0 + w]);
                    for v in &mut dst[w..] {
                        *v = 0.0;
                    }
                }
            }
        }
        // B is (n × k): the pack absorbs the transpose — each logical
        // column j is a contiguous source row, scattered NR-strided into
        // its panel.
        Orient::Nt => {
            for (qi, dst_q) in dst.chunks_exact_mut(k * NR).enumerate() {
                for j in 0..NR {
                    let row = (panels.start + qi) * NR + j;
                    if row < n {
                        for (kk, &v) in b[row * k..(row + 1) * k].iter().enumerate() {
                            dst_q[kk * NR + j] = v;
                        }
                    } else {
                        for kk in 0..k {
                            dst_q[kk * NR + j] = 0.0;
                        }
                    }
                }
            }
        }
    }
}

/// One MR×NR output tile over a KC-bounded k range: load C, run the
/// register-tiled inner kernel, store C. `c` starts at the tile's top-left
/// element with row stride `ldc`; only the `mr_eff × nr_eff` valid region
/// is loaded and stored (padded panel lanes accumulate zeros into dead
/// accumulator slots). The B panel is stored at dtype `T` and widened to
/// f32 per element (`scale` is this panel's quantization scale); for
/// `T = f32` the decode is the identity and the kernel is exactly the
/// pre-dtype instruction stream.
fn micro_tile<T: Dtype>(
    apanel: &[f32],
    bpanel: &[T],
    scale: f32,
    c: &mut [f32],
    ldc: usize,
    mr_eff: usize,
    nr_eff: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for (i, accrow) in acc.iter_mut().enumerate().take(mr_eff) {
        accrow[..nr_eff].copy_from_slice(&c[i * ldc..i * ldc + nr_eff]);
    }
    // The register-tiled inner loop: one contiguous MR-chunk of A and one
    // NR-chunk of B per k step; lanes span columns, each (i, j) keeps a
    // single k-ascending chain. Decode happens before the multiply, so
    // every product and every add round in f32.
    for (av, bv) in apanel.chunks_exact(MR).zip(bpanel.chunks_exact(NR)) {
        let av: &[f32; MR] = av.try_into().expect("MR chunk");
        let bv: &[T; NR] = bv.try_into().expect("NR chunk");
        let mut bw = [0.0f32; NR];
        for (w, &bj) in bw.iter_mut().zip(bv) {
            *w = bj.decode(scale);
        }
        for (accrow, &ai) in acc.iter_mut().zip(av) {
            for (slot, &bj) in accrow.iter_mut().zip(&bw) {
                *slot += ai * bj;
            }
        }
    }
    for (i, accrow) in acc.iter().enumerate().take(mr_eff) {
        c[i * ldc..i * ldc + nr_eff].copy_from_slice(&accrow[..nr_eff]);
    }
}

// ---------------------------------------------------------------------------
// Blocked transpose.
// ---------------------------------------------------------------------------

/// Tile-blocked transpose of a row-major `rows × cols` slice into the
/// row-major `cols × rows` destination. A naive element loop walks the
/// destination with a `rows`-stride and evicts every cache line `TB` times;
/// blocking on TB×TB tiles keeps both the source rows and the destination
/// columns of a tile resident. (`Tensor::transpose` routes through this;
/// the GEMM family itself never materializes a transpose — its pack step
/// absorbs operand orientation.)
pub fn transpose_into(src: &[f32], dst: &mut [f32], rows: usize, cols: usize) {
    debug_assert_eq!(src.len(), rows * cols);
    debug_assert_eq!(dst.len(), rows * cols);
    for i0 in (0..rows).step_by(TB) {
        let i1 = (i0 + TB).min(rows);
        for j0 in (0..cols).step_by(TB) {
            let j1 = (j0 + TB).min(cols);
            for i in i0..i1 {
                let srow = &src[i * cols..(i + 1) * cols];
                for j in j0..j1 {
                    dst[j * rows + i] = srow[j];
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Elementwise / reduction helpers.
// ---------------------------------------------------------------------------

/// In-place row-wise numerically-stable softmax over a row-major
/// `rows × cols` buffer (the attention-probability transform).
pub fn softmax_rows_into(x: &mut [f32], rows: usize, cols: usize) {
    debug_assert_eq!(x.len(), rows * cols);
    for row in x.chunks_exact_mut(cols) {
        let mx = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mut z = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            z += *v;
        }
        for v in row.iter_mut() {
            *v /= z;
        }
    }
}

/// Slice axpy: `dst += s * src` (each product rounded once, then added —
/// identical to `Tensor::axpy` on the same data).
pub fn axpy_into(dst: &mut [f32], s: f32, src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, &x) in dst.iter_mut().zip(src) {
        *d += s * x;
    }
}

/// Elementwise sum into a destination buffer: `dst = a + b`.
pub fn add_into(a: &[f32], b: &[f32], dst: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), dst.len());
    for ((d, &x), &y) in dst.iter_mut().zip(a).zip(b) {
        *d = x + y;
    }
}

/// In-place scalar multiply.
pub fn scale_into(x: &mut [f32], s: f32) {
    for v in x.iter_mut() {
        *v *= s;
    }
}

/// Relative Frobenius error ‖a-b‖/max(‖b‖, eps); the standard closeness
/// measure used across tests.
pub fn rel_err(a: &Tensor, b: &Tensor) -> f32 {
    assert_eq!(a.shape(), b.shape());
    let diff = a.sub(b).fro_norm();
    diff / b.fro_norm().max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for t in 0..k {
                    acc += a.at(i, t) * b.at(t, j);
                }
                c.set(i, j, acc);
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive_on_random_shapes() {
        let mut rng = Pcg64::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (65, 64, 63), (128, 17, 70)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let got = a.matmul(&b);
            let want = naive_matmul(&a, &b);
            assert!(rel_err(&got, &want) < 1e-5, "({m},{k},{n})");
        }
    }

    #[test]
    fn pack_sizes_round_up_to_panels() {
        assert_eq!(pack_sizes(1, 3, 1), (MR * 3, NR * 3));
        assert_eq!(pack_sizes(MR, 2, NR), (MR * 2, NR * 2));
        assert_eq!(pack_sizes(MR + 1, 2, NR + 1), (2 * MR * 2, 2 * NR * 2));
        assert_eq!(pack_sizes(0, 5, 7), (0, NR * 5));
    }

    #[test]
    fn transpose_variants_agree() {
        let mut rng = Pcg64::new(2);
        let a = Tensor::randn(&[9, 13], 1.0, &mut rng);
        let b = Tensor::randn(&[9, 11], 1.0, &mut rng);
        // a^T b via t_matmul vs explicit transpose
        let got = a.t_matmul(&b);
        let want = a.transpose().matmul(&b);
        assert!(rel_err(&got, &want) < 1e-5);
        // a b^T via matmul_t
        let c = Tensor::randn(&[7, 13], 1.0, &mut rng);
        let got2 = a.matmul_t(&c);
        let want2 = a.matmul(&c.transpose());
        assert!(rel_err(&got2, &want2) < 1e-5);
    }

    #[test]
    fn blocked_transpose_matches_elementwise_on_tile_straddling_shapes() {
        let mut rng = Pcg64::new(21);
        for &(r, c) in &[(1usize, 1usize), (1, 200), (200, 1), (31, 33), (64, 64), (97, 45)] {
            let t = Tensor::randn(&[r, c], 1.0, &mut rng);
            let tt = t.transpose();
            assert_eq!(tt.shape(), &[c, r]);
            for i in 0..r {
                for j in 0..c {
                    assert_eq!(tt.at(j, i), t.at(i, j), "({r},{c}) at ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg64::new(3);
        let a = Tensor::randn(&[6, 6], 1.0, &mut rng);
        assert!(rel_err(&a.matmul(&Tensor::eye(6)), &a) < 1e-6);
        assert!(rel_err(&Tensor::eye(6).matmul(&a), &a) < 1e-6);
    }

    #[test]
    fn slices() {
        let t = Tensor::from_vec(&[3, 4], (0..12).map(|x| x as f32).collect());
        assert_eq!(t.row(1).data(), &[4., 5., 6., 7.]);
        assert_eq!(t.rows_slice(1, 3).shape(), &[2, 4]);
        assert_eq!(t.cols_slice(1, 3).data(), &[1., 2., 5., 6., 9., 10.]);
    }

    #[test]
    fn mid_slice_roundtrip() {
        let mut rng = Pcg64::new(4);
        let mut core = Tensor::randn(&[3, 5, 2], 1.0, &mut rng);
        let m = Tensor::randn(&[3, 2], 1.0, &mut rng);
        core.set_mid_slice(2, &m);
        assert_eq!(core.mid_slice(2), m);
        // untouched slices keep their values finite and distinct
        assert_ne!(core.mid_slice(1), m);
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = Tensor::full(&[2, 2], 1.0);
        let b = Tensor::full(&[2, 2], 2.0);
        a.axpy(0.5, &b);
        assert_eq!(a.data(), &[2.0, 2.0, 2.0, 2.0]);
        assert_eq!(a.scale(0.5).data(), &[1.0, 1.0, 1.0, 1.0]);
    }

    #[test]
    fn kernels_accumulate_into_nonzero_output() {
        // The encoder's backward fuses `dst += A·B` through the kernels'
        // accumulation semantics; pin it for all three orientations.
        let mut rng = Pcg64::new(11);
        let mut packs = PackScratch::new();
        let a = Tensor::randn(&[5, 7], 1.0, &mut rng);
        let b = Tensor::randn(&[7, 4], 1.0, &mut rng);
        let base = Tensor::randn(&[5, 4], 1.0, &mut rng);
        let mut c = base.clone();
        matmul_into(a.data(), b.data(), c.data_mut(), 5, 7, 4, 1, &mut packs);
        let want = base.add(&a.matmul(&b));
        assert!(rel_err(&c, &want) < 1e-5, "matmul_into accumulate");
        let bt = b.transpose(); // (4, 7)
        let mut c2 = base.clone();
        matmul_t_into(a.data(), bt.data(), c2.data_mut(), 5, 7, 4, 1, &mut packs);
        assert!(rel_err(&c2, &want) < 1e-5, "matmul_t_into accumulate");
        let at = a.transpose(); // (7, 5)
        let mut c3 = base.clone();
        t_matmul_into(at.data(), b.data(), c3.data_mut(), 5, 7, 4, 1, &mut packs);
        assert!(rel_err(&c3, &want) < 1e-5, "t_matmul_into accumulate");
    }

    #[test]
    fn prepacked_b_is_bit_identical() {
        // A bind-time PackedB must produce the same bits as the per-call
        // pack on both sides of the small-product threshold, accumulating
        // into non-zero C, at 1 and 4 threads.
        let mut rng = Pcg64::new(17);
        let mut packs = PackScratch::new();
        for &(m, k, n) in &[
            (1usize, 4usize, 4usize), // tiny: panel-reading scalar path
            (3, 5, 7),                // ragged tiny
            (8, 8, 8),                // just under the pack threshold
            (64, 64, 64),             // packed path, exact panels
            (37, 129, 21),            // packed path, ragged panels
            (130, 70, 90),            // packed path, ragged everything
        ] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let base = Tensor::randn(&[m, n], 1.0, &mut rng);
            let bp = PackedB::pack(b.data(), k, n);
            assert_eq!((bp.k(), bp.n()), (k, n));
            assert!(bp.panel_bytes() >= k * n * 4);
            for threads in [1usize, 4] {
                let mut c0 = base.clone();
                matmul_into(a.data(), b.data(), c0.data_mut(), m, k, n, threads, &mut packs);
                let mut c1 = base.clone();
                matmul_into_prepacked(a.data(), &bp, c1.data_mut(), m, threads, &mut packs);
                for (x, y) in c0.data().iter().zip(c1.data()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "({m},{k},{n}) threads={threads}");
                }
            }
        }
    }

    #[test]
    fn parallel_pack_b_is_bit_identical() {
        // pack_b bands NR-panels across workers above PACK_PAR_MIN_ELEMS
        // source elements; it is pure data movement, so 1-thread and
        // 4-thread GEMMs must agree bit-for-bit on both sides of the
        // banding threshold.
        let mut rng = Pcg64::new(23);
        let mut packs = PackScratch::new();
        for &(m, k, n) in &[
            (40usize, 260usize, 300usize), // k·n ≈ 78k > PACK_PAR_MIN_ELEMS: banded pack
            (40, 60, 70),                  // under the threshold: serial pack
        ] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let mut c1 = vec![0.0f32; m * n];
            matmul_into(a.data(), b.data(), &mut c1, m, k, n, 1, &mut packs);
            let mut c4 = vec![0.0f32; m * n];
            matmul_into(a.data(), b.data(), &mut c4, m, k, n, 4, &mut packs);
            for (x, y) in c1.iter().zip(&c4) {
                assert_eq!(x.to_bits(), y.to_bits(), "({m},{k},{n})");
            }
        }
    }

    #[test]
    fn dtyped_prepacked_f32_variant_is_bit_identical() {
        // PackedBAny::F32 must route through the exact pre-dtype pack and
        // kernel: same bits as the per-call path.
        let mut rng = Pcg64::new(29);
        let mut packs = PackScratch::new();
        let (m, k, n) = (37usize, 64usize, 50usize);
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let bp = PackedBAny::pack(b.data(), k, n, DtypeKind::F32);
        assert_eq!(bp.kind(), DtypeKind::F32);
        assert_eq!((bp.k(), bp.n()), (k, n));
        let mut c0 = vec![0.0f32; m * n];
        matmul_into(a.data(), b.data(), &mut c0, m, k, n, 1, &mut packs);
        let mut c1 = vec![0.0f32; m * n];
        matmul_into_prepacked_any(a.data(), &bp, &mut c1, m, 1, &mut packs);
        for (x, y) in c0.iter().zip(&c1) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn quantized_prepacked_matches_f32_within_tolerance() {
        // bf16 / int8 packed operands decode per element and accumulate in
        // f32; outputs stay within the dtype's quantization tolerance of
        // the f32 product on both sides of the small-product threshold and
        // at 1 and 4 threads.
        let mut rng = Pcg64::new(31);
        let mut packs = PackScratch::new();
        for &(m, k, n) in &[(3usize, 5usize, 7usize), (37, 129, 21), (64, 64, 64)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let mut want = vec![0.0f32; m * n];
            matmul_into(a.data(), b.data(), &mut want, m, k, n, 1, &mut packs);
            for (kind, tol) in [(DtypeKind::Bf16, 5e-2f32), (DtypeKind::I8, 2e-1)] {
                let bp = PackedBAny::pack(b.data(), k, n, kind);
                assert_eq!(bp.kind(), kind);
                // Quantized panels hold fewer bytes than the f32 pack.
                let f32_bytes = PackedB::pack(b.data(), k, n).panel_bytes();
                assert!(bp.panel_bytes() < f32_bytes, "{kind:?} ({m},{k},{n})");
                for threads in [1usize, 4] {
                    let mut got = vec![0.0f32; m * n];
                    matmul_into_prepacked_any(a.data(), &bp, &mut got, m, threads, &mut packs);
                    // k-length dot products of N(0,1) data have stddev √k;
                    // normalize the error bound by that.
                    let denom = (k as f32).sqrt();
                    for (g, w) in got.iter().zip(&want) {
                        assert!(
                            (g - w).abs() / denom < tol,
                            "{kind:?} ({m},{k},{n}) t={threads}: {g} vs {w}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn quantized_pack_bytes_shrink_with_dtype() {
        let (k, n) = (64usize, 48usize);
        let b = vec![0.5f32; k * n];
        let f32b = PackedBAny::pack(&b, k, n, DtypeKind::F32).panel_bytes();
        let bf16b = PackedBAny::pack(&b, k, n, DtypeKind::Bf16).panel_bytes();
        let i8b = PackedBAny::pack(&b, k, n, DtypeKind::I8).panel_bytes();
        assert!(bf16b < f32b, "bf16 {bf16b} vs f32 {f32b}");
        assert!(i8b < bf16b, "int8 {i8b} vs bf16 {bf16b}");
        // int8 carries one f32 scale per NR-panel on top of 1-byte elems.
        assert_eq!(i8b, n.div_ceil(NR) * NR * k + n.div_ceil(NR) * 4);
    }

    #[test]
    fn arena_and_local_pack_paths_are_bit_identical() {
        // The `*_into_local` variants only swap where the pack scratch
        // lives; the packed panels — and therefore the bits — must match.
        let mut rng = Pcg64::new(13);
        let (m, k, n) = (37, 29, 21);
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let mut packs = PackScratch::new();
        let mut c_arena = vec![0.0f32; m * n];
        matmul_into(a.data(), b.data(), &mut c_arena, m, k, n, 1, &mut packs);
        let mut c_local = vec![0.0f32; m * n];
        matmul_into_local(a.data(), b.data(), &mut c_local, m, k, n, 1);
        for (x, y) in c_arena.iter().zip(&c_local) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn in_place_ops_match_tensor_ops() {
        let mut rng = Pcg64::new(6);
        // softmax_rows_into matches a per-row manual softmax.
        let t = Tensor::randn(&[3, 5], 2.0, &mut rng);
        let mut s = t.data().to_vec();
        softmax_rows_into(&mut s, 3, 5);
        for i in 0..3 {
            let row = &s[i * 5..(i + 1) * 5];
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "row {i} sums to {sum}");
            // Ordering preserved (softmax is monotone).
            let src = &t.data()[i * 5..(i + 1) * 5];
            for a in 0..5 {
                for b in 0..5 {
                    assert_eq!(src[a] < src[b], row[a] < row[b]);
                }
            }
        }
        // axpy_into is bitwise-identical to Tensor::axpy.
        let mut a = Tensor::randn(&[4, 4], 1.0, &mut rng);
        let b = Tensor::randn(&[4, 4], 1.0, &mut rng);
        let mut raw = a.data().to_vec();
        a.axpy(0.3, &b);
        axpy_into(&mut raw, 0.3, b.data());
        assert_eq!(a.data(), &raw[..]);
        // add_into matches Tensor::add; scale_into matches Tensor::scale.
        let c = Tensor::randn(&[2, 3], 1.0, &mut rng);
        let d = Tensor::randn(&[2, 3], 1.0, &mut rng);
        let mut sum = vec![0.0f32; 6];
        add_into(c.data(), d.data(), &mut sum);
        assert_eq!(&sum[..], c.add(&d).data());
        scale_into(&mut sum, 0.5);
        assert_eq!(&sum[..], c.add(&d).scale(0.5).data());
    }

    #[test]
    fn associativity_of_chain_products() {
        // (X G1) G2 == X (G1 G2) — the algebraic fact the TT apply relies on.
        let mut rng = Pcg64::new(5);
        let x = Tensor::randn(&[8, 16], 1.0, &mut rng);
        let g1 = Tensor::randn(&[16, 4], 1.0, &mut rng);
        let g2 = Tensor::randn(&[4, 4], 1.0, &mut rng);
        let left = x.matmul(&g1).matmul(&g2);
        let right = x.matmul(&g1.matmul(&g2));
        assert!(rel_err(&left, &right) < 1e-5);
    }
}
