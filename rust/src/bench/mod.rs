//! Micro-benchmark harness and table emitters.
//!
//! `criterion` is absent from the offline registry; this module provides the
//! subset the repo needs: warmup + timed iterations with mean / stddev /
//! percentile reporting, plus markdown & CSV table builders used by the
//! per-paper-table bench binaries to print rows in the paper's layout.

use std::time::{Duration, Instant};

/// Timing statistics over a set of sample durations (seconds).
#[derive(Clone, Debug)]
pub struct Stats {
    pub samples: Vec<f64>,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    /// Tail percentile (the serving-latency SLO number).
    pub p99: f64,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<f64>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
        // Linear interpolation between order statistics (type-7 estimator,
        // the numpy/R default). Round-to-nearest-rank collapses p95/p99
        // onto the max (or onto each other) for small n, which biased the
        // BENCH tail numbers exactly where tails matter.
        let pct = |p: f64| {
            let rank = p * (samples.len() - 1) as f64;
            let lo = rank.floor() as usize;
            let hi = rank.ceil() as usize;
            let frac = rank - lo as f64;
            samples[lo] + frac * (samples[hi] - samples[lo])
        };
        Stats {
            mean,
            std: var.sqrt(),
            min: samples[0],
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            samples,
        }
    }

    /// Human-friendly duration formatting.
    pub fn fmt_time(secs: f64) -> String {
        if secs >= 1.0 {
            format!("{:.3} s", secs)
        } else if secs >= 1e-3 {
            format!("{:.3} ms", secs * 1e3)
        } else if secs >= 1e-6 {
            format!("{:.3} µs", secs * 1e6)
        } else {
            format!("{:.1} ns", secs * 1e9)
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "mean {} ± {}  (min {}, p50 {}, p95 {}, p99 {}, n={})",
            Self::fmt_time(self.mean),
            Self::fmt_time(self.std),
            Self::fmt_time(self.min),
            Self::fmt_time(self.p50),
            Self::fmt_time(self.p95),
            Self::fmt_time(self.p99),
            self.samples.len()
        )
    }
}

/// Benchmark a closure: `warmup` untimed runs, then `iters` timed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let stats = Stats::from_samples(samples);
    println!("[bench] {:<42} {}", name, stats.summary());
    stats
}

/// Benchmark with a time budget: run until `budget` elapses (at least
/// `min_iters`). Suited for end-to-end steps of uneven cost.
pub fn bench_for(name: &str, budget: Duration, min_iters: usize, mut f: impl FnMut()) -> Stats {
    f(); // warmup
    let start = Instant::now();
    let mut samples = Vec::new();
    while start.elapsed() < budget || samples.len() < min_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if samples.len() > 10_000 {
            break;
        }
    }
    let stats = Stats::from_samples(samples);
    println!("[bench] {:<42} {}", name, stats.summary());
    stats
}

/// A simple aligned-markdown table builder for paper-style result tables.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render as aligned markdown.
    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let body: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            format!("| {} |", body.join(" | "))
        };
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        let mut out = format!("\n### {}\n\n", self.title);
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&format!("| {} |\n", sep.join(" | ")));
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (for plotting scripts).
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = self.header.iter().map(esc).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Print markdown to stdout and persist both renderings under results/.
    pub fn emit(&self, stem: &str) {
        print!("{}", self.to_markdown());
        let _ = std::fs::create_dir_all("results");
        let _ = std::fs::write(format!("results/{stem}.md"), self.to_markdown());
        let _ = std::fs::write(format!("results/{stem}.csv"), self.to_csv());
        println!("\n[saved] results/{stem}.md results/{stem}.csv");
    }
}

/// Where the record `BENCH_<name>.json` lands: the working directory by
/// default, overridable via `METATT_BENCH_<NAME>_OUT` (read-only env
/// access — nothing here ever mutates the environment). The pr2 record
/// also honors the pre-PR-4 spelling `METATT_BENCH_OUT`, which
/// hotpath_micro used before emission was centralized here.
pub fn record_path(name: &str) -> String {
    if let Ok(p) = std::env::var(format!("METATT_BENCH_{}_OUT", name.to_uppercase())) {
        return p;
    }
    if name == "pr2" {
        if let Ok(p) = std::env::var("METATT_BENCH_OUT") {
            return p;
        }
    }
    format!("BENCH_{name}.json")
}

/// Serialize a record document to `path` (pretty JSON).
fn write_record_to(path: &str, doc: &crate::util::json::Json) -> std::io::Result<()> {
    std::fs::write(path, doc.to_pretty())
}

/// Persist a per-PR benchmark record at [`record_path`] and print where it
/// landed. One helper so PR-specific bench sections share the env/path
/// logic instead of copy-pasting it.
pub fn save_record(name: &str, doc: &crate::util::json::Json) -> std::io::Result<()> {
    let path = record_path(name);
    write_record_to(&path, doc)?;
    println!("[saved] {path}");
    Ok(())
}

/// Format `mean(std-err-in-last-digit)` the way the paper prints metrics,
/// e.g. 88.6(4) for 88.6 ± 0.4. Values in percent.
pub fn paper_fmt(mean: f64, stderr: f64) -> String {
    if !mean.is_finite() {
        return "n/a".into();
    }
    if stderr <= 0.0 || !stderr.is_finite() {
        return format!("{:.1}", mean);
    }
    if stderr >= 1.0 {
        format!("{:.0}({:.0})", mean, stderr.ceil())
    } else {
        format!("{:.1}({:.0})", mean, (stderr * 10.0).ceil())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_on_known_samples() {
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert!(s.p95 >= s.p50);
        assert!(s.p99 >= s.p95);
    }

    #[test]
    fn percentiles_interpolate_between_order_statistics() {
        // n=4: ranks are p*(n-1). The old round-to-nearest-rank estimator
        // returned s[2]=3.0 for p50 and s[3]=4.0 for both p95 and p99 —
        // the median was biased a whole sample upward and the two tail
        // percentiles collapsed onto the max (and onto each other).
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0]);
        assert!((s.p50 - 2.5).abs() < 1e-12, "median of 4 samples, got {}", s.p50);
        assert!((s.p95 - 3.85).abs() < 1e-12, "p95 rank 2.85, got {}", s.p95);
        assert!((s.p99 - 3.97).abs() < 1e-12, "p99 rank 2.97, got {}", s.p99);
        assert!(s.p99 < 4.0 && s.p95 < s.p99, "tails must not collapse onto the max");
        // Exact-integer ranks land on the order statistic itself.
        let t = Stats::from_samples(vec![10.0, 20.0, 30.0]);
        assert_eq!(t.p50, 20.0);
        // A single sample is every percentile.
        let one = Stats::from_samples(vec![7.0]);
        assert_eq!((one.p50, one.p95, one.p99), (7.0, 7.0, 7.0));
        // Unsorted input is sorted first.
        let u = Stats::from_samples(vec![4.0, 1.0, 3.0, 2.0]);
        assert!((u.p50 - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_prints_the_slo_tail() {
        // The doc comment calls p99 "the serving-latency SLO number";
        // summary() must actually print it.
        let s = Stats::from_samples(vec![1.0; 5]);
        assert!(s.summary().contains("p99"), "summary omits p99: {}", s.summary());
    }

    #[test]
    fn bench_runs_closure() {
        let mut count = 0;
        let s = bench("noop", 2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.samples.len(), 5);
    }

    #[test]
    fn table_markdown_and_csv() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into(), "x,y".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| a | b"));
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
    }

    #[test]
    fn paper_fmt_matches_convention() {
        assert_eq!(paper_fmt(88.62, 0.36), "88.6(4)");
        assert_eq!(paper_fmt(61.0, 2.1), "61(3)");
        assert_eq!(paper_fmt(90.0, 0.0), "90.0");
    }

    #[test]
    fn fmt_time_units() {
        assert!(Stats::fmt_time(2.0).ends_with(" s"));
        assert!(Stats::fmt_time(2e-3).ends_with("ms"));
        assert!(Stats::fmt_time(2e-6).ends_with("µs"));
        assert!(Stats::fmt_time(2e-9).ends_with("ns"));
    }

    #[test]
    fn record_path_and_write_round_trip() {
        use crate::util::json::Json;
        // Default path derivation (no env mutation: set_var in a parallel
        // test harness races other tests' env reads).
        assert_eq!(record_path("testrec"), "BENCH_testrec.json");
        // The writer half, against an explicit temp path.
        let path = std::env::temp_dir().join("metatt_bench_testrec.json");
        let doc = Json::obj(vec![("ok", Json::Bool(true))]);
        write_record_to(path.to_str().unwrap(), &doc).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.contains("\"ok\""), "record body: {body}");
        let _ = std::fs::remove_file(&path);
    }
}
