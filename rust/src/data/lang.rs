//! The planted synthetic language shared by every task generator.
//!
//! Token space (vocab `V`, default 1024):
//! * ids 0..4 — specials: PAD, CLS, SEP, MASK.
//! * "function" tokens — emitted by a 6-state bigram automaton; sentences
//!   that follow the automaton are "grammatical".
//! * topic bands — contiguous id ranges carrying content: per-topic nouns.
//! * polarity bands — positive / negative sentiment carriers.
//!
//! A sentence is sampled by walking the automaton and, at content states,
//! emitting from the active topic / polarity band. Perturbation helpers
//! build the second element of pair tasks (paraphrase via synonym
//! substitution inside a band, contradiction via polarity flip, random
//! unrelated sentences, automaton violations for the CoLA analogue).

use crate::util::rng::Pcg64;

pub const PAD: u32 = 0;
pub const CLS: u32 = 1;
pub const SEP: u32 = 2;
pub const MASK: u32 = 3;
/// Number of reserved special ids.
pub const SPECIAL_TOKENS: u32 = 4;

/// Automaton states.
const N_STATES: usize = 6;

/// The synthetic language: vocabulary layout + transition tables.
#[derive(Clone, Debug)]
pub struct SynthLang {
    pub vocab: usize,
    /// Function-token range start (one sub-band per automaton state).
    func_base: u32,
    func_band: u32,
    /// Topic bands: `n_topics` bands of `band` tokens each.
    topic_base: u32,
    pub n_topics: usize,
    band: u32,
    /// Positive / negative polarity bands.
    pos_base: u32,
    neg_base: u32,
    pol_band: u32,
    /// Bigram automaton: transition[state] = list of next states.
    transition: [[usize; 2]; N_STATES],
}

impl SynthLang {
    /// Default layout for a given vocab size (>= 256).
    pub fn new(vocab: usize) -> SynthLang {
        assert!(vocab >= 256, "vocab too small");
        let func_band = 8u32;
        let func_base = SPECIAL_TOKENS;
        let n_topics = 8usize;
        let band = 24u32;
        let topic_base = func_base + N_STATES as u32 * func_band;
        let pol_band = 24u32;
        let pos_base = topic_base + n_topics as u32 * band;
        let neg_base = pos_base + pol_band;
        assert!((neg_base + pol_band) as usize <= vocab, "vocab layout overflow");
        SynthLang {
            vocab,
            func_base,
            func_band,
            topic_base,
            n_topics,
            band,
            pos_base,
            neg_base,
            pol_band,
            // A fixed, slightly non-trivial cycle structure.
            transition: [[1, 3], [2, 2], [3, 5], [4, 4], [5, 0], [0, 1]],
        }
    }

    /// Sample a grammatical sentence of exactly `len` tokens about `topic`
    /// with sentiment polarity `pol` (+1 positive, -1 negative, 0 neutral).
    ///
    /// Function tokens trace an automaton walk; after each function token a
    /// content token (topic/polarity carrier) may be interleaved *without*
    /// advancing the automaton, so the subsequence of function tokens is
    /// exactly an automaton path — the grammaticality invariant that
    /// [`is_grammatical`](Self::is_grammatical) checks and
    /// [`corrupt_grammar`](Self::corrupt_grammar) breaks.
    pub fn sentence(
        &self,
        len: usize,
        topic: usize,
        pol: i32,
        rng: &mut Pcg64,
    ) -> Vec<u32> {
        assert!(topic < self.n_topics);
        let mut out = Vec::with_capacity(len);
        let mut state = rng.uniform_usize(N_STATES);
        while out.len() < len {
            out.push(
                self.func_base + state as u32 * self.func_band
                    + rng.uniform_u32(self.func_band),
            );
            if out.len() < len && rng.bernoulli(0.55) {
                out.push(self.content_token(topic, pol, rng));
            }
            state = self.transition[state][rng.uniform_usize(2)];
        }
        out
    }

    fn content_token(&self, topic: usize, pol: i32, rng: &mut Pcg64) -> u32 {
        // Polarity token with prob 0.4 when polarized, else a topic token.
        if pol != 0 && rng.bernoulli(0.4) {
            let base = if pol > 0 { self.pos_base } else { self.neg_base };
            return base + rng.uniform_u32(self.pol_band);
        }
        self.topic_base + topic as u32 * self.band + rng.uniform_u32(self.band)
    }

    /// Is `tok` a function token, and if so which automaton state emitted it?
    fn func_state(&self, tok: u32) -> Option<usize> {
        if tok >= self.func_base && tok < self.func_base + N_STATES as u32 * self.func_band {
            Some(((tok - self.func_base) / self.func_band) as usize)
        } else {
            None
        }
    }

    /// Grammaticality check used to *verify* the CoLA generator: every
    /// consecutive pair of function tokens must be automaton-compatible.
    pub fn is_grammatical(&self, toks: &[u32]) -> bool {
        let states: Vec<usize> = toks.iter().filter_map(|&t| self.func_state(t)).collect();
        states.windows(2).all(|w| self.transition[w[0]].contains(&w[1]))
    }

    /// Corrupt grammar: replace function tokens so at least one automaton
    /// edge in the function-token subsequence becomes invalid (the
    /// CoLA-analogue negative class).
    pub fn corrupt_grammar(&self, toks: &mut [u32], rng: &mut Pcg64) {
        let idxs: Vec<usize> = toks
            .iter()
            .enumerate()
            .filter(|(_, &t)| self.func_state(t).is_some())
            .map(|(i, _)| i)
            .collect();
        if idxs.len() < 2 {
            // No function structure to break; overwrite the head with an
            // incompatible pair (state 0 → state 2 is invalid).
            if toks.len() >= 2 {
                toks[0] = self.func_base;
                toks[1] = self.func_base + 2 * self.func_band;
            }
            return;
        }
        // Break 1/3 of the edges: for a chosen position k >= 1, replace the
        // function token at idxs[k] with one from a state NOT reachable from
        // the state at idxs[k-1]. Each state has 2 successors of 6, so an
        // invalid target always exists.
        let n_corrupt = (idxs.len() / 3).max(1);
        for _ in 0..n_corrupt {
            let k = 1 + rng.uniform_usize(idxs.len() - 1);
            let prev_state = self.func_state(toks[idxs[k - 1]]).unwrap();
            let invalid: Vec<usize> = (0..N_STATES)
                .filter(|s| !self.transition[prev_state].contains(s))
                .collect();
            let bad_state = invalid[rng.uniform_usize(invalid.len())];
            toks[idxs[k]] = self.func_base + bad_state as u32 * self.func_band
                + rng.uniform_u32(self.func_band);
        }
    }

    /// Paraphrase: substitute content tokens by *synonyms* (same band),
    /// keeping function structure — token overlap is low but meaning (band
    /// pattern) is identical.
    pub fn paraphrase(&self, toks: &[u32], rng: &mut Pcg64) -> Vec<u32> {
        toks.iter()
            .map(|&t| {
                if t >= self.topic_base && t < self.pos_base {
                    let band_idx = (t - self.topic_base) / self.band;
                    self.topic_base + band_idx * self.band + rng.uniform_u32(self.band)
                } else if t >= self.pos_base && t < self.pos_base + self.pol_band {
                    self.pos_base + rng.uniform_u32(self.pol_band)
                } else if t >= self.neg_base && t < self.neg_base + self.pol_band {
                    self.neg_base + rng.uniform_u32(self.pol_band)
                } else {
                    t
                }
            })
            .collect()
    }

    /// Flip sentiment polarity tokens (entailment → contradiction).
    pub fn flip_polarity(&self, toks: &[u32]) -> Vec<u32> {
        toks.iter()
            .map(|&t| {
                if t >= self.pos_base && t < self.pos_base + self.pol_band {
                    t - self.pos_base + self.neg_base
                } else if t >= self.neg_base && t < self.neg_base + self.pol_band {
                    t - self.neg_base + self.pos_base
                } else {
                    t
                }
            })
            .collect()
    }

    /// Change the topic of content tokens (unrelated sentence derivation).
    pub fn retopic(&self, toks: &[u32], new_topic: usize, rng: &mut Pcg64) -> Vec<u32> {
        assert!(new_topic < self.n_topics);
        toks.iter()
            .map(|&t| {
                if t >= self.topic_base && t < self.pos_base {
                    self.topic_base + new_topic as u32 * self.band + rng.uniform_u32(self.band)
                } else {
                    t
                }
            })
            .collect()
    }

    /// Fraction of content positions whose band matches between a and b —
    /// the similarity signal for the STS-B analogue.
    pub fn band_similarity(&self, a: &[u32], b: &[u32]) -> f32 {
        let band_of = |t: u32| -> Option<u32> {
            if t >= self.topic_base && t < self.pos_base {
                Some((t - self.topic_base) / self.band)
            } else if t >= self.pos_base && t < self.pos_base + self.pol_band {
                Some(1000)
            } else if t >= self.neg_base && t < self.neg_base + self.pol_band {
                Some(1001)
            } else {
                None
            }
        };
        let ab: Vec<_> = a.iter().filter_map(|&t| band_of(t)).collect();
        let bb: Vec<_> = b.iter().filter_map(|&t| band_of(t)).collect();
        if ab.is_empty() || bb.is_empty() {
            return 0.0;
        }
        let n = ab.len().min(bb.len());
        let same = (0..n).filter(|&i| ab[i] == bb[i]).count();
        same as f32 / n as f32
    }

    /// Count positive minus negative polarity tokens (sentiment signal).
    pub fn polarity_score(&self, toks: &[u32]) -> i32 {
        toks.iter()
            .map(|&t| {
                if t >= self.pos_base && t < self.pos_base + self.pol_band {
                    1
                } else if t >= self.neg_base && t < self.neg_base + self.pol_band {
                    -1
                } else {
                    0
                }
            })
            .sum()
    }

    /// A random token id excluding specials — for MLM negative sampling.
    pub fn random_token(&self, rng: &mut Pcg64) -> u32 {
        SPECIAL_TOKENS + rng.uniform_u32((self.vocab as u32) - SPECIAL_TOKENS)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sentences_are_grammatical() {
        let lang = SynthLang::new(1024);
        let mut rng = Pcg64::new(1);
        for _ in 0..50 {
            let s = lang.sentence(30, 2, 1, &mut rng);
            assert_eq!(s.len(), 30);
            assert!(lang.is_grammatical(&s));
            assert!(s.iter().all(|&t| (t as usize) < lang.vocab && t >= SPECIAL_TOKENS));
        }
    }

    #[test]
    fn corruption_breaks_grammar_mostly() {
        let lang = SynthLang::new(1024);
        let mut rng = Pcg64::new(2);
        let mut broken = 0;
        let n = 100;
        for _ in 0..n {
            let mut s = lang.sentence(30, 1, 0, &mut rng);
            lang.corrupt_grammar(&mut s, &mut rng);
            if !lang.is_grammatical(&s) {
                broken += 1;
            }
        }
        assert!(broken > n * 8 / 10, "only {broken}/{n} corrupted");
    }

    #[test]
    fn paraphrase_keeps_band_similarity_high() {
        let lang = SynthLang::new(1024);
        let mut rng = Pcg64::new(3);
        let s = lang.sentence(40, 3, 1, &mut rng);
        let p = lang.paraphrase(&s, &mut rng);
        assert!(lang.band_similarity(&s, &p) > 0.95);
        // ...while raw token overlap is low
        let overlap = s.iter().zip(&p).filter(|(a, b)| a == b).count();
        assert!(overlap < s.len(), "paraphrase should change tokens");
        let u = lang.retopic(&s, 6, &mut rng);
        assert!(lang.band_similarity(&s, &u) < 0.7);
    }

    #[test]
    fn polarity_flip_negates_score() {
        let lang = SynthLang::new(1024);
        let mut rng = Pcg64::new(4);
        let s = lang.sentence(40, 0, 1, &mut rng);
        let score = lang.polarity_score(&s);
        assert!(score > 0, "positive sentence score {score}");
        let f = lang.flip_polarity(&s);
        assert_eq!(lang.polarity_score(&f), -score);
    }
}
