//! The eight synthetic GLUE-analogue task generators.

use super::lang::{SynthLang, CLS, PAD, SEP};
use super::TaskInfo;
use crate::metrics::MetricKind;
use crate::util::rng::Pcg64;

/// One training / evaluation example: token ids padded to a fixed sequence
/// length, a class label (classification) or score (regression).
#[derive(Clone, Debug)]
pub struct Example {
    pub tokens: Vec<u32>,
    pub label: usize,
    /// Regression target (STS-B analogue), in [0, 5]; 0.0 otherwise.
    pub score: f32,
}

/// A generated dataset split.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub task: TaskId,
    pub seq_len: usize,
    pub train: Vec<Example>,
    pub eval: Vec<Example>,
}

/// Kind of supervised objective.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    Classify(usize),
    Regress,
}

/// Task identifiers, named after their GLUE analogues.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum TaskId {
    ColaSyn,
    MnliSyn,
    MrpcSyn,
    QnliSyn,
    QqpSyn,
    RteSyn,
    Sst2Syn,
    StsbSyn,
}

/// All tasks, in the paper's Table-1 column order.
pub const ALL_TASKS: [TaskId; 8] = [
    TaskId::ColaSyn,
    TaskId::MnliSyn,
    TaskId::MrpcSyn,
    TaskId::QnliSyn,
    TaskId::QqpSyn,
    TaskId::RteSyn,
    TaskId::Sst2Syn,
    TaskId::StsbSyn,
];

impl TaskId {
    pub fn name(&self) -> &'static str {
        match self {
            TaskId::ColaSyn => "cola_syn",
            TaskId::MnliSyn => "mnli_syn",
            TaskId::MrpcSyn => "mrpc_syn",
            TaskId::QnliSyn => "qnli_syn",
            TaskId::QqpSyn => "qqp_syn",
            TaskId::RteSyn => "rte_syn",
            TaskId::Sst2Syn => "sst2_syn",
            TaskId::StsbSyn => "stsb_syn",
        }
    }

    pub fn from_name(s: &str) -> Result<TaskId, String> {
        ALL_TASKS
            .iter()
            .find(|t| t.name() == s)
            .copied()
            .ok_or_else(|| format!("unknown task '{s}'"))
    }

    pub fn info(&self) -> TaskInfo {
        let (analogue, classes, regression, metric, train, pair) = match self {
            TaskId::ColaSyn => ("CoLA", 2, false, MetricKind::Matthews, 8_000, false),
            TaskId::MnliSyn => ("MNLI", 3, false, MetricKind::Accuracy, 40_000, true),
            TaskId::MrpcSyn => ("MRPC", 2, false, MetricKind::Accuracy, 3_000, true),
            TaskId::QnliSyn => ("QNLI", 2, false, MetricKind::Accuracy, 10_000, true),
            TaskId::QqpSyn => ("QQP", 2, false, MetricKind::Accuracy, 36_000, true),
            TaskId::RteSyn => ("RTE", 2, false, MetricKind::Accuracy, 2_500, true),
            TaskId::Sst2Syn => ("SST-2", 2, false, MetricKind::Accuracy, 6_700, false),
            TaskId::StsbSyn => ("STS-B", 1, true, MetricKind::Spearman, 5_700, true),
        };
        TaskInfo {
            id: *self,
            glue_analogue: analogue,
            num_classes: classes,
            regression,
            metric,
            train_size: train,
            eval_size: 500,
            pair: pair,
        }
    }

    pub fn kind(&self) -> TaskKind {
        let info = self.info();
        if info.regression {
            TaskKind::Regress
        } else {
            TaskKind::Classify(info.num_classes)
        }
    }

    /// Generate `n_train` + `n_eval` examples at `seq_len` with the given
    /// seed. Train/eval are independent draws from the same process.
    pub fn generate(&self, n_train: usize, n_eval: usize, seed: u64) -> Dataset {
        self.generate_at(n_train, n_eval, seed, 64, 1024)
    }

    /// Generate for a specific model preset's sequence length and vocab
    /// (the synthetic language layout must fit inside the model's vocab).
    pub fn generate_at(
        &self,
        n_train: usize,
        n_eval: usize,
        seed: u64,
        seq_len: usize,
        vocab: usize,
    ) -> Dataset {
        let lang = SynthLang::new(vocab);
        let mut rng = Pcg64::with_stream(seed, task_stream(*self));
        let gen_split = |n: usize, rng: &mut Pcg64| -> Vec<Example> {
            (0..n).map(|_| self.example(&lang, seq_len, rng)).collect()
        };
        let train = gen_split(n_train, &mut rng);
        let eval = gen_split(n_eval, &mut rng);
        Dataset { task: *self, seq_len, train, eval }
    }

    fn example(&self, lang: &SynthLang, seq_len: usize, rng: &mut Pcg64) -> Example {
        match self {
            TaskId::ColaSyn => cola(lang, seq_len, rng),
            TaskId::Sst2Syn => sst2(lang, seq_len, rng),
            TaskId::MrpcSyn => pair_paraphrase(lang, seq_len, rng, 0.5),
            TaskId::QqpSyn => pair_paraphrase(lang, seq_len, rng, 0.37), // QQP is ~37% dup
            TaskId::RteSyn => rte(lang, seq_len, rng),
            TaskId::QnliSyn => qnli(lang, seq_len, rng),
            TaskId::MnliSyn => mnli(lang, seq_len, rng),
            TaskId::StsbSyn => stsb(lang, seq_len, rng),
        }
    }
}

fn task_stream(t: TaskId) -> u64 {
    // Stable per-task stream ids so multi-task runs draw independent data.
    match t {
        TaskId::ColaSyn => 101,
        TaskId::MnliSyn => 102,
        TaskId::MrpcSyn => 103,
        TaskId::QnliSyn => 104,
        TaskId::QqpSyn => 105,
        TaskId::RteSyn => 106,
        TaskId::Sst2Syn => 107,
        TaskId::StsbSyn => 108,
    }
}

/// Wrap a single sentence as `[CLS] s [SEP]` padded to `seq_len`.
fn wrap_single(s: &[u32], seq_len: usize) -> Vec<u32> {
    let mut out = Vec::with_capacity(seq_len);
    out.push(CLS);
    out.extend_from_slice(&s[..s.len().min(seq_len - 2)]);
    out.push(SEP);
    out.resize(seq_len, PAD);
    out
}

/// Wrap a pair as `[CLS] a [SEP] b [SEP]` padded to `seq_len`.
fn wrap_pair(a: &[u32], b: &[u32], seq_len: usize) -> Vec<u32> {
    let budget = seq_len - 3;
    let la = a.len().min(budget / 2);
    let lb = b.len().min(budget - la);
    let mut out = Vec::with_capacity(seq_len);
    out.push(CLS);
    out.extend_from_slice(&a[..la]);
    out.push(SEP);
    out.extend_from_slice(&b[..lb]);
    out.push(SEP);
    out.resize(seq_len, PAD);
    out
}

fn sent_len(seq_len: usize, pair: bool, rng: &mut Pcg64) -> usize {
    let max = if pair { (seq_len - 3) / 2 } else { seq_len - 2 };
    let lo = (max * 3) / 4;
    lo + rng.uniform_usize(max - lo + 1)
}

fn cola(lang: &SynthLang, seq_len: usize, rng: &mut Pcg64) -> Example {
    let topic = rng.uniform_usize(lang.n_topics);
    let mut s = lang.sentence(sent_len(seq_len, false, rng), topic, 0, rng);
    // CoLA is unbalanced: ~70% acceptable.
    let acceptable = rng.bernoulli(0.7);
    if !acceptable {
        lang.corrupt_grammar(&mut s, rng);
    }
    Example {
        tokens: wrap_single(&s, seq_len),
        label: acceptable as usize,
        score: 0.0,
    }
}

fn sst2(lang: &SynthLang, seq_len: usize, rng: &mut Pcg64) -> Example {
    let topic = rng.uniform_usize(lang.n_topics);
    let positive = rng.bernoulli(0.5);
    let pol = if positive { 1 } else { -1 };
    let s = lang.sentence(sent_len(seq_len, false, rng), topic, pol, rng);
    Example {
        tokens: wrap_single(&s, seq_len),
        label: positive as usize,
        score: 0.0,
    }
}

fn pair_paraphrase(
    lang: &SynthLang,
    seq_len: usize,
    rng: &mut Pcg64,
    p_pos: f64,
) -> Example {
    let topic = rng.uniform_usize(lang.n_topics);
    let a = lang.sentence(sent_len(seq_len, true, rng), topic, 0, rng);
    let positive = rng.bernoulli(p_pos);
    let b = if positive {
        lang.paraphrase(&a, rng)
    } else if rng.bernoulli(0.5) {
        // Hard negative: same function skeleton, different topic.
        let other = (topic + 1 + rng.uniform_usize(lang.n_topics - 1)) % lang.n_topics;
        lang.retopic(&a, other, rng)
    } else {
        // Easy negative: fresh unrelated sentence.
        let other = (topic + 1 + rng.uniform_usize(lang.n_topics - 1)) % lang.n_topics;
        lang.sentence(sent_len(seq_len, true, rng), other, 0, rng)
    };
    Example {
        tokens: wrap_pair(&a, &b, seq_len),
        label: positive as usize,
        score: 0.0,
    }
}

fn rte(lang: &SynthLang, seq_len: usize, rng: &mut Pcg64) -> Example {
    let topic = rng.uniform_usize(lang.n_topics);
    let pol = if rng.bernoulli(0.5) { 1 } else { -1 };
    let premise = lang.sentence(sent_len(seq_len, true, rng), topic, pol, rng);
    let entail = rng.bernoulli(0.5);
    let hypothesis = if entail {
        // Entailed: paraphrase of a prefix of the premise.
        let cut = premise.len() / 2 + rng.uniform_usize(premise.len() / 2);
        lang.paraphrase(&premise[..cut], rng)
    } else if rng.bernoulli(0.5) {
        // Contradiction-style negative: polarity flipped paraphrase.
        lang.flip_polarity(&lang.paraphrase(&premise, rng))
    } else {
        // Unrelated negative.
        let other = (topic + 1 + rng.uniform_usize(lang.n_topics - 1)) % lang.n_topics;
        lang.sentence(sent_len(seq_len, true, rng), other, -pol, rng)
    };
    Example {
        tokens: wrap_pair(&premise, &hypothesis, seq_len),
        label: entail as usize,
        score: 0.0,
    }
}

fn qnli(lang: &SynthLang, seq_len: usize, rng: &mut Pcg64) -> Example {
    // "Does the context sentence answer the question?" — modeled as: the
    // context contains the question's topic band (answer present) or not.
    let topic = rng.uniform_usize(lang.n_topics);
    let question = lang.sentence(sent_len(seq_len, true, rng), topic, 0, rng);
    let answered = rng.bernoulli(0.5);
    let ctx_topic = if answered {
        topic
    } else {
        (topic + 1 + rng.uniform_usize(lang.n_topics - 1)) % lang.n_topics
    };
    let context = lang.sentence(sent_len(seq_len, true, rng), ctx_topic, 0, rng);
    Example {
        tokens: wrap_pair(&question, &context, seq_len),
        label: answered as usize,
        score: 0.0,
    }
}

fn mnli(lang: &SynthLang, seq_len: usize, rng: &mut Pcg64) -> Example {
    // 3-way: 0 = contradiction, 1 = neutral, 2 = entailment.
    let topic = rng.uniform_usize(lang.n_topics);
    let pol = if rng.bernoulli(0.5) { 1 } else { -1 };
    let premise = lang.sentence(sent_len(seq_len, true, rng), topic, pol, rng);
    let label = rng.uniform_usize(3);
    let hypothesis = match label {
        2 => {
            let cut = premise.len() / 2 + rng.uniform_usize(premise.len() / 2);
            lang.paraphrase(&premise[..cut], rng)
        }
        0 => lang.flip_polarity(&lang.paraphrase(&premise, rng)),
        _ => {
            let other = (topic + 1 + rng.uniform_usize(lang.n_topics - 1)) % lang.n_topics;
            lang.sentence(sent_len(seq_len, true, rng), other, 0, rng)
        }
    };
    Example {
        tokens: wrap_pair(&premise, &hypothesis, seq_len),
        label,
        score: 0.0,
    }
}

fn stsb(lang: &SynthLang, seq_len: usize, rng: &mut Pcg64) -> Example {
    let topic = rng.uniform_usize(lang.n_topics);
    let a = lang.sentence(sent_len(seq_len, true, rng), topic, 0, rng);
    // Derivation mixture spanning the similarity spectrum.
    let b = match rng.uniform_usize(4) {
        0 => lang.paraphrase(&a, rng), // ~5
        1 => {
            // partially retopic'd paraphrase (~2-4)
            let mut p = lang.paraphrase(&a, rng);
            let other = (topic + 1 + rng.uniform_usize(lang.n_topics - 1)) % lang.n_topics;
            let half = lang.retopic(&p.split_off(p.len() / 2), other, rng);
            p.extend(half);
            p
        }
        2 => {
            let other = (topic + 1 + rng.uniform_usize(lang.n_topics - 1)) % lang.n_topics;
            lang.retopic(&a, other, rng) // ~0-1 (structure kept)
        }
        _ => {
            let other = (topic + 1 + rng.uniform_usize(lang.n_topics - 1)) % lang.n_topics;
            lang.sentence(sent_len(seq_len, true, rng), other, 0, rng) // ~0
        }
    };
    let score = 5.0 * lang.band_similarity(&a, &b);
    Example {
        tokens: wrap_pair(&a, &b, seq_len),
        label: 0,
        score,
    }
}

/// Downsample per the paper's MTL protocol (§3.2): at most `cap` training
/// samples and at most `eval_cap` evaluation samples, keeping order
/// deterministic via the provided rng.
pub fn downsample(ds: &Dataset, cap: usize, eval_cap: usize, rng: &mut Pcg64) -> Dataset {
    let pick = |xs: &[Example], cap: usize, rng: &mut Pcg64| -> Vec<Example> {
        if xs.len() <= cap {
            return xs.to_vec();
        }
        let idx = rng.choose_k(xs.len(), cap);
        idx.into_iter().map(|i| xs[i].clone()).collect()
    };
    Dataset {
        task: ds.task,
        seq_len: ds.seq_len,
        train: pick(&ds.train, cap, rng),
        eval: pick(&ds.eval, eval_cap, rng),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wrap_shapes_are_exact() {
        let s: Vec<u32> = (10..40).collect();
        let w = wrap_single(&s, 64);
        assert_eq!(w.len(), 64);
        assert_eq!(w[0], CLS);
        assert_eq!(w[31], SEP);
        assert!(w[32..].iter().all(|&t| t == PAD));
        let p = wrap_pair(&s, &s, 64);
        assert_eq!(p.len(), 64);
        assert_eq!(p.iter().filter(|&&t| t == SEP).count(), 2);
    }

    #[test]
    fn cola_positive_examples_are_grammatical() {
        let ds = TaskId::ColaSyn.generate(300, 0, 9);
        let lang = SynthLang::new(1024);
        let strip = |e: &Example| -> Vec<u32> {
            e.tokens
                .iter()
                .copied()
                .filter(|&t| t >= super::super::lang::SPECIAL_TOKENS)
                .collect()
        };
        let pos_ok = ds
            .train
            .iter()
            .filter(|e| e.label == 1)
            .filter(|e| lang.is_grammatical(&strip(e)))
            .count();
        let pos_total = ds.train.iter().filter(|e| e.label == 1).count();
        assert_eq!(pos_ok, pos_total, "grammatical positives");
        let neg_bad = ds
            .train
            .iter()
            .filter(|e| e.label == 0)
            .filter(|e| !lang.is_grammatical(&strip(e)))
            .count();
        let neg_total = ds.train.iter().filter(|e| e.label == 0).count();
        assert!(neg_bad * 10 >= neg_total * 8, "{neg_bad}/{neg_total} corrupted");
        // unbalanced as designed
        assert!(pos_total > ds.train.len() / 2);
    }

    #[test]
    fn stsb_scores_span_the_range() {
        let ds = TaskId::StsbSyn.generate(400, 0, 3);
        let hi = ds.train.iter().filter(|e| e.score > 4.0).count();
        let lo = ds.train.iter().filter(|e| e.score < 1.0).count();
        assert!(hi > 40, "high-similarity pairs {hi}");
        assert!(lo > 40, "low-similarity pairs {lo}");
    }

    #[test]
    fn downsample_caps_sizes() {
        let ds = TaskId::MrpcSyn.generate(800, 700, 4);
        let mut rng = Pcg64::new(1);
        let small = downsample(&ds, 500, 100, &mut rng);
        assert_eq!(small.train.len(), 500);
        assert_eq!(small.eval.len(), 100);
        // under cap: untouched
        let same = downsample(&small, 5_000, 500, &mut rng);
        assert_eq!(same.train.len(), 500);
    }

    #[test]
    fn task_names_roundtrip() {
        for t in ALL_TASKS {
            assert_eq!(TaskId::from_name(t.name()).unwrap(), t);
        }
        assert!(TaskId::from_name("nope").is_err());
    }
}
