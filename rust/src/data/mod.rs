//! Synthetic GLUE workload suite and the pretraining corpus.
//!
//! The paper fine-tunes RoBERTa on GLUE. That data (and the pretrained
//! checkpoint) is not available in this environment, so this module builds
//! the closest synthetic equivalent that exercises the same code paths
//! (DESIGN.md §3): eight tasks with the same *types* as GLUE —
//! single-sentence classification with unbalanced labels (CoLA-like,
//! Matthews metric), sentence-pair entailment/paraphrase tasks with a [SEP]
//! marker (MNLI/RTE/MRPC/QQP/QNLI-like), sentiment (SST-2-like), and pair
//! similarity regression (STS-B-like, Spearman metric).
//!
//! Sentences are drawn from a planted generative process over a shared
//! vocabulary (see [`lang`]): a small "grammar" automaton emits mostly
//! well-formed token streams, topic-token mixtures carry sentiment/content,
//! and pair tasks derive the second sentence from the first by controlled
//! perturbations. The tasks are learnable by an attention model but not by
//! bag-of-unigram statistics alone (pair tasks require cross-position
//! comparison) — the property that makes the PEFT comparison meaningful.

mod batch;
mod lang;
mod mlm;
mod tasks;

pub use batch::{Batch, Batcher};
pub use lang::{SynthLang, CLS, MASK, PAD, SEP, SPECIAL_TOKENS};
pub use mlm::{MlmBatch, MlmCorpus};
pub use tasks::{downsample, Dataset, Example, TaskId, TaskKind, ALL_TASKS};

use crate::metrics::MetricKind;

/// Static description of one task in the suite.
#[derive(Clone, Debug)]
pub struct TaskInfo {
    pub id: TaskId,
    /// GLUE analogue the generator mimics.
    pub glue_analogue: &'static str,
    pub num_classes: usize,
    /// True for regression (STS-B analogue).
    pub regression: bool,
    pub metric: MetricKind,
    /// Nominal training-set size (mirrors GLUE's relative cardinalities:
    /// MNLI/QQP ≫ SST-2/QNLI ≫ CoLA ≫ MRPC/RTE/STS-B).
    pub train_size: usize,
    pub eval_size: usize,
    /// Pair task (premise [SEP] hypothesis) vs single sentence.
    pub pair: bool,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn suite_covers_eight_tasks_with_glue_metric_mix() {
        assert_eq!(ALL_TASKS.len(), 8);
        let infos: Vec<TaskInfo> = ALL_TASKS.iter().map(|t| t.info()).collect();
        assert!(infos.iter().any(|i| i.metric == MetricKind::Matthews));
        assert!(infos.iter().any(|i| i.metric == MetricKind::Spearman));
        assert!(infos.iter().filter(|i| i.metric == MetricKind::Accuracy).count() >= 5);
        assert!(infos.iter().any(|i| i.num_classes == 3)); // MNLI analogue
        assert!(infos.iter().any(|i| !i.pair)); // single-sentence tasks exist
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        let a = TaskId::MrpcSyn.generate(64, 32, 77);
        let b = TaskId::MrpcSyn.generate(64, 32, 77);
        assert_eq!(a.train.len(), b.train.len());
        for (x, y) in a.train.iter().zip(&b.train) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.label, y.label);
        }
        let c = TaskId::MrpcSyn.generate(64, 32, 78);
        let same = a
            .train
            .iter()
            .zip(&c.train)
            .filter(|(x, y)| x.tokens == y.tokens)
            .count();
        assert!(same < a.train.len() / 2, "different seeds must differ");
    }

    #[test]
    fn labels_are_in_range_and_nondegenerate() {
        let mut rng = Pcg64::new(5);
        for task in ALL_TASKS {
            let n = 200 + rng.uniform_usize(50);
            let ds = task.generate(n, 50, 13);
            let info = task.info();
            assert_eq!(ds.train.len(), n);
            if info.regression {
                assert!(ds.train.iter().all(|e| (0.0..=5.0).contains(&e.score)));
                let mean: f32 =
                    ds.train.iter().map(|e| e.score).sum::<f32>() / ds.train.len() as f32;
                assert!(mean > 0.5 && mean < 4.5, "{:?} score mean {mean}", task);
            } else {
                assert!(ds.train.iter().all(|e| e.label < info.num_classes));
                // every class appears
                for c in 0..info.num_classes {
                    let cnt = ds.train.iter().filter(|e| e.label == c).count();
                    assert!(cnt > 0, "{:?} class {c} empty", task);
                    assert!(
                        cnt < ds.train.len() * 9 / 10,
                        "{:?} class {c} degenerate ({cnt}/{})",
                        task,
                        ds.train.len()
                    );
                }
            }
        }
    }
}
