//! Batching: fixed-shape batches for the AOT-compiled train/eval steps.
//!
//! HLO executables have static shapes, so every batch is exactly
//! `batch_size × seq_len`; the final ragged batch of an epoch is padded by
//! repeating examples and a `weights` mask zeroes their loss contribution.

use super::tasks::{Dataset, Example};
use crate::util::rng::Pcg64;

/// A fixed-shape batch ready for device upload.
#[derive(Clone, Debug)]
pub struct Batch {
    /// Token ids, row-major `[batch, seq]`, as i32 for the HLO input.
    pub tokens: Vec<i32>,
    /// Class labels (i32) — zeros for regression tasks.
    pub labels: Vec<i32>,
    /// Regression targets (f32) — zeros for classification tasks.
    pub scores: Vec<f32>,
    /// Per-example loss weights (0.0 marks padding rows).
    pub weights: Vec<f32>,
    pub batch_size: usize,
    pub seq_len: usize,
}

impl Batch {
    fn from_examples(examples: &[&Example], batch_size: usize, seq_len: usize) -> Batch {
        assert!(!examples.is_empty() && examples.len() <= batch_size);
        let mut tokens = Vec::with_capacity(batch_size * seq_len);
        let mut labels = Vec::with_capacity(batch_size);
        let mut scores = Vec::with_capacity(batch_size);
        let mut weights = Vec::with_capacity(batch_size);
        for i in 0..batch_size {
            // Pad the tail by cycling examples with zero weight.
            let (e, w) = if i < examples.len() {
                (examples[i], 1.0)
            } else {
                (examples[i % examples.len()], 0.0)
            };
            assert_eq!(e.tokens.len(), seq_len, "example length mismatch");
            tokens.extend(e.tokens.iter().map(|&t| t as i32));
            labels.push(e.label as i32);
            scores.push(e.score);
            weights.push(w);
        }
        Batch { tokens, labels, scores, weights, batch_size, seq_len }
    }

    /// Number of real (non-padding) examples.
    pub fn real_count(&self) -> usize {
        self.weights.iter().filter(|&&w| w > 0.0).count()
    }
}

/// Epoch iterator producing shuffled fixed-shape batches.
pub struct Batcher {
    batch_size: usize,
}

impl Batcher {
    pub fn new(batch_size: usize) -> Batcher {
        assert!(batch_size >= 1);
        Batcher { batch_size }
    }

    /// Shuffled training batches for one epoch.
    pub fn epoch(&self, ds: &Dataset, rng: &mut Pcg64) -> Vec<Batch> {
        let mut order: Vec<usize> = (0..ds.train.len()).collect();
        rng.shuffle(&mut order);
        order
            .chunks(self.batch_size)
            .map(|chunk| {
                let refs: Vec<&Example> = chunk.iter().map(|&i| &ds.train[i]).collect();
                Batch::from_examples(&refs, self.batch_size, ds.seq_len)
            })
            .collect()
    }

    /// Deterministic evaluation batches.
    pub fn eval(&self, ds: &Dataset) -> Vec<Batch> {
        ds.eval
            .chunks(self.batch_size)
            .map(|chunk| {
                let refs: Vec<&Example> = chunk.iter().collect();
                Batch::from_examples(&refs, self.batch_size, ds.seq_len)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::TaskId;

    #[test]
    fn epoch_covers_every_example_once() {
        let ds = TaskId::Sst2Syn.generate(103, 10, 1);
        let batcher = Batcher::new(16);
        let mut rng = Pcg64::new(2);
        let batches = batcher.epoch(&ds, &mut rng);
        assert_eq!(batches.len(), 7); // ceil(103/16)
        let total_real: usize = batches.iter().map(|b| b.real_count()).sum();
        assert_eq!(total_real, 103);
        for b in &batches {
            assert_eq!(b.tokens.len(), 16 * ds.seq_len);
            assert_eq!(b.labels.len(), 16);
        }
        // last batch padded with zero weights
        let last = batches.last().unwrap();
        assert_eq!(last.real_count(), 103 % 16);
    }

    #[test]
    fn eval_batches_are_deterministic() {
        let ds = TaskId::MrpcSyn.generate(10, 33, 1);
        let batcher = Batcher::new(8);
        let a = batcher.eval(&ds);
        let b = batcher.eval(&ds);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.weights, y.weights);
        }
    }

    #[test]
    fn shuffling_differs_across_epochs() {
        let ds = TaskId::Sst2Syn.generate(64, 0, 1);
        let batcher = Batcher::new(16);
        let mut rng = Pcg64::new(3);
        let e1 = batcher.epoch(&ds, &mut rng);
        let e2 = batcher.epoch(&ds, &mut rng);
        assert_ne!(e1[0].tokens, e2[0].tokens);
    }
}
