//! Masked-language-model pretraining corpus.
//!
//! The paper fine-tunes a *pretrained* RoBERTa. Our substitution (DESIGN.md
//! §3) pretrains the from-scratch encoder in-repo on an MLM objective over
//! the same synthetic language the tasks are built from, so the frozen
//! backbone the adapters steer has real (if small) linguistic structure:
//! the automaton grammar, topic bands and polarity bands of [`SynthLang`].
//!
//! Masking follows BERT: 15% of non-special positions are selected; of
//! those 80% become `[MASK]`, 10% a random token, 10% stay. Loss weights
//! are 1 at selected positions, 0 elsewhere.

use super::lang::{SynthLang, CLS, MASK, SEP, SPECIAL_TOKENS};
#[cfg(test)]
use super::lang::PAD;
use crate::util::rng::Pcg64;

/// One MLM batch ready for the pretrain-step artifact.
#[derive(Clone, Debug)]
pub struct MlmBatch {
    /// Masked input ids, `[batch, seq]` row-major.
    pub tokens: Vec<i32>,
    /// Original ids (targets), `[batch, seq]`.
    pub targets: Vec<i32>,
    /// Loss weights, `[batch, seq]` (1.0 at masked positions).
    pub weights: Vec<f32>,
    pub batch_size: usize,
    pub seq_len: usize,
}

/// Streaming MLM batch generator.
pub struct MlmCorpus {
    lang: SynthLang,
    seq_len: usize,
    rng: Pcg64,
}

impl MlmCorpus {
    pub fn new(vocab: usize, seq_len: usize, seed: u64) -> MlmCorpus {
        MlmCorpus {
            lang: SynthLang::new(vocab),
            seq_len,
            rng: Pcg64::with_stream(seed, 777),
        }
    }

    /// Next batch of `batch_size` masked sentences.
    pub fn next_batch(&mut self, batch_size: usize) -> MlmBatch {
        let n = batch_size * self.seq_len;
        let mut tokens = Vec::with_capacity(n);
        let mut targets = Vec::with_capacity(n);
        let mut weights = Vec::with_capacity(n);
        for _ in 0..batch_size {
            let topic = self.rng.uniform_usize(self.lang.n_topics);
            let pol = [-1, 0, 1][self.rng.uniform_usize(3)];
            let body_len = self.seq_len - 2;
            let sent = self.lang.sentence(body_len, topic, pol, &mut self.rng);
            let mut row: Vec<u32> = Vec::with_capacity(self.seq_len);
            row.push(CLS);
            row.extend_from_slice(&sent);
            row.push(SEP);
            debug_assert_eq!(row.len(), self.seq_len);
            for &orig in &row {
                let maskable = orig >= SPECIAL_TOKENS;
                let selected = maskable && self.rng.bernoulli(0.15);
                let input = if selected {
                    let roll = self.rng.uniform_f64();
                    if roll < 0.8 {
                        MASK
                    } else if roll < 0.9 {
                        self.lang.random_token(&mut self.rng)
                    } else {
                        orig
                    }
                } else {
                    orig
                };
                tokens.push(input as i32);
                targets.push(orig as i32);
                weights.push(if selected { 1.0 } else { 0.0 });
            }
        }
        MlmBatch { tokens, targets, weights, batch_size, seq_len: self.seq_len }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes_and_masking_rate() {
        let mut corpus = MlmCorpus::new(512, 32, 1);
        let b = corpus.next_batch(64);
        assert_eq!(b.tokens.len(), 64 * 32);
        assert_eq!(b.targets.len(), 64 * 32);
        let masked = b.weights.iter().filter(|&&w| w > 0.0).count();
        let frac = masked as f64 / b.weights.len() as f64;
        assert!((0.08..0.22).contains(&frac), "mask fraction {frac}");
        // No PAD in pretraining rows; specials never selected.
        for (i, &w) in b.weights.iter().enumerate() {
            assert_ne!(b.tokens[i], PAD as i32);
            if w > 0.0 {
                assert!(b.targets[i] >= SPECIAL_TOKENS as i32);
            }
        }
    }

    #[test]
    fn masked_positions_mostly_mask_token() {
        let mut corpus = MlmCorpus::new(512, 32, 2);
        let b = corpus.next_batch(128);
        let (mut mask_tok, mut total) = (0, 0);
        for (i, &w) in b.weights.iter().enumerate() {
            if w > 0.0 {
                total += 1;
                if b.tokens[i] == MASK as i32 {
                    mask_tok += 1;
                }
            }
        }
        let frac = mask_tok as f64 / total as f64;
        assert!((0.7..0.9).contains(&frac), "MASK fraction {frac}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = MlmCorpus::new(512, 32, 9).next_batch(4);
        let b = MlmCorpus::new(512, 32, 9).next_batch(4);
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.weights, b.weights);
    }
}
