//! The pure-rust reference backend (`--backend ref`).
//!
//! Executes every step kind of the MetaTT pipeline directly on host tensors
//! via [`super::encoder`] — no HLO artifacts, no Python, no network. Specs
//! are resolved through [`super::layout::synthesize_entry`], so the backend
//! supports *any* (preset, adapter, rank, classes, tasks, batch, seq)
//! combination a manifest could describe, including the full DMRG rank
//! ladder — which is what makes the training/DMRG/MTL coordinators
//! hermetically testable.

use super::backend::{Backend, BackendKind, Step};
use super::encoder;
use super::layout;
use super::registry::{ArtifactEntry, ArtifactSpec, StepKind};
use crate::config::ModelPreset;
use crate::data::{Batch, MlmBatch};
use crate::tensor::{DtypeKind, Tensor};
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::collections::HashSet;
use std::sync::{Arc, Mutex, Weak};

/// Pure-rust CPU backend. Stateless apart from bind telemetry and the
/// per-backbone packed-panel cache.
pub struct RefBackend {
    /// Stems of every spec bound so far — the analogue of the PJRT
    /// executable cache, reported through `cached_executables` so the DMRG
    /// hot-swap accounting works identically across backends.
    bound: Mutex<HashSet<String>>,
    /// Worker-thread budget every bound step executes with. Results are
    /// bit-identical for any value (tests/determinism.rs).
    threads: usize,
    /// Whether bound steps use the workspace arena (zero-allocation hot
    /// path). Results are bit-identical either way; off is the plain
    /// allocate-per-intermediate reference mode.
    arena: bool,
    /// Bind-time packed-panel caches, keyed by (identity of the frozen
    /// `Arc` they were built from, storage dtype): every step bound against
    /// the same backbone at the same dtype (train + eval runners, all DMRG
    /// ranks, every serving worker) shares ONE packed copy of the frozen
    /// layer weights. Weak keys keep the cache from pinning dropped
    /// backbones; dead entries are pruned on the next bind.
    packed: Mutex<Vec<(Weak<HashMap<String, Tensor>>, DtypeKind, Arc<encoder::PackedFrozen>)>>,
}

/// Arena default from the environment: on unless `METATT_ARENA` is set to
/// `0` / `off` / `false`.
fn arena_from_env() -> bool {
    !matches!(
        std::env::var("METATT_ARENA").as_deref(),
        Ok("0") | Ok("off") | Ok("false")
    )
}

impl RefBackend {
    /// Backend with the environment-derived thread count (`METATT_THREADS`
    /// when set and valid, else the host's available parallelism).
    pub fn new() -> RefBackend {
        Self::with_threads(crate::util::threadpool::default_threads())
            .expect("default_threads() >= 1")
    }

    /// Backend with an explicit thread count (>= 1; `0` is a configuration
    /// error surfaced cleanly rather than a panic). The workspace arena is
    /// on unless disabled via `METATT_ARENA=0`.
    pub fn with_threads(threads: usize) -> Result<RefBackend> {
        Self::with_config(threads, arena_from_env())
    }

    /// Backend with explicit thread count *and* arena mode (the determinism
    /// suite pins arena-on == arena-off bit-identity through this).
    pub fn with_config(threads: usize, arena: bool) -> Result<RefBackend> {
        if threads == 0 {
            bail!(
                "backend thread count must be >= 1 (got 0): pass --threads 1 \
                 for serial execution or omit the flag to auto-detect"
            );
        }
        // Size the lazily-created kernel pool for this budget (no-op if a
        // region already ran; the pool is capped at 16 workers regardless).
        crate::util::threadpool::request_pool_capacity(threads);
        Ok(RefBackend {
            bound: Mutex::new(HashSet::new()),
            threads,
            arena,
            packed: Mutex::new(Vec::new()),
        })
    }

    /// The shared packed-panel copy of `frozen`'s layer weights at `kind`,
    /// built on the first bind against this (backbone, dtype) and reused
    /// (refcounted) by every later bind of the same `Arc` at the same
    /// dtype. Identity is pointer equality on a *live* entry: dead weak
    /// entries are pruned first, so a recycled allocation address can
    /// never alias a stale cache line.
    fn packed_frozen(
        &self,
        frozen: &Arc<HashMap<String, Tensor>>,
        kind: DtypeKind,
    ) -> Arc<encoder::PackedFrozen> {
        let mut cache = self.packed.lock().unwrap();
        cache.retain(|(weak, _, _)| weak.strong_count() > 0);
        if let Some((_, _, packed)) = cache.iter().find(|(weak, k, _)| {
            *k == kind && std::ptr::eq(weak.as_ptr(), Arc::as_ptr(frozen))
        }) {
            return Arc::clone(packed);
        }
        let packed = Arc::new(encoder::pack_frozen_weights(frozen, kind));
        cache.push((Arc::downgrade(frozen), kind, Arc::clone(&packed)));
        packed
    }

    /// The shared bind body behind [`Backend::bind`] (always f32) and
    /// [`Backend::bind_serve`] (dtype selected by `--serve-dtype`): frozen
    /// set validation, bind telemetry, and the packed-panel cache lookup
    /// at `dtype`.
    fn bind_at<'a>(
        &'a self,
        spec: &ArtifactSpec,
        frozen: &Arc<HashMap<String, Tensor>>,
        dtype: DtypeKind,
    ) -> Result<Box<dyn Step + 'a>> {
        let entry = self.entry(spec)?;
        // Validate the frozen set up front, exactly like the PJRT bind.
        for io in entry.frozen_inputs() {
            match frozen.get(&io.name) {
                None => bail!(
                    "frozen input '{}' missing for {}",
                    io.name,
                    spec.stem()
                ),
                Some(t) if t.shape() != &io.shape[..] => bail!(
                    "frozen input '{}': shape {:?}, layout wants {:?}",
                    io.name,
                    t.shape(),
                    io.shape
                ),
                _ => {}
            }
        }
        self.bound.lock().unwrap().insert(spec.stem());
        // One-time per-bind work: weight-name indices, the step's workspace
        // arena — which owns the aligned pack scratch the packed GEMM
        // kernels check their A/B panel buffers out of, so a warmed step
        // packs without allocating — and the bind-time packed-panel copies
        // of the frozen layer weights (forward orientation), so the
        // forward GEMMs of every subsequent call skip the per-call B pack
        // entirely. (Backward `dY·Wᵀ` keeps its per-call pack: the kernel
        // absorbs the transpose bit-identically, and caching both
        // orientations would double the footprint.) Refcount bumps only
        // for the frozen map and its shared packed panels — the backbone
        // AND its packed copy are shared across every bound step (train +
        // eval runners, all DMRG ranks, every serving worker).
        // Only specs that actually *freeze* the per-layer weights consult
        // the cache: full fine-tuning freezes just the classifier heads
        // (its frozen map may still carry checkpointed encoder arrays the
        // forward must never read from a stale pack), and pretrain/apply
        // specs freeze nothing — all of those get an empty map instead of
        // packing panels no lookup could ever return.
        let packs_apply = entry.frozen_inputs().iter().any(|io| io.name == "wq");
        let packed = if packs_apply {
            self.packed_frozen(frozen, dtype)
        } else {
            Arc::new(encoder::PackedFrozen::new())
        };
        let scratch = encoder::StepScratch::new(&entry, self.arena, packed)?;
        Ok(Box::new(RefStep {
            entry,
            frozen: Arc::clone(frozen),
            threads: self.threads,
            scratch: Mutex::new(scratch),
        }))
    }
}

impl Default for RefBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for RefBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Ref
    }

    fn platform(&self) -> String {
        "cpu (pure rust)".to_string()
    }

    fn describe(&self) -> String {
        format!(
            "backend: ref — pure-rust reference executor\n\
             artifacts: synthesized on demand (no manifest needed)\n\
             worker threads: {}\n\
             workspace arena: {}\n\
             steps bound this session: {}",
            self.threads,
            if self.arena { "on (zero-allocation steady state)" } else { "off" },
            self.cached_executables()
        )
    }

    fn threads(&self) -> usize {
        self.threads
    }

    fn entry(&self, spec: &ArtifactSpec) -> Result<ArtifactEntry> {
        layout::synthesize_entry(spec).map_err(anyhow::Error::msg)
    }

    fn bind<'a>(
        &'a self,
        spec: &ArtifactSpec,
        frozen: &Arc<HashMap<String, Tensor>>,
    ) -> Result<Box<dyn Step + 'a>> {
        self.bind_at(spec, frozen, DtypeKind::F32)
    }

    fn bind_serve<'a>(
        &'a self,
        spec: &ArtifactSpec,
        frozen: &Arc<HashMap<String, Tensor>>,
        dtype: DtypeKind,
    ) -> Result<Box<dyn Step + 'a>> {
        // Quantized frozen panels are a *serving* precision trade; train
        // and pretrain binds must never read them. `DtypeKind::F32` is
        // exactly `bind` (same cache entry, bit-exact path).
        if dtype != DtypeKind::F32 && spec.step != StepKind::Eval {
            bail!(
                "bind_serve at --serve-dtype {} needs an eval spec (got {})",
                dtype.name(),
                spec.stem()
            );
        }
        self.bind_at(spec, frozen, dtype)
    }

    fn cached_executables(&self) -> usize {
        self.bound.lock().unwrap().len()
    }

    fn pretrain_spec(&self, preset: ModelPreset) -> Result<ArtifactSpec> {
        let dims = preset.dims(1);
        Ok(ArtifactSpec {
            step: StepKind::Pretrain,
            model: preset.name().to_string(),
            adapter: "none".to_string(),
            rank: 0,
            classes: 1,
            tasks: 1,
            batch: 16,
            seq: dims.max_seq,
        })
    }

    fn apply_spec(&self, adapter: &str, rank: usize) -> Result<ArtifactSpec> {
        // The AOT pipeline lowers apply artifacts at base_sim serving shape;
        // the reference backend mirrors that default.
        let preset = ModelPreset::BaseSim;
        let dims = preset.dims(1);
        Ok(ArtifactSpec {
            step: StepKind::Apply,
            model: preset.name().to_string(),
            adapter: adapter.to_string(),
            rank,
            classes: 1,
            tasks: 1,
            batch: 64,
            seq: dims.max_seq,
        })
    }
}

/// A bound reference step: the synthesized layout + a shared handle on the
/// frozen weights + the backend's thread budget + the per-step scratch
/// (workspace arena, weight indices, packed transposed frozen weights).
struct RefStep {
    entry: ArtifactEntry,
    frozen: Arc<HashMap<String, Tensor>>,
    threads: usize,
    scratch: Mutex<encoder::StepScratch>,
}

impl RefStep {
    /// Shape-validate the trainable tensors against the layout (the same
    /// contract the PJRT uploader enforces).
    fn check_trainable(&self, trainable: &[Tensor]) -> Result<()> {
        let specs = self.entry.trainable_inputs();
        if trainable.len() != specs.len() {
            bail!(
                "{}: {} trainable tensors supplied, layout wants {}",
                self.entry.spec.stem(),
                trainable.len(),
                specs.len()
            );
        }
        for (t, io) in trainable.iter().zip(specs) {
            if t.shape() != &io.shape[..] {
                bail!(
                    "trainable '{}': shape {:?}, layout wants {:?}",
                    io.name,
                    t.shape(),
                    io.shape
                );
            }
        }
        Ok(())
    }
}

impl Step for RefStep {
    fn entry(&self) -> &ArtifactEntry {
        &self.entry
    }

    fn run_train(
        &self,
        trainable: &[Tensor],
        batch: &Batch,
        task_id: i32,
        alpha: f32,
    ) -> Result<(f32, Vec<Tensor>)> {
        if self.entry.spec.step != StepKind::Train {
            bail!("{} is not a train step", self.entry.spec.stem());
        }
        self.check_trainable(trainable)?;
        let mut scratch = self.scratch.lock().unwrap();
        encoder::train_step(
            &self.entry,
            &self.frozen,
            trainable,
            batch,
            task_id,
            alpha,
            self.threads,
            &mut scratch,
        )
    }

    fn run_eval(
        &self,
        trainable: &[Tensor],
        batch: &Batch,
        task_id: i32,
        alpha: f32,
    ) -> Result<Tensor> {
        if self.entry.spec.step != StepKind::Eval {
            bail!("{} is not an eval step", self.entry.spec.stem());
        }
        self.check_trainable(trainable)?;
        let mut scratch = self.scratch.lock().unwrap();
        encoder::eval_step(
            &self.entry,
            &self.frozen,
            trainable,
            batch,
            task_id,
            alpha,
            self.threads,
            &mut scratch,
        )
    }

    fn run_pretrain(&self, trainable: &[Tensor], batch: &MlmBatch) -> Result<(f32, Vec<Tensor>)> {
        if self.entry.spec.step != StepKind::Pretrain {
            bail!("{} is not a pretrain step", self.entry.spec.stem());
        }
        self.check_trainable(trainable)?;
        let mut scratch = self.scratch.lock().unwrap();
        encoder::pretrain_step(
            &self.entry,
            &self.frozen,
            trainable,
            batch,
            self.threads,
            &mut scratch,
        )
    }

    fn run_raw(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        match self.entry.spec.step {
            StepKind::Apply => {
                let mut scratch = self.scratch.lock().unwrap();
                encoder::apply_step(&self.entry, inputs, self.threads, &mut scratch)
            }
            _ => bail!(
                "run_raw on the ref backend supports apply specs only (got {})",
                self.entry.spec.stem()
            ),
        }
    }

    fn run_serve(
        &self,
        pairs: &[Vec<(Tensor, Tensor)>],
        tokens: &[i32],
        task_id: i32,
        out: &mut [f32],
    ) -> Result<()> {
        if self.entry.spec.step != StepKind::Eval {
            bail!(
                "run_serve needs an eval-spec step (got {})",
                self.entry.spec.stem()
            );
        }
        let mut scratch = self.scratch.lock().unwrap();
        encoder::serve_step(
            &self.entry,
            &self.frozen,
            pairs,
            tokens,
            task_id,
            self.threads,
            &mut scratch,
            out,
        )
    }

    fn run_serve_packed(
        &self,
        pairs: &[Vec<encoder::FoldedPairPacked>],
        tokens: &[i32],
        task_id: i32,
        out: &mut [f32],
    ) -> Result<()> {
        if self.entry.spec.step != StepKind::Eval {
            bail!(
                "run_serve_packed needs an eval-spec step (got {})",
                self.entry.spec.stem()
            );
        }
        let mut scratch = self.scratch.lock().unwrap();
        encoder::serve_step_packed(
            &self.entry,
            &self.frozen,
            pairs,
            tokens,
            task_id,
            self.threads,
            &mut scratch,
            out,
        )
    }

    fn recycle(&self, outputs: Vec<Tensor>) {
        // Consumed step outputs (gradient tensors) go back to the arena so
        // the steady-state train loop stays allocation-free.
        self.scratch.lock().unwrap().workspace_mut().recycle_vec(outputs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::assemble_frozen;
    use crate::util::rng::Pcg64;

    fn tiny_eval_spec() -> ArtifactSpec {
        ArtifactSpec {
            step: StepKind::Eval,
            model: "tiny".into(),
            adapter: "metatt4d".into(),
            rank: 4,
            classes: 2,
            tasks: 1,
            batch: 4,
            seq: 8,
        }
    }

    #[test]
    fn bind_validates_frozen_set() {
        let backend = RefBackend::new();
        let spec = tiny_eval_spec();
        // Empty frozen set must be rejected with a helpful error.
        let err = backend.bind(&spec, &Arc::new(HashMap::new())).unwrap_err();
        assert!(format!("{err:#}").contains("frozen input"), "{err:#}");
        // A proper frozen set binds and is counted.
        let entry = backend.entry(&spec).unwrap();
        let frozen = Arc::new(assemble_frozen(&entry, None, ModelPreset::Tiny).unwrap());
        backend.bind(&spec, &frozen).unwrap();
        assert_eq!(backend.cached_executables(), 1);
        // Re-binding the same spec does not double count.
        backend.bind(&spec, &frozen).unwrap();
        assert_eq!(backend.cached_executables(), 1);
    }

    #[test]
    fn apply_step_runs_the_tt_chain() {
        let backend = RefBackend::new();
        let spec = backend.apply_spec("metatt4d", 8).unwrap();
        let entry = backend.entry(&spec).unwrap();
        let step = backend.bind(&spec, &Arc::new(HashMap::new())).unwrap();
        let mut rng = Pcg64::new(3);
        let inputs: Vec<Tensor> = entry
            .inputs
            .iter()
            .map(|io| Tensor::randn(&io.shape, 0.5, &mut rng))
            .collect();
        let out = step.run_raw(&inputs).unwrap().remove(0);
        let want = inputs[0]
            .matmul(&inputs[1])
            .matmul(&inputs[2])
            .matmul(&inputs[3]);
        assert_eq!(out, want);
    }
}
