//! The execution-backend seam: every training/eval/pretrain step goes
//! through the [`Backend`] + [`Step`] traits.
//!
//! Two implementations exist:
//!
//! * [`super::RefBackend`] (`--backend ref`, the default) — pure-rust CPU
//!   execution of the encoder forward/backward on top of `tensor::ops`.
//!   Hermetic: no HLO artifacts, no Python, no network.
//! * `Runtime` (`--backend pjrt`, behind the `pjrt` cargo feature) — the
//!   original PJRT path: AOT-lowered HLO artifacts compiled and cached per
//!   [`ArtifactSpec`], frozen weights resident on device.
//!
//! The coordinator layer is written entirely against `&dyn Backend`, so the
//! DMRG executable hot-swap, MTL task routing, and checkpointing logic is
//! identical across backends.

use super::encoder::FoldedPairPacked;
use super::registry::{ArtifactEntry, ArtifactSpec};
use crate::config::ModelPreset;
use crate::data::{Batch, MlmBatch};
use crate::tensor::{DtypeKind, Tensor};
use anyhow::Result;
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

/// Which execution backend to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-rust reference executor (hermetic, CPU).
    Ref,
    /// PJRT/XLA over AOT-lowered HLO artifacts (requires `--features pjrt`).
    Pjrt,
}

impl BackendKind {
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Ref => "ref",
            BackendKind::Pjrt => "pjrt",
        }
    }

    pub fn from_name(s: &str) -> Result<BackendKind, String> {
        match s {
            "ref" => Ok(BackendKind::Ref),
            "pjrt" => Ok(BackendKind::Pjrt),
            other => Err(format!("unknown backend '{other}' (want ref|pjrt)")),
        }
    }
}

/// A bound step: ready to execute with only the per-step inputs.
/// (The PJRT implementation holds resident frozen device buffers + the
/// compiled executable; the reference implementation holds host tensors.)
pub trait Step {
    /// The artifact layout this step was bound against.
    fn entry(&self) -> &ArtifactEntry;

    /// One fwd+bwd step. Returns (loss, grads in trainable order).
    fn run_train(
        &self,
        trainable: &[Tensor],
        batch: &Batch,
        task_id: i32,
        alpha: f32,
    ) -> Result<(f32, Vec<Tensor>)>;

    /// One fwd (eval) step. Returns logits `[batch, classes]`.
    fn run_eval(
        &self,
        trainable: &[Tensor],
        batch: &Batch,
        task_id: i32,
        alpha: f32,
    ) -> Result<Tensor>;

    /// One MLM pretraining step (no frozen inputs; `trainable` is the whole
    /// encoder). Returns (loss, grads).
    fn run_pretrain(&self, trainable: &[Tensor], batch: &MlmBatch) -> Result<(f32, Vec<Tensor>)>;

    /// Raw positional execution (serving-apply / micro-bench path).
    fn run_raw(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>>;

    /// Batched folded-adapter serving forward (eval-spec steps only): the
    /// cache-free inference encoder with per-(layer, matrix) pre-folded
    /// factor pairs from [`crate::tt::MetaTt::fold_for_serving`] in place
    /// of the family adapter math, CLS-pooled through the frozen head of
    /// `task_id`. Logits land in `out` (`batch · classes`, row-major) —
    /// nothing escapes the backend's workspace, so a warmed serving tick
    /// allocates nothing. This is the multi-task serving engine's hot
    /// path ([`crate::serving`]); backends without a host-side serving
    /// forward report unsupported.
    fn run_serve(
        &self,
        _pairs: &[Vec<(Tensor, Tensor)>],
        _tokens: &[i32],
        _task_id: i32,
        _out: &mut [f32],
    ) -> Result<()> {
        anyhow::bail!("this backend has no folded-adapter serving path")
    }

    /// [`Step::run_serve`] over *pre-packed* folded factor pairs — the
    /// dtype-selected serving hot path (PR 7). The pairs come from
    /// [`FoldedPairPacked::pack`] at the dtype the step was bound with
    /// ([`Backend::bind_serve`]); the f32 instantiation is bit-identical
    /// to `run_serve` on the dense pairs, quantized instantiations carry
    /// the dtype's tolerance contract. Only steps bound through
    /// `bind_serve` are guaranteed to support this.
    fn run_serve_packed(
        &self,
        _pairs: &[Vec<FoldedPairPacked>],
        _tokens: &[i32],
        _task_id: i32,
        _out: &mut [f32],
    ) -> Result<()> {
        anyhow::bail!("this backend has no packed folded-adapter serving path")
    }

    /// Hand consumed step outputs (e.g. the gradient tensors of a train
    /// step, after the optimizer has applied them) back to the backend.
    /// The reference backend returns the buffers to its workspace arena so
    /// the steady-state train loop performs zero heap allocations; other
    /// backends may simply drop them (the default).
    fn recycle(&self, _outputs: Vec<Tensor>) {}
}

/// An execution backend: resolves [`ArtifactSpec`]s to I/O layouts and binds
/// executable steps.
pub trait Backend: Send + Sync {
    fn kind(&self) -> BackendKind;

    /// Human-readable platform string (PJRT platform name / "cpu (pure rust)").
    fn platform(&self) -> String;

    /// Multi-line status summary for `metatt info`.
    fn describe(&self) -> String;

    /// Resolve the I/O layout of `spec`, erroring if this backend cannot
    /// execute it (e.g. missing HLO artifact on the PJRT side).
    fn entry(&self, spec: &ArtifactSpec) -> Result<ArtifactEntry>;

    /// Bind `spec` with the frozen input set, validating names and shapes.
    /// The map is shared (`Arc`) because rebinding is routine — the DMRG
    /// scheduler hot-swaps steps per rank — and the frozen backbone can be
    /// tens of MB; backends keep a refcount, never a deep copy.
    fn bind<'a>(
        &'a self,
        spec: &ArtifactSpec,
        frozen: &Arc<HashMap<String, Tensor>>,
    ) -> Result<Box<dyn Step + 'a>>;

    /// Bind a serving step whose frozen-panel storage dtype is selected at
    /// bind time (`--serve-dtype`). `DtypeKind::F32` is exactly [`Backend::bind`]
    /// (the bit-exact path); backends without a quantized serving path
    /// reject the other dtypes here, at bind, rather than failing per tick.
    fn bind_serve<'a>(
        &'a self,
        spec: &ArtifactSpec,
        frozen: &Arc<HashMap<String, Tensor>>,
        dtype: DtypeKind,
    ) -> Result<Box<dyn Step + 'a>> {
        match dtype {
            DtypeKind::F32 => self.bind(spec, frozen),
            other => anyhow::bail!(
                "backend '{}' serves f32 only (requested --serve-dtype {})",
                self.kind().name(),
                other.name()
            ),
        }
    }

    /// Number of distinct compiled/bound executables so far — the DMRG
    /// hot-swap telemetry.
    fn cached_executables(&self) -> usize;

    /// Worker-thread budget this backend executes steps with. Coordinators
    /// use it for their own fan-out (e.g. parallel dataset generation);
    /// backends without host-side parallelism report 1.
    fn threads(&self) -> usize {
        1
    }

    /// The MLM pretraining spec for a preset.
    fn pretrain_spec(&self, preset: ModelPreset) -> Result<ArtifactSpec>;

    /// A serving-apply spec for (adapter, rank).
    fn apply_spec(&self, adapter: &str, rank: usize) -> Result<ArtifactSpec>;
}

/// Construct a backend by kind. `artifact_dir` is only read by the PJRT
/// backend (manifest + HLO files); `threads` (>= 1) is the worker budget
/// of the reference backend's step execution — PJRT delegates threading to
/// XLA and ignores it.
pub fn make_backend(
    kind: BackendKind,
    artifact_dir: &Path,
    threads: usize,
) -> Result<Box<dyn Backend>> {
    match kind {
        BackendKind::Ref => {
            let _ = artifact_dir;
            Ok(Box::new(super::RefBackend::with_threads(threads)?))
        }
        #[cfg(feature = "pjrt")]
        BackendKind::Pjrt => {
            let _ = threads;
            Ok(Box::new(super::Runtime::new(artifact_dir)?))
        }
        #[cfg(not(feature = "pjrt"))]
        BackendKind::Pjrt => {
            let _ = (artifact_dir, threads);
            anyhow::bail!(
                "backend 'pjrt' is not compiled into this binary — rebuild with \
                 `cargo build --features pjrt` (and real PJRT bindings), or use \
                 `--backend ref`"
            )
        }
    }
}

/// Backend selection from the environment: `METATT_BACKEND` (ref|pjrt,
/// default ref), `METATT_ARTIFACTS` (default "artifacts"), and
/// `METATT_THREADS` (default: host parallelism). Used by the bench
/// binaries and examples so env vars flip the whole harness.
pub fn backend_from_env() -> Result<Box<dyn Backend>> {
    let kind = match std::env::var("METATT_BACKEND") {
        Ok(v) => BackendKind::from_name(&v).map_err(anyhow::Error::msg)?,
        Err(_) => BackendKind::Ref,
    };
    let dir = std::env::var("METATT_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    let threads =
        crate::util::threadpool::resolve_threads(None).map_err(anyhow::Error::msg)?;
    make_backend(kind, Path::new(&dir), threads)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_roundtrip() {
        for k in [BackendKind::Ref, BackendKind::Pjrt] {
            assert_eq!(BackendKind::from_name(k.name()).unwrap(), k);
        }
        assert!(BackendKind::from_name("tpu").is_err());
    }

    #[test]
    fn ref_backend_constructs_without_artifacts() {
        let b = make_backend(BackendKind::Ref, Path::new("/nonexistent"), 2).unwrap();
        assert_eq!(b.kind(), BackendKind::Ref);
        assert_eq!(b.cached_executables(), 0);
        assert_eq!(b.threads(), 2);
    }

    #[test]
    fn zero_threads_is_a_clean_error() {
        let err = make_backend(BackendKind::Ref, Path::new("."), 0).unwrap_err();
        assert!(format!("{err:#}").contains(">= 1"), "{err:#}");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_backend_requires_feature() {
        let err = make_backend(BackendKind::Pjrt, Path::new("artifacts"), 1).unwrap_err();
        assert!(format!("{err:#}").contains("--features pjrt"));
    }
}
