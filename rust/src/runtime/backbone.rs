//! Frozen-backbone assembly: encoder weights + per-task classifier heads.
//!
//! Fine-tuning runs need the full `frozen_specs` input set of the artifact:
//! the 20 encoder arrays (from the pretraining checkpoint, or freshly
//! initialized when no checkpoint exists) plus the frozen random classifier
//! heads (the paper freezes heads to isolate adapter capacity, §3.1).

use super::registry::ArtifactEntry;
use crate::config::ModelPreset;
use crate::coordinator::checkpoint;
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Initialize encoder weights in rust (used when pretraining from scratch
/// and as the no-checkpoint fallback): N(0, 0.02) embeddings, fan-in-scaled
/// normal matrices, zero biases, unit layernorm gains.
pub fn init_encoder_weights(entry_inputs: &[(String, Vec<usize>)], seed: u64) -> Vec<(String, Tensor)> {
    let mut rng = Pcg64::with_stream(seed, 0xbac6b0de);
    entry_inputs
        .iter()
        .map(|(name, shape)| {
            let t = if name.ends_with("_g") {
                Tensor::full(shape, 1.0)
            } else if name.starts_with('b') || name.ends_with("_b") {
                Tensor::zeros(shape)
            } else if name.contains("emb") {
                Tensor::randn(shape, 0.02, &mut rng)
            } else {
                let fan_in = if shape.len() >= 2 {
                    shape[shape.len() - 2]
                } else {
                    shape[shape.len() - 1]
                };
                Tensor::randn(shape, 1.0 / (fan_in as f32).sqrt(), &mut rng)
            };
            (name.clone(), t)
        })
        .collect()
}

/// Default checkpoint path for a preset.
pub fn checkpoint_path(preset: ModelPreset) -> PathBuf {
    Path::new("checkpoints").join(format!("pretrained_{}.bin", preset.name()))
}

/// Build the frozen input map for a fine-tuning artifact: encoder weights
/// from `ckpt` (or fresh, seeded, if None/missing) + random frozen heads.
///
/// Head seed is fixed per (preset, tasks, classes) so every method sees the
/// *same* frozen head — the paper's controlled comparison.
pub fn assemble_frozen(
    entry: &ArtifactEntry,
    ckpt: Option<&Path>,
    preset: ModelPreset,
) -> Result<HashMap<String, Tensor>> {
    let mut out: HashMap<String, Tensor> = HashMap::new();
    // Encoder weights.
    let loaded: Option<Vec<(String, Tensor)>> = match ckpt {
        Some(p) if p.exists() => {
            Some(checkpoint::load(p).map_err(anyhow::Error::msg)?)
        }
        _ => None,
    };
    match loaded {
        Some(tensors) => {
            for (name, t) in tensors {
                out.insert(name, t);
            }
        }
        None => {
            let shapes: Vec<(String, Vec<usize>)> = entry
                .frozen_inputs()
                .iter()
                .filter(|io| !io.name.starts_with("cls_"))
                .map(|io| (io.name.clone(), io.shape.clone()))
                .collect();
            for (name, t) in init_encoder_weights(&shapes, 0x5eed) {
                out.insert(name, t);
            }
        }
    }
    // Frozen random heads.
    let spec = &entry.spec;
    let head_seed = head_seed(spec.tasks, spec.classes, preset);
    let mut rng = Pcg64::with_stream(head_seed, 0xc1a55);
    for io in entry.frozen_inputs() {
        if io.name == "cls_w" {
            let d = io.shape[1] as f32;
            out.insert(io.name.clone(), Tensor::randn(&io.shape, 1.0 / d.sqrt(), &mut rng));
        } else if io.name == "cls_b" {
            out.insert(io.name.clone(), Tensor::zeros(&io.shape));
        }
    }
    // Sanity: every frozen input is covered with the right shape.
    for io in entry.frozen_inputs() {
        match out.get(&io.name) {
            None => bail!("frozen input '{}' not assembled", io.name),
            Some(t) if t.shape() != &io.shape[..] => bail!(
                "frozen '{}': checkpoint shape {:?} != artifact {:?} — wrong preset checkpoint?",
                io.name,
                t.shape(),
                io.shape
            ),
            _ => {}
        }
    }
    Ok(out)
}

fn head_seed(tasks: usize, classes: usize, preset: ModelPreset) -> u64 {
    (tasks as u64) << 32 | (classes as u64) << 16 | preset.name().len() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_weights_follow_conventions() {
        let shapes = vec![
            ("tok_emb".to_string(), vec![512usize, 64]),
            ("wq".to_string(), vec![4, 64, 64]),
            ("bq".to_string(), vec![4, 64]),
            ("ln1_g".to_string(), vec![4, 64]),
            ("ln1_b".to_string(), vec![4, 64]),
        ];
        let ws = init_encoder_weights(&shapes, 1);
        let get = |n: &str| &ws.iter().find(|(name, _)| name == n).unwrap().1;
        assert!(get("ln1_g").data().iter().all(|&x| x == 1.0));
        assert!(get("bq").data().iter().all(|&x| x == 0.0));
        assert!(get("ln1_b").data().iter().all(|&x| x == 0.0));
        assert!(get("tok_emb").max_abs() < 0.2); // 0.02 std
        let wq_std = get("wq").fro_norm() / ((4 * 64 * 64) as f32).sqrt();
        assert!((wq_std - 1.0 / 8.0).abs() < 0.02, "wq std {wq_std}");
    }

    #[test]
    fn init_is_deterministic() {
        let shapes = vec![("wq".to_string(), vec![2usize, 8, 8])];
        assert_eq!(init_encoder_weights(&shapes, 7), init_encoder_weights(&shapes, 7));
        assert_ne!(
            init_encoder_weights(&shapes, 7)[0].1,
            init_encoder_weights(&shapes, 8)[0].1
        );
    }
}
