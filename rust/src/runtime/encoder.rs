//! Pure-rust forward + backward of the adapted transformer encoder.
//!
//! This is the compute core of the reference backend (`--backend ref`): a
//! faithful re-implementation of `python/compile/model.py` on top of
//! [`crate::tensor`] — RoBERTa-style post-LN encoder, tanh-GELU MLP,
//! learned positions, pad-masked attention, adapters on the Q (m=0) and V
//! (m=1) projections, CLS pooling through frozen per-task heads, weighted
//! CE / MSE task losses, and the weight-tied MLM pretraining objective.
//!
//! The backward pass is hand-derived reverse mode over the same graph: the
//! forward caches layer activations (`LayerCache`), the backward walks them
//! in reverse, accumulating gradients by *name + contiguous chunk* into a
//! [`GradSink`] keyed by the artifact's trainable layout. Because every
//! structural axis (layer, matrix, head, task) is the leading axis of its
//! array, all sliced accumulations are contiguous chunks — no strided
//! scatter is ever needed. Gradients are checked against central finite
//! differences in `tests/ref_backend.rs`.
//!
//! **Parallel execution.** Every step entry point takes a thread budget
//! (plumbed from `--threads` via the backend). Inside a step the work is
//! data-parallel along structurally independent axes: the big GEMMs split
//! output row bands (`tensor::ops::*_mt`), attention fans out per
//! (batch, head), and the LayerNorm / GELU / MLM-softmax row loops split
//! row bands. Cross-row *reductions* (bias column sums, LN γ/β grads, the
//! scalar loss) always run in a fixed serial order, so 1-thread and
//! N-thread executions are **bit-identical** (`tests/determinism.rs`).

use super::registry::{ArtifactEntry, IoSpec};
use crate::adapters::AdapterKind;
use crate::config::ModelPreset;
use crate::data::{Batch, MlmBatch};
use crate::tensor::Tensor;
use crate::tt::MetaTtKind;
use crate::util::rng::Pcg64;
use crate::util::threadpool::{scope_map, scope_rows, SharedSliceMut};
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

const PAD_ID: i32 = 0;
const LN_EPS: f32 = 1e-5;
const MASK_NEG: f32 = -1e9;

/// Minimum elementwise work (elements touched) for a row loop to go
/// parallel; below it region dispatch costs more than the loop.
const PAR_MIN_ELEMS: usize = 1 << 14;

/// Minimum rows per band for the row-parallel loops.
const ROW_BAND: usize = 16;

/// Gate a thread budget on the amount of work: serial below the threshold.
fn gate(threads: usize, work: usize) -> usize {
    crate::util::threadpool::gated_threads(threads, work, PAR_MIN_ELEMS)
}

// ---------------------------------------------------------------------------
// Small dense helpers.
// ---------------------------------------------------------------------------

/// Copy the `i`-th leading-axis slice of a stacked array as an (r × c)
/// matrix. Works for any tensor whose trailing element count is r·c.
fn chunk_mat(t: &Tensor, i: usize, r: usize, c: usize) -> Tensor {
    let len = r * c;
    Tensor::from_vec(&[r, c], t.data()[i * len..(i + 1) * len].to_vec())
}

/// Copy rows `[row0, row0+nrows)` × cols `[col0, col0+ncols)` of a matrix.
fn block(m: &Tensor, row0: usize, nrows: usize, col0: usize, ncols: usize) -> Tensor {
    let cols = m.shape()[1];
    let mut out = Tensor::zeros(&[nrows, ncols]);
    for i in 0..nrows {
        let src = (row0 + i) * cols + col0;
        out.data_mut()[i * ncols..(i + 1) * ncols]
            .copy_from_slice(&m.data()[src..src + ncols]);
    }
    out
}

/// `dst[row0.., col0..] += src` for a (nrows × ncols) block.
fn add_block(dst: &mut Tensor, row0: usize, col0: usize, src: &Tensor) {
    let (nrows, ncols) = (src.shape()[0], src.shape()[1]);
    let cols = dst.shape()[1];
    for i in 0..nrows {
        let d0 = (row0 + i) * cols + col0;
        for j in 0..ncols {
            dst.data_mut()[d0 + j] += src.data()[i * ncols + j];
        }
    }
}

/// `t[i, :] += bias` for every row.
fn add_row_bias(t: &mut Tensor, bias: &[f32]) {
    let cols = t.shape()[1];
    debug_assert_eq!(cols, bias.len());
    for row in t.data_mut().chunks_exact_mut(cols) {
        for (v, b) in row.iter_mut().zip(bias) {
            *v += *b;
        }
    }
}

/// Column sums of a matrix.
fn colsum(t: &Tensor) -> Vec<f32> {
    let cols = t.shape()[1];
    let mut out = vec![0.0f32; cols];
    for row in t.data().chunks_exact(cols) {
        for (o, v) in out.iter_mut().zip(row) {
            *o += *v;
        }
    }
    out
}

/// Elementwise product with a per-column vector: `t[i, j] * v[j]`.
fn mul_cols(t: &Tensor, v: &[f32]) -> Tensor {
    let cols = t.shape()[1];
    debug_assert_eq!(cols, v.len());
    let mut out = t.clone();
    for row in out.data_mut().chunks_exact_mut(cols) {
        for (x, s) in row.iter_mut().zip(v) {
            *x *= *s;
        }
    }
    out
}

/// Column sums of the elementwise product of two matrices (Σ_i a[i,j]·b[i,j]).
fn colsum_mul(a: &Tensor, b: &Tensor) -> Vec<f32> {
    debug_assert_eq!(a.shape(), b.shape());
    let cols = a.shape()[1];
    let mut out = vec![0.0f32; cols];
    for (ra, rb) in a.data().chunks_exact(cols).zip(b.data().chunks_exact(cols)) {
        for j in 0..cols {
            out[j] += ra[j] * rb[j];
        }
    }
    out
}

// tanh-approximate GELU (jax.nn.gelu default) and its derivative.
const GELU_C: f32 = 0.797_884_6; // sqrt(2/π)
const GELU_K: f32 = 0.044_715;

fn gelu(u: f32) -> f32 {
    0.5 * u * (1.0 + (GELU_C * (u + GELU_K * u * u * u)).tanh())
}

fn gelu_prime(u: f32) -> f32 {
    let w = GELU_C * (u + GELU_K * u * u * u);
    let t = w.tanh();
    0.5 * (1.0 + t) + 0.5 * u * (1.0 - t * t) * GELU_C * (1.0 + 3.0 * GELU_K * u * u)
}

// ---------------------------------------------------------------------------
// LayerNorm with cached normalization state.
// ---------------------------------------------------------------------------

struct LnCache {
    /// Normalized input (x - μ)/σ, needed by both the output and the grads.
    xhat: Tensor,
    /// 1/σ per row.
    inv_std: Vec<f32>,
}

/// `y = (x - μ)/sqrt(var + ε) · g + b` per row (biased variance, as jnp.var).
/// Rows are independent and band-split across `threads`; each row's stats
/// are computed by exactly one worker, so thread count never changes bits.
fn layer_norm(
    x: &Tensor,
    gamma: &[f32],
    beta: &[f32],
    threads: usize,
) -> (Tensor, LnCache) {
    let (n, d) = (x.shape()[0], x.shape()[1]);
    let mut xhat = Tensor::zeros(&[n, d]);
    let mut y = Tensor::zeros(&[n, d]);
    let mut inv_std = vec![0.0f32; n];
    {
        let xs = x.data();
        let xhs = SharedSliceMut::new(xhat.data_mut());
        let ys = SharedSliceMut::new(y.data_mut());
        let invs = SharedSliceMut::new(&mut inv_std);
        scope_rows(gate(threads, n * d), n, ROW_BAND, |band| {
            // SAFETY: bands are disjoint row ranges; each buffer is sliced
            // to this band only.
            let xh_band = unsafe { xhs.range_mut(band.start * d, band.end * d) };
            let y_band = unsafe { ys.range_mut(band.start * d, band.end * d) };
            let inv_band = unsafe { invs.range_mut(band.start, band.end) };
            for i in band.clone() {
                let row = &xs[i * d..(i + 1) * d];
                let o = (i - band.start) * d;
                let mu = row.iter().sum::<f32>() / d as f32;
                let var =
                    row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
                let inv = 1.0 / (var + LN_EPS).sqrt();
                inv_band[i - band.start] = inv;
                for j in 0..d {
                    let xh = (row[j] - mu) * inv;
                    xh_band[o + j] = xh;
                    y_band[o + j] = xh * gamma[j] + beta[j];
                }
            }
        });
    }
    (y, LnCache { xhat, inv_std })
}

/// LayerNorm backward. Returns dx; if `dgb` is Some((dgamma, dbeta)) the
/// parameter gradients are accumulated into the provided buffers. The dx
/// rows are band-parallel; the γ/β reduction runs in a fixed serial row
/// order so its accumulation never depends on the thread count.
fn layer_norm_backward(
    dy: &Tensor,
    cache: &LnCache,
    gamma: &[f32],
    dgb: Option<(&mut [f32], &mut [f32])>,
    threads: usize,
) -> Tensor {
    let (n, d) = (dy.shape()[0], dy.shape()[1]);
    let mut dx = Tensor::zeros(&[n, d]);
    {
        let dys = dy.data();
        let xhs = cache.xhat.data();
        let dxs = SharedSliceMut::new(dx.data_mut());
        scope_rows(gate(threads, n * d), n, ROW_BAND, |band| {
            // SAFETY: bands are disjoint row ranges of dx.
            let dx_band = unsafe { dxs.range_mut(band.start * d, band.end * d) };
            for i in band.clone() {
                let dyr = &dys[i * d..(i + 1) * d];
                let xhr = &xhs[i * d..(i + 1) * d];
                let o = (i - band.start) * d;
                let mut m1 = 0.0f32; // mean of dxhat
                let mut m2 = 0.0f32; // mean of dxhat ∘ xhat
                for j in 0..d {
                    let dxh = dyr[j] * gamma[j];
                    m1 += dxh;
                    m2 += dxh * xhr[j];
                }
                m1 /= d as f32;
                m2 /= d as f32;
                let inv = cache.inv_std[i];
                for j in 0..d {
                    let dxh = dyr[j] * gamma[j];
                    dx_band[o + j] = (dxh - m1 - xhr[j] * m2) * inv;
                }
            }
        });
    }
    if let Some((dg, db)) = dgb {
        for i in 0..n {
            let dyr = &dy.data()[i * d..(i + 1) * d];
            let xhr = &cache.xhat.data()[i * d..(i + 1) * d];
            for j in 0..d {
                dg[j] += dyr[j] * xhr[j];
                db[j] += dyr[j];
            }
        }
    }
    dx
}

// ---------------------------------------------------------------------------
// Gradient sink: name + contiguous-chunk accumulation in trainable order.
// ---------------------------------------------------------------------------

/// Accumulates gradients for the artifact's ordered trainable arrays.
struct GradSink {
    grads: Vec<Tensor>,
    index: HashMap<String, usize>,
}

impl GradSink {
    fn new(specs: &[IoSpec]) -> GradSink {
        let grads = specs.iter().map(|s| Tensor::zeros(&s.shape)).collect();
        let index = specs
            .iter()
            .enumerate()
            .map(|(i, s)| (s.name.clone(), i))
            .collect();
        GradSink { grads, index }
    }

    /// `grad[name][offset..offset+len] += src` (contiguous chunk).
    fn add_chunk(&mut self, name: &str, offset: usize, src: &[f32]) {
        let i = *self.index.get(name).unwrap_or_else(|| {
            panic!("gradient for unknown trainable '{name}'")
        });
        let dst = &mut self.grads[i].data_mut()[offset..offset + src.len()];
        for (d, s) in dst.iter_mut().zip(src) {
            *d += *s;
        }
    }

    fn add_all(&mut self, name: &str, src: &Tensor) {
        self.add_chunk(name, 0, src.data());
    }

    fn into_vec(self) -> Vec<Tensor> {
        self.grads
    }
}

// ---------------------------------------------------------------------------
// Weight resolution: frozen map + ordered trainable slice, by name.
// ---------------------------------------------------------------------------

struct Weights<'a> {
    map: HashMap<&'a str, &'a Tensor>,
}

impl<'a> Weights<'a> {
    fn build(
        entry: &'a ArtifactEntry,
        frozen: &'a HashMap<String, Tensor>,
        trainable: &'a [Tensor],
    ) -> Result<Weights<'a>> {
        let mut map: HashMap<&str, &Tensor> = HashMap::new();
        for io in entry.frozen_inputs() {
            let t = frozen
                .get(&io.name)
                .ok_or_else(|| anyhow!("frozen input '{}' missing", io.name))?;
            map.insert(io.name.as_str(), t);
        }
        for (io, t) in entry.trainable_inputs().iter().zip(trainable) {
            map.insert(io.name.as_str(), t);
        }
        Ok(Weights { map })
    }

    fn get(&self, name: &str) -> &Tensor {
        self.map
            .get(name)
            .unwrap_or_else(|| panic!("weight '{name}' not resolved"))
    }

    fn vec(&self, name: &str) -> &[f32] {
        self.get(name).data()
    }

    /// Row `i` of a (rows, d) stacked vector array.
    fn row(&self, name: &str, i: usize, d: usize) -> &[f32] {
        &self.get(name).data()[i * d..(i + 1) * d]
    }
}

// ---------------------------------------------------------------------------
// Model dimensions derived from the artifact spec.
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
struct Dims {
    b: usize,
    s: usize,
    n: usize,
    d: usize,
    h: usize,
    dh: usize,
    f: usize,
    l: usize,
    v: usize,
    classes: usize,
}

fn dims_of(entry: &ArtifactEntry) -> Result<Dims> {
    let preset = ModelPreset::from_name(&entry.spec.model).map_err(anyhow::Error::msg)?;
    let md = preset.dims(entry.spec.tasks.max(1));
    let (b, s) = (entry.spec.batch, entry.spec.seq);
    Ok(Dims {
        b,
        s,
        n: b * s,
        d: md.hidden,
        h: md.heads,
        dh: md.hidden / md.heads,
        f: md.ffn,
        l: md.layers,
        v: md.vocab,
        classes: entry.spec.classes,
    })
}

// ---------------------------------------------------------------------------
// Adapter application (forward + backward), all Table-1 families.
// ---------------------------------------------------------------------------

struct AdapterCtx<'a> {
    /// None for "full"/"none" (zero delta).
    kind: Option<AdapterKind>,
    params: &'a [Tensor],
    alpha: f32,
    task: usize,
    rank: usize,
    heads: usize,
    matrices: usize,
    d: usize,
    /// Thread budget for the activation-sized GEMMs (the r×r factor
    /// products stay serial — they are far below the parallel threshold).
    threads: usize,
    /// VeRA's frozen shared projections (seed-fixed), built once per step.
    vera_frozen: Option<(Tensor, Tensor)>,
}

impl<'a> AdapterCtx<'a> {
    fn new(
        entry: &ArtifactEntry,
        params: &'a [Tensor],
        alpha: f32,
        task: usize,
        threads: usize,
    ) -> Result<Self> {
        let dims = dims_of(entry)?;
        let kind = match entry.spec.adapter.as_str() {
            "full" | "none" => None,
            name => Some(AdapterKind::from_name(name).map_err(anyhow::Error::msg)?),
        };
        let vera_frozen = if matches!(kind, Some(AdapterKind::VeRa)) {
            // Mirror of model.py `_vera_frozen`: shared random A (d×r),
            // B (r×d), seed-fixed so every step agrees. (The PJRT artifacts
            // bake jax-PRNG draws; the reference backend uses its own fixed
            // PCG stream — same distribution, different realization.)
            let r = entry.spec.rank;
            let d = dims.d;
            let mut rng = Pcg64::with_stream(7, 0x7e2a);
            let a = Tensor::randn(&[d, r], 1.0 / (d as f32).sqrt(), &mut rng);
            let b = Tensor::randn(&[r, d], 1.0 / (r as f32).sqrt(), &mut rng);
            Some((a, b))
        } else {
            None
        };
        Ok(AdapterCtx {
            kind,
            params,
            alpha,
            task,
            rank: entry.spec.rank,
            heads: dims.h,
            matrices: 2,
            d: dims.d,
            threads,
            vera_frozen,
        })
    }

    /// Adapter delta for activations `x` (n × d) at (layer, matrix).
    fn apply(&self, x: &Tensor, layer: usize, matrix: usize) -> (Tensor, AdapterCache) {
        let (n, d, r) = (x.shape()[0], self.d, self.rank);
        let a = self.alpha;
        let th = self.threads;
        match self.kind {
            None => (Tensor::zeros(&[n, d]), AdapterCache::None),
            Some(AdapterKind::MetaTt(MetaTtKind::FourD)) => {
                let [g1, g2, g3, g4] = self.p4();
                let mid = chunk_mat(g2, layer, r, r).matmul(&chunk_mat(g3, matrix, r, r));
                let xg1 = x.matmul_mt(g1, th);
                let xgm = xg1.matmul(&mid);
                let delta = xgm.matmul_mt(g4, th).scale(a);
                (delta, AdapterCache::Tt4 { xg1, xgm, mid })
            }
            Some(AdapterKind::MetaTt(MetaTtKind::FourPlusOneD)) => {
                let [g1, g2, g3, g4, g5] = self.p5();
                let ca = chunk_mat(g2, layer, r, r);
                let cb = chunk_mat(g3, self.task, r, r);
                let cc = chunk_mat(g4, matrix, r, r);
                let ab = ca.matmul(&cb);
                let bc = cb.matmul(&cc);
                let mid = ab.matmul(&cc);
                let xg1 = x.matmul_mt(g1, th);
                let xgm = xg1.matmul(&mid);
                let delta = xgm.matmul_mt(g5, th).scale(a);
                (delta, AdapterCache::Tt4p1 { xg1, xgm, ca, ab, bc, mid })
            }
            Some(AdapterKind::MetaTt(MetaTtKind::FiveD)) => {
                let [g1, g2, g3, g4, g5] = self.p5();
                let dh = d / self.heads;
                let lm = chunk_mat(g2, layer, r, r).matmul(&chunk_mat(g3, matrix, r, r));
                let xg1 = x.matmul_mt(g1, th);
                let xlm = xg1.matmul(&lm);
                let mut delta = Tensor::zeros(&[n, d]);
                let mut xh = Vec::with_capacity(self.heads);
                for hh in 0..self.heads {
                    let xhh = xlm.matmul(&chunk_mat(g4, hh, r, r));
                    let y = xhh.matmul_mt(g5, th).scale(a); // (n, dh)
                    add_block(&mut delta, 0, hh * dh, &y);
                    xh.push(xhh);
                }
                (delta, AdapterCache::Tt5 { xg1, xlm, lm, xh })
            }
            Some(AdapterKind::LoRa) => {
                let (pa, pb) = (&self.params[0], &self.params[1]);
                let idx = layer * self.matrices + matrix;
                let am = chunk_mat(pa, idx, d, r);
                let bm = chunk_mat(pb, idx, r, d);
                let xa = x.matmul_mt(&am, th);
                let delta = xa.matmul_mt(&bm, th).scale(a);
                (delta, AdapterCache::Lora { xa })
            }
            Some(AdapterKind::VeRa) => {
                let (fa, fb) = self.vera_frozen.as_ref().expect("vera frozen");
                let idx = layer * self.matrices + matrix;
                let dvec = &self.params[0].data()[idx * r..(idx + 1) * r];
                let bvec = &self.params[1].data()[idx * d..(idx + 1) * d];
                let xa = x.matmul_mt(fa, th);
                let t = mul_cols(&xa, dvec);
                let tb = t.matmul_mt(fb, th);
                let delta = mul_cols(&tb, bvec).scale(a);
                (delta, AdapterCache::Vera { xa, tb })
            }
            Some(AdapterKind::LoTr) => {
                let (u, sall, vmat) = (&self.params[0], &self.params[1], &self.params[2]);
                let idx = layer * self.matrices + matrix;
                let sm = chunk_mat(sall, idx, r, r);
                let xu = x.matmul_mt(u, th);
                let xus = xu.matmul(&sm);
                let delta = xus.matmul_mt(vmat, th).scale(a);
                (delta, AdapterCache::Lotr { xu, xus, sm })
            }
            Some(AdapterKind::Full) => (Tensor::zeros(&[n, d]), AdapterCache::None),
        }
    }

    /// Backward through the delta at (layer, matrix): accumulates parameter
    /// grads into `sink` and `dx += ∂delta/∂x · dy`.
    fn backward(
        &self,
        x: &Tensor,
        layer: usize,
        matrix: usize,
        cache: &AdapterCache,
        dy: &Tensor,
        dx: &mut Tensor,
        sink: &mut GradSink,
    ) {
        let (d, r) = (self.d, self.rank);
        let th = self.threads;
        let dya = dy.scale(self.alpha); // fold α once
        match (self.kind, cache) {
            (None, _) | (Some(AdapterKind::Full), _) => {}
            (Some(AdapterKind::MetaTt(MetaTtKind::FourD)), AdapterCache::Tt4 { xg1, xgm, mid }) => {
                let [g1, g2, g3, g4] = self.p4();
                sink.add_all("g4", &xgm.t_matmul_mt(&dya, th));
                let dxgm = dya.matmul_t_mt(g4, th);
                let dmid = xg1.t_matmul_mt(&dxgm, th);
                let g2l = chunk_mat(g2, layer, r, r);
                let g3m = chunk_mat(g3, matrix, r, r);
                sink.add_chunk("g2", layer * r * r, dmid.matmul_t(&g3m).data());
                sink.add_chunk("g3", matrix * r * r, g2l.t_matmul(&dmid).data());
                let dxg1 = dxgm.matmul_t(mid);
                sink.add_all("g1", &x.t_matmul_mt(&dxg1, th));
                dx.axpy(1.0, &dxg1.matmul_t_mt(g1, th));
            }
            (
                Some(AdapterKind::MetaTt(MetaTtKind::FourPlusOneD)),
                AdapterCache::Tt4p1 { xg1, xgm, ca, ab, bc, mid },
            ) => {
                let [g1, _g2, _g3, g4, g5] = self.p5();
                sink.add_all("g5", &xgm.t_matmul_mt(&dya, th));
                let dxgm = dya.matmul_t_mt(g5, th);
                let dmid = xg1.t_matmul_mt(&dxgm, th);
                let cc = chunk_mat(g4, matrix, r, r);
                sink.add_chunk("g2", layer * r * r, dmid.matmul_t(bc).data());
                sink.add_chunk(
                    "g3",
                    self.task * r * r,
                    ca.t_matmul(&dmid).matmul_t(&cc).data(),
                );
                sink.add_chunk("g4", matrix * r * r, ab.t_matmul(&dmid).data());
                let dxg1 = dxgm.matmul_t(mid);
                sink.add_all("g1", &x.t_matmul_mt(&dxg1, th));
                dx.axpy(1.0, &dxg1.matmul_t_mt(g1, th));
            }
            (
                Some(AdapterKind::MetaTt(MetaTtKind::FiveD)),
                AdapterCache::Tt5 { xg1, xlm, lm, xh },
            ) => {
                let [g1, g2, g3, g4, g5] = self.p5();
                let dh = d / self.heads;
                let n = dy.shape()[0];
                let mut dxlm = Tensor::zeros(&[n, r]);
                for hh in 0..self.heads {
                    let dyh = block(&dya, 0, n, hh * dh, dh);
                    sink.add_all("g5", &xh[hh].t_matmul_mt(&dyh, th));
                    let dxh = dyh.matmul_t_mt(g5, th);
                    sink.add_chunk("g4", hh * r * r, xlm.t_matmul_mt(&dxh, th).data());
                    let g4h = chunk_mat(g4, hh, r, r);
                    dxlm.axpy(1.0, &dxh.matmul_t(&g4h));
                }
                let dlm = xg1.t_matmul_mt(&dxlm, th);
                let g2l = chunk_mat(g2, layer, r, r);
                let g3m = chunk_mat(g3, matrix, r, r);
                sink.add_chunk("g2", layer * r * r, dlm.matmul_t(&g3m).data());
                sink.add_chunk("g3", matrix * r * r, g2l.t_matmul(&dlm).data());
                let dxg1 = dxlm.matmul_t(lm);
                sink.add_all("g1", &x.t_matmul_mt(&dxg1, th));
                dx.axpy(1.0, &dxg1.matmul_t_mt(g1, th));
            }
            (Some(AdapterKind::LoRa), AdapterCache::Lora { xa }) => {
                let (pa, pb) = (&self.params[0], &self.params[1]);
                let idx = layer * self.matrices + matrix;
                let am = chunk_mat(pa, idx, d, r);
                let bm = chunk_mat(pb, idx, r, d);
                sink.add_chunk("lora_b", idx * r * d, xa.t_matmul_mt(&dya, th).data());
                let dxa = dya.matmul_t_mt(&bm, th);
                sink.add_chunk("lora_a", idx * d * r, x.t_matmul_mt(&dxa, th).data());
                dx.axpy(1.0, &dxa.matmul_t_mt(&am, th));
            }
            (Some(AdapterKind::VeRa), AdapterCache::Vera { xa, tb }) => {
                let (fa, fb) = self.vera_frozen.as_ref().expect("vera frozen");
                let idx = layer * self.matrices + matrix;
                let dvec = &self.params[0].data()[idx * r..(idx + 1) * r];
                let bvec = &self.params[1].data()[idx * d..(idx + 1) * d];
                sink.add_chunk("vera_b", idx * d, &colsum_mul(&dya, tb));
                let dtb = mul_cols(&dya, bvec);
                let dt = dtb.matmul_t_mt(fb, th);
                sink.add_chunk("vera_d", idx * r, &colsum_mul(&dt, xa));
                let dxa = mul_cols(&dt, dvec);
                dx.axpy(1.0, &dxa.matmul_t_mt(fa, th));
            }
            (Some(AdapterKind::LoTr), AdapterCache::Lotr { xu, xus, sm }) => {
                let (u, _sall, vmat) = (&self.params[0], &self.params[1], &self.params[2]);
                let idx = layer * self.matrices + matrix;
                sink.add_all("lotr_v", &xus.t_matmul_mt(&dya, th));
                let dxus = dya.matmul_t_mt(vmat, th);
                sink.add_chunk("lotr_s", idx * r * r, xu.t_matmul_mt(&dxus, th).data());
                let dxu = dxus.matmul_t(sm);
                sink.add_all("lotr_u", &x.t_matmul_mt(&dxu, th));
                dx.axpy(1.0, &dxu.matmul_t_mt(u, th));
            }
            (kind, _) => panic!("adapter cache mismatch for {kind:?}"),
        }
    }

    fn p4(&self) -> [&Tensor; 4] {
        [&self.params[0], &self.params[1], &self.params[2], &self.params[3]]
    }

    fn p5(&self) -> [&Tensor; 5] {
        [
            &self.params[0],
            &self.params[1],
            &self.params[2],
            &self.params[3],
            &self.params[4],
        ]
    }
}

enum AdapterCache {
    None,
    Tt4 { xg1: Tensor, xgm: Tensor, mid: Tensor },
    Tt4p1 { xg1: Tensor, xgm: Tensor, ca: Tensor, ab: Tensor, bc: Tensor, mid: Tensor },
    Tt5 { xg1: Tensor, xlm: Tensor, lm: Tensor, xh: Vec<Tensor> },
    Lora { xa: Tensor },
    Vera { xa: Tensor, tb: Tensor },
    Lotr { xu: Tensor, xus: Tensor, sm: Tensor },
}

// ---------------------------------------------------------------------------
// Encoder forward.
// ---------------------------------------------------------------------------

struct LayerCache {
    x_in: Tensor,
    q: Tensor,
    k: Tensor,
    v: Tensor,
    ad_q: AdapterCache,
    ad_v: AdapterCache,
    /// Attention probabilities per (batch · head), each (s × s).
    probs: Vec<Tensor>,
    ctx: Tensor,
    ln1: LnCache,
    x_mid: Tensor,
    u: Tensor,
    g: Tensor,
    ln2: LnCache,
}

struct EncoderCache {
    emb_ln: LnCache,
    layers: Vec<LayerCache>,
}

/// Run the encoder; returns final hidden states (n × d) plus the cache the
/// backward pass consumes. `threads` is the step's worker budget; all
/// parallel splits are along independent rows / (batch, head) pairs so the
/// output is identical for any value.
fn encoder_forward(
    dims: &Dims,
    w: &Weights,
    adapter: &AdapterCtx,
    tokens: &[i32],
    threads: usize,
) -> (Tensor, EncoderCache) {
    let Dims { b, s, n, d, h, dh, f, l, .. } = *dims;
    // Embeddings: token + learned position (row-parallel gather).
    let tok_emb = w.get("tok_emb");
    let pos_emb = w.get("pos_emb");
    let mut x_emb = Tensor::zeros(&[n, d]);
    {
        let xs = SharedSliceMut::new(x_emb.data_mut());
        scope_rows(gate(threads, n * d), n, ROW_BAND, |band| {
            // SAFETY: bands are disjoint row ranges of x_emb.
            let dst = unsafe { xs.range_mut(band.start * d, band.end * d) };
            for i in band.clone() {
                let tok = tokens[i] as usize;
                let pos = i % s;
                let te = &tok_emb.data()[tok * d..(tok + 1) * d];
                let pe = &pos_emb.data()[pos * d..(pos + 1) * d];
                let o = (i - band.start) * d;
                for j in 0..d {
                    dst[o + j] = te[j] + pe[j];
                }
            }
        });
    }
    let (x0, emb_ln) = layer_norm(&x_emb, w.vec("emb_ln_g"), w.vec("emb_ln_b"), threads);

    let inv_sqrt_dh = 1.0 / (dh as f32).sqrt();
    let mut x = x0;
    let mut layers = Vec::with_capacity(l);
    for layer in 0..l {
        let x_in = x;
        // Projections with adapters on Q (m=0) and V (m=1).
        let wq = chunk_mat(w.get("wq"), layer, d, d);
        let wk = chunk_mat(w.get("wk"), layer, d, d);
        let wv = chunk_mat(w.get("wv"), layer, d, d);
        let (dq, ad_q) = adapter.apply(&x_in, layer, 0);
        let (dv, ad_v) = adapter.apply(&x_in, layer, 1);
        let mut q = x_in.matmul_mt(&wq, threads);
        add_row_bias(&mut q, w.row("bq", layer, d));
        q.axpy(1.0, &dq);
        let mut k = x_in.matmul_mt(&wk, threads);
        add_row_bias(&mut k, w.row("bk", layer, d));
        let mut v = x_in.matmul_mt(&wv, threads);
        add_row_bias(&mut v, w.row("bv", layer, d));
        v.axpy(1.0, &dv);

        // Pad-masked multi-head attention: the (batch, head) pairs are
        // independent, so they fan out across workers; each pair's block is
        // computed by one worker and assembled serially in pair order.
        let attn_threads = gate(threads, b * h * s * s * dh);
        let head_blocks = scope_map(attn_threads, b * h, |pair| {
            let (bi, hi) = (pair / h, pair % h);
            let qh = block(&q, bi * s, s, hi * dh, dh);
            let kh = block(&k, bi * s, s, hi * dh, dh);
            let vh = block(&v, bi * s, s, hi * dh, dh);
            let mut scores = qh.matmul_t(&kh).scale(inv_sqrt_dh);
            for key in 0..s {
                if tokens[bi * s + key] == PAD_ID {
                    for query in 0..s {
                        let val = scores.at(query, key) + MASK_NEG;
                        scores.set(query, key, val);
                    }
                }
            }
            // Row-wise stable softmax.
            let mut probs = scores;
            for qi in 0..s {
                let row = &mut probs.data_mut()[qi * s..(qi + 1) * s];
                let mx = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
                let mut z = 0.0f32;
                for v in row.iter_mut() {
                    *v = (*v - mx).exp();
                    z += *v;
                }
                for v in row.iter_mut() {
                    *v /= z;
                }
            }
            let ctx_h = probs.matmul(&vh);
            (probs, ctx_h)
        });
        let mut ctx = Tensor::zeros(&[n, d]);
        let mut probs_all = Vec::with_capacity(b * h);
        for (pair, (probs, ctx_h)) in head_blocks.into_iter().enumerate() {
            let (bi, hi) = (pair / h, pair % h);
            add_block(&mut ctx, bi * s, hi * dh, &ctx_h);
            probs_all.push(probs);
        }
        let wo = chunk_mat(w.get("wo"), layer, d, d);
        let mut attn_out = ctx.matmul_mt(&wo, threads);
        add_row_bias(&mut attn_out, w.row("bo", layer, d));
        let (x_mid, ln1) = layer_norm(
            &x_in.add(&attn_out),
            w.row("ln1_g", layer, d),
            w.row("ln1_b", layer, d),
            threads,
        );

        // GELU MLP (tanh GELU is the most expensive elementwise op in the
        // step — band-parallel over rows).
        let w1 = chunk_mat(w.get("w1"), layer, d, f);
        let w2 = chunk_mat(w.get("w2"), layer, f, d);
        let mut u = x_mid.matmul_mt(&w1, threads);
        add_row_bias(&mut u, w.row("b1", layer, f));
        let mut g = u.clone();
        {
            let gs = SharedSliceMut::new(g.data_mut());
            scope_rows(gate(threads, n * f), n, ROW_BAND, |band| {
                // SAFETY: bands are disjoint row ranges of g.
                let dst = unsafe { gs.range_mut(band.start * f, band.end * f) };
                for v in dst.iter_mut() {
                    *v = gelu(*v);
                }
            });
        }
        let mut m_out = g.matmul_mt(&w2, threads);
        add_row_bias(&mut m_out, w.row("b2", layer, d));
        let (x_out, ln2) = layer_norm(
            &x_mid.add(&m_out),
            w.row("ln2_g", layer, d),
            w.row("ln2_b", layer, d),
            threads,
        );

        layers.push(LayerCache {
            x_in,
            q,
            k,
            v,
            ad_q,
            ad_v,
            probs: probs_all,
            ctx,
            ln1,
            x_mid,
            u,
            g,
            ln2,
        });
        x = x_out;
    }
    (x, EncoderCache { emb_ln, layers })
}

// ---------------------------------------------------------------------------
// Encoder backward.
// ---------------------------------------------------------------------------

/// Reverse pass through the encoder. `d_hidden` is ∂L/∂(final hidden states).
/// Adapter grads always flow into `sink`; encoder-weight grads only when
/// `train_encoder` (full FT / pretraining).
#[allow(clippy::too_many_arguments)]
fn encoder_backward(
    dims: &Dims,
    w: &Weights,
    adapter: &AdapterCtx,
    tokens: &[i32],
    cache: &EncoderCache,
    d_hidden: Tensor,
    sink: &mut GradSink,
    train_encoder: bool,
    threads: usize,
) {
    let Dims { b, s, n, d, h, dh, f, l, .. } = *dims;
    let inv_sqrt_dh = 1.0 / (dh as f32).sqrt();
    let mut dx = d_hidden; // gradient w.r.t. the current layer's output
    for layer in (0..l).rev() {
        let lc = &cache.layers[layer];

        // --- LN2 over (x_mid + m_out).
        let mut dg_buf = vec![0.0f32; d];
        let mut db_buf = vec![0.0f32; d];
        let d_res2 = layer_norm_backward(
            &dx,
            &lc.ln2,
            w.row("ln2_g", layer, d),
            train_encoder.then_some((&mut dg_buf[..], &mut db_buf[..])),
            threads,
        );
        if train_encoder {
            sink.add_chunk("ln2_g", layer * d, &dg_buf);
            sink.add_chunk("ln2_b", layer * d, &db_buf);
        }

        // --- MLP: m_out = gelu(x_mid·w1 + b1)·w2 + b2.
        let w1 = chunk_mat(w.get("w1"), layer, d, f);
        let w2 = chunk_mat(w.get("w2"), layer, f, d);
        let d_mout = &d_res2; // residual: d(m_out) = d_res2, d(x_mid) += d_res2
        if train_encoder {
            sink.add_chunk("w2", layer * f * d, lc.g.t_matmul_mt(d_mout, threads).data());
            sink.add_chunk("b2", layer * d, &colsum(d_mout));
        }
        let mut dgelu = d_mout.matmul_t_mt(&w2, threads); // (n, f)
        {
            let dgs = SharedSliceMut::new(dgelu.data_mut());
            let us = lc.u.data();
            scope_rows(gate(threads, n * f), n, ROW_BAND, |band| {
                // SAFETY: bands are disjoint row ranges of dgelu.
                let dst = unsafe { dgs.range_mut(band.start * f, band.end * f) };
                for (dv, &uv) in dst.iter_mut().zip(&us[band.start * f..band.end * f]) {
                    *dv *= gelu_prime(uv);
                }
            });
        }
        if train_encoder {
            sink.add_chunk("w1", layer * d * f, lc.x_mid.t_matmul_mt(&dgelu, threads).data());
            sink.add_chunk("b1", layer * f, &colsum(&dgelu));
        }
        let mut d_xmid = d_res2.clone();
        d_xmid.axpy(1.0, &dgelu.matmul_t_mt(&w1, threads));

        // --- LN1 over (x_in + attn_out).
        let mut dg_buf = vec![0.0f32; d];
        let mut db_buf = vec![0.0f32; d];
        let d_res1 = layer_norm_backward(
            &d_xmid,
            &lc.ln1,
            w.row("ln1_g", layer, d),
            train_encoder.then_some((&mut dg_buf[..], &mut db_buf[..])),
            threads,
        );
        if train_encoder {
            sink.add_chunk("ln1_g", layer * d, &dg_buf);
            sink.add_chunk("ln1_b", layer * d, &db_buf);
        }

        // --- Output projection: attn_out = ctx·wo + bo.
        let wo = chunk_mat(w.get("wo"), layer, d, d);
        if train_encoder {
            sink.add_chunk("wo", layer * d * d, lc.ctx.t_matmul_mt(&d_res1, threads).data());
            sink.add_chunk("bo", layer * d, &colsum(&d_res1));
        }
        let d_ctx = d_res1.matmul_t_mt(&wo, threads);

        // --- Attention backward per (batch, head): independent pairs fan
        // out; their dq/dk/dv blocks are assembled serially in pair order.
        let attn_threads = gate(threads, b * h * s * s * dh);
        let grads = scope_map(attn_threads, b * h, |pair| {
            let (bi, hi) = (pair / h, pair % h);
            let probs = &lc.probs[pair];
            let qh = block(&lc.q, bi * s, s, hi * dh, dh);
            let kh = block(&lc.k, bi * s, s, hi * dh, dh);
            let vh = block(&lc.v, bi * s, s, hi * dh, dh);
            let d_ctx_h = block(&d_ctx, bi * s, s, hi * dh, dh);
            let d_probs = d_ctx_h.matmul_t(&vh); // (s, s)
            let d_vh = probs.t_matmul(&d_ctx_h);
            // Softmax backward, row-wise.
            let mut d_scores = Tensor::zeros(&[s, s]);
            for qi in 0..s {
                let pr = &probs.data()[qi * s..(qi + 1) * s];
                let dp = &d_probs.data()[qi * s..(qi + 1) * s];
                let dot: f32 = pr.iter().zip(dp).map(|(&p, &g)| p * g).sum();
                for key in 0..s {
                    d_scores.data_mut()[qi * s + key] = pr[key] * (dp[key] - dot);
                }
            }
            let d_qh = d_scores.matmul(&kh).scale(inv_sqrt_dh);
            let d_kh = d_scores.t_matmul(&qh).scale(inv_sqrt_dh);
            (d_qh, d_kh, d_vh)
        });
        let mut dq = Tensor::zeros(&[n, d]);
        let mut dk = Tensor::zeros(&[n, d]);
        let mut dv = Tensor::zeros(&[n, d]);
        for (pair, (d_qh, d_kh, d_vh)) in grads.into_iter().enumerate() {
            let (bi, hi) = (pair / h, pair % h);
            add_block(&mut dq, bi * s, hi * dh, &d_qh);
            add_block(&mut dk, bi * s, hi * dh, &d_kh);
            add_block(&mut dv, bi * s, hi * dh, &d_vh);
        }

        // --- Projections + adapters back to the layer input.
        let wq = chunk_mat(w.get("wq"), layer, d, d);
        let wk = chunk_mat(w.get("wk"), layer, d, d);
        let wv = chunk_mat(w.get("wv"), layer, d, d);
        let mut d_xin = d_res1; // residual branch
        d_xin.axpy(1.0, &dq.matmul_t_mt(&wq, threads));
        d_xin.axpy(1.0, &dk.matmul_t_mt(&wk, threads));
        d_xin.axpy(1.0, &dv.matmul_t_mt(&wv, threads));
        if train_encoder {
            sink.add_chunk("wq", layer * d * d, lc.x_in.t_matmul_mt(&dq, threads).data());
            sink.add_chunk("bq", layer * d, &colsum(&dq));
            sink.add_chunk("wk", layer * d * d, lc.x_in.t_matmul_mt(&dk, threads).data());
            sink.add_chunk("bk", layer * d, &colsum(&dk));
            sink.add_chunk("wv", layer * d * d, lc.x_in.t_matmul_mt(&dv, threads).data());
            sink.add_chunk("bv", layer * d, &colsum(&dv));
        }
        adapter.backward(&lc.x_in, layer, 0, &lc.ad_q, &dq, &mut d_xin, sink);
        adapter.backward(&lc.x_in, layer, 1, &lc.ad_v, &dv, &mut d_xin, sink);
        dx = d_xin;
    }

    // --- Embedding LN + scatter.
    let mut dg_buf = vec![0.0f32; d];
    let mut db_buf = vec![0.0f32; d];
    let d_emb = layer_norm_backward(
        &dx,
        &cache.emb_ln,
        w.vec("emb_ln_g"),
        train_encoder.then_some((&mut dg_buf[..], &mut db_buf[..])),
        threads,
    );
    if train_encoder {
        sink.add_chunk("emb_ln_g", 0, &dg_buf);
        sink.add_chunk("emb_ln_b", 0, &db_buf);
        for i in 0..n {
            let tok = tokens[i] as usize;
            let pos = i % s;
            let row = &d_emb.data()[i * d..(i + 1) * d];
            sink.add_chunk("tok_emb", tok * d, row);
            sink.add_chunk("pos_emb", pos * d, row);
        }
    }
}

// ---------------------------------------------------------------------------
// Task head + losses.
// ---------------------------------------------------------------------------

/// CLS-pooled logits through the frozen per-task head.
fn head_logits(dims: &Dims, w: &Weights, hidden: &Tensor, task: usize) -> Tensor {
    let Dims { b, s, d, classes, .. } = *dims;
    let cls_w = chunk_mat(w.get("cls_w"), task, d, classes);
    let cls_b = &w.get("cls_b").data()[task * classes..(task + 1) * classes];
    let mut pooled = Tensor::zeros(&[b, d]);
    for bi in 0..b {
        let src = &hidden.data()[bi * s * d..bi * s * d + d]; // CLS row
        pooled.data_mut()[bi * d..(bi + 1) * d].copy_from_slice(src);
    }
    let mut logits = pooled.matmul(&cls_w);
    add_row_bias(&mut logits, cls_b);
    logits
}

/// Weighted task loss + ∂loss/∂logits (CE for classification, MSE on
/// score/5 for the regression analogue).
fn task_loss_grad(
    logits: &Tensor,
    batch: &Batch,
    classes: usize,
) -> (f32, Tensor) {
    let b = batch.batch_size;
    let wsum: f32 = batch.weights.iter().sum::<f32>().max(1e-6);
    let mut dlogits = Tensor::zeros(&[b, classes]);
    let mut loss = 0.0f64;
    if classes == 1 {
        for i in 0..b {
            let pred = logits.at(i, 0);
            let target = batch.scores[i] / 5.0;
            let wgt = batch.weights[i];
            loss += ((pred - target) * (pred - target) * wgt) as f64;
            dlogits.set(i, 0, 2.0 * (pred - target) * wgt / wsum);
        }
    } else {
        for i in 0..b {
            let row = &logits.data()[i * classes..(i + 1) * classes];
            let mx = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
            let z: f32 = row.iter().map(|&v| (v - mx).exp()).sum();
            let lz = z.ln() + mx;
            let label = batch.labels[i] as usize;
            let wgt = batch.weights[i];
            loss += ((lz - row[label]) * wgt) as f64;
            for c in 0..classes {
                let p = (row[c] - lz).exp();
                let ind = if c == label { 1.0 } else { 0.0 };
                dlogits.set(i, c, (p - ind) * wgt / wsum);
            }
        }
    }
    ((loss / wsum as f64) as f32, dlogits)
}

// ---------------------------------------------------------------------------
// Public step entry points (used by the reference backend).
// ---------------------------------------------------------------------------

fn validate_batch(entry: &ArtifactEntry, batch_size: usize, seq_len: usize) -> Result<()> {
    if batch_size != entry.spec.batch || seq_len != entry.spec.seq {
        bail!(
            "batch shape ({batch_size}, {seq_len}) does not match spec {} ({}, {})",
            entry.spec.stem(),
            entry.spec.batch,
            entry.spec.seq
        );
    }
    Ok(())
}

/// One fwd+bwd fine-tuning step. Returns (loss, grads in trainable order).
/// `threads` is the worker budget; results are identical for any value.
pub fn train_step(
    entry: &ArtifactEntry,
    frozen: &HashMap<String, Tensor>,
    trainable: &[Tensor],
    batch: &Batch,
    task_id: i32,
    alpha: f32,
    threads: usize,
) -> Result<(f32, Vec<Tensor>)> {
    validate_batch(entry, batch.batch_size, batch.seq_len)?;
    let dims = dims_of(entry)?;
    let task = task_id as usize;
    let w = Weights::build(entry, frozen, trainable)?;
    let adapter = AdapterCtx::new(entry, trainable, alpha, task, threads)?;
    let train_encoder = entry.spec.adapter == "full";

    let (hidden, cache) = encoder_forward(&dims, &w, &adapter, &batch.tokens, threads);
    let logits = head_logits(&dims, &w, &hidden, task);
    let (loss, dlogits) = task_loss_grad(&logits, batch, dims.classes);

    // Head is frozen: only ∂/∂pooled flows back, scattered into CLS rows.
    let cls_w = chunk_mat(w.get("cls_w"), task, dims.d, dims.classes);
    let d_pooled = dlogits.matmul_t(&cls_w); // (b, d)
    let mut d_hidden = Tensor::zeros(&[dims.n, dims.d]);
    for bi in 0..dims.b {
        let dst = bi * dims.s * dims.d;
        let src = &d_pooled.data()[bi * dims.d..(bi + 1) * dims.d];
        d_hidden.data_mut()[dst..dst + dims.d].copy_from_slice(src);
    }

    let mut sink = GradSink::new(entry.trainable_inputs());
    encoder_backward(
        &dims,
        &w,
        &adapter,
        &batch.tokens,
        &cache,
        d_hidden,
        &mut sink,
        train_encoder,
        threads,
    );
    Ok((loss, sink.into_vec()))
}

/// One fwd (eval) step. Returns logits `[batch, classes]`.
pub fn eval_step(
    entry: &ArtifactEntry,
    frozen: &HashMap<String, Tensor>,
    trainable: &[Tensor],
    batch: &Batch,
    task_id: i32,
    alpha: f32,
    threads: usize,
) -> Result<Tensor> {
    validate_batch(entry, batch.batch_size, batch.seq_len)?;
    let dims = dims_of(entry)?;
    let task = task_id as usize;
    let w = Weights::build(entry, frozen, trainable)?;
    let adapter = AdapterCtx::new(entry, trainable, alpha, task, threads)?;
    let (hidden, _cache) = encoder_forward(&dims, &w, &adapter, &batch.tokens, threads);
    Ok(head_logits(&dims, &w, &hidden, task))
}

/// One MLM pretraining step over all encoder weights (weight-tied output
/// head: logits = h · tok_embᵀ). Returns (loss, grads).
pub fn pretrain_step(
    entry: &ArtifactEntry,
    trainable: &[Tensor],
    batch: &MlmBatch,
    threads: usize,
) -> Result<(f32, Vec<Tensor>)> {
    validate_batch(entry, batch.batch_size, batch.seq_len)?;
    let dims = dims_of(entry)?;
    let empty = HashMap::new();
    let w = Weights::build(entry, &empty, trainable)?;
    let adapter = AdapterCtx {
        kind: None,
        params: trainable,
        alpha: 0.0,
        task: 0,
        rank: 0,
        heads: dims.h,
        matrices: 2,
        d: dims.d,
        threads,
        vera_frozen: None,
    };
    let (hidden, cache) = encoder_forward(&dims, &w, &adapter, &batch.tokens, threads);

    // Weight-tied MLM head over every position. The vocab softmax is the
    // most expensive row loop of the whole pretrain step: rows fan out
    // across workers; the scalar loss reduces serially in row order so the
    // sum never depends on the thread count.
    let tok_emb = w.get("tok_emb"); // (v, d)
    let logits = hidden.matmul_t_mt(tok_emb, threads); // (n, v)
    let wsum: f32 = batch.weights.iter().sum::<f32>().max(1e-6);
    let (n, v) = (dims.n, dims.v);
    let mut dlogits = Tensor::zeros(&[n, v]);
    let mut row_loss = vec![0.0f64; n];
    {
        let dls = SharedSliceMut::new(dlogits.data_mut());
        let rls = SharedSliceMut::new(&mut row_loss);
        scope_rows(gate(threads, n * v), n, ROW_BAND, |band| {
            // SAFETY: bands are disjoint row ranges of dlogits / row_loss.
            let d_band = unsafe { dls.range_mut(band.start * v, band.end * v) };
            let l_band = unsafe { rls.range_mut(band.start, band.end) };
            for i in band.clone() {
                let wgt = batch.weights[i];
                let row = &logits.data()[i * v..(i + 1) * v];
                let target = batch.targets[i] as usize;
                let mx = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
                let z: f32 = row.iter().map(|&x| (x - mx).exp()).sum();
                let lz = z.ln() + mx;
                if wgt != 0.0 {
                    l_band[i - band.start] = ((lz - row[target]) * wgt) as f64;
                }
                let scale = wgt / wsum;
                if scale != 0.0 {
                    let drow = &mut d_band[(i - band.start) * v..(i - band.start + 1) * v];
                    for c in 0..v {
                        let p = (row[c] - lz).exp();
                        drow[c] = p * scale;
                    }
                    drow[target] -= scale;
                }
            }
        });
    }
    let loss: f64 = row_loss.iter().sum(); // fixed row order
    let loss = (loss / wsum as f64) as f32;

    let mut sink = GradSink::new(entry.trainable_inputs());
    // Head: dh = dlogits · tok_emb ; d tok_emb += dlogitsᵀ · hidden.
    let d_hidden = dlogits.matmul_mt(tok_emb, threads);
    sink.add_all("tok_emb", &dlogits.t_matmul_mt(&hidden, threads));
    encoder_backward(
        &dims,
        &w,
        &adapter,
        &batch.tokens,
        &cache,
        d_hidden,
        &mut sink,
        true,
        threads,
    );
    Ok((loss, sink.into_vec()))
}

/// Raw positional apply (serving hot path): `y = x·g1·mid·g4` (TT families)
/// or `y = x·a·b` (LoRA), α = 1 as baked into the AOT apply artifacts.
pub fn apply_step(
    entry: &ArtifactEntry,
    inputs: &[Tensor],
    threads: usize,
) -> Result<Vec<Tensor>> {
    if inputs.len() != entry.inputs.len() {
        bail!(
            "apply expects {} inputs, got {}",
            entry.inputs.len(),
            inputs.len()
        );
    }
    for (t, io) in inputs.iter().zip(&entry.inputs) {
        if t.shape() != &io.shape[..] {
            bail!(
                "apply input '{}': shape {:?}, spec wants {:?}",
                io.name,
                t.shape(),
                io.shape
            );
        }
    }
    let y = if entry.spec.adapter == "lora" {
        inputs[0]
            .matmul_mt(&inputs[1], threads)
            .matmul_mt(&inputs[2], threads)
    } else {
        inputs[0]
            .matmul_mt(&inputs[1], threads)
            .matmul_mt(&inputs[2], threads)
            .matmul_mt(&inputs[3], threads)
    };
    Ok(vec![y])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::assert_close;

    #[test]
    fn gelu_matches_finite_difference() {
        let eps = 1e-3f32;
        for &u in &[-3.0f32, -1.0, -0.1, 0.0, 0.5, 2.0] {
            let fd = (gelu(u + eps) - gelu(u - eps)) / (2.0 * eps);
            let an = gelu_prime(u);
            assert!((fd - an).abs() < 1e-3, "u={u}: fd {fd} vs {an}");
        }
        // Known values: gelu(0) = 0, gelu(∞) → identity.
        assert_eq!(gelu(0.0), 0.0);
        assert!((gelu(10.0) - 10.0).abs() < 1e-4);
    }

    #[test]
    fn layer_norm_backward_matches_finite_difference() {
        let mut rng = Pcg64::new(9);
        let x = Tensor::randn(&[3, 8], 1.0, &mut rng);
        let gamma: Vec<f32> = (0..8).map(|j| 1.0 + 0.1 * j as f32).collect();
        let beta = vec![0.05f32; 8];
        let dy = Tensor::randn(&[3, 8], 1.0, &mut rng);
        let (_, cache) = layer_norm(&x, &gamma, &beta, 1);
        let dx = layer_norm_backward(&dy, &cache, &gamma, None, 1);
        // Scalar objective: L = Σ y ∘ dy; check a handful of coordinates.
        let loss = |xp: &Tensor| -> f32 {
            let (y, _) = layer_norm(xp, &gamma, &beta, 1);
            y.data().iter().zip(dy.data()).map(|(&a, &b)| a * b).sum()
        };
        let eps = 1e-3;
        for &(i, j) in &[(0usize, 0usize), (1, 3), (2, 7)] {
            let mut xp = x.clone();
            xp.data_mut()[i * 8 + j] += eps;
            let mut xm = x.clone();
            xm.data_mut()[i * 8 + j] -= eps;
            let fd = (loss(&xp) - loss(&xm)) / (2.0 * eps);
            let an = dx.data()[i * 8 + j];
            assert!((fd - an).abs() < 2e-2, "({i},{j}): fd {fd} vs {an}");
        }
    }

    #[test]
    fn block_helpers_roundtrip() {
        let mut rng = Pcg64::new(2);
        let m = Tensor::randn(&[6, 10], 1.0, &mut rng);
        let blk = block(&m, 2, 3, 4, 5);
        assert_eq!(blk.shape(), &[3, 5]);
        assert_eq!(blk.at(0, 0), m.at(2, 4));
        assert_eq!(blk.at(2, 4), m.at(4, 8));
        let mut dst = Tensor::zeros(&[6, 10]);
        add_block(&mut dst, 2, 4, &blk);
        assert_eq!(block(&dst, 2, 3, 4, 5), blk);
        assert_eq!(dst.at(0, 0), 0.0);
    }

    #[test]
    fn colsum_and_mul_cols() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(colsum(&t), vec![5., 7., 9.]);
        let m = mul_cols(&t, &[2.0, 0.0, 1.0]);
        assert_eq!(m.data(), &[2., 0., 3., 8., 0., 6.]);
        assert_close(
            &colsum_mul(&t, &t),
            &[17.0, 29.0, 45.0],
            1e-6,
            1e-6,
            "colsum_mul",
        );
    }
}
