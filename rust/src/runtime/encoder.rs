//! Pure-rust forward + backward of the adapted transformer encoder.
//!
//! This is the compute core of the reference backend (`--backend ref`): a
//! faithful re-implementation of `python/compile/model.py` on top of
//! [`crate::tensor`] — RoBERTa-style post-LN encoder, tanh-GELU MLP,
//! learned positions, pad-masked attention, adapters on the Q (m=0) and V
//! (m=1) projections, CLS pooling through frozen per-task heads, weighted
//! CE / MSE task losses, and the weight-tied MLM pretraining objective.
//!
//! The backward pass is hand-derived reverse mode over the same graph: the
//! forward caches layer activations (`LayerCache`), the backward walks them
//! in reverse, accumulating gradients by *name + contiguous chunk* into a
//! [`GradSink`] keyed by the artifact's trainable layout. Because every
//! structural axis (layer, matrix, head, task) is the leading axis of its
//! array, all sliced accumulations are contiguous chunks — no strided
//! scatter is ever needed. Gradients are checked against central finite
//! differences in `tests/ref_backend.rs`.
//!
//! **Zero-allocation hot path (PR 3).** Every intermediate tensor of a step
//! is checked out of the bound step's [`crate::tensor::Workspace`] arena
//! (inside [`StepScratch`]) and recycled at the end of the step, so after a
//! one-step warmup the steady-state train loop performs no heap allocations
//! (`tests/alloc_regression.rs`). Three step-level GEMM savings ride on the
//! same refactor:
//!
//! * **Shared TT prefix** — the first adapter GEMM (`x·G1`, `x·U`, `x·A`)
//!   is identical for the Q and V applies of a layer; `apply_pair` /
//!   `backward_pair` compute it once and, on the backward side, accumulate
//!   the two matrices' prefix cotangents before the single `xᵀ·(…)` /
//!   `(…)·G1ᵀ` projection pair.
//! * **Per-step middle products** — the tiny r×r `mid` factors depend only
//!   on the parameters, not the batch, so [`AdapterPre`] computes every
//!   (layer, matrix) product once per step instead of once per apply.
//! **Packed GEMMs (PR 4).** Every matmul in a step runs the packed
//! register-tiled kernel family (`tensor::ops`). Workspace-reachable call
//! sites hand the kernels the arena's aligned pack scratch
//! (`Workspace::packs`), so panel packing allocates nothing in steady
//! state; the per-(batch, head) attention GEMMs execute *inside* parallel
//! regions where the arena is unreachable and use the kernels'
//! per-worker-thread `*_into_local` scratch instead (persistent pool
//! workers keep it warm). Packing preserves the per-element k-ascending
//! accumulation order, so step results are bit-identical to the PR 3
//! blocked kernels. The kernel's pack step also absorbs operand
//! transposes, which retired PR 3's bind-time `Packed` transposed copies
//! of the frozen weights: backward `dY·Wᵀ` runs `matmul_t` directly on the
//! forward-orientation chunk at full speed (and, per the long-standing
//! contract, the exact same bits), halving per-bound-step frozen-weight
//! memory.
//!
//! **Parallel execution.** Every step entry point takes a thread budget
//! (plumbed from `--threads` via the backend). Inside a step the work is
//! data-parallel along structurally independent axes: the big GEMMs split
//! output row bands (`tensor::ops`), attention fans out per (batch, head)
//! over flat pair-major buffers, and the LayerNorm / GELU / MLM-softmax row
//! loops split row bands. Cross-row *reductions* (bias column sums, LN γ/β
//! grads, the scalar loss) always run in a fixed serial order, so 1-thread
//! and N-thread executions are **bit-identical**, with the arena on or off
//! (`tests/determinism.rs`).

use super::registry::{ArtifactEntry, IoSpec};
use crate::adapters::AdapterKind;
use crate::config::ModelPreset;
use crate::data::{Batch, MlmBatch};
use crate::tensor::{
    add_into, axpy_into, matmul_into, matmul_into_local, matmul_into_prepacked_any,
    matmul_t_into, matmul_t_into_local, scale_into, softmax_rows_into, t_matmul_into,
    t_matmul_into_local, DtypeKind, PackedBAny, Tensor, Workspace,
};
use crate::tt::MetaTtKind;
use crate::util::rng::Pcg64;
use crate::util::threadpool::{scope_for, scope_rows, SharedSliceMut};
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::Arc;

const PAD_ID: i32 = 0;
const LN_EPS: f32 = 1e-5;
const MASK_NEG: f32 = -1e9;

/// Minimum elementwise work (elements touched) for a row loop to go
/// parallel; below it region dispatch costs more than the loop.
const PAR_MIN_ELEMS: usize = 1 << 14;

/// Minimum rows per band for the row-parallel loops.
const ROW_BAND: usize = 16;

/// Gate a thread budget on the amount of work: serial below the threshold.
fn gate(threads: usize, work: usize) -> usize {
    crate::util::threadpool::gated_threads(threads, work, PAR_MIN_ELEMS)
}

// ---------------------------------------------------------------------------
// Small dense helpers.
// ---------------------------------------------------------------------------

/// `t[i, :] += bias` for every row.
fn add_row_bias(t: &mut Tensor, bias: &[f32]) {
    let cols = t.shape()[1];
    debug_assert_eq!(cols, bias.len());
    for row in t.data_mut().chunks_exact_mut(cols) {
        for (v, b) in row.iter_mut().zip(bias) {
            *v += *b;
        }
    }
}

/// Column sums of a matrix accumulated into `out` (rows in ascending order,
/// so the reduction never depends on the thread count).
fn colsum_acc(t: &Tensor, out: &mut [f32]) {
    let cols = t.shape()[1];
    debug_assert_eq!(cols, out.len());
    for row in t.data().chunks_exact(cols) {
        for (o, v) in out.iter_mut().zip(row) {
            *o += *v;
        }
    }
}

/// Elementwise product with a per-column vector into a workspace tensor:
/// `out[i, j] = t[i, j] * v[j]`.
fn mul_cols_ws(ws: &mut Workspace, t: &Tensor, v: &[f32]) -> Tensor {
    let cols = t.shape()[1];
    debug_assert_eq!(cols, v.len());
    let mut out = ws.take(t.shape());
    for (orow, trow) in out
        .data_mut()
        .chunks_exact_mut(cols)
        .zip(t.data().chunks_exact(cols))
    {
        for ((o, &x), &s) in orow.iter_mut().zip(trow).zip(v) {
            *o = x * s;
        }
    }
    out
}

/// `dst[i, j] += t[i, j] * v[j]` (per-column scaling, accumulated).
fn acc_mul_cols(dst: &mut Tensor, t: &Tensor, v: &[f32]) {
    let cols = t.shape()[1];
    debug_assert_eq!(dst.shape(), t.shape());
    for (drow, trow) in dst
        .data_mut()
        .chunks_exact_mut(cols)
        .zip(t.data().chunks_exact(cols))
    {
        for ((o, &x), &s) in drow.iter_mut().zip(trow).zip(v) {
            *o += x * s;
        }
    }
}

/// `dst[i, j] += s · (t[i, j] * v[j])` — the VeRA delta application. The
/// inner product is rounded before the scale so the result matches the
/// historical two-step (`mul_cols` then scaled axpy) form bit-for-bit.
fn acc_mul_cols_scaled(dst: &mut Tensor, t: &Tensor, v: &[f32], s: f32) {
    let cols = t.shape()[1];
    debug_assert_eq!(dst.shape(), t.shape());
    for (drow, trow) in dst
        .data_mut()
        .chunks_exact_mut(cols)
        .zip(t.data().chunks_exact(cols))
    {
        for ((o, &x), &c) in drow.iter_mut().zip(trow).zip(v) {
            let z = x * c;
            *o += s * z;
        }
    }
}

/// Column sums of the elementwise product of two matrices, accumulated:
/// `out[j] += Σ_i a[i,j]·b[i,j]` (rows ascending — fixed reduction order).
fn colsum_mul_acc(a: &Tensor, b: &Tensor, out: &mut [f32]) {
    debug_assert_eq!(a.shape(), b.shape());
    let cols = a.shape()[1];
    debug_assert_eq!(cols, out.len());
    for (ra, rb) in a.data().chunks_exact(cols).zip(b.data().chunks_exact(cols)) {
        for (j, o) in out.iter_mut().enumerate() {
            *o += ra[j] * rb[j];
        }
    }
}

/// Copy rows `[row0, row0+nrows)` × cols `[col0, col0+ncols)` of a matrix
/// into a workspace tensor.
fn copy_block(
    ws: &mut Workspace,
    m: &Tensor,
    row0: usize,
    nrows: usize,
    col0: usize,
    ncols: usize,
) -> Tensor {
    let cols = m.shape()[1];
    let mut out = ws.take(&[nrows, ncols]);
    for i in 0..nrows {
        let src = (row0 + i) * cols + col0;
        out.data_mut()[i * ncols..(i + 1) * ncols]
            .copy_from_slice(&m.data()[src..src + ncols]);
    }
    out
}

/// `dst[row0.., col0..] += s·src` for a (nrows × ncols) block; each product
/// is rounded before the add (matches the historical scale-then-axpy form).
fn add_block_scaled(dst: &mut Tensor, row0: usize, col0: usize, src: &Tensor, s: f32) {
    let (nrows, ncols) = (src.shape()[0], src.shape()[1]);
    let cols = dst.shape()[1];
    for i in 0..nrows {
        let d0 = (row0 + i) * cols + col0;
        let drow = &mut dst.data_mut()[d0..d0 + ncols];
        let srow = &src.data()[i * ncols..(i + 1) * ncols];
        for (o, &x) in drow.iter_mut().zip(srow) {
            *o += s * x;
        }
    }
}

// tanh-approximate GELU (jax.nn.gelu default) and its derivative.
const GELU_C: f32 = 0.797_884_6; // sqrt(2/π)
const GELU_K: f32 = 0.044_715;

fn gelu(u: f32) -> f32 {
    0.5 * u * (1.0 + (GELU_C * (u + GELU_K * u * u * u)).tanh())
}

fn gelu_prime(u: f32) -> f32 {
    let w = GELU_C * (u + GELU_K * u * u * u);
    let t = w.tanh();
    0.5 * (1.0 + t) + 0.5 * u * (1.0 - t * t) * GELU_C * (1.0 + 3.0 * GELU_K * u * u)
}

/// `gelu(u)` into a workspace tensor, row-band-parallel.
fn gelu_ws(ws: &mut Workspace, u: &Tensor, threads: usize) -> Tensor {
    let (n, f) = (u.shape()[0], u.shape()[1]);
    let mut g = ws.take(&[n, f]);
    {
        let us = u.data();
        let gs = SharedSliceMut::new(g.data_mut());
        scope_rows(gate(threads, n * f), n, ROW_BAND, |band| {
            // SAFETY: bands are disjoint row ranges of g.
            let dst = unsafe { gs.range_mut(band.start * f, band.end * f) };
            for (o, &x) in dst.iter_mut().zip(&us[band.start * f..band.end * f]) {
                *o = gelu(x);
            }
        });
    }
    g
}

// ---------------------------------------------------------------------------
// Workspace-backed GEMM shorthands.
// ---------------------------------------------------------------------------

/// `a · b` into a workspace tensor.
fn mm(ws: &mut Workspace, a: &Tensor, b: &Tensor, threads: usize) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let n = b.shape()[b.ndim() - 1];
    debug_assert_eq!(b.len(), k * n);
    let mut out = ws.take(&[m, n]);
    matmul_into(a.data(), b.data(), out.data_mut(), m, k, n, threads, ws.packs());
    out
}

/// `a · Wᵀ` into a workspace tensor, for a layer-chunked weight in its
/// forward orientation. The packed kernel's B-pack absorbs the transpose
/// (contiguous source-row reads), so no pre-transposed copy is ever needed.
fn mm_wt(
    ws: &mut Workspace,
    a: &Tensor,
    w_chunk: &[f32],
    out_cols: usize,
    threads: usize,
) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    debug_assert_eq!(w_chunk.len(), out_cols * k);
    let mut out = ws.take(&[m, out_cols]);
    matmul_t_into(a.data(), w_chunk, out.data_mut(), m, k, out_cols, threads, ws.packs());
    out
}

/// `dst += a · Wᵀ` accumulated in place (the kernels accumulate into their
/// output, so no temporary is needed). `ws` supplies the pack scratch.
fn acc_mm_wt(
    dst: &mut Tensor,
    a: &Tensor,
    w_chunk: &[f32],
    out_cols: usize,
    threads: usize,
    ws: &mut Workspace,
) {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    debug_assert_eq!(dst.len(), m * out_cols);
    debug_assert_eq!(w_chunk.len(), out_cols * k);
    matmul_t_into(a.data(), w_chunk, dst.data_mut(), m, k, out_cols, threads, ws.packs());
}

/// `s · t` into a workspace tensor.
fn scale_ws(ws: &mut Workspace, t: &Tensor, s: f32) -> Tensor {
    let mut out = ws.take(t.shape());
    for (o, &x) in out.data_mut().iter_mut().zip(t.data()) {
        *o = s * x;
    }
    out
}

/// `a + b` into a workspace tensor.
fn add_ws(ws: &mut Workspace, a: &Tensor, b: &Tensor) -> Tensor {
    debug_assert_eq!(a.shape(), b.shape());
    let mut out = ws.take(a.shape());
    add_into(a.data(), b.data(), out.data_mut());
    out
}

// ---------------------------------------------------------------------------
// LayerNorm with cached normalization state.
// ---------------------------------------------------------------------------

struct LnCache {
    /// Normalized input (x - μ)/σ, needed by both the output and the grads.
    xhat: Tensor,
    /// 1/σ per row (workspace-backed vector).
    inv_std: Tensor,
}

impl LnCache {
    fn recycle_into(self, ws: &mut Workspace) {
        ws.recycle(self.xhat);
        ws.recycle(self.inv_std);
    }
}

/// `y = (x - μ)/sqrt(var + ε) · g + b` per row (biased variance, as jnp.var).
/// Rows are independent and band-split across `threads`; each row's stats
/// are computed by exactly one worker, so thread count never changes bits.
fn layer_norm(
    x: &Tensor,
    gamma: &[f32],
    beta: &[f32],
    threads: usize,
    ws: &mut Workspace,
) -> (Tensor, LnCache) {
    let (n, d) = (x.shape()[0], x.shape()[1]);
    let mut xhat = ws.take(&[n, d]);
    let mut y = ws.take(&[n, d]);
    let mut inv_std = ws.take(&[n]);
    {
        let xs = x.data();
        let xhs = SharedSliceMut::new(xhat.data_mut());
        let ys = SharedSliceMut::new(y.data_mut());
        let invs = SharedSliceMut::new(inv_std.data_mut());
        scope_rows(gate(threads, n * d), n, ROW_BAND, |band| {
            // SAFETY: bands are disjoint row ranges; each buffer is sliced
            // to this band only.
            let xh_band = unsafe { xhs.range_mut(band.start * d, band.end * d) };
            let y_band = unsafe { ys.range_mut(band.start * d, band.end * d) };
            let inv_band = unsafe { invs.range_mut(band.start, band.end) };
            for i in band.clone() {
                let row = &xs[i * d..(i + 1) * d];
                let o = (i - band.start) * d;
                let mu = row.iter().sum::<f32>() / d as f32;
                let var =
                    row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
                let inv = 1.0 / (var + LN_EPS).sqrt();
                inv_band[i - band.start] = inv;
                for j in 0..d {
                    let xh = (row[j] - mu) * inv;
                    xh_band[o + j] = xh;
                    y_band[o + j] = xh * gamma[j] + beta[j];
                }
            }
        });
    }
    (y, LnCache { xhat, inv_std })
}

/// Inference-mode LayerNorm: same bits as [`layer_norm`]'s `y`, but no
/// normalization cache is materialized at all.
fn layer_norm_infer(
    x: &Tensor,
    gamma: &[f32],
    beta: &[f32],
    threads: usize,
    ws: &mut Workspace,
) -> Tensor {
    let (n, d) = (x.shape()[0], x.shape()[1]);
    let mut y = ws.take(&[n, d]);
    {
        let xs = x.data();
        let ys = SharedSliceMut::new(y.data_mut());
        scope_rows(gate(threads, n * d), n, ROW_BAND, |band| {
            // SAFETY: bands are disjoint row ranges of y.
            let y_band = unsafe { ys.range_mut(band.start * d, band.end * d) };
            for i in band.clone() {
                let row = &xs[i * d..(i + 1) * d];
                let o = (i - band.start) * d;
                let mu = row.iter().sum::<f32>() / d as f32;
                let var =
                    row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
                let inv = 1.0 / (var + LN_EPS).sqrt();
                for j in 0..d {
                    let xh = (row[j] - mu) * inv;
                    y_band[o + j] = xh * gamma[j] + beta[j];
                }
            }
        });
    }
    y
}

/// LayerNorm backward. Returns dx; if `dgb` is Some((dgamma, dbeta)) the
/// parameter gradients are accumulated into the provided buffers (which may
/// be the grad sink's chunks directly). The dx rows are band-parallel; the
/// γ/β reduction runs in a fixed serial row order so its accumulation never
/// depends on the thread count.
fn layer_norm_backward(
    dy: &Tensor,
    cache: &LnCache,
    gamma: &[f32],
    dgb: Option<(&mut [f32], &mut [f32])>,
    threads: usize,
    ws: &mut Workspace,
) -> Tensor {
    let (n, d) = (dy.shape()[0], dy.shape()[1]);
    let mut dx = ws.take(&[n, d]);
    {
        let dys = dy.data();
        let xhs = cache.xhat.data();
        let invs = cache.inv_std.data();
        let dxs = SharedSliceMut::new(dx.data_mut());
        scope_rows(gate(threads, n * d), n, ROW_BAND, |band| {
            // SAFETY: bands are disjoint row ranges of dx.
            let dx_band = unsafe { dxs.range_mut(band.start * d, band.end * d) };
            for i in band.clone() {
                let dyr = &dys[i * d..(i + 1) * d];
                let xhr = &xhs[i * d..(i + 1) * d];
                let o = (i - band.start) * d;
                let mut m1 = 0.0f32; // mean of dxhat
                let mut m2 = 0.0f32; // mean of dxhat ∘ xhat
                for j in 0..d {
                    let dxh = dyr[j] * gamma[j];
                    m1 += dxh;
                    m2 += dxh * xhr[j];
                }
                m1 /= d as f32;
                m2 /= d as f32;
                let inv = invs[i];
                for j in 0..d {
                    let dxh = dyr[j] * gamma[j];
                    dx_band[o + j] = (dxh - m1 - xhr[j] * m2) * inv;
                }
            }
        });
    }
    if let Some((dg, db)) = dgb {
        for i in 0..n {
            let dyr = &dy.data()[i * d..(i + 1) * d];
            let xhr = &cache.xhat.data()[i * d..(i + 1) * d];
            for j in 0..d {
                dg[j] += dyr[j] * xhr[j];
                db[j] += dyr[j];
            }
        }
    }
    dx
}

// ---------------------------------------------------------------------------
// Gradient sink: name + contiguous-chunk accumulation in trainable order.
// ---------------------------------------------------------------------------

/// Accumulates gradients for the artifact's ordered trainable arrays.
/// Buffers are workspace checkouts; the name → index map is prebuilt once
/// per bound step, so constructing a sink allocates nothing in steady
/// state. Backward GEMMs accumulate *directly* into the chunks.
struct GradSink<'a> {
    grads: Vec<Tensor>,
    index: &'a HashMap<String, usize>,
}

impl<'a> GradSink<'a> {
    fn new(specs: &[IoSpec], index: &'a HashMap<String, usize>, ws: &mut Workspace) -> Self {
        let mut grads = ws.take_vec();
        for s in specs {
            grads.push(ws.take(&s.shape));
        }
        GradSink { grads, index }
    }

    fn idx(&self, name: &str) -> usize {
        *self.index.get(name).unwrap_or_else(|| {
            panic!("gradient for unknown trainable '{name}'")
        })
    }

    /// `grad[name][offset..offset+len]` as a raw accumulation target.
    fn chunk_mut(&mut self, name: &str, offset: usize, len: usize) -> &mut [f32] {
        let i = self.idx(name);
        &mut self.grads[i].data_mut()[offset..offset + len]
    }

    /// Two disjoint chunks of *different* trainable tensors at once (the
    /// LayerNorm γ/β pair).
    fn two_chunks_mut(
        &mut self,
        a: (&str, usize, usize),
        b: (&str, usize, usize),
    ) -> (&mut [f32], &mut [f32]) {
        let ia = self.idx(a.0);
        let ib = self.idx(b.0);
        assert_ne!(ia, ib, "two_chunks_mut needs distinct tensors");
        let hi = ia.max(ib);
        let lo = ia.min(ib);
        let (left, right) = self.grads.split_at_mut(hi);
        let (t_lo, t_hi) = (&mut left[lo], &mut right[0]);
        let (t_a, t_b) = if ia < ib { (t_lo, t_hi) } else { (t_hi, t_lo) };
        (
            &mut t_a.data_mut()[a.1..a.1 + a.2],
            &mut t_b.data_mut()[b.1..b.1 + b.2],
        )
    }

    /// `grad[name][offset..offset+len] += src` (contiguous chunk).
    fn add_chunk(&mut self, name: &str, offset: usize, src: &[f32]) {
        let dst = self.chunk_mut(name, offset, src.len());
        for (d, s) in dst.iter_mut().zip(src) {
            *d += *s;
        }
    }

    fn into_vec(self) -> Vec<Tensor> {
        self.grads
    }
}

// ---------------------------------------------------------------------------
// Weight resolution: prebuilt name index over frozen map + trainable slice.
// ---------------------------------------------------------------------------

/// Where a named weight lives for a bound step.
#[derive(Clone, Copy, Debug)]
enum WeightSlot {
    /// In the backend's frozen map (looked up by name).
    Frozen,
    /// At this index of the per-call trainable slice.
    Trainable(usize),
}

/// Per-call weight view: the bind-time name index plus the step's borrowed
/// frozen map, trainable tensors, and the bind-time packed-panel copies of
/// the frozen layer weights. Resolution allocates nothing.
struct Weights<'a> {
    index: &'a HashMap<String, WeightSlot>,
    frozen: &'a HashMap<String, Tensor>,
    trainable: &'a [Tensor],
    packed: &'a HashMap<String, Vec<PackedBAny>>,
}

impl<'a> Weights<'a> {
    fn get(&self, name: &str) -> &'a Tensor {
        match self.index.get(name) {
            Some(WeightSlot::Frozen) => self.frozen.get(name).unwrap_or_else(|| {
                panic!("frozen weight '{name}' missing from the bound set")
            }),
            Some(WeightSlot::Trainable(i)) => &self.trainable[*i],
            None => panic!("weight '{name}' not in the step layout"),
        }
    }

    fn vec(&self, name: &str) -> &'a [f32] {
        self.get(name).data()
    }

    /// Row `i` of a (rows, d) stacked vector array.
    fn row(&self, name: &str, i: usize, d: usize) -> &'a [f32] {
        &self.get(name).data()[i * d..(i + 1) * d]
    }

    /// The `i`-th leading-axis chunk of a stacked array, as a raw slice of
    /// `len` elements (layer weight matrices are contiguous chunks — no
    /// copy is ever needed on the forward orientation).
    fn chunk(&self, name: &str, i: usize, len: usize) -> &'a [f32] {
        &self.get(name).data()[i * len..(i + 1) * len]
    }

    /// The bind-time packed-panel copy of layer chunk `i` of a frozen
    /// weight, when one was built. Gated on the weight actually being
    /// frozen *for this step*: full fine-tuning trains these arrays, and
    /// its frozen map (assembled from a pretrained checkpoint) may still
    /// carry their initial values — serving those stale panels instead of
    /// the live trainable tensor would silently freeze the forward.
    fn packed_chunk(&self, name: &str, i: usize) -> Option<&'a PackedBAny> {
        match self.index.get(name) {
            Some(WeightSlot::Frozen) => self.packed.get(name).and_then(|v| v.get(i)),
            _ => None,
        }
    }
}

/// Forward `x·W` GEMM against a layer chunk of a stacked weight, routed
/// through the bind-time packed-panel copy when one exists. For f32 packs
/// (every train/eval bind) this is bit-identical to the on-the-fly path —
/// the cache only skips the per-call B pack. Quantized packs (serving
/// binds at `--serve-dtype bf16|int8`) decode the stored panels back to
/// f32 inside the microkernel, so the product carries the dtype's
/// quantization tolerance instead.
#[allow(clippy::too_many_arguments)]
fn frozen_mm(
    w: &Weights,
    name: &str,
    layer: usize,
    x: &Tensor,
    out: &mut Tensor,
    k: usize,
    n_cols: usize,
    threads: usize,
    ws: &mut Workspace,
) {
    let m = x.shape()[0];
    match w.packed_chunk(name, layer) {
        Some(p) => {
            debug_assert_eq!((p.k(), p.n()), (k, n_cols));
            matmul_into_prepacked_any(x.data(), p, out.data_mut(), m, threads, ws.packs());
        }
        None => matmul_into(
            x.data(),
            w.chunk(name, layer, k * n_cols),
            out.data_mut(),
            m,
            k,
            n_cols,
            threads,
            ws.packs(),
        ),
    }
}

// ---------------------------------------------------------------------------
// Model dimensions derived from the artifact spec.
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
struct Dims {
    b: usize,
    s: usize,
    n: usize,
    d: usize,
    h: usize,
    dh: usize,
    f: usize,
    l: usize,
    v: usize,
    classes: usize,
}

fn dims_of(entry: &ArtifactEntry) -> Result<Dims> {
    let preset = ModelPreset::from_name(&entry.spec.model).map_err(anyhow::Error::msg)?;
    let md = preset.dims(entry.spec.tasks.max(1));
    let (b, s) = (entry.spec.batch, entry.spec.seq);
    Ok(Dims {
        b,
        s,
        n: b * s,
        d: md.hidden,
        h: md.heads,
        dh: md.hidden / md.heads,
        f: md.ffn,
        l: md.layers,
        v: md.vocab,
        classes: entry.spec.classes,
    })
}

// ---------------------------------------------------------------------------
// Step scratch: everything a bound step reuses across calls.
// ---------------------------------------------------------------------------

/// Per-bound-step reusable state: the workspace arena (which owns the GEMM
/// pack scratch), the weight-name and gradient-name indices, the persistent
/// adapter-precompute containers, the pooled layer-cache vector, and the
/// bind-time packed-panel copies of the frozen layer weights. (PR 3's
/// bind-time *transposed* frozen-weight copies stay gone: the packed
/// kernel's B-pack absorbs the backward transpose bit-identically. The
/// `packed` map below is the ROADMAP follow-up on the *forward* side —
/// NR-panel packs of the step-invariant `x·W` operands, built once per
/// bind so the forward GEMMs of every train/eval/serving call skip the
/// per-call `pack_b` at the same memory cost as the deleted PR 3 copies.)
/// Owned by the backend's step behind a mutex; after a one-step warmup,
/// running a step against this scratch allocates nothing.
pub struct StepScratch {
    ws: Workspace,
    index: HashMap<String, WeightSlot>,
    grad_index: HashMap<String, usize>,
    pre: AdapterPre,
    layers: Vec<LayerCache>,
    /// Per-row f64 loss terms of the MLM objective (f64 lives outside the
    /// f32 arena; the container persists so pretrain steps stay pooled).
    row_loss: Vec<f64>,
    /// Bind-time NR-panel packs of the frozen per-layer weight chunks in
    /// their forward orientation (`wq`/`wk`/`wv`/`wo`/`w1`/`w2`), indexed
    /// by layer. Shared (`Arc`) across every step bound against the same
    /// frozen map — train + eval runners, all DMRG ranks, every serving
    /// worker pay the panel memory once (the backend builds it via
    /// [`pack_frozen_weights`] and caches per backbone). Backward `dY·Wᵀ`
    /// GEMMs keep their per-call pack (caching the transposed orientation
    /// too would double the memory again).
    packed: Arc<PackedFrozen>,
}

/// Map of frozen stacked-weight name → per-layer-chunk packed panels, at
/// the storage dtype the step was bound with (f32 for every train/eval
/// bind; `--serve-dtype` for serving binds).
pub type PackedFrozen = HashMap<String, Vec<PackedBAny>>;

/// The per-layer GEMM operand families worth packing at bind time.
const PACKED_FAMILIES: [&str; 6] = ["wq", "wk", "wv", "wo", "w1", "w2"];

/// Build the bind-time packed-panel cache for a frozen-weight map: every
/// step-invariant per-layer forward operand present in the map (stacked
/// `[l, k, n]`) is packed once in its forward orientation. A pure function
/// of the map — which is what lets backends share the result across every
/// spec bound against the same backbone `Arc`. Callers must invoke this
/// only for specs that freeze these arrays (the backend skips it for full
/// fine-tuning / pretrain / apply binds, whose frozen maps either lack the
/// families or — full FT with a pretrained checkpoint — carry values no
/// lookup may ever return; `Weights::packed_chunk` gates on the slot as
/// the second line of defense). At `DtypeKind::F32` bit-identity is free —
/// the cached panels come from the same packer the per-call path runs.
/// Quantized kinds trade the dtype's tolerance for panel bandwidth.
pub fn pack_frozen_weights(frozen: &HashMap<String, Tensor>, kind: DtypeKind) -> PackedFrozen {
    let mut packed = PackedFrozen::new();
    for name in PACKED_FAMILIES {
        let Some(t) = frozen.get(name) else { continue };
        if t.ndim() != 3 {
            continue;
        }
        let (l, k, n) = (t.shape()[0], t.shape()[1], t.shape()[2]);
        let chunk = k * n;
        let per_layer = (0..l)
            .map(|li| PackedBAny::pack(&t.data()[li * chunk..(li + 1) * chunk], k, n, kind))
            .collect();
        packed.insert(name.to_string(), per_layer);
    }
    packed
}

/// Total panel bytes a [`PackedFrozen`] cache holds — the per-tick frozen
/// operand traffic the serving bandwidth telemetry reports.
pub fn packed_frozen_bytes(packed: &PackedFrozen) -> usize {
    packed.values().flatten().map(|p| p.panel_bytes()).sum()
}

/// A folded adapter factor pair (`A = [d, r]` α-pre-scaled, `B = [r, d]`,
/// from [`crate::tt::MetaTt::fold_for_serving`]) pre-packed at a serving
/// storage dtype. The serving engine's adapter cache holds these instead
/// of dense tensors: the per-tick pack of both operands disappears, and at
/// bf16/int8 the resident factor bytes shrink 2–4×. The f32 instantiation
/// is bit-identical to running [`serve_step`] on the dense pair.
#[derive(Debug)]
pub struct FoldedPairPacked {
    /// Packed `A` (`k = d`, `n = r`).
    pub a: PackedBAny,
    /// Packed `B` (`k = r`, `n = d`).
    pub b: PackedBAny,
}

impl FoldedPairPacked {
    /// Pack a dense folded `(A, B)` pair at `kind`. Shapes must be the
    /// serving contract's `[d, r]` / `[r, d]`.
    pub fn pack(a: &Tensor, b: &Tensor, kind: DtypeKind) -> FoldedPairPacked {
        assert_eq!(a.ndim(), 2, "folded A must be a matrix, got {:?}", a.shape());
        assert_eq!(b.ndim(), 2, "folded B must be a matrix, got {:?}", b.shape());
        FoldedPairPacked {
            a: PackedBAny::pack(a.data(), a.shape()[0], a.shape()[1], kind),
            b: PackedBAny::pack(b.data(), b.shape()[0], b.shape()[1], kind),
        }
    }

    /// Resident panel bytes of both factors (the byte-LRU accounting unit).
    pub fn bytes(&self) -> usize {
        self.a.panel_bytes() + self.b.panel_bytes()
    }
}

impl StepScratch {
    pub fn new(
        entry: &ArtifactEntry,
        arena: bool,
        packed: Arc<PackedFrozen>,
    ) -> Result<StepScratch> {
        // Validates the spec's model preset at bind time (the historical
        // bind contract), even though the dims themselves are re-derived
        // per step call.
        dims_of(entry)?;
        let mut index = HashMap::new();
        for io in entry.frozen_inputs() {
            index.insert(io.name.clone(), WeightSlot::Frozen);
        }
        for (i, io) in entry.trainable_inputs().iter().enumerate() {
            index.insert(io.name.clone(), WeightSlot::Trainable(i));
        }
        let grad_index = entry
            .trainable_inputs()
            .iter()
            .enumerate()
            .map(|(i, io)| (io.name.clone(), i))
            .collect();
        Ok(StepScratch {
            ws: Workspace::new(arena),
            index,
            grad_index,
            pre: AdapterPre::default(),
            layers: Vec::new(),
            row_loss: Vec::new(),
            packed,
        })
    }

    /// The step's workspace (the backend's `recycle` hook feeds consumed
    /// outputs back through this).
    pub fn workspace_mut(&mut self) -> &mut Workspace {
        &mut self.ws
    }
}

// ---------------------------------------------------------------------------
// Adapter application (forward + backward), all Table-1 families.
// ---------------------------------------------------------------------------

/// Per-step adapter precomputations, stored in persistent containers so
/// refilling them each step allocates nothing: every (layer, matrix) r×r
/// `mid` product, the (4+1)D backward-only `ab`/`bc` factors, and VeRA's
/// seed-fixed frozen projections.
#[derive(Default)]
struct AdapterPre {
    /// `layer·matrices + matrix` → the r×r middle product of the chain.
    mids: Vec<Tensor>,
    /// (4+1)D backward only: `G2[l]·G3[t]` per layer.
    ab: Vec<Tensor>,
    /// (4+1)D backward only: `G3[t]·G4[m]` per matrix.
    bc: Vec<Tensor>,
    /// VeRA's frozen shared projections (A: d×r, B: r×d), seed-fixed.
    vera: Option<(Tensor, Tensor)>,
}

impl AdapterPre {
    /// Recompute the per-step products. `train` additionally materializes
    /// the backward-only factors; inference forwards skip them.
    #[allow(clippy::too_many_arguments)]
    fn fill(
        &mut self,
        kind: Option<AdapterKind>,
        dims: &Dims,
        params: &[Tensor],
        rank: usize,
        task: usize,
        matrices: usize,
        train: bool,
        ws: &mut Workspace,
    ) {
        debug_assert!(self.mids.is_empty() && self.ab.is_empty() && self.bc.is_empty());
        let r = rank;
        let rr = r * r;
        match kind {
            Some(AdapterKind::MetaTt(MetaTtKind::FourD))
            | Some(AdapterKind::MetaTt(MetaTtKind::FiveD)) => {
                let (g2, g3) = (&params[1], &params[2]);
                for l in 0..dims.l {
                    let g2l = &g2.data()[l * rr..(l + 1) * rr];
                    for m in 0..matrices {
                        let g3m = &g3.data()[m * rr..(m + 1) * rr];
                        let mut mid = ws.take(&[r, r]);
                        matmul_into(g2l, g3m, mid.data_mut(), r, r, r, 1, ws.packs());
                        self.mids.push(mid);
                    }
                }
            }
            Some(AdapterKind::MetaTt(MetaTtKind::FourPlusOneD)) => {
                let (g2, g3, g4) = (&params[1], &params[2], &params[3]);
                let cb = &g3.data()[task * rr..(task + 1) * rr];
                if train {
                    for m in 0..matrices {
                        let cc = &g4.data()[m * rr..(m + 1) * rr];
                        let mut bcm = ws.take(&[r, r]);
                        matmul_into(cb, cc, bcm.data_mut(), r, r, r, 1, ws.packs());
                        self.bc.push(bcm);
                    }
                }
                for l in 0..dims.l {
                    let ca = &g2.data()[l * rr..(l + 1) * rr];
                    let mut abl = ws.take(&[r, r]);
                    matmul_into(ca, cb, abl.data_mut(), r, r, r, 1, ws.packs());
                    for m in 0..matrices {
                        let cc = &g4.data()[m * rr..(m + 1) * rr];
                        let mut mid = ws.take(&[r, r]);
                        matmul_into(abl.data(), cc, mid.data_mut(), r, r, r, 1, ws.packs());
                        self.mids.push(mid);
                    }
                    if train {
                        self.ab.push(abl);
                    } else {
                        ws.recycle(abl);
                    }
                }
            }
            Some(AdapterKind::VeRa) => {
                // Mirror of model.py `_vera_frozen`: shared random A (d×r),
                // B (r×d), seed-fixed so every step agrees. (The PJRT
                // artifacts bake jax-PRNG draws; the reference backend uses
                // its own fixed PCG stream — same distribution, different
                // realization.) Generated once per bound step and kept —
                // the draws are parameter-independent constants, so
                // regenerating ~2·d·r normals per step would be exactly the
                // per-step-constant recomputation this refactor removes.
                if self.vera.is_none() {
                    let d = dims.d;
                    let mut rng = Pcg64::with_stream(7, 0x7e2a);
                    let mut a = ws.take(&[d, r]);
                    rng.fill_normal(a.data_mut(), 1.0 / (d as f32).sqrt());
                    let mut b = ws.take(&[r, d]);
                    rng.fill_normal(b.data_mut(), 1.0 / (r as f32).sqrt());
                    self.vera = Some((a, b));
                }
            }
            _ => {}
        }
    }

    /// Return the per-step tensors to the workspace, keeping the containers
    /// for the next step (VeRA's frozen projections persist — they are
    /// step-invariant constants).
    fn recycle_into(&mut self, ws: &mut Workspace) {
        for t in self.mids.drain(..) {
            ws.recycle(t);
        }
        for t in self.ab.drain(..) {
            ws.recycle(t);
        }
        for t in self.bc.drain(..) {
            ws.recycle(t);
        }
    }
}

struct AdapterCtx<'a> {
    /// None for "full"/"none" (zero delta).
    kind: Option<AdapterKind>,
    params: &'a [Tensor],
    alpha: f32,
    /// Task index ((4+1)D task-core slicing).
    task: usize,
    rank: usize,
    heads: usize,
    matrices: usize,
    d: usize,
    /// Thread budget for the activation-sized GEMMs (the r×r factor
    /// products stay serial — they are far below the parallel threshold).
    threads: usize,
    pre: &'a AdapterPre,
}

/// Resolve the adapter kind of a spec ("full"/"none" → None).
fn adapter_kind_of(entry: &ArtifactEntry) -> Result<Option<AdapterKind>> {
    Ok(match entry.spec.adapter.as_str() {
        "full" | "none" => None,
        name => match AdapterKind::from_name(name).map_err(anyhow::Error::msg)? {
            AdapterKind::Full => None,
            k => Some(k),
        },
    })
}

impl<'a> AdapterCtx<'a> {
    /// Adapter deltas for both adapted matrices of `layer`, accumulated in
    /// place: `q += α·Δ_{l,0}(x)`, `v += α·Δ_{l,1}(x)`. The x-side prefix
    /// GEMM (`x·G1` / `x·U` / `x·A`) is computed once and shared.
    fn apply_pair(
        &self,
        ws: &mut Workspace,
        x: &Tensor,
        layer: usize,
        q: &mut Tensor,
        v: &mut Tensor,
    ) -> PairCache {
        let (n, d, r) = (x.shape()[0], self.d, self.rank);
        let a = self.alpha;
        let th = self.threads;
        match self.kind {
            None => PairCache::None,
            Some(AdapterKind::Full) => PairCache::None,
            Some(AdapterKind::MetaTt(MetaTtKind::FourD))
            | Some(AdapterKind::MetaTt(MetaTtKind::FourPlusOneD)) => {
                let g1 = &self.params[0];
                let g_last = &self.params[self.params.len() - 1]; // g4 / g5
                let xg1 = mm(ws, x, g1, th); // (n, r) — shared by Q and V
                let mut pair = [None, None];
                for (m, out) in [(0usize, &mut *q), (1, &mut *v)] {
                    let mid = &self.pre.mids[layer * self.matrices + m];
                    let mut xgm = ws.take(&[n, r]);
                    matmul_into(xg1.data(), mid.data(), xgm.data_mut(), n, r, r, 1, ws.packs());
                    let delta = mm(ws, &xgm, g_last, th); // (n, d)
                    axpy_into(out.data_mut(), a, delta.data());
                    ws.recycle(delta);
                    pair[m] = Some(xgm);
                }
                PairCache::Tt {
                    xg1,
                    xgm_q: pair[0].take().expect("q cache"),
                    xgm_v: pair[1].take().expect("v cache"),
                }
            }
            Some(AdapterKind::MetaTt(MetaTtKind::FiveD)) => {
                let g1 = &self.params[0];
                let g4 = &self.params[3];
                let g5 = &self.params[4];
                let dh = d / self.heads;
                let rr = r * r;
                let xg1 = mm(ws, x, g1, th);
                let mut xlm_c = [None, None];
                let mut xh_c = [None, None];
                for (m, out) in [(0usize, &mut *q), (1, &mut *v)] {
                    let lm = &self.pre.mids[layer * self.matrices + m];
                    let mut xlm = ws.take(&[n, r]);
                    matmul_into(xg1.data(), lm.data(), xlm.data_mut(), n, r, r, 1, ws.packs());
                    let mut xh = ws.take(&[self.heads, n, r]);
                    for hh in 0..self.heads {
                        let g4h = &g4.data()[hh * rr..(hh + 1) * rr];
                        {
                            let blk = &mut xh.data_mut()[hh * n * r..(hh + 1) * n * r];
                            matmul_into(xlm.data(), g4h, blk, n, r, r, 1, ws.packs());
                        }
                        let mut y = ws.take(&[n, dh]);
                        matmul_into(
                            &xh.data()[hh * n * r..(hh + 1) * n * r],
                            g5.data(),
                            y.data_mut(),
                            n,
                            r,
                            dh,
                            th,
                            ws.packs(),
                        );
                        add_block_scaled(out, 0, hh * dh, &y, a);
                        ws.recycle(y);
                    }
                    xlm_c[m] = Some(xlm);
                    xh_c[m] = Some(xh);
                }
                PairCache::Tt5 {
                    xg1,
                    xlm_q: xlm_c[0].take().expect("q cache"),
                    xh_q: xh_c[0].take().expect("q cache"),
                    xlm_v: xlm_c[1].take().expect("v cache"),
                    xh_v: xh_c[1].take().expect("v cache"),
                }
            }
            Some(AdapterKind::LoRa) => {
                let (pa, pb) = (&self.params[0], &self.params[1]);
                let mut xa_c = [None, None];
                for (m, out) in [(0usize, &mut *q), (1, &mut *v)] {
                    let idx = layer * self.matrices + m;
                    let am = &pa.data()[idx * d * r..(idx + 1) * d * r];
                    let bm = &pb.data()[idx * r * d..(idx + 1) * r * d];
                    let mut xa = ws.take(&[n, r]);
                    matmul_into(x.data(), am, xa.data_mut(), n, d, r, th, ws.packs());
                    let mut delta = ws.take(&[n, d]);
                    matmul_into(xa.data(), bm, delta.data_mut(), n, r, d, th, ws.packs());
                    axpy_into(out.data_mut(), a, delta.data());
                    ws.recycle(delta);
                    xa_c[m] = Some(xa);
                }
                PairCache::Lora {
                    xa_q: xa_c[0].take().expect("q cache"),
                    xa_v: xa_c[1].take().expect("v cache"),
                }
            }
            Some(AdapterKind::VeRa) => {
                let (fa, fb) = self.pre.vera.as_ref().expect("vera frozen");
                let xa = mm(ws, x, fa, th); // shared: fa is the same for Q and V
                let mut tb_c = [None, None];
                for (m, out) in [(0usize, &mut *q), (1, &mut *v)] {
                    let idx = layer * self.matrices + m;
                    let dvec = &self.params[0].data()[idx * r..(idx + 1) * r];
                    let bvec = &self.params[1].data()[idx * d..(idx + 1) * d];
                    let t = mul_cols_ws(ws, &xa, dvec);
                    let tb = mm(ws, &t, fb, th);
                    ws.recycle(t);
                    acc_mul_cols_scaled(out, &tb, bvec, a);
                    tb_c[m] = Some(tb);
                }
                PairCache::Vera {
                    xa,
                    tb_q: tb_c[0].take().expect("q cache"),
                    tb_v: tb_c[1].take().expect("v cache"),
                }
            }
            Some(AdapterKind::LoTr) => {
                let (u, sall, vmat) = (&self.params[0], &self.params[1], &self.params[2]);
                let rr = r * r;
                let xu = mm(ws, x, u, th); // shared: U is global across (l, m)
                let mut xus_c = [None, None];
                for (m, out) in [(0usize, &mut *q), (1, &mut *v)] {
                    let idx = layer * self.matrices + m;
                    let sm = &sall.data()[idx * rr..(idx + 1) * rr];
                    let mut xus = ws.take(&[n, r]);
                    matmul_into(xu.data(), sm, xus.data_mut(), n, r, r, 1, ws.packs());
                    let delta = mm(ws, &xus, vmat, th);
                    axpy_into(out.data_mut(), a, delta.data());
                    ws.recycle(delta);
                    xus_c[m] = Some(xus);
                }
                PairCache::Lotr {
                    xu,
                    xus_q: xus_c[0].take().expect("q cache"),
                    xus_v: xus_c[1].take().expect("v cache"),
                }
            }
        }
    }

    /// Backward through both deltas of `layer`: accumulates parameter grads
    /// into `sink` and `dx += Σ_m ∂Δ_m/∂x · dy_m`. For the shared-prefix
    /// families the per-matrix prefix cotangents are summed *before* the
    /// final `xᵀ·(…)` / `(…)·G1ᵀ` projections, halving the big GEMMs.
    #[allow(clippy::too_many_arguments)]
    fn backward_pair(
        &self,
        ws: &mut Workspace,
        x: &Tensor,
        layer: usize,
        cache: &PairCache,
        dq: &Tensor,
        dv: &Tensor,
        dx: &mut Tensor,
        sink: &mut GradSink,
    ) {
        let (d, r) = (self.d, self.rank);
        let rr = r * r;
        let th = self.threads;
        let n = dq.shape()[0];
        let a = self.alpha;
        match (self.kind, cache) {
            (None, _) | (Some(AdapterKind::Full), _) => {}
            (Some(AdapterKind::MetaTt(MetaTtKind::FourD)), PairCache::Tt { xg1, xgm_q, xgm_v }) => {
                let (g1, g2, g3, g4) = (
                    &self.params[0],
                    &self.params[1],
                    &self.params[2],
                    &self.params[3],
                );
                let mut dxg1 = ws.take(&[n, r]);
                for (m, dy, xgm) in [(0usize, dq, xgm_q), (1, dv, xgm_v)] {
                    let dya = scale_ws(ws, dy, a);
                    t_matmul_into(
                        xgm.data(),
                        dya.data(),
                        sink.chunk_mut("g4", 0, r * d),
                        r,
                        n,
                        d,
                        th,
                        ws.packs(),
                    );
                    let mut dxgm = ws.take(&[n, r]);
                    matmul_t_into(dya.data(), g4.data(), dxgm.data_mut(), n, d, r, th, ws.packs());
                    ws.recycle(dya);
                    let mut dmid = ws.take(&[r, r]);
                    t_matmul_into(xg1.data(), dxgm.data(), dmid.data_mut(), r, n, r, th, ws.packs());
                    let g3m = &g3.data()[m * rr..(m + 1) * rr];
                    matmul_t_into(
                        dmid.data(),
                        g3m,
                        sink.chunk_mut("g2", layer * rr, rr),
                        r,
                        r,
                        r,
                        1,
                        ws.packs(),
                    );
                    let g2l = &g2.data()[layer * rr..(layer + 1) * rr];
                    t_matmul_into(
                        g2l,
                        dmid.data(),
                        sink.chunk_mut("g3", m * rr, rr),
                        r,
                        r,
                        r,
                        1,
                        ws.packs(),
                    );
                    ws.recycle(dmid);
                    let mid = &self.pre.mids[layer * self.matrices + m];
                    matmul_t_into(dxgm.data(), mid.data(), dxg1.data_mut(), n, r, r, 1, ws.packs());
                    ws.recycle(dxgm);
                }
                // Fused tail: one xᵀ·dxg1 and one dxg1·G1ᵀ for both matrices.
                t_matmul_into(
                    x.data(),
                    dxg1.data(),
                    sink.chunk_mut("g1", 0, d * r),
                    d,
                    n,
                    r,
                    th,
                    ws.packs(),
                );
                matmul_t_into(dxg1.data(), g1.data(), dx.data_mut(), n, r, d, th, ws.packs());
                ws.recycle(dxg1);
            }
            (
                Some(AdapterKind::MetaTt(MetaTtKind::FourPlusOneD)),
                PairCache::Tt { xg1, xgm_q, xgm_v },
            ) => {
                let (g1, g5) = (&self.params[0], &self.params[4]);
                let mut dxg1 = ws.take(&[n, r]);
                for (m, dy, xgm) in [(0usize, dq, xgm_q), (1, dv, xgm_v)] {
                    let dya = scale_ws(ws, dy, a);
                    t_matmul_into(
                        xgm.data(),
                        dya.data(),
                        sink.chunk_mut("g5", 0, r * d),
                        r,
                        n,
                        d,
                        th,
                        ws.packs(),
                    );
                    let mut dxgm = ws.take(&[n, r]);
                    matmul_t_into(dya.data(), g5.data(), dxgm.data_mut(), n, d, r, th, ws.packs());
                    ws.recycle(dya);
                    let mut dmid = ws.take(&[r, r]);
                    t_matmul_into(xg1.data(), dxgm.data(), dmid.data_mut(), r, n, r, th, ws.packs());
                    // g2[l] += dmid·bc[m]ᵀ
                    matmul_t_into(
                        dmid.data(),
                        self.pre.bc[m].data(),
                        sink.chunk_mut("g2", layer * rr, rr),
                        r,
                        r,
                        r,
                        1,
                        ws.packs(),
                    );
                    // g3[t] += ca[l]ᵀ·dmid·cc[m]ᵀ (two r×r products)
                    let ca = &self.params[1].data()[layer * rr..(layer + 1) * rr];
                    let cc = &self.params[3].data()[m * rr..(m + 1) * rr];
                    let mut tmp = ws.take(&[r, r]);
                    t_matmul_into(ca, dmid.data(), tmp.data_mut(), r, r, r, 1, ws.packs());
                    matmul_t_into(
                        tmp.data(),
                        cc,
                        sink.chunk_mut("g3", self.task * rr, rr),
                        r,
                        r,
                        r,
                        1,
                        ws.packs(),
                    );
                    ws.recycle(tmp);
                    // g4[m] += ab[l]ᵀ·dmid
                    t_matmul_into(
                        self.pre.ab[layer].data(),
                        dmid.data(),
                        sink.chunk_mut("g4", m * rr, rr),
                        r,
                        r,
                        r,
                        1,
                        ws.packs(),
                    );
                    ws.recycle(dmid);
                    let mid = &self.pre.mids[layer * self.matrices + m];
                    matmul_t_into(dxgm.data(), mid.data(), dxg1.data_mut(), n, r, r, 1, ws.packs());
                    ws.recycle(dxgm);
                }
                t_matmul_into(
                    x.data(),
                    dxg1.data(),
                    sink.chunk_mut("g1", 0, d * r),
                    d,
                    n,
                    r,
                    th,
                    ws.packs(),
                );
                matmul_t_into(dxg1.data(), g1.data(), dx.data_mut(), n, r, d, th, ws.packs());
                ws.recycle(dxg1);
            }
            (
                Some(AdapterKind::MetaTt(MetaTtKind::FiveD)),
                PairCache::Tt5 { xg1, xlm_q, xh_q, xlm_v, xh_v },
            ) => {
                let (g1, g2, g3, g4, g5) = (
                    &self.params[0],
                    &self.params[1],
                    &self.params[2],
                    &self.params[3],
                    &self.params[4],
                );
                let dh = d / self.heads;
                let mut dxg1 = ws.take(&[n, r]);
                for (m, dy, xlm, xh) in
                    [(0usize, dq, xlm_q, xh_q), (1, dv, xlm_v, xh_v)]
                {
                    let dya = scale_ws(ws, dy, a);
                    let mut dxlm = ws.take(&[n, r]);
                    for hh in 0..self.heads {
                        let dyh = copy_block(ws, &dya, 0, n, hh * dh, dh);
                        let xh_blk = &xh.data()[hh * n * r..(hh + 1) * n * r];
                        t_matmul_into(
                            xh_blk,
                            dyh.data(),
                            sink.chunk_mut("g5", 0, r * dh),
                            r,
                            n,
                            dh,
                            th,
                            ws.packs(),
                        );
                        let mut dxh = ws.take(&[n, r]);
                        matmul_t_into(dyh.data(), g5.data(), dxh.data_mut(), n, dh, r, th, ws.packs());
                        ws.recycle(dyh);
                        t_matmul_into(
                            xlm.data(),
                            dxh.data(),
                            sink.chunk_mut("g4", hh * rr, rr),
                            r,
                            n,
                            r,
                            th,
                            ws.packs(),
                        );
                        let g4h = &g4.data()[hh * rr..(hh + 1) * rr];
                        matmul_t_into(dxh.data(), g4h, dxlm.data_mut(), n, r, r, 1, ws.packs());
                        ws.recycle(dxh);
                    }
                    ws.recycle(dya);
                    let mut dlm = ws.take(&[r, r]);
                    t_matmul_into(xg1.data(), dxlm.data(), dlm.data_mut(), r, n, r, th, ws.packs());
                    let g3m = &g3.data()[m * rr..(m + 1) * rr];
                    matmul_t_into(
                        dlm.data(),
                        g3m,
                        sink.chunk_mut("g2", layer * rr, rr),
                        r,
                        r,
                        r,
                        1,
                        ws.packs(),
                    );
                    let g2l = &g2.data()[layer * rr..(layer + 1) * rr];
                    t_matmul_into(
                        g2l,
                        dlm.data(),
                        sink.chunk_mut("g3", m * rr, rr),
                        r,
                        r,
                        r,
                        1,
                        ws.packs(),
                    );
                    ws.recycle(dlm);
                    let lm = &self.pre.mids[layer * self.matrices + m];
                    matmul_t_into(dxlm.data(), lm.data(), dxg1.data_mut(), n, r, r, 1, ws.packs());
                    ws.recycle(dxlm);
                }
                t_matmul_into(
                    x.data(),
                    dxg1.data(),
                    sink.chunk_mut("g1", 0, d * r),
                    d,
                    n,
                    r,
                    th,
                    ws.packs(),
                );
                matmul_t_into(dxg1.data(), g1.data(), dx.data_mut(), n, r, d, th, ws.packs());
                ws.recycle(dxg1);
            }
            (Some(AdapterKind::LoRa), PairCache::Lora { xa_q, xa_v }) => {
                let (pa, pb) = (&self.params[0], &self.params[1]);
                for (m, dy, xa) in [(0usize, dq, xa_q), (1, dv, xa_v)] {
                    let idx = layer * self.matrices + m;
                    let am = &pa.data()[idx * d * r..(idx + 1) * d * r];
                    let bm = &pb.data()[idx * r * d..(idx + 1) * r * d];
                    let dya = scale_ws(ws, dy, a);
                    t_matmul_into(
                        xa.data(),
                        dya.data(),
                        sink.chunk_mut("lora_b", idx * r * d, r * d),
                        r,
                        n,
                        d,
                        th,
                        ws.packs(),
                    );
                    let mut dxa = ws.take(&[n, r]);
                    matmul_t_into(dya.data(), bm, dxa.data_mut(), n, d, r, th, ws.packs());
                    ws.recycle(dya);
                    t_matmul_into(
                        x.data(),
                        dxa.data(),
                        sink.chunk_mut("lora_a", idx * d * r, d * r),
                        d,
                        n,
                        r,
                        th,
                        ws.packs(),
                    );
                    matmul_t_into(dxa.data(), am, dx.data_mut(), n, r, d, th, ws.packs());
                    ws.recycle(dxa);
                }
            }
            (Some(AdapterKind::VeRa), PairCache::Vera { xa, tb_q, tb_v }) => {
                let (fa, fb) = self.pre.vera.as_ref().expect("vera frozen");
                let mut dsum = ws.take(&[n, r]);
                for (m, dy, tb) in [(0usize, dq, tb_q), (1, dv, tb_v)] {
                    let idx = layer * self.matrices + m;
                    let dvec = &self.params[0].data()[idx * r..(idx + 1) * r];
                    let bvec = &self.params[1].data()[idx * d..(idx + 1) * d];
                    let dya = scale_ws(ws, dy, a);
                    colsum_mul_acc(&dya, tb, sink.chunk_mut("vera_b", idx * d, d));
                    let dtb = mul_cols_ws(ws, &dya, bvec);
                    ws.recycle(dya);
                    let mut dt = ws.take(&[n, r]);
                    matmul_t_into(dtb.data(), fb.data(), dt.data_mut(), n, d, r, th, ws.packs());
                    ws.recycle(dtb);
                    colsum_mul_acc(&dt, xa, sink.chunk_mut("vera_d", idx * r, r));
                    acc_mul_cols(&mut dsum, &dt, dvec);
                    ws.recycle(dt);
                }
                // Fused: dx += (Σ_m dt_m ∘ d_m)·Aᵀ — one GEMM for both.
                matmul_t_into(dsum.data(), fa.data(), dx.data_mut(), n, r, d, th, ws.packs());
                ws.recycle(dsum);
            }
            (Some(AdapterKind::LoTr), PairCache::Lotr { xu, xus_q, xus_v }) => {
                let (u, sall, vmat) = (&self.params[0], &self.params[1], &self.params[2]);
                let mut dxu = ws.take(&[n, r]);
                for (m, dy, xus) in [(0usize, dq, xus_q), (1, dv, xus_v)] {
                    let idx = layer * self.matrices + m;
                    let sm = &sall.data()[idx * rr..(idx + 1) * rr];
                    let dya = scale_ws(ws, dy, a);
                    t_matmul_into(
                        xus.data(),
                        dya.data(),
                        sink.chunk_mut("lotr_v", 0, r * d),
                        r,
                        n,
                        d,
                        th,
                        ws.packs(),
                    );
                    let mut dxus = ws.take(&[n, r]);
                    matmul_t_into(dya.data(), vmat.data(), dxus.data_mut(), n, d, r, th, ws.packs());
                    ws.recycle(dya);
                    t_matmul_into(
                        xu.data(),
                        dxus.data(),
                        sink.chunk_mut("lotr_s", idx * rr, rr),
                        r,
                        n,
                        r,
                        th,
                        ws.packs(),
                    );
                    matmul_t_into(dxus.data(), sm, dxu.data_mut(), n, r, r, 1, ws.packs());
                    ws.recycle(dxus);
                }
                // Fused: one xᵀ·dxu and one dxu·Uᵀ for both matrices.
                t_matmul_into(
                    x.data(),
                    dxu.data(),
                    sink.chunk_mut("lotr_u", 0, d * r),
                    d,
                    n,
                    r,
                    th,
                    ws.packs(),
                );
                matmul_t_into(dxu.data(), u.data(), dx.data_mut(), n, r, d, th, ws.packs());
                ws.recycle(dxu);
            }
            (kind, _) => panic!("adapter cache mismatch for {kind:?}"),
        }
    }
}

enum PairCache {
    None,
    /// MetaTT-4D / (4+1)D: shared `x·G1` plus the per-matrix `x·G1·mid`.
    Tt { xg1: Tensor, xgm_q: Tensor, xgm_v: Tensor },
    /// MetaTT-5D: shared `x·G1`, per-matrix `x·G1·lm` and per-head stack.
    Tt5 { xg1: Tensor, xlm_q: Tensor, xh_q: Tensor, xlm_v: Tensor, xh_v: Tensor },
    Lora { xa_q: Tensor, xa_v: Tensor },
    /// VeRA: shared `x·A` plus the per-matrix `(x·A ∘ d)·B`.
    Vera { xa: Tensor, tb_q: Tensor, tb_v: Tensor },
    /// LoTR: shared `x·U` plus the per-matrix `x·U·S`.
    Lotr { xu: Tensor, xus_q: Tensor, xus_v: Tensor },
}

impl PairCache {
    fn recycle_into(self, ws: &mut Workspace) {
        match self {
            PairCache::None => {}
            PairCache::Tt { xg1, xgm_q, xgm_v } => {
                ws.recycle_all([xg1, xgm_q, xgm_v]);
            }
            PairCache::Tt5 { xg1, xlm_q, xh_q, xlm_v, xh_v } => {
                ws.recycle_all([xg1, xlm_q, xh_q, xlm_v, xh_v]);
            }
            PairCache::Lora { xa_q, xa_v } => ws.recycle_all([xa_q, xa_v]),
            PairCache::Vera { xa, tb_q, tb_v } => ws.recycle_all([xa, tb_q, tb_v]),
            PairCache::Lotr { xu, xus_q, xus_v } => ws.recycle_all([xu, xus_q, xus_v]),
        }
    }
}

// ---------------------------------------------------------------------------
// Pad-masked multi-head attention over flat pair-major buffers.
// ---------------------------------------------------------------------------

/// Gather the per-(batch, head) blocks of a (n×d) matrix into a flat
/// `[b·h, s, dh]` buffer (each pair's block contiguous and row-major).
fn gather_heads(
    src: &Tensor,
    dst: &mut Tensor,
    b: usize,
    s: usize,
    h: usize,
    dh: usize,
    threads: usize,
) {
    let d = h * dh;
    let ss = src.data();
    let dsh = SharedSliceMut::new(dst.data_mut());
    scope_for(gate(threads, b * h * s * dh), b * h, |pair| {
        let (bi, hi) = (pair / h, pair % h);
        // SAFETY: each pair owns its contiguous flat block.
        let blk = unsafe { dsh.range_mut(pair * s * dh, (pair + 1) * s * dh) };
        for si in 0..s {
            let src_off = (bi * s + si) * d + hi * dh;
            blk[si * dh..(si + 1) * dh].copy_from_slice(&ss[src_off..src_off + dh]);
        }
    });
}

/// Scatter-add flat `[b·h, s, dh]` head blocks back into a (n×d) matrix.
/// Each element receives exactly one pair's contribution, so the result is
/// independent of the thread count.
fn scatter_heads_add(
    src: &Tensor,
    dst: &mut Tensor,
    b: usize,
    s: usize,
    h: usize,
    dh: usize,
    threads: usize,
) {
    let d = h * dh;
    let ss = src.data();
    let dsh = SharedSliceMut::new(dst.data_mut());
    scope_for(gate(threads, b * h * s * dh), b * h, |pair| {
        let (bi, hi) = (pair / h, pair % h);
        for si in 0..s {
            let dst_off = (bi * s + si) * d + hi * dh;
            // SAFETY: (pair, row) destination segments are pairwise disjoint.
            let seg = unsafe { dsh.range_mut(dst_off, dst_off + dh) };
            let srow = &ss[(pair * s + si) * dh..(pair * s + si + 1) * dh];
            for (o, &x) in seg.iter_mut().zip(srow) {
                *o += x;
            }
        }
    });
}

/// Attention forward: returns the context (n×d) and the attention
/// probabilities as one flat `[b·h, s, s]` tensor (the backward cache).
/// The (batch, head) pairs are independent and fan out across workers; all
/// per-pair temporaries live in pre-checked-out flat buffers, so the
/// parallel region itself allocates nothing.
fn attention_forward(
    dims: &Dims,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    tokens: &[i32],
    threads: usize,
    ws: &mut Workspace,
) -> (Tensor, Tensor) {
    let Dims { b, s, n, d, h, dh, .. } = *dims;
    let inv_sqrt_dh = 1.0 / (dh as f32).sqrt();
    let mut qh = ws.take(&[b * h, s, dh]);
    gather_heads(q, &mut qh, b, s, h, dh, threads);
    let mut kh = ws.take(&[b * h, s, dh]);
    gather_heads(k, &mut kh, b, s, h, dh, threads);
    let mut vh = ws.take(&[b * h, s, dh]);
    gather_heads(v, &mut vh, b, s, h, dh, threads);
    let mut probs = ws.take(&[b * h, s, s]);
    let mut ctxh = ws.take(&[b * h, s, dh]);
    {
        let qs = qh.data();
        let ks = kh.data();
        let vs = vh.data();
        let ps = SharedSliceMut::new(probs.data_mut());
        let cs = SharedSliceMut::new(ctxh.data_mut());
        scope_for(gate(threads, b * h * s * s * dh), b * h, |pair| {
            let bi = pair / h;
            let q_blk = &qs[pair * s * dh..(pair + 1) * s * dh];
            let k_blk = &ks[pair * s * dh..(pair + 1) * s * dh];
            let v_blk = &vs[pair * s * dh..(pair + 1) * s * dh];
            // SAFETY: each pair owns its flat probs / ctx blocks.
            let p_blk = unsafe { ps.range_mut(pair * s * s, (pair + 1) * s * s) };
            // In-region GEMMs use the per-worker pack scratch: the arena
            // lives outside this parallel region.
            matmul_t_into_local(q_blk, k_blk, p_blk, s, dh, s, 1);
            scale_into(p_blk, inv_sqrt_dh);
            for key in 0..s {
                if tokens[bi * s + key] == PAD_ID {
                    for query in 0..s {
                        p_blk[query * s + key] += MASK_NEG;
                    }
                }
            }
            softmax_rows_into(p_blk, s, s);
            let c_blk = unsafe { cs.range_mut(pair * s * dh, (pair + 1) * s * dh) };
            matmul_into_local(p_blk, v_blk, c_blk, s, s, dh, 1);
        });
    }
    ws.recycle(qh);
    ws.recycle(kh);
    ws.recycle(vh);
    let mut ctx = ws.take(&[n, d]);
    scatter_heads_add(&ctxh, &mut ctx, b, s, h, dh, threads);
    ws.recycle(ctxh);
    (ctx, probs)
}

/// Attention backward: d(ctx) → (dq, dk, dv), all (n×d). Per-pair math in
/// flat buffers, same fan-out and determinism contract as the forward.
#[allow(clippy::too_many_arguments)]
fn attention_backward(
    dims: &Dims,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    probs: &Tensor,
    d_ctx: &Tensor,
    threads: usize,
    ws: &mut Workspace,
) -> (Tensor, Tensor, Tensor) {
    let Dims { b, s, n, d, h, dh, .. } = *dims;
    let inv_sqrt_dh = 1.0 / (dh as f32).sqrt();
    let mut qh = ws.take(&[b * h, s, dh]);
    gather_heads(q, &mut qh, b, s, h, dh, threads);
    let mut kh = ws.take(&[b * h, s, dh]);
    gather_heads(k, &mut kh, b, s, h, dh, threads);
    let mut vh = ws.take(&[b * h, s, dh]);
    gather_heads(v, &mut vh, b, s, h, dh, threads);
    let mut dctxh = ws.take(&[b * h, s, dh]);
    gather_heads(d_ctx, &mut dctxh, b, s, h, dh, threads);
    let mut dscores = ws.take(&[b * h, s, s]);
    let mut dqh = ws.take(&[b * h, s, dh]);
    let mut dkh = ws.take(&[b * h, s, dh]);
    let mut dvh = ws.take(&[b * h, s, dh]);
    {
        let qs = qh.data();
        let ks = kh.data();
        let vs = vh.data();
        let dcs = dctxh.data();
        let prs = probs.data();
        let dss = SharedSliceMut::new(dscores.data_mut());
        let dqs = SharedSliceMut::new(dqh.data_mut());
        let dks = SharedSliceMut::new(dkh.data_mut());
        let dvs = SharedSliceMut::new(dvh.data_mut());
        scope_for(gate(threads, b * h * s * s * dh), b * h, |pair| {
            let q_blk = &qs[pair * s * dh..(pair + 1) * s * dh];
            let k_blk = &ks[pair * s * dh..(pair + 1) * s * dh];
            let v_blk = &vs[pair * s * dh..(pair + 1) * s * dh];
            let dc_blk = &dcs[pair * s * dh..(pair + 1) * s * dh];
            let p_blk = &prs[pair * s * s..(pair + 1) * s * s];
            // SAFETY: each pair owns its flat output blocks.
            let ds_blk = unsafe { dss.range_mut(pair * s * s, (pair + 1) * s * s) };
            let dq_blk = unsafe { dqs.range_mut(pair * s * dh, (pair + 1) * s * dh) };
            let dk_blk = unsafe { dks.range_mut(pair * s * dh, (pair + 1) * s * dh) };
            let dv_blk = unsafe { dvs.range_mut(pair * s * dh, (pair + 1) * s * dh) };
            // d_probs = d_ctx_h · vhᵀ ; d_vh = probsᵀ · d_ctx_h. (Per-worker
            // pack scratch: the arena lives outside this parallel region.)
            matmul_t_into_local(dc_blk, v_blk, ds_blk, s, dh, s, 1);
            t_matmul_into_local(p_blk, dc_blk, dv_blk, s, s, dh, 1);
            // Softmax backward, row-wise, in place over d_probs.
            for qi in 0..s {
                let pr = &p_blk[qi * s..(qi + 1) * s];
                let dp = &mut ds_blk[qi * s..(qi + 1) * s];
                let dot: f32 = pr.iter().zip(dp.iter()).map(|(&p, &g)| p * g).sum();
                for (dpv, &p) in dp.iter_mut().zip(pr) {
                    *dpv = p * (*dpv - dot);
                }
            }
            // d_qh = d_scores·kh·s ; d_kh = d_scoresᵀ·qh·s.
            matmul_into_local(ds_blk, k_blk, dq_blk, s, s, dh, 1);
            scale_into(dq_blk, inv_sqrt_dh);
            t_matmul_into_local(ds_blk, q_blk, dk_blk, s, s, dh, 1);
            scale_into(dk_blk, inv_sqrt_dh);
        });
    }
    ws.recycle(qh);
    ws.recycle(kh);
    ws.recycle(vh);
    ws.recycle(dctxh);
    ws.recycle(dscores);
    let mut dq = ws.take(&[n, d]);
    scatter_heads_add(&dqh, &mut dq, b, s, h, dh, threads);
    let mut dk = ws.take(&[n, d]);
    scatter_heads_add(&dkh, &mut dk, b, s, h, dh, threads);
    let mut dv = ws.take(&[n, d]);
    scatter_heads_add(&dvh, &mut dv, b, s, h, dh, threads);
    ws.recycle(dqh);
    ws.recycle(dkh);
    ws.recycle(dvh);
    (dq, dk, dv)
}

// ---------------------------------------------------------------------------
// Encoder forward.
// ---------------------------------------------------------------------------

struct LayerCache {
    x_in: Tensor,
    q: Tensor,
    k: Tensor,
    v: Tensor,
    pair: PairCache,
    /// Attention probabilities, flat `[b·h, s, s]`.
    probs: Tensor,
    ctx: Tensor,
    ln1: LnCache,
    x_mid: Tensor,
    u: Tensor,
    g: Tensor,
    ln2: LnCache,
}

impl LayerCache {
    fn recycle_into(self, ws: &mut Workspace) {
        ws.recycle_all([
            self.x_in, self.q, self.k, self.v, self.probs, self.ctx, self.x_mid, self.u,
            self.g,
        ]);
        self.pair.recycle_into(ws);
        self.ln1.recycle_into(ws);
        self.ln2.recycle_into(ws);
    }
}

/// Token + learned-position embedding gather (row-parallel).
fn embed(
    dims: &Dims,
    w: &Weights,
    tokens: &[i32],
    threads: usize,
    ws: &mut Workspace,
) -> Tensor {
    let Dims { s, n, d, .. } = *dims;
    let tok_emb = w.get("tok_emb");
    let pos_emb = w.get("pos_emb");
    let mut x_emb = ws.take(&[n, d]);
    {
        let xs = SharedSliceMut::new(x_emb.data_mut());
        scope_rows(gate(threads, n * d), n, ROW_BAND, |band| {
            // SAFETY: bands are disjoint row ranges of x_emb.
            let dst = unsafe { xs.range_mut(band.start * d, band.end * d) };
            for i in band.clone() {
                let tok = tokens[i] as usize;
                let pos = i % s;
                let te = &tok_emb.data()[tok * d..(tok + 1) * d];
                let pe = &pos_emb.data()[pos * d..(pos + 1) * d];
                let o = (i - band.start) * d;
                for j in 0..d {
                    dst[o + j] = te[j] + pe[j];
                }
            }
        });
    }
    x_emb
}

/// Base Q/K/V projections (frozen weights + biases, no adapter delta).
fn project_qkv_base(
    dims: &Dims,
    w: &Weights,
    x_in: &Tensor,
    layer: usize,
    threads: usize,
    ws: &mut Workspace,
) -> (Tensor, Tensor, Tensor) {
    let Dims { n, d, .. } = *dims;
    let mut q = ws.take(&[n, d]);
    frozen_mm(w, "wq", layer, x_in, &mut q, d, d, threads, ws);
    add_row_bias(&mut q, w.row("bq", layer, d));
    let mut k = ws.take(&[n, d]);
    frozen_mm(w, "wk", layer, x_in, &mut k, d, d, threads, ws);
    add_row_bias(&mut k, w.row("bk", layer, d));
    let mut v = ws.take(&[n, d]);
    frozen_mm(w, "wv", layer, x_in, &mut v, d, d, threads, ws);
    add_row_bias(&mut v, w.row("bv", layer, d));
    (q, k, v)
}

/// Q/K/V projections with the layer's adapter deltas applied to Q and V.
#[allow(clippy::too_many_arguments)]
fn project_qkv(
    dims: &Dims,
    w: &Weights,
    adapter: &AdapterCtx,
    x_in: &Tensor,
    layer: usize,
    threads: usize,
    ws: &mut Workspace,
) -> (Tensor, Tensor, Tensor, PairCache) {
    let (mut q, k, mut v) = project_qkv_base(dims, w, x_in, layer, threads, ws);
    let pair = adapter.apply_pair(ws, x_in, layer, &mut q, &mut v);
    (q, k, v, pair)
}

/// Serving-path adapter delta: `q += x·A₀·B₀`, `v += x·A₁·B₁` with α (and
/// the whole middle of the TT chain) pre-folded into A by
/// [`crate::tt::MetaTt::fold_for_serving`]. The kernels accumulate into
/// their output, so each delta fuses into the projection without a
/// temporary; only the per-matrix `x·A` prefix is a workspace checkout.
fn apply_folded_pair(
    ws: &mut Workspace,
    x: &Tensor,
    pair: &[(Tensor, Tensor)],
    q: &mut Tensor,
    v: &mut Tensor,
    threads: usize,
) {
    let n = x.shape()[0];
    for (m, out) in [(0usize, &mut *q), (1, &mut *v)] {
        let (a, b) = &pair[m];
        let (d_in, ra) = (a.shape()[0], a.shape()[1]);
        debug_assert_eq!(x.shape()[1], d_in);
        let mut xa = ws.take(&[n, ra]);
        matmul_into(x.data(), a.data(), xa.data_mut(), n, d_in, ra, threads, ws.packs());
        matmul_into(
            xa.data(),
            b.data(),
            out.data_mut(),
            n,
            ra,
            b.shape()[1],
            threads,
            ws.packs(),
        );
        ws.recycle(xa);
    }
}

/// [`apply_folded_pair`] over pre-packed factor pairs: both GEMMs route
/// through [`matmul_into_prepacked_any`], skipping the per-tick B pack and
/// decoding quantized panels in the microkernel. The f32 instantiation is
/// bit-identical to the dense path (same kernels, same pack bytes).
fn apply_folded_pair_packed(
    ws: &mut Workspace,
    x: &Tensor,
    pair: &[FoldedPairPacked],
    q: &mut Tensor,
    v: &mut Tensor,
    threads: usize,
) {
    let n = x.shape()[0];
    for (m, out) in [(0usize, &mut *q), (1, &mut *v)] {
        let p = &pair[m];
        let ra = p.a.n();
        debug_assert_eq!(x.shape()[1], p.a.k());
        let mut xa = ws.take(&[n, ra]);
        matmul_into_prepacked_any(x.data(), &p.a, xa.data_mut(), n, threads, ws.packs());
        matmul_into_prepacked_any(xa.data(), &p.b, out.data_mut(), n, threads, ws.packs());
        ws.recycle(xa);
    }
}

/// Adapter representation for the inference forward: the trainable family
/// parameters (the eval path) or pre-folded per-(layer, matrix) factor
/// pairs (the serving path — family-agnostic, two GEMMs per delta), dense
/// or pre-packed at a serving dtype.
enum InferAdapter<'a> {
    Family(AdapterCtx<'a>),
    Folded(&'a [Vec<(Tensor, Tensor)>]),
    FoldedPacked(&'a [Vec<FoldedPairPacked>]),
}

/// Run the encoder; returns final hidden states (n × d) plus the embedding
/// LN cache; per-layer caches are pushed onto `layers` (the scratch's
/// pooled vector). `threads` is the step's worker budget; all parallel
/// splits are along independent rows / (batch, head) pairs so the output is
/// identical for any value.
#[allow(clippy::too_many_arguments)]
fn encoder_forward(
    dims: &Dims,
    w: &Weights,
    adapter: &AdapterCtx,
    tokens: &[i32],
    threads: usize,
    ws: &mut Workspace,
    layers: &mut Vec<LayerCache>,
) -> (Tensor, LnCache) {
    debug_assert!(layers.is_empty(), "stale layer caches");
    let Dims { n, d, f, l, .. } = *dims;
    let x_emb = embed(dims, w, tokens, threads, ws);
    let (x0, emb_ln) = layer_norm(&x_emb, w.vec("emb_ln_g"), w.vec("emb_ln_b"), threads, ws);
    ws.recycle(x_emb);

    let mut x = x0;
    for layer in 0..l {
        let x_in = x;
        let (q, k, v, pair) = project_qkv(dims, w, adapter, &x_in, layer, threads, ws);
        let (ctx, probs) = attention_forward(dims, &q, &k, &v, tokens, threads, ws);
        let mut attn_out = ws.take(&[n, d]);
        frozen_mm(w, "wo", layer, &ctx, &mut attn_out, d, d, threads, ws);
        add_row_bias(&mut attn_out, w.row("bo", layer, d));
        let res1 = add_ws(ws, &x_in, &attn_out);
        ws.recycle(attn_out);
        let (x_mid, ln1) =
            layer_norm(&res1, w.row("ln1_g", layer, d), w.row("ln1_b", layer, d), threads, ws);
        ws.recycle(res1);

        // GELU MLP (tanh GELU is the most expensive elementwise op in the
        // step — band-parallel over rows).
        let mut u = ws.take(&[n, f]);
        frozen_mm(w, "w1", layer, &x_mid, &mut u, d, f, threads, ws);
        add_row_bias(&mut u, w.row("b1", layer, f));
        let g = gelu_ws(ws, &u, threads);
        let mut m_out = ws.take(&[n, d]);
        frozen_mm(w, "w2", layer, &g, &mut m_out, f, d, threads, ws);
        add_row_bias(&mut m_out, w.row("b2", layer, d));
        let res2 = add_ws(ws, &x_mid, &m_out);
        ws.recycle(m_out);
        let (x_out, ln2) =
            layer_norm(&res2, w.row("ln2_g", layer, d), w.row("ln2_b", layer, d), threads, ws);
        ws.recycle(res2);

        layers.push(LayerCache { x_in, q, k, v, pair, probs, ctx, ln1, x_mid, u, g, ln2 });
        x = x_out;
    }
    (x, emb_ln)
}

/// Inference-mode encoder forward: bit-identical hidden states, but no
/// backward cache is built at all — every intermediate (LN stats, attention
/// probabilities, adapter prefixes, layer activations) is recycled as soon
/// as its consumer has run. `adapter` selects the delta form: the trainable
/// family parameters (`eval_step`) or pre-folded factor pairs
/// (`serve_step` — the multi-task serving engine's hot path).
fn encoder_forward_infer(
    dims: &Dims,
    w: &Weights,
    adapter: &InferAdapter,
    tokens: &[i32],
    threads: usize,
    ws: &mut Workspace,
) -> Tensor {
    let Dims { n, d, f, l, .. } = *dims;
    let x_emb = embed(dims, w, tokens, threads, ws);
    let x0 = layer_norm_infer(&x_emb, w.vec("emb_ln_g"), w.vec("emb_ln_b"), threads, ws);
    ws.recycle(x_emb);

    let mut x = x0;
    for layer in 0..l {
        let x_in = x;
        let (q, k, v) = match adapter {
            InferAdapter::Family(ctx) => {
                let (q, k, v, pair) = project_qkv(dims, w, ctx, &x_in, layer, threads, ws);
                pair.recycle_into(ws);
                (q, k, v)
            }
            InferAdapter::Folded(pairs) => {
                let (mut q, k, mut v) = project_qkv_base(dims, w, &x_in, layer, threads, ws);
                apply_folded_pair(ws, &x_in, &pairs[layer], &mut q, &mut v, threads);
                (q, k, v)
            }
            InferAdapter::FoldedPacked(pairs) => {
                let (mut q, k, mut v) = project_qkv_base(dims, w, &x_in, layer, threads, ws);
                apply_folded_pair_packed(ws, &x_in, &pairs[layer], &mut q, &mut v, threads);
                (q, k, v)
            }
        };
        let (ctx, probs) = attention_forward(dims, &q, &k, &v, tokens, threads, ws);
        ws.recycle_all([q, k, v, probs]);
        let mut attn_out = ws.take(&[n, d]);
        frozen_mm(w, "wo", layer, &ctx, &mut attn_out, d, d, threads, ws);
        add_row_bias(&mut attn_out, w.row("bo", layer, d));
        ws.recycle(ctx);
        let res1 = add_ws(ws, &x_in, &attn_out);
        ws.recycle(attn_out);
        ws.recycle(x_in);
        let x_mid =
            layer_norm_infer(&res1, w.row("ln1_g", layer, d), w.row("ln1_b", layer, d), threads, ws);
        ws.recycle(res1);

        let mut u = ws.take(&[n, f]);
        frozen_mm(w, "w1", layer, &x_mid, &mut u, d, f, threads, ws);
        add_row_bias(&mut u, w.row("b1", layer, f));
        let g = gelu_ws(ws, &u, threads);
        ws.recycle(u);
        let mut m_out = ws.take(&[n, d]);
        frozen_mm(w, "w2", layer, &g, &mut m_out, f, d, threads, ws);
        add_row_bias(&mut m_out, w.row("b2", layer, d));
        ws.recycle(g);
        let res2 = add_ws(ws, &x_mid, &m_out);
        ws.recycle(m_out);
        ws.recycle(x_mid);
        let x_out =
            layer_norm_infer(&res2, w.row("ln2_g", layer, d), w.row("ln2_b", layer, d), threads, ws);
        ws.recycle(res2);
        x = x_out;
    }
    x
}

// ---------------------------------------------------------------------------
// Encoder backward.
// ---------------------------------------------------------------------------

/// Reverse pass through the encoder. `d_hidden` is ∂L/∂(final hidden
/// states). Adapter grads always flow into `sink`; encoder-weight grads
/// only when `train_encoder` (full FT / pretraining). Layer caches are
/// drained off `layers` and recycled as each layer completes, so the
/// scratch vector is empty (capacity retained) on return.
#[allow(clippy::too_many_arguments)]
fn encoder_backward(
    dims: &Dims,
    w: &Weights,
    adapter: &AdapterCtx,
    tokens: &[i32],
    layers: &mut Vec<LayerCache>,
    emb_ln: LnCache,
    d_hidden: Tensor,
    sink: &mut GradSink,
    train_encoder: bool,
    threads: usize,
    ws: &mut Workspace,
) {
    let Dims { s, n, d, f, .. } = *dims;
    let mut dx = d_hidden; // gradient w.r.t. the current layer's output
    while let Some(lc) = layers.pop() {
        let layer = layers.len();

        // --- LN2 over (x_mid + m_out).
        let d_res2 = if train_encoder {
            let (dg, db) =
                sink.two_chunks_mut(("ln2_g", layer * d, d), ("ln2_b", layer * d, d));
            layer_norm_backward(&dx, &lc.ln2, w.row("ln2_g", layer, d), Some((dg, db)), threads, ws)
        } else {
            layer_norm_backward(&dx, &lc.ln2, w.row("ln2_g", layer, d), None, threads, ws)
        };
        ws.recycle(dx);

        // --- MLP: m_out = gelu(x_mid·w1 + b1)·w2 + b2.
        // residual: d(m_out) = d_res2, d(x_mid) += d_res2
        let w1c = w.chunk("w1", layer, d * f);
        let w2c = w.chunk("w2", layer, f * d);
        if train_encoder {
            t_matmul_into(
                lc.g.data(),
                d_res2.data(),
                sink.chunk_mut("w2", layer * f * d, f * d),
                f,
                n,
                d,
                threads,
                ws.packs(),
            );
            colsum_acc(&d_res2, sink.chunk_mut("b2", layer * d, d));
        }
        let mut dgelu = mm_wt(ws, &d_res2, w2c, f, threads);
        {
            let dgs = SharedSliceMut::new(dgelu.data_mut());
            let us = lc.u.data();
            scope_rows(gate(threads, n * f), n, ROW_BAND, |band| {
                // SAFETY: bands are disjoint row ranges of dgelu.
                let dst = unsafe { dgs.range_mut(band.start * f, band.end * f) };
                for (dv, &uv) in dst.iter_mut().zip(&us[band.start * f..band.end * f]) {
                    *dv *= gelu_prime(uv);
                }
            });
        }
        if train_encoder {
            t_matmul_into(
                lc.x_mid.data(),
                dgelu.data(),
                sink.chunk_mut("w1", layer * d * f, d * f),
                d,
                n,
                f,
                threads,
                ws.packs(),
            );
            colsum_acc(&dgelu, sink.chunk_mut("b1", layer * f, f));
        }
        let mut d_xmid = ws.take(&[n, d]);
        d_xmid.data_mut().copy_from_slice(d_res2.data());
        acc_mm_wt(&mut d_xmid, &dgelu, w1c, d, threads, ws);
        ws.recycle(d_res2);
        ws.recycle(dgelu);

        // --- LN1 over (x_in + attn_out).
        let d_res1 = if train_encoder {
            let (dg, db) =
                sink.two_chunks_mut(("ln1_g", layer * d, d), ("ln1_b", layer * d, d));
            layer_norm_backward(&d_xmid, &lc.ln1, w.row("ln1_g", layer, d), Some((dg, db)), threads, ws)
        } else {
            layer_norm_backward(&d_xmid, &lc.ln1, w.row("ln1_g", layer, d), None, threads, ws)
        };
        ws.recycle(d_xmid);

        // --- Output projection: attn_out = ctx·wo + bo.
        let woc = w.chunk("wo", layer, d * d);
        if train_encoder {
            t_matmul_into(
                lc.ctx.data(),
                d_res1.data(),
                sink.chunk_mut("wo", layer * d * d, d * d),
                d,
                n,
                d,
                threads,
                ws.packs(),
            );
            colsum_acc(&d_res1, sink.chunk_mut("bo", layer * d, d));
        }
        let d_ctx = mm_wt(ws, &d_res1, woc, d, threads);

        // --- Attention backward per (batch, head).
        let (dq, dk, dv) =
            attention_backward(dims, &lc.q, &lc.k, &lc.v, &lc.probs, &d_ctx, threads, ws);
        ws.recycle(d_ctx);

        // --- Projections + adapters back to the layer input.
        let wqc = w.chunk("wq", layer, d * d);
        let wkc = w.chunk("wk", layer, d * d);
        let wvc = w.chunk("wv", layer, d * d);
        let mut d_xin = d_res1; // residual branch seeds the accumulator
        acc_mm_wt(&mut d_xin, &dq, wqc, d, threads, ws);
        acc_mm_wt(&mut d_xin, &dk, wkc, d, threads, ws);
        acc_mm_wt(&mut d_xin, &dv, wvc, d, threads, ws);
        if train_encoder {
            t_matmul_into(
                lc.x_in.data(),
                dq.data(),
                sink.chunk_mut("wq", layer * d * d, d * d),
                d,
                n,
                d,
                threads,
                ws.packs(),
            );
            colsum_acc(&dq, sink.chunk_mut("bq", layer * d, d));
            t_matmul_into(
                lc.x_in.data(),
                dk.data(),
                sink.chunk_mut("wk", layer * d * d, d * d),
                d,
                n,
                d,
                threads,
                ws.packs(),
            );
            colsum_acc(&dk, sink.chunk_mut("bk", layer * d, d));
            t_matmul_into(
                lc.x_in.data(),
                dv.data(),
                sink.chunk_mut("wv", layer * d * d, d * d),
                d,
                n,
                d,
                threads,
                ws.packs(),
            );
            colsum_acc(&dv, sink.chunk_mut("bv", layer * d, d));
        }
        adapter.backward_pair(ws, &lc.x_in, layer, &lc.pair, &dq, &dv, &mut d_xin, sink);
        ws.recycle_all([dq, dk, dv]);
        lc.recycle_into(ws);
        dx = d_xin;
    }

    // --- Embedding LN + scatter.
    let d_emb = if train_encoder {
        let (dg, db) = sink.two_chunks_mut(("emb_ln_g", 0, d), ("emb_ln_b", 0, d));
        layer_norm_backward(&dx, &emb_ln, w.vec("emb_ln_g"), Some((dg, db)), threads, ws)
    } else {
        layer_norm_backward(&dx, &emb_ln, w.vec("emb_ln_g"), None, threads, ws)
    };
    ws.recycle(dx);
    emb_ln.recycle_into(ws);
    if train_encoder {
        for i in 0..n {
            let tok = tokens[i] as usize;
            let pos = i % s;
            let row = &d_emb.data()[i * d..(i + 1) * d];
            sink.add_chunk("tok_emb", tok * d, row);
            sink.add_chunk("pos_emb", pos * d, row);
        }
    }
    ws.recycle(d_emb);
}

// ---------------------------------------------------------------------------
// Task head + losses.
// ---------------------------------------------------------------------------

/// CLS-pooled logits through the frozen per-task head (workspace-backed).
fn head_logits(
    dims: &Dims,
    w: &Weights,
    hidden: &Tensor,
    task: usize,
    threads: usize,
    ws: &mut Workspace,
) -> Tensor {
    let Dims { b, s, d, classes, .. } = *dims;
    let cls_w = w.chunk("cls_w", task, d * classes);
    let cls_b = &w.get("cls_b").data()[task * classes..(task + 1) * classes];
    let mut pooled = ws.take(&[b, d]);
    for bi in 0..b {
        let src = &hidden.data()[bi * s * d..bi * s * d + d]; // CLS row
        pooled.data_mut()[bi * d..(bi + 1) * d].copy_from_slice(src);
    }
    let mut logits = ws.take(&[b, classes]);
    matmul_into(pooled.data(), cls_w, logits.data_mut(), b, d, classes, threads, ws.packs());
    add_row_bias(&mut logits, cls_b);
    ws.recycle(pooled);
    logits
}

/// Weighted task loss + ∂loss/∂logits (CE for classification, MSE on
/// score/5 for the regression analogue).
fn task_loss_grad(
    logits: &Tensor,
    batch: &Batch,
    classes: usize,
    ws: &mut Workspace,
) -> (f32, Tensor) {
    let b = batch.batch_size;
    let wsum: f32 = batch.weights.iter().sum::<f32>().max(1e-6);
    let mut dlogits = ws.take(&[b, classes]);
    let mut loss = 0.0f64;
    if classes == 1 {
        for i in 0..b {
            let pred = logits.at(i, 0);
            let target = batch.scores[i] / 5.0;
            let wgt = batch.weights[i];
            loss += ((pred - target) * (pred - target) * wgt) as f64;
            dlogits.set(i, 0, 2.0 * (pred - target) * wgt / wsum);
        }
    } else {
        for i in 0..b {
            let row = &logits.data()[i * classes..(i + 1) * classes];
            let mx = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
            let z: f32 = row.iter().map(|&v| (v - mx).exp()).sum();
            let lz = z.ln() + mx;
            let label = batch.labels[i] as usize;
            let wgt = batch.weights[i];
            loss += ((lz - row[label]) * wgt) as f64;
            for c in 0..classes {
                let p = (row[c] - lz).exp();
                let ind = if c == label { 1.0 } else { 0.0 };
                dlogits.set(i, c, (p - ind) * wgt / wsum);
            }
        }
    }
    ((loss / wsum as f64) as f32, dlogits)
}

// ---------------------------------------------------------------------------
// Public step entry points (used by the reference backend).
// ---------------------------------------------------------------------------

fn validate_batch(entry: &ArtifactEntry, batch_size: usize, seq_len: usize) -> Result<()> {
    if batch_size != entry.spec.batch || seq_len != entry.spec.seq {
        bail!(
            "batch shape ({batch_size}, {seq_len}) does not match spec {} ({}, {})",
            entry.spec.stem(),
            entry.spec.batch,
            entry.spec.seq
        );
    }
    Ok(())
}

/// One fwd+bwd fine-tuning step. Returns (loss, grads in trainable order).
/// `threads` is the worker budget; results are identical for any value and
/// for the arena on or off. The returned gradient tensors are workspace
/// checkouts — hand them back through `Step::recycle` once consumed to keep
/// the steady-state loop allocation-free.
#[allow(clippy::too_many_arguments)]
pub fn train_step(
    entry: &ArtifactEntry,
    frozen: &HashMap<String, Tensor>,
    trainable: &[Tensor],
    batch: &Batch,
    task_id: i32,
    alpha: f32,
    threads: usize,
    scratch: &mut StepScratch,
) -> Result<(f32, Vec<Tensor>)> {
    validate_batch(entry, batch.batch_size, batch.seq_len)?;
    let dims = dims_of(entry)?;
    let task = task_id as usize;
    let kind = adapter_kind_of(entry)?;
    let train_encoder = entry.spec.adapter == "full";
    let StepScratch { ws, index, grad_index, pre, layers, packed, .. } = scratch;
    let w = Weights { index: &*index, frozen, trainable, packed: &**packed };
    pre.fill(kind, &dims, trainable, entry.spec.rank, task, 2, true, ws);
    let adapter = AdapterCtx {
        kind,
        params: trainable,
        alpha,
        task,
        rank: entry.spec.rank,
        heads: dims.h,
        matrices: 2,
        d: dims.d,
        threads,
        pre: &*pre,
    };

    let (hidden, emb_ln) = encoder_forward(&dims, &w, &adapter, &batch.tokens, threads, ws, layers);
    let logits = head_logits(&dims, &w, &hidden, task, threads, ws);
    let (loss, dlogits) = task_loss_grad(&logits, batch, dims.classes, ws);
    ws.recycle(logits);
    ws.recycle(hidden);

    // Head is frozen: only ∂/∂pooled flows back, scattered into CLS rows.
    let cls_chunk = w.chunk("cls_w", task, dims.d * dims.classes);
    let d_pooled = mm_wt(ws, &dlogits, cls_chunk, dims.d, threads);
    ws.recycle(dlogits);
    let mut d_hidden = ws.take(&[dims.n, dims.d]);
    for bi in 0..dims.b {
        let dst = bi * dims.s * dims.d;
        let src = &d_pooled.data()[bi * dims.d..(bi + 1) * dims.d];
        d_hidden.data_mut()[dst..dst + dims.d].copy_from_slice(src);
    }
    ws.recycle(d_pooled);

    let mut sink = GradSink::new(entry.trainable_inputs(), &*grad_index, ws);
    encoder_backward(
        &dims,
        &w,
        &adapter,
        &batch.tokens,
        layers,
        emb_ln,
        d_hidden,
        &mut sink,
        train_encoder,
        threads,
        ws,
    );
    pre.recycle_into(ws);
    Ok((loss, sink.into_vec()))
}

/// One fwd (eval) step. Returns logits `[batch, classes]`. Runs the
/// cache-free inference forward: no layer caches, no backward-only adapter
/// products, every intermediate recycled in place.
#[allow(clippy::too_many_arguments)]
pub fn eval_step(
    entry: &ArtifactEntry,
    frozen: &HashMap<String, Tensor>,
    trainable: &[Tensor],
    batch: &Batch,
    task_id: i32,
    alpha: f32,
    threads: usize,
    scratch: &mut StepScratch,
) -> Result<Tensor> {
    validate_batch(entry, batch.batch_size, batch.seq_len)?;
    let dims = dims_of(entry)?;
    let task = task_id as usize;
    let kind = adapter_kind_of(entry)?;
    let StepScratch { ws, index, pre, packed, .. } = scratch;
    let w = Weights { index: &*index, frozen, trainable, packed: &**packed };
    pre.fill(kind, &dims, trainable, entry.spec.rank, task, 2, false, ws);
    let adapter = InferAdapter::Family(AdapterCtx {
        kind,
        params: trainable,
        alpha,
        task,
        rank: entry.spec.rank,
        heads: dims.h,
        matrices: 2,
        d: dims.d,
        threads,
        pre: &*pre,
    });
    let hidden = encoder_forward_infer(&dims, &w, &adapter, &batch.tokens, threads, ws);
    let logits = head_logits(&dims, &w, &hidden, task, threads, ws);
    ws.recycle(hidden);
    pre.recycle_into(ws);
    Ok(logits)
}

/// One batched serving forward (the multi-task engine's hot path): the
/// cache-free inference encoder over **pre-folded** adapter factor pairs
/// (`MetaTt::fold_for_serving` — family-agnostic, exactly two extra GEMMs
/// per adapted projection), CLS-pooled through the frozen head of `task_id`.
/// Logits are written into `out` (`batch · classes`, row-major) and nothing
/// escapes the workspace, so a warmed serving tick performs zero heap
/// allocations (pinned by `tests/alloc_regression.rs`).
///
/// Every row of the batch depends only on its own tokens (row-banded GEMMs,
/// per-row LayerNorm/softmax, per-(batch, head) attention), so a response's
/// bits are independent of which other requests were coalesced into the
/// batch — the property that makes dynamic batching transparent to clients
/// (pinned by `tests/serving.rs`).
#[allow(clippy::too_many_arguments)]
pub fn serve_step(
    entry: &ArtifactEntry,
    frozen: &HashMap<String, Tensor>,
    pairs: &[Vec<(Tensor, Tensor)>],
    tokens: &[i32],
    task_id: i32,
    threads: usize,
    scratch: &mut StepScratch,
    out: &mut [f32],
) -> Result<()> {
    let dims = dims_of(entry)?;
    validate_serve_io(entry, &dims, tokens, task_id, pairs.len(), out)?;
    for (l, row) in pairs.iter().enumerate() {
        if row.len() != 2 {
            bail!("serve: layer {l} folds {} matrices, expected 2 (Q, V)", row.len());
        }
        for (m, (a, b)) in row.iter().enumerate() {
            let ra = a.shape()[a.ndim() - 1];
            if a.shape() != &[dims.d, ra][..] || b.shape() != &[ra, dims.d][..] {
                bail!(
                    "serve: folded pair (layer {l}, matrix {m}) has shapes {:?}/{:?}, \
                     want [{d}, r]/[r, {d}]",
                    a.shape(),
                    b.shape(),
                    d = dims.d
                );
            }
        }
    }
    let StepScratch { ws, index, packed, .. } = scratch;
    let w = Weights { index: &*index, frozen, trainable: &[], packed: &**packed };
    let hidden =
        encoder_forward_infer(&dims, &w, &InferAdapter::Folded(pairs), tokens, threads, ws);
    let logits = head_logits(&dims, &w, &hidden, task_id as usize, threads, ws);
    ws.recycle(hidden);
    out.copy_from_slice(logits.data());
    ws.recycle(logits);
    Ok(())
}

/// [`serve_step`] over **pre-packed** folded factor pairs: the adapter
/// GEMMs run [`matmul_into_prepacked_any`] against panels packed once at
/// fold time ([`FoldedPairPacked::pack`]) instead of re-packing the dense
/// factors every tick. At `DtypeKind::F32` the logits are bit-identical to
/// [`serve_step`] on the dense pairs; quantized dtypes carry the dtype's
/// tolerance contract (pinned by the parity tests in `tests/serving.rs`).
#[allow(clippy::too_many_arguments)]
pub fn serve_step_packed(
    entry: &ArtifactEntry,
    frozen: &HashMap<String, Tensor>,
    pairs: &[Vec<FoldedPairPacked>],
    tokens: &[i32],
    task_id: i32,
    threads: usize,
    scratch: &mut StepScratch,
    out: &mut [f32],
) -> Result<()> {
    let dims = dims_of(entry)?;
    validate_serve_io(entry, &dims, tokens, task_id, pairs.len(), out)?;
    for (l, row) in pairs.iter().enumerate() {
        if row.len() != 2 {
            bail!("serve: layer {l} folds {} matrices, expected 2 (Q, V)", row.len());
        }
        for (m, p) in row.iter().enumerate() {
            if p.a.k() != dims.d || p.b.k() != p.a.n() || p.b.n() != dims.d {
                bail!(
                    "serve: packed folded pair (layer {l}, matrix {m}) has shapes \
                     [{}, {}]/[{}, {}], want [{d}, r]/[r, {d}]",
                    p.a.k(),
                    p.a.n(),
                    p.b.k(),
                    p.b.n(),
                    d = dims.d
                );
            }
        }
    }
    let StepScratch { ws, index, packed, .. } = scratch;
    let w = Weights { index: &*index, frozen, trainable: &[], packed: &**packed };
    let hidden =
        encoder_forward_infer(&dims, &w, &InferAdapter::FoldedPacked(pairs), tokens, threads, ws);
    let logits = head_logits(&dims, &w, &hidden, task_id as usize, threads, ws);
    ws.recycle(hidden);
    out.copy_from_slice(logits.data());
    ws.recycle(logits);
    Ok(())
}

/// The serve-entry validation shared by the dense and packed paths:
/// token count, task range, folded layer count, and output buffer size.
fn validate_serve_io(
    entry: &ArtifactEntry,
    dims: &Dims,
    tokens: &[i32],
    task_id: i32,
    n_pair_layers: usize,
    out: &[f32],
) -> Result<()> {
    if tokens.len() != dims.n {
        bail!(
            "serve: {} tokens supplied, spec {} wants {} ({} x {})",
            tokens.len(),
            entry.spec.stem(),
            dims.n,
            dims.b,
            dims.s
        );
    }
    if task_id < 0 || task_id as usize >= entry.spec.tasks.max(1) {
        bail!("serve: task {} out of range ({} heads)", task_id, entry.spec.tasks.max(1));
    }
    if n_pair_layers != dims.l {
        bail!("serve: folded adapter has {} layers, model has {}", n_pair_layers, dims.l);
    }
    if out.len() != dims.b * dims.classes {
        bail!(
            "serve: output buffer holds {} floats, batch {} x {} classes needs {}",
            out.len(),
            dims.b,
            dims.classes,
            dims.b * dims.classes
        );
    }
    Ok(())
}

/// One MLM pretraining step over all encoder weights (weight-tied output
/// head: logits = h · tok_embᵀ). Returns (loss, grads).
pub fn pretrain_step(
    entry: &ArtifactEntry,
    frozen: &HashMap<String, Tensor>,
    trainable: &[Tensor],
    batch: &MlmBatch,
    threads: usize,
    scratch: &mut StepScratch,
) -> Result<(f32, Vec<Tensor>)> {
    validate_batch(entry, batch.batch_size, batch.seq_len)?;
    let dims = dims_of(entry)?;
    let StepScratch { ws, index, grad_index, pre, layers, row_loss, packed } = scratch;
    let w = Weights { index: &*index, frozen, trainable, packed: &**packed };
    let adapter = AdapterCtx {
        kind: None,
        params: trainable,
        alpha: 0.0,
        task: 0,
        rank: 0,
        heads: dims.h,
        matrices: 2,
        d: dims.d,
        threads,
        pre: &*pre,
    };
    let (hidden, emb_ln) = encoder_forward(&dims, &w, &adapter, &batch.tokens, threads, ws, layers);

    // Weight-tied MLM head over every position. The vocab softmax is the
    // most expensive row loop of the whole pretrain step: rows fan out
    // across workers; the scalar loss reduces serially in row order so the
    // sum never depends on the thread count.
    let tok_emb = w.get("tok_emb"); // (v, d)
    let (n, v, d) = (dims.n, dims.v, dims.d);
    let mut logits = ws.take(&[n, v]);
    matmul_t_into(hidden.data(), tok_emb.data(), logits.data_mut(), n, d, v, threads, ws.packs());
    let wsum: f32 = batch.weights.iter().sum::<f32>().max(1e-6);
    let mut dlogits = ws.take(&[n, v]);
    row_loss.clear();
    row_loss.resize(n, 0.0);
    {
        let dls = SharedSliceMut::new(dlogits.data_mut());
        let rls = SharedSliceMut::new(&mut row_loss[..]);
        scope_rows(gate(threads, n * v), n, ROW_BAND, |band| {
            // SAFETY: bands are disjoint row ranges of dlogits / row_loss.
            let d_band = unsafe { dls.range_mut(band.start * v, band.end * v) };
            let l_band = unsafe { rls.range_mut(band.start, band.end) };
            for i in band.clone() {
                let wgt = batch.weights[i];
                let row = &logits.data()[i * v..(i + 1) * v];
                let target = batch.targets[i] as usize;
                let mx = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
                let z: f32 = row.iter().map(|&x| (x - mx).exp()).sum();
                let lz = z.ln() + mx;
                if wgt != 0.0 {
                    l_band[i - band.start] = ((lz - row[target]) * wgt) as f64;
                }
                let scale = wgt / wsum;
                if scale != 0.0 {
                    let drow = &mut d_band[(i - band.start) * v..(i - band.start + 1) * v];
                    for c in 0..v {
                        let p = (row[c] - lz).exp();
                        drow[c] = p * scale;
                    }
                    drow[target] -= scale;
                }
            }
        });
    }
    ws.recycle(logits);
    let loss: f64 = row_loss.iter().sum(); // fixed row order
    let loss = (loss / wsum as f64) as f32;

    let mut sink = GradSink::new(entry.trainable_inputs(), &*grad_index, ws);
    // Head: dh = dlogits · tok_emb ; d tok_emb += dlogitsᵀ · hidden.
    let d_hidden = mm(ws, &dlogits, tok_emb, threads);
    t_matmul_into(
        dlogits.data(),
        hidden.data(),
        sink.chunk_mut("tok_emb", 0, v * d),
        v,
        n,
        d,
        threads,
        ws.packs(),
    );
    ws.recycle(dlogits);
    ws.recycle(hidden);
    encoder_backward(
        &dims,
        &w,
        &adapter,
        &batch.tokens,
        layers,
        emb_ln,
        d_hidden,
        &mut sink,
        true,
        threads,
        ws,
    );
    Ok((loss, sink.into_vec()))
}

/// Raw positional apply (serving hot path): `y = x·g1·mid·g4` (TT families)
/// or `y = x·a·b` (LoRA), α = 1 as baked into the AOT apply artifacts.
/// Intermediates come from the step workspace; only the output escapes.
pub fn apply_step(
    entry: &ArtifactEntry,
    inputs: &[Tensor],
    threads: usize,
    scratch: &mut StepScratch,
) -> Result<Vec<Tensor>> {
    if inputs.len() != entry.inputs.len() {
        bail!(
            "apply expects {} inputs, got {}",
            entry.inputs.len(),
            inputs.len()
        );
    }
    for (t, io) in inputs.iter().zip(&entry.inputs) {
        if t.shape() != &io.shape[..] {
            bail!(
                "apply input '{}': shape {:?}, spec wants {:?}",
                io.name,
                t.shape(),
                io.shape
            );
        }
    }
    let ws = scratch.workspace_mut();
    let y = if entry.spec.adapter == "lora" {
        let xa = mm(ws, &inputs[0], &inputs[1], threads);
        let y = inputs_mm_out(&xa, &inputs[2], threads);
        ws.recycle(xa);
        y
    } else {
        let xg = mm(ws, &inputs[0], &inputs[1], threads);
        let xm = mm(ws, &xg, &inputs[2], threads);
        ws.recycle(xg);
        let y = inputs_mm_out(&xm, &inputs[3], threads);
        ws.recycle(xm);
        y
    };
    Ok(vec![y])
}

/// Final apply GEMM into a plain (escaping) tensor (per-thread pack
/// scratch: the output allocates anyway, and no workspace is in scope).
fn inputs_mm_out(a: &Tensor, b: &Tensor, threads: usize) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let n = b.shape()[b.ndim() - 1];
    let mut out = Tensor::zeros(&[m, n]);
    matmul_into_local(a.data(), b.data(), out.data_mut(), m, k, n, threads);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::assert_close;

    #[test]
    fn gelu_matches_finite_difference() {
        let eps = 1e-3f32;
        for &u in &[-3.0f32, -1.0, -0.1, 0.0, 0.5, 2.0] {
            let fd = (gelu(u + eps) - gelu(u - eps)) / (2.0 * eps);
            let an = gelu_prime(u);
            assert!((fd - an).abs() < 1e-3, "u={u}: fd {fd} vs {an}");
        }
        // Known values: gelu(0) = 0, gelu(∞) → identity.
        assert_eq!(gelu(0.0), 0.0);
        assert!((gelu(10.0) - 10.0).abs() < 1e-4);
    }

    #[test]
    fn layer_norm_backward_matches_finite_difference() {
        let mut ws = Workspace::new(true);
        let mut rng = Pcg64::new(9);
        let x = Tensor::randn(&[3, 8], 1.0, &mut rng);
        let gamma: Vec<f32> = (0..8).map(|j| 1.0 + 0.1 * j as f32).collect();
        let beta = vec![0.05f32; 8];
        let dy = Tensor::randn(&[3, 8], 1.0, &mut rng);
        let (_, cache) = layer_norm(&x, &gamma, &beta, 1, &mut ws);
        let dx = layer_norm_backward(&dy, &cache, &gamma, None, 1, &mut ws);
        // Scalar objective: L = Σ y ∘ dy; check a handful of coordinates.
        let mut loss = |xp: &Tensor| -> f32 {
            let (y, c) = layer_norm(xp, &gamma, &beta, 1, &mut ws);
            let l = y.data().iter().zip(dy.data()).map(|(&a, &b)| a * b).sum();
            c.recycle_into(&mut ws);
            ws.recycle(y);
            l
        };
        let eps = 1e-3;
        for &(i, j) in &[(0usize, 0usize), (1, 3), (2, 7)] {
            let mut xp = x.clone();
            xp.data_mut()[i * 8 + j] += eps;
            let mut xm = x.clone();
            xm.data_mut()[i * 8 + j] -= eps;
            let fd = (loss(&xp) - loss(&xm)) / (2.0 * eps);
            let an = dx.data()[i * 8 + j];
            assert!((fd - an).abs() < 2e-2, "({i},{j}): fd {fd} vs {an}");
        }
    }

    #[test]
    fn layer_norm_infer_matches_cached_forward_bitwise() {
        let mut ws = Workspace::new(true);
        let mut rng = Pcg64::new(12);
        let x = Tensor::randn(&[5, 16], 1.3, &mut rng);
        let gamma: Vec<f32> = (0..16).map(|j| 0.8 + 0.05 * j as f32).collect();
        let beta: Vec<f32> = (0..16).map(|j| 0.01 * j as f32).collect();
        let (y, cache) = layer_norm(&x, &gamma, &beta, 1, &mut ws);
        let y_inf = layer_norm_infer(&x, &gamma, &beta, 1, &mut ws);
        assert_eq!(y, y_inf, "inference LN must be bit-identical");
        cache.recycle_into(&mut ws);
    }

    #[test]
    fn block_helpers_roundtrip() {
        let mut ws = Workspace::new(true);
        let mut rng = Pcg64::new(2);
        let m = Tensor::randn(&[6, 10], 1.0, &mut rng);
        let blk = copy_block(&mut ws, &m, 2, 3, 4, 5);
        assert_eq!(blk.shape(), &[3, 5]);
        assert_eq!(blk.at(0, 0), m.at(2, 4));
        assert_eq!(blk.at(2, 4), m.at(4, 8));
        let mut dst = Tensor::zeros(&[6, 10]);
        add_block_scaled(&mut dst, 2, 4, &blk, 2.0);
        assert_eq!(dst.at(2, 4), 2.0 * m.at(2, 4));
        assert_eq!(dst.at(4, 8), 2.0 * m.at(4, 8));
        assert_eq!(dst.at(0, 0), 0.0);
    }

    #[test]
    fn column_helpers_accumulate() {
        let mut ws = Workspace::new(true);
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let mut cs = vec![1.0f32; 3];
        colsum_acc(&t, &mut cs);
        assert_close(&cs, &[6., 8., 10.], 1e-6, 1e-6, "colsum_acc");
        let m = mul_cols_ws(&mut ws, &t, &[2.0, 0.0, 1.0]);
        assert_eq!(m.data(), &[2., 0., 3., 8., 0., 6.]);
        let mut cm = vec![0.0f32; 3];
        colsum_mul_acc(&t, &t, &mut cm);
        assert_close(&cm, &[17.0, 29.0, 45.0], 1e-6, 1e-6, "colsum_mul_acc");
        // acc_mul_cols / acc_mul_cols_scaled against the manual forms.
        let mut acc = Tensor::zeros(&[2, 3]);
        acc_mul_cols(&mut acc, &t, &[1.0, 2.0, 3.0]);
        assert_eq!(acc.data(), &[1., 4., 9., 4., 10., 18.]);
        let mut acc2 = Tensor::zeros(&[2, 3]);
        acc_mul_cols_scaled(&mut acc2, &t, &[1.0, 2.0, 3.0], 0.5);
        assert_eq!(acc2.data(), &[0.5, 2., 4.5, 2., 5., 9.]);
    }

    #[test]
    fn gather_scatter_heads_roundtrip() {
        let mut ws = Workspace::new(true);
        let (b, s, h, dh) = (2usize, 3usize, 2usize, 2usize);
        let d = h * dh;
        let mut rng = Pcg64::new(4);
        let src = Tensor::randn(&[b * s, d], 1.0, &mut rng);
        let mut flat = ws.take(&[b * h, s, dh]);
        gather_heads(&src, &mut flat, b, s, h, dh, 1);
        // pair (bi=1, hi=0), row 2 must equal src row (1*3+2), cols 0..2.
        let pair = 2; // bi=1, hi=0
        assert_eq!(
            &flat.data()[(pair * s + 2) * dh..(pair * s + 2) * dh + dh],
            &src.data()[(3 + 2) * d..(3 + 2) * d + dh],
        );
        let mut back = ws.take(&[b * s, d]);
        scatter_heads_add(&flat, &mut back, b, s, h, dh, 1);
        assert_eq!(back, src, "gather→scatter must reconstruct the matrix");
    }
}
