//! Artifact registry: the manifest written by `python/compile/aot.py`.
//!
//! Every AOT-lowered HLO artifact is identified by an [`ArtifactSpec`]
//! (model preset, adapter, rank, tasks, batch, seq, step kind). The python
//! side lowers one HLO text file per spec and records, in
//! `artifacts/manifest.json`, the file name plus the *exact ordered input
//! layout* (frozen weights, trainable params, data) and output layout the
//! rust executor must honor. The registry parses and indexes that manifest.

use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// What a lowered computation does.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum StepKind {
    /// fwd+bwd: outputs (loss, grads...) given (frozen, trainable, batch).
    Train,
    /// fwd only: outputs logits/scores given (frozen, trainable, batch).
    Eval,
    /// MLM pretraining step over all weights.
    Pretrain,
    /// Serving apply: folded adapter application (hotpath bench).
    Apply,
}

impl StepKind {
    pub fn name(&self) -> &'static str {
        match self {
            StepKind::Train => "train",
            StepKind::Eval => "eval",
            StepKind::Pretrain => "pretrain",
            StepKind::Apply => "apply",
        }
    }

    pub fn from_name(s: &str) -> Result<StepKind, String> {
        match s {
            "train" => Ok(StepKind::Train),
            "eval" => Ok(StepKind::Eval),
            "pretrain" => Ok(StepKind::Pretrain),
            "apply" => Ok(StepKind::Apply),
            other => Err(format!("unknown step kind '{other}'")),
        }
    }
}

/// Identity of one artifact. Equality/order derive the cache key.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ArtifactSpec {
    pub step: StepKind,
    /// Model preset name ("tiny", "small", "base_sim").
    pub model: String,
    /// Adapter name ("metatt4d", "lora", … or "none" for pretrain).
    pub adapter: String,
    pub rank: usize,
    /// Task-head arity: number of classes (or 1 for regression).
    pub classes: usize,
    /// Number of tasks wired into the graph (MTL artifacts).
    pub tasks: usize,
    pub batch: usize,
    pub seq: usize,
}

impl ArtifactSpec {
    /// Canonical file stem, mirrored by aot.py.
    pub fn stem(&self) -> String {
        format!(
            "{}_{}_{}_r{}_c{}_t{}_b{}_s{}",
            self.step.name(),
            self.model,
            self.adapter,
            self.rank,
            self.classes,
            self.tasks,
            self.batch,
            self.seq
        )
    }
}

/// One named input or output of an artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// "f32" or "i32".
    pub dtype: String,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// A manifest entry: artifact identity + file + I/O layout.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub spec: ArtifactSpec,
    pub file: PathBuf,
    /// Ordered HLO parameters: frozen weights first, then trainable, then
    /// data inputs — the exact call convention of the executable.
    pub inputs: Vec<IoSpec>,
    /// Ordered tuple outputs.
    pub outputs: Vec<IoSpec>,
    /// Index ranges partitioning `inputs`.
    pub n_frozen: usize,
    pub n_trainable: usize,
}

impl ArtifactEntry {
    pub fn frozen_inputs(&self) -> &[IoSpec] {
        &self.inputs[..self.n_frozen]
    }
    pub fn trainable_inputs(&self) -> &[IoSpec] {
        &self.inputs[self.n_frozen..self.n_frozen + self.n_trainable]
    }
    pub fn data_inputs(&self) -> &[IoSpec] {
        &self.inputs[self.n_frozen + self.n_trainable..]
    }
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    entries: BTreeMap<ArtifactSpec, ArtifactEntry>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load from `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e} (run `make artifacts` first)", path.display()))?;
        let doc = json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
        Self::from_json(&doc, dir)
    }

    pub fn from_json(doc: &Json, dir: &Path) -> Result<Manifest, String> {
        let arr = doc
            .get("artifacts")
            .and_then(|v| v.as_arr())
            .ok_or("manifest missing 'artifacts' array")?;
        let mut entries = BTreeMap::new();
        for item in arr {
            let entry = parse_entry(item, dir)?;
            entries.insert(entry.spec.clone(), entry);
        }
        Ok(Manifest { entries, dir: dir.to_path_buf() })
    }

    pub fn get(&self, spec: &ArtifactSpec) -> Option<&ArtifactEntry> {
        self.entries.get(spec)
    }

    pub fn require(&self, spec: &ArtifactSpec) -> Result<&ArtifactEntry, String> {
        self.get(spec).ok_or_else(|| {
            format!(
                "artifact {} not in manifest ({} available); re-run `make artifacts`",
                spec.stem(),
                self.entries.len()
            )
        })
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn specs(&self) -> impl Iterator<Item = &ArtifactSpec> {
        self.entries.keys()
    }
}

fn parse_entry(item: &Json, dir: &Path) -> Result<ArtifactEntry, String> {
    let s = |key: &str| -> Result<String, String> {
        item.get(key)
            .and_then(|v| v.as_str())
            .map(|s| s.to_string())
            .ok_or_else(|| format!("artifact entry missing '{key}'"))
    };
    let n = |key: &str| -> Result<usize, String> {
        item.get(key)
            .and_then(|v| v.as_usize())
            .ok_or_else(|| format!("artifact entry missing '{key}'"))
    };
    let spec = ArtifactSpec {
        step: StepKind::from_name(&s("step")?)?,
        model: s("model")?,
        adapter: s("adapter")?,
        rank: n("rank")?,
        classes: n("classes")?,
        tasks: n("tasks")?,
        batch: n("batch")?,
        seq: n("seq")?,
    };
    let parse_ios = |key: &str| -> Result<Vec<IoSpec>, String> {
        item.get(key)
            .and_then(|v| v.as_arr())
            .ok_or_else(|| format!("artifact entry missing '{key}'"))?
            .iter()
            .map(|io| {
                let name = io
                    .get("name")
                    .and_then(|v| v.as_str())
                    .ok_or("io missing name")?
                    .to_string();
                let dtype = io
                    .get("dtype")
                    .and_then(|v| v.as_str())
                    .unwrap_or("f32")
                    .to_string();
                let shape = io
                    .get("shape")
                    .and_then(|v| v.as_arr())
                    .ok_or("io missing shape")?
                    .iter()
                    .map(|d| d.as_usize().ok_or("bad dim"))
                    .collect::<Result<Vec<_>, _>>()?;
                Ok(IoSpec { name, shape, dtype })
            })
            .collect()
    };
    Ok(ArtifactEntry {
        file: dir.join(s("file")?),
        inputs: parse_ios("inputs")?,
        outputs: parse_ios("outputs")?,
        n_frozen: n("n_frozen")?,
        n_trainable: n("n_trainable")?,
        spec,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": [
        {
          "step": "train", "model": "tiny", "adapter": "metatt4d",
          "rank": 8, "classes": 2, "tasks": 1, "batch": 16, "seq": 64,
          "file": "train_tiny_metatt4d_r8_c2_t1_b16_s64.hlo.txt",
          "n_frozen": 2, "n_trainable": 4,
          "inputs": [
            {"name": "tok_emb", "shape": [1024, 128], "dtype": "f32"},
            {"name": "pos_emb", "shape": [64, 128], "dtype": "f32"},
            {"name": "g1", "shape": [128, 8], "dtype": "f32"},
            {"name": "g2", "shape": [4, 8, 8], "dtype": "f32"},
            {"name": "g3", "shape": [2, 8, 8], "dtype": "f32"},
            {"name": "g4", "shape": [8, 128], "dtype": "f32"},
            {"name": "tokens", "shape": [16, 64], "dtype": "i32"},
            {"name": "labels", "shape": [16], "dtype": "i32"}
          ],
          "outputs": [
            {"name": "loss", "shape": [], "dtype": "f32"},
            {"name": "grad_g1", "shape": [128, 8], "dtype": "f32"}
          ]
        }
      ]
    }"#;

    #[test]
    fn manifest_roundtrip() {
        let doc = crate::util::json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json(&doc, Path::new("artifacts")).unwrap();
        assert_eq!(m.len(), 1);
        let spec = ArtifactSpec {
            step: StepKind::Train,
            model: "tiny".into(),
            adapter: "metatt4d".into(),
            rank: 8,
            classes: 2,
            tasks: 1,
            batch: 16,
            seq: 64,
        };
        let e = m.require(&spec).unwrap();
        assert_eq!(e.frozen_inputs().len(), 2);
        assert_eq!(e.trainable_inputs().len(), 4);
        assert_eq!(e.data_inputs().len(), 2);
        assert_eq!(e.data_inputs()[0].dtype, "i32");
        assert_eq!(e.trainable_inputs()[1].numel(), 4 * 8 * 8);
        assert_eq!(spec.stem(), "train_tiny_metatt4d_r8_c2_t1_b16_s64");
        // missing spec is a helpful error
        let mut missing = spec.clone();
        missing.rank = 99;
        assert!(m.require(&missing).is_err());
    }
}
