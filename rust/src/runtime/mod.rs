//! Execution runtime: the backend seam, spec layouts, and step execution.
//!
//! * `backend` — the [`Backend`] / [`Step`] traits every coordinator is
//!   written against, plus backend construction ([`make_backend`], which
//!   takes the worker-thread budget resolved from `--threads` /
//!   `[runtime] threads` / `METATT_THREADS`).
//! * `layout` — spec-derived I/O layouts (the rust mirror of model.py);
//!   lets any backend or test synthesize an [`ArtifactEntry`] offline.
//! * `reference` — [`RefBackend`]: hermetic pure-rust CPU execution of
//!   train / eval / pretrain / apply steps (`encoder` holds the math).
//! * `registry` — [`ArtifactSpec`] identities + `artifacts/manifest.json`
//!   parsing (written by aot.py, consumed by the PJRT backend).
//! * `backbone` — frozen-weight assembly (encoder checkpoint + heads).
//! * `exec` (feature `pjrt`) — the PJRT client, spec-keyed executable
//!   cache, and device step runners over AOT-lowered HLO artifacts.

mod backbone;
mod backend;
mod encoder;
mod layout;
mod reference;
mod registry;

#[cfg(feature = "pjrt")]
mod exec;

pub use backbone::{assemble_frozen, checkpoint_path, init_encoder_weights};
pub use backend::{backend_from_env, make_backend, Backend, BackendKind, Step};
pub use encoder::{
    pack_frozen_weights, packed_frozen_bytes, FoldedPairPacked, PackedFrozen,
};
pub use layout::{encoder_specs, frozen_specs, synthesize_entry, trainable_specs};
pub use reference::RefBackend;
pub use registry::{ArtifactEntry, ArtifactSpec, IoSpec, Manifest, StepKind};

#[cfg(feature = "pjrt")]
pub use exec::{Runtime, StepRunner};
