//! PJRT runtime: artifact registry, executable cache, step execution.
//!
//! `registry` parses `artifacts/manifest.json` (written by aot.py);
//! `exec` owns the PJRT client, the spec-keyed executable cache, and the
//! step runners; `backbone` assembles the frozen-weight input set.

mod backbone;
mod exec;
mod registry;

pub use backbone::{assemble_frozen, checkpoint_path, init_encoder_weights};
pub use exec::{Runtime, StepRunner};
pub use registry::{ArtifactEntry, ArtifactSpec, IoSpec, Manifest, StepKind};
