//! PJRT execution: client wrapper, executable cache, step runners.
//!
//! Compiled only with `--features pjrt`; implements the [`Backend`] /
//! [`Step`] seam over AOT-lowered HLO artifacts. Design decisions
//! (DESIGN.md §7):
//!
//! * **Executable cache keyed by [`ArtifactSpec`]** — the DMRG scheduler
//!   changes TT ranks mid-run, which changes HLO shapes; each rank's
//!   artifact is compiled once and hot-swapped in O(1) afterwards.
//! * **Frozen weights upload once** — the pretrained backbone (+ heads) is
//!   transferred to device buffers at [`StepRunner`] construction; per-step
//!   uploads are only the (small) trainable arrays and the data batch.
//! * Outputs come back as one tuple literal, decomposed per the manifest's
//!   output layout.

use super::backend::{Backend, BackendKind, Step};
use super::registry::{ArtifactEntry, ArtifactSpec, Manifest, StepKind};
use crate::config::ModelPreset;
use crate::data::{Batch, MlmBatch};
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

/// PJRT client + artifact registry + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<HashMap<ArtifactSpec, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

// SAFETY: the PJRT C API is documented thread-safe; `PjRtClient` and
// `PjRtLoadedExecutable` are immutable handles after creation and the
// executable cache is mutex-guarded. The rust wrapper types only lack the
// auto-traits because they hold raw pointers.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// CPU client over the given artifact directory.
    pub fn new(artifact_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir).map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// Compile (or fetch cached) the executable for `spec`.
    pub fn executable(
        &self,
        spec: &ArtifactSpec,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(spec) {
            return Ok(exe.clone());
        }
        let entry = self.manifest.require(spec).map_err(|e| anyhow!(e))?;
        let proto = xla::HloModuleProto::from_text_file(
            entry.file.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parse HLO {}", entry.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compile {}", spec.stem()))?,
        );
        self.cache.lock().unwrap().insert(spec.clone(), exe.clone());
        Ok(exe)
    }

    /// Upload an f32 tensor.
    pub fn upload(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(t.data(), t.shape(), None)?)
    }

    /// Upload an i32 array.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload an f32 scalar.
    pub fn upload_scalar(&self, v: f32) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(&[v], &[], None)?)
    }

    /// Upload an i32 scalar.
    pub fn upload_scalar_i32(&self, v: i32) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(&[v], &[], None)?)
    }
}

/// Decompose the single tuple output of an artifact execution into f32
/// tensors shaped per the manifest.
fn decompose_outputs(
    entry: &ArtifactEntry,
    result: Vec<Vec<xla::PjRtBuffer>>,
) -> Result<Vec<Tensor>> {
    let buf = result
        .into_iter()
        .next()
        .and_then(|d| d.into_iter().next())
        .context("empty execution result")?;
    let mut literal = buf.to_literal_sync()?;
    let parts = literal.decompose_tuple()?;
    if parts.len() != entry.outputs.len() {
        bail!(
            "artifact {} returned {} outputs, manifest says {}",
            entry.spec.stem(),
            parts.len(),
            entry.outputs.len()
        );
    }
    let mut out = Vec::with_capacity(parts.len());
    for (lit, spec) in parts.into_iter().zip(&entry.outputs) {
        let data: Vec<f32> = lit.to_vec::<f32>().with_context(|| {
            format!("output {} of {} not f32", spec.name, entry.spec.stem())
        })?;
        if data.len() != spec.numel() {
            bail!(
                "output {} of {}: got {} elements, want {:?}",
                spec.name,
                entry.spec.stem(),
                data.len(),
                spec.shape
            );
        }
        out.push(Tensor::from_vec(&spec.shape, data));
    }
    Ok(out)
}

/// A bound step: compiled executable + resident frozen buffers.
///
/// `run_train` / `run_eval` take only the things that change per step.
pub struct StepRunner<'rt> {
    rt: &'rt Runtime,
    pub entry: ArtifactEntry,
    exe: std::sync::Arc<xla::PjRtLoadedExecutable>,
    frozen: Vec<xla::PjRtBuffer>,
}

impl<'rt> StepRunner<'rt> {
    /// Bind `spec`, uploading `frozen` (name → tensor) once. Every frozen
    /// input in the manifest must be present with the exact shape.
    pub fn bind(
        rt: &'rt Runtime,
        spec: &ArtifactSpec,
        frozen: &HashMap<String, Tensor>,
    ) -> Result<StepRunner<'rt>> {
        let entry = rt.manifest.require(spec).map_err(|e| anyhow!(e))?.clone();
        let exe = rt.executable(spec)?;
        let mut buffers = Vec::with_capacity(entry.n_frozen);
        for io in entry.frozen_inputs() {
            let t = frozen.get(&io.name).with_context(|| {
                format!("frozen input '{}' missing for {}", io.name, spec.stem())
            })?;
            if t.shape() != &io.shape[..] {
                bail!(
                    "frozen input '{}': shape {:?}, manifest wants {:?}",
                    io.name,
                    t.shape(),
                    io.shape
                );
            }
            buffers.push(rt.upload(t)?);
        }
        Ok(StepRunner { rt, entry, exe, frozen: buffers })
    }

    /// Validate trainable tensors against the manifest and upload.
    fn upload_trainable(&self, trainable: &[Tensor]) -> Result<Vec<xla::PjRtBuffer>> {
        let specs = self.entry.trainable_inputs();
        if trainable.len() != specs.len() {
            bail!(
                "{}: {} trainable tensors supplied, manifest wants {}",
                self.entry.spec.stem(),
                trainable.len(),
                specs.len()
            );
        }
        let mut out = Vec::with_capacity(trainable.len());
        for (t, io) in trainable.iter().zip(specs) {
            if t.shape() != &io.shape[..] {
                bail!(
                    "trainable '{}': shape {:?}, manifest wants {:?}",
                    io.name,
                    t.shape(),
                    io.shape
                );
            }
            out.push(self.rt.upload(t)?);
        }
        Ok(out)
    }

    fn execute(&self, args: Vec<xla::PjRtBuffer>) -> Result<Vec<Tensor>> {
        // Frozen buffers first, then per-step args — the HLO parameter order.
        let ordered: Vec<&xla::PjRtBuffer> =
            self.frozen.iter().chain(args.iter()).collect();
        let result = self.exe.execute_b(&ordered)?;
        decompose_outputs(&self.entry, result)
    }
}

impl Step for StepRunner<'_> {
    fn entry(&self) -> &ArtifactEntry {
        &self.entry
    }

    /// One fwd+bwd step. Returns (loss, grads in trainable order).
    fn run_train(
        &self,
        trainable: &[Tensor],
        batch: &Batch,
        task_id: i32,
        alpha: f32,
    ) -> Result<(f32, Vec<Tensor>)> {
        let mut args = self.upload_trainable(trainable)?;
        args.push(self.rt.upload_i32(&batch.tokens, &[batch.batch_size, batch.seq_len])?);
        args.push(self.rt.upload_i32(&batch.labels, &[batch.batch_size])?);
        args.push(self.rt.upload(&Tensor::from_vec(&[batch.batch_size], batch.scores.clone()))?);
        args.push(self.rt.upload(&Tensor::from_vec(&[batch.batch_size], batch.weights.clone()))?);
        args.push(self.rt.upload_scalar_i32(task_id)?);
        args.push(self.rt.upload_scalar(alpha)?);
        let mut outs = self.execute(args)?;
        let grads = outs.split_off(1);
        let loss = outs[0].data()[0];
        Ok((loss, grads))
    }

    /// One fwd (eval) step. Returns logits `[batch, classes]`.
    fn run_eval(
        &self,
        trainable: &[Tensor],
        batch: &Batch,
        task_id: i32,
        alpha: f32,
    ) -> Result<Tensor> {
        let mut args = self.upload_trainable(trainable)?;
        args.push(self.rt.upload_i32(&batch.tokens, &[batch.batch_size, batch.seq_len])?);
        args.push(self.rt.upload_scalar_i32(task_id)?);
        args.push(self.rt.upload_scalar(alpha)?);
        let outs = self.execute(args)?;
        Ok(outs.into_iter().next().unwrap())
    }

    /// One MLM pretraining step (no frozen inputs; `trainable` is the whole
    /// encoder). Returns (loss, grads).
    fn run_pretrain(
        &self,
        trainable: &[Tensor],
        batch: &MlmBatch,
    ) -> Result<(f32, Vec<Tensor>)> {
        let mut args = self.upload_trainable(trainable)?;
        args.push(self.rt.upload_i32(&batch.tokens, &[batch.batch_size, batch.seq_len])?);
        args.push(self.rt.upload_i32(&batch.targets, &[batch.batch_size, batch.seq_len])?);
        args.push(self.rt.upload(&Tensor::from_vec(
            &[batch.batch_size, batch.seq_len],
            batch.weights.clone(),
        ))?);
        let mut outs = self.execute(args)?;
        let grads = outs.split_off(1);
        Ok((outs[0].data()[0], grads))
    }

    /// Raw positional execution (used by the apply/serve micro-bench).
    fn run_raw(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let mut args = Vec::with_capacity(inputs.len());
        for t in inputs {
            args.push(self.rt.upload(t)?);
        }
        self.execute(args)
    }
}

impl Backend for Runtime {
    fn kind(&self) -> BackendKind {
        BackendKind::Pjrt
    }

    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn describe(&self) -> String {
        let mut by_step = std::collections::BTreeMap::new();
        for spec in self.manifest.specs() {
            *by_step.entry(spec.step.name()).or_insert(0usize) += 1;
        }
        let steps: Vec<String> =
            by_step.iter().map(|(k, n)| format!("  {k:>9}: {n}")).collect();
        format!(
            "backend: pjrt — platform {}\nartifacts: {} entries in {}\n{}",
            Backend::platform(self),
            self.manifest.len(),
            self.manifest.dir.display(),
            steps.join("\n")
        )
    }

    fn entry(&self, spec: &ArtifactSpec) -> Result<ArtifactEntry> {
        self.manifest
            .require(spec)
            .map(|e| e.clone())
            .map_err(|e| anyhow!(e))
    }

    fn bind<'a>(
        &'a self,
        spec: &ArtifactSpec,
        frozen: &std::sync::Arc<HashMap<String, Tensor>>,
    ) -> Result<Box<dyn Step + 'a>> {
        // The PJRT runner uploads the frozen set to device buffers, so only
        // the shared host map is read here — no host copy either way.
        Ok(Box::new(StepRunner::bind(self, spec, frozen)?))
    }

    fn cached_executables(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    fn pretrain_spec(&self, preset: ModelPreset) -> Result<ArtifactSpec> {
        self.manifest
            .specs()
            .find(|s| s.step == StepKind::Pretrain && s.model == preset.name())
            .cloned()
            .ok_or_else(|| {
                anyhow!(
                    "no pretrain artifact for '{}' in manifest — run `make artifacts`",
                    preset.name()
                )
            })
    }

    fn apply_spec(&self, adapter: &str, rank: usize) -> Result<ArtifactSpec> {
        self.manifest
            .specs()
            .find(|s| s.step == StepKind::Apply && s.adapter == adapter && s.rank == rank)
            .cloned()
            .ok_or_else(|| anyhow!("no apply artifact for {adapter} at rank {rank}"))
    }
}
