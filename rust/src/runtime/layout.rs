//! Spec-derived I/O layouts: the rust mirror of `python/compile/model.py`.
//!
//! The AOT pipeline records each artifact's exact input/output layout in
//! `artifacts/manifest.json`. That layout is *derivable* from the
//! [`ArtifactSpec`] alone — `frozen_specs` / `adapter_param_specs` /
//! `_input_specs` in model.py are pure functions of (preset, adapter, rank,
//! classes, tasks, batch, seq). This module re-derives it in rust so the
//! pure-rust reference backend (and any test) can synthesize a full
//! [`ArtifactEntry`] without a manifest, Python, or artifacts on disk.
//! model.py remains the source of truth; `layout_matches_adapter_param_specs`
//! below pins the rust mirror against `adapters::AdapterSpec::param_specs`,
//! which is itself pinned against model.py by the python test suite.

use super::registry::{ArtifactEntry, ArtifactSpec, IoSpec, StepKind};
use crate::adapters::{AdapterKind, AdapterSpec};
use crate::config::ModelPreset;
use std::path::PathBuf;

fn io(name: &str, shape: &[usize], dtype: &str) -> IoSpec {
    IoSpec { name: name.to_string(), shape: shape.to_vec(), dtype: dtype.to_string() }
}

/// Ordered frozen-weight layout: the 20 encoder arrays + per-task classifier
/// heads (mirror of model.py `frozen_specs`).
pub fn frozen_specs(preset: ModelPreset, tasks: usize, classes: usize) -> Vec<IoSpec> {
    let dims = preset.dims(tasks.max(1));
    let (d, l, f) = (dims.hidden, dims.layers, dims.ffn);
    let (v, s) = (dims.vocab, dims.max_seq);
    vec![
        io("tok_emb", &[v, d], "f32"),
        io("pos_emb", &[s, d], "f32"),
        io("emb_ln_g", &[d], "f32"),
        io("emb_ln_b", &[d], "f32"),
        io("wq", &[l, d, d], "f32"),
        io("bq", &[l, d], "f32"),
        io("wk", &[l, d, d], "f32"),
        io("bk", &[l, d], "f32"),
        io("wv", &[l, d, d], "f32"),
        io("bv", &[l, d], "f32"),
        io("wo", &[l, d, d], "f32"),
        io("bo", &[l, d], "f32"),
        io("ln1_g", &[l, d], "f32"),
        io("ln1_b", &[l, d], "f32"),
        io("w1", &[l, d, f], "f32"),
        io("b1", &[l, f], "f32"),
        io("w2", &[l, f, d], "f32"),
        io("b2", &[l, d], "f32"),
        io("ln2_g", &[l, d], "f32"),
        io("ln2_b", &[l, d], "f32"),
        io("cls_w", &[tasks, d, classes], "f32"),
        io("cls_b", &[tasks, classes], "f32"),
    ]
}

/// The 20 encoder arrays (frozen set minus the classifier heads) — the
/// trainable layout for pretraining and full fine-tuning.
pub fn encoder_specs(preset: ModelPreset) -> Vec<IoSpec> {
    let mut all = frozen_specs(preset, 1, 1);
    all.truncate(all.len() - 2);
    all
}

/// Ordered trainable layout for `spec` (adapter params, or the encoder for
/// full fine-tuning / pretraining).
pub fn trainable_specs(spec: &ArtifactSpec) -> Result<Vec<IoSpec>, String> {
    let preset = ModelPreset::from_name(&spec.model)?;
    if spec.step == StepKind::Pretrain || spec.adapter == "full" {
        // Pretraining and full fine-tuning train the encoder itself.
        return Ok(encoder_specs(preset));
    }
    if spec.adapter == "none" {
        // "none" marks the adapter-free pretrain graphs; on a fine-tuning
        // step it would freeze AND train the same arrays (a silent no-op).
        return Err(format!(
            "adapter 'none' is only valid for pretrain specs (got {})",
            spec.stem()
        ));
    }
    let kind = AdapterKind::from_name(&spec.adapter)?;
    let dims = preset.dims(spec.tasks.max(1));
    let aspec = AdapterSpec::new(kind, spec.rank, 1.0, dims);
    Ok(aspec
        .param_specs()
        .into_iter()
        .map(|p| io(&p.name, &p.shape, "f32"))
        .collect())
}

/// Synthesize the full [`ArtifactEntry`] (ordered inputs, outputs, frozen /
/// trainable partition) for `spec`, exactly as aot.py would have recorded it
/// in the manifest. This is what lets the reference backend run without
/// `make artifacts`.
pub fn synthesize_entry(spec: &ArtifactSpec) -> Result<ArtifactEntry, String> {
    let preset = ModelPreset::from_name(&spec.model)?;
    let dims = preset.dims(spec.tasks.max(1));
    let (b, s, d) = (spec.batch, spec.seq, dims.hidden);
    if spec.seq > dims.max_seq {
        return Err(format!(
            "spec seq {} exceeds preset '{}' max_seq {}",
            spec.seq,
            spec.model,
            dims.max_seq
        ));
    }
    let (inputs, outputs, n_frozen, n_trainable) = match spec.step {
        StepKind::Train | StepKind::Eval => {
            let mut frozen = frozen_specs(preset, spec.tasks.max(1), spec.classes);
            if spec.adapter == "full" {
                // Full FT trains the encoder itself; only the heads stay frozen.
                frozen = frozen.split_off(frozen.len() - 2);
            }
            let trainable = trainable_specs(spec)?;
            let (nf, nt) = (frozen.len(), trainable.len());
            let mut inputs = frozen;
            inputs.extend(trainable.iter().cloned());
            inputs.push(io("tokens", &[b, s], "i32"));
            let outputs = if spec.step == StepKind::Train {
                inputs.push(io("labels", &[b], "i32"));
                inputs.push(io("scores", &[b], "f32"));
                inputs.push(io("weights", &[b], "f32"));
                let mut outs = vec![io("loss", &[], "f32")];
                outs.extend(trainable.iter().map(|t| {
                    io(&format!("grad_{}", t.name), &t.shape, "f32")
                }));
                outs
            } else {
                vec![io("logits", &[b, spec.classes], "f32")]
            };
            inputs.push(io("task_id", &[], "i32"));
            inputs.push(io("alpha", &[], "f32"));
            (inputs, outputs, nf, nt)
        }
        StepKind::Pretrain => {
            let trainable = encoder_specs(preset);
            let nt = trainable.len();
            let mut inputs = trainable.clone();
            inputs.push(io("tokens", &[b, s], "i32"));
            inputs.push(io("targets", &[b, s], "i32"));
            inputs.push(io("mask", &[b, s], "f32"));
            let mut outputs = vec![io("loss", &[], "f32")];
            outputs.extend(trainable.iter().map(|t| {
                io(&format!("grad_{}", t.name), &t.shape, "f32")
            }));
            (inputs, outputs, 0, nt)
        }
        StepKind::Apply => {
            let n = b * s;
            let r = spec.rank;
            let inputs = if spec.adapter == "lora" {
                vec![
                    io("x", &[n, d], "f32"),
                    io("lora_a", &[d, r], "f32"),
                    io("lora_b", &[r, d], "f32"),
                ]
            } else {
                vec![
                    io("x", &[n, d], "f32"),
                    io("g1", &[d, r], "f32"),
                    io("mid", &[r, r], "f32"),
                    io("g4", &[r, d], "f32"),
                ]
            };
            let nt = inputs.len() - 1;
            let outputs = vec![io("y", &[n, d], "f32")];
            (inputs, outputs, 0, nt)
        }
    };
    Ok(ArtifactEntry {
        spec: spec.clone(),
        // No file backs a synthesized entry; the path records provenance.
        file: PathBuf::from(format!("<synthesized>/{}", spec.stem())),
        inputs,
        outputs,
        n_frozen,
        n_trainable,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec(step: StepKind, adapter: &str) -> ArtifactSpec {
        ArtifactSpec {
            step,
            model: "tiny".into(),
            adapter: adapter.into(),
            rank: 8,
            classes: 2,
            tasks: 1,
            batch: 16,
            seq: 32,
        }
    }

    #[test]
    fn train_entry_matches_manifest_shape_conventions() {
        let e = synthesize_entry(&tiny_spec(StepKind::Train, "metatt4d")).unwrap();
        assert_eq!(e.frozen_inputs().len(), 22); // 20 encoder arrays + 2 heads
        assert_eq!(e.trainable_inputs().len(), 4); // g1..g4
        // data inputs: tokens, labels, scores, weights, task_id, alpha
        assert_eq!(e.data_inputs().len(), 6);
        assert_eq!(e.data_inputs()[0].dtype, "i32");
        assert_eq!(e.outputs.len(), 1 + 4); // loss + grads
        assert_eq!(e.outputs[1].name, "grad_g1");
        assert_eq!(e.outputs[1].shape, vec![64, 8]);
        // Frozen heads sized by (tasks, d, classes).
        let cls_w = e.frozen_inputs().iter().find(|io| io.name == "cls_w").unwrap();
        assert_eq!(cls_w.shape, vec![1, 64, 2]);
    }

    #[test]
    fn eval_and_pretrain_entries() {
        let e = synthesize_entry(&tiny_spec(StepKind::Eval, "lora")).unwrap();
        assert_eq!(e.outputs.len(), 1);
        assert_eq!(e.outputs[0].shape, vec![16, 2]);
        assert_eq!(e.data_inputs().len(), 3); // tokens, task_id, alpha

        let p = synthesize_entry(&tiny_spec(StepKind::Pretrain, "none")).unwrap();
        assert_eq!(p.n_frozen, 0);
        assert_eq!(p.trainable_inputs().len(), 20);
        assert_eq!(p.outputs.len(), 21);
    }

    #[test]
    fn full_ft_keeps_only_heads_frozen() {
        let e = synthesize_entry(&tiny_spec(StepKind::Train, "full")).unwrap();
        assert_eq!(e.frozen_inputs().len(), 2);
        assert!(e.frozen_inputs().iter().all(|io| io.name.starts_with("cls_")));
        assert_eq!(e.trainable_inputs().len(), 20);
    }

    #[test]
    fn layout_matches_adapter_param_specs() {
        for adapter in ["metatt4d", "metatt5d", "metatt4p1d", "lora", "vera", "lotr"] {
            let mut spec = tiny_spec(StepKind::Train, adapter);
            spec.tasks = 3;
            let e = synthesize_entry(&spec).unwrap();
            let kind = AdapterKind::from_name(adapter).unwrap();
            let aspec = AdapterSpec::new(
                kind,
                8,
                1.0,
                ModelPreset::Tiny.dims(3),
            );
            let want = aspec.param_specs();
            let got = e.trainable_inputs();
            assert_eq!(got.len(), want.len(), "{adapter}");
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.name, w.name, "{adapter}");
                assert_eq!(g.shape, w.shape, "{adapter}");
            }
        }
    }

    #[test]
    fn seq_beyond_preset_is_rejected() {
        let mut spec = tiny_spec(StepKind::Train, "metatt4d");
        spec.seq = 64; // tiny max_seq is 32
        assert!(synthesize_entry(&spec).is_err());
    }

    #[test]
    fn adapter_none_rejected_outside_pretrain() {
        // A train/eval spec with adapter "none" would freeze and train the
        // same arrays — reject it instead of synthesizing a no-op entry.
        for step in [StepKind::Train, StepKind::Eval] {
            let err = synthesize_entry(&tiny_spec(step, "none")).unwrap_err();
            assert!(err.contains("pretrain"), "{err}");
        }
        assert!(synthesize_entry(&tiny_spec(StepKind::Pretrain, "none")).is_ok());
    }
}
