//! # MetaTT — a global tensor-train adapter for parameter-efficient fine-tuning
//!
//! Reproduction of *MetaTT: A Global Tensor-Train Adapter for
//! Parameter-Efficient Fine-Tuning* (Lopez-Piqueres et al., cs.LG 2025) as a
//! three-layer rust + JAX + Pallas system:
//!
//! * **L1 (build time, python)** — Pallas kernels for the fused TT-adapter
//!   apply, validated against a pure-`jnp` oracle.
//! * **L2 (build time, python)** — a from-scratch JAX transformer encoder
//!   whose Q/V projections are steered by a single *global* tensor-train
//!   adapter; fwd/bwd lowered AOT to HLO text artifacts.
//! * **L3 (run time, rust — this crate)** — the coordinator: PJRT runtime,
//!   training orchestration, AdamW, the DMRG-inspired rank-adaptive sweep
//!   (paper Algorithm 1), the synthetic GLUE workload suite, metrics, and
//!   the benchmark harness that regenerates every table and figure of the
//!   paper's evaluation.
//!
//! Python never runs on the training/serving path: `make artifacts` lowers
//! the compute graphs once; everything after that is this crate.
//!
//! ## Crate map
//!
//! | module | role |
//! |---|---|
//! | [`tensor`] | dense f32 host tensors (DMRG, optimizer, metrics) |
//! | [`linalg`] | Householder QR + Jacobi SVD (+ truncated SVD) |
//! | [`tt`] | tensor-train container, MetaTT variants, DMRG sweep |
//! | [`adapters`] | parameter layouts + analytic counts for all baselines |
//! | [`optim`] | AdamW / SGD, LR schedules, gradient clipping |
//! | [`data`] | synthetic GLUE suite + MLM pretraining corpus |
//! | [`metrics`] | accuracy, Matthews, Spearman, seed aggregation |
//! | [`runtime`] | PJRT client, artifact registry, executable cache |
//! | [`coordinator`] | trainers (single-task, MTL, DMRG), checkpoints |
//! | [`bench`] | micro-bench harness + paper-style table emitters |
//! | [`config`] | experiment configuration (TOML) |
//! | [`cli`] | launcher argument parsing |

pub mod adapters;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod metrics;
pub mod optim;
pub mod runtime;
pub mod tensor;
pub mod testutil;
pub mod tt;
pub mod util;

/// Crate version string surfaced by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
