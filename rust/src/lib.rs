//! # MetaTT — a global tensor-train adapter for parameter-efficient fine-tuning
//!
//! Reproduction of *MetaTT: A Global Tensor-Train Adapter for
//! Parameter-Efficient Fine-Tuning* (Lopez-Piqueres et al., cs.LG 2025) as a
//! three-layer rust + JAX + Pallas system:
//!
//! * **L1 (build time, python)** — Pallas kernels for the fused TT-adapter
//!   apply, validated against a pure-`jnp` oracle.
//! * **L2 (build time, python)** — a from-scratch JAX transformer encoder
//!   whose Q/V projections are steered by a single *global* tensor-train
//!   adapter; fwd/bwd lowered AOT to HLO text artifacts.
//! * **L3 (run time, rust — this crate)** — the coordinator: pluggable
//!   execution backends, training orchestration, AdamW, the DMRG-inspired
//!   rank-adaptive sweep (paper Algorithm 1), the synthetic GLUE workload
//!   suite, metrics, and the benchmark harness that regenerates every table
//!   and figure of the paper's evaluation.
//!
//! ## Execution backends
//!
//! Every training/eval/pretrain step runs through the
//! [`runtime::Backend`] seam (`--backend ref|pjrt` on the CLI):
//!
//! * **`ref`** (default) — pure-rust CPU reference executor. Hermetic: no
//!   HLO artifacts, no Python, no network; the entire train/DMRG/MTL stack
//!   (and `cargo test -q`) runs on it out of the box.
//! * **`pjrt`** (cargo feature `pjrt`) — the AOT path: `make artifacts`
//!   lowers the compute graphs once, then this crate compiles and caches
//!   the HLO executables through PJRT. The vendored `xla` crate is a
//!   compile-only stub; link real PJRT bindings to execute.
//!
//! ## Crate map
//!
//! | module | role |
//! |---|---|
//! | [`tensor`] | dense f32 host tensors; blocked matmul kernel family with row-band parallelism (`*_mt`); [`tensor::Workspace`] step arena behind the zero-allocation hot path |
//! | [`linalg`] | Householder QR + Jacobi SVD (+ truncated SVD) |
//! | [`tt`] | tensor-train container, MetaTT variants, DMRG sweep |
//! | [`adapters`] | parameter layouts + analytic counts for all baselines |
//! | [`optim`] | AdamW / SGD, LR schedules, gradient clipping |
//! | [`data`] | synthetic GLUE suite + MLM pretraining corpus |
//! | [`metrics`] | accuracy, Matthews, Spearman, seed aggregation |
//! | [`obs`] | zero-overhead observability: armed/unarmed span tracer (per-thread lock-free rings → Chrome trace JSON), metrics registry (counters/gauges/log-linear histograms, Prometheus text), `STAT` exposition + `--metrics-out` (`BENCH_pr10.json`) |
//! | [`runtime`] | `Backend`/`Step` seam: pure-rust ref executor, spec-derived I/O layouts, artifact registry, PJRT cache (feature `pjrt`) |
//! | [`serving`] | multi-task serving engine: bounded admission queue, dynamic same-task batcher, per-task folded-adapter LRU cache with checkpoint hot-swap, closed-loop load generator (`BENCH_pr5.json`) |
//! | [`coordinator`] | trainers (single-task, MTL, DMRG), checkpoints (v2 container carries adapter metadata) |
//! | [`bench`] | micro-bench harness + paper-style table emitters |
//! | [`config`] | experiment configuration (TOML, incl. backend + `[runtime] threads`) |
//! | [`cli`] | launcher argument parsing |
//! | [`util`] | PCG RNG, JSON/TOML, thread pools: FIFO [`util::threadpool::ThreadPool`] for coordinator fan-out and the scoped pool (`scope_for` / `scope_map` / `scope_rows`) that runs borrowed parallel regions inside kernels — 1-thread and N-thread runs are bit-identical |

pub mod adapters;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod metrics;
pub mod obs;
pub mod optim;
pub mod runtime;
pub mod serving;
pub mod tensor;
pub mod testutil;
pub mod tt;
pub mod util;

/// Crate version string surfaced by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
