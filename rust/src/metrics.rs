//! Evaluation metrics and seed aggregation.
//!
//! The GLUE suite mixes metrics: Matthews correlation (CoLA), Spearman rank
//! correlation (STS-B), plain accuracy (the rest). Results are aggregated
//! across seeds as mean ± standard error, printed `mean(err)` as the paper
//! does in Tables 1–2.

/// Classification accuracy.
pub fn accuracy(pred: &[usize], gold: &[usize]) -> f64 {
    assert_eq!(pred.len(), gold.len());
    if pred.is_empty() {
        return 0.0;
    }
    let hits = pred.iter().zip(gold).filter(|(p, g)| p == g).count();
    hits as f64 / pred.len() as f64
}

/// Matthews correlation coefficient for binary labels {0, 1}.
///
/// Returns `None` when any prediction or gold label is non-binary (the
/// metric is undefined there — callers decide whether that is an error).
/// Degenerate-but-binary batches (e.g. a single-class eval slice or a
/// constant predictor) are well-handled: the denominator vanishes and the
/// conventional value 0 is returned.
pub fn matthews_corr(pred: &[usize], gold: &[usize]) -> Option<f64> {
    assert_eq!(pred.len(), gold.len());
    let (mut tp, mut tn, mut fp, mut fnn) = (0f64, 0f64, 0f64, 0f64);
    for (&p, &g) in pred.iter().zip(gold) {
        match (p, g) {
            (1, 1) => tp += 1.0,
            (0, 0) => tn += 1.0,
            (1, 0) => fp += 1.0,
            (0, 1) => fnn += 1.0,
            _ => return None,
        }
    }
    let denom = ((tp + fp) * (tp + fnn) * (tn + fp) * (tn + fnn)).sqrt();
    Some(if denom == 0.0 {
        0.0
    } else {
        (tp * tn - fp * fnn) / denom
    })
}

/// Spearman rank correlation between two score vectors (average ranks for
/// ties).
///
/// Returns `None` when any score is non-finite (NaN/±inf): ranks are
/// undefined there, and the old `partial_cmp(..).unwrap_or(Equal)` sort
/// silently corrupted *every* rank around a single NaN, yielding a
/// plausible-looking garbage correlation. Callers decide whether a
/// non-finite score vector is an error (mirrors [`matthews_corr`]).
pub fn spearman_corr(a: &[f32], b: &[f32]) -> Option<f64> {
    assert_eq!(a.len(), b.len());
    if a.iter().chain(b).any(|x| !x.is_finite()) {
        return None;
    }
    if a.len() < 2 {
        return Some(0.0);
    }
    let ra = ranks(a);
    let rb = ranks(b);
    Some(pearson(&ra, &rb))
}

fn ranks(xs: &[f32]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    // Inputs are pre-checked finite, so partial_cmp is total here; the
    // expect documents (and enforces) that contract.
    idx.sort_by(|&i, &j| {
        xs[i].partial_cmp(&xs[j]).expect("ranks() requires finite scores")
    });
    let mut out = vec![0.0f64; xs.len()];
    let mut i = 0;
    while i < idx.len() {
        // Group ties, assign average rank (1-based).
        let mut j = i;
        while j + 1 < idx.len() && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            out[idx[k]] = avg;
        }
        i = j + 1;
    }
    out
}

fn pearson(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let (mut cov, mut va, mut vb) = (0.0, 0.0, 0.0);
    for (&x, &y) in a.iter().zip(b) {
        cov += (x - ma) * (y - mb);
        va += (x - ma) * (x - ma);
        vb += (y - mb) * (y - mb);
    }
    let denom = (va * vb).sqrt();
    if denom == 0.0 {
        0.0
    } else {
        cov / denom
    }
}

/// Mean and standard error of the mean over trial results.
pub fn mean_stderr(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (f64::NAN, f64::NAN);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0);
    (mean, (var / n).sqrt())
}

/// Which metric a task reports (paper Table 1 caption).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    Accuracy,
    Matthews,
    Spearman,
}

impl MetricKind {
    pub fn name(&self) -> &'static str {
        match self {
            MetricKind::Accuracy => "accuracy",
            MetricKind::Matthews => "matthews",
            MetricKind::Spearman => "spearman",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basics() {
        assert_eq!(accuracy(&[1, 0, 1], &[1, 1, 1]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn matthews_perfect_and_inverse() {
        let gold = [0, 1, 0, 1, 1, 0];
        assert!((matthews_corr(&gold, &gold).unwrap() - 1.0).abs() < 1e-12);
        let inv: Vec<usize> = gold.iter().map(|&g| 1 - g).collect();
        assert!((matthews_corr(&inv, &gold).unwrap() + 1.0).abs() < 1e-12);
        // Constant predictor → 0 by convention.
        assert_eq!(matthews_corr(&[1, 1, 1, 1, 1, 1], &gold), Some(0.0));
    }

    #[test]
    fn matthews_known_value() {
        // tp=2 tn=1 fp=1 fn=1 → (2-1)/sqrt(3*3*2*2) = 1/6
        let pred = [1, 1, 1, 0, 0];
        let gold = [1, 1, 0, 1, 0];
        assert!((matthews_corr(&pred, &gold).unwrap() - 1.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn matthews_degenerate_single_class_batch_is_zero_not_panic() {
        // All-gold-one batch (single class): denominator vanishes → 0.
        assert_eq!(matthews_corr(&[1, 0, 1], &[1, 1, 1]), Some(0.0));
        // All-pred == all-gold single class still 0 (no signal, no crash).
        assert_eq!(matthews_corr(&[0, 0], &[0, 0]), Some(0.0));
    }

    #[test]
    fn matthews_rejects_non_binary_labels() {
        assert_eq!(matthews_corr(&[0, 2], &[1, 0]), None);
        assert_eq!(matthews_corr(&[0, 1], &[1, 3]), None);
    }

    #[test]
    fn spearman_monotone_is_one() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [10.0f32, 20.0, 30.0, 40.0];
        assert!((spearman_corr(&a, &b).unwrap() - 1.0).abs() < 1e-12);
        let c = [4.0f32, 3.0, 2.0, 1.0];
        assert!((spearman_corr(&a, &c).unwrap() + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_handles_ties() {
        let a = [1.0f32, 1.0, 2.0, 3.0];
        let b = [1.0f32, 1.0, 2.0, 3.0];
        assert!((spearman_corr(&a, &b).unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn spearman_rejects_non_finite_scores() {
        // A single NaN used to silently corrupt every rank (the sort's
        // unwrap_or(Equal) made the comparator non-transitive) and return
        // a plausible-looking value; now it is a clean None.
        let a = [1.0f32, f32::NAN, 3.0, 4.0];
        let b = [10.0f32, 20.0, 30.0, 40.0];
        assert_eq!(spearman_corr(&a, &b), None);
        assert_eq!(spearman_corr(&b, &a), None, "NaN on either side");
        let inf = [1.0f32, f32::INFINITY, 3.0, 4.0];
        assert_eq!(spearman_corr(&inf, &b), None);
        // Finite inputs are unaffected.
        assert!(spearman_corr(&b, &b).is_some());
        // Degenerate short inputs keep the 0-by-convention value.
        assert_eq!(spearman_corr(&[1.0f32], &[2.0f32]), Some(0.0));
    }

    #[test]
    fn mean_stderr_basics() {
        let (m, e) = mean_stderr(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((e - (1.0f64 / 3.0).sqrt()).abs() < 1e-12);
        let (m1, e1) = mean_stderr(&[5.0]);
        assert_eq!((m1, e1), (5.0, 0.0));
    }
}
