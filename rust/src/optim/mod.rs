//! Optimizers and learning-rate schedules.
//!
//! AdamW with decoupled weight decay (Loshchilov & Hutter) is the paper's
//! optimizer; SGD is kept for ablations. The LR schedule is the
//! HuggingFace-style linear warmup (warmup_ratio of total steps) followed by
//! linear decay to zero, matching the paper's Appendix A.3/D settings.
//! `Adam::reset_moments` exists because the DMRG sweep changes parameter
//! shapes mid-run: "one must reinitialize Adam moments after each
//! truncation" (paper §3.3).

/// Linear warmup + linear decay schedule.
#[derive(Clone, Copy, Debug)]
pub struct LrSchedule {
    pub base_lr: f32,
    pub total_steps: usize,
    pub warmup_steps: usize,
}

impl LrSchedule {
    pub fn new(base_lr: f32, total_steps: usize, warmup_ratio: f32) -> LrSchedule {
        let warmup_steps = ((total_steps as f32) * warmup_ratio).round() as usize;
        LrSchedule { base_lr, total_steps: total_steps.max(1), warmup_steps }
    }

    /// Constant learning rate (used by the DMRG experiments, §3.3).
    pub fn constant(base_lr: f32) -> LrSchedule {
        LrSchedule { base_lr, total_steps: usize::MAX, warmup_steps: 0 }
    }

    pub fn lr_at(&self, step: usize) -> f32 {
        if self.total_steps == usize::MAX {
            return self.base_lr;
        }
        if self.warmup_steps > 0 && step < self.warmup_steps {
            return self.base_lr * (step as f32 + 1.0) / self.warmup_steps as f32;
        }
        let remaining = self.total_steps.saturating_sub(step) as f32;
        let denom = self.total_steps.saturating_sub(self.warmup_steps).max(1) as f32;
        self.base_lr * (remaining / denom).clamp(0.0, 1.0)
    }
}

/// AdamW over a flat parameter vector.
#[derive(Clone, Debug)]
pub struct AdamW {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    /// Timestep since the last moment reset (bias correction restarts too —
    /// the whole optimizer state is fresh after a DMRG truncation).
    t: u64,
}

impl AdamW {
    pub fn new(param_len: usize, weight_decay: f32) -> AdamW {
        AdamW {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay,
            m: vec![0.0; param_len],
            v: vec![0.0; param_len],
            t: 0,
        }
    }

    pub fn param_len(&self) -> usize {
        self.m.len()
    }

    /// Drop all moments and restart bias correction; must be called whenever
    /// the parameter vector changes shape (DMRG truncation).
    pub fn reset_moments(&mut self, new_param_len: usize) {
        self.m = vec![0.0; new_param_len];
        self.v = vec![0.0; new_param_len];
        self.t = 0;
    }

    /// One AdamW step: `params -= lr * (mhat / (sqrt(vhat)+eps) + wd * p)`.
    pub fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        assert_eq!(params.len(), self.m.len(), "param/moment length mismatch");
        assert_eq!(params.len(), grads.len());
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            let g = grads[i];
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            params[i] -= lr * (mhat / (vhat.sqrt() + self.eps) + self.weight_decay * params[i]);
        }
    }
}

/// Plain SGD with optional momentum (ablation baseline).
#[derive(Clone, Debug)]
pub struct Sgd {
    pub momentum: f32,
    vel: Vec<f32>,
}

impl Sgd {
    pub fn new(param_len: usize, momentum: f32) -> Sgd {
        Sgd { momentum, vel: vec![0.0; param_len] }
    }

    pub fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        assert_eq!(params.len(), self.vel.len());
        for i in 0..params.len() {
            self.vel[i] = self.momentum * self.vel[i] + grads[i];
            params[i] -= lr * self.vel[i];
        }
    }
}

/// Clip gradients to a maximum global L2 norm (the paper uses max 3.0 in
/// the MTL experiments, Appendix B). Returns the pre-clip norm.
pub fn clip_global_norm(grads: &mut [f32], max_norm: f32) -> f32 {
    let norm = grads.iter().map(|&g| (g as f64) * (g as f64)).sum::<f64>().sqrt() as f32;
    if norm > max_norm && norm > 0.0 {
        let scale = max_norm / norm;
        for g in grads.iter_mut() {
            *g *= scale;
        }
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adamw_minimizes_quadratic() {
        // f(p) = 0.5 Σ (p - c)^2, grad = p - c
        let c = [3.0f32, -2.0, 0.5, 7.0];
        let mut p = vec![0.0f32; 4];
        let mut opt = AdamW::new(4, 0.0);
        for _ in 0..2000 {
            let g: Vec<f32> = p.iter().zip(&c).map(|(&pi, &ci)| pi - ci).collect();
            opt.step(&mut p, &g, 0.05);
        }
        for (pi, ci) in p.iter().zip(&c) {
            assert!((pi - ci).abs() < 1e-2, "{pi} vs {ci}");
        }
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut p = vec![1.0f32];
        let mut opt = AdamW::new(1, 0.1);
        // zero gradient: only decay acts
        for _ in 0..10 {
            opt.step(&mut p, &[0.0], 0.1);
        }
        assert!(p[0] < 1.0 && p[0] > 0.8);
    }

    #[test]
    fn reset_moments_changes_shape() {
        let mut opt = AdamW::new(4, 0.0);
        let mut p = vec![1.0f32; 4];
        opt.step(&mut p, &[1.0; 4], 0.01);
        opt.reset_moments(2);
        assert_eq!(opt.param_len(), 2);
        let mut p2 = vec![1.0f32; 2];
        opt.step(&mut p2, &[1.0; 2], 0.01); // must not panic
    }

    #[test]
    fn sgd_with_momentum_converges() {
        let mut p = vec![5.0f32];
        let mut opt = Sgd::new(1, 0.9);
        for _ in 0..300 {
            let g = [p[0]];
            opt.step(&mut p, &g, 0.01);
        }
        assert!(p[0].abs() < 0.05);
    }

    #[test]
    fn clip_caps_norm() {
        let mut g = vec![3.0f32, 4.0];
        let pre = clip_global_norm(&mut g, 1.0);
        assert!((pre - 5.0).abs() < 1e-6);
        let post = (g[0] * g[0] + g[1] * g[1]).sqrt();
        assert!((post - 1.0).abs() < 1e-6);
        // below threshold: untouched
        let mut h = vec![0.3f32, 0.4];
        clip_global_norm(&mut h, 1.0);
        assert_eq!(h, vec![0.3, 0.4]);
    }

    #[test]
    fn schedule_warmup_then_decay() {
        let s = LrSchedule::new(1.0, 100, 0.1);
        assert!(s.lr_at(0) > 0.0 && s.lr_at(0) <= 0.2);
        assert!((s.lr_at(9) - 1.0).abs() < 1e-6); // end of warmup
        assert!(s.lr_at(50) < 1.0);
        assert!(s.lr_at(99) < s.lr_at(50));
        let c = LrSchedule::constant(0.5);
        assert_eq!(c.lr_at(0), 0.5);
        assert_eq!(c.lr_at(10_000), 0.5);
    }
}
