//! Tiny command-line parser for the `metatt` launcher.
//!
//! No `clap` in the offline registry; this covers what the launcher needs:
//! one positional subcommand, `--key value` / `--key=value` options,
//! boolean `--flag`s, and typed accessors with defaults. Unknown options
//! are an error so typos fail loudly.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, options, and free positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse argv (excluding argv[0]). `opt_names` lists value-taking
    /// options, `flag_names` lists boolean flags (both without `--`).
    /// Anything else starting with `--` is an error so typos fail loudly.
    pub fn parse(
        argv: &[String],
        opt_names: &[&str],
        flag_names: &[&str],
    ) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        // Consume the subcommand iff the first token is not an option —
        // `next_if` keeps peek+advance atomic, so there is no unwrap to
        // panic on when argv is exhausted or starts with a flag.
        if let Some(first) = it.next_if(|tok| !tok.starts_with('-')) {
            args.command = first.clone();
        }
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                if flag_names.contains(&key.as_str()) {
                    if inline_val.is_some() {
                        return Err(format!("--{key} is a flag and takes no value"));
                    }
                    args.flags.push(key);
                } else if opt_names.contains(&key.as_str()) {
                    if let Some(v) = inline_val {
                        args.opts.insert(key, v);
                    } else if let Some(next) = it.next() {
                        args.opts.insert(key, next.clone());
                    } else {
                        return Err(format!("--{key} expects a value"));
                    }
                } else {
                    return Err(format!("unknown option --{key}"));
                }
            } else {
                args.positional.push(tok.clone());
            }
        }
        Ok(args)
    }

    /// Parse from the process environment.
    pub fn from_env(opt_names: &[&str], flag_names: &[&str]) -> Result<Args, String> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&argv, opt_names, flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// Optional integer: distinguishes "not given" (None) from an explicit
    /// value, for options whose default comes from elsewhere (env, TOML).
    pub fn usize_opt(&self, name: &str) -> Result<Option<usize>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn f32_or(&self, name: &str, default: f32) -> Result<f32, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects a float, got '{v}'")),
        }
    }

    /// Comma-separated list of usizes, e.g. `--ranks 4,8,16`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Result<Vec<usize>, String> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| format!("--{name} expects ints, got '{p}'"))
                })
                .collect(),
        }
    }

    /// Comma-separated list of strings, e.g. `--tasks mrpc_syn,rte_syn`.
    pub fn str_list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|p| p.trim().to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|p| p.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        let a = Args::parse(
            &argv("train --task mrpc_syn --rank=8 --verbose out.json"),
            &["task", "rank"],
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.get("task"), Some("mrpc_syn"));
        assert_eq!(a.usize_or("rank", 0).unwrap(), 8);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["out.json"]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(Args::parse(&argv("x --nope 1"), &["yep"], &[]).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(Args::parse(&argv("x --verbose=1"), &[], &["verbose"]).is_err());
        assert!(Args::parse(&argv("x --task"), &["task"], &[]).is_err());
    }

    #[test]
    fn trailing_value_option_is_usage_error_not_panic() {
        // A value-taking option as the *last* token must come back as a
        // clean usage error at every argv position, including when it is
        // the only token (no subcommand to consume first).
        for cmdline in ["train --rank", "--rank", "train --task mrpc --rank"] {
            let err = Args::parse(&argv(cmdline), &["rank", "task"], &[]).unwrap_err();
            assert!(err.contains("expects a value"), "{cmdline}: {err}");
        }
    }

    #[test]
    fn option_first_argv_has_no_subcommand() {
        // argv starting with an option: nothing is consumed as a command.
        let a = Args::parse(&argv("--verbose train"), &["task"], &["verbose"]).unwrap();
        assert_eq!(a.command, "");
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["train"]);
        // Empty argv parses to an empty command without panicking.
        let e = Args::parse(&[], &[], &[]).unwrap();
        assert_eq!(e.command, "");
    }

    #[test]
    fn lists_and_defaults() {
        let a = Args::parse(&argv("t --ranks 4,8,16"), &["ranks", "tasks"], &[]).unwrap();
        assert_eq!(a.usize_list_or("ranks", &[]).unwrap(), vec![4, 8, 16]);
        assert_eq!(a.str_list_or("tasks", &["cola_syn"]), vec!["cola_syn"]);
        assert_eq!(a.f32_or("missing", 0.5).unwrap(), 0.5);
    }

    #[test]
    fn optional_integers_distinguish_absent_from_given() {
        let a = Args::parse(&argv("t --threads 4"), &["threads"], &[]).unwrap();
        assert_eq!(a.usize_opt("threads").unwrap(), Some(4));
        assert_eq!(a.usize_opt("missing").unwrap(), None);
        let bad = Args::parse(&argv("t --threads four"), &["threads"], &[]).unwrap();
        assert!(bad.usize_opt("threads").is_err());
    }

    #[test]
    fn negative_numbers_are_values_not_flags() {
        let a = Args::parse(&argv("t --lr -0.5"), &["lr"], &[]).unwrap();
        // "-0.5" starts with '-' but not "--", so it's consumed as a value.
        assert_eq!(a.f32_or("lr", 0.0).unwrap(), -0.5);
    }
}
