//! Structured results logging: every experiment run appends a JSON record
//! under `results/` so tables can be rebuilt without re-running.

use crate::util::json::Json;
use std::path::Path;

/// Append one JSON record to `results/<name>.jsonl`.
pub fn append_record(name: &str, record: &Json) {
    let dir = Path::new("results");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join(format!("{name}.jsonl"));
    let mut line = record.to_string();
    line.push('\n');
    use std::io::Write;
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(&path) {
        let _ = f.write_all(line.as_bytes());
    }
}

/// Load all records from `results/<name>.jsonl`.
pub fn load_records(name: &str) -> Vec<Json> {
    let path = Path::new("results").join(format!("{name}.jsonl"));
    let Ok(text) = std::fs::read_to_string(&path) else {
        return Vec::new();
    };
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .filter_map(|l| crate::util::json::parse(l).ok())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_and_load_roundtrip() {
        let name = "results_test_tmp";
        let path = Path::new("results").join(format!("{name}.jsonl"));
        let _ = std::fs::remove_file(&path);
        append_record(name, &Json::obj(vec![("a", Json::num(1.0))]));
        append_record(name, &Json::obj(vec![("a", Json::num(2.0))]));
        let recs = load_records(name);
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[1].get("a").unwrap().as_f64(), Some(2.0));
        let _ = std::fs::remove_file(&path);
    }
}
