//! Sequential multi-task learning (paper §3.2, first bullet).
//!
//! "This approach involves first fine-tuning a model on a specific task,
//! transferring the adapter to a new task for further fine-tuning, and then
//! transferring the adapter back to the original task. […] a significant
//! challenge with sequential learning is the risk of catastrophic
//! forgetting or training interference."
//!
//! This module implements exactly that A → B → A protocol with a single
//! shared adapter, measuring the paper's failure mode: the metric on task A
//! immediately after phase B (the *forgetting gap*) versus after
//! re-adaptation. Joint training (`mtl.rs`) is the paper's preferred
//! alternative; this exists so the comparison in §3.2 is reproducible.

use crate::adapters::AdapterSpec;
use crate::config::{ExperimentConfig, ModelPreset, TrainConfig};
use crate::coordinator::trainer::{eval_metric, SingleTaskTrainer};
use crate::data::{Batcher, TaskId};
use crate::runtime::Backend;
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;
use anyhow::Result;
use std::path::Path;

/// One phase of the sequence: which task was trained and the metrics of
/// *both* tasks after it.
#[derive(Clone, Debug)]
pub struct PhaseLog {
    pub trained_task: TaskId,
    pub metric_a: f64,
    pub metric_b: f64,
}

/// Result of the A → B → A protocol.
#[derive(Clone, Debug)]
pub struct SequentialResult {
    pub task_a: TaskId,
    pub task_b: TaskId,
    pub phases: Vec<PhaseLog>,
    /// metric_A(after phase 1) − metric_A(after phase 2): how much of task
    /// A was forgotten while training on B (positive = forgetting).
    pub forgetting_gap: f64,
    /// metric_A(after phase 3) − metric_A(after phase 1): net gain from the
    /// round trip (the paper's hoped-for transfer, usually ≤ 0).
    pub roundtrip_gain: f64,
}

/// Run sequential learning A → B → A with a single shared adapter.
/// Both tasks must be binary (the shared 2-class artifact).
pub fn run_sequential(
    backend: &dyn Backend,
    model: ModelPreset,
    spec: &AdapterSpec,
    task_a: TaskId,
    task_b: TaskId,
    train: &TrainConfig,
    alpha: f32,
    checkpoint: Option<&Path>,
) -> Result<SequentialResult> {
    for t in [task_a, task_b] {
        let info = t.info();
        anyhow::ensure!(
            !info.regression && info.num_classes == 2,
            "sequential learning uses binary tasks; got {}",
            t.name()
        );
    }
    fn make_trainer<'a>(
        backend: &'a dyn Backend,
        model: ModelPreset,
        spec: &AdapterSpec,
        alpha: f32,
        train: &TrainConfig,
        checkpoint: Option<&Path>,
        task: TaskId,
    ) -> Result<SingleTaskTrainer<'a>> {
        let exp = ExperimentConfig {
            model,
            adapter: spec.kind,
            rank: spec.rank,
            alpha,
            tasks: vec![task.name().to_string()],
            train: train.clone(),
            backend: backend.kind(),
            threads: Some(backend.threads()),
        };
        SingleTaskTrainer::prepare(backend, &exp, task, checkpoint)
    }
    let trainer_a = make_trainer(backend, model, spec, alpha, train, checkpoint, task_a)?;
    let trainer_b = make_trainer(backend, model, spec, alpha, train, checkpoint, task_b)?;
    let batcher = Batcher::new(train.batch_size);

    let eval_both = |params: &[Tensor],
                     ta: &SingleTaskTrainer,
                     tb: &SingleTaskTrainer|
     -> Result<(f64, f64)> {
        let ma = eval_metric(
            ta.eval_runner.as_ref(), params, &ta.ds, &batcher, 0, alpha, task_a.info().metric,
        )?;
        let mb = eval_metric(
            tb.eval_runner.as_ref(), params, &tb.ds, &batcher, 0, alpha, task_b.info().metric,
        )?;
        Ok((ma, mb))
    };

    let mut rng = Pcg64::with_stream(train.seed, 0x1417);
    let mut params = spec.init_params_with(&mut rng, None);
    let mut phases = Vec::new();
    for (phase, trainer) in [(&trainer_a), (&trainer_b), (&trainer_a)].iter().enumerate() {
        trainer.run_from(spec, &mut params)?;
        let (ma, mb) = eval_both(&params, &trainer_a, &trainer_b)?;
        phases.push(PhaseLog {
            trained_task: if phase == 1 { task_b } else { task_a },
            metric_a: ma,
            metric_b: mb,
        });
    }
    Ok(SequentialResult {
        task_a,
        task_b,
        forgetting_gap: phases[0].metric_a - phases[1].metric_a,
        roundtrip_gain: phases[2].metric_a - phases[0].metric_a,
        phases,
    })
}
