//! Single-task fine-tuning trainer (the Table-1 workhorse).
//!
//! Mirrors the paper's protocol (§3.1 / Appendix D): AdamW with linear
//! warmup (warmup_ratio) + linear decay, only adapter weights trainable,
//! frozen random classifier head, eval at every epoch, best-epoch metric
//! reported; multiple seeds aggregated by the caller.

use crate::adapters::AdapterSpec;
use crate::config::{ExperimentConfig, ModelPreset, TrainConfig};
use crate::data::{Batcher, Dataset, TaskId};
use crate::metrics::{self, MetricKind};
use crate::optim::{clip_global_norm, AdamW, LrSchedule};
use crate::runtime::{assemble_frozen, ArtifactSpec, Backend, Step, StepKind};
use crate::tensor::Tensor;
use crate::tt::InitStrategy;
use crate::util::rng::Pcg64;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// Per-epoch record.
#[derive(Clone, Debug)]
pub struct EpochLog {
    pub epoch: usize,
    pub train_loss: f64,
    pub metric: f64,
}

/// Outcome of one fine-tuning run.
#[derive(Clone, Debug)]
pub struct TrainResult {
    pub task: TaskId,
    pub adapter: String,
    pub rank: usize,
    pub param_count: usize,
    pub epochs: Vec<EpochLog>,
    /// Best eval metric across epochs (the paper's reporting rule).
    pub best_metric: f64,
    /// Final trained adapter tensors (export layout).
    pub params: Vec<Tensor>,
}

/// Flatten/unflatten helpers over a list of tensors (optimizer state is one
/// flat vector).
pub fn flatten_all(ts: &[Tensor]) -> Vec<f32> {
    let mut out = Vec::with_capacity(ts.iter().map(|t| t.len()).sum());
    for t in ts {
        out.extend_from_slice(t.data());
    }
    out
}

pub fn unflatten_all(ts: &mut [Tensor], flat: &[f32]) {
    let mut off = 0;
    for t in ts.iter_mut() {
        let n = t.len();
        t.data_mut().copy_from_slice(&flat[off..off + n]);
        off += n;
    }
    debug_assert_eq!(off, flat.len());
}

/// Compute the task metric from logits batches.
pub fn eval_metric(
    runner: &dyn Step,
    params: &[Tensor],
    ds: &Dataset,
    batcher: &Batcher,
    task_idx: i32,
    alpha: f32,
    metric: MetricKind,
) -> Result<f64> {
    let mut preds: Vec<usize> = Vec::new();
    let mut golds: Vec<usize> = Vec::new();
    let mut pred_scores: Vec<f32> = Vec::new();
    let mut gold_scores: Vec<f32> = Vec::new();
    for batch in batcher.eval(ds) {
        let logits = runner.run_eval(params, &batch, task_idx, alpha)?;
        let classes = logits.cols();
        for i in 0..batch.batch_size {
            if batch.weights[i] == 0.0 {
                continue;
            }
            if metric == MetricKind::Spearman {
                pred_scores.push(logits.at(i, 0));
                gold_scores.push(batch.scores[i]);
            } else {
                let mut best = 0;
                for c in 1..classes {
                    if logits.at(i, c) > logits.at(i, best) {
                        best = c;
                    }
                }
                preds.push(best);
                golds.push(batch.labels[i] as usize);
            }
        }
    }
    Ok(match metric {
        MetricKind::Accuracy => metrics::accuracy(&preds, &golds),
        MetricKind::Matthews => metrics::matthews_corr(&preds, &golds)
            .ok_or_else(|| anyhow!("matthews metric on non-binary labels"))?,
        MetricKind::Spearman => metrics::spearman_corr(&pred_scores, &gold_scores)
            .ok_or_else(|| anyhow!("spearman metric on non-finite scores (diverged run?)"))?,
    })
}

/// A fully-wired single-task fine-tuning session (backend-agnostic).
pub struct SingleTaskTrainer<'rt> {
    pub train_runner: Box<dyn Step + 'rt>,
    pub eval_runner: Box<dyn Step + 'rt>,
    pub task: TaskId,
    pub ds: Dataset,
    pub cfg: TrainConfig,
    pub alpha: f32,
}

impl<'rt> SingleTaskTrainer<'rt> {
    /// Wire up runners + data for `cfg` on `task`.
    pub fn prepare(
        backend: &'rt dyn Backend,
        exp: &ExperimentConfig,
        task: TaskId,
        checkpoint: Option<&Path>,
    ) -> Result<SingleTaskTrainer<'rt>> {
        let info = task.info();
        let classes = if info.regression { 1 } else { info.num_classes };
        let dims = exp.model.dims(1);
        let train_spec = ArtifactSpec {
            step: StepKind::Train,
            model: exp.model.name().to_string(),
            adapter: exp.adapter.name(),
            rank: exp.rank,
            classes,
            tasks: 1,
            batch: exp.train.batch_size,
            seq: dims.max_seq,
        };
        let mut eval_spec = train_spec.clone();
        eval_spec.step = StepKind::Eval;
        let entry = backend.entry(&train_spec)?;
        let frozen = std::sync::Arc::new(assemble_frozen(&entry, checkpoint, exp.model)?);
        let train_runner = backend.bind(&train_spec, &frozen)?;
        let eval_runner = backend.bind(&eval_spec, &frozen)?;
        let mut data_rng = Pcg64::with_stream(exp.train.seed, 0xda7a);
        let n_train = exp.train.train_cap.min(info.train_size);
        let ds = task.generate_at(
            n_train,
            exp.train.eval_cap.min(info.eval_size),
            exp.train.seed,
            dims.max_seq,
            dims.vocab,
        );
        let _ = &mut data_rng;
        Ok(SingleTaskTrainer {
            train_runner,
            eval_runner,
            task,
            ds,
            cfg: exp.train.clone(),
            alpha: exp.alpha,
        })
    }

    /// Run the training loop from the spec's default init.
    pub fn run(&self, spec: &AdapterSpec, init: Option<&InitStrategy>) -> Result<TrainResult> {
        let mut rng = Pcg64::with_stream(self.cfg.seed, 0x1417);
        let mut params = spec.init_params_with(&mut rng, init);
        self.run_from(spec, &mut params)
    }

    /// Training loop over provided (mutable) params; returns the result and
    /// leaves the trained values in `params`.
    pub fn run_from(
        &self,
        spec: &AdapterSpec,
        params: &mut Vec<Tensor>,
    ) -> Result<TrainResult> {
        let info = self.task.info();
        let batcher = Batcher::new(self.cfg.batch_size);
        let steps_per_epoch = self.ds.train.len().div_ceil(self.cfg.batch_size);
        let total_steps = steps_per_epoch * self.cfg.epochs;
        let sched = LrSchedule::new(self.cfg.lr, total_steps, self.cfg.warmup_ratio);
        let mut flat = flatten_all(params);
        let mut opt = AdamW::new(flat.len(), self.cfg.weight_decay);
        let mut rng = Pcg64::with_stream(self.cfg.seed, 0x0bac);
        let mut step = 0usize;
        let mut epochs = Vec::new();
        let mut best = f64::NEG_INFINITY;
        for epoch in 0..self.cfg.epochs {
            let mut loss_sum = 0.0f64;
            let mut nb = 0usize;
            for batch in batcher.epoch(&self.ds, &mut rng) {
                let (loss, grads) = self.train_runner.run_train(params, &batch, 0, self.alpha)?;
                let mut gflat = flatten_all(&grads);
                if self.cfg.grad_clip > 0.0 {
                    clip_global_norm(&mut gflat, self.cfg.grad_clip);
                }
                opt.step(&mut flat, &gflat, sched.lr_at(step));
                unflatten_all(params, &flat);
                // Return the consumed grad buffers to the backend's arena.
                self.train_runner.recycle(grads);
                loss_sum += loss as f64;
                nb += 1;
                step += 1;
            }
            let metric = eval_metric(
                self.eval_runner.as_ref(),
                params,
                &self.ds,
                &batcher,
                0,
                self.alpha,
                info.metric,
            )?;
            best = best.max(metric);
            epochs.push(EpochLog {
                epoch,
                train_loss: loss_sum / nb.max(1) as f64,
                metric,
            });
        }
        Ok(TrainResult {
            task: self.task,
            adapter: spec.kind.name(),
            rank: spec.rank,
            param_count: spec.param_count(),
            epochs,
            best_metric: best,
            params: params.clone(),
        })
    }
}

/// Initial trainable tensors for a spec. Adapters come from their init
/// rules; **full fine-tuning** trains the encoder itself, so its trainable
/// set is the pretrained checkpoint (or a fresh encoder when absent).
pub fn init_trainable(
    spec: &AdapterSpec,
    entry: &crate::runtime::ArtifactEntry,
    checkpoint: Option<&Path>,
    seed: u64,
    init: Option<&InitStrategy>,
) -> Result<Vec<Tensor>> {
    if !matches!(spec.kind, crate::adapters::AdapterKind::Full) {
        let mut rng = Pcg64::with_stream(seed, 0x1417);
        return Ok(spec.init_params_with(&mut rng, init));
    }
    let shapes: Vec<(String, Vec<usize>)> = entry
        .trainable_inputs()
        .iter()
        .map(|io| (io.name.clone(), io.shape.clone()))
        .collect();
    match checkpoint {
        Some(p) if p.exists() => {
            let named = crate::coordinator::checkpoint::load(p).map_err(anyhow::Error::msg)?;
            let map: std::collections::HashMap<String, Tensor> = named.into_iter().collect();
            shapes
                .iter()
                .map(|(name, shape)| {
                    let t = map
                        .get(name)
                        .with_context(|| format!("checkpoint missing '{name}' for full FT"))?;
                    anyhow::ensure!(
                        t.shape() == &shape[..],
                        "checkpoint '{}' shape {:?} != artifact {:?}",
                        name,
                        t.shape(),
                        shape
                    );
                    Ok(t.clone())
                })
                .collect()
        }
        _ => Ok(crate::runtime::init_encoder_weights(&shapes, seed)
            .into_iter()
            .map(|(_, t)| t)
            .collect()),
    }
}

/// Convenience: run one seed of (model, adapter, rank, task) end to end.
pub fn run_single_task(
    backend: &dyn Backend,
    model: ModelPreset,
    adapter_spec: &AdapterSpec,
    task: TaskId,
    train: &TrainConfig,
    alpha: f32,
    checkpoint: Option<&Path>,
    init: Option<&InitStrategy>,
) -> Result<TrainResult> {
    let exp = ExperimentConfig {
        model,
        adapter: adapter_spec.kind,
        rank: adapter_spec.rank,
        alpha,
        tasks: vec![task.name().to_string()],
        train: train.clone(),
        backend: backend.kind(),
        threads: Some(backend.threads()),
    };
    let trainer = SingleTaskTrainer::prepare(backend, &exp, task, checkpoint)
        .with_context(|| format!("prepare {} on {}", adapter_spec.kind.name(), task.name()))?;
    let mut params = init_trainable(
        adapter_spec,
        trainer.train_runner.entry(),
        checkpoint,
        train.seed,
        init,
    )?;
    trainer.run_from(adapter_spec, &mut params)
}
