//! L3 coordinator: the paper's training orchestration, in rust.
//!
//! * [`trainer`] — single-task fine-tuning (Table 1 protocol)
//! * [`mtl`] — joint multi-task training with task cores (Table 2, Figs 4-5)
//! * [`dmrg`] — AdamW interleaved with rank-adaptive sweeps + executable
//!   hot-swap (Figs 2, 6)
//! * [`pretrain`] — MLM pretraining of the frozen backbone
//! * [`checkpoint`] — binary tensor container
//! * [`results`] — JSONL experiment records

pub mod checkpoint;
pub mod dmrg;
pub mod mtl;
pub mod pretrain;
pub mod results;
pub mod sequential;
pub mod trainer;

pub use dmrg::{run_dmrg, run_fixed_rank_baseline, DmrgConfig, DmrgResult};
pub use mtl::{run_mtl, MtlConfig, MtlResult};
pub use pretrain::{pretrain, PretrainConfig};
pub use sequential::{run_sequential, SequentialResult};
pub use trainer::{run_single_task, SingleTaskTrainer, TrainResult};
