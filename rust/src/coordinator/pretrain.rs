//! MLM pretraining of the frozen backbone (the RoBERTa stand-in).
//!
//! Runs the full-weight MLM artifact for `steps` batches of the synthetic
//! corpus, AdamW + constant LR + warmup, and writes the checkpoint every
//! `save_every` steps and at the end. The resulting weights are the frozen
//! encoder every fine-tuning experiment loads (DESIGN.md §3 substitution).

use crate::config::ModelPreset;
use crate::coordinator::checkpoint;
use crate::coordinator::trainer::{flatten_all, unflatten_all};
use crate::data::MlmCorpus;
use crate::optim::{clip_global_norm, AdamW, LrSchedule};
use crate::runtime::{checkpoint_path, init_encoder_weights, Backend, Step};
use crate::tensor::Tensor;
use anyhow::Result;
use std::collections::HashMap;

/// Pretraining configuration.
#[derive(Clone, Debug)]
pub struct PretrainConfig {
    pub steps: usize,
    pub lr: f32,
    pub warmup: usize,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for PretrainConfig {
    fn default() -> PretrainConfig {
        PretrainConfig { steps: 600, lr: 1e-3, warmup: 50, seed: 1234, log_every: 50 }
    }
}

/// Loss trace of a pretraining run.
#[derive(Clone, Debug)]
pub struct PretrainResult {
    pub losses: Vec<(usize, f64)>,
    pub final_loss: f64,
    pub checkpoint: std::path::PathBuf,
}

/// Run MLM pretraining for `preset`; saves `checkpoints/pretrained_<p>.bin`.
pub fn pretrain(
    backend: &dyn Backend,
    preset: ModelPreset,
    cfg: &PretrainConfig,
) -> Result<PretrainResult> {
    let spec = backend.pretrain_spec(preset)?;
    let entry = backend.entry(&spec)?;
    // Trainable = the whole encoder; initialize in-rust.
    let shapes: Vec<(String, Vec<usize>)> = entry
        .trainable_inputs()
        .iter()
        .map(|io| (io.name.clone(), io.shape.clone()))
        .collect();
    let named = init_encoder_weights(&shapes, cfg.seed);
    let mut params: Vec<Tensor> = named.iter().map(|(_, t)| t.clone()).collect();
    let names: Vec<String> = named.into_iter().map(|(n, _)| n).collect();

    let runner = backend.bind(&spec, &std::sync::Arc::new(HashMap::new()))?;
    println!(
        "[pretrain {}] backend: {} ({} worker threads)",
        preset.name(),
        backend.platform(),
        backend.threads()
    );
    let dims = preset.dims(1);
    let mut corpus = MlmCorpus::new(dims.vocab, spec.seq, cfg.seed);
    let sched = LrSchedule::new(cfg.lr, cfg.steps, cfg.warmup as f32 / cfg.steps.max(1) as f32);
    let mut flat = flatten_all(&params);
    let mut opt = AdamW::new(flat.len(), 0.01);
    let mut losses = Vec::new();
    let mut final_loss = f64::NAN;
    for step in 0..cfg.steps {
        let batch = corpus.next_batch(spec.batch);
        let (loss, grads) = runner.run_pretrain(&params, &batch)?;
        let mut gflat = flatten_all(&grads);
        clip_global_norm(&mut gflat, 1.0);
        opt.step(&mut flat, &gflat, sched.lr_at(step));
        unflatten_all(&mut params, &flat);
        // Return the consumed grad buffers to the backend's arena.
        runner.recycle(grads);
        final_loss = loss as f64;
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            losses.push((step, loss as f64));
            println!("[pretrain {}] step {:>5} loss {:.4}", preset.name(), step, loss);
        }
    }
    let path = checkpoint_path(preset);
    let tensors: Vec<(String, Tensor)> =
        names.into_iter().zip(params.into_iter()).collect();
    checkpoint::save(&path, &tensors).map_err(anyhow::Error::msg)?;
    println!("[pretrain {}] saved {}", preset.name(), path.display());
    Ok(PretrainResult { losses, final_loss, checkpoint: path })
}
