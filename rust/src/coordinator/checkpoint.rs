//! Checkpoint store: a minimal binary tensor container.
//!
//! Format (little-endian):
//! ```text
//! v1: magic "MTT1" | u32 n_tensors
//! v2: magic "MTT2" | u32 meta_len | meta JSON bytes | u32 n_tensors
//! per tensor: u32 name_len | name bytes | u32 ndim | u64 dims... | f32 data...
//! ```
//! Used for the pretrained frozen backbone (written by `metatt pretrain`,
//! read by every fine-tuning run) and for trained adapter states. The v2
//! header carries a small named-metadata section ([`CheckpointMeta`]:
//! adapter family, rank, task count, α, model preset) so consumers like
//! `metatt serve --checkpoint` can validate compatibility up front instead
//! of failing on a shape mismatch deep inside bind. v1 files keep loading
//! unchanged ([`load`] / [`load_with_meta`] accept both).
//!
//! **Crash safety (PR 8).** Writers append an 8-byte trailer — the magic
//! `"MTTC"` followed by the little-endian IEEE CRC32 of every preceding
//! byte — and land the file via temp-file + `sync_all` + atomic rename, so
//! a crash mid-save can never replace a good checkpoint with a torn one
//! (the hot-swap `reload` path reads either the old file or the new file,
//! never half of each). The loader verifies and strips the trailer when
//! the last 8 bytes carry the magic; trailer-less files from older writers
//! keep loading through the original path.

use crate::obs::{global_event, EventCode};
use crate::tensor::Tensor;
use crate::util::fault::FaultPlan;
use crate::util::json::{self, Json};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"MTT1";
const MAGIC_V2: &[u8; 4] = b"MTT2";
/// Trailer magic: `body | "MTTC" | u32 crc32(body | "MTTC"-preceding bytes)`.
const TRAILER_MAGIC: &[u8; 4] = b"MTTC";

/// IEEE CRC32 (reflected, polynomial 0xEDB88320) — the zlib/PNG variant.
/// Bitwise, dependency-free; checkpoint saves are not write-bound.
fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// Cap on the v2 metadata section: the meta JSON is a handful of scalar
/// fields, so anything larger is corruption, not data.
const MAX_META_LEN: usize = 1 << 16;

/// Named metadata describing the adapter state a checkpoint holds.
#[derive(Clone, Debug, PartialEq)]
pub struct CheckpointMeta {
    /// Adapter family name ("metatt4d", "metatt4p1d", …).
    pub adapter: String,
    /// TT interior rank (or the family's rank parameter).
    pub rank: usize,
    /// Number of tasks the adapter was trained over (task-core arity).
    pub tasks: usize,
    /// Scaling α the adapter was trained with.
    pub alpha: f32,
    /// Model preset the adapter sizes itself against.
    pub model: String,
    /// Storage dtype of the tensors in this container ("f32" for every
    /// writer today — the trainer always checkpoints full precision).
    /// `metatt serve --checkpoint` validates `--serve-dtype` against it:
    /// an f32 source may serve at any dtype (quantization happens at
    /// bind/fold), but a non-f32 source pins the serving dtype. Files
    /// written before this field existed load as "f32".
    pub dtype: String,
}

impl CheckpointMeta {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("adapter", Json::str(self.adapter.clone())),
            ("rank", Json::num(self.rank as f64)),
            ("tasks", Json::num(self.tasks as f64)),
            ("alpha", Json::num(self.alpha)),
            ("model", Json::str(self.model.clone())),
            ("dtype", Json::str(self.dtype.clone())),
        ])
    }

    fn from_json(doc: &Json) -> Result<CheckpointMeta, String> {
        let s = |k: &str| {
            doc.get(k)
                .and_then(|v| v.as_str())
                .map(|v| v.to_string())
                .ok_or_else(|| format!("checkpoint meta missing '{k}'"))
        };
        let n = |k: &str| {
            doc.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| format!("checkpoint meta missing '{k}'"))
        };
        Ok(CheckpointMeta {
            adapter: s("adapter")?,
            rank: n("rank")?,
            tasks: n("tasks")?,
            alpha: doc
                .get("alpha")
                .and_then(|v| v.as_f64())
                .ok_or("checkpoint meta missing 'alpha'")? as f32,
            model: s("model")?,
            // Absent in files written before the dtype field existed;
            // every such writer stored full-precision tensors.
            dtype: doc
                .get("dtype")
                .and_then(|v| v.as_str())
                .unwrap_or("f32")
                .to_string(),
        })
    }
}

/// Serialize the per-tensor body shared by both container versions.
fn body_bytes(tensors: &[(String, Tensor)]) -> Vec<u8> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for (name, t) in tensors {
        let nb = name.as_bytes();
        buf.extend_from_slice(&(nb.len() as u32).to_le_bytes());
        buf.extend_from_slice(nb);
        buf.extend_from_slice(&(t.ndim() as u32).to_le_bytes());
        for &d in t.shape() {
            buf.extend_from_slice(&(d as u64).to_le_bytes());
        }
        for &v in t.data() {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    buf
}

/// Crash-safe landing: append the CRC trailer, write a sibling temp file,
/// fsync, and atomically rename over `path`. A reader racing the save — or
/// a crash at any instant — observes either the previous complete file or
/// the new complete file, never a prefix. `faults` may tear the write
/// (`torn_write@save=N`): only half the temp file lands and the rename is
/// skipped, simulating a crash mid-save.
fn write_file(path: &Path, buf: &[u8], faults: Option<&FaultPlan>) -> Result<(), String> {
    if let Some(parent) = path.parent() {
        let _ = std::fs::create_dir_all(parent);
    }
    let mut full = Vec::with_capacity(buf.len() + 8);
    full.extend_from_slice(buf);
    full.extend_from_slice(TRAILER_MAGIC);
    full.extend_from_slice(&crc32(buf).to_le_bytes());
    let file_name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "checkpoint".into());
    let tmp = path.with_file_name(format!("{file_name}.tmp"));
    if faults.is_some_and(|f| f.on_save()) {
        let _ = std::fs::write(&tmp, &full[..full.len() / 2]);
        global_event(EventCode::CkptSave, full.len() as u64, 1);
        return Err(format!(
            "injected fault: torn write left {} partial; {} untouched",
            tmp.display(),
            path.display()
        ));
    }
    let land = (|| -> std::io::Result<()> {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&full)?;
        f.sync_all()?;
        drop(f);
        std::fs::rename(&tmp, path)
    })();
    land.map_err(|e| {
        let _ = std::fs::remove_file(&tmp);
        format!("write {}: {e}", path.display())
    })?;
    // Span payload: (bytes landed, torn? 0/1). These free functions have no
    // engine to hand them an `Obs`, so they go through the process-global
    // handle — a single relaxed load on a static when tracing is unarmed.
    global_event(EventCode::CkptSave, full.len() as u64, 0);
    Ok(())
}

/// Save named tensors (v1 container, no metadata). Order is preserved.
pub fn save(path: &Path, tensors: &[(String, Tensor)]) -> Result<(), String> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&body_bytes(tensors));
    write_file(path, &buf, None)
}

/// Save named tensors with a [`CheckpointMeta`] header (v2 container).
pub fn save_with_meta(
    path: &Path,
    meta: &CheckpointMeta,
    tensors: &[(String, Tensor)],
) -> Result<(), String> {
    save_with_meta_faults(path, meta, tensors, None)
}

/// [`save_with_meta`] with an explicit fault plan: `torn_write@save=N`
/// entries tear the Nth save (partial temp file, no rename) so chaos tests
/// can pin that a crashed save never corrupts the live checkpoint.
pub fn save_with_meta_faults(
    path: &Path,
    meta: &CheckpointMeta,
    tensors: &[(String, Tensor)],
    faults: Option<&FaultPlan>,
) -> Result<(), String> {
    let meta_bytes = meta.to_json().to_string().into_bytes();
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC_V2);
    buf.extend_from_slice(&(meta_bytes.len() as u32).to_le_bytes());
    buf.extend_from_slice(&meta_bytes);
    buf.extend_from_slice(&body_bytes(tensors));
    write_file(path, &buf, faults)
}

/// Hard cap on tensor rank: nothing in the layout exceeds 4-D, so a larger
/// header value is corruption, not data.
const MAX_NDIM: usize = 16;

/// Load named tensors in stored order (metadata, if any, discarded).
pub fn load(path: &Path) -> Result<Vec<(String, Tensor)>, String> {
    load_with_meta(path).map(|(_, tensors)| tensors)
}

/// Load named tensors plus the v2 metadata header when present (legacy v1
/// files return `None` metadata).
///
/// Header fields come from disk and may be corrupted (or adversarial), so
/// every count is validated against the bytes actually present *before* it
/// sizes an allocation, and all products use checked arithmetic — a crafted
/// `u64::MAX`-dimension shape must produce a clean `Err`, not a wrapped
/// multiply in release mode followed by a bogus `take` length or OOM.
pub fn load_with_meta(
    path: &Path,
) -> Result<(Option<CheckpointMeta>, Vec<(String, Tensor)>), String> {
    let mut f = std::fs::File::open(path).map_err(|e| format!("open {}: {e}", path.display()))?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf).map_err(|e| format!("read {}: {e}", path.display()))?;
    // CRC trailer (see module docs): verify and strip when the last 8
    // bytes carry the trailer magic; files from pre-trailer writers fall
    // through to the original parse. A trailer-shaped tail whose checksum
    // does not match is rejected — that is a torn or bit-flipped file, and
    // the structural parse below cannot be trusted to catch it.
    if buf.len() >= 12 && &buf[buf.len() - 8..buf.len() - 4] == TRAILER_MAGIC {
        let stored = u32::from_le_bytes(buf[buf.len() - 4..].try_into().unwrap());
        let body_len = buf.len() - 8;
        if crc32(&buf[..body_len]) != stored {
            return Err(format!(
                "{}: checksum mismatch (torn or corrupted checkpoint write)",
                path.display()
            ));
        }
        buf.truncate(body_len);
    }
    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8], String> {
        // `pos + n` cannot wrap: pos <= buf.len() and n is validated below.
        if n > buf.len() - *pos {
            return Err("truncated checkpoint".into());
        }
        let s = &buf[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    let magic: [u8; 4] = take(&mut pos, 4)?.try_into().unwrap();
    let meta = if &magic == MAGIC {
        None
    } else if &magic == MAGIC_V2 {
        let meta_len = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        if meta_len > MAX_META_LEN {
            return Err(format!("checkpoint meta section implausibly large ({meta_len} bytes)"));
        }
        let raw = take(&mut pos, meta_len)?;
        let text = std::str::from_utf8(raw).map_err(|_| "checkpoint meta is not UTF-8")?;
        let doc = json::parse(text).map_err(|e| format!("checkpoint meta: {e}"))?;
        Some(CheckpointMeta::from_json(&doc)?)
    } else {
        return Err(format!("{}: bad magic (not a MetaTT checkpoint)", path.display()));
    };
    let n = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
    // Every tensor costs >= 8 header bytes; cap the preallocation by what
    // the file could possibly hold instead of trusting the raw u32.
    let max_plausible = (buf.len() - pos) / 8;
    if n > max_plausible {
        return Err(format!(
            "checkpoint header claims {n} tensors but only {} bytes remain",
            buf.len() - pos
        ));
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let name_len = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())
            .map_err(|_| "bad tensor name".to_string())?;
        let ndim = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        if ndim > MAX_NDIM {
            return Err(format!("tensor '{name}': implausible rank {ndim}"));
        }
        let mut shape = Vec::with_capacity(ndim);
        let mut numel_u64: u64 = 1;
        for _ in 0..ndim {
            let dim = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
            numel_u64 = numel_u64
                .checked_mul(dim)
                .ok_or_else(|| format!("tensor '{name}': shape product overflows"))?;
            let dim_usize = usize::try_from(dim)
                .map_err(|_| format!("tensor '{name}': dimension {dim} exceeds usize"))?;
            shape.push(dim_usize);
        }
        let byte_len = numel_u64
            .checked_mul(4)
            .ok_or_else(|| format!("tensor '{name}': byte length overflows"))?;
        // Validate against the remaining bytes before any allocation.
        let remaining = (buf.len() - pos) as u64;
        if byte_len > remaining {
            return Err(format!(
                "tensor '{name}': header claims {byte_len} data bytes but only \
                 {remaining} remain"
            ));
        }
        let numel = numel_u64 as usize; // <= remaining/4, fits usize
        let raw = take(&mut pos, numel * 4)?;
        let data: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        out.push((name, Tensor::from_vec(&shape, data)));
    }
    if pos != buf.len() {
        return Err("trailing bytes in checkpoint".into());
    }
    global_event(EventCode::CkptLoad, buf.len() as u64, out.len() as u64);
    Ok((meta, out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn roundtrip_preserves_names_shapes_data() {
        let mut rng = Pcg64::new(1);
        let tensors = vec![
            ("emb".to_string(), Tensor::randn(&[10, 4], 1.0, &mut rng)),
            ("scalar-ish".to_string(), Tensor::randn(&[1], 1.0, &mut rng)),
            ("core.g2".to_string(), Tensor::randn(&[3, 2, 3], 1.0, &mut rng)),
        ];
        let dir = std::env::temp_dir().join("metatt_ckpt_test");
        let path = dir.join("test.bin");
        save(&path, &tensors).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.len(), 3);
        for ((n0, t0), (n1, t1)) in tensors.iter().zip(&loaded) {
            assert_eq!(n0, n1);
            assert_eq!(t0, t1);
        }
        std::fs::remove_file(&path).ok();
    }

    fn demo_meta() -> CheckpointMeta {
        CheckpointMeta {
            adapter: "metatt4p1d".into(),
            rank: 6,
            tasks: 3,
            alpha: 1.5,
            model: "tiny".into(),
            dtype: "f32".into(),
        }
    }

    #[test]
    fn meta_without_dtype_defaults_to_f32() {
        // Files written before the dtype field existed must keep loading.
        let meta_json =
            br#"{"adapter": "metatt4d", "rank": 4, "tasks": 1, "alpha": 1.0, "model": "tiny"}"#;
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(b"MTT2");
        buf.extend_from_slice(&(meta_json.len() as u32).to_le_bytes());
        buf.extend_from_slice(meta_json);
        buf.extend_from_slice(&0u32.to_le_bytes()); // zero tensors
        let dir = std::env::temp_dir().join("metatt_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("no_dtype_meta.bin");
        std::fs::write(&p, &buf).unwrap();
        let (meta, _) = load_with_meta(&p).unwrap();
        assert_eq!(meta.unwrap().dtype, "f32");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn v2_meta_roundtrips_and_v1_loads_as_legacy() {
        let mut rng = Pcg64::new(2);
        let tensors = vec![("g1".to_string(), Tensor::randn(&[8, 4], 1.0, &mut rng))];
        let dir = std::env::temp_dir().join("metatt_ckpt_test");
        let v2 = dir.join("meta.bin");
        save_with_meta(&v2, &demo_meta(), &tensors).unwrap();
        let (meta, loaded) = load_with_meta(&v2).unwrap();
        assert_eq!(meta.as_ref(), Some(&demo_meta()));
        assert_eq!(loaded, tensors);
        // The meta-unaware `load` reads v2 files too (meta skipped).
        assert_eq!(load(&v2).unwrap(), tensors);
        // Legacy v1 files come back with no metadata, tensors intact.
        let v1 = dir.join("legacy.bin");
        save(&v1, &tensors).unwrap();
        let (meta1, loaded1) = load_with_meta(&v1).unwrap();
        assert!(meta1.is_none());
        assert_eq!(loaded1, tensors);
        std::fs::remove_file(&v2).ok();
        std::fs::remove_file(&v1).ok();
    }

    #[test]
    fn v2_with_corrupt_meta_is_rejected() {
        let dir = std::env::temp_dir().join("metatt_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        // Truncated meta section: header claims more bytes than present.
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(b"MTT2");
        buf.extend_from_slice(&100u32.to_le_bytes());
        buf.extend_from_slice(b"{}"); // only 2 of the promised 100 bytes
        let p = dir.join("trunc_meta.bin");
        std::fs::write(&p, &buf).unwrap();
        assert!(load_with_meta(&p).unwrap_err().contains("truncated"));
        std::fs::remove_file(&p).ok();
        // Valid-length but incomplete meta JSON: a clean field error.
        let meta_json = b"{\"adapter\": \"lora\"}";
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(b"MTT2");
        buf.extend_from_slice(&(meta_json.len() as u32).to_le_bytes());
        buf.extend_from_slice(meta_json);
        buf.extend_from_slice(&0u32.to_le_bytes()); // zero tensors
        let p = dir.join("partial_meta.bin");
        std::fs::write(&p, &buf).unwrap();
        let err = load_with_meta(&p).unwrap_err();
        assert!(err.contains("meta missing"), "unexpected: {err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("metatt_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    /// Build a crafted checkpoint: magic, tensor count, then one tensor
    /// header with the given shape dims and (possibly missing) data bytes.
    fn crafted(shape_dims: &[u64], data_bytes: usize) -> Vec<u8> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&1u32.to_le_bytes());
        let name = b"t";
        buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
        buf.extend_from_slice(name);
        buf.extend_from_slice(&(shape_dims.len() as u32).to_le_bytes());
        for &d in shape_dims {
            buf.extend_from_slice(&d.to_le_bytes());
        }
        buf.resize(buf.len() + data_bytes, 0u8);
        buf
    }

    fn write_and_load(tag: &str, bytes: &[u8]) -> Result<Vec<(String, Tensor)>, String> {
        let dir = std::env::temp_dir().join("metatt_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("crafted_{tag}.bin"));
        std::fs::write(&path, bytes).unwrap();
        let res = load(&path);
        std::fs::remove_file(&path).ok();
        res
    }

    #[test]
    fn crafted_shape_product_overflow_is_rejected() {
        // u64::MAX * 2 wraps in release if multiplied unchecked; the loader
        // must reject it cleanly instead of computing a bogus take length.
        let err = write_and_load("overflow", &crafted(&[u64::MAX, 2], 0)).unwrap_err();
        assert!(err.contains("overflow"), "unexpected error: {err}");
    }

    #[test]
    fn crafted_numel_times_four_overflow_is_rejected() {
        // numel fits u64 but numel*4 wraps: 2^62 elements.
        let err = write_and_load("x4", &crafted(&[1u64 << 62], 0)).unwrap_err();
        assert!(
            err.contains("overflow") || err.contains("remain"),
            "unexpected error: {err}"
        );
    }

    #[test]
    fn crafted_oversized_numel_is_rejected_before_allocating() {
        // A "1 TB tensor" header over an 8-byte body must fail on the
        // remaining-bytes check, never preallocate.
        let err = write_and_load("huge", &crafted(&[1u64 << 38], 8)).unwrap_err();
        assert!(err.contains("remain"), "unexpected error: {err}");
    }

    #[test]
    fn crafted_tensor_count_is_capped_by_file_size() {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&u32::MAX.to_le_bytes()); // 4 billion tensors
        let err = write_and_load("count", &buf).unwrap_err();
        assert!(err.contains("tensors"), "unexpected error: {err}");
    }

    #[test]
    fn crafted_implausible_rank_is_rejected() {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.push(b't');
        buf.extend_from_slice(&1000u32.to_le_bytes()); // ndim = 1000
        let err = write_and_load("rank", &buf).unwrap_err();
        assert!(err.contains("rank"), "unexpected error: {err}");
    }

    #[test]
    fn truncated_data_is_rejected() {
        // Valid 4x4 header but only half the f32 payload present.
        let err = write_and_load("trunc", &crafted(&[4, 4], 32)).unwrap_err();
        assert!(err.contains("remain"), "unexpected error: {err}");
    }

    #[test]
    fn crc32_matches_the_ieee_reference_vector() {
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn saved_files_carry_a_verifying_trailer_and_a_bit_flip_is_caught() {
        let mut rng = Pcg64::new(4);
        let tensors = vec![("g1".to_string(), Tensor::randn(&[8, 4], 1.0, &mut rng))];
        let dir = std::env::temp_dir().join("metatt_ckpt_test");
        let path = dir.join("crc.bin");
        save(&path, &tensors).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[bytes.len() - 8..bytes.len() - 4], TRAILER_MAGIC);
        assert_eq!(load(&path).unwrap(), tensors);
        // Flip one payload bit: the structural parse would happily accept
        // the mutated f32, so only the checksum can catch this.
        bytes[20] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(err.contains("checksum mismatch"), "unexpected: {err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn torn_tail_is_rejected_cleanly() {
        let mut rng = Pcg64::new(5);
        let tensors = vec![("g1".to_string(), Tensor::randn(&[8, 4], 1.0, &mut rng))];
        let dir = std::env::temp_dir().join("metatt_ckpt_test");
        let path = dir.join("torn_tail.bin");
        save(&path, &tensors).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Drop the trailer plus part of the payload: falls through to the
        // legacy parse, which sees a truncated body.
        std::fs::write(&path, &bytes[..bytes.len() - 20]).unwrap();
        let err = load(&path).unwrap_err();
        assert!(
            err.contains("remain") || err.contains("truncated"),
            "unexpected: {err}"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn injected_torn_save_leaves_the_previous_checkpoint_intact() {
        let mut rng = Pcg64::new(6);
        let a = vec![("g1".to_string(), Tensor::randn(&[8, 4], 1.0, &mut rng))];
        let b = vec![("g1".to_string(), Tensor::randn(&[8, 4], 1.0, &mut rng))];
        let dir = std::env::temp_dir().join("metatt_ckpt_test");
        let path = dir.join("atomic.bin");
        save_with_meta(&path, &demo_meta(), &a).unwrap();
        // Tear the *next* save: the error is surfaced, the temp file holds
        // only a prefix, and the live checkpoint still loads as `a`.
        let plan = FaultPlan::parse("torn_write@save=1").unwrap();
        let err =
            save_with_meta_faults(&path, &demo_meta(), &b, Some(&plan)).unwrap_err();
        assert!(err.contains("torn write"), "unexpected: {err}");
        let (meta, loaded) = load_with_meta(&path).unwrap();
        assert_eq!(meta.unwrap(), demo_meta());
        assert_eq!(loaded, a, "a torn save must never touch the live file");
        // The torn temp file itself is rejected, not silently parsed.
        let tmp = dir.join("atomic.bin.tmp");
        assert!(load(&tmp).is_err(), "a torn prefix must not load");
        // A retry with the fault spent lands normally (save counter = 2).
        save_with_meta_faults(&path, &demo_meta(), &b, Some(&plan)).unwrap();
        assert_eq!(load(&path).unwrap(), b);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&tmp).ok();
    }
}
