//! Checkpoint store: a minimal binary tensor container.
//!
//! Format (little-endian):
//! ```text
//! magic "MTT1" | u32 n_tensors
//! per tensor: u32 name_len | name bytes | u32 ndim | u64 dims... | f32 data...
//! ```
//! Used for the pretrained frozen backbone (written by `metatt pretrain`,
//! read by every fine-tuning run) and for trained adapter states.

use crate::tensor::Tensor;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"MTT1";

/// Save named tensors. Order is preserved.
pub fn save(path: &Path, tensors: &[(String, Tensor)]) -> Result<(), String> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for (name, t) in tensors {
        let nb = name.as_bytes();
        buf.extend_from_slice(&(nb.len() as u32).to_le_bytes());
        buf.extend_from_slice(nb);
        buf.extend_from_slice(&(t.ndim() as u32).to_le_bytes());
        for &d in t.shape() {
            buf.extend_from_slice(&(d as u64).to_le_bytes());
        }
        for &v in t.data() {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    if let Some(parent) = path.parent() {
        let _ = std::fs::create_dir_all(parent);
    }
    let mut f = std::fs::File::create(path).map_err(|e| format!("create {}: {e}", path.display()))?;
    f.write_all(&buf).map_err(|e| format!("write {}: {e}", path.display()))
}

/// Load named tensors in stored order.
pub fn load(path: &Path) -> Result<Vec<(String, Tensor)>, String> {
    let mut f = std::fs::File::open(path).map_err(|e| format!("open {}: {e}", path.display()))?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf).map_err(|e| format!("read {}: {e}", path.display()))?;
    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8], String> {
        if *pos + n > buf.len() {
            return Err("truncated checkpoint".into());
        }
        let s = &buf[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    if take(&mut pos, 4)? != MAGIC {
        return Err(format!("{}: bad magic (not a MetaTT checkpoint)", path.display()));
    }
    let n = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let name_len = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())
            .map_err(|_| "bad tensor name".to_string())?;
        let ndim = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize);
        }
        let numel: usize = shape.iter().product();
        let raw = take(&mut pos, numel * 4)?;
        let data: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        out.push((name, Tensor::from_vec(&shape, data)));
    }
    if pos != buf.len() {
        return Err("trailing bytes in checkpoint".into());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn roundtrip_preserves_names_shapes_data() {
        let mut rng = Pcg64::new(1);
        let tensors = vec![
            ("emb".to_string(), Tensor::randn(&[10, 4], 1.0, &mut rng)),
            ("scalar-ish".to_string(), Tensor::randn(&[1], 1.0, &mut rng)),
            ("core.g2".to_string(), Tensor::randn(&[3, 2, 3], 1.0, &mut rng)),
        ];
        let dir = std::env::temp_dir().join("metatt_ckpt_test");
        let path = dir.join("test.bin");
        save(&path, &tensors).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.len(), 3);
        for ((n0, t0), (n1, t1)) in tensors.iter().zip(&loaded) {
            assert_eq!(n0, n1);
            assert_eq!(t0, t1);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("metatt_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
