//! DMRG rank-adaptive training scheduler (paper §3.3, Figures 2 & 6).
//!
//! Interleaves AdamW epochs with DMRG-inspired sweeps (Algorithm 1): after
//! each scheduled epoch the TT is truncated to the next rank on the
//! schedule, *then* evaluated (the paper's ordering — this is what produces
//! the characteristic accuracy gorges followed by rapid recovery). A rank
//! change means new parameter shapes, so the scheduler
//!
//!   1. imports the trained cores into the host-side [`MetaTt`] chain,
//!   2. runs [`dmrg_sweep`] (merge → truncated Jacobi SVD → re-split),
//!   3. **reinitializes the Adam moments** (paper: "one must reinitialize
//!      Adam moments after each truncation"),
//!   4. **hot-swaps the compiled executable** for the matching-rank HLO
//!      artifact via the runtime's spec-keyed cache (DESIGN.md §7.1).

use crate::adapters::{AdapterKind, AdapterSpec};
use crate::config::{ModelPreset, TrainConfig};
use crate::coordinator::trainer::{eval_metric, flatten_all, unflatten_all};
use crate::data::{Batcher, TaskId};
use crate::optim::{clip_global_norm, AdamW, LrSchedule};
use crate::runtime::{assemble_frozen, ArtifactSpec, Backend, Step, StepKind};
use crate::tt::{dmrg_sweep, MetaTt, RankSchedule};
use crate::util::rng::Pcg64;
use anyhow::{Context, Result};
use std::path::Path;

/// Per-epoch record of a DMRG run.
#[derive(Clone, Debug)]
pub struct DmrgEpochLog {
    pub epoch: usize,
    pub train_loss: f64,
    pub metric: f64,
    /// Max interior TT rank when this epoch was *evaluated*.
    pub rank: usize,
    /// Whether a sweep fired after this epoch's training (before eval).
    pub swept: bool,
    /// Max relative singular weight dropped by that sweep.
    pub dropped: f32,
}

/// Result of an AdamW+DMRG run.
#[derive(Clone, Debug)]
pub struct DmrgResult {
    pub task: TaskId,
    pub epochs: Vec<DmrgEpochLog>,
    /// Best metric observed at the final (smallest) rank.
    pub best_at_final_rank: f64,
    pub final_rank: usize,
    pub executables_compiled: usize,
}

/// Configuration for the DMRG experiment.
#[derive(Clone, Debug)]
pub struct DmrgConfig {
    pub train: TrainConfig,
    pub alpha: f32,
    pub start_rank: usize,
    pub schedule: RankSchedule,
}

impl Default for DmrgConfig {
    fn default() -> DmrgConfig {
        DmrgConfig {
            // Paper §3.3 / App. C: constant lr 5e-4, alpha 2 (paper batch is
            // 32; artifacts are lowered at batch 16 — same steps/epoch scale
            // at our downsampled caps).
            train: TrainConfig {
                epochs: 20,
                batch_size: 16,
                lr: 5e-4,
                warmup_ratio: 0.0,
                grad_clip: 3.0,
                ..Default::default()
            },
            alpha: 2.0,
            start_rank: 10,
            // Anneal 10 -> 4, one rank every 2 epochs starting after epoch 2.
            schedule: RankSchedule::anneal(9, 4, 2, 2),
        }
    }
}

fn make_spec(
    step: StepKind,
    model: ModelPreset,
    kind: AdapterKind,
    rank: usize,
    batch: usize,
) -> ArtifactSpec {
    let dims = model.dims(1);
    ArtifactSpec {
        step,
        model: model.name().to_string(),
        adapter: kind.name(),
        rank,
        classes: 2,
        tasks: 1,
        batch,
        seq: dims.max_seq,
    }
}

/// Run AdamW interleaved with DMRG sweeps on a binary task (MRPC/RTE
/// analogues in the paper).
pub fn run_dmrg(
    backend: &dyn Backend,
    model: ModelPreset,
    kind: AdapterKind,
    task: TaskId,
    cfg: &DmrgConfig,
    checkpoint: Option<&Path>,
) -> Result<DmrgResult> {
    let info = task.info();
    anyhow::ensure!(
        !info.regression && info.num_classes == 2,
        "DMRG experiments use binary tasks (paper Figs 2/6)"
    );
    let dims = model.dims(1);
    let metatt_kind = match kind {
        AdapterKind::MetaTt(k) => k,
        other => anyhow::bail!("DMRG needs a MetaTT adapter, got {:?}", other),
    };

    // Host-side TT mirror at the starting rank.
    let spec0 = AdapterSpec::new(kind, cfg.start_rank, cfg.alpha, dims);
    let mut rng = Pcg64::with_stream(cfg.train.seed, 0xd312);
    let mut tt = spec0.build_metatt(&mut rng);
    let mut params = tt.export_cores();

    // Verify the whole rank ladder is executable before starting (on the
    // PJRT backend this checks the manifest; the ref backend synthesizes
    // every rank's layout, so the ladder is always available). Each rank's
    // check is independent — fan out across the backend's worker budget.
    let ladder = cfg.schedule.ranks_visited(cfg.start_rank);
    let checks = crate::util::threadpool::par_map(&ladder, backend.threads(), |&r| {
        backend
            .entry(&make_spec(StepKind::Train, model, kind, r, cfg.train.batch_size))
            .map(|_| ())
            .with_context(|| format!("rank-{r} artifact missing for the DMRG ladder"))
    });
    for c in checks {
        c?;
    }

    // Frozen inputs are rank-independent; assemble once, re-bind per rank.
    let entry0 = backend.entry(&make_spec(
        StepKind::Train,
        model,
        kind,
        cfg.start_rank,
        cfg.train.batch_size,
    ))?;
    let frozen = std::sync::Arc::new(assemble_frozen(&entry0, checkpoint, model)?);

    let compiled_before = backend.cached_executables();
    let (mut train_runner, mut eval_runner) =
        bind_pair(backend, &frozen, model, kind, cfg.start_rank, cfg.train.batch_size)?;

    let ds = task.generate_at(
        cfg.train.train_cap.min(info.train_size),
        cfg.train.eval_cap.min(info.eval_size),
        cfg.train.seed,
        dims.max_seq,
        dims.vocab,
    );
    let batcher = Batcher::new(cfg.train.batch_size);
    let sched = LrSchedule::constant(cfg.train.lr); // paper: constant lr
    let mut flat = flatten_all(&params);
    let mut opt = AdamW::new(flat.len(), cfg.train.weight_decay);

    let mut epochs = Vec::new();
    let mut data_rng = Pcg64::with_stream(cfg.train.seed, 0x0bad);
    let mut step = 0usize;
    for epoch in 0..cfg.train.epochs {
        let mut loss_sum = 0.0;
        let mut nb = 0usize;
        for batch in batcher.epoch(&ds, &mut data_rng) {
            let (loss, grads) = train_runner.run_train(&params, &batch, 0, cfg.alpha)?;
            let mut gflat = flatten_all(&grads);
            if cfg.train.grad_clip > 0.0 {
                clip_global_norm(&mut gflat, cfg.train.grad_clip);
            }
            opt.step(&mut flat, &gflat, sched.lr_at(step));
            unflatten_all(&mut params, &flat);
            // Return the consumed grad buffers to the backend's arena.
            train_runner.recycle(grads);
            loss_sum += loss as f64;
            nb += 1;
            step += 1;
        }

        // Scheduled truncation, applied BEFORE this epoch's eval (paper).
        let mut swept = false;
        let mut dropped = 0.0f32;
        if let Some(target) = cfg.schedule.rank_after_epoch(epoch) {
            if target < tt.chain.max_rank() {
                tt.import_cores(&params);
                let report = dmrg_sweep(&mut tt.chain, &|_| target);
                dropped = report.max_dropped();
                // The sweep may return bonds < target when the numerical
                // rank collapsed; artifacts exist per uniform rank, so pad
                // back up to the uniform target if needed.
                pad_chain_to_rank(&mut tt, target);
                params = tt.export_cores();
                flat = flatten_all(&params);
                // Moments are shape-bound: reset (paper §3.3).
                opt.reset_moments(flat.len());
                // Hot-swap executables for the new rank.
                let (t, e) =
                    bind_pair(backend, &frozen, model, kind, target, cfg.train.batch_size)?;
                train_runner = t;
                eval_runner = e;
                swept = true;
            }
        }

        let metric = eval_metric(
            eval_runner.as_ref(),
            &params,
            &ds,
            &batcher,
            0,
            cfg.alpha,
            info.metric,
        )?;
        epochs.push(DmrgEpochLog {
            epoch,
            train_loss: loss_sum / nb.max(1) as f64,
            metric,
            rank: tt.chain.max_rank(),
            swept,
            dropped,
        });
    }
    let final_rank = cfg.schedule.final_rank();
    let best_at_final = epochs
        .iter()
        .filter(|e| e.rank <= final_rank)
        .map(|e| e.metric)
        .fold(f64::NEG_INFINITY, f64::max);
    let _ = metatt_kind;
    Ok(DmrgResult {
        task,
        epochs,
        best_at_final_rank: best_at_final,
        final_rank,
        executables_compiled: backend.cached_executables() - compiled_before,
    })
}

/// Bind the train + eval steps for one rank of the ladder (the executable
/// hot-swap unit).
fn bind_pair<'a>(
    backend: &'a dyn Backend,
    frozen: &std::sync::Arc<std::collections::HashMap<String, crate::tensor::Tensor>>,
    model: ModelPreset,
    kind: AdapterKind,
    rank: usize,
    batch: usize,
) -> Result<(Box<dyn Step + 'a>, Box<dyn Step + 'a>)> {
    let tr = backend.bind(&make_spec(StepKind::Train, model, kind, rank, batch), frozen)?;
    let ev = backend.bind(&make_spec(StepKind::Eval, model, kind, rank, batch), frozen)?;
    Ok((tr, ev))
}

/// Zero-pad every interior bond of the chain up to `rank` so the exported
/// shapes match the uniform-rank artifact layout. Padding with zeros is
/// exact: the represented tensor is unchanged.
fn pad_chain_to_rank(tt: &mut MetaTt, rank: usize) {
    use crate::tensor::Tensor;
    let d = tt.chain.order();
    let mut cores: Vec<Tensor> = tt.chain.cores().to_vec();
    for k in 0..d {
        let c = &cores[k];
        let (rl, n, rr) = (c.shape()[0], c.shape()[1], c.shape()[2]);
        let want_rl = if k == 0 { 1 } else { rank };
        let want_rr = if k == d - 1 { 1 } else { rank };
        if rl == want_rl && rr == want_rr {
            continue;
        }
        let mut p = Tensor::zeros(&[want_rl, n, want_rr]);
        for a in 0..rl {
            for j in 0..n {
                for b in 0..rr {
                    p.set3(a, j, b, c.at3(a, j, b));
                }
            }
        }
        cores[k] = p;
    }
    tt.chain = crate::tt::TtChain::new(cores);
}

/// Fixed-rank AdamW baseline at rank `r` (the paper's comparison curves).
pub fn run_fixed_rank_baseline(
    backend: &dyn Backend,
    model: ModelPreset,
    kind: AdapterKind,
    task: TaskId,
    rank: usize,
    cfg: &DmrgConfig,
    checkpoint: Option<&Path>,
) -> Result<Vec<DmrgEpochLog>> {
    let mut fixed = cfg.clone();
    fixed.start_rank = rank;
    fixed.schedule = RankSchedule { steps: vec![(usize::MAX - 1, rank)] };
    let res = run_dmrg(backend, model, kind, task, &fixed, checkpoint)?;
    Ok(res.epochs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapters::ModelDims;
    use crate::tt::MetaTtKind;

    #[test]
    fn pad_chain_preserves_tensor_and_reaches_rank() {
        let dims = ModelDims {
            hidden: 16,
            layers: 3,
            heads: 4,
            matrices: 2,
            tasks: 1,
            vocab: 512,
            ffn: 64,
            max_seq: 32,
        };
        let spec = AdapterSpec::new(AdapterKind::MetaTt(MetaTtKind::FourD), 6, 1.0, dims);
        let mut rng = Pcg64::new(3);
        let init = crate::tt::InitStrategy::from_code("no-no-no-no").unwrap();
        let mut tt = spec.build_metatt_with(&mut rng, Some(&init));
        let before = tt.delta_w(1, 0, 0);
        dmrg_sweep(&mut tt.chain, &|_| 3);
        pad_chain_to_rank(&mut tt, 5);
        assert!(tt.chain.ranks().iter().all(|&r| r == 5));
        let after = tt.delta_w(1, 0, 0);
        // rank-3 truncation loses something, but padding must not change it
        let sweep_err = crate::tensor::rel_err(&after, &before);
        assert!(sweep_err < 1.0, "pad broke the tensor: {sweep_err}");
        // padding exactness: re-sweep at 5 and compare to itself padded
        let mut tt2 = tt.clone();
        pad_chain_to_rank(&mut tt2, 5);
        assert_eq!(tt2.delta_w(1, 0, 0), after);
    }
}
