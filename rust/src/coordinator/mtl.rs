//! Multi-task joint training (paper §3.2, Table 2, Appendix B).
//!
//! Joint training minimizes the composite loss Σ_k L_k by interleaving
//! fixed-shape batches from every task within each epoch (each batch
//! carries its task id, which selects both the frozen head and — for
//! MetaTT-(4+1)D — the task core G3[t]). Datasets are downsampled to the
//! paper's caps (≤5000 train / ≤500 eval per task); per-epoch evaluation
//! reports each task's metric and their mean, and the per-core
//! normalized-gradient probes `‖∇G‖_F/√|G|` of Appendix B are recorded for
//! the Figure 4/5 heatmaps.

use crate::adapters::AdapterSpec;
use crate::config::{ModelPreset, TrainConfig};
use crate::coordinator::trainer::{eval_metric, flatten_all, unflatten_all};
use crate::data::{downsample, Batcher, Dataset, TaskId};
use crate::optim::{clip_global_norm, AdamW, LrSchedule};
use crate::runtime::{assemble_frozen, ArtifactSpec, Backend, Step, StepKind};
use crate::util::rng::Pcg64;
use anyhow::{Context, Result};
use std::path::Path;

/// Per-epoch MTL record.
#[derive(Clone, Debug)]
pub struct MtlEpochLog {
    pub epoch: usize,
    pub train_loss: f64,
    /// Metric per task, in task order.
    pub metrics: Vec<f64>,
    pub mean_metric: f64,
    /// Normalized gradient `‖∇G‖_F/√|G|` per trainable array (Appendix B),
    /// averaged over the epoch's steps.
    pub grad_norms: Vec<f64>,
}

/// Result of one MTL run.
#[derive(Clone, Debug)]
pub struct MtlResult {
    pub tasks: Vec<TaskId>,
    pub adapter: String,
    pub param_count: usize,
    /// Names of the trainable arrays (for the Fig 4/5 heatmap axes).
    pub param_names: Vec<String>,
    pub epochs: Vec<MtlEpochLog>,
    /// Best mean-across-tasks metric over epochs (the paper's Table-2 rule).
    pub best_mean: f64,
    /// Per-task metric at the best-mean epoch.
    pub best_per_task: Vec<f64>,
    /// Final trained adapter tensors (export layout) — what `metatt mtl
    /// --save-adapter` checkpoints for the serving engine.
    pub params: Vec<crate::tensor::Tensor>,
}

/// Joint training configuration on top of [`TrainConfig`].
#[derive(Clone, Debug)]
pub struct MtlConfig {
    pub train: TrainConfig,
    pub alpha: f32,
    /// Paper's caps: ≤5000 train / ≤500 eval per task.
    pub per_task_cap: usize,
    pub eval_cap: usize,
}

impl Default for MtlConfig {
    fn default() -> MtlConfig {
        MtlConfig {
            train: TrainConfig { grad_clip: 3.0, ..Default::default() },
            alpha: 2.0, // Appendix B setting
            per_task_cap: 5_000,
            eval_cap: 500,
        }
    }
}

/// Run joint multi-task training of `spec` over `tasks`.
pub fn run_mtl(
    backend: &dyn Backend,
    model: ModelPreset,
    spec: &AdapterSpec,
    tasks: &[TaskId],
    cfg: &MtlConfig,
    checkpoint: Option<&Path>,
) -> Result<MtlResult> {
    assert!(!tasks.is_empty());
    let dims = model.dims(tasks.len());
    // All MTL tasks share one 2-class artifact (CoLA/MRPC/RTE/QNLI analogues
    // are all binary — mirrors the paper's task selection).
    for t in tasks {
        let info = t.info();
        anyhow::ensure!(
            !info.regression && info.num_classes == 2,
            "MTL supports binary tasks (paper §3.2); got {}",
            t.name()
        );
    }
    let train_spec = ArtifactSpec {
        step: StepKind::Train,
        model: model.name().to_string(),
        adapter: spec.kind.name(),
        rank: spec.rank,
        classes: 2,
        tasks: tasks.len(),
        batch: cfg.train.batch_size,
        seq: dims.max_seq,
    };
    let mut eval_spec = train_spec.clone();
    eval_spec.step = StepKind::Eval;
    let entry = backend.entry(&train_spec)?;
    let frozen = std::sync::Arc::new(assemble_frozen(&entry, checkpoint, model)?);
    let train_runner = backend.bind(&train_spec, &frozen)?;
    let eval_runner = backend.bind(&eval_spec, &frozen)?;

    // Data: generate + downsample per the paper's protocol. Generation is
    // per-task-seeded (independent), so it fans out across the backend's
    // worker budget; downsampling shares one RNG stream and stays serial
    // in task order so the draw sequence never depends on the thread count.
    let mut data_rng = Pcg64::with_stream(cfg.train.seed, 0xd011 + tasks.len() as u64);
    let generated: Vec<Dataset> = crate::util::threadpool::par_map(tasks, backend.threads(), |t| {
        let info = t.info();
        t.generate_at(
            info.train_size.min(cfg.per_task_cap * 2),
            info.eval_size,
            cfg.train.seed,
            dims.max_seq,
            dims.vocab,
        )
    });
    // (Peak memory holds all T pre-downsample sets at once — the price of
    // parallel generation; each is freed as its downsample completes.)
    let datasets: Vec<Dataset> = generated
        .into_iter()
        .map(|full| downsample(&full, cfg.per_task_cap, cfg.eval_cap, &mut data_rng))
        .collect();

    let mut rng = Pcg64::with_stream(cfg.train.seed, 0x3417);
    let mut params = spec.init_params_with(&mut rng, None);
    let param_names: Vec<String> =
        spec.param_specs().iter().map(|p| p.name.clone()).collect();
    let batcher = Batcher::new(cfg.train.batch_size);
    let steps_per_epoch: usize = datasets
        .iter()
        .map(|d| d.train.len().div_ceil(cfg.train.batch_size))
        .sum();
    let total = steps_per_epoch * cfg.train.epochs;
    let sched = LrSchedule::new(cfg.train.lr, total, cfg.train.warmup_ratio);
    let mut flat = flatten_all(&params);
    let mut opt = AdamW::new(flat.len(), cfg.train.weight_decay);

    let mut epochs: Vec<MtlEpochLog> = Vec::new();
    let mut step = 0usize;
    for epoch in 0..cfg.train.epochs {
        // Interleave: all tasks' batches, shuffled together.
        let mut tagged: Vec<(usize, crate::data::Batch)> = Vec::new();
        for (ti, ds) in datasets.iter().enumerate() {
            for b in batcher.epoch(ds, &mut rng) {
                tagged.push((ti, b));
            }
        }
        rng.shuffle(&mut tagged);
        let mut loss_sum = 0.0;
        let mut grad_sums = vec![0.0f64; params.len()];
        for (ti, batch) in &tagged {
            let (loss, grads) =
                train_runner.run_train(&params, batch, *ti as i32, cfg.alpha)?;
            // Appendix-B probe: ‖∇G‖_F/√|G| per core, before clipping.
            for (gi, g) in grads.iter().enumerate() {
                let nnz = g.nnz().max(1);
                grad_sums[gi] += (g.fro_norm() as f64) / (nnz as f64).sqrt();
            }
            let mut gflat = flatten_all(&grads);
            if cfg.train.grad_clip > 0.0 {
                clip_global_norm(&mut gflat, cfg.train.grad_clip);
            }
            opt.step(&mut flat, &gflat, sched.lr_at(step));
            unflatten_all(&mut params, &flat);
            // Return the consumed grad buffers to the backend's arena.
            train_runner.recycle(grads);
            loss_sum += loss as f64;
            step += 1;
        }
        // Per-task eval.
        let mut metrics = Vec::with_capacity(tasks.len());
        for (ti, ds) in datasets.iter().enumerate() {
            let m = eval_metric(
                eval_runner.as_ref(),
                &params,
                ds,
                &batcher,
                ti as i32,
                cfg.alpha,
                tasks[ti].info().metric,
            )?;
            metrics.push(m);
        }
        let mean = metrics.iter().sum::<f64>() / metrics.len() as f64;
        epochs.push(MtlEpochLog {
            epoch,
            train_loss: loss_sum / tagged.len().max(1) as f64,
            mean_metric: mean,
            metrics,
            grad_norms: grad_sums
                .iter()
                .map(|s| s / tagged.len().max(1) as f64)
                .collect(),
        });
    }
    let best = epochs
        .iter()
        .max_by(|a, b| a.mean_metric.partial_cmp(&b.mean_metric).unwrap())
        .context("no epochs")?;
    Ok(MtlResult {
        tasks: tasks.to_vec(),
        adapter: spec.kind.name(),
        param_count: spec.param_count(),
        param_names,
        best_mean: best.mean_metric,
        best_per_task: best.metrics.clone(),
        epochs,
        params,
    })
}
