//! Hand-rolled infrastructure substrates (the offline registry ships only
//! the `xla` closure): RNG, JSON, TOML, and a thread pool.

pub mod fault;
pub mod json;
pub mod rng;
pub mod threadpool;
pub mod toml;
