//! Deterministic fault injection for the serving stack (PR 8).
//!
//! A [`FaultPlan`] is a parsed, seeded schedule of faults that the serving
//! path consults at named injection points. The spec grammar (comma
//! separated, also read from the `METATT_FAULTS` env var by the CLI):
//!
//! ```text
//! worker_panic@tick=17     panic inside the worker's batch execution on
//!                          the 17th serve tick (global 1-based counter
//!                          across all workers of the engine)
//! net_drop@frame=3         drop the TCP connection that delivers the 3rd
//!                          request frame (global across connections),
//!                          before the request is admitted
//! slow_tick=5ms@p=0.01     sleep 5ms before a tick with probability 0.01
//!                          (seeded rng — deterministic draw sequence)
//! torn_write@save=2        tear the 2nd checkpoint save: only a prefix of
//!                          the temp file lands and the atomic rename
//!                          never happens
//! seed=42                  seed for the probabilistic faults
//! ```
//!
//! Every hook takes one relaxed atomic load and returns when the plan is
//! empty, so an unfaulted engine pays nothing on the hot path — in
//! particular the zero-allocation warmed serving tick is untouched (the
//! hooks never allocate). Each plan owns its own counters and rng: tests
//! running in parallel inside one process do not interfere, which is why
//! the plan is threaded explicitly (`EngineConfig::faults`,
//! `save_with_meta_faults`) instead of living in a process-wide global.

use crate::util::rng::Pcg64;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// A seeded, thread-safe schedule of injected faults. See the module docs
/// for the spec grammar. `FaultPlan::empty()` (the default) disarms every
/// hook.
#[derive(Debug)]
pub struct FaultPlan {
    /// The original spec string (for display / bench records).
    spec: String,
    /// 1-based serve-tick ordinals that panic (`worker_panic@tick=N`).
    panic_ticks: Vec<u64>,
    /// 1-based request-frame ordinals that drop the connection
    /// (`net_drop@frame=N`).
    drop_frames: Vec<u64>,
    /// 1-based checkpoint-save ordinals that tear (`torn_write@save=N`).
    torn_saves: Vec<u64>,
    /// `slow_tick=DURms@p=P`: sleep `DUR` before a tick with probability
    /// `P`.
    slow: Option<(Duration, f64)>,
    ticks: AtomicU64,
    frames: AtomicU64,
    saves: AtomicU64,
    rng: Mutex<Pcg64>,
    armed: bool,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan::empty()
    }
}

impl FaultPlan {
    /// A disarmed plan: every hook is a near-free early return.
    pub fn empty() -> FaultPlan {
        FaultPlan::parse("").expect("empty spec always parses")
    }

    /// Parse a fault spec (see module docs). An empty or whitespace-only
    /// spec yields a disarmed plan.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut panic_ticks = Vec::new();
        let mut drop_frames = Vec::new();
        let mut torn_saves = Vec::new();
        let mut slow = None;
        let mut seed = 0u64;
        for raw in spec.split(',') {
            let item = raw.trim();
            if item.is_empty() {
                continue;
            }
            if let Some(rest) = item.strip_prefix("worker_panic@tick=") {
                panic_ticks.push(parse_ordinal(item, rest)?);
            } else if let Some(rest) = item.strip_prefix("net_drop@frame=") {
                drop_frames.push(parse_ordinal(item, rest)?);
            } else if let Some(rest) = item.strip_prefix("torn_write@save=") {
                torn_saves.push(parse_ordinal(item, rest)?);
            } else if let Some(rest) = item.strip_prefix("slow_tick=") {
                let (dur_s, p_s) = rest
                    .split_once("@p=")
                    .ok_or_else(|| format!("`{item}`: expected slow_tick=<N>ms@p=<P>"))?;
                let ms = dur_s
                    .strip_suffix("ms")
                    .ok_or_else(|| format!("`{item}`: duration needs an `ms` suffix"))?;
                let ms: u64 = ms
                    .parse()
                    .map_err(|_| format!("`{item}`: bad millisecond count `{ms}`"))?;
                let p: f64 = p_s
                    .parse()
                    .map_err(|_| format!("`{item}`: bad probability `{p_s}`"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(format!("`{item}`: probability must be in [0, 1]"));
                }
                if slow.is_some() {
                    return Err(format!("`{item}`: slow_tick given twice"));
                }
                slow = Some((Duration::from_millis(ms), p));
            } else if let Some(rest) = item.strip_prefix("seed=") {
                seed = rest
                    .parse()
                    .map_err(|_| format!("`{item}`: bad seed `{rest}`"))?;
            } else {
                return Err(format!(
                    "unknown fault `{item}` (expected worker_panic@tick=N, \
                     net_drop@frame=N, torn_write@save=N, slow_tick=<N>ms@p=<P>, \
                     or seed=N)"
                ));
            }
        }
        let armed = !panic_ticks.is_empty()
            || !drop_frames.is_empty()
            || !torn_saves.is_empty()
            || slow.is_some();
        Ok(FaultPlan {
            spec: spec.trim().to_string(),
            panic_ticks,
            drop_frames,
            torn_saves,
            slow,
            ticks: AtomicU64::new(0),
            frames: AtomicU64::new(0),
            saves: AtomicU64::new(0),
            rng: Mutex::new(Pcg64::with_stream(seed, 0xfa17)),
            armed,
        })
    }

    /// Parse the `METATT_FAULTS` env var (absent/empty → disarmed plan).
    pub fn from_env() -> Result<FaultPlan, String> {
        match std::env::var("METATT_FAULTS") {
            Ok(spec) => FaultPlan::parse(&spec),
            Err(_) => Ok(FaultPlan::empty()),
        }
    }

    /// True if any fault is scheduled.
    pub fn is_armed(&self) -> bool {
        self.armed
    }

    /// The original spec string.
    pub fn spec(&self) -> &str {
        &self.spec
    }

    /// Worker-side hook, called inside the batch execution guard right
    /// before the forward. May sleep (`slow_tick`) and may panic
    /// (`worker_panic`) — the engine's supervision contains the panic.
    #[inline]
    pub fn on_serve_tick(&self) {
        if !self.armed {
            return;
        }
        self.serve_tick_armed();
    }

    #[cold]
    fn serve_tick_armed(&self) {
        let tick = self.ticks.fetch_add(1, Ordering::Relaxed) + 1;
        if let Some((dur, p)) = self.slow {
            let fire = self.rng.lock().unwrap().bernoulli(p);
            if fire {
                std::thread::sleep(dur);
            }
        }
        if self.panic_ticks.contains(&tick) {
            panic!("injected fault: worker_panic at serve tick {tick}");
        }
    }

    /// Network hook, called once per fully-read request frame *before*
    /// admission. Returns true when the server should drop the connection
    /// (abandoning the frame — the client must retry on a new connection).
    #[inline]
    pub fn on_net_frame(&self) -> bool {
        if !self.armed {
            return false;
        }
        let frame = self.frames.fetch_add(1, Ordering::Relaxed) + 1;
        self.drop_frames.contains(&frame)
    }

    /// Checkpoint hook, called once per `save`. Returns true when this
    /// save should be torn (partial temp file, no rename).
    #[inline]
    pub fn on_save(&self) -> bool {
        if !self.armed {
            return false;
        }
        let save = self.saves.fetch_add(1, Ordering::Relaxed) + 1;
        self.torn_saves.contains(&save)
    }
}

fn parse_ordinal(item: &str, rest: &str) -> Result<u64, String> {
    let n: u64 = rest
        .parse()
        .map_err(|_| format!("`{item}`: bad ordinal `{rest}`"))?;
    if n == 0 {
        return Err(format!("`{item}`: ordinals are 1-based"));
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_whitespace_specs_are_disarmed() {
        for spec in ["", "  ", " , ,"] {
            let plan = FaultPlan::parse(spec).unwrap();
            assert!(!plan.is_armed());
            assert!(!plan.on_net_frame());
            assert!(!plan.on_save());
            plan.on_serve_tick(); // must be a no-op, not a panic
        }
    }

    #[test]
    fn full_grammar_parses() {
        let plan = FaultPlan::parse(
            "worker_panic@tick=17, net_drop@frame=3,slow_tick=5ms@p=0.01,\
             torn_write@save=2,seed=9",
        )
        .unwrap();
        assert!(plan.is_armed());
        assert_eq!(plan.panic_ticks, vec![17]);
        assert_eq!(plan.drop_frames, vec![3]);
        assert_eq!(plan.torn_saves, vec![2]);
        let (dur, p) = plan.slow.unwrap();
        assert_eq!(dur, Duration::from_millis(5));
        assert!((p - 0.01).abs() < 1e-12);
    }

    #[test]
    fn bad_specs_are_rejected_with_the_offending_item() {
        for (spec, needle) in [
            ("worker_panic@tick=zero", "bad ordinal"),
            ("worker_panic@tick=0", "1-based"),
            ("net_drop@frame=", "bad ordinal"),
            ("slow_tick=5@p=0.1", "ms` suffix"),
            ("slow_tick=5ms@p=1.5", "probability"),
            ("slow_tick=5ms", "expected slow_tick"),
            ("slow_tick=1ms@p=0.1,slow_tick=2ms@p=0.2", "twice"),
            ("seed=abc", "bad seed"),
            ("explode@now=1", "unknown fault"),
        ] {
            let err = FaultPlan::parse(spec).unwrap_err();
            assert!(err.contains(needle), "{spec}: {err}");
        }
    }

    #[test]
    fn counters_fire_exactly_at_their_ordinal() {
        let plan = FaultPlan::parse("net_drop@frame=3,torn_write@save=1").unwrap();
        assert!(!plan.on_net_frame()); // frame 1
        assert!(!plan.on_net_frame()); // frame 2
        assert!(plan.on_net_frame()); // frame 3 — fires
        assert!(!plan.on_net_frame()); // frame 4
        assert!(plan.on_save()); // save 1 — fires
        assert!(!plan.on_save()); // save 2
    }

    #[test]
    fn worker_panic_fires_on_the_scheduled_tick_only() {
        let plan = FaultPlan::parse("worker_panic@tick=2").unwrap();
        plan.on_serve_tick(); // tick 1: fine
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            plan.on_serve_tick() // tick 2: panics
        }));
        assert!(err.is_err(), "tick 2 must panic");
        plan.on_serve_tick(); // tick 3: fine again
    }

    #[test]
    fn slow_tick_draws_are_seed_deterministic() {
        // Two plans with the same seed consume identical bernoulli
        // sequences; a different seed diverges. Probed via the rng
        // directly so the test never sleeps.
        let a = FaultPlan::parse("slow_tick=1ms@p=0.5,seed=7").unwrap();
        let b = FaultPlan::parse("slow_tick=1ms@p=0.5,seed=7").unwrap();
        let c = FaultPlan::parse("slow_tick=1ms@p=0.5,seed=8").unwrap();
        let draw = |p: &FaultPlan| -> Vec<bool> {
            let mut rng = p.rng.lock().unwrap();
            (0..64).map(|_| rng.bernoulli(0.5)).collect()
        };
        assert_eq!(draw(&a), draw(&b));
        assert_ne!(draw(&a), draw(&c));
    }
}
