//! Deterministic fault injection for the serving stack (PR 8).
//!
//! A [`FaultPlan`] is a parsed, seeded schedule of faults that the serving
//! path consults at named injection points. The spec grammar (comma
//! separated, also read from the `METATT_FAULTS` env var by the CLI):
//!
//! ```text
//! worker_panic@tick=17     panic inside the worker's batch execution on
//!                          the 17th serve tick (global 1-based counter
//!                          across all workers of the engine)
//! net_drop@frame=3         drop the TCP connection that delivers the 3rd
//!                          request frame (global across connections),
//!                          before the request is admitted
//! slow_tick=5ms@p=0.01     sleep 5ms before a tick with probability 0.01
//!                          (seeded rng — deterministic draw sequence)
//! torn_write@save=2        tear the 2nd checkpoint save: only a prefix of
//!                          the temp file lands and the atomic rename
//!                          never happens
//! shard_down@tick=4        kill the shard probed by the router's 4th
//!                          supervision tick (global 1-based counter; the
//!                          supervisor probes live shards round-robin each
//!                          heartbeat, so a tick maps deterministically to
//!                          one shard): its queue is drained + failed over
//! shard_wedge=40ms@p=0.05  each supervision probe wedges its shard for
//!                          40ms with probability 0.05 (seeded rng) — the
//!                          shard reports Degraded and is routed around
//!                          until the wedge passes
//! seed=42                  seed for the probabilistic faults
//! ```
//!
//! Every hook takes one relaxed atomic load and returns when the plan is
//! empty, so an unfaulted engine pays nothing on the hot path — in
//! particular the zero-allocation warmed serving tick is untouched (the
//! hooks never allocate). Each plan owns its own counters and rng: tests
//! running in parallel inside one process do not interfere, which is why
//! the plan is threaded explicitly (`EngineConfig::faults`,
//! `save_with_meta_faults`) instead of living in a process-wide global.

use crate::util::rng::Pcg64;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// A seeded, thread-safe schedule of injected faults. See the module docs
/// for the spec grammar. `FaultPlan::empty()` (the default) disarms every
/// hook.
#[derive(Debug)]
pub struct FaultPlan {
    /// The original spec string (for display / bench records).
    spec: String,
    /// 1-based serve-tick ordinals that panic (`worker_panic@tick=N`).
    panic_ticks: Vec<u64>,
    /// 1-based request-frame ordinals that drop the connection
    /// (`net_drop@frame=N`).
    drop_frames: Vec<u64>,
    /// 1-based checkpoint-save ordinals that tear (`torn_write@save=N`).
    torn_saves: Vec<u64>,
    /// `slow_tick=DURms@p=P`: sleep `DUR` before a tick with probability
    /// `P`.
    slow: Option<(Duration, f64)>,
    /// 1-based shard supervision-tick ordinals that kill the probed shard
    /// (`shard_down@tick=N`).
    down_ticks: Vec<u64>,
    /// `shard_wedge=DURms@p=P`: each supervision probe wedges its shard
    /// for `DUR` with probability `P`.
    wedge: Option<(Duration, f64)>,
    ticks: AtomicU64,
    frames: AtomicU64,
    saves: AtomicU64,
    shard_ticks: AtomicU64,
    rng: Mutex<Pcg64>,
    armed: bool,
}

/// What one shard supervision probe injected (see
/// [`FaultPlan::on_shard_tick`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardFault {
    /// Nothing scheduled for this probe.
    None,
    /// Kill the probed shard: the router marks it Down and fails its
    /// queued work over to a surviving replica.
    Down,
    /// Wedge the probed shard for the given duration: the router reports
    /// it Degraded and routes around it until the wedge passes.
    Wedge(Duration),
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan::empty()
    }
}

impl FaultPlan {
    /// A disarmed plan: every hook is a near-free early return.
    pub fn empty() -> FaultPlan {
        FaultPlan::parse("").expect("empty spec always parses")
    }

    /// Parse a fault spec (see module docs). An empty or whitespace-only
    /// spec yields a disarmed plan.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut panic_ticks = Vec::new();
        let mut drop_frames = Vec::new();
        let mut torn_saves = Vec::new();
        let mut down_ticks = Vec::new();
        let mut slow = None;
        let mut wedge = None;
        let mut seed = 0u64;
        for raw in spec.split(',') {
            let item = raw.trim();
            if item.is_empty() {
                continue;
            }
            if let Some(rest) = item.strip_prefix("worker_panic@tick=") {
                panic_ticks.push(parse_ordinal(item, rest)?);
            } else if let Some(rest) = item.strip_prefix("net_drop@frame=") {
                drop_frames.push(parse_ordinal(item, rest)?);
            } else if let Some(rest) = item.strip_prefix("torn_write@save=") {
                torn_saves.push(parse_ordinal(item, rest)?);
            } else if let Some(rest) = item.strip_prefix("shard_down@tick=") {
                down_ticks.push(parse_ordinal(item, rest)?);
            } else if let Some(rest) = item.strip_prefix("slow_tick=") {
                if slow.is_some() {
                    return Err(format!("`{item}`: slow_tick given twice"));
                }
                slow = Some(parse_dur_prob(item, rest)?);
            } else if let Some(rest) = item.strip_prefix("shard_wedge=") {
                if wedge.is_some() {
                    return Err(format!("`{item}`: shard_wedge given twice"));
                }
                wedge = Some(parse_dur_prob(item, rest)?);
            } else if let Some(rest) = item.strip_prefix("seed=") {
                seed = rest
                    .parse()
                    .map_err(|_| format!("`{item}`: bad seed `{rest}`"))?;
            } else {
                return Err(format!(
                    "unknown fault `{item}` (expected worker_panic@tick=N, \
                     net_drop@frame=N, torn_write@save=N, shard_down@tick=N, \
                     slow_tick=<N>ms@p=<P>, shard_wedge=<N>ms@p=<P>, or seed=N)"
                ));
            }
        }
        let armed = !panic_ticks.is_empty()
            || !drop_frames.is_empty()
            || !torn_saves.is_empty()
            || !down_ticks.is_empty()
            || slow.is_some()
            || wedge.is_some();
        Ok(FaultPlan {
            spec: spec.trim().to_string(),
            panic_ticks,
            drop_frames,
            torn_saves,
            slow,
            down_ticks,
            wedge,
            ticks: AtomicU64::new(0),
            frames: AtomicU64::new(0),
            saves: AtomicU64::new(0),
            shard_ticks: AtomicU64::new(0),
            rng: Mutex::new(Pcg64::with_stream(seed, 0xfa17)),
            armed,
        })
    }

    /// Parse the `METATT_FAULTS` env var (absent/empty → disarmed plan).
    pub fn from_env() -> Result<FaultPlan, String> {
        match std::env::var("METATT_FAULTS") {
            Ok(spec) => FaultPlan::parse(&spec),
            Err(_) => Ok(FaultPlan::empty()),
        }
    }

    /// True if any fault is scheduled.
    pub fn is_armed(&self) -> bool {
        self.armed
    }

    /// The original spec string.
    pub fn spec(&self) -> &str {
        &self.spec
    }

    /// Worker-side hook, called inside the batch execution guard right
    /// before the forward. May sleep (`slow_tick`) and may panic
    /// (`worker_panic`) — the engine's supervision contains the panic.
    /// Returns the injected sleep in µs (0 when nothing fired) so the
    /// tracer can stamp a `slow_tick` span (PR 10); the sleep happens
    /// before any scheduled panic, so a slow tick is on the clock even
    /// when the same tick also panics.
    #[inline]
    pub fn on_serve_tick(&self) -> u64 {
        if !self.armed {
            return 0;
        }
        self.serve_tick_armed()
    }

    #[cold]
    fn serve_tick_armed(&self) -> u64 {
        let tick = self.ticks.fetch_add(1, Ordering::Relaxed) + 1;
        let mut slept_us = 0;
        if let Some((dur, p)) = self.slow {
            let fire = self.rng.lock().unwrap().bernoulli(p);
            if fire {
                std::thread::sleep(dur);
                slept_us = dur.as_micros() as u64;
            }
        }
        if self.panic_ticks.contains(&tick) {
            panic!("injected fault: worker_panic at serve tick {tick}");
        }
        slept_us
    }

    /// Network hook, called once per fully-read request frame *before*
    /// admission. Returns true when the server should drop the connection
    /// (abandoning the frame — the client must retry on a new connection).
    #[inline]
    pub fn on_net_frame(&self) -> bool {
        if !self.armed {
            return false;
        }
        let frame = self.frames.fetch_add(1, Ordering::Relaxed) + 1;
        self.drop_frames.contains(&frame)
    }

    /// Checkpoint hook, called once per `save`. Returns true when this
    /// save should be torn (partial temp file, no rename).
    #[inline]
    pub fn on_save(&self) -> bool {
        if !self.armed {
            return false;
        }
        let save = self.saves.fetch_add(1, Ordering::Relaxed) + 1;
        self.torn_saves.contains(&save)
    }

    /// Router hook, called once per shard supervision probe. `shard` is the
    /// probed shard's index (attribution only — the schedule is keyed by
    /// the global probe ordinal, which maps deterministically to a shard
    /// because the supervisor probes live shards round-robin each
    /// heartbeat). Returns what the probe injected; the router acts on it.
    #[inline]
    pub fn on_shard_tick(&self, shard: usize) -> ShardFault {
        if !self.armed {
            return ShardFault::None;
        }
        self.shard_tick_armed(shard)
    }

    #[cold]
    fn shard_tick_armed(&self, _shard: usize) -> ShardFault {
        let tick = self.shard_ticks.fetch_add(1, Ordering::Relaxed) + 1;
        if self.down_ticks.contains(&tick) {
            return ShardFault::Down;
        }
        if let Some((dur, p)) = self.wedge {
            let fire = self.rng.lock().unwrap().bernoulli(p);
            if fire {
                return ShardFault::Wedge(dur);
            }
        }
        ShardFault::None
    }
}

/// Parse the shared `<N>ms@p=<P>` payload of `slow_tick=` / `shard_wedge=`.
fn parse_dur_prob(item: &str, rest: &str) -> Result<(Duration, f64), String> {
    let name = item.split('=').next().unwrap_or(item);
    let (dur_s, p_s) = rest
        .split_once("@p=")
        .ok_or_else(|| format!("`{item}`: expected {name}=<N>ms@p=<P>"))?;
    let ms = dur_s
        .strip_suffix("ms")
        .ok_or_else(|| format!("`{item}`: duration needs an `ms` suffix"))?;
    let ms: u64 = ms
        .parse()
        .map_err(|_| format!("`{item}`: bad millisecond count `{ms}`"))?;
    let p: f64 = p_s
        .parse()
        .map_err(|_| format!("`{item}`: bad probability `{p_s}`"))?;
    if !(0.0..=1.0).contains(&p) {
        return Err(format!("`{item}`: probability must be in [0, 1]"));
    }
    Ok((Duration::from_millis(ms), p))
}

fn parse_ordinal(item: &str, rest: &str) -> Result<u64, String> {
    let n: u64 = rest
        .parse()
        .map_err(|_| format!("`{item}`: bad ordinal `{rest}`"))?;
    if n == 0 {
        return Err(format!("`{item}`: ordinals are 1-based"));
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_whitespace_specs_are_disarmed() {
        for spec in ["", "  ", " , ,"] {
            let plan = FaultPlan::parse(spec).unwrap();
            assert!(!plan.is_armed());
            assert!(!plan.on_net_frame());
            assert!(!plan.on_save());
            assert_eq!(plan.on_shard_tick(0), ShardFault::None);
            assert_eq!(plan.on_serve_tick(), 0); // must be a no-op, not a panic
        }
    }

    #[test]
    fn full_grammar_parses() {
        let plan = FaultPlan::parse(
            "worker_panic@tick=17, net_drop@frame=3,slow_tick=5ms@p=0.01,\
             torn_write@save=2,shard_down@tick=4,shard_wedge=40ms@p=0.25,seed=9",
        )
        .unwrap();
        assert!(plan.is_armed());
        assert_eq!(plan.panic_ticks, vec![17]);
        assert_eq!(plan.drop_frames, vec![3]);
        assert_eq!(plan.torn_saves, vec![2]);
        assert_eq!(plan.down_ticks, vec![4]);
        let (dur, p) = plan.slow.unwrap();
        assert_eq!(dur, Duration::from_millis(5));
        assert!((p - 0.01).abs() < 1e-12);
        let (dur, p) = plan.wedge.unwrap();
        assert_eq!(dur, Duration::from_millis(40));
        assert!((p - 0.25).abs() < 1e-12);
    }

    #[test]
    fn bad_specs_are_rejected_with_the_offending_item() {
        for (spec, needle) in [
            ("worker_panic@tick=zero", "bad ordinal"),
            ("worker_panic@tick=0", "1-based"),
            ("net_drop@frame=", "bad ordinal"),
            ("slow_tick=5@p=0.1", "ms` suffix"),
            ("slow_tick=5ms@p=1.5", "probability"),
            ("slow_tick=5ms", "expected slow_tick"),
            ("slow_tick=1ms@p=0.1,slow_tick=2ms@p=0.2", "twice"),
            ("shard_down@tick=0", "1-based"),
            ("shard_wedge=5@p=0.1", "ms` suffix"),
            ("shard_wedge=5ms", "expected shard_wedge"),
            ("shard_wedge=1ms@p=0.1,shard_wedge=2ms@p=0.2", "twice"),
            ("seed=abc", "bad seed"),
            ("explode@now=1", "unknown fault"),
        ] {
            let err = FaultPlan::parse(spec).unwrap_err();
            assert!(err.contains(needle), "{spec}: {err}");
        }
    }

    #[test]
    fn counters_fire_exactly_at_their_ordinal() {
        let plan = FaultPlan::parse("net_drop@frame=3,torn_write@save=1").unwrap();
        assert!(!plan.on_net_frame()); // frame 1
        assert!(!plan.on_net_frame()); // frame 2
        assert!(plan.on_net_frame()); // frame 3 — fires
        assert!(!plan.on_net_frame()); // frame 4
        assert!(plan.on_save()); // save 1 — fires
        assert!(!plan.on_save()); // save 2
    }

    #[test]
    fn slow_tick_at_p1_reports_its_sleep() {
        let plan = FaultPlan::parse("slow_tick=5ms@p=1.0").unwrap();
        let slept = plan.on_serve_tick();
        assert_eq!(slept, 5_000, "p=1.0 slow tick must report the injected sleep in µs");
    }

    #[test]
    fn worker_panic_fires_on_the_scheduled_tick_only() {
        let plan = FaultPlan::parse("worker_panic@tick=2").unwrap();
        plan.on_serve_tick(); // tick 1: fine
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            plan.on_serve_tick() // tick 2: panics
        }));
        assert!(err.is_err(), "tick 2 must panic");
        plan.on_serve_tick(); // tick 3: fine again
    }

    #[test]
    fn shard_down_fires_exactly_at_its_probe_ordinal() {
        // Two shards probed round-robin: ordinal 3 is shard 0's 2nd probe.
        let plan = FaultPlan::parse("shard_down@tick=3").unwrap();
        assert_eq!(plan.on_shard_tick(0), ShardFault::None); // tick 1
        assert_eq!(plan.on_shard_tick(1), ShardFault::None); // tick 2
        assert_eq!(plan.on_shard_tick(0), ShardFault::Down); // tick 3 — fires
        assert_eq!(plan.on_shard_tick(1), ShardFault::None); // tick 4
    }

    #[test]
    fn shard_wedge_draws_are_seed_deterministic() {
        let probe = |seed: u64| -> Vec<ShardFault> {
            let plan =
                FaultPlan::parse(&format!("shard_wedge=7ms@p=0.5,seed={seed}")).unwrap();
            (0..64).map(|i| plan.on_shard_tick(i % 2)).collect()
        };
        assert_eq!(probe(7), probe(7), "same seed, same wedge schedule");
        assert_ne!(probe(7), probe(8), "different seed, different draws");
        assert!(
            probe(7).contains(&ShardFault::Wedge(Duration::from_millis(7))),
            "p=0.5 over 64 probes must wedge at least once"
        );
    }

    #[test]
    fn slow_tick_draws_are_seed_deterministic() {
        // Two plans with the same seed consume identical bernoulli
        // sequences; a different seed diverges. Probed via the rng
        // directly so the test never sleeps.
        let a = FaultPlan::parse("slow_tick=1ms@p=0.5,seed=7").unwrap();
        let b = FaultPlan::parse("slow_tick=1ms@p=0.5,seed=7").unwrap();
        let c = FaultPlan::parse("slow_tick=1ms@p=0.5,seed=8").unwrap();
        let draw = |p: &FaultPlan| -> Vec<bool> {
            let mut rng = p.rng.lock().unwrap();
            (0..64).map(|_| rng.bernoulli(0.5)).collect()
        };
        assert_eq!(draw(&a), draw(&b));
        assert_ne!(draw(&a), draw(&c));
    }
}
