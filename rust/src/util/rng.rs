//! Deterministic pseudo-random number generation.
//!
//! The offline vendored registry ships no `rand` crate, so we implement the
//! PCG-XSH-RR 64/32 generator (O'Neill 2014) plus the distributions the
//! training stack needs: uniform ints/floats, Box-Muller normals, shuffles
//! and categorical sampling. Every consumer of randomness in the repo
//! (data generators, initializers, trial seeds) goes through this type so
//! runs are reproducible from a single `u64` seed, mirroring the paper's
//! fixed-seed protocol (Appendix D).

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output, period 2^64 per stream.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg64 {
    /// Create a generator from a seed and stream id. Different streams with
    /// the same seed are statistically independent.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Create a generator from a seed on the default stream.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Derive an independent child generator; used to hand each dataset /
    /// trial / initializer its own stream without coupling their draws.
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        let seed = ((self.next_u32() as u64) << 32) | self.next_u32() as u64;
        Pcg64::with_stream(seed ^ tag.wrapping_mul(0x9e3779b97f4a7c15), tag)
    }

    /// Next raw 32 bits.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64 bits.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform integer in `[0, bound)` using Lemire-style rejection to avoid
    /// modulo bias.
    pub fn uniform_u32(&mut self, bound: u32) -> u32 {
        assert!(bound > 0, "uniform_u32 bound must be positive");
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            if r >= threshold {
                return r % bound;
            }
        }
    }

    /// Uniform usize in `[0, bound)`.
    ///
    /// # Panics
    /// Panics on `bound == 0` (a uniform draw over an empty range is
    /// undefined — callers with a possibly-empty range must guard it, see
    /// e.g. `serving::request_tokens`'s single-token-vocab contract) and on
    /// bounds beyond `u32::MAX` (the generator emits 32-bit draws).
    pub fn uniform_usize(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "uniform_usize bound must be positive (empty range)");
        assert!(bound <= u32::MAX as usize, "uniform_usize bound exceeds u32::MAX");
        self.uniform_u32(bound as u32) as usize
    }

    /// Uniform f32 in `[0, 1)` with 24 bits of entropy.
    pub fn uniform_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of entropy.
    pub fn uniform_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn uniform_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform_f32()
    }

    /// Standard normal via Box-Muller. One value per call (the twin is
    /// discarded; simplicity over a cached state that complicates forking).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            return (r * theta.cos()) as f32;
        }
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Fill a slice with N(0, std) draws.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * std;
        }
    }

    /// Bernoulli draw with probability `p`.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform_f64() < p
    }

    /// Fisher-Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.len() < 2 {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.uniform_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical needs positive total weight");
        let mut u = self.uniform_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Choose `k` distinct indices from `[0, n)` (k <= n), order randomized.
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_f32_in_unit_interval() {
        let mut rng = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = rng.uniform_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_u32_unbiased_small_bound() {
        let mut rng = Pcg64::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[rng.uniform_u32(5) as usize] += 1;
        }
        for &c in &counts {
            // expectation 10_000; loose 10% band
            assert!((9_000..11_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(11);
        let n = 100_000;
        let (mut sum, mut sq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = rng.normal() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Pcg64::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Pcg64::new(13);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[rng.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((2.6..3.4).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    #[should_panic(expected = "uniform_usize bound must be positive")]
    fn uniform_usize_zero_bound_panics_loudly() {
        // The empty-range contract is explicit, not an implicit assert
        // without a message: callers that can see bound 0 (degenerate
        // vocab) must guard before calling.
        let mut rng = Pcg64::new(1);
        let _ = rng.uniform_usize(0);
    }

    #[test]
    fn choose_k_distinct() {
        let mut rng = Pcg64::new(17);
        let picked = rng.choose_k(20, 8);
        assert_eq!(picked.len(), 8);
        let mut s = picked.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 8);
    }
}
