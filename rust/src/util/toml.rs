//! Minimal TOML-subset parser for experiment configuration files.
//!
//! Supports the subset used by `configs/*.toml`: top-level and nested
//! `[tables]`, `[[array.of.tables]]`, and key/value pairs with strings,
//! integers, floats, booleans and homogeneous inline arrays. Comments with
//! `#`. Values parse into the same [`Json`](super::json::Json) tree as the
//! JSON module so downstream config code has a single value type.

use super::json::Json;
use std::collections::BTreeMap;

/// Parse a TOML-subset document into a Json object tree.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut root: BTreeMap<String, Json> = BTreeMap::new();
    // Path of the table currently being filled.
    let mut current_path: Vec<String> = Vec::new();
    // Whether current_path addresses the last element of an array-of-tables.
    let mut current_is_array = false;

    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| format!("line {}: {}", lineno + 1, msg);

        if let Some(inner) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
            let path = parse_path(inner).map_err(|e| err(&e))?;
            push_array_table(&mut root, &path).map_err(|e| err(&e))?;
            current_path = path;
            current_is_array = true;
        } else if let Some(inner) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            let path = parse_path(inner).map_err(|e| err(&e))?;
            ensure_table(&mut root, &path).map_err(|e| err(&e))?;
            current_path = path;
            current_is_array = false;
        } else if let Some(eq) = find_eq(&line) {
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(err("empty key"));
            }
            let val = parse_value(line[eq + 1..].trim()).map_err(|e| err(&e))?;
            let table = resolve_mut(&mut root, &current_path, current_is_array)
                .map_err(|e| err(&e))?;
            if table.insert(key.to_string(), val).is_some() {
                return Err(err(&format!("duplicate key '{key}'")));
            }
        } else {
            return Err(err("expected key = value or [table]"));
        }
    }
    Ok(Json::Obj(root))
}

/// Parse a TOML file from disk.
pub fn parse_file(path: &std::path::Path) -> Result<Json, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("read {}: {e}", path.display()))?;
    parse(&text).map_err(|e| format!("{}: {e}", path.display()))
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside of a string starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn find_eq(line: &str) -> Option<usize> {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '=' if !in_str => return Some(i),
            _ => {}
        }
    }
    None
}

fn parse_path(s: &str) -> Result<Vec<String>, String> {
    let parts: Vec<String> = s.split('.').map(|p| p.trim().to_string()).collect();
    if parts.iter().any(|p| p.is_empty()) {
        return Err(format!("bad table path '{s}'"));
    }
    Ok(parts)
}

fn ensure_table(root: &mut BTreeMap<String, Json>, path: &[String]) -> Result<(), String> {
    let mut cur = root;
    for part in path {
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| Json::Obj(BTreeMap::new()));
        cur = match entry {
            Json::Obj(m) => m,
            Json::Arr(items) => match items.last_mut() {
                Some(Json::Obj(m)) => m,
                _ => return Err(format!("'{part}' is not a table")),
            },
            _ => return Err(format!("'{part}' is not a table")),
        };
    }
    Ok(())
}

fn push_array_table(root: &mut BTreeMap<String, Json>, path: &[String]) -> Result<(), String> {
    let (last, prefix) = path.split_last().ok_or("empty path")?;
    ensure_table(root, prefix)?;
    let mut cur = root;
    for part in prefix {
        cur = match cur.get_mut(part) {
            Some(Json::Obj(m)) => m,
            Some(Json::Arr(items)) => match items.last_mut() {
                Some(Json::Obj(m)) => m,
                _ => return Err(format!("'{part}' is not a table")),
            },
            _ => return Err(format!("'{part}' is not a table")),
        };
    }
    match cur.entry(last.clone()).or_insert_with(|| Json::Arr(Vec::new())) {
        Json::Arr(items) => {
            items.push(Json::Obj(BTreeMap::new()));
            Ok(())
        }
        _ => Err(format!("'{last}' is not an array of tables")),
    }
}

fn resolve_mut<'a>(
    root: &'a mut BTreeMap<String, Json>,
    path: &[String],
    is_array: bool,
) -> Result<&'a mut BTreeMap<String, Json>, String> {
    let mut cur = root;
    for (i, part) in path.iter().enumerate() {
        let at_last = i + 1 == path.len();
        cur = match cur.get_mut(part) {
            Some(Json::Obj(m)) => m,
            Some(Json::Arr(items)) if at_last && is_array || !at_last => {
                match items.last_mut() {
                    Some(Json::Obj(m)) => m,
                    _ => return Err(format!("'{part}' is not a table")),
                }
            }
            _ => return Err(format!("unknown table '{part}'")),
        };
    }
    Ok(cur)
}

fn parse_value(s: &str) -> Result<Json, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    other => return Err(format!("bad escape {:?}", other)),
                }
            } else {
                out.push(c);
            }
        }
        return Ok(Json::Str(out));
    }
    if s == "true" {
        return Ok(Json::Bool(true));
    }
    if s == "false" {
        return Ok(Json::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(Json::Arr(vec![]));
        }
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            items.push(parse_value(part.trim())?);
        }
        return Ok(Json::Arr(items));
    }
    // numbers, with TOML underscores allowed
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    cleaned
        .parse::<f64>()
        .map(Json::Num)
        .map_err(|_| format!("bad value '{s}'"))
}

/// Split an inline-array body on commas that are not nested in [] or "".
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_document() {
        let doc = r#"
# experiment config
name = "table1"
seeds = [42, 2025, 33305628]
alpha = 0.5

[model]
layers = 4
dim = 128
label = "tiny" # inline comment

[train.sched]
warmup_ratio = 0.06
"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("name").unwrap().as_str().unwrap(), "table1");
        assert_eq!(v.get("seeds").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("alpha").unwrap().as_f64().unwrap(), 0.5);
        assert_eq!(v.get("model").unwrap().get("dim").unwrap().as_i64().unwrap(), 128);
        assert_eq!(
            v.get("train").unwrap().get("sched").unwrap().get("warmup_ratio").unwrap().as_f64().unwrap(),
            0.06
        );
    }

    #[test]
    fn array_of_tables() {
        let doc = r#"
[[run]]
task = "mrpc_syn"
rank = 8

[[run]]
task = "rte_syn"
rank = 16
"#;
        let v = parse(doc).unwrap();
        let runs = v.get("run").unwrap().as_arr().unwrap();
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[1].get("rank").unwrap().as_i64().unwrap(), 16);
    }

    #[test]
    fn nested_arrays_and_strings() {
        let doc = r#"grid = [[1, 2], [3, 4]]
msg = "a#b, [c]""#;
        let v = parse(doc).unwrap();
        let grid = v.get("grid").unwrap().as_arr().unwrap();
        assert_eq!(grid[1].as_arr().unwrap()[0].as_i64().unwrap(), 3);
        assert_eq!(v.get("msg").unwrap().as_str().unwrap(), "a#b, [c]");
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse("just words").is_err());
        assert!(parse("a = ").is_err());
        assert!(parse("[unclosed").is_err());
        assert!(parse("a=1\na=2").is_err());
    }

    #[test]
    fn underscores_in_numbers() {
        let v = parse("n = 100_000").unwrap();
        assert_eq!(v.get("n").unwrap().as_i64().unwrap(), 100_000);
    }
}
