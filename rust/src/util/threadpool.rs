//! A small fixed-size thread pool with scoped parallel-map helpers.
//!
//! The coordinator uses this for embarrassingly parallel work: generating
//! synthetic datasets, running independent seeds of an experiment, and
//! sweeping benchmark grids. No `tokio` in the offline registry, and the
//! workloads are CPU-bound anyway, so plain `std::thread` + channels is the
//! right tool.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool. Jobs are executed FIFO; `join` blocks until the
/// queue drains and workers exit.
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("metatt-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // all senders dropped
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { sender: Some(tx), workers }
    }

    /// Default-sized pool: available parallelism capped at 8 (experiment
    /// trials are memory-hungry; more workers rarely help on this box).
    pub fn default_size() -> Self {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n.min(8))
    }

    /// Submit a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.sender
            .as_ref()
            .expect("pool joined")
            .send(Box::new(job))
            .expect("worker pool hung up");
    }

    /// Drain the queue and stop the workers.
    pub fn join(mut self) {
        drop(self.sender.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Parallel map over `items`, preserving order, with at most `threads`
/// concurrent evaluations. `f` runs on borrowed scope threads, so it may
/// capture references to the caller's stack.
pub fn par_map<T, U, F>(items: &[T], threads: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.iter().map(&f).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut out: Vec<Option<U>> = (0..items.len()).map(|_| None).collect();
    let out_cells: Vec<Mutex<&mut Option<U>>> =
        out.iter_mut().map(Mutex::new).collect();
    thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let val = f(&items[i]);
                **out_cells[i].lock().unwrap() = Some(val);
            });
        }
    });
    drop(out_cells);
    out.into_iter().map(|v| v.expect("par_map slot filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<usize> = (0..57).collect();
        let out = par_map(&items, 4, |&x| x * x);
        assert_eq!(out, items.iter().map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_single_thread_and_empty() {
        let items: Vec<usize> = vec![];
        assert!(par_map(&items, 4, |&x| x).is_empty());
        let one = vec![3usize];
        assert_eq!(par_map(&one, 1, |&x| x + 1), vec![4]);
    }

    #[test]
    fn par_map_borrows_stack() {
        let base = vec![10usize, 20, 30];
        let items = vec![0usize, 1, 2];
        let out = par_map(&items, 2, |&i| base[i] + 1);
        assert_eq!(out, vec![11, 21, 31]);
    }
}
