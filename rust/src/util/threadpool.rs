//! Thread pools: a fixed-size FIFO job pool for coordinator-level fan-out
//! and a persistent **scoped** pool that powers the data-parallel kernels.
//!
//! Two distinct workloads, two designs:
//!
//! * [`ThreadPool`] — coarse `'static` jobs (independent experiment seeds,
//!   dataset generation, bench grids). Plain `std::thread` + channels.
//! * [`ScopedPool`] / [`scope_for`] — the RefBackend hot path. A parallel
//!   region lasts microseconds-to-milliseconds and borrows the caller's
//!   stack (tensor slices), so jobs cannot be `'static` and per-region
//!   thread spawning would dominate. The scoped pool keeps its workers
//!   alive across regions and dispatches a *borrowed* closure by address;
//!   the submitting call blocks until the region completes, which is what
//!   makes the lifetime erasure sound (see `ScopedPool::run`). Workers are
//!   persistent, so per-worker-thread state (the packed-GEMM `*_into_local`
//!   pack scratch) warms up once and is reused across regions.
//!
//! Thread-count resolution lives here too ([`resolve_threads`]): explicit
//! config (`--threads` / `[runtime] threads`) wins, then the
//! `METATT_THREADS` env var, then the host's available parallelism.
//! `0` is always rejected with a helpful message rather than a panic.
//!
//! **Determinism contract:** none of the helpers change *what* is computed,
//! only *where*. Every parallel consumer in the crate assigns each output
//! row/band to exactly one worker and keeps per-row accumulation order
//! fixed, so 1-thread and N-thread runs are bit-identical (asserted by
//! `tests/determinism.rs`).

use std::cell::Cell;
use std::marker::PhantomData;
use std::ops::Range;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Upper bound on auto-detected thread counts: beyond this the reference
/// executor's memory bandwidth saturates long before the cores do.
const MAX_AUTO_THREADS: usize = 8;

/// Hard cap on the global scoped pool's worker count.
const MAX_POOL_THREADS: usize = 16;

static POOL: OnceLock<ScopedPool> = OnceLock::new();
static POOL_FLOOR: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

/// Register an explicit thread budget (from `--threads` / `[runtime]
/// threads`) so the global scoped pool — sized lazily at its first parallel
/// region — spawns enough workers to honor it. Backends call this at
/// construction, which precedes any region; once the pool exists its size
/// is frozen, so a late larger request warns instead of silently
/// under-delivering.
pub fn request_pool_capacity(threads: usize) {
    POOL_FLOOR.fetch_max(threads, std::sync::atomic::Ordering::Relaxed);
    if let Some(pool) = POOL.get() {
        // +1: the caller of a region is itself a worker.
        if threads > pool.size + 1 {
            eprintln!(
                "note: {} threads requested but the kernel pool was already \
                 sized with {} workers at its first use — parallel regions \
                 will use at most {} threads",
                threads,
                pool.size,
                pool.size + 1
            );
        }
    }
}

/// Thread budget gated on work size: serial below `min_work`, the caller's
/// budget above it (region dispatch costs ~µs; don't pay it for tiny loops).
pub fn gated_threads(threads: usize, work: usize, min_work: usize) -> usize {
    if work < min_work {
        1
    } else {
        threads
    }
}

/// Resolve the effective worker-thread count.
///
/// Precedence: `explicit` (CLI/TOML) > `METATT_THREADS` env var > host
/// `available_parallelism()` capped at [`MAX_AUTO_THREADS`]. A configured
/// value of `0` is rejected with a helpful message (use `1` for serial
/// execution, or omit the setting for auto-detection).
pub fn resolve_threads(explicit: Option<usize>) -> Result<usize, String> {
    match explicit {
        Some(0) => Err(
            "thread count must be >= 1 (got 0): use `1` for serial execution \
             or omit the setting to auto-detect"
                .to_string(),
        ),
        Some(n) => Ok(n),
        None => match std::env::var("METATT_THREADS") {
            Ok(v) => match v.trim().parse::<usize>() {
                Ok(0) => Err(
                    "METATT_THREADS must be >= 1 (got 0): use 1 for serial \
                     execution or unset the variable to auto-detect"
                        .to_string(),
                ),
                Ok(n) => Ok(n),
                Err(_) => Err(format!(
                    "METATT_THREADS expects a positive integer, got '{v}'"
                )),
            },
            Err(_) => Ok(auto_threads()),
        },
    }
}

/// Host-derived default thread count (no env / config consulted).
pub fn auto_threads() -> usize {
    thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(MAX_AUTO_THREADS)
}

/// Best-effort resolution for infallible constructors: configured value if
/// valid, host default otherwise.
pub fn default_threads() -> usize {
    resolve_threads(None).unwrap_or_else(|_| auto_threads())
}

/// Fixed-size worker pool. Jobs are executed FIFO; `join` blocks until the
/// queue drains and workers exit.
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers. `n == 0` is a configuration error (not a panic):
    /// callers surface the message next to the `--threads` / `threads =`
    /// setting that produced it.
    pub fn new(n: usize) -> Result<Self, String> {
        if n == 0 {
            return Err(
                "ThreadPool size must be >= 1 (got 0): use 1 for serial \
                 execution or omit the setting to auto-detect"
                    .to_string(),
            );
        }
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("metatt-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // all senders dropped
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Ok(ThreadPool { sender: Some(tx), workers })
    }

    /// Default-sized pool honoring the runtime configuration: the
    /// `METATT_THREADS` env var when set (and valid), else the host's
    /// available parallelism capped at [`MAX_AUTO_THREADS`].
    pub fn default_size() -> Self {
        Self::new(default_threads()).expect("default_threads() >= 1")
    }

    /// Submit a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.sender
            .as_ref()
            .expect("pool joined")
            .send(Box::new(job))
            .expect("worker pool hung up");
    }

    /// Drain the queue and stop the workers.
    pub fn join(mut self) {
        drop(self.sender.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Scoped pool: persistent workers, borrowed closures, one region at a time.
// ---------------------------------------------------------------------------

/// Type-erased pointer to the region's `Fn(usize)` closure. Only valid
/// while the submitting `run` call blocks; workers re-validate the region
/// under the state lock before touching it.
#[derive(Clone, Copy)]
struct RegionJob {
    f: *const (dyn Fn(usize) + Sync),
}
// SAFETY: the pointer is only dereferenced by workers registered in
// `State::active`, and `ScopedPool::run` does not return (and therefore the
// pointee cannot be dropped) until `active == 0` and all items completed.
unsafe impl Send for RegionJob {}

struct State {
    /// Bumped per region so sleeping workers recognize fresh work.
    epoch: u64,
    /// `Some` while a region is being executed.
    job: Option<RegionJob>,
    /// Next item index to hand out.
    next: usize,
    /// Item count of the current region.
    n: usize,
    /// Completed item count.
    done: usize,
    /// Max pool workers allowed to join the current region.
    limit: usize,
    /// Pool workers that joined the current region.
    joined: usize,
    /// Pool workers currently registered on the region (inside the steal
    /// loop). The caller only returns once this hits zero.
    active: usize,
    /// Set when any item's closure panicked; the caller re-raises after the
    /// region drains (so no dangling job pointer survives the unwind).
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    work_cv: Condvar,
    done_cv: Condvar,
}

/// A persistent pool executing *scoped* parallel regions: `run` dispatches
/// a borrowed `Fn(usize)` across the workers and blocks until every index
/// has been processed. One region runs at a time (regions are short); a
/// nested `run` from inside a region executes inline to avoid deadlock.
pub struct ScopedPool {
    shared: Arc<Shared>,
    /// Serializes regions so `State` describes exactly one of them.
    dispatch: Mutex<()>,
    size: usize,
    workers: Vec<thread::JoinHandle<()>>,
}

thread_local! {
    /// True while this thread is executing region items — used to run
    /// nested regions inline (a worker blocking on `dispatch` while its own
    /// region holds it would deadlock).
    static IN_REGION: Cell<bool> = const { Cell::new(false) };
}

impl ScopedPool {
    /// Spawn a scoped pool with `size` workers.
    pub fn new(size: usize) -> ScopedPool {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                job: None,
                next: 0,
                n: 0,
                done: 0,
                limit: 0,
                joined: 0,
                active: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let workers = (0..size)
            .map(|i| {
                let shared = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("metatt-scoped-{i}"))
                    .spawn(move || Self::worker_loop(&shared))
                    .expect("spawn scoped worker")
            })
            .collect();
        ScopedPool { shared, dispatch: Mutex::new(()), size, workers }
    }

    /// The process-wide pool used by the parallel kernels. Sized once at
    /// first use from the host parallelism, `METATT_THREADS`, and any
    /// explicit budget registered via [`request_pool_capacity`] before the
    /// first parallel region (backend construction does this), capped at
    /// [`MAX_POOL_THREADS`]; idle workers cost nothing.
    pub fn global() -> &'static ScopedPool {
        POOL.get_or_init(|| {
            let n = auto_threads()
                .max(default_threads())
                .max(POOL_FLOOR.load(std::sync::atomic::Ordering::Relaxed))
                .clamp(1, MAX_POOL_THREADS);
            // `run` uses the caller as one worker, so the pool only needs
            // n - 1 helpers; keep at least one so threads=2 parallelizes.
            ScopedPool::new((n.saturating_sub(1)).max(1))
        })
    }

    fn worker_loop(shared: &Shared) {
        let mut seen = 0u64;
        let mut st = shared.state.lock().unwrap();
        loop {
            if st.shutdown {
                return;
            }
            let fresh = st.job.is_some() && st.epoch > seen;
            if !fresh {
                st = shared.work_cv.wait(st).unwrap();
                continue;
            }
            seen = st.epoch;
            if st.joined >= st.limit {
                continue; // region already has its quota of workers
            }
            st.joined += 1;
            st.active += 1;
            let job = st.job.expect("fresh region has a job");
            loop {
                if st.next >= st.n {
                    st.active -= 1;
                    if st.active == 0 {
                        shared.done_cv.notify_all();
                    }
                    break;
                }
                let i = st.next;
                st.next += 1;
                drop(st);
                IN_REGION.with(|c| c.set(true));
                // SAFETY: `run` blocks until active == 0, so `job.f`
                // outlives this call. A panicking item is caught so the
                // region's accounting still drains; the caller re-raises.
                let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
                    (*job.f)(i)
                }))
                .is_ok();
                IN_REGION.with(|c| c.set(false));
                st = shared.state.lock().unwrap();
                st.done += 1;
                if !ok {
                    st.panicked = true;
                }
                if st.done == st.n {
                    shared.done_cv.notify_all();
                }
            }
        }
    }

    /// Execute `f(0..n)` across up to `threads` threads (the caller plus
    /// pool workers), blocking until all items complete. `f` may freely
    /// borrow the caller's stack. Items are handed out in order but run
    /// concurrently; callers must make item writes disjoint.
    pub fn run(&self, threads: usize, n: usize, f: &(dyn Fn(usize) + Sync)) {
        if n == 0 {
            return;
        }
        let nested = IN_REGION.with(|c| c.get());
        if threads <= 1 || n == 1 || nested {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let _region = self.dispatch.lock().unwrap();
        // Lifetime erasure: raw pointers carry no lifetime. Sound because
        // this call blocks until every worker has deregistered (active == 0),
        // so the pointee outlives all dereferences.
        let job = RegionJob { f: f as *const (dyn Fn(usize) + Sync) };
        {
            let mut st = self.shared.state.lock().unwrap();
            st.epoch += 1;
            st.job = Some(job);
            st.next = 0;
            st.n = n;
            st.done = 0;
            st.limit = (threads - 1).min(self.size);
            st.joined = 0;
            st.active = 0;
            st.panicked = false;
            self.shared.work_cv.notify_all();
        }
        // The caller is a full participant in its own region. Its panics
        // are caught like a worker's so the region always drains and the
        // job pointer is cleared before any unwind leaves this frame.
        loop {
            let i = {
                let mut st = self.shared.state.lock().unwrap();
                if st.next >= st.n {
                    break;
                }
                let i = st.next;
                st.next += 1;
                i
            };
            IN_REGION.with(|c| c.set(true));
            let ok =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i))).is_ok();
            IN_REGION.with(|c| c.set(false));
            let mut st = self.shared.state.lock().unwrap();
            st.done += 1;
            if !ok {
                st.panicked = true;
            }
            if st.done == st.n {
                self.shared.done_cv.notify_all();
            }
        }
        let mut st = self.shared.state.lock().unwrap();
        while !(st.done == st.n && st.active == 0) {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        st.job = None;
        let panicked = st.panicked;
        st.panicked = false;
        drop(st);
        if panicked {
            panic!("a parallel region item panicked (see worker output above)");
        }
    }
}

impl Drop for ScopedPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Scope-style helpers over the global pool.
// ---------------------------------------------------------------------------

/// Scoped parallel-for over `0..n` on the global pool: `f(i)` runs from up
/// to `threads` threads and may borrow the caller's stack. Blocks until all
/// items complete.
pub fn scope_for(threads: usize, n: usize, f: impl Fn(usize) + Sync) {
    ScopedPool::global().run(threads, n, &f);
}

/// Scoped parallel map over `0..n`, preserving index order.
pub fn scope_map<U: Send>(
    threads: usize,
    n: usize,
    f: impl Fn(usize) -> U + Sync,
) -> Vec<U> {
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<U>> = (0..n).map(|_| None).collect();
    {
        let cells: Vec<Mutex<&mut Option<U>>> = out.iter_mut().map(Mutex::new).collect();
        scope_for(threads, n, |i| {
            **cells[i].lock().unwrap() = Some(f(i));
        });
    }
    out.into_iter().map(|v| v.expect("scope_map slot filled")).collect()
}

/// Split `0..rows` into contiguous bands of at least `min_rows` and run
/// `f(band_range)` for each band in parallel (each row belongs to exactly
/// one band, so per-row work — and accumulation order — is independent of
/// the thread count). Serial when a single band suffices.
///
/// The unit is whatever the caller says it is: the encoder's row loops band
/// over output rows, while the packed GEMM kernels band over *MR-panels*
/// of packed A (each band packs its own panel slice and shares one packed
/// B), so the banding policy lives here either way.
pub fn scope_rows(
    threads: usize,
    rows: usize,
    min_rows: usize,
    f: impl Fn(Range<usize>) + Sync,
) {
    if rows == 0 {
        return;
    }
    // Floor division so no band drops under `min_rows` (a ceil here could
    // produce bands one row short of the cache-granularity floor).
    let max_bands = (rows / min_rows.max(1)).max(1);
    // A few bands per thread keeps stragglers short without shredding rows.
    let bands = (threads * 2).clamp(1, max_bands);
    if threads <= 1 || bands <= 1 {
        f(0..rows);
        return;
    }
    let band_rows = rows.div_ceil(bands);
    let bands = rows.div_ceil(band_rows);
    scope_for(threads, bands, |b| {
        let lo = b * band_rows;
        let hi = (lo + band_rows).min(rows);
        f(lo..hi);
    });
}

/// Shared mutable slice for disjoint-range parallel writes (the kernels'
/// row-band output buffers). The *caller* guarantees ranges handed to
/// concurrent workers never overlap; the type only carries the pointer
/// across the closure boundary.
pub struct SharedSliceMut<'a, T> {
    ptr: *mut T,
    len: usize,
    _pd: PhantomData<&'a mut [T]>,
}

// SAFETY: access is restricted to `range_mut`, whose disjointness contract
// the caller upholds; T: Send makes cross-thread writes sound.
unsafe impl<T: Send> Send for SharedSliceMut<'_, T> {}
unsafe impl<T: Send> Sync for SharedSliceMut<'_, T> {}

impl<'a, T> SharedSliceMut<'a, T> {
    pub fn new(s: &'a mut [T]) -> Self {
        SharedSliceMut { ptr: s.as_mut_ptr(), len: s.len(), _pd: PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Mutable view of `[lo, hi)`.
    ///
    /// # Safety
    /// Concurrent callers must use pairwise-disjoint ranges, and `hi <=
    /// len` (checked). The borrow must end before the backing slice's
    /// borrow does (guaranteed by the `'a` lifetime).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn range_mut(&self, lo: usize, hi: usize) -> &mut [T] {
        assert!(lo <= hi && hi <= self.len, "range {lo}..{hi} out of {}", self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(lo), hi - lo)
    }
}

/// Parallel map over `items`, preserving order, with at most `threads`
/// concurrent evaluations. `f` runs on pool threads but may capture
/// references to the caller's stack (scope-style borrows).
pub fn par_map<T, U, F>(items: &[T], threads: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    scope_map(threads, items.len(), |i| f(&items[i]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4).unwrap();
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn zero_sized_pool_is_a_clean_error() {
        let err = ThreadPool::new(0).unwrap_err();
        assert!(err.contains(">= 1"), "unhelpful message: {err}");
    }

    #[test]
    fn resolve_threads_rejects_zero_and_accepts_explicit() {
        assert!(resolve_threads(Some(0)).unwrap_err().contains(">= 1"));
        assert_eq!(resolve_threads(Some(3)).unwrap(), 3);
        assert!(default_threads() >= 1);
    }

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<usize> = (0..57).collect();
        let out = par_map(&items, 4, |&x| x * x);
        assert_eq!(out, items.iter().map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_single_thread_and_empty() {
        let items: Vec<usize> = vec![];
        assert!(par_map(&items, 4, |&x| x).is_empty());
        let one = vec![3usize];
        assert_eq!(par_map(&one, 1, |&x| x + 1), vec![4]);
    }

    #[test]
    fn par_map_borrows_stack() {
        let base = vec![10usize, 20, 30];
        let items = vec![0usize, 1, 2];
        let out = par_map(&items, 2, |&i| base[i] + 1);
        assert_eq!(out, vec![11, 21, 31]);
    }

    #[test]
    fn scope_for_covers_every_index_once() {
        let n = 997;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        scope_for(4, n, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn scope_rows_partitions_rows_exactly() {
        for rows in [0usize, 1, 7, 64, 1000] {
            let hits: Vec<AtomicUsize> = (0..rows).map(|_| AtomicUsize::new(0)).collect();
            scope_rows(4, rows, 8, |r| {
                for i in r {
                    hits[i].fetch_add(1, Ordering::SeqCst);
                }
            });
            assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1), "rows={rows}");
        }
    }

    #[test]
    fn nested_regions_run_inline_without_deadlock() {
        let total = AtomicUsize::new(0);
        scope_for(4, 8, |_| {
            // Inner region must not dead-lock on the dispatch mutex.
            scope_for(4, 8, |_| {
                total.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(total.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn concurrent_regions_from_plain_threads_serialize() {
        let total = AtomicUsize::new(0);
        thread::scope(|s| {
            for _ in 0..3 {
                s.spawn(|| {
                    scope_for(4, 100, |_| {
                        total.fetch_add(1, Ordering::SeqCst);
                    });
                });
            }
        });
        assert_eq!(total.load(Ordering::SeqCst), 300);
    }

    #[test]
    fn panicking_region_item_propagates_not_hangs() {
        let res = std::panic::catch_unwind(|| {
            scope_for(4, 64, |i| {
                if i == 33 {
                    panic!("boom");
                }
            });
        });
        assert!(res.is_err(), "region panic must propagate to the caller");
        // The pool must still be usable afterwards.
        let hits = AtomicUsize::new(0);
        scope_for(4, 16, |_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn shared_slice_disjoint_writes() {
        let mut v = vec![0usize; 100];
        {
            let sh = SharedSliceMut::new(&mut v);
            scope_rows(4, 100, 10, |r| {
                let band = unsafe { sh.range_mut(r.start, r.end) };
                for (off, x) in band.iter_mut().enumerate() {
                    *x = r.start + off;
                }
            });
        }
        assert!(v.iter().enumerate().all(|(i, &x)| x == i));
    }
}
