//! Minimal JSON parser / writer.
//!
//! Used for `artifacts/manifest.json` (written by `python/compile/aot.py`)
//! and for structured results logs under `results/`. The vendored registry
//! has no `serde_json`, so this is a small, strict, recursive-descent
//! implementation covering the full JSON grammar minus `\u` surrogate pairs
//! beyond the BMP (we only exchange ASCII identifiers and numbers).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are kept in a BTreeMap so serialized output is
/// deterministic (stable diffs for results files).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Field lookup on an object; None on non-objects / missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize with two-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        let pad = "  ".repeat(depth + 1);
        let close = "  ".repeat(depth);
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    item.write_pretty(out, depth + 1);
                }
                out.push('\n');
                out.push_str(&close);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                out.push_str(&close);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() {
        if n == n.trunc() && n.abs() < 1e15 {
            let _ = write!(out, "{}", n as i64);
        } else {
            let _ = write!(out, "{}", n);
        }
    } else {
        // JSON has no NaN/Inf; emit null like most tolerant writers.
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Errors carry the byte offset.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.pos)),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err("short \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.pos)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let s = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| "invalid utf8".to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number '{text}' at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\n", "d": true}, "e": null}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64().unwrap(), -300.0);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str().unwrap(), "hi\n");
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        let re2 = parse(&v.to_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":}").is_err());
    }

    #[test]
    fn integers_stay_integers_in_output() {
        let v = Json::num(42.0);
        assert_eq!(v.to_string(), "42");
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
