//! Multi-task serving engine over folded MetaTT adapters.
//!
//! MetaTT's deployment story (paper §2.4, and the TT-LoRA line of work):
//! one frozen backbone, one compact TT adapter whose middle cores index
//! layer, matrix type, and **task** — so serving many tasks means swapping
//! tiny folded factor pairs, never the model. This module turns that into
//! a real multi-tenant request path:
//!
//! ```text
//! submit → [AdmissionQueue]  bounded, blocking backpressure
//!        → [BatchPolicy]     dynamic same-task batching (max_batch /
//!                            batch-deadline tick, padding-free semantics:
//!                            row bits never depend on batchmates)
//!        → [AdapterStore]    per-task fold_for_serving cache — lazy fold +
//!                            pack at the serve dtype, byte-budget LRU
//!                            eviction, generation counters, snapshot
//!                            reads through checkpoint hot-swap
//!        → worker            Step::run_serve_packed on the ref backend: the
//!                            cache-free inference forward + two folded
//!                            GEMMs per adapted projection off pre-packed
//!                            (optionally bf16/int8) panels, zero-allocation
//!                            once warmed
//!        → Response          per-request one-shot channel
//! ```
//!
//! Requests carry an optional **deadline** and a **priority class**: the
//! batcher orders by (priority, earliest deadline, admission) and *sheds*
//! requests whose deadline passed before a worker reached them — answered
//! with an explicit `Expired` status and zero compute, the overload valve
//! that keeps goodput up when offered load exceeds capacity.
//!
//! [`net`] puts a TCP front-end on the same path: a length-prefixed binary
//! protocol (`MTS1`, std::net only) whose server drains gracefully on
//! shutdown. [`loadgen`] adds the deterministic closed-loop load generator
//! (`BENCH_pr5.json`), an open-loop Poisson arrival mode, and the overload
//! sweep behind `BENCH_pr6.json` (goodput / shed / tail latency at
//! multiples of measured capacity).
//!
//! The path is **self-healing** (PR 8): a worker that panics or errors on
//! a batch is supervised — the batch is requeued, the worker re-binds a
//! fresh step, and a request that keeps failing is bisected down and
//! answered with an explicit `Error` (quarantine) instead of poisoning its
//! batch-mates. [`RetryClient`] gives the TCP client reconnect-with-backoff
//! and safe re-send; `crate::util::fault` injects deterministic faults at
//! every seam so all of this is testable (`tests/chaos.rs`).
//!
//! The path is **sharded** (PR 9): a [`ShardRouter`] owns N engines —
//! replica groups of one adapter — routes by task with cache affinity, and
//! supervises them end-to-end on a heartbeat: Live/Degraded/Down health,
//! automatic failover of a Down shard's queue into a surviving replica
//! (through the urgency-ordered requeue path, never dropped), work
//! stealing between replicas under skew, and displacement admission when
//! capacity shrinks. The front-ends are generic over [`ServeTarget`], so
//! one engine and an N-shard topology speak the same MTS1 wire protocol
//! and admission semantics — routing lives strictly behind admission.
//!
//! The path is **observable** (PR 10): every request carries always-on
//! µs stage stamps (admit → batch-formed → tick-start → tick-end →
//! response-written) that ride the wire back to clients, and every
//! lifecycle seam calls into [`crate::obs`] — a lock-free ring-buffer
//! span tracer plus a metrics registry that is a single relaxed atomic
//! load when unarmed, so the warmed zero-alloc serve tick is untouched.
//! Armed via `--trace` / `METATT_TRACE=1`, exported as Chrome trace JSON,
//! and scraped live through the `STAT` admin frame on MTS1 (a
//! Prometheus-style text snapshot from an engine or router).
//!
//! Entry points: [`ServingEngine::new`] → [`ServingEngine::serve`] with a
//! driver closure; [`ShardRouter::new`] → [`ShardRouter::serve`] for a
//! topology; [`run_load`] for a full measured run (what `metatt
//! serve` does); [`serve_net`] inside a driver for the TCP front-end;
//! [`run_overload_bench`] for the overload sweep.

mod batcher;
mod cache;
mod engine;
mod loadgen;
pub mod net;
mod request;
mod router;

pub use batcher::BatchPolicy;
pub use cache::{metatt_from_tensors, AdapterStore, CacheStats, FoldedAdapter};
pub use engine::{adapter_spec_for, EngineConfig, EngineStats, ServeTarget, ServingEngine};
pub use router::{RoutePolicy, RouterConfig, RouterStats, ShardHealth, ShardRouter};
pub use loadgen::{
    closed_loop_in, open_loop_in, overload_report_json, report_json, request_stream,
    request_tokens, resilience_report_json, run_load, run_open_loop, run_overload_bench,
    stage_json, warmup_in, LoadGenConfig, LoadReport, OpenLoopConfig, OpenLoopReport,
    OverloadConfig, OverloadReport, StageBreakdown,
};
pub use net::{
    run_net_load, serve_net, serve_net_with, NetClient, NetClientConfig, NetLoadReport,
    NetResponse, NetServerConfig, NetStats, RetryClient, RetryPolicy, WireStatus,
    DEFAULT_NET_TIMEOUT,
};
pub use request::{AdmissionQueue, Request, Response, ResponseHandle, ResponseStatus, StageStamps};
