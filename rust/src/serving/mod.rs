//! Multi-task serving engine over folded MetaTT adapters.
//!
//! MetaTT's deployment story (paper §2.4, and the TT-LoRA line of work):
//! one frozen backbone, one compact TT adapter whose middle cores index
//! layer, matrix type, and **task** — so serving many tasks means swapping
//! tiny folded factor pairs, never the model. This module turns that into
//! a real multi-tenant request path:
//!
//! ```text
//! submit → [AdmissionQueue]  bounded, blocking backpressure
//!        → [BatchPolicy]     dynamic same-task batching (max_batch /
//!                            batch-deadline tick, padding-free semantics:
//!                            row bits never depend on batchmates)
//!        → [AdapterStore]    per-task fold_for_serving cache — lazy fold,
//!                            LRU eviction, generation counters, snapshot
//!                            reads through checkpoint hot-swap
//!        → worker            Step::run_serve on the ref backend: the
//!                            cache-free inference forward + two folded
//!                            GEMMs per adapted projection, zero-allocation
//!                            once warmed
//!        → Response          per-request one-shot channel
//! ```
//!
//! [`loadgen`] adds the deterministic closed-loop load generator that
//! drives the engine in-process and emits `BENCH_pr5.json` (latency
//! percentiles, throughput, batch-size histogram, cache hit rate).
//!
//! Entry points: [`ServingEngine::new`] → [`ServingEngine::serve`] with a
//! driver closure; [`run_load`] for a full measured run (what `metatt
//! serve` does).

mod batcher;
mod cache;
mod engine;
mod loadgen;
mod request;

pub use batcher::BatchPolicy;
pub use cache::{metatt_from_tensors, AdapterStore, CacheStats, FoldedAdapter};
pub use engine::{adapter_spec_for, EngineConfig, EngineStats, ServingEngine};
pub use loadgen::{
    report_json, request_stream, request_tokens, run_load, LoadGenConfig, LoadReport,
};
pub use request::{AdmissionQueue, Request, Response, ResponseHandle};
