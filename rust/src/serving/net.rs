//! TCP front-end: a length-prefixed binary protocol over `std::net` that
//! makes the in-process engine — queue, EDF batcher, folded-adapter cache,
//! abort path — reachable from outside the process.
//!
//! # Wire protocol (`MTS1`), all integers little-endian
//!
//! **Handshake.** The client sends the 4-byte magic `MTS1`; the server
//! answers a 20-byte hello: magic `MTS1`, then `u32` seq-len, `u32` vocab,
//! `u32` classes, `u32` num-tasks — everything a client needs to build
//! valid requests without out-of-band configuration.
//!
//! **Request frame** (client → server): `u32` body length, then
//! `u64` client-chosen request id · `u32` task · `u8` priority (lower =
//! more urgent) · `u64` deadline in µs relative to server receipt (0 =
//! none) · `u32` token count · that many `i32` token ids.
//!
//! **Response frame** (server → client): `u32` body length, then `u64` the
//! echoed request id · `u8` status. For status `0` (ok) and `1` (expired —
//! the deadline passed before a worker reached the request; it was shed,
//! not computed): `u32` task · `u64` adapter generation · `u32` batch rows
//! · `u32` logit count · that many `f32` logits (bit-exact: serving logits
//! round-trip the wire unchanged; expired responses carry zero logits) ·
//! five `u64` stage stamps on the server's µs clock (admit, batch-formed,
//! tick-start, tick-end, done; zeros when a stage never ran). Decoders
//! tolerate their absence, so pre-stamp frames still parse.
//! For status `2` (error — validation or shutdown): `u32` message length ·
//! UTF-8 message. Responses are written in request order per connection
//! (pipelining is allowed; a connection may have many requests in flight).
//!
//! **Admin frame.** A 4-byte request body `STAT` (unambiguous: real
//! request bodies are >= 25 bytes) asks for a Prometheus-style text
//! snapshot of the serve target's metrics; the server answers a status-`3`
//! frame: `u64` id 0 · `u8` status `3` · `u32` text length · UTF-8 text.
//! [`NetClient::stat`] wraps the round trip.
//!
//! # Server lifecycle
//!
//! [`serve_net`] runs inside the serve-target's driver slot (a single
//! engine's `serve` or a shard router's — the server half is generic over
//! [`ServeTarget`], so routing across shards happens strictly behind the
//! admission call and MTS1 is unchanged): an accept loop (non-blocking +
//! backoff poll, so no self-connect tricks) hands each
//! connection a reader thread (decode → `submit_with` — blocking admission
//! is per-connection TCP backpressure) and a writer thread (await handles
//! in order → encode). **Graceful drain** on shutdown: the accept loop
//! stops taking connections, readers stop consuming new frames (an
//! in-flight frame gets a grace period to finish arriving), writers flush
//! every already-admitted response — workers are still running, so those
//! handles all resolve — and only then are sockets closed. After the
//! driver returns, `serve` closes the queue and the workers drain; no
//! admitted request is ever dropped on a clean shutdown.

use super::engine::ServeTarget;
use super::request::{Response, ResponseHandle, ResponseStatus};
use anyhow::{anyhow, bail, Result};
use crate::util::rng::Pcg64;
use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Protocol magic + version ("MetaTT Serve v1").
pub const WIRE_MAGIC: [u8; 4] = *b"MTS1";
/// Largest accepted frame body (bytes) — a decode guard, not a tunable.
pub const MAX_FRAME: usize = 1 << 22;

const STATUS_OK: u8 = 0;
const STATUS_EXPIRED: u8 = 1;
const STATUS_ERROR: u8 = 2;
const STATUS_STAT: u8 = 3;

/// The admin request body asking for a metrics snapshot (see module docs).
const STAT_BODY: &[u8] = b"STAT";

/// Idle accept-poll bounds: the loop sleeps `ACCEPT_POLL_MIN` right after
/// traffic (snappy accepts) and doubles per empty poll up to
/// `ACCEPT_POLL_MAX`, so an idle listener costs ~20 accept syscalls per
/// second instead of the 200/s a fixed 5 ms poll burned.
const ACCEPT_POLL_MIN: Duration = Duration::from_millis(1);
const ACCEPT_POLL_MAX: Duration = Duration::from_millis(50);

/// Exponential idle backoff for the nonblocking accept loop (see the
/// bounds above). Pure arithmetic so the regression test can pin the
/// idle-second poll budget without real sleeps.
struct AcceptBackoff {
    cur: Duration,
}

impl AcceptBackoff {
    fn new() -> AcceptBackoff {
        AcceptBackoff { cur: ACCEPT_POLL_MIN }
    }

    /// The delay to sleep for this empty poll; doubles (capped) for the
    /// next one.
    fn idle_delay(&mut self) -> Duration {
        let d = self.cur;
        self.cur = (self.cur * 2).min(ACCEPT_POLL_MAX);
        d
    }

    /// A connection arrived: the next idle poll is prompt again.
    fn accepted(&mut self) {
        self.cur = ACCEPT_POLL_MIN;
    }
}
/// Per-connection read timeout — the granularity at which readers notice
/// the shutdown flag.
const READ_TICK: Duration = Duration::from_millis(25);
/// Default socket read/write timeout on the client side (`--net-timeout-ms`):
/// a dead or wedged peer surfaces as a clean timeout error instead of a
/// forever-blocked `recv`.
pub const DEFAULT_NET_TIMEOUT: Duration = Duration::from_secs(30);

/// Server-side tunables for [`serve_net_with`] (CLI flags map onto these;
/// [`serve_net`] uses the defaults).
#[derive(Clone, Copy, Debug)]
pub struct NetServerConfig {
    /// After shutdown, how long a half-received frame may keep a
    /// connection open before it is abandoned (the request was never
    /// admitted). `--drain-grace-ms`, validated > 0 by the CLI.
    pub drain_grace: Duration,
}

impl Default for NetServerConfig {
    fn default() -> NetServerConfig {
        NetServerConfig { drain_grace: Duration::from_secs(1) }
    }
}

/// One parsed response frame (client side).
#[derive(Clone, Debug, PartialEq)]
pub struct NetResponse {
    pub id: u64,
    pub status: WireStatus,
    pub task: usize,
    pub generation: u64,
    pub batch_rows: usize,
    pub logits: Vec<f32>,
    /// Populated for `WireStatus::Error` frames.
    pub error: Option<String>,
    /// Stage stamps on the server's µs clock (0 = stage never ran, or a
    /// pre-stamp peer). `admit_us → done_us` is the engine-side latency,
    /// free of client-side socket and scheduling time.
    pub admit_us: u64,
    pub batch_us: u64,
    pub start_us: u64,
    pub end_us: u64,
    pub done_us: u64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireStatus {
    Ok,
    Expired,
    Error,
}

impl WireStatus {
    fn from_u8(b: u8) -> Result<WireStatus> {
        match b {
            STATUS_OK => Ok(WireStatus::Ok),
            STATUS_EXPIRED => Ok(WireStatus::Expired),
            STATUS_ERROR => Ok(WireStatus::Error),
            other => bail!("unknown response status byte {other}"),
        }
    }
}

/// Server-side counters from one [`serve_net`] run.
#[derive(Clone, Copy, Debug, Default)]
pub struct NetStats {
    pub connections: u64,
    /// Request frames decoded and admitted (or answered with an error).
    pub requests: u64,
}

// ---------------------------------------------------------------------------
// Frame codecs (pure functions — unit-tested without sockets).
// ---------------------------------------------------------------------------

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Reader over a frame body with bounds-checked typed takes.
struct Cursor<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, at: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.at + n > self.buf.len() {
            bail!("truncated frame: wanted {n} bytes at offset {}", self.at);
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.at
    }

    fn done(&self) -> Result<()> {
        if self.at != self.buf.len() {
            bail!("{} trailing bytes after frame body", self.buf.len() - self.at);
        }
        Ok(())
    }
}

/// Encode a full request frame (length prefix included).
pub fn encode_request(
    id: u64,
    task: usize,
    priority: u8,
    deadline_us: u64,
    tokens: &[i32],
) -> Vec<u8> {
    let body_len = 8 + 4 + 1 + 8 + 4 + 4 * tokens.len();
    let mut buf = Vec::with_capacity(4 + body_len);
    put_u32(&mut buf, body_len as u32);
    put_u64(&mut buf, id);
    put_u32(&mut buf, task as u32);
    buf.push(priority);
    put_u64(&mut buf, deadline_us);
    put_u32(&mut buf, tokens.len() as u32);
    for &t in tokens {
        buf.extend_from_slice(&t.to_le_bytes());
    }
    buf
}

/// Decoded request frame body.
pub struct WireRequest {
    pub id: u64,
    pub task: usize,
    pub priority: u8,
    /// Relative deadline in µs; 0 = none.
    pub deadline_us: u64,
    pub tokens: Vec<i32>,
}

/// Decode a request frame body (the bytes after the length prefix).
pub fn decode_request(body: &[u8]) -> Result<WireRequest> {
    let mut c = Cursor::new(body);
    let id = c.u64()?;
    let task = c.u32()? as usize;
    let priority = c.u8()?;
    let deadline_us = c.u64()?;
    let n = c.u32()? as usize;
    if n > MAX_FRAME / 4 {
        bail!("request claims {n} tokens — frame cap exceeded");
    }
    let raw = c.take(4 * n)?;
    let tokens = raw
        .chunks_exact(4)
        .map(|ch| i32::from_le_bytes(ch.try_into().unwrap()))
        .collect();
    c.done()?;
    Ok(WireRequest { id, task, priority, deadline_us, tokens })
}

/// Encode an ok/expired response frame (length prefix included). `stamps`
/// is `[admit, batch, start, end, done]` in server-clock µs (zeros for
/// stages that never ran).
pub fn encode_response(
    id: u64,
    status: WireStatus,
    task: usize,
    generation: u64,
    batch_rows: usize,
    logits: &[f32],
    stamps: [u64; 5],
) -> Vec<u8> {
    debug_assert!(status != WireStatus::Error, "error frames carry a message instead");
    let body_len = 8 + 1 + 4 + 8 + 4 + 4 + 4 * logits.len() + 8 * stamps.len();
    let mut buf = Vec::with_capacity(4 + body_len);
    put_u32(&mut buf, body_len as u32);
    put_u64(&mut buf, id);
    buf.push(if status == WireStatus::Ok { STATUS_OK } else { STATUS_EXPIRED });
    put_u32(&mut buf, task as u32);
    put_u64(&mut buf, generation);
    put_u32(&mut buf, batch_rows as u32);
    put_u32(&mut buf, logits.len() as u32);
    for &x in logits {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    for s in stamps {
        put_u64(&mut buf, s);
    }
    buf
}

/// Encode an error response frame (length prefix included).
pub fn encode_error(id: u64, msg: &str) -> Vec<u8> {
    encode_text_frame(id, STATUS_ERROR, msg)
}

/// Encode a `STAT` admin response frame (length prefix included).
pub fn encode_stat(id: u64, text: &str) -> Vec<u8> {
    encode_text_frame(id, STATUS_STAT, text)
}

fn encode_text_frame(id: u64, status: u8, msg: &str) -> Vec<u8> {
    let msg = msg.as_bytes();
    let msg = &msg[..msg.len().min(MAX_FRAME / 2)];
    let body_len = 8 + 1 + 4 + msg.len();
    let mut buf = Vec::with_capacity(4 + body_len);
    put_u32(&mut buf, body_len as u32);
    put_u64(&mut buf, id);
    buf.push(status);
    put_u32(&mut buf, msg.len() as u32);
    buf.extend_from_slice(msg);
    buf
}

/// Decode a response frame body (the bytes after the length prefix).
pub fn decode_response(body: &[u8]) -> Result<NetResponse> {
    let mut c = Cursor::new(body);
    let id = c.u64()?;
    let status = WireStatus::from_u8(c.u8()?)?;
    if status == WireStatus::Error {
        let n = c.u32()? as usize;
        let msg = String::from_utf8_lossy(c.take(n)?).into_owned();
        c.done()?;
        return Ok(NetResponse {
            id,
            status,
            task: 0,
            generation: 0,
            batch_rows: 0,
            logits: Vec::new(),
            error: Some(msg),
            admit_us: 0,
            batch_us: 0,
            start_us: 0,
            end_us: 0,
            done_us: 0,
        });
    }
    let task = c.u32()? as usize;
    let generation = c.u64()?;
    let batch_rows = c.u32()? as usize;
    let n = c.u32()? as usize;
    if n > MAX_FRAME / 4 {
        bail!("response claims {n} logits — frame cap exceeded");
    }
    let raw = c.take(4 * n)?;
    let logits = raw
        .chunks_exact(4)
        .map(|ch| f32::from_le_bytes(ch.try_into().unwrap()))
        .collect();
    // Stage stamps were appended to the frame in PR 10; tolerate their
    // absence so pre-stamp frames still decode (stamps read as zeros).
    let stamps = if c.remaining() >= 40 {
        [c.u64()?, c.u64()?, c.u64()?, c.u64()?, c.u64()?]
    } else {
        [0u64; 5]
    };
    c.done()?;
    Ok(NetResponse {
        id,
        status,
        task,
        generation,
        batch_rows,
        logits,
        error: None,
        admit_us: stamps[0],
        batch_us: stamps[1],
        start_us: stamps[2],
        end_us: stamps[3],
        done_us: stamps[4],
    })
}

fn encode_hello<T: ServeTarget>(engine: &T) -> Vec<u8> {
    let mut buf = Vec::with_capacity(20);
    buf.extend_from_slice(&WIRE_MAGIC);
    put_u32(&mut buf, engine.seq_len() as u32);
    put_u32(&mut buf, engine.vocab() as u32);
    put_u32(&mut buf, engine.classes() as u32);
    put_u32(&mut buf, engine.num_tasks() as u32);
    buf
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

enum ReadStatus {
    Done,
    /// Clean EOF at a frame boundary.
    Eof,
    /// Shutdown requested while idle (or an in-flight frame overstayed the
    /// drain grace period).
    Idle,
}

/// Fill `buf` from a read-timeout stream. Timeouts are idle ticks: before
/// any byte of `buf` arrives, a tick with the shutdown flag set returns
/// [`ReadStatus::Idle`]; once bytes have arrived the frame is finished
/// regardless (finish admitted work), bounded by the `grace` window
/// ([`NetServerConfig::drain_grace`]).
fn read_exact_idle(
    stream: &mut TcpStream,
    buf: &mut [u8],
    shutdown: &AtomicBool,
    grace: Duration,
) -> std::io::Result<ReadStatus> {
    let mut filled = 0;
    let mut grace_from: Option<Instant> = None;
    while filled < buf.len() {
        match stream.read(&mut buf[filled..]) {
            Ok(0) => {
                return if filled == 0 {
                    Ok(ReadStatus::Eof)
                } else {
                    Err(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "connection closed mid-frame",
                    ))
                };
            }
            Ok(n) => filled += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if shutdown.load(Ordering::Relaxed) {
                    if filled == 0 {
                        return Ok(ReadStatus::Idle);
                    }
                    let from = *grace_from.get_or_insert_with(Instant::now);
                    if from.elapsed() >= grace {
                        return Ok(ReadStatus::Idle);
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(ReadStatus::Done)
}

/// One queued write: the client's id plus what to answer it with.
struct WriteCmd {
    client_id: u64,
    outcome: Outcome,
}

/// What the reader decided for one frame: a pending engine handle, an
/// immediate error message, or a metrics snapshot (`STAT` admin frame).
enum Outcome {
    Handle(ResponseHandle),
    Error(String),
    Stat(String),
}

fn response_frame(client_id: u64, resp: &Response) -> Vec<u8> {
    let status = match resp.status {
        ResponseStatus::Ok => WireStatus::Ok,
        ResponseStatus::Expired => WireStatus::Expired,
        // Quarantined (poisoned request): surface the engine's message as
        // an error frame.
        ResponseStatus::Error => {
            let msg = resp.error.as_deref().unwrap_or("request failed execution");
            return encode_error(client_id, msg);
        }
    };
    encode_response(
        client_id,
        status,
        resp.task,
        resp.generation,
        resp.batch_rows,
        &resp.logits,
        [
            resp.stamps.admit_us,
            resp.stamps.batch_us,
            resp.stamps.start_us,
            resp.stamps.end_us,
            resp.done_us,
        ],
    )
}

/// Await handles in request order and stream frames back. A write failure
/// (client went away) stops writing; remaining handles are dropped, which
/// is harmless — workers ignore dead response channels.
fn writer_loop(stream: &mut TcpStream, rx: mpsc::Receiver<WriteCmd>) {
    for cmd in rx {
        let frame = match cmd.outcome {
            Outcome::Handle(handle) => match handle.wait() {
                Ok(resp) => response_frame(cmd.client_id, &resp),
                // Dropped before execution (worker failure / abort).
                Err(e) => encode_error(cmd.client_id, &e),
            },
            Outcome::Error(msg) => encode_error(cmd.client_id, &msg),
            Outcome::Stat(text) => encode_stat(cmd.client_id, &text),
        };
        if stream.write_all(&frame).is_err() {
            break;
        }
    }
    let _ = stream.shutdown(Shutdown::Write);
}

/// Read frames, admit them, and feed the writer until EOF, shutdown, or a
/// connection error. Returns the number of request frames handled.
fn reader_loop<T: ServeTarget>(
    engine: &T,
    stream: &mut TcpStream,
    shutdown: &AtomicBool,
    grace: Duration,
    tx: mpsc::Sender<WriteCmd>,
) -> std::io::Result<u64> {
    let mut served = 0u64;
    loop {
        let mut len4 = [0u8; 4];
        match read_exact_idle(stream, &mut len4, shutdown, grace)? {
            ReadStatus::Done => {}
            ReadStatus::Eof | ReadStatus::Idle => return Ok(served),
        }
        let body_len = u32::from_le_bytes(len4) as usize;
        if body_len > MAX_FRAME {
            // Protocol violation: answer nothing (we cannot trust the
            // stream framing any more) and drop the connection.
            engine.obs().net.oversized_frames.inc();
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("frame body of {body_len} bytes exceeds the {MAX_FRAME} cap"),
            ));
        }
        let mut body = vec![0u8; body_len];
        match read_exact_idle(stream, &mut body, shutdown, grace)? {
            ReadStatus::Done => {}
            ReadStatus::Eof | ReadStatus::Idle => return Ok(served),
        }
        // Admin frame: a 4-byte `STAT` body (real request bodies are >= 25
        // bytes) is answered with a metrics snapshot through the ordinary
        // writer queue — ordered with pipelined responses, not counted as
        // a request, and invisible to request-frame fault injection.
        if body == STAT_BODY {
            engine.obs().net.stat_frames.inc();
            let cmd = WriteCmd { client_id: 0, outcome: Outcome::Stat(engine.metrics_text()) };
            if tx.send(cmd).is_err() {
                return Ok(served);
            }
            continue;
        }
        // Injected connection drop (`net_drop@frame=N`): abandon the
        // just-read frame WITHOUT admitting it and stop reading. Returning
        // Ok lets handle_conn's writer join flush every already-admitted
        // response before the socket closes, so the client observes:
        // pending responses, then EOF where this frame's response should
        // be — exactly a mid-stream connection loss, which its retry layer
        // must survive by re-sending on a fresh connection.
        if engine.faults().on_net_frame() {
            return Ok(served);
        }
        served += 1;
        let cmd = match decode_request(&body) {
            Ok(wire) => {
                let deadline = if wire.deadline_us == 0 {
                    None
                } else {
                    Some(Duration::from_micros(wire.deadline_us))
                };
                match engine.submit_with(wire.task, wire.tokens, deadline, wire.priority) {
                    Ok(handle) => {
                        WriteCmd { client_id: wire.id, outcome: Outcome::Handle(handle) }
                    }
                    Err(e) => WriteCmd {
                        client_id: wire.id,
                        outcome: Outcome::Error(format!("{e:#}")),
                    },
                }
            }
            // Undecodable body but intact framing: answer an error frame
            // with the best-effort id 0 and keep the connection.
            Err(e) => {
                engine.obs().net.bad_frames.inc();
                WriteCmd { client_id: 0, outcome: Outcome::Error(format!("{e:#}")) }
            }
        };
        if tx.send(cmd).is_err() {
            // Writer died (client closed its read half) — stop reading.
            return Ok(served);
        }
    }
}

fn handle_conn<T: ServeTarget>(
    engine: &T,
    mut stream: TcpStream,
    shutdown: &AtomicBool,
    grace: Duration,
) -> std::io::Result<u64> {
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(READ_TICK))?;
    // Handshake: magic in, hello out.
    let mut magic = [0u8; 4];
    match read_exact_idle(&mut stream, &mut magic, shutdown, grace)? {
        ReadStatus::Done => {}
        ReadStatus::Eof | ReadStatus::Idle => return Ok(0),
    }
    if magic != WIRE_MAGIC {
        engine.obs().net.bad_magic.inc();
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "bad protocol magic (want MTS1)",
        ));
    }
    stream.write_all(&encode_hello(engine))?;
    let mut wstream = stream.try_clone()?;
    let (tx, rx) = mpsc::channel::<WriteCmd>();
    std::thread::scope(|scope| {
        let writer = scope.spawn(move || writer_loop(&mut wstream, rx));
        let served = reader_loop(engine, &mut stream, shutdown, grace, tx);
        // `tx` was moved into reader_loop and dropped there: the writer
        // drains every queued response (workers are still running) and
        // exits; joining it completes the flush-before-close drain.
        let _ = writer.join();
        served
    })
}

/// Run the TCP front-end over `listener` until `shutdown` is set. Call
/// inside the serve target's driver (single engine or shard router —
/// identical wire behavior either way):
///
/// ```ignore
/// engine.serve(|eng| net::serve_net(eng, listener, &shutdown))??;
/// router.serve(|r| net::serve_net(r, listener, &shutdown))??;
/// ```
///
/// Connection errors (bad magic, oversized frames, mid-frame EOF) drop
/// that connection only; the listener keeps serving.
pub fn serve_net<T: ServeTarget>(
    engine: &T,
    listener: TcpListener,
    shutdown: &AtomicBool,
) -> Result<NetStats> {
    serve_net_with(engine, listener, shutdown, &NetServerConfig::default())
}

/// [`serve_net`] with an explicit [`NetServerConfig`] (drain grace for
/// idle connections after shutdown is signalled).
pub fn serve_net_with<T: ServeTarget>(
    engine: &T,
    listener: TcpListener,
    shutdown: &AtomicBool,
    cfg: &NetServerConfig,
) -> Result<NetStats> {
    let grace = cfg.drain_grace;
    listener
        .set_nonblocking(true)
        .map_err(|e| anyhow!("listener nonblocking: {e}"))?;
    let connections = AtomicU64::new(0);
    let requests = AtomicU64::new(0);
    std::thread::scope(|scope| {
        let mut backoff = AcceptBackoff::new();
        while !shutdown.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    backoff.accepted();
                    connections.fetch_add(1, Ordering::Relaxed);
                    let requests = &requests;
                    scope.spawn(move || match handle_conn(engine, stream, shutdown, grace) {
                        Ok(n) => {
                            requests.fetch_add(n, Ordering::Relaxed);
                        }
                        // I/O or protocol error dropped the connection;
                        // the listener keeps serving the rest.
                        Err(_) => engine.obs().net.dropped_conns.inc(),
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(backoff.idle_delay());
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(anyhow!("accept failed: {e}")),
            }
        }
        // Scope exit joins every connection handler: readers stop at the
        // shutdown flag, writers flush admitted responses, sockets close.
        Ok(())
    })?;
    Ok(NetStats {
        connections: connections.load(Ordering::Relaxed),
        requests: requests.load(Ordering::Relaxed),
    })
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// What the server advertises at connect time.
#[derive(Clone, Copy, Debug)]
pub struct Hello {
    pub seq: usize,
    pub vocab: usize,
    pub classes: usize,
    pub num_tasks: usize,
}

/// A blocking client connection. Requests may be pipelined: `send` any
/// number, then `recv` responses in the same order.
pub struct NetClient {
    stream: TcpStream,
    pub hello: Hello,
}

/// Translate a socket-timeout error kind into a clean, self-describing
/// error. Blocking sockets report an elapsed `SO_RCVTIMEO`/`SO_SNDTIMEO`
/// as `WouldBlock` (Unix) or `TimedOut` (Windows) — callers should see
/// "timed out", not a platform errno.
fn io_ctx(what: &str, e: std::io::Error) -> anyhow::Error {
    use std::io::ErrorKind;
    match e.kind() {
        ErrorKind::WouldBlock | ErrorKind::TimedOut => {
            anyhow!("{what}: timed out waiting on the socket")
        }
        _ => anyhow!("{what}: {e}"),
    }
}

impl NetClient {
    /// Connect and handshake with the default socket I/O timeout
    /// ([`DEFAULT_NET_TIMEOUT`]). A hung or partitioned server therefore
    /// surfaces as a clean "timed out" error rather than a permanent block.
    pub fn connect(addr: &str) -> Result<NetClient> {
        Self::connect_with(addr, Some(DEFAULT_NET_TIMEOUT))
    }

    /// Connect and handshake with an explicit socket I/O timeout applied
    /// to every read and write (`None` = block forever).
    pub fn connect_with(addr: &str, io_timeout: Option<Duration>) -> Result<NetClient> {
        let mut stream =
            TcpStream::connect(addr).map_err(|e| anyhow!("connect {addr}: {e}"))?;
        stream.set_nodelay(true).ok();
        stream
            .set_read_timeout(io_timeout)
            .map_err(|e| anyhow!("set read timeout: {e}"))?;
        stream
            .set_write_timeout(io_timeout)
            .map_err(|e| anyhow!("set write timeout: {e}"))?;
        stream
            .write_all(&WIRE_MAGIC)
            .map_err(|e| io_ctx("handshake write", e))?;
        let mut hello = [0u8; 20];
        stream
            .read_exact(&mut hello)
            .map_err(|e| io_ctx("handshake read", e))?;
        if hello[0..4] != WIRE_MAGIC {
            bail!("server answered with bad magic (not a MetaTT serving endpoint?)");
        }
        let word =
            |i: usize| u32::from_le_bytes(hello[i..i + 4].try_into().unwrap()) as usize;
        Ok(NetClient {
            stream,
            hello: Hello {
                seq: word(4),
                vocab: word(8),
                classes: word(12),
                num_tasks: word(16),
            },
        })
    }

    /// Connect with retries — absorbs the server-startup race when the
    /// client is launched right after the server process.
    pub fn connect_retry(addr: &str, timeout: Duration) -> Result<NetClient> {
        Self::connect_retry_with(addr, timeout, Some(DEFAULT_NET_TIMEOUT))
    }

    /// [`NetClient::connect_retry`] with an explicit per-socket I/O
    /// timeout for the connection once established.
    pub fn connect_retry_with(
        addr: &str,
        timeout: Duration,
        io_timeout: Option<Duration>,
    ) -> Result<NetClient> {
        let t0 = Instant::now();
        loop {
            match Self::connect_with(addr, io_timeout) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    if t0.elapsed() >= timeout {
                        return Err(e.context(format!("gave up after {timeout:?}")));
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    }

    /// Send one request frame (`deadline_us` 0 = no deadline).
    pub fn send(
        &mut self,
        id: u64,
        task: usize,
        priority: u8,
        deadline_us: u64,
        tokens: &[i32],
    ) -> Result<()> {
        let frame = encode_request(id, task, priority, deadline_us, tokens);
        self.stream.write_all(&frame).map_err(|e| io_ctx("send", e))
    }

    /// Receive the next response frame (blocking).
    pub fn recv(&mut self) -> Result<NetResponse> {
        let mut len4 = [0u8; 4];
        self.stream.read_exact(&mut len4).map_err(|e| io_ctx("recv", e))?;
        let body_len = u32::from_le_bytes(len4) as usize;
        if body_len > MAX_FRAME {
            bail!("response frame of {body_len} bytes exceeds the {MAX_FRAME} cap");
        }
        let mut body = vec![0u8; body_len];
        self.stream.read_exact(&mut body).map_err(|e| io_ctx("recv body", e))?;
        decode_response(&body)
    }

    /// One closed-loop round trip.
    pub fn call(
        &mut self,
        id: u64,
        task: usize,
        priority: u8,
        deadline_us: u64,
        tokens: &[i32],
    ) -> Result<NetResponse> {
        self.send(id, task, priority, deadline_us, tokens)?;
        self.recv()
    }

    /// Fetch the server's live metrics snapshot (`STAT` admin frame):
    /// Prometheus-style text from the serve target behind this connection.
    /// Do not interleave with pipelined requests awaiting `recv` — the
    /// snapshot is answered in order through the same writer.
    pub fn stat(&mut self) -> Result<String> {
        let mut frame = Vec::with_capacity(4 + STAT_BODY.len());
        put_u32(&mut frame, STAT_BODY.len() as u32);
        frame.extend_from_slice(STAT_BODY);
        self.stream.write_all(&frame).map_err(|e| io_ctx("stat send", e))?;
        let mut len4 = [0u8; 4];
        self.stream.read_exact(&mut len4).map_err(|e| io_ctx("stat recv", e))?;
        let body_len = u32::from_le_bytes(len4) as usize;
        if body_len > MAX_FRAME {
            bail!("stat frame of {body_len} bytes exceeds the {MAX_FRAME} cap");
        }
        let mut body = vec![0u8; body_len];
        self.stream.read_exact(&mut body).map_err(|e| io_ctx("stat recv body", e))?;
        let mut c = Cursor::new(&body);
        let _id = c.u64()?;
        let status = c.u8()?;
        if status != STATUS_STAT {
            bail!("expected a stat frame (status {STATUS_STAT}), got status {status}");
        }
        let n = c.u32()? as usize;
        let text = String::from_utf8_lossy(c.take(n)?).into_owned();
        c.done()?;
        Ok(text)
    }
}

// ---------------------------------------------------------------------------
// Retrying client
// ---------------------------------------------------------------------------

/// Retry/backoff policy for [`RetryClient`].
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Total attempts per request, including the first (>= 1).
    pub max_attempts: u32,
    /// Backoff before retry `k` (1-based) is `base_backoff * 2^(k-1)`,
    /// capped at `max_backoff`, then scaled by jitter in `[0.5, 1.0]`.
    pub base_backoff: Duration,
    pub max_backoff: Duration,
    /// Seed for the jitter stream — fixed seed, fixed delays.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 5,
            base_backoff: Duration::from_millis(20),
            max_backoff: Duration::from_secs(1),
            seed: 0,
        }
    }
}

impl RetryPolicy {
    /// The delay before 1-based retry `attempt`, jittered by `rng`.
    /// Exposed so tests can pin the schedule for a given seed.
    pub fn backoff_delay(&self, attempt: u32, rng: &mut Pcg64) -> Duration {
        let exp = attempt.saturating_sub(1).min(20);
        let raw = self
            .base_backoff
            .saturating_mul(1u32 << exp)
            .min(self.max_backoff);
        // Jitter in [0.5, 1.0] keeps retries from synchronising across
        // clients while never collapsing the delay to zero.
        raw.mul_f64(0.5 + 0.5 * rng.uniform_f64())
    }
}

/// A [`NetClient`] wrapper that survives connection loss: on any send or
/// receive failure it reconnects (with capped exponential backoff and
/// seeded jitter) and re-sends the request. Re-sending is safe because
/// serve computation is pure — responses are keyed by the caller-chosen
/// request id, and a request the server never admitted left no trace.
pub struct RetryClient {
    addr: String,
    connect_timeout: Duration,
    io_timeout: Option<Duration>,
    policy: RetryPolicy,
    rng: Pcg64,
    conn: Option<NetClient>,
    /// Round trips that needed at least one retry.
    pub retries: u64,
    /// Reconnects performed after a connection was lost mid-use
    /// (excludes each client's initial connect).
    pub reconnects: u64,
}

impl RetryClient {
    /// Lazily-connecting client for `addr`. `connect_timeout` bounds each
    /// (re)connect attempt loop; `io_timeout` applies per socket op.
    pub fn new(
        addr: &str,
        connect_timeout: Duration,
        io_timeout: Option<Duration>,
        policy: RetryPolicy,
    ) -> RetryClient {
        RetryClient {
            addr: addr.to_string(),
            connect_timeout,
            io_timeout,
            rng: Pcg64::with_stream(policy.seed, 0x4e7c),
            policy,
            conn: None,
            retries: 0,
            reconnects: 0,
        }
    }

    /// The server hello, connecting first if necessary.
    pub fn hello(&mut self) -> Result<Hello> {
        Ok(self.ensure()?.hello)
    }

    fn ensure(&mut self) -> Result<&mut NetClient> {
        if self.conn.is_none() {
            self.conn = Some(NetClient::connect_retry_with(
                &self.addr,
                self.connect_timeout,
                self.io_timeout,
            )?);
        }
        Ok(self.conn.as_mut().unwrap())
    }

    /// One round trip that retries across connection loss. Fails only
    /// after `max_attempts` consecutive failures for this request.
    pub fn call(
        &mut self,
        id: u64,
        task: usize,
        priority: u8,
        deadline_us: u64,
        tokens: &[i32],
    ) -> Result<NetResponse> {
        let mut attempt = 0u32;
        loop {
            attempt += 1;
            let had_conn = self.conn.is_some();
            let res = self
                .ensure()
                .and_then(|c| c.call(id, task, priority, deadline_us, tokens));
            match res {
                Ok(resp) => {
                    if resp.id != id {
                        // Ordering is per-connection; a stray id means the
                        // stream is out of sync. Drop it and retry fresh.
                        self.conn = None;
                        if attempt >= self.policy.max_attempts.max(1) {
                            bail!(
                                "request {id} failed after {attempt} attempts \
                                 (last response carried id {})",
                                resp.id
                            );
                        }
                    } else {
                        return Ok(resp);
                    }
                }
                Err(e) => {
                    self.conn = None;
                    if attempt >= self.policy.max_attempts.max(1) {
                        return Err(e.context(format!(
                            "request {id} failed after {attempt} attempts"
                        )));
                    }
                }
            }
            if had_conn {
                self.reconnects += 1;
            }
            self.retries += 1;
            std::thread::sleep(self.policy.backoff_delay(attempt, &mut self.rng));
        }
    }
}

/// Client-side knobs for [`run_net_load`].
#[derive(Clone, Copy, Debug)]
pub struct NetClientConfig {
    /// How long each client keeps retrying the initial connect.
    pub connect_timeout: Duration,
    /// Per-socket-operation timeout (`None` = block forever).
    pub io_timeout: Option<Duration>,
    /// Retry/backoff across connection loss; each client derives its own
    /// jitter stream from `retry.seed + client index`.
    pub retry: RetryPolicy,
}

impl Default for NetClientConfig {
    fn default() -> Self {
        NetClientConfig {
            connect_timeout: Duration::from_secs(10),
            io_timeout: Some(DEFAULT_NET_TIMEOUT),
            retry: RetryPolicy::default(),
        }
    }
}

/// What a closed-loop TCP client run measured (client side).
#[derive(Clone, Debug)]
pub struct NetLoadReport {
    pub total: usize,
    /// Computed responses.
    pub ok: usize,
    /// Responses shed with `Expired`.
    pub expired: usize,
    /// Error frames (validation / shutdown).
    pub errors: usize,
    pub elapsed: f64,
    /// Computed responses per second.
    pub throughput_rps: f64,
    /// send → receive round-trip of computed responses, seconds; None when
    /// nothing completed. Client **wall** clock: includes socket and
    /// client-side scheduling time.
    pub latency: Option<crate::bench::Stats>,
    /// admit → done on the **server's** clock (from the response frame's
    /// stage stamps), seconds — the engine-side latency the wall clock
    /// wraps. None when nothing completed or the server sent no stamps.
    pub engine_latency: Option<crate::bench::Stats>,
    /// Per-stage breakdown (queue-wait / batch-wait / compute / respond)
    /// from the same stamps.
    pub stages: Option<super::loadgen::StageBreakdown>,
    /// Round trips that needed at least one retry, across all clients.
    pub retries: u64,
    /// Mid-run reconnects after connection loss, across all clients.
    pub reconnects: u64,
}

/// Closed-loop clients over TCP: each thread opens its own connection,
/// derives its deterministic request stream from the server's hello
/// (seq/vocab/num-tasks travel in-band), and round-trips one request at a
/// time. The network twin of [`super::loadgen::run_load`]'s client half —
/// same streams, so a given `(seed, client, index)` asks the same question
/// in-process and over the wire.
pub fn run_net_load(
    addr: &str,
    cfg: &super::loadgen::LoadGenConfig,
    net: &NetClientConfig,
) -> Result<NetLoadReport> {
    if cfg.clients == 0 || cfg.requests_per_client == 0 {
        bail!(
            "net load needs >= 1 client and >= 1 request per client (got {} x {})",
            cfg.clients,
            cfg.requests_per_client
        );
    }
    let deadline_us = cfg.deadline.map_or(0, |d| d.as_micros() as u64);
    let t0 = Instant::now();
    type ClientOut = (Vec<f64>, Vec<[u64; 5]>, usize, usize, u64, u64);
    let per_client: Vec<ClientOut> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..cfg.clients)
            .map(|client| {
                scope.spawn(move || -> Result<ClientOut> {
                    // Each client gets its own jitter stream so backoffs
                    // de-synchronise even under a shared base seed.
                    let policy = RetryPolicy {
                        seed: net.retry.seed.wrapping_add(client as u64),
                        ..net.retry
                    };
                    let mut conn = RetryClient::new(
                        addr,
                        net.connect_timeout,
                        net.io_timeout,
                        policy,
                    );
                    let hello = conn.hello()?;
                    let stream = super::loadgen::request_stream(
                        cfg,
                        hello.num_tasks,
                        hello.seq,
                        hello.vocab,
                        client,
                        cfg.requests_per_client,
                    );
                    let mut lats = Vec::with_capacity(stream.len());
                    let mut stamp_rows = Vec::with_capacity(stream.len());
                    let (mut expired, mut errors) = (0usize, 0usize);
                    for (i, (task, tokens)) in stream.into_iter().enumerate() {
                        let id = ((client as u64) << 32) | i as u64;
                        let sent = Instant::now();
                        let resp =
                            conn.call(id, task, cfg.priority, deadline_us, &tokens)?;
                        match resp.status {
                            WireStatus::Ok => {
                                lats.push(sent.elapsed().as_secs_f64());
                                // Computed responses from a stamping server
                                // carry a full lifecycle (start > 0).
                                if resp.start_us > 0 {
                                    stamp_rows.push([
                                        resp.admit_us,
                                        resp.batch_us,
                                        resp.start_us,
                                        resp.end_us,
                                        resp.done_us,
                                    ]);
                                }
                            }
                            WireStatus::Expired => expired += 1,
                            WireStatus::Error => errors += 1,
                        }
                        if cfg.think_us > 0 {
                            std::thread::sleep(Duration::from_micros(cfg.think_us));
                        }
                    }
                    Ok((lats, stamp_rows, expired, errors, conn.retries, conn.reconnects))
                })
            })
            .collect();
        let mut results = Vec::with_capacity(handles.len());
        for h in handles {
            results.push(h.join().map_err(|_| anyhow!("net load client panicked"))??);
        }
        Ok::<_, anyhow::Error>(results)
    })?;
    let elapsed = t0.elapsed().as_secs_f64();
    let mut lats = Vec::new();
    let mut stamp_rows = Vec::new();
    let (mut expired, mut errors) = (0usize, 0usize);
    let (mut retries, mut reconnects) = (0u64, 0u64);
    for (l, s, e, x, r, rc) in per_client {
        lats.extend(l);
        stamp_rows.extend(s);
        expired += e;
        errors += x;
        retries += r;
        reconnects += rc;
    }
    let ok = lats.len();
    let engine_lats: Vec<f64> = stamp_rows
        .iter()
        .map(|r| r[4].saturating_sub(r[0]) as f64 / 1e6)
        .collect();
    Ok(NetLoadReport {
        total: ok + expired + errors,
        ok,
        expired,
        errors,
        elapsed,
        throughput_rps: ok as f64 / elapsed.max(1e-9),
        latency: if lats.is_empty() {
            None
        } else {
            Some(crate::bench::Stats::from_samples(lats))
        },
        engine_latency: if engine_lats.is_empty() {
            None
        } else {
            Some(crate::bench::Stats::from_samples(engine_lats))
        },
        stages: super::loadgen::StageBreakdown::from_stamp_rows(&stamp_rows),
        retries,
        reconnects,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_frame_round_trips() {
        let tokens = vec![1i32, 5, 9, 1023, 0];
        let frame = encode_request(42, 2, 3, 1_500_000, &tokens);
        let body_len = u32::from_le_bytes(frame[0..4].try_into().unwrap()) as usize;
        assert_eq!(body_len, frame.len() - 4);
        let wire = decode_request(&frame[4..]).unwrap();
        assert_eq!(wire.id, 42);
        assert_eq!(wire.task, 2);
        assert_eq!(wire.priority, 3);
        assert_eq!(wire.deadline_us, 1_500_000);
        assert_eq!(wire.tokens, tokens);
    }

    #[test]
    fn response_frame_round_trips_logit_bits() {
        // Include values whose bit patterns are easy to corrupt: negative
        // zero, subnormals, and a NaN payload.
        let logits = vec![1.5f32, -0.0, f32::from_bits(0x0000_0001), f32::from_bits(0x7fc0_1234)];
        let frame = encode_response(7, WireStatus::Ok, 1, 3, 4, &logits, [10, 20, 30, 40, 50]);
        let got = decode_response(&frame[4..]).unwrap();
        assert_eq!(got.id, 7);
        assert_eq!(got.status, WireStatus::Ok);
        assert_eq!(got.task, 1);
        assert_eq!(got.generation, 3);
        assert_eq!(got.batch_rows, 4);
        assert_eq!(got.logits.len(), logits.len());
        for (a, b) in got.logits.iter().zip(&logits) {
            assert_eq!(a.to_bits(), b.to_bits(), "logit bits must survive the wire");
        }
        assert_eq!(
            (got.admit_us, got.batch_us, got.start_us, got.end_us, got.done_us),
            (10, 20, 30, 40, 50),
            "stage stamps must survive the wire"
        );
        let expired = encode_response(8, WireStatus::Expired, 2, 0, 0, &[], [0; 5]);
        let got = decode_response(&expired[4..]).unwrap();
        assert_eq!(got.status, WireStatus::Expired);
        assert!(got.logits.is_empty());
    }

    #[test]
    fn stampless_response_frames_still_decode() {
        // A pre-PR10 peer's frame: same layout, no trailing stamps. The
        // decoder must tolerate it and report zero stamps.
        let full = encode_response(7, WireStatus::Ok, 1, 3, 4, &[1.0f32, 2.0], [9; 5]);
        let legacy_body = &full[4..full.len() - 40];
        let got = decode_response(legacy_body).unwrap();
        assert_eq!(got.id, 7);
        assert_eq!(got.logits, vec![1.0f32, 2.0]);
        assert_eq!(got.done_us, 0, "absent stamps decode as zeros");
    }

    #[test]
    fn stat_frame_round_trips() {
        let text = "# TYPE metatt_engine_requests_total counter\nmetatt_engine_requests_total 42\n";
        let frame = encode_stat(0, text);
        let body = &frame[4..];
        let mut c = Cursor::new(body);
        assert_eq!(c.u64().unwrap(), 0);
        assert_eq!(c.u8().unwrap(), STATUS_STAT);
        let n = c.u32().unwrap() as usize;
        assert_eq!(std::str::from_utf8(c.take(n).unwrap()).unwrap(), text);
        c.done().unwrap();
        // decode_response refuses the admin status — stat frames are only
        // parsed by NetClient::stat, never mixed into the response path.
        assert!(decode_response(body).is_err());
    }

    #[test]
    fn error_frame_round_trips() {
        let frame = encode_error(99, "task 7 out of range (3 served)");
        let got = decode_response(&frame[4..]).unwrap();
        assert_eq!(got.id, 99);
        assert_eq!(got.status, WireStatus::Error);
        assert_eq!(got.error.as_deref(), Some("task 7 out of range (3 served)"));
    }

    #[test]
    fn malformed_frames_are_clean_errors() {
        // Truncated body.
        let frame = encode_request(1, 0, 0, 0, &[1, 2, 3]);
        assert!(decode_request(&frame[4..frame.len() - 2]).is_err());
        // Trailing garbage.
        let mut long = frame[4..].to_vec();
        long.push(0xab);
        assert!(decode_request(&long).is_err());
        // Token count beyond the frame cap.
        let mut huge = Vec::new();
        put_u64(&mut huge, 1);
        put_u32(&mut huge, 0);
        huge.push(0);
        put_u64(&mut huge, 0);
        put_u32(&mut huge, u32::MAX);
        assert!(decode_request(&huge).is_err());
        // Unknown status byte.
        let mut bad = Vec::new();
        put_u64(&mut bad, 1);
        bad.push(17);
        assert!(decode_response(&bad).is_err());
    }

    #[test]
    fn backoff_schedule_is_seed_deterministic_capped_and_jittered() {
        let policy = RetryPolicy {
            max_attempts: 8,
            base_backoff: Duration::from_millis(20),
            max_backoff: Duration::from_millis(100),
            seed: 7,
        };
        let schedule = |seed: u64| -> Vec<Duration> {
            let mut rng = Pcg64::with_stream(seed, 0x4e7c);
            (1..=6).map(|k| policy.backoff_delay(k, &mut rng)).collect()
        };
        assert_eq!(schedule(7), schedule(7), "same seed, same delays");
        assert_ne!(schedule(7), schedule(8), "different seed, different jitter");
        let mut rng = Pcg64::with_stream(7, 0x4e7c);
        for (k, d) in (1u32..=6).map(|k| (k, policy.backoff_delay(k, &mut rng))) {
            let raw = policy
                .base_backoff
                .saturating_mul(1 << (k - 1))
                .min(policy.max_backoff);
            assert!(d >= raw.mul_f64(0.5) && d <= raw, "attempt {k}: {d:?} vs {raw:?}");
            // The cap binds from attempt 4 on (20 * 2^3 = 160 > 100).
            if k >= 4 {
                assert!(d <= policy.max_backoff);
            }
        }
    }

    #[test]
    fn idle_accept_loop_does_not_burn_a_core_of_syscalls() {
        // Regression: the accept loop used a fixed 5 ms poll, i.e. an idle
        // server woke up and issued ~200 accept syscalls every second,
        // forever. Each idle_delay() call below corresponds to exactly one
        // accept syscall, so summing delays to one second counts the
        // idle-second syscall budget.
        let mut b = AcceptBackoff::new();
        let mut polls = 0u32;
        let mut slept = Duration::ZERO;
        while slept < Duration::from_secs(1) {
            slept += b.idle_delay();
            polls += 1;
        }
        // Doubling from 1 ms caps at 50 ms within 7 polls; an idle second
        // then costs ~25 polls. Assert well under the old 200/s.
        assert!(polls <= 40, "an idle second should need few polls, got {polls}");
        // A burst resets the backoff: the poll right after an accept is at
        // the minimum again, so accept latency stays snappy under load.
        b.accepted();
        assert!(b.idle_delay() <= ACCEPT_POLL_MIN);
        // The schedule is monotone and capped.
        let mut prev = Duration::ZERO;
        for _ in 0..20 {
            let d = b.idle_delay();
            assert!(d >= prev && d <= ACCEPT_POLL_MAX, "delay {d:?} out of order/cap");
            prev = d;
        }
    }
}
